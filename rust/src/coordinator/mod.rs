//! Coordinator layer: superstep-synchronized **global aggregators**
//! (the paper's §4.2 manager-side coordination contribution).
//!
//! A program registers named aggregators ([`AggregatorSpec`]) — each a
//! commutative monoid over `f64` ([`AggOp`]: sum / min / max / count, or
//! a user-supplied fold with its identity). During a superstep every
//! compute unit folds contributions into a worker-local partial vector;
//! at the sync barrier each worker ships its partial to the manager,
//! whose [`Coordinator`] folds the partials into one global vector and
//! re-broadcasts it with the *resume* command. Programs read the folded
//! values at the **next** superstep (classic Pregel aggregator
//! visibility), which is exactly what convergence-driven termination
//! needs: report a residual this superstep, observe the global residual
//! next superstep, vote to halt when it drops under a threshold.
//!
//! The per-superstep global values are also recorded as
//! [`AggregatorTrace`]s and surfaced through
//! [`crate::metrics::JobMetrics::aggregators`], so benches and the CLI
//! can plot convergence curves without re-running the job.
//!
//! Why monoids over `f64`: folds must be insensitive to worker count and
//! fold order (workers sync in arbitrary order), so associativity +
//! commutativity + identity are the contract; `f64` keeps the control
//! plane schema-free while covering counts, residuals, and extrema. The
//! design is engine-agnostic — `gopher::engine` threads it through its
//! manager/worker protocol, and nothing here depends on Gopher types.
//!
//! The barrier is also where external supervision attaches: a
//! [`RunControl`] handle (shared atomics) lets the `serve` layer watch
//! per-superstep progress and request cancellation, which the managers
//! honor at the next barrier.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;

/// Shared live-control handle for one run: an external supervisor (the
/// `serve` job registry) watches per-superstep progress and can request
/// cancellation; both engines' managers touch it at every barrier.
///
/// Cloning shares the underlying atomics, so a handle kept by the
/// supervisor observes the engine's updates. Everything is lock-free —
/// the manager writes once per barrier, observers poll — and the type
/// stays `Clone + Debug + Default` so the engine configs can keep their
/// derives.
///
/// Beyond the superstep number, the barrier publishes the run's
/// cumulative message/byte counts and the just-completed superstep's
/// straggler ratio (§6.5: slowest partition compute / next-slowest) —
/// the live series `GET /v1/metrics?format=prometheus` exposes per
/// running job.
#[derive(Clone, Debug)]
pub struct RunControl {
    cancel: Arc<AtomicBool>,
    superstep: Arc<AtomicUsize>,
    messages: Arc<AtomicU64>,
    bytes: Arc<AtomicU64>,
    /// Straggler ratio of the last completed superstep, stored as
    /// `f64::to_bits` (atomics carry no floats).
    straggler: Arc<AtomicU64>,
    /// Async-checkpoint flush operations enqueued but not yet durable
    /// (0 for sync-mode and checkpoint-free runs).
    ckpt_inflight: Arc<AtomicU64>,
}

impl Default for RunControl {
    fn default() -> RunControl {
        RunControl {
            cancel: Arc::default(),
            superstep: Arc::default(),
            messages: Arc::default(),
            bytes: Arc::default(),
            // Seed with 1.0 ("nobody has straggled yet") so readers need
            // no zero-bits sentinel — which would also be the bit pattern
            // of a legitimately published 0.0.
            straggler: Arc::new(AtomicU64::new(1.0f64.to_bits())),
            ckpt_inflight: Arc::default(),
        }
    }
}

impl RunControl {
    /// Fresh handle: not cancelled, zero supersteps completed.
    pub fn new() -> RunControl {
        RunControl::default()
    }

    /// Request cancellation. The manager honors it at the next barrier
    /// (so the job stops within one superstep) and the run errors out
    /// with a "cancelled" failure instead of returning partial output.
    pub fn cancel(&self) {
        self.cancel.store(true, Ordering::Relaxed);
    }

    /// Whether cancellation has been requested.
    pub fn is_cancelled(&self) -> bool {
        self.cancel.load(Ordering::Relaxed)
    }

    /// Manager-side: record that barrier `superstep` completed.
    pub fn publish_superstep(&self, superstep: usize) {
        self.superstep.store(superstep, Ordering::Relaxed);
    }

    /// Manager-side: publish cumulative traffic and the completed
    /// superstep's straggler ratio alongside the barrier.
    pub fn publish_progress(&self, messages: u64, bytes: u64, straggler: f64) {
        self.messages.store(messages, Ordering::Relaxed);
        self.bytes.store(bytes, Ordering::Relaxed);
        self.straggler.store(straggler.to_bits(), Ordering::Relaxed);
    }

    /// Observer-side: the last completed superstep (0 before the first
    /// barrier).
    pub fn superstep(&self) -> usize {
        self.superstep.load(Ordering::Relaxed)
    }

    /// Observer-side: cumulative data messages sent so far.
    pub fn messages(&self) -> u64 {
        self.messages.load(Ordering::Relaxed)
    }

    /// Observer-side: cumulative encoded data bytes sent so far.
    pub fn bytes(&self) -> u64 {
        self.bytes.load(Ordering::Relaxed)
    }

    /// Observer-side: straggler ratio of the last completed superstep
    /// (`1.0` before the first barrier: nobody has straggled yet).
    pub fn straggler_ratio(&self) -> f64 {
        f64::from_bits(self.straggler.load(Ordering::Relaxed))
    }

    /// Manager-side: publish the async checkpoint backlog (flush
    /// operations enqueued but not yet durable) at the barrier. Sync
    /// runs publish 0.
    pub fn publish_ckpt_inflight(&self, inflight: u64) {
        self.ckpt_inflight.store(inflight, Ordering::Relaxed);
    }

    /// Observer-side: the async checkpoint backlog as of the last
    /// barrier (the `goffish_ckpt_inflight` gauge).
    pub fn ckpt_inflight(&self) -> u64 {
        self.ckpt_inflight.load(Ordering::Relaxed)
    }
}

/// A commutative monoid over `f64`: the fold applied worker-side per
/// contribution and manager-side across workers.
#[derive(Clone, Copy, Debug)]
pub enum AggOp {
    /// `a + b`, identity `0.0`.
    Sum,
    /// `min(a, b)`, identity `+inf`.
    Min,
    /// `max(a, b)`, identity `-inf`.
    Max,
    /// `a + b`, identity `0.0` — semantically "number of events"; kept
    /// distinct from [`AggOp::Sum`] so traces self-describe.
    Count,
    /// User-defined monoid: `fold` must be associative and commutative
    /// with `identity` as its neutral element.
    Custom { identity: f64, fold: fn(f64, f64) -> f64 },
}

impl AggOp {
    /// The monoid's neutral element.
    pub fn identity(&self) -> f64 {
        match self {
            AggOp::Sum | AggOp::Count => 0.0,
            AggOp::Min => f64::INFINITY,
            AggOp::Max => f64::NEG_INFINITY,
            AggOp::Custom { identity, .. } => *identity,
        }
    }

    /// Fold two values.
    pub fn fold(&self, a: f64, b: f64) -> f64 {
        match self {
            AggOp::Sum | AggOp::Count => a + b,
            AggOp::Min => a.min(b),
            AggOp::Max => a.max(b),
            AggOp::Custom { fold, .. } => fold(a, b),
        }
    }
}

/// One named aggregator slot registered by a program.
#[derive(Clone, Debug)]
pub struct AggregatorSpec {
    pub name: &'static str,
    pub op: AggOp,
}

impl AggregatorSpec {
    pub fn new(name: &'static str, op: AggOp) -> AggregatorSpec {
        AggregatorSpec { name, op }
    }
}

/// The registry of aggregators for one job (shared by every worker and
/// the manager; slot order is the wire order of partial vectors).
#[derive(Clone, Debug, Default)]
pub struct Aggregators {
    specs: Vec<AggregatorSpec>,
}

impl Aggregators {
    pub fn new(specs: Vec<AggregatorSpec>) -> Aggregators {
        Aggregators { specs }
    }

    pub fn is_empty(&self) -> bool {
        self.specs.is_empty()
    }

    pub fn len(&self) -> usize {
        self.specs.len()
    }

    pub fn specs(&self) -> &[AggregatorSpec] {
        &self.specs
    }

    /// Slot index of a named aggregator.
    pub fn index_of(&self, name: &str) -> Option<usize> {
        self.specs.iter().position(|s| s.name == name)
    }

    /// A fresh partial vector holding every slot's identity.
    pub fn identity_values(&self) -> Vec<f64> {
        self.specs.iter().map(|s| s.op.identity()).collect()
    }

    /// Fold a contribution vector into an accumulator, slot-wise. Short
    /// contributions (e.g. from a failed worker) fold what they carry.
    pub fn fold_into(&self, acc: &mut [f64], contrib: &[f64]) {
        for (i, &c) in contrib.iter().enumerate() {
            if i < acc.len() {
                acc[i] = self.specs[i].op.fold(acc[i], c);
            }
        }
    }
}

/// Per-superstep global values of one aggregator across a whole job.
#[derive(Clone, Debug)]
pub struct AggregatorTrace {
    pub name: String,
    /// `values[s]` = folded global value at the end of superstep `s+1`.
    pub values: Vec<f64>,
}

impl AggregatorTrace {
    /// The final folded value (None for a job with zero supersteps).
    pub fn last(&self) -> Option<f64> {
        self.values.last().copied()
    }
}

/// Manager-side state: folds worker partials at each superstep barrier
/// and keeps the full per-superstep history.
#[derive(Clone, Debug)]
pub struct Coordinator {
    aggs: Aggregators,
    history: Vec<Vec<f64>>,
}

impl Coordinator {
    pub fn new(aggs: Aggregators) -> Coordinator {
        Coordinator { aggs, history: Vec::new() }
    }

    /// Rebuild a coordinator from a checkpointed history (entry `s` =
    /// globals folded at barrier `s+1`): a resumed job's traces cover
    /// the whole run, not just the supersteps after the restart — the
    /// recovery-parity requirement on `JobOutput::aggregators`.
    pub fn with_history(aggs: Aggregators, history: Vec<Vec<f64>>) -> Coordinator {
        Coordinator { aggs, history }
    }

    pub fn aggregators(&self) -> &Aggregators {
        &self.aggs
    }

    /// Fold one superstep's worker partials into the global vector,
    /// record it in the history, and return it (the manager broadcasts
    /// the returned vector with the resume command).
    pub fn fold_superstep(&mut self, partials: &[Vec<f64>]) -> Vec<f64> {
        let mut acc = self.aggs.identity_values();
        for p in partials {
            self.aggs.fold_into(&mut acc, p);
        }
        self.history.push(acc.clone());
        acc
    }

    /// Global values per completed superstep (same order as folded).
    pub fn history(&self) -> &[Vec<f64>] {
        &self.history
    }

    /// Convert the history into per-aggregator traces for `JobMetrics`.
    pub fn into_traces(self) -> Vec<AggregatorTrace> {
        let mut traces: Vec<AggregatorTrace> = self
            .aggs
            .specs
            .iter()
            .map(|s| AggregatorTrace { name: s.name.to_string(), values: Vec::new() })
            .collect();
        for step in &self.history {
            for (i, &v) in step.iter().enumerate() {
                traces[i].values.push(v);
            }
        }
        traces
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ops_fold_with_identities() {
        for op in [AggOp::Sum, AggOp::Min, AggOp::Max, AggOp::Count] {
            assert_eq!(op.fold(op.identity(), 3.5), 3.5, "{op:?}");
            assert_eq!(op.fold(3.5, op.identity()), 3.5, "{op:?}");
        }
        assert_eq!(AggOp::Sum.fold(2.0, 3.0), 5.0);
        assert_eq!(AggOp::Min.fold(2.0, 3.0), 2.0);
        assert_eq!(AggOp::Max.fold(2.0, 3.0), 3.0);
        assert_eq!(AggOp::Count.fold(4.0, 1.0), 5.0);
    }

    #[test]
    fn custom_monoid() {
        fn product(a: f64, b: f64) -> f64 {
            a * b
        }
        let op = AggOp::Custom { identity: 1.0, fold: product };
        assert_eq!(op.identity(), 1.0);
        assert_eq!(op.fold(op.identity(), 6.0), 6.0);
        assert_eq!(op.fold(2.0, 3.0), 6.0);
    }

    fn two_aggs() -> Aggregators {
        Aggregators::new(vec![
            AggregatorSpec::new("delta", AggOp::Sum),
            AggregatorSpec::new("coldest", AggOp::Min),
        ])
    }

    #[test]
    fn registry_lookup_and_identities() {
        let aggs = two_aggs();
        assert_eq!(aggs.len(), 2);
        assert!(!aggs.is_empty());
        assert_eq!(aggs.index_of("delta"), Some(0));
        assert_eq!(aggs.index_of("coldest"), Some(1));
        assert_eq!(aggs.index_of("missing"), None);
        let ids = aggs.identity_values();
        assert_eq!(ids[0], 0.0);
        assert!(ids[1].is_infinite() && ids[1] > 0.0);
    }

    #[test]
    fn fold_into_is_slotwise_and_tolerates_short_vectors() {
        let aggs = two_aggs();
        let mut acc = aggs.identity_values();
        aggs.fold_into(&mut acc, &[2.0, 5.0]);
        aggs.fold_into(&mut acc, &[3.0, 1.0]);
        assert_eq!(acc, vec![5.0, 1.0]);
        // A failed worker ships an empty partial: a no-op.
        aggs.fold_into(&mut acc, &[]);
        assert_eq!(acc, vec![5.0, 1.0]);
    }

    #[test]
    fn coordinator_folds_and_traces() {
        let mut c = Coordinator::new(two_aggs());
        let g1 = c.fold_superstep(&[vec![1.0, 9.0], vec![2.0, 4.0]]);
        assert_eq!(g1, vec![3.0, 4.0]);
        let g2 = c.fold_superstep(&[vec![0.5, 7.0]]);
        assert_eq!(g2, vec![0.5, 7.0]);
        assert_eq!(c.history().len(), 2);
        let traces = c.into_traces();
        assert_eq!(traces.len(), 2);
        assert_eq!(traces[0].name, "delta");
        assert_eq!(traces[0].values, vec![3.0, 0.5]);
        assert_eq!(traces[1].values, vec![4.0, 7.0]);
        assert_eq!(traces[0].last(), Some(0.5));
    }

    #[test]
    fn fold_order_does_not_matter() {
        let aggs = two_aggs();
        let parts = [vec![1.0, 3.0], vec![4.0, 2.0], vec![2.0, 8.0]];
        let mut a = Coordinator::new(aggs.clone());
        let mut b = Coordinator::new(aggs);
        let fwd = a.fold_superstep(&parts);
        let rev: Vec<Vec<f64>> = parts.iter().rev().cloned().collect();
        let bwd = b.fold_superstep(&rev);
        assert_eq!(fwd, bwd);
    }

    #[test]
    fn run_control_is_shared_across_clones() {
        let ctl = RunControl::new();
        let observer = ctl.clone();
        assert!(!observer.is_cancelled());
        assert_eq!(observer.superstep(), 0);
        ctl.publish_superstep(7);
        ctl.cancel();
        assert!(observer.is_cancelled());
        assert_eq!(observer.superstep(), 7);
        // Progress defaults: no traffic, straggler 1.0 pre-barrier.
        assert_eq!(observer.messages(), 0);
        assert_eq!(observer.bytes(), 0);
        assert_eq!(observer.straggler_ratio(), 1.0);
        ctl.publish_progress(120, 960, 2.5);
        assert_eq!(observer.messages(), 120);
        assert_eq!(observer.bytes(), 960);
        assert!((observer.straggler_ratio() - 2.5).abs() < 1e-12);
    }

    #[test]
    fn empty_registry_is_free() {
        let mut c = Coordinator::new(Aggregators::default());
        assert!(c.aggregators().is_empty());
        let g = c.fold_superstep(&[Vec::new(), Vec::new()]);
        assert!(g.is_empty());
        assert!(c.into_traces().is_empty());
    }
}
