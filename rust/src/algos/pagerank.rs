//! PageRank (paper §5.3) — classic damped PageRank in both models.
//!
//! The paper's Gopher PageRank "simulates one iteration of vertex rank
//! updates within a sub-graph per superstep, running for the same 30
//! supersteps as Giraph": no superstep savings, which is exactly why
//! PageRank is Gopher's weakest case (Fig 4a, LJ). We reproduce that
//! faithfully: per superstep each sub-graph performs one rank update over
//! its local topology; contributions across remote edges travel as
//! messages.
//!
//! On top of the faithful fixed-iteration mode, [`PageRankSg::epsilon`]
//! enables **aggregator-driven convergence** via the coordinator layer:
//! every sub-graph reports its local L1 rank delta into the global
//! `pr_l1_delta` sum, and once the folded global delta drops below
//! `epsilon` every sub-graph votes to halt on the same superstep — the
//! termination machinery Giraph-style aggregators exist for. Remote
//! contributions also fold through a combiner (sum per target vertex),
//! cutting bytes on the wire.
//!
//! The per-sub-graph rank update is the numeric hot spot, and is
//! pluggable via [`RankKernel`]:
//! * [`RankKernel::Scalar`] — CSR in-edge loop in Rust;
//! * [`RankKernel::Xla`] — the AOT-compiled Pallas/JAX `pagerank_step`
//!   block kernel via PJRT (paper §7's "fast shared-memory kernels").
//!
//! Semantics (both models, matching Pregel's canonical PageRank): ranks
//! start at `1/N`; each update is `0.15/N + 0.85 * Σ contribs`; dangling
//! vertices leak mass (no redistribution), as in Pregel/Giraph.

use std::sync::Arc;

use crate::ckpt::StateCodec;
use crate::coordinator::{AggOp, AggregatorSpec};
use crate::gofs::Subgraph;
use crate::gopher::{IncomingMessage, SubgraphContext, SubgraphProgram};
use crate::graph::csr::{Graph, VertexId};
use crate::pregel::{VertexContext, VertexProgram};
use crate::runtime::XlaEngine;

pub const DEFAULT_SUPERSTEPS: usize = 30;
pub const ALPHA: f32 = 0.85;

/// Name of the global L1 rank-delta aggregator (Sum).
pub const AGG_L1_DELTA: &str = "pr_l1_delta";

/// Which implementation computes the per-sub-graph rank update.
#[derive(Clone, Default)]
pub enum RankKernel {
    #[default]
    Scalar,
    /// AOT XLA executable ladder (falls back to scalar for sub-graphs
    /// larger than the largest compiled rung).
    Xla(Arc<XlaEngine>),
}

/// Sub-graph centric PageRank.
pub struct PageRankSg {
    /// Superstep cap; with `epsilon: None` this is the exact run length
    /// (the paper's fixed-iteration mode).
    pub supersteps: usize,
    pub kernel: RankKernel,
    /// When set, terminate early once the global L1 rank delta (folded
    /// by the coordinator's `pr_l1_delta` aggregator) drops below this.
    pub epsilon: Option<f32>,
}

impl Default for PageRankSg {
    fn default() -> Self {
        Self {
            supersteps: DEFAULT_SUPERSTEPS,
            kernel: RankKernel::Scalar,
            epsilon: None,
        }
    }
}

/// Per-sub-graph PageRank state.
pub struct PrState {
    pub ranks: Vec<f32>,
    /// Global out-degree (local + remote out-edges) per local vertex.
    outdeg: Vec<f32>,
    /// Padded dense in-adjacency for the XLA path (built once at init).
    dense: Option<DenseBlock>,
}

struct DenseBlock {
    n_pad: usize,
    /// Service-side registered adjacency block id: the padded in-link
    /// matrix is constant across supersteps, so it is uploaded once at
    /// init instead of copied into every kernel call (§Perf).
    block: u64,
}

/// Checkpoint codec for [`PrState`] — satisfies the `State: StateCodec`
/// bound, but [`PageRankSg`] overrides *both* checkpoint hooks to
/// persist only the ranks: out-degrees recompute identically from
/// topology, and the XLA `dense` block is a registered service handle
/// that cannot survive a process restart (decoding alone yields
/// `dense: None`, i.e. the scalar path).
impl StateCodec for PrState {
    fn encode_state(&self, e: &mut crate::util::codec::Encoder) {
        self.ranks.encode_state(e);
        self.outdeg.encode_state(e);
    }
    fn decode_state(d: &mut crate::util::codec::Decoder) -> anyhow::Result<Self> {
        Ok(PrState {
            ranks: Vec::<f32>::decode_state(d)?,
            outdeg: Vec::<f32>::decode_state(d)?,
            dense: None,
        })
    }
}

impl PageRankSg {
    /// One rank update over the sub-graph's *local* topology, reading
    /// `state.ranks` (previous superstep) and writing the new ranks.
    /// Remote contributions are added by the caller.
    fn rank_update(&self, state: &PrState, sg: &Subgraph, base: f32) -> Vec<f32> {
        if let (RankKernel::Xla(engine), Some(dense)) = (&self.kernel, &state.dense) {
            let n = sg.num_vertices();
            let mut ranks = vec![0f32; dense.n_pad];
            ranks[..n].copy_from_slice(&state.ranks);
            // Padding rows carry out_deg = -1 (the model's "dead" marker).
            let mut out_deg = vec![-1f32; dense.n_pad];
            out_deg[..n].copy_from_slice(&state.outdeg);
            if let Ok(out) = engine.pagerank_step_cached(
                dense.n_pad,
                dense.block,
                &ranks,
                &out_deg,
                base,
                ALPHA,
            ) {
                return out[..n].to_vec();
            }
            // XLA failure falls through to the scalar path (correctness
            // first; failures are surfaced by runtime's own tests).
        }
        // Scalar: new[u] = base + alpha * sum over local in-edges of
        // rank[v]/outdeg[v].
        let n = sg.num_vertices();
        let contrib: Vec<f32> = state
            .ranks
            .iter()
            .zip(&state.outdeg)
            .map(|(&r, &d)| if d > 0.0 { r / d } else { 0.0 })
            .collect();
        let mut out = vec![0f32; n];
        for (u, o) in out.iter_mut().enumerate() {
            let mut acc = 0f32;
            for v in sg.local.in_neighbors(u as u32) {
                acc += contrib[*v as usize];
            }
            *o = base + ALPHA * acc;
        }
        out
    }
}

impl SubgraphProgram for PageRankSg {
    type Msg = (u32, f32); // (global vertex id, contribution)
    type State = PrState;

    fn init(&self, sg: &Subgraph) -> PrState {
        let n = sg.num_vertices();
        let mut outdeg = vec![0f32; n];
        for (v, d) in outdeg.iter_mut().enumerate() {
            *d = sg.local.out_degree(v as u32) as f32;
        }
        for r in &sg.remote_out {
            outdeg[r.local as usize] += 1.0;
        }
        let dense = match &self.kernel {
            RankKernel::Xla(engine) if n <= engine.max_rung() => {
                let n_pad = engine.rung_for(n).expect("n <= max rung");
                let mut adj = vec![0f32; n_pad * n_pad];
                for (v, u, _) in sg.local.edges() {
                    // edge v -> u: in-adjacency A[u][v] = 1
                    adj[u as usize * n_pad + v as usize] = 1.0;
                }
                engine
                    .register_block(n_pad, &adj)
                    .ok()
                    .map(|block| DenseBlock { n_pad, block })
            }
            _ => None,
        };
        PrState { ranks: vec![0.0; n], outdeg, dense }
    }

    fn compute(
        &self,
        state: &mut PrState,
        sg: &Subgraph,
        ctx: &mut SubgraphContext<'_, Self::Msg>,
        msgs: &[IncomingMessage<Self::Msg>],
    ) {
        let n_total = sg.num_global_vertices as f32;
        let base = (1.0 - ALPHA) / n_total;
        let s = ctx.superstep();

        if s == 1 {
            state.ranks = vec![1.0 / n_total; sg.num_vertices()];
        } else {
            // Local rank update from the previous superstep's ranks…
            let mut new_ranks = self.rank_update(state, sg, base);
            // …plus remote contributions that arrived as messages.
            for m in msgs {
                let (gv, c) = m.payload;
                if let Some(local) = ctx.local_vertex(gv) {
                    new_ranks[local as usize] += ALPHA * c;
                }
            }
            if self.epsilon.is_some() {
                let delta: f64 = state
                    .ranks
                    .iter()
                    .zip(&new_ranks)
                    .map(|(&a, &b)| (a - b).abs() as f64)
                    .sum();
                if let Some(slot) = ctx.aggregator(AGG_L1_DELTA) {
                    ctx.aggregate(slot, delta);
                }
            }
            state.ranks = new_ranks;
        }

        // Convergence mode: the global delta folded at the end of
        // superstep s-1 is visible now, and every sub-graph observes the
        // same value — so all halt on the same superstep. Deltas are
        // first reported at s=2, hence first visible at s=3.
        let converged = match self.epsilon {
            Some(eps) if s >= 3 => ctx
                .aggregator(AGG_L1_DELTA)
                .and_then(|slot| ctx.aggregated(slot))
                .is_some_and(|global_delta| global_delta < eps as f64),
            _ => false,
        };

        if s < self.supersteps && !converged {
            // Send this superstep's contributions over remote out-edges.
            for r in &sg.remote_out {
                let d = state.outdeg[r.local as usize];
                if d > 0.0 {
                    ctx.send_to_subgraph_vertex(
                        crate::gofs::SubgraphId {
                            partition: r.partition,
                            index: r.subgraph,
                        },
                        r.target_global,
                        (r.target_global, state.ranks[r.local as usize] / d),
                    );
                }
            }
        } else {
            ctx.vote_to_halt();
        }
    }

    fn aggregators(&self) -> Vec<AggregatorSpec> {
        if self.epsilon.is_some() {
            vec![AggregatorSpec::new(AGG_L1_DELTA, AggOp::Sum)]
        } else {
            Vec::new()
        }
    }

    /// Contributions to the same target vertex sum (the receiver adds
    /// `ALPHA * c` per message, so a pre-summed message is equivalent).
    fn combine(&self, a: &Self::Msg, b: &Self::Msg) -> Option<Self::Msg> {
        Some((a.0, a.1 + b.1))
    }

    /// Checkpoint save override: persist only the ranks — out-degrees
    /// and the XLA dense block are rebuilt from topology on restore, so
    /// serializing them would only double the snapshot's states bytes.
    fn save_state(&self, state: &PrState, e: &mut crate::util::codec::Encoder) {
        state.ranks.encode_state(e);
    }

    /// Checkpoint restore override: decode the serialized ranks, then
    /// re-run `init` so out-degrees are recomputed (identically — the
    /// restored state is bit-exact) and the XLA dense adjacency block
    /// (a service handle that cannot be persisted) is re-registered
    /// for the resumed process.
    fn restore_state(
        &self,
        sg: &Subgraph,
        d: &mut crate::util::codec::Decoder,
    ) -> anyhow::Result<PrState> {
        let ranks = Vec::<f32>::decode_state(d)?;
        let mut fresh = self.init(sg);
        fresh.ranks = ranks;
        Ok(fresh)
    }

    /// Per-vertex final rank.
    fn emit(&self, state: &PrState, sg: &Subgraph) -> Vec<(VertexId, f64)> {
        sg.vertices
            .iter()
            .zip(&state.ranks)
            .map(|(&v, &r)| (v, r as f64))
            .collect()
    }
}

/// Vertex-centric PageRank (the Pregel canon).
pub struct PageRankVx {
    pub supersteps: usize,
}

impl Default for PageRankVx {
    fn default() -> Self {
        Self { supersteps: DEFAULT_SUPERSTEPS }
    }
}

impl VertexProgram for PageRankVx {
    type Msg = f32;
    type Value = f32;

    fn init(&self, _vertex: VertexId, _g: &Graph) -> f32 {
        0.0
    }

    fn compute(&self, value: &mut f32, ctx: &mut VertexContext<'_, f32>, msgs: &[f32]) {
        let n = ctx.num_vertices() as f32;
        if ctx.superstep() == 1 {
            *value = 1.0 / n;
        } else {
            let sum: f32 = msgs.iter().sum();
            *value = (1.0 - ALPHA) / n + ALPHA * sum;
        }
        if ctx.superstep() < self.supersteps {
            let d = ctx.out_degree() as f32;
            if d > 0.0 {
                ctx.send_to_all_neighbors(*value / d);
            }
        } else {
            ctx.vote_to_halt();
        }
    }

    fn combine(&self, a: &f32, b: &f32) -> Option<f32> {
        Some(a + b)
    }

    fn emit(&self, vertex: VertexId, value: &f32) -> Vec<(VertexId, f64)> {
        vec![(vertex, *value as f64)]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::gather_vertex_values;
    use crate::gofs::subgraph::discover;
    use crate::gopher::{run, GopherConfig};
    use crate::graph::gen;
    use crate::partition::{HashPartitioner, MultilevelPartitioner, Partitioner};
    use crate::pregel::{run_vertex, PregelConfig};
    use std::collections::BTreeMap;

    fn sg_ranks(g: &crate::graph::Graph, k: usize, supersteps: usize) -> Vec<f32> {
        let parts = MultilevelPartitioner::default().partition(g, k);
        let dg = discover(g, &parts).unwrap();
        let prog = PageRankSg { supersteps, kernel: RankKernel::Scalar, epsilon: None };
        let res = run(&dg, &prog, &GopherConfig::default()).unwrap();
        let states: BTreeMap<_, Vec<f32>> =
            res.states.into_iter().map(|(id, s)| (id, s.ranks)).collect();
        gather_vertex_values(&dg, &states)
    }

    fn vx_ranks(g: &crate::graph::Graph, k: usize, supersteps: usize) -> Vec<f32> {
        let parts = HashPartitioner::default().partition(g, k);
        let res = run_vertex(g, &parts, &PageRankVx { supersteps }, &PregelConfig::default())
            .unwrap();
        res.values
    }

    #[test]
    fn models_agree_on_trace_graph() {
        let g = gen::trace(400, 15, 0.15, 9);
        let a = sg_ranks(&g, 3, 15);
        let b = vx_ranks(&g, 3, 15);
        for (v, (&x, &y)) in a.iter().zip(&b).enumerate() {
            assert!(
                (x - y).abs() < 1e-6 * (1.0 + x.abs()),
                "vertex {v}: sg={x} vx={y}"
            );
        }
    }

    #[test]
    fn ring_converges_to_uniform() {
        // Directed ring: perfectly symmetric, rank must be uniform 1/n.
        let n = 24;
        let edges: Vec<(u32, u32)> = (0..n).map(|i| (i, (i + 1) % n)).collect();
        let g = crate::graph::Graph::from_edges(n as usize, &edges, None, true).unwrap();
        let ranks = sg_ranks(&g, 3, 30);
        for &r in &ranks {
            assert!((r - 1.0 / n as f32).abs() < 1e-5, "rank {r}");
        }
    }

    #[test]
    fn hub_outranks_spokes() {
        let g = gen::trace(300, 10, 0.5, 3);
        let ranks = sg_ranks(&g, 2, 30);
        let hub = ranks[0]; // vertex 0 is the mega-hub
        let mean: f32 = ranks.iter().sum::<f32>() / ranks.len() as f32;
        assert!(hub > 10.0 * mean, "hub={hub} mean={mean}");
    }

    #[test]
    fn takes_exactly_configured_supersteps() {
        let g = gen::social(200, 3, 0.0, 2);
        let parts = MultilevelPartitioner::default().partition(&g, 2);
        let dg = discover(&g, &parts).unwrap();
        let prog = PageRankSg { supersteps: 12, kernel: RankKernel::Scalar, epsilon: None };
        let res = run(&dg, &prog, &GopherConfig::default()).unwrap();
        assert_eq!(res.metrics.num_supersteps(), 12);
        let vres = run_vertex(
            &g,
            &HashPartitioner::default().partition(&g, 2),
            &PageRankVx { supersteps: 12 },
            &PregelConfig::default(),
        )
        .unwrap();
        assert_eq!(vres.metrics.num_supersteps(), 12);
    }

    #[test]
    fn aggregator_convergence_beats_fixed_iterations() {
        // The coordinator win: PageRank terminates via the global
        // `pr_l1_delta` aggregator in fewer supersteps than the seed's
        // fixed-iteration run (DEFAULT_SUPERSTEPS = 30).
        let g = gen::social(400, 5, 0.0, 31);
        let parts = MultilevelPartitioner::default().partition(&g, 3);
        let dg = discover(&g, &parts).unwrap();
        let eps = 0.05f32;
        let prog = PageRankSg {
            supersteps: 60,
            kernel: RankKernel::Scalar,
            epsilon: Some(eps),
        };
        let res = run(&dg, &prog, &GopherConfig::default()).unwrap();
        let steps = res.metrics.num_supersteps();
        assert!(steps >= 3, "needs at least report+observe supersteps");
        assert!(
            steps < DEFAULT_SUPERSTEPS,
            "aggregator convergence took {steps} supersteps, \
             fixed mode takes {DEFAULT_SUPERSTEPS}"
        );

        // The coordinator recorded the full delta trace, and the value
        // that triggered the halt is below epsilon.
        let trace = res.metrics.aggregator(AGG_L1_DELTA).expect("delta trace");
        assert_eq!(trace.values.len(), steps);
        assert!(trace.values[steps - 2] < eps as f64, "{:?}", trace.values);
        // Deltas shrink as the ranks settle.
        assert!(trace.values[steps - 2] < trace.values[1]);

        // Stopping at successive-delta < eps leaves the ranks within
        // ~alpha/(1-alpha) * eps of the fixpoint in L1; compare against
        // a long fixed run.
        let states: BTreeMap<_, Vec<f32>> =
            res.states.into_iter().map(|(id, s)| (id, s.ranks)).collect();
        let got = gather_vertex_values(&dg, &states);
        let want = sg_ranks(&g, 3, 60);
        let l1: f32 = got.iter().zip(&want).map(|(&a, &b)| (a - b).abs()).sum();
        assert!(l1 < 8.0 * eps, "l1 distance to fixpoint reference: {l1}");
    }

    #[test]
    fn mass_within_bounds() {
        // With dangling leak, total mass stays in (0, 1].
        let g = gen::social(300, 4, 0.02, 8);
        let ranks = sg_ranks(&g, 3, 20);
        let total: f32 = ranks.iter().sum();
        assert!(total > 0.15 && total <= 1.0 + 1e-4, "total={total}");
    }
}
