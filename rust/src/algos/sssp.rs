//! Single Source Shortest Path (paper §5.2, Algorithm 3).
//!
//! Sub-graph centric: run Dijkstra *to completion inside the sub-graph*
//! each superstep, seeded by the source (superstep 1) or by improved
//! boundary distances from incoming messages; then push improved
//! distances across remote edges. Supersteps ~ weighted meta-diameter.
//!
//! Vertex-centric: the classic relax-and-forward, one hop per superstep.
//!
//! Both honour edge weights (1.0 for unweighted graphs) and treat
//! undirected graphs as traversable both ways.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::ckpt::StateCodec;
use crate::gofs::Subgraph;
use crate::gopher::{IncomingMessage, SubgraphContext, SubgraphProgram};
use crate::graph::csr::{Graph, VertexId};
use crate::pregel::{VertexContext, VertexProgram};

/// Sub-graph centric SSSP (paper Algorithm 3).
pub struct SsspSg {
    pub source: VertexId,
}

/// Per-sub-graph SSSP state: tentative distance per local vertex.
pub struct SsspState {
    pub dist: Vec<f32>,
}

/// Value-only state: the distance vector round-trips bit-exactly
/// (`f32` LE, `+inf` included), so the default checkpoint hooks apply.
impl StateCodec for SsspState {
    fn encode_state(&self, e: &mut crate::util::codec::Encoder) {
        self.dist.encode_state(e);
    }
    fn decode_state(d: &mut crate::util::codec::Decoder) -> anyhow::Result<Self> {
        Ok(SsspState { dist: Vec::<f32>::decode_state(d)? })
    }
}

/// f32 ordered for the heap (distances are never NaN).
#[derive(PartialEq, PartialOrd)]
struct Ord32(f32);
impl Eq for Ord32 {}
#[allow(clippy::derive_ord_xor_partial_ord)]
impl Ord for Ord32 {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.partial_cmp(other).unwrap()
    }
}

impl SsspSg {
    /// Dijkstra within the sub-graph from the open set; returns the local
    /// vertices whose distance improved (for boundary propagation).
    fn dijkstra(sg: &Subgraph, dist: &mut [f32], openset: Vec<u32>) -> Vec<u32> {
        let undirected = !sg.local.directed();
        let mut heap: BinaryHeap<Reverse<(Ord32, u32)>> = openset
            .iter()
            .map(|&v| Reverse((Ord32(dist[v as usize]), v)))
            .collect();
        let mut improved = vec![false; dist.len()];
        for &v in &openset {
            improved[v as usize] = true;
        }
        while let Some(Reverse((Ord32(d), v))) = heap.pop() {
            if d > dist[v as usize] {
                continue; // stale entry
            }
            let mut relax = |t: u32, w: f32, dist: &mut [f32], heap: &mut BinaryHeap<Reverse<(Ord32, u32)>>, improved: &mut [bool]| {
                let nd = d + w;
                if nd < dist[t as usize] {
                    dist[t as usize] = nd;
                    improved[t as usize] = true;
                    heap.push(Reverse((Ord32(nd), t)));
                }
            };
            for (t, ei) in sg.local.out_edges(v) {
                relax(t, sg.local.weight(ei), dist, &mut heap, &mut improved);
            }
            if undirected {
                for (s, ei) in sg.local.in_edges(v) {
                    relax(s, sg.local.weight(ei), dist, &mut heap, &mut improved);
                }
            }
        }
        improved
            .iter()
            .enumerate()
            .filter(|(_, &i)| i)
            .map(|(v, _)| v as u32)
            .collect()
    }
}

impl SubgraphProgram for SsspSg {
    type Msg = (u32, f32); // (global vertex id, candidate distance)
    type State = SsspState;

    fn init(&self, sg: &Subgraph) -> SsspState {
        SsspState { dist: vec![f32::INFINITY; sg.num_vertices()] }
    }

    fn compute(
        &self,
        state: &mut SsspState,
        sg: &Subgraph,
        ctx: &mut SubgraphContext<'_, Self::Msg>,
        msgs: &[IncomingMessage<Self::Msg>],
    ) {
        let mut openset: Vec<u32> = Vec::new();
        if ctx.superstep() == 1 {
            if let Some(local) = ctx.local_vertex(self.source) {
                state.dist[local as usize] = 0.0;
                openset.push(local);
            }
        }
        for m in msgs {
            let (gv, cand) = m.payload;
            if let Some(local) = ctx.local_vertex(gv) {
                if cand < state.dist[local as usize] {
                    state.dist[local as usize] = cand;
                    openset.push(local);
                }
            }
        }
        if !openset.is_empty() {
            let improved = Self::dijkstra(sg, &mut state.dist, openset);
            // Push improved distances over boundary edges.
            let undirected = !sg.local.directed();
            for r in &sg.remote_out {
                if improved.binary_search(&r.local).is_ok() {
                    let cand = state.dist[r.local as usize] + r.weight;
                    if cand.is_finite() {
                        ctx.send_to_subgraph_vertex(
                            crate::gofs::SubgraphId {
                                partition: r.partition,
                                index: r.subgraph,
                            }
                            ,
                            r.target_global,
                            (r.target_global, cand),
                        );
                    }
                }
            }
            if undirected {
                for r in &sg.remote_in {
                    if improved.binary_search(&r.local).is_ok() {
                        let cand = state.dist[r.local as usize] + r.weight;
                        if cand.is_finite() {
                            ctx.send_to_subgraph_vertex(
                                crate::gofs::SubgraphId {
                                    partition: r.partition,
                                    index: r.subgraph,
                                }
                                ,
                                r.target_global,
                                (r.target_global, cand),
                            );
                        }
                    }
                }
            }
        }
        ctx.vote_to_halt(); // Algorithm 3 line 18: always halt, messages wake us.
    }

    /// Candidate distances to the same target vertex fold by min (the
    /// receiver keeps the minimum anyway), cutting bytes on the wire.
    fn combine(&self, a: &Self::Msg, b: &Self::Msg) -> Option<Self::Msg> {
        Some(if a.1 <= b.1 { *a } else { *b })
    }

    /// Per-vertex tentative distance (`+inf` for unreachable vertices).
    fn emit(&self, state: &SsspState, sg: &Subgraph) -> Vec<(VertexId, f64)> {
        sg.vertices
            .iter()
            .zip(&state.dist)
            .map(|(&v, &d)| (v, d as f64))
            .collect()
    }
}

/// Vertex-centric SSSP.
pub struct SsspVx {
    pub source: VertexId,
}

impl VertexProgram for SsspVx {
    type Msg = f32;
    type Value = f32;

    fn init(&self, _vertex: VertexId, _g: &Graph) -> f32 {
        f32::INFINITY
    }

    fn compute(
        &self,
        value: &mut f32,
        ctx: &mut VertexContext<'_, f32>,
        msgs: &[f32],
    ) {
        let mut best = *value;
        if ctx.superstep() == 1 && ctx.vertex() == self.source {
            best = 0.0;
        }
        for &m in msgs {
            best = best.min(m);
        }
        if best < *value || (ctx.superstep() == 1 && best == 0.0) {
            *value = best;
            let undirected = {
                // Graph direction decides traversal (match SsspSg).
                !ctx_graph_directed(ctx)
            };
            let out: Vec<(VertexId, f32)> = ctx.out_edges_weighted();
            for (t, w) in out {
                ctx.send_to(t, best + w);
            }
            if undirected {
                let graph = ctx_graph(ctx);
                let v = ctx.vertex();
                let ins: Vec<(VertexId, f32)> = graph
                    .in_edges(v)
                    .map(|(s, ei)| (s, graph.weight(ei)))
                    .collect();
                for (s, w) in ins {
                    ctx.send_to(s, best + w);
                }
            }
        }
        ctx.vote_to_halt();
    }

    fn combine(&self, a: &f32, b: &f32) -> Option<f32> {
        Some(a.min(*b))
    }

    fn emit(&self, vertex: VertexId, value: &f32) -> Vec<(VertexId, f64)> {
        vec![(vertex, *value as f64)]
    }
}

// Context accessors that keep VertexContext's public API tight while the
// SSSP program needs the underlying graph for undirected relaxation.
fn ctx_graph<'a, M: Clone>(ctx: &VertexContext<'a, M>) -> &'a Graph {
    ctx.graph()
}
fn ctx_graph_directed<M: Clone>(ctx: &VertexContext<'_, M>) -> bool {
    ctx.graph().directed()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::gather_vertex_values;
    use crate::gofs::subgraph::discover;
    use crate::gopher::{run, GopherConfig};
    use crate::graph::{gen, props};
    use crate::partition::{HashPartitioner, MultilevelPartitioner, Partitioner};
    use crate::pregel::{run_vertex, PregelConfig};
    use std::collections::BTreeMap;

    /// Single-machine Dijkstra oracle over the full graph.
    fn oracle(g: &crate::graph::Graph, source: VertexId) -> Vec<f32> {
        let undirected = !g.directed();
        let n = g.num_vertices();
        let mut dist = vec![f32::INFINITY; n];
        dist[source as usize] = 0.0;
        let mut heap = BinaryHeap::new();
        heap.push(Reverse((Ord32(0.0), source)));
        while let Some(Reverse((Ord32(d), v))) = heap.pop() {
            if d > dist[v as usize] {
                continue;
            }
            let mut relax = |t: u32, w: f32, dist: &mut Vec<f32>, heap: &mut BinaryHeap<_>| {
                if d + w < dist[t as usize] {
                    dist[t as usize] = d + w;
                    heap.push(Reverse((Ord32(d + w), t)));
                }
            };
            for (t, ei) in g.out_edges(v) {
                relax(t, g.weight(ei), &mut dist, &mut heap);
            }
            if undirected {
                for (s, ei) in g.in_edges(v) {
                    relax(s, g.weight(ei), &mut dist, &mut heap);
                }
            }
        }
        dist
    }

    fn assert_dist_eq(got: &[f32], want: &[f32]) {
        assert_eq!(got.len(), want.len());
        for (v, (&a, &b)) in got.iter().zip(want).enumerate() {
            if a.is_infinite() && b.is_infinite() {
                continue;
            }
            assert!((a - b).abs() < 1e-4, "vertex {v}: got {a}, want {b}");
        }
    }

    #[test]
    fn subgraph_sssp_weighted_road() {
        let g = gen::with_random_weights(&gen::road(14, 0.92, 0.02, 41), 1.0, 10.0, 42);
        let parts = MultilevelPartitioner::default().partition(&g, 4);
        let dg = discover(&g, &parts).unwrap();
        let res = run(&dg, &SsspSg { source: 0 }, &GopherConfig::default()).unwrap();
        let states: BTreeMap<_, Vec<f32>> =
            res.states.into_iter().map(|(id, s)| (id, s.dist)).collect();
        let got = gather_vertex_values(&dg, &states);
        assert_dist_eq(&got, &oracle(&g, 0));
    }

    #[test]
    fn vertex_sssp_matches_oracle() {
        let g = gen::with_random_weights(&gen::grid(8, 8), 1.0, 5.0, 7);
        let parts = HashPartitioner::default().partition(&g, 3);
        let res = run_vertex(&g, &parts, &SsspVx { source: 0 }, &PregelConfig::default()).unwrap();
        assert_dist_eq(&res.values, &oracle(&g, 0));
    }

    #[test]
    fn models_agree_on_directed_trace() {
        let g = gen::with_random_weights(&gen::trace(600, 20, 0.2, 5), 1.0, 4.0, 6);
        let parts = MultilevelPartitioner::default().partition(&g, 3);
        let dg = discover(&g, &parts).unwrap();
        let sg_res = run(&dg, &SsspSg { source: 0 }, &GopherConfig::default()).unwrap();
        let states: BTreeMap<_, Vec<f32>> =
            sg_res.states.into_iter().map(|(id, s)| (id, s.dist)).collect();
        let sg_dist = gather_vertex_values(&dg, &states);
        let vx = run_vertex(
            &g,
            &HashPartitioner::default().partition(&g, 3),
            &SsspVx { source: 0 },
            &PregelConfig::default(),
        )
        .unwrap();
        assert_dist_eq(&sg_dist, &vx.values);
        assert_dist_eq(&sg_dist, &oracle(&g, 0));
    }

    #[test]
    fn unreachable_stays_infinite() {
        let g = crate::graph::Graph::from_edges(4, &[(0, 1)], None, false).unwrap();
        let parts = crate::partition::Partitioning::new(2, vec![0, 0, 1, 1]);
        let dg = discover(&g, &parts).unwrap();
        let res = run(&dg, &SsspSg { source: 0 }, &GopherConfig::default()).unwrap();
        let states: BTreeMap<_, Vec<f32>> =
            res.states.into_iter().map(|(id, s)| (id, s.dist)).collect();
        let got = gather_vertex_values(&dg, &states);
        assert_eq!(got[0], 0.0);
        assert_eq!(got[1], 1.0);
        assert!(got[2].is_infinite() && got[3].is_infinite());
    }

    #[test]
    fn subgraph_supersteps_scale_with_meta_diameter() {
        let g = gen::chain(120);
        let parts = MultilevelPartitioner::default().partition(&g, 4);
        let dg = discover(&g, &parts).unwrap();
        let sg_res = run(&dg, &SsspSg { source: 0 }, &GopherConfig::default()).unwrap();
        let vx_res = run_vertex(
            &g,
            &HashPartitioner::default().partition(&g, 4),
            &SsspVx { source: 0 },
            &PregelConfig::default(),
        )
        .unwrap();
        assert!(
            sg_res.metrics.num_supersteps() * 5 < vx_res.metrics.num_supersteps(),
            "sg={} vx={}",
            sg_res.metrics.num_supersteps(),
            vx_res.metrics.num_supersteps()
        );
        // BFS-distance sanity on the unweighted chain.
        let states: BTreeMap<_, Vec<f32>> =
            sg_res.states.into_iter().map(|(id, s)| (id, s.dist)).collect();
        let got = gather_vertex_values(&dg, &states);
        let bfs = props::bfs_distances(&g, 0);
        for (v, (&a, &b)) in got.iter().zip(&bfs).enumerate() {
            assert_eq!(a as u32, b, "vertex {v}");
        }
    }
}
