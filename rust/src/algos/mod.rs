//! Graph algorithms in both programming models (paper §5).
//!
//! Every algorithm ships a **sub-graph centric** (Gopher) and a
//! **vertex-centric** (Pregel baseline) implementation so the benchmark
//! harnesses can reproduce the paper's Gopher-vs-Giraph comparisons with
//! everything else held equal:
//!
//! | algorithm | sub-graph centric | vertex centric |
//! |---|---|---|
//! | Max Value (Alg 1 & 2)       | [`maxvalue::MaxValueSg`] | [`maxvalue::MaxValueVx`] |
//! | Connected Components (§5.1) | [`cc::CcSg`]             | [`cc::CcVx`] |
//! | SSSP (Alg 3, §5.2)          | [`sssp::SsspSg`]         | [`sssp::SsspVx`] |
//! | BFS                         | [`bfs::BfsSg`]           | [`bfs::BfsVx`] |
//! | PageRank (§5.3)             | [`pagerank::PageRankSg`] | [`pagerank::PageRankVx`] |
//! | BlockRank (§5.3)            | [`blockrank::BlockRankSg`] | — (paper has none) |
//! | Label Propagation           | [`labelprop::LabelPropSg`] | [`labelprop::LabelPropVx`] |
//!
//! The sub-graph PageRank/BlockRank/SSSP/CC programs can route their
//! per-sub-graph inner loops through the AOT-compiled XLA kernels (see
//! `runtime::programs`) — the paper §7's "fast shared-memory kernels
//! within a sub-graph". The sub-graph programs also exercise the
//! coordinator layer: PageRank and Label Propagation terminate via
//! global aggregators, and SSSP/CC/BFS/MaxValue/PageRank define message
//! combiners that fold same-destination traffic before the wire.
//!
//! Every program also implements the `emit` hook of its engine trait,
//! and [`registry`] maps algorithm names + [`registry::AlgoParams`] to
//! runnable jobs on either engine — the single dispatch surface behind
//! [`crate::job::Job`] and the CLI.

pub mod maxvalue;
pub mod cc;
pub mod sssp;
pub mod bfs;
pub mod pagerank;
pub mod blockrank;
pub mod labelprop;
pub mod registry;

use crate::gofs::{DistributedGraph, SubgraphId};
use std::collections::BTreeMap;

/// Scatter per-sub-graph per-vertex vectors back to one global vector.
///
/// `states[sg]` must hold one value per local vertex of `sg`, in local-id
/// order. Vertices never covered (impossible for a complete run) panic.
pub fn gather_vertex_values<T: Copy>(
    dg: &DistributedGraph,
    states: &BTreeMap<SubgraphId, Vec<T>>,
) -> Vec<T> {
    let mut out: Vec<Option<T>> = vec![None; dg.num_global_vertices as usize];
    for sg in dg.subgraphs() {
        let vals = &states[&sg.id];
        assert_eq!(vals.len(), sg.num_vertices(), "state length mismatch for {}", sg.id);
        for (i, &v) in sg.vertices.iter().enumerate() {
            out[v as usize] = Some(vals[i]);
        }
    }
    out.into_iter()
        .map(|v| v.expect("every vertex covered by exactly one sub-graph"))
        .collect()
}

/// Scatter a single per-sub-graph value to every vertex of the sub-graph.
pub fn gather_subgraph_values<T: Copy>(
    dg: &DistributedGraph,
    states: &BTreeMap<SubgraphId, T>,
) -> Vec<T> {
    let mut out: Vec<Option<T>> = vec![None; dg.num_global_vertices as usize];
    for sg in dg.subgraphs() {
        let val = states[&sg.id];
        for &v in &sg.vertices {
            out[v as usize] = Some(val);
        }
    }
    out.into_iter()
        .map(|v| v.expect("every vertex covered"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gofs::subgraph::discover;
    use crate::graph::gen;
    use crate::partition::{Partitioner, RangePartitioner};

    #[test]
    fn gather_round_trips_vertex_ids() {
        let g = gen::road(10, 0.9, 0.02, 3);
        let parts = RangePartitioner.partition(&g, 3);
        let dg = discover(&g, &parts).unwrap();
        let states: BTreeMap<SubgraphId, Vec<u32>> = dg
            .subgraphs()
            .map(|sg| (sg.id, sg.vertices.clone()))
            .collect();
        let gathered = gather_vertex_values(&dg, &states);
        let expect: Vec<u32> = (0..g.num_vertices() as u32).collect();
        assert_eq!(gathered, expect);
    }

    #[test]
    fn gather_subgraph_uniform() {
        let g = gen::chain(6);
        let parts = RangePartitioner.partition(&g, 2);
        let dg = discover(&g, &parts).unwrap();
        let states: BTreeMap<SubgraphId, u32> =
            dg.subgraphs().map(|sg| (sg.id, sg.id.partition)).collect();
        let gathered = gather_subgraph_values(&dg, &states);
        assert_eq!(gathered, vec![0, 0, 0, 1, 1, 1]);
    }
}
