//! Connected Components (paper §5.1, HCC-style max-label flood).
//!
//! Label = largest global vertex id in the component. The sub-graph
//! centric version exploits that a sub-graph is connected by definition:
//! its label is uniform, so the in-memory phase is a single max and the
//! flood runs over the meta-graph — `O(meta-diameter + 1)` supersteps vs
//! `O(vertex diameter)` for the vertex-centric version. This is the
//! paper's 554 → 7 superstep collapse on the road network (Fig 4c).

use crate::gofs::Subgraph;
use crate::gopher::{IncomingMessage, SubgraphContext, SubgraphProgram};
use crate::graph::csr::{Graph, VertexId};
use crate::pregel::{VertexContext, VertexProgram};

/// Sub-graph centric Connected Components.
pub struct CcSg;

impl SubgraphProgram for CcSg {
    type Msg = u32;
    /// Component label (uniform across the sub-graph's vertices).
    type State = u32;

    fn init(&self, _sg: &Subgraph) -> u32 {
        0
    }

    fn compute(
        &self,
        state: &mut u32,
        sg: &Subgraph,
        ctx: &mut SubgraphContext<'_, u32>,
        msgs: &[IncomingMessage<u32>],
    ) {
        let mut changed = false;
        if ctx.superstep() == 1 {
            // The sub-graph is connected: its label is its max vertex id.
            *state = sg.vertices.iter().copied().max().unwrap_or(0);
            changed = true;
        }
        for m in msgs {
            if m.payload > *state {
                *state = m.payload;
                changed = true;
            }
        }
        if changed {
            ctx.send_to_all_neighbors(*state);
        } else {
            ctx.vote_to_halt();
        }
    }

    /// Labels bound for the same sub-graph mailbox fold by max — the
    /// receiver's flood keeps the maximum anyway.
    fn combine(&self, a: &u32, b: &u32) -> Option<u32> {
        Some(*a.max(b))
    }

    /// Per-vertex component label (uniform across the sub-graph).
    fn emit(&self, state: &u32, sg: &Subgraph) -> Vec<(VertexId, f64)> {
        sg.vertices.iter().map(|&v| (v, *state as f64)).collect()
    }
}

/// Vertex-centric Connected Components (HCC).
pub struct CcVx;

impl VertexProgram for CcVx {
    type Msg = u32;
    type Value = u32;

    fn init(&self, vertex: VertexId, _g: &Graph) -> u32 {
        vertex
    }

    fn compute(
        &self,
        value: &mut u32,
        ctx: &mut VertexContext<'_, u32>,
        msgs: &[u32],
    ) {
        let mut changed = ctx.superstep() == 1;
        for &m in msgs {
            if m > *value {
                *value = m;
                changed = true;
            }
        }
        if changed {
            ctx.send_to_all_undirected(*value);
        } else {
            ctx.vote_to_halt();
        }
    }

    fn combine(&self, a: &u32, b: &u32) -> Option<u32> {
        Some(*a.max(b))
    }

    fn emit(&self, vertex: VertexId, value: &u32) -> Vec<(VertexId, f64)> {
        vec![(vertex, *value as f64)]
    }
}

/// Number of distinct labels (= component count) in a label vector.
pub fn count_components(labels: &[u32]) -> usize {
    let mut sorted: Vec<u32> = labels.to_vec();
    sorted.sort_unstable();
    sorted.dedup();
    sorted.len()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::gather_subgraph_values;
    use crate::gofs::subgraph::discover;
    use crate::gopher::{run, GopherConfig};
    use crate::graph::{gen, props};
    use crate::partition::{HashPartitioner, MultilevelPartitioner, Partitioner};
    use crate::pregel::{run_vertex, PregelConfig};

    fn check_labels_match_ground_truth(labels: &[u32], g: &crate::graph::Graph) {
        let truth = props::wcc_labels(g);
        // Same partition structure: labels equal iff truth labels equal.
        assert_eq!(labels.len(), truth.len());
        for (u, v, _) in g.edges() {
            assert_eq!(labels[u as usize], labels[v as usize]);
        }
        assert_eq!(count_components(labels), props::wcc_count(g));
        // Each component labelled by its max member.
        for (v, &l) in labels.iter().enumerate() {
            assert!(l >= v as u32);
            assert_eq!(truth[l as usize], truth[v], "label of {v} outside its component");
        }
    }

    #[test]
    fn subgraph_cc_on_fragmented_road() {
        let g = gen::road(18, 0.88, 0.01, 31); // many components
        let parts = MultilevelPartitioner::default().partition(&g, 4);
        let dg = discover(&g, &parts).unwrap();
        let res = run(&dg, &CcSg, &GopherConfig::default()).unwrap();
        let labels = gather_subgraph_values(&dg, &res.states);
        check_labels_match_ground_truth(&labels, &g);
    }

    #[test]
    fn vertex_cc_matches_ground_truth() {
        let g = gen::road(12, 0.9, 0.01, 33);
        let parts = HashPartitioner::default().partition(&g, 3);
        let res = run_vertex(&g, &parts, &CcVx, &PregelConfig::default()).unwrap();
        check_labels_match_ground_truth(&res.values, &g);
    }

    #[test]
    fn both_models_agree_on_social_graph() {
        let g = gen::social(500, 4, 0.05, 17);
        let parts = MultilevelPartitioner::default().partition(&g, 3);
        let dg = discover(&g, &parts).unwrap();
        let sg_labels = gather_subgraph_values(
            &dg,
            &run(&dg, &CcSg, &GopherConfig::default()).unwrap().states,
        );
        let vx = run_vertex(
            &g,
            &HashPartitioner::default().partition(&g, 3),
            &CcVx,
            &PregelConfig::default(),
        )
        .unwrap();
        assert_eq!(sg_labels, vx.values);
    }

    #[test]
    fn superstep_collapse_on_high_diameter_graph() {
        let g = gen::chain(200);
        let parts = MultilevelPartitioner::default().partition(&g, 4);
        let dg = discover(&g, &parts).unwrap();
        let sg_res = run(&dg, &CcSg, &GopherConfig::default()).unwrap();
        let vx_res = run_vertex(
            &g,
            &HashPartitioner::default().partition(&g, 4),
            &CcVx,
            &PregelConfig::default(),
        )
        .unwrap();
        // Paper Fig 4c: sub-graph supersteps ~ meta-diameter (tiny);
        // vertex supersteps ~ vertex diameter (huge).
        assert!(
            sg_res.metrics.num_supersteps() * 10
                < vx_res.metrics.num_supersteps(),
            "sg={} vx={}",
            sg_res.metrics.num_supersteps(),
            vx_res.metrics.num_supersteps()
        );
    }

    #[test]
    fn isolated_vertices_self_labelled() {
        let g = crate::graph::Graph::from_edges(5, &[(0, 1)], None, false).unwrap();
        let parts = crate::partition::Partitioning::new(2, vec![0, 0, 1, 1, 1]);
        let dg = discover(&g, &parts).unwrap();
        let res = run(&dg, &CcSg, &GopherConfig::default()).unwrap();
        let labels = gather_subgraph_values(&dg, &res.states);
        assert_eq!(labels, vec![1, 1, 2, 3, 4]);
    }
}
