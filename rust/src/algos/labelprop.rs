//! Label Propagation (community detection) — the coordinator-layer
//! showcase algorithm.
//!
//! Synchronous label propagation (Raghavan et al. 2007) over the
//! undirected view: every vertex starts with its own label and each
//! round adopts the most frequent label among its neighbours (smallest
//! label wins ties; a vertex keeps its label when it is already among
//! the most frequent — the standard oscillation damper). One Gopher
//! superstep = one global round: local vertices read their neighbours'
//! previous-round labels directly from sub-graph memory, and boundary
//! labels travel as `(vertex, label)` messages, cached by the receiver —
//! so the result is exactly partition-independent synchronous LP.
//!
//! **Termination is aggregator-driven**: every sub-graph reports its
//! per-round change count into the global [`AGG_CHANGES`] sum; when the
//! folded count hits zero, every sub-graph observes it on the same
//! superstep and votes to halt — no fixed round count, no extra
//! message round-trips. Synchronous LP can two-cycle on bipartite
//! structures, so [`LabelPropSg::max_rounds`] caps the run.

use std::collections::HashMap;

use crate::ckpt::StateCodec;
use crate::coordinator::{AggOp, AggregatorSpec};
use crate::gofs::Subgraph;
use crate::gopher::{IncomingMessage, SubgraphContext, SubgraphProgram};
use crate::graph::csr::{Graph, VertexId};
use crate::pregel::{VertexContext, VertexProgram};

/// Name of the global changed-labels-this-round aggregator (Sum).
pub const AGG_CHANGES: &str = "lp_changes";

/// Sub-graph centric synchronous label propagation.
pub struct LabelPropSg {
    /// Hard cap on propagation rounds (sync LP can oscillate).
    pub max_rounds: usize,
}

impl Default for LabelPropSg {
    fn default() -> Self {
        Self { max_rounds: 50 }
    }
}

/// Per-sub-graph LP state.
pub struct LpState {
    /// Current label per local vertex.
    pub labels: Vec<u32>,
    /// Last known label of each remote boundary neighbour (global id).
    remote_labels: HashMap<u32, u32>,
    /// Remote neighbours per local vertex (undirected view; repeats
    /// model parallel edges, matching the local frequency counting).
    remote_adj: Vec<Vec<u32>>,
    /// Local vertices with at least one remote edge, with the sub-graphs
    /// each must notify: (local vertex, neighbour sub-graph ids).
    boundary: Vec<(u32, Vec<crate::gofs::SubgraphId>)>,
}

/// Checkpoint codec for [`LpState`]: only the propagation state
/// (labels + cached boundary labels) is serialized — `remote_adj` and
/// `boundary` derive from topology, so [`LabelPropSg::restore_state`]
/// rebuilds them via `init` (decoding alone leaves them empty).
impl StateCodec for LpState {
    fn encode_state(&self, e: &mut crate::util::codec::Encoder) {
        self.labels.encode_state(e);
        self.remote_labels.encode_state(e);
    }
    fn decode_state(d: &mut crate::util::codec::Decoder) -> anyhow::Result<Self> {
        Ok(LpState {
            labels: Vec::<u32>::decode_state(d)?,
            remote_labels: HashMap::<u32, u32>::decode_state(d)?,
            remote_adj: Vec::new(),
            boundary: Vec::new(),
        })
    }
}

impl LabelPropSg {
    /// One synchronous LP round over the local vertices; returns how
    /// many labels changed and the per-vertex changed mask.
    fn round(&self, st: &mut LpState, sg: &Subgraph) -> (u64, Vec<bool>) {
        let n = sg.num_vertices();
        let old = st.labels.clone();
        let mut changes = 0u64;
        let mut mask = vec![false; n];
        let mut freq: HashMap<u32, u32> = HashMap::new();
        for v in 0..n as u32 {
            freq.clear();
            for &nb in sg.local.out_neighbors(v) {
                *freq.entry(old[nb as usize]).or_insert(0) += 1;
            }
            for &nb in sg.local.in_neighbors(v) {
                *freq.entry(old[nb as usize]).or_insert(0) += 1;
            }
            for &gnb in &st.remote_adj[v as usize] {
                if let Some(&l) = st.remote_labels.get(&gnb) {
                    *freq.entry(l).or_insert(0) += 1;
                }
            }
            if freq.is_empty() {
                continue; // isolated vertex keeps its own label
            }
            let best_count = *freq.values().max().unwrap();
            let current = old[v as usize];
            // Keep the current label when it is already maximal.
            if freq.get(&current).copied().unwrap_or(0) == best_count {
                continue;
            }
            let best_label = freq
                .iter()
                .filter(|(_, &c)| c == best_count)
                .map(|(&l, _)| l)
                .min()
                .unwrap();
            st.labels[v as usize] = best_label;
            mask[v as usize] = true;
            changes += 1;
        }
        (changes, mask)
    }
}

impl SubgraphProgram for LabelPropSg {
    type Msg = (u32, u32); // (global vertex id, its new label)
    type State = LpState;

    fn init(&self, sg: &Subgraph) -> LpState {
        let n = sg.num_vertices();
        let mut remote_adj: Vec<Vec<u32>> = vec![Vec::new(); n];
        let mut notify: Vec<Vec<crate::gofs::SubgraphId>> = vec![Vec::new(); n];
        for r in sg.remote_out.iter().chain(&sg.remote_in) {
            remote_adj[r.local as usize].push(r.target_global);
            let id = crate::gofs::SubgraphId {
                partition: r.partition,
                index: r.subgraph,
            };
            let list = &mut notify[r.local as usize];
            if !list.contains(&id) {
                list.push(id);
            }
        }
        let boundary = notify
            .into_iter()
            .enumerate()
            .filter(|(_, ids)| !ids.is_empty())
            .map(|(v, ids)| (v as u32, ids))
            .collect();
        LpState {
            labels: sg.vertices.clone(),
            remote_labels: HashMap::new(),
            remote_adj,
            boundary,
        }
    }

    fn compute(
        &self,
        st: &mut LpState,
        sg: &Subgraph,
        ctx: &mut SubgraphContext<'_, Self::Msg>,
        msgs: &[IncomingMessage<Self::Msg>],
    ) {
        for m in msgs {
            let (gv, label) = m.payload;
            st.remote_labels.insert(gv, label);
        }
        let s = ctx.superstep();

        // Round 1 only establishes boundary labels; propagation starts
        // once every sub-graph knows its remote neighbourhood.
        let (changes, changed_mask) = if s == 1 {
            (sg.num_vertices() as u64, None)
        } else {
            let (changes, mask) = self.round(st, sg);
            (changes, Some(mask))
        };

        let slot = ctx.aggregator(AGG_CHANGES).expect("registered aggregator");
        ctx.aggregate(slot, changes as f64);

        // Globally converged: the previous round changed nothing
        // anywhere (visible to every sub-graph at once), or we hit the
        // oscillation cap.
        let converged = s >= 3
            && ctx
                .aggregated(slot)
                .is_some_and(|global_changes| global_changes == 0.0);
        if converged || s > self.max_rounds {
            ctx.vote_to_halt();
            return;
        }

        // Ship boundary labels: everything at round 1, changes after.
        for (v, ids) in &st.boundary {
            let changed = match &changed_mask {
                None => true,
                Some(mask) => mask[*v as usize],
            };
            if changed {
                let payload = (sg.vertices[*v as usize], st.labels[*v as usize]);
                for id in ids {
                    ctx.send_to_subgraph(*id, payload);
                }
            }
        }
        // No vote_to_halt here: a sub-graph that hasn't observed global
        // convergence stays in the active set by simply not halting —
        // the aggregator, not message arrival, decides termination.
    }

    fn aggregators(&self) -> Vec<AggregatorSpec> {
        vec![AggregatorSpec::new(AGG_CHANGES, AggOp::Sum)]
    }

    /// Per-vertex community label.
    fn emit(&self, state: &LpState, sg: &Subgraph) -> Vec<(VertexId, f64)> {
        sg.vertices
            .iter()
            .zip(&state.labels)
            .map(|(&v, &l)| (v, l as f64))
            .collect()
    }

    /// Checkpoint restore override: decode the propagation state, then
    /// rebuild the topology-derived remote adjacency / boundary lists
    /// via `init` — they are identical for the same sub-graph, so the
    /// restored state is bit-exact.
    fn restore_state(
        &self,
        sg: &Subgraph,
        d: &mut crate::util::codec::Decoder,
    ) -> anyhow::Result<LpState> {
        let saved = LpState::decode_state(d)?;
        let mut fresh = self.init(sg);
        fresh.labels = saved.labels;
        fresh.remote_labels = saved.remote_labels;
        Ok(fresh)
    }
}

/// Vertex-centric synchronous label propagation: the same rule and the
/// same aggregator-driven termination, over the pregel baseline — the
/// coordinator layer now rides both engines, so the unified job layer
/// can run `labelprop` on either and get identical labels.
///
/// Every active vertex re-announces its label each superstep (there is
/// no receiver-side cache here, unlike [`LabelPropSg`]): superstep 1
/// only establishes neighbour labels, and superstep `s ≥ 2` computes
/// synchronous round `s − 1`, exactly in phase with the sub-graph
/// version — including the change accounting that feeds the global
/// [`AGG_CHANGES`] sum, so both engines halt on the same superstep.
pub struct LabelPropVx {
    /// Hard cap on propagation rounds (sync LP can oscillate).
    pub max_rounds: usize,
}

impl Default for LabelPropVx {
    fn default() -> Self {
        Self { max_rounds: 50 }
    }
}

impl VertexProgram for LabelPropVx {
    type Msg = u32; // the sender's current label
    type Value = u32;

    fn init(&self, vertex: VertexId, _g: &Graph) -> u32 {
        vertex
    }

    fn compute(&self, value: &mut u32, ctx: &mut VertexContext<'_, u32>, msgs: &[u32]) {
        let slot = ctx.aggregator(AGG_CHANGES).expect("registered aggregator");
        let s = ctx.superstep();
        // Superstep 1 mirrors the sub-graph version's bootstrap round
        // (label announcement only), including its change accounting.
        let changed = if s == 1 {
            true
        } else {
            let mut freq: HashMap<u32, u32> = HashMap::new();
            for &m in msgs {
                *freq.entry(m).or_insert(0) += 1;
            }
            let current = *value;
            match freq.values().max().copied() {
                // Isolated vertex: keeps its own label forever.
                None => false,
                // Keep the current label when it is already maximal
                // (the standard oscillation damper).
                Some(best) if freq.get(&current).copied().unwrap_or(0) == best => false,
                Some(best) => {
                    *value = freq
                        .iter()
                        .filter(|(_, &c)| c == best)
                        .map(|(&l, _)| l)
                        .min()
                        .unwrap();
                    true
                }
            }
        };
        ctx.aggregate(slot, if changed { 1.0 } else { 0.0 });

        // Globally converged: the round before last changed nothing
        // anywhere (every vertex observes this on the same superstep),
        // or we hit the oscillation cap.
        let converged = s >= 3
            && ctx
                .aggregated(slot)
                .is_some_and(|global_changes| global_changes == 0.0);
        if converged || s > self.max_rounds {
            ctx.vote_to_halt();
            return;
        }
        ctx.send_to_all_undirected(*value);
    }

    fn aggregators(&self) -> Vec<AggregatorSpec> {
        vec![AggregatorSpec::new(AGG_CHANGES, AggOp::Sum)]
    }

    fn emit(&self, vertex: VertexId, value: &u32) -> Vec<(VertexId, f64)> {
        vec![(vertex, *value as f64)]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::gather_vertex_values;
    use crate::gofs::subgraph::discover;
    use crate::gopher::{run, GopherConfig};
    use crate::graph::Graph;
    use crate::partition::{HashPartitioner, Partitioner, Partitioning};
    use std::collections::BTreeMap;

    fn lp_labels(g: &Graph, parts: Partitioning) -> (Vec<u32>, crate::metrics::JobMetrics) {
        let dg = discover(g, &parts).unwrap();
        let res = run(&dg, &LabelPropSg::default(), &GopherConfig::default()).unwrap();
        let states: BTreeMap<_, Vec<u32>> =
            res.states.into_iter().map(|(id, s)| (id, s.labels)).collect();
        (gather_vertex_values(&dg, &states), res.metrics)
    }

    /// Two 5-cliques joined by one bridge edge.
    fn two_cliques() -> Graph {
        let mut edges = Vec::new();
        for c in [0u32, 5] {
            for i in 0..5 {
                for j in (i + 1)..5 {
                    edges.push((c + i, c + j));
                }
            }
        }
        edges.push((4, 5)); // bridge
        Graph::from_edges(10, &edges, None, false).unwrap()
    }

    #[test]
    fn cliques_converge_to_uniform_communities() {
        let g = two_cliques();
        let parts = Partitioning::new(2, vec![0, 0, 0, 0, 0, 1, 1, 1, 1, 1]);
        let (labels, metrics) = lp_labels(&g, parts);
        // Each clique settles on one label.
        assert!(labels[0..5].iter().all(|&l| l == labels[0]), "{labels:?}");
        assert!(labels[5..10].iter().all(|&l| l == labels[5]), "{labels:?}");
        // Convergence came from the aggregator, well under the cap.
        let steps = metrics.num_supersteps();
        assert!(steps < LabelPropSg::default().max_rounds, "steps={steps}");
        let trace = metrics.aggregator(AGG_CHANGES).expect("changes trace");
        assert_eq!(trace.values.len(), steps);
        assert_eq!(trace.values[steps - 2], 0.0, "{:?}", trace.values);
    }

    #[test]
    fn vertex_engine_matches_subgraph_engine() {
        use crate::pregel::{run_vertex, PregelConfig};
        // Sync LP is engine-independent: the pregel implementation
        // (aggregator-terminated, like the Gopher one) must produce the
        // same labels in the same number of supersteps.
        let g = crate::graph::gen::social(200, 4, 0.05, 9);
        let parts = HashPartitioner::default().partition(&g, 3);
        let (sg_labels, sg_metrics) = lp_labels(&g, parts.clone());
        let vx = run_vertex(&g, &parts, &LabelPropVx::default(), &PregelConfig::default())
            .unwrap();
        assert_eq!(sg_labels, vx.values);
        assert_eq!(sg_metrics.num_supersteps(), vx.metrics.num_supersteps());
        // The vertex engine's coordinator recorded the same change trace.
        let sg_trace = sg_metrics.aggregator(AGG_CHANGES).expect("gopher trace");
        let vx_trace = vx.metrics.aggregator(AGG_CHANGES).expect("pregel trace");
        assert_eq!(sg_trace.values, vx_trace.values);
    }

    #[test]
    fn result_is_partition_invariant() {
        // One superstep == one synchronous global round regardless of
        // how the graph is scattered, so labels must match exactly.
        let g = crate::graph::gen::social(200, 4, 0.05, 9);
        let single = lp_labels(&g, Partitioning::new(1, vec![0; g.num_vertices()])).0;
        let parts3 = HashPartitioner::default().partition(&g, 3);
        let split = lp_labels(&g, parts3).0;
        assert_eq!(single, split);
    }

    #[test]
    fn oscillation_capped_by_max_rounds() {
        // A bare pair two-cycles under strict sync LP (each endpoint
        // adopts the other's label every round), so the aggregator never
        // sees zero changes — the max_rounds cap must terminate the job.
        let g = Graph::from_edges(2, &[(0, 1)], None, false).unwrap();
        let parts = Partitioning::new(2, vec![0, 1]);
        let dg = discover(&g, &parts).unwrap();
        let prog = LabelPropSg { max_rounds: 6 };
        let res = run(&dg, &prog, &GopherConfig::default()).unwrap();
        // Halts at the first superstep past the cap.
        assert_eq!(res.metrics.num_supersteps(), 7);
        let trace = res.metrics.aggregator(AGG_CHANGES).expect("changes trace");
        // Every round after init flips both labels: the trace shows the
        // oscillation the cap exists for.
        assert!(trace.values[1..].iter().all(|&c| c == 2.0), "{:?}", trace.values);
    }

    #[test]
    fn isolated_vertices_keep_their_labels() {
        // Triangle {0,1,2} plus isolated vertices 3 and 4: the triangle
        // settles on one label, the isolates keep their own.
        let g = Graph::from_edges(5, &[(0, 1), (1, 2), (0, 2)], None, false).unwrap();
        let parts = Partitioning::new(2, vec![0, 0, 1, 1, 1]);
        let (labels, _) = lp_labels(&g, parts);
        assert_eq!(labels[3], 3);
        assert_eq!(labels[4], 4);
        assert_eq!(labels[0], labels[1]);
        assert_eq!(labels[1], labels[2]);
    }
}
