//! BlockRank (paper §5.3; Kamvar et al. 2003) — the sub-graph native
//! alternative to classic PageRank.
//!
//! Three phases, mapped onto supersteps exactly as the paper sketches:
//!
//! 1. **Local PageRank** (superstep 1): rank each sub-graph *in
//!    isolation* to (near-)convergence in one superstep — the expensive
//!    shared-memory phase, scalar or via the AOT `pagerank_local` XLA
//!    kernel; then broadcast this block's row of the block-transition
//!    matrix `B` (`B[i][j]` = rank mass flowing block `i` → block `j`).
//! 2. **Block ranking** (superstep 2): every sub-graph now holds all of
//!    `B`; each runs the tiny meta-PageRank locally (deterministic, so
//!    no further exchange is needed), seeds its vertices with
//!    `localpr(v) * blockrank(block)`, and starts the global phase.
//! 3. **Seeded classic PageRank** (supersteps 3+): standard damped
//!    updates, but *convergence-driven*: a sub-graph stops sending and
//!    votes to halt once its local residual drops under `eps`.
//!    Receivers cache the last contribution per remote in-edge, so a
//!    halted sender's mass keeps flowing (frozen) — this is what lets
//!    the algorithm terminate in fewer supersteps than fixed-30 classic
//!    PageRank while converging to the same fixpoint.
//!
//! With `seed_with_blockrank = false` phases 1–2 are skipped (uniform
//! start): that is the *classic-PR-with-convergence* arm of the A2
//! ablation in DESIGN.md §6.

use std::collections::HashMap;

use anyhow::Result;

use crate::ckpt::StateCodec;
use crate::gofs::{Subgraph, SubgraphId};
use crate::gopher::{IncomingMessage, MsgCodec, SubgraphContext, SubgraphProgram};
use crate::graph::VertexId;
use crate::util::codec::{Decoder, Encoder};

use super::pagerank::{RankKernel, ALPHA};

/// BlockRank message: block-matrix rows (phase 1→2) or frozen-cacheable
/// rank contributions (phase 3).
#[derive(Clone, Debug)]
pub enum BrMsg {
    /// One entry of the block transition matrix: mass `w` from block
    /// `src` to block `dst` (flat block indices).
    Row { src: u32, dst: u32, w: f32 },
    /// Rank contribution from global vertex `sender`.
    Contrib { sender: u32, value: f32 },
}

impl MsgCodec for BrMsg {
    fn encode(&self, e: &mut Encoder) {
        match self {
            BrMsg::Row { src, dst, w } => {
                e.put_u8(0);
                e.put_varint(*src as u64);
                e.put_varint(*dst as u64);
                e.put_f32(*w);
            }
            BrMsg::Contrib { sender, value } => {
                e.put_u8(1);
                e.put_varint(*sender as u64);
                e.put_f32(*value);
            }
        }
    }

    fn decode(d: &mut Decoder) -> Result<Self> {
        match d.get_u8()? {
            0 => Ok(BrMsg::Row {
                src: d.get_varint()? as u32,
                dst: d.get_varint()? as u32,
                w: d.get_f32()?,
            }),
            1 => Ok(BrMsg::Contrib { sender: d.get_varint()? as u32, value: d.get_f32()? }),
            t => anyhow::bail!("bad BrMsg tag {t}"),
        }
    }
}

/// Sub-graph centric BlockRank.
pub struct BlockRankSg {
    /// Flat-index offsets per partition (from the sub-graph directory).
    offsets: Vec<u32>,
    /// Total number of blocks.
    total_blocks: u32,
    /// Residual threshold for the global phase.
    pub eps: f32,
    /// Don't resend a contribution that changed less than this.
    pub send_eps: f32,
    /// Local PageRank iterations in phase 1 (scalar path).
    pub local_iters: usize,
    /// Skip phases 1–2 (uniform seed): the classic-PR comparison arm.
    pub seed_with_blockrank: bool,
    pub kernel: RankKernel,
}

impl BlockRankSg {
    /// `directory[p]` = number of sub-graphs on partition `p` (available
    /// from `DistributedGraph` or `StoreMeta`).
    pub fn new(directory: &[u32]) -> Self {
        let mut offsets = Vec::with_capacity(directory.len());
        let mut acc = 0u32;
        for &c in directory {
            offsets.push(acc);
            acc += c;
        }
        Self {
            offsets,
            total_blocks: acc,
            eps: 1e-7,
            send_eps: 1e-9,
            local_iters: 10,
            seed_with_blockrank: true,
            kernel: RankKernel::Scalar,
        }
    }

    fn flat(&self, id: SubgraphId) -> u32 {
        self.offsets[id.partition as usize] + id.index
    }

    /// Phase-1 local PageRank over the isolated block (out-degrees and
    /// teleport computed block-locally, per Kamvar et al.).
    fn local_pagerank(&self, sg: &Subgraph) -> Vec<f32> {
        let n = sg.num_vertices();
        if n == 0 {
            return Vec::new();
        }
        let base = (1.0 - ALPHA) / n as f32;
        if let RankKernel::Xla(engine) = &self.kernel {
            if let Some(n_pad) = engine.rung_for(n) {
                let mut adj = vec![0f32; n_pad * n_pad];
                for (v, u, _) in sg.local.edges() {
                    adj[u as usize * n_pad + v as usize] = 1.0;
                }
                let mut out_deg = vec![-1f32; n_pad];
                for (v, d) in out_deg.iter_mut().enumerate().take(n) {
                    *d = sg.local.out_degree(v as u32) as f32;
                }
                if let Ok(out) = engine.pagerank_local(n_pad, &adj, &out_deg, base, ALPHA) {
                    return out[..n].to_vec();
                }
            }
        }
        // Scalar fallback.
        let outdeg: Vec<f32> =
            (0..n).map(|v| sg.local.out_degree(v as u32) as f32).collect();
        let mut ranks = vec![1.0 / n as f32; n];
        for _ in 0..self.local_iters {
            let contrib: Vec<f32> = ranks
                .iter()
                .zip(&outdeg)
                .map(|(&r, &d)| if d > 0.0 { r / d } else { 0.0 })
                .collect();
            let mut next = vec![base; n];
            for u in 0..n {
                for v in sg.local.in_neighbors(u as u32) {
                    next[u] += ALPHA * contrib[*v as usize];
                }
            }
            ranks = next;
        }
        ranks
    }

    /// Meta PageRank over the collected block matrix (runs identically on
    /// every sub-graph — no exchange needed).
    fn block_rank(&self, rows: &[(u32, u32, f32)]) -> Vec<f32> {
        let t = self.total_blocks as usize;
        let mut row_sum = vec![0f32; t];
        for &(s, _, w) in rows {
            row_sum[s as usize] += w;
        }
        let mut b = vec![1.0 / t as f32; t];
        let base = (1.0 - ALPHA) / t as f32;
        for _ in 0..20 {
            let mut next = vec![base; t];
            for &(s, d, w) in rows {
                if row_sum[s as usize] > 0.0 {
                    next[d as usize] += ALPHA * b[s as usize] * w / row_sum[s as usize];
                }
            }
            // Blocks with no outgoing mass leak (dangling blocks), as in
            // the vertex-level semantics.
            b = next;
        }
        // Normalise so Σ blockrank = 1 (seeding needs a distribution).
        let total: f32 = b.iter().sum();
        if total > 0.0 {
            for x in &mut b {
                *x /= total;
            }
        }
        b
    }
}

/// Per-sub-graph BlockRank state.
pub struct BrState {
    pub ranks: Vec<f32>,
    localpr: Vec<f32>,
    /// Global out-degree per local vertex.
    outdeg: Vec<f32>,
    /// Collected block-matrix entries (phase 2 input).
    rows: Vec<(u32, u32, f32)>,
    /// Cached last contribution per (local target, remote sender).
    remote_in: HashMap<(u32, u32), f32>,
    /// Last sent contribution per remote out-edge index.
    last_sent: Vec<f32>,
    /// Superstep at which this block last changed materially.
    pub converged_at: Option<usize>,
}

/// Checkpoint codec for [`BrState`]: everything is plain run-state
/// (the frozen-contribution caches included), so the default hooks
/// apply. The `remote_in` map serializes in key order — see the
/// [`StateCodec`] determinism contract.
impl StateCodec for BrState {
    fn encode_state(&self, e: &mut crate::util::codec::Encoder) {
        self.ranks.encode_state(e);
        self.localpr.encode_state(e);
        self.outdeg.encode_state(e);
        self.rows.encode_state(e);
        self.remote_in.encode_state(e);
        self.last_sent.encode_state(e);
        self.converged_at.encode_state(e);
    }
    fn decode_state(d: &mut crate::util::codec::Decoder) -> Result<Self> {
        Ok(BrState {
            ranks: Vec::<f32>::decode_state(d)?,
            localpr: Vec::<f32>::decode_state(d)?,
            outdeg: Vec::<f32>::decode_state(d)?,
            rows: Vec::<(u32, u32, f32)>::decode_state(d)?,
            remote_in: HashMap::<(u32, u32), f32>::decode_state(d)?,
            last_sent: Vec::<f32>::decode_state(d)?,
            converged_at: Option::<usize>::decode_state(d)?,
        })
    }
}

impl SubgraphProgram for BlockRankSg {
    type Msg = BrMsg;
    type State = BrState;

    fn init(&self, sg: &Subgraph) -> BrState {
        let n = sg.num_vertices();
        let mut outdeg = vec![0f32; n];
        for (v, d) in outdeg.iter_mut().enumerate() {
            *d = sg.local.out_degree(v as u32) as f32;
        }
        for r in &sg.remote_out {
            outdeg[r.local as usize] += 1.0;
        }
        BrState {
            ranks: vec![0.0; n],
            localpr: Vec::new(),
            outdeg,
            rows: Vec::new(),
            remote_in: HashMap::new(),
            last_sent: vec![f32::NEG_INFINITY; sg.remote_out.len()],
            converged_at: None,
        }
    }

    fn compute(
        &self,
        st: &mut BrState,
        sg: &Subgraph,
        ctx: &mut SubgraphContext<'_, BrMsg>,
        msgs: &[IncomingMessage<BrMsg>],
    ) {
        let n_total = sg.num_global_vertices as f32;
        let base = (1.0 - ALPHA) / n_total;
        let s = ctx.superstep();
        let seeded = self.seed_with_blockrank;

        // Collect incoming messages by kind.
        for m in msgs {
            match &m.payload {
                BrMsg::Row { src, dst, w } => st.rows.push((*src, *dst, *w)),
                BrMsg::Contrib { sender, value } => {
                    if let Some(target) = m.vertex.and_then(|gv| ctx.local_vertex(gv)) {
                        st.remote_in.insert((target, *sender), *value);
                    }
                }
            }
        }

        if seeded && s == 1 {
            // ---- Phase 1: local PageRank + broadcast my B row.
            st.localpr = self.local_pagerank(sg);
            let my_flat = self.flat(sg.id);
            let mut row: HashMap<u32, f32> = HashMap::new();
            // Self-mass via local edges.
            let mut self_mass = 0f32;
            for (v, &lp) in st.localpr.iter().enumerate() {
                let d = st.outdeg[v];
                if d > 0.0 {
                    self_mass += lp * sg.local.out_degree(v as u32) as f32 / d;
                }
            }
            if self_mass > 0.0 {
                row.insert(my_flat, self_mass);
            }
            for r in &sg.remote_out {
                let d = st.outdeg[r.local as usize];
                if d > 0.0 {
                    let nb_flat = self.offsets[r.partition as usize] + r.subgraph;
                    *row.entry(nb_flat).or_insert(0.0) +=
                        st.localpr[r.local as usize] / d;
                }
            }
            for (dst, w) in row {
                ctx.send_to_all_subgraphs(BrMsg::Row { src: my_flat, dst, w });
            }
            return; // phase 2 runs next superstep
        }

        let classic_start = if seeded { 2 } else { 1 };
        if s == classic_start {
            // ---- Phase 2 (or classic start): seed ranks.
            if seeded {
                let b = self.block_rank(&st.rows);
                let mine = b[self.flat(sg.id) as usize];
                st.ranks = st.localpr.iter().map(|&lp| lp * mine).collect();
            } else {
                st.ranks = vec![1.0 / n_total; sg.num_vertices()];
            }
        } else {
            // ---- Phase 3: one damped update with cached remote input.
            let contrib: Vec<f32> = st
                .ranks
                .iter()
                .zip(&st.outdeg)
                .map(|(&r, &d)| if d > 0.0 { r / d } else { 0.0 })
                .collect();
            let n = sg.num_vertices();
            let mut next = vec![base; n];
            for (u, nx) in next.iter_mut().enumerate() {
                for v in sg.local.in_neighbors(u as u32) {
                    *nx += ALPHA * contrib[*v as usize];
                }
            }
            for (&(target, _), &c) in &st.remote_in {
                next[target as usize] += ALPHA * c;
            }
            let delta = st
                .ranks
                .iter()
                .zip(&next)
                .map(|(&a, &b)| (a - b).abs())
                .fold(0f32, f32::max);
            st.ranks = next;
            if delta < self.eps {
                if st.converged_at.is_none() {
                    st.converged_at = Some(s);
                }
                ctx.vote_to_halt();
                return; // frozen: neighbours keep our cached contributions
            }
            st.converged_at = None;
        }

        // Send (changed) contributions over remote out-edges.
        for (i, r) in sg.remote_out.iter().enumerate() {
            let d = st.outdeg[r.local as usize];
            if d <= 0.0 {
                continue;
            }
            let c = st.ranks[r.local as usize] / d;
            if (c - st.last_sent[i]).abs() > self.send_eps {
                st.last_sent[i] = c;
                ctx.send_to_subgraph_vertex(
                    SubgraphId { partition: r.partition, index: r.subgraph },
                    r.target_global,
                    BrMsg::Contrib { sender: sg.vertices[r.local as usize], value: c },
                );
            }
        }
    }

    /// Per-vertex final rank.
    fn emit(&self, state: &BrState, sg: &Subgraph) -> Vec<(VertexId, f64)> {
        sg.vertices
            .iter()
            .zip(&state.ranks)
            .map(|(&v, &r)| (v, r as f64))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::gather_vertex_values;
    use crate::algos::pagerank::{PageRankSg, RankKernel};
    use crate::gofs::subgraph::discover;
    use crate::gopher::{run, GopherConfig};
    use crate::graph::gen;
    use crate::partition::{MultilevelPartitioner, Partitioner};
    use std::collections::BTreeMap;

    fn blockrank_ranks(
        g: &crate::graph::Graph,
        k: usize,
        seeded: bool,
    ) -> (Vec<f32>, usize) {
        let parts = MultilevelPartitioner::default().partition(g, k);
        let dg = discover(g, &parts).unwrap();
        let directory: Vec<u32> = dg.partitions.iter().map(|p| p.len() as u32).collect();
        let mut prog = BlockRankSg::new(&directory);
        prog.seed_with_blockrank = seeded;
        prog.eps = 1e-8;
        let cfg = GopherConfig { max_supersteps: 300, ..Default::default() };
        let res = run(&dg, &prog, &cfg).unwrap();
        let steps = res.metrics.num_supersteps();
        let states: BTreeMap<_, Vec<f32>> =
            res.states.into_iter().map(|(id, s)| (id, s.ranks)).collect();
        (gather_vertex_values(&dg, &states), steps)
    }

    #[test]
    fn converges_near_classic_pagerank() {
        let g = gen::social(300, 4, 0.0, 12);
        let (br, _) = blockrank_ranks(&g, 3, true);
        // Classic 60-superstep PageRank as the fixpoint reference.
        let parts = MultilevelPartitioner::default().partition(&g, 3);
        let dg = discover(&g, &parts).unwrap();
        let prog = PageRankSg { supersteps: 60, kernel: RankKernel::Scalar, epsilon: None };
        let res = run(&dg, &prog, &GopherConfig::default()).unwrap();
        let states: BTreeMap<_, Vec<f32>> =
            res.states.into_iter().map(|(id, s)| (id, s.ranks)).collect();
        let classic = gather_vertex_values(&dg, &states);
        for (v, (&a, &b)) in br.iter().zip(&classic).enumerate() {
            assert!(
                (a - b).abs() < 5e-4 * (1.0 + b.abs() * 1e3),
                "vertex {v}: blockrank={a} classic={b}"
            );
        }
    }

    #[test]
    fn seeding_reduces_supersteps() {
        let g = gen::social(400, 5, 0.0, 23);
        let (_, seeded_steps) = blockrank_ranks(&g, 3, true);
        let (_, uniform_steps) = blockrank_ranks(&g, 3, false);
        // The paper's claim: BlockRank's warm start converges in fewer
        // supersteps than a uniform start.
        assert!(
            seeded_steps <= uniform_steps,
            "seeded={seeded_steps} uniform={uniform_steps}"
        );
    }

    #[test]
    fn ring_uniform_fixpoint() {
        let n = 16u32;
        let edges: Vec<(u32, u32)> = (0..n).map(|i| (i, (i + 1) % n)).collect();
        let g = crate::graph::Graph::from_edges(n as usize, &edges, None, true).unwrap();
        let (br, _) = blockrank_ranks(&g, 2, true);
        for &r in &br {
            assert!((r - 1.0 / n as f32).abs() < 1e-4, "rank {r}");
        }
    }

    #[test]
    fn msg_codec_round_trip() {
        for m in [
            BrMsg::Row { src: 3, dst: 900, w: 0.25 },
            BrMsg::Contrib { sender: 12345, value: -1.5 },
        ] {
            let mut e = Encoder::new();
            m.encode(&mut e);
            let bytes = e.into_bytes();
            let mut d = Decoder::new(&bytes);
            let back = BrMsg::decode(&mut d).unwrap();
            match (&m, &back) {
                (BrMsg::Row { src: a, dst: b, w: c }, BrMsg::Row { src: x, dst: y, w: z }) => {
                    assert_eq!((a, b, c), (x, y, z));
                }
                (
                    BrMsg::Contrib { sender: a, value: b },
                    BrMsg::Contrib { sender: x, value: y },
                ) => assert_eq!((a, b), (x, y)),
                _ => panic!("kind changed in round trip"),
            }
        }
    }
}
