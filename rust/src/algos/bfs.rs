//! Breadth-First Search levels from a source (a §5 traversal algorithm).
//!
//! Sub-graph centric: flood the whole sub-graph in one superstep (local
//! BFS), push frontier levels across remote edges. Vertex-centric: one
//! hop per superstep. Undirected view for undirected graphs, out-edges
//! for directed ones (matching SSSP's convention).

use std::collections::VecDeque;

use crate::gofs::Subgraph;
use crate::gopher::{IncomingMessage, SubgraphContext, SubgraphProgram};
use crate::graph::csr::{Graph, VertexId};
use crate::pregel::{VertexContext, VertexProgram};

pub const UNREACHED: u32 = u32::MAX;

/// Sub-graph centric BFS.
pub struct BfsSg {
    pub source: VertexId,
}

impl SubgraphProgram for BfsSg {
    type Msg = (u32, u32); // (global vertex, candidate level)
    type State = Vec<u32>; // level per local vertex

    fn init(&self, sg: &Subgraph) -> Vec<u32> {
        vec![UNREACHED; sg.num_vertices()]
    }

    fn compute(
        &self,
        levels: &mut Vec<u32>,
        sg: &Subgraph,
        ctx: &mut SubgraphContext<'_, Self::Msg>,
        msgs: &[IncomingMessage<Self::Msg>],
    ) {
        let mut frontier: Vec<u32> = Vec::new();
        if ctx.superstep() == 1 {
            if let Some(local) = ctx.local_vertex(self.source) {
                levels[local as usize] = 0;
                frontier.push(local);
            }
        }
        for m in msgs {
            let (gv, lvl) = m.payload;
            if let Some(local) = ctx.local_vertex(gv) {
                if lvl < levels[local as usize] {
                    levels[local as usize] = lvl;
                    frontier.push(local);
                }
            }
        }
        if !frontier.is_empty() {
            // In-memory BFS over the whole sub-graph in this superstep.
            let undirected = !sg.local.directed();
            let mut q: VecDeque<u32> = frontier.into_iter().collect();
            let mut improved = vec![false; levels.len()];
            for &v in &q {
                improved[v as usize] = true;
            }
            while let Some(v) = q.pop_front() {
                let lv = levels[v as usize];
                let mut visit = |t: u32, levels: &mut Vec<u32>, q: &mut VecDeque<u32>, improved: &mut Vec<bool>| {
                    if lv + 1 < levels[t as usize] {
                        levels[t as usize] = lv + 1;
                        improved[t as usize] = true;
                        q.push_back(t);
                    }
                };
                let outs: Vec<u32> = sg.local.out_neighbors(v).to_vec();
                for t in outs {
                    visit(t, levels, &mut q, &mut improved);
                }
                if undirected {
                    let ins: Vec<u32> = sg.local.in_neighbors(v).to_vec();
                    for s in ins {
                        visit(s, levels, &mut q, &mut improved);
                    }
                }
            }
            // Boundary push.
            let push = |r: &crate::gofs::RemoteRef,
                        levels: &[u32],
                        improved: &[bool],
                        ctx: &mut SubgraphContext<'_, Self::Msg>| {
                if improved[r.local as usize] {
                    let lvl = levels[r.local as usize];
                    if lvl != UNREACHED {
                        ctx.send_to_subgraph_vertex(
                            crate::gofs::SubgraphId {
                                partition: r.partition,
                                index: r.subgraph,
                            },
                            r.target_global,
                            (r.target_global, lvl + 1),
                        );
                    }
                }
            };
            for r in &sg.remote_out {
                push(r, levels, &improved, ctx);
            }
            if undirected {
                for r in &sg.remote_in {
                    push(r, levels, &improved, ctx);
                }
            }
        }
        ctx.vote_to_halt();
    }

    /// Candidate levels for the same target vertex fold by min.
    fn combine(&self, a: &Self::Msg, b: &Self::Msg) -> Option<Self::Msg> {
        Some(if a.1 <= b.1 { *a } else { *b })
    }

    /// Per-vertex BFS level ([`UNREACHED`] stays the raw sentinel so
    /// both engines emit identical values).
    fn emit(&self, levels: &Vec<u32>, sg: &Subgraph) -> Vec<(VertexId, f64)> {
        sg.vertices
            .iter()
            .zip(levels)
            .map(|(&v, &l)| (v, l as f64))
            .collect()
    }
}

/// Vertex-centric BFS.
pub struct BfsVx {
    pub source: VertexId,
}

impl VertexProgram for BfsVx {
    type Msg = u32;
    type Value = u32;

    fn init(&self, _vertex: VertexId, _g: &Graph) -> u32 {
        UNREACHED
    }

    fn compute(&self, value: &mut u32, ctx: &mut VertexContext<'_, u32>, msgs: &[u32]) {
        let mut best = *value;
        if ctx.superstep() == 1 && ctx.vertex() == self.source {
            best = 0;
        }
        for &m in msgs {
            best = best.min(m);
        }
        if best < *value {
            *value = best;
            let next = best + 1;
            if ctx.graph().directed() {
                ctx.send_to_all_neighbors(next);
            } else {
                ctx.send_to_all_undirected(next);
            }
        }
        ctx.vote_to_halt();
    }

    fn combine(&self, a: &u32, b: &u32) -> Option<u32> {
        Some(*a.min(b))
    }

    fn emit(&self, vertex: VertexId, value: &u32) -> Vec<(VertexId, f64)> {
        vec![(vertex, *value as f64)]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::gather_vertex_values;
    use crate::gofs::subgraph::discover;
    use crate::gopher::{run, GopherConfig};
    use crate::graph::{gen, props};
    use crate::partition::{HashPartitioner, MultilevelPartitioner, Partitioner};
    use crate::pregel::{run_vertex, PregelConfig};

    #[test]
    fn subgraph_bfs_matches_oracle() {
        let g = gen::road(12, 0.9, 0.02, 51);
        let parts = MultilevelPartitioner::default().partition(&g, 3);
        let dg = discover(&g, &parts).unwrap();
        let res = run(&dg, &BfsSg { source: 0 }, &GopherConfig::default()).unwrap();
        let got = gather_vertex_values(&dg, &res.states);
        let want = props::bfs_distances(&g, 0);
        assert_eq!(got, want);
    }

    #[test]
    fn vertex_bfs_matches_oracle() {
        let g = gen::grid(7, 9);
        let parts = HashPartitioner::default().partition(&g, 3);
        let res = run_vertex(&g, &parts, &BfsVx { source: 5 }, &PregelConfig::default()).unwrap();
        assert_eq!(res.values, props::bfs_distances(&g, 5));
    }

    #[test]
    fn directed_bfs_follows_out_edges_only() {
        // 0 -> 1 -> 2, and 3 -> 1 (unreachable from 0 in directed sense).
        let g = crate::graph::Graph::from_edges(4, &[(0, 1), (1, 2), (3, 1)], None, true).unwrap();
        let parts = crate::partition::Partitioning::new(2, vec![0, 0, 1, 1]);
        let dg = discover(&g, &parts).unwrap();
        let res = run(&dg, &BfsSg { source: 0 }, &GopherConfig::default()).unwrap();
        let got = gather_vertex_values(&dg, &res.states);
        assert_eq!(got, vec![0, 1, 2, UNREACHED]);
    }

    #[test]
    fn superstep_advantage_on_chain() {
        let g = gen::chain(100);
        let parts = MultilevelPartitioner::default().partition(&g, 4);
        let dg = discover(&g, &parts).unwrap();
        let sg = run(&dg, &BfsSg { source: 0 }, &GopherConfig::default()).unwrap();
        let vx = run_vertex(
            &g,
            &HashPartitioner::default().partition(&g, 4),
            &BfsVx { source: 0 },
            &PregelConfig::default(),
        )
        .unwrap();
        assert_eq!(
            gather_vertex_values(&dg, &sg.states),
            vx.values
        );
        assert!(sg.metrics.num_supersteps() * 5 < vx.metrics.num_supersteps());
    }
}
