//! Max Vertex Value — the paper's running example (Algorithms 1 and 2).
//!
//! The vertex "value" is its global id (as in the paper's Fig 2, any
//! per-vertex attribute works the same way). The sub-graph centric
//! version finds the local max in-memory in superstep 1, then floods over
//! the meta-graph; the vertex-centric one floods hop by hop.

use crate::gofs::Subgraph;
use crate::gopher::{IncomingMessage, SubgraphContext, SubgraphProgram};
use crate::graph::csr::{Graph, VertexId};
use crate::pregel::{VertexContext, VertexProgram};

/// Sub-graph centric Max Value (paper Algorithm 2).
pub struct MaxValueSg;

impl SubgraphProgram for MaxValueSg {
    type Msg = f32;
    /// The sub-graph's current max (uniform across its vertices).
    type State = f32;

    fn init(&self, _sg: &Subgraph) -> f32 {
        f32::NEG_INFINITY
    }

    fn compute(
        &self,
        state: &mut f32,
        sg: &Subgraph,
        ctx: &mut SubgraphContext<'_, f32>,
        msgs: &[IncomingMessage<f32>],
    ) {
        let mut changed = false;
        if ctx.superstep() == 1 {
            // Shared-memory phase: local max over the whole sub-graph.
            *state = sg
                .vertices
                .iter()
                .map(|&v| v as f32)
                .fold(f32::NEG_INFINITY, f32::max);
            changed = true;
        }
        for m in msgs {
            if m.payload > *state {
                *state = m.payload;
                changed = true;
            }
        }
        if changed {
            ctx.send_to_all_neighbors(*state);
        } else {
            ctx.vote_to_halt();
        }
    }

    /// Values bound for the same sub-graph mailbox fold by max.
    fn combine(&self, a: &f32, b: &f32) -> Option<f32> {
        Some(a.max(*b))
    }

    /// Per-vertex converged max (uniform across the sub-graph).
    fn emit(&self, state: &f32, sg: &Subgraph) -> Vec<(VertexId, f64)> {
        sg.vertices.iter().map(|&v| (v, *state as f64)).collect()
    }
}

/// Vertex-centric Max Value (paper Algorithm 1).
pub struct MaxValueVx;

impl VertexProgram for MaxValueVx {
    type Msg = f32;
    type Value = f32;

    fn init(&self, vertex: VertexId, _g: &Graph) -> f32 {
        vertex as f32
    }

    fn compute(
        &self,
        value: &mut f32,
        ctx: &mut VertexContext<'_, f32>,
        msgs: &[f32],
    ) {
        let mut changed = ctx.superstep() == 1;
        for &m in msgs {
            if m > *value {
                *value = m;
                changed = true;
            }
        }
        if changed {
            ctx.send_to_all_undirected(*value);
        } else {
            ctx.vote_to_halt();
        }
    }

    fn combine(&self, a: &f32, b: &f32) -> Option<f32> {
        Some(a.max(*b))
    }

    fn emit(&self, vertex: VertexId, value: &f32) -> Vec<(VertexId, f64)> {
        vec![(vertex, *value as f64)]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gofs::subgraph::discover;
    use crate::gopher::{run, GopherConfig};
    use crate::graph::gen;
    use crate::partition::{HashPartitioner, Partitioner, RangePartitioner};
    use crate::pregel::{run_vertex, PregelConfig};

    #[test]
    fn both_models_agree_and_sg_uses_fewer_supersteps() {
        let g = gen::road(14, 0.92, 0.02, 21);
        let parts = RangePartitioner.partition(&g, 4);
        let dg = discover(&g, &parts).unwrap();
        let sg_res = run(&dg, &MaxValueSg, &GopherConfig::default()).unwrap();
        let vparts = HashPartitioner::default().partition(&g, 4);
        let vx_res = run_vertex(&g, &vparts, &MaxValueVx, &PregelConfig::default()).unwrap();

        // Per-vertex agreement.
        let sg_vals = crate::algos::gather_subgraph_values(&dg, &sg_res.states);
        for (v, (&a, &b)) in sg_vals.iter().zip(vx_res.values.iter()).enumerate() {
            // Careful: vertex-centric max flows only within its WCC, as
            // does the sub-graph one; both must therefore agree per vertex.
            assert_eq!(a, b, "vertex {v}");
        }
        // Superstep advantage (paper Fig 2: 4 vs 7 on the example).
        assert!(
            sg_res.metrics.num_supersteps() <= vx_res.metrics.num_supersteps(),
            "sg={} vx={}",
            sg_res.metrics.num_supersteps(),
            vx_res.metrics.num_supersteps()
        );
    }

    #[test]
    fn chain_worst_case_gap() {
        // A chain is the paper's best case for sub-graphs: superstep count
        // collapses from O(n) to O(k).
        let g = gen::chain(64);
        let parts = RangePartitioner.partition(&g, 4);
        let dg = discover(&g, &parts).unwrap();
        let sg_res = run(&dg, &MaxValueSg, &GopherConfig::default()).unwrap();
        let vx_res = run_vertex(
            &g,
            &HashPartitioner::default().partition(&g, 4),
            &MaxValueVx,
            &PregelConfig::default(),
        )
        .unwrap();
        assert!(sg_res.metrics.num_supersteps() <= 6);
        assert!(vx_res.metrics.num_supersteps() >= 63);
    }
}
