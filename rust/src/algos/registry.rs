//! Algorithm registry: the single name → runnable-job mapping shared by
//! the CLI and the unified job layer ([`crate::job`]).
//!
//! Each [`AlgoEntry`] binds an algorithm name to up to two monomorphic
//! run functions — one per engine — that construct the concrete program
//! from engine-agnostic [`AlgoParams`], execute it, and return the
//! uniform [`JobOutput`]. This is what collapses the CLI's historical
//! twin `match algo { … }` blocks into one registry-driven path: adding
//! an algorithm is a one-file change (the program) plus one entry here,
//! with no CLI edits.
//!
//! The entry's `gopher`/`vertex` options double as the capability
//! matrix: [`crate::job::JobBuilder::build`] rejects an engine the
//! entry does not implement with a typed error, before anything runs.

use anyhow::Result;

use crate::algos;
use crate::algos::pagerank::RankKernel;
use crate::gofs::{DistributedGraph, Store};
use crate::gopher::{self, GopherConfig, SubgraphProgram};
use crate::graph::{Graph, VertexId};
use crate::job::JobOutput;
use crate::partition::Partitioning;
use crate::pregel::{self, PregelConfig, VertexProgram};

/// Engine-agnostic algorithm parameters. Each run function picks out
/// the fields its program needs and ignores the rest (exactly like CLI
/// flags: `--source` does nothing for PageRank).
#[derive(Clone)]
pub struct AlgoParams {
    /// Source vertex (BFS / SSSP).
    pub source: VertexId,
    /// Fixed iteration count (PageRank) or round cap (label propagation).
    pub supersteps: usize,
    /// Aggregator-driven PageRank convergence threshold (Gopher only;
    /// the job builder rejects it on the vertex engine).
    pub epsilon: Option<f32>,
    /// Numeric kernel for the rank-update hot loops.
    pub kernel: RankKernel,
}

impl Default for AlgoParams {
    fn default() -> Self {
        Self {
            source: 0,
            supersteps: algos::pagerank::DEFAULT_SUPERSTEPS,
            epsilon: None,
            kernel: RankKernel::Scalar,
        }
    }
}

/// Where a Gopher run reads its sub-graphs from.
pub enum GopherTarget<'a> {
    /// An already-discovered in-memory distributed graph.
    Mem(&'a DistributedGraph),
    /// An on-disk GoFS store (data-local loading).
    Disk(&'a Store),
}

impl GopherTarget<'_> {
    /// Sub-graph count per partition (BlockRank's block directory).
    pub fn directory(&self) -> Vec<u32> {
        match self {
            GopherTarget::Mem(dg) => {
                dg.partitions.iter().map(|p| p.len() as u32).collect()
            }
            GopherTarget::Disk(store) => store.meta().subgraph_counts.clone(),
        }
    }
}

/// Boxed-free job factory for the Gopher engine (plain fn pointers:
/// every entry is a monomorphic wrapper around the generic engine).
pub type GopherRunFn =
    fn(&AlgoParams, &GopherTarget<'_>, &GopherConfig) -> Result<JobOutput>;

/// Job factory for the vertex engine.
pub type VertexRunFn =
    fn(&AlgoParams, &Graph, &Partitioning, &PregelConfig) -> Result<JobOutput>;

/// One registered algorithm.
pub struct AlgoEntry {
    pub name: &'static str,
    /// One-line description (`goffish help`-style listings).
    pub description: &'static str,
    /// Sub-graph centric implementation, if any.
    pub gopher: Option<GopherRunFn>,
    /// Vertex-centric implementation, if any.
    pub vertex: Option<VertexRunFn>,
}

/// Run a sub-graph program against either target and wrap the result.
fn run_sg<P: SubgraphProgram>(
    target: &GopherTarget<'_>,
    prog: &P,
    cfg: &GopherConfig,
) -> Result<JobOutput> {
    let res = match target {
        GopherTarget::Mem(dg) => gopher::run(dg, prog, cfg)?,
        GopherTarget::Disk(store) => gopher::run_on_store(store, prog, cfg)?,
    };
    Ok(JobOutput::from_gopher(res))
}

/// Run a vertex program and wrap the result (per-vertex emit included).
fn run_vx<P: VertexProgram>(
    g: &Graph,
    parts: &Partitioning,
    prog: &P,
    cfg: &PregelConfig,
) -> Result<JobOutput> {
    let res = pregel::run_vertex(g, parts, prog, cfg)?;
    Ok(JobOutput::from_vertex(prog, res))
}

// ------------------------------------------------------ per-algo run fns

fn gopher_cc(
    _p: &AlgoParams,
    t: &GopherTarget<'_>,
    cfg: &GopherConfig,
) -> Result<JobOutput> {
    run_sg(t, &algos::cc::CcSg, cfg)
}

fn vertex_cc(
    _p: &AlgoParams,
    g: &Graph,
    parts: &Partitioning,
    cfg: &PregelConfig,
) -> Result<JobOutput> {
    run_vx(g, parts, &algos::cc::CcVx, cfg)
}

fn gopher_maxvalue(
    _p: &AlgoParams,
    t: &GopherTarget<'_>,
    cfg: &GopherConfig,
) -> Result<JobOutput> {
    run_sg(t, &algos::maxvalue::MaxValueSg, cfg)
}

fn vertex_maxvalue(
    _p: &AlgoParams,
    g: &Graph,
    parts: &Partitioning,
    cfg: &PregelConfig,
) -> Result<JobOutput> {
    run_vx(g, parts, &algos::maxvalue::MaxValueVx, cfg)
}

fn gopher_bfs(
    p: &AlgoParams,
    t: &GopherTarget<'_>,
    cfg: &GopherConfig,
) -> Result<JobOutput> {
    run_sg(t, &algos::bfs::BfsSg { source: p.source }, cfg)
}

fn vertex_bfs(
    p: &AlgoParams,
    g: &Graph,
    parts: &Partitioning,
    cfg: &PregelConfig,
) -> Result<JobOutput> {
    run_vx(g, parts, &algos::bfs::BfsVx { source: p.source }, cfg)
}

fn gopher_sssp(
    p: &AlgoParams,
    t: &GopherTarget<'_>,
    cfg: &GopherConfig,
) -> Result<JobOutput> {
    run_sg(t, &algos::sssp::SsspSg { source: p.source }, cfg)
}

fn vertex_sssp(
    p: &AlgoParams,
    g: &Graph,
    parts: &Partitioning,
    cfg: &PregelConfig,
) -> Result<JobOutput> {
    run_vx(g, parts, &algos::sssp::SsspVx { source: p.source }, cfg)
}

fn gopher_pagerank(
    p: &AlgoParams,
    t: &GopherTarget<'_>,
    cfg: &GopherConfig,
) -> Result<JobOutput> {
    let prog = algos::pagerank::PageRankSg {
        supersteps: p.supersteps,
        kernel: p.kernel.clone(),
        epsilon: p.epsilon,
    };
    run_sg(t, &prog, cfg)
}

fn vertex_pagerank(
    p: &AlgoParams,
    g: &Graph,
    parts: &Partitioning,
    cfg: &PregelConfig,
) -> Result<JobOutput> {
    run_vx(g, parts, &algos::pagerank::PageRankVx { supersteps: p.supersteps }, cfg)
}

fn gopher_blockrank(
    p: &AlgoParams,
    t: &GopherTarget<'_>,
    cfg: &GopherConfig,
) -> Result<JobOutput> {
    let mut prog = algos::blockrank::BlockRankSg::new(&t.directory());
    prog.kernel = p.kernel.clone();
    // BlockRank is convergence-driven: cap its superstep budget (the
    // seed CLI hard-coded 500) unless the caller asked for even less.
    let cfg2 = GopherConfig {
        max_supersteps: cfg.max_supersteps.min(500),
        ..cfg.clone()
    };
    run_sg(t, &prog, &cfg2)
}

fn gopher_labelprop(
    p: &AlgoParams,
    t: &GopherTarget<'_>,
    cfg: &GopherConfig,
) -> Result<JobOutput> {
    run_sg(t, &algos::labelprop::LabelPropSg { max_rounds: p.supersteps }, cfg)
}

fn vertex_labelprop(
    p: &AlgoParams,
    g: &Graph,
    parts: &Partitioning,
    cfg: &PregelConfig,
) -> Result<JobOutput> {
    run_vx(g, parts, &algos::labelprop::LabelPropVx { max_rounds: p.supersteps }, cfg)
}

// --------------------------------------------------------------- entries

static ENTRIES: &[AlgoEntry] = &[
    AlgoEntry {
        name: "cc",
        description: "connected components (HCC max-label flood, paper §5.1)",
        gopher: Some(gopher_cc),
        vertex: Some(vertex_cc),
    },
    AlgoEntry {
        name: "maxvalue",
        description: "max vertex value (the paper's Algorithms 1 & 2)",
        gopher: Some(gopher_maxvalue),
        vertex: Some(vertex_maxvalue),
    },
    AlgoEntry {
        name: "bfs",
        description: "breadth-first levels from --source",
        gopher: Some(gopher_bfs),
        vertex: Some(vertex_bfs),
    },
    AlgoEntry {
        name: "sssp",
        description: "single-source shortest paths from --source (Alg 3)",
        gopher: Some(gopher_sssp),
        vertex: Some(vertex_sssp),
    },
    AlgoEntry {
        name: "pagerank",
        description: "damped PageRank; --epsilon enables aggregator convergence",
        gopher: Some(gopher_pagerank),
        vertex: Some(vertex_pagerank),
    },
    AlgoEntry {
        name: "blockrank",
        description: "BlockRank warm-started convergent PageRank (paper §5.3)",
        gopher: Some(gopher_blockrank),
        vertex: None, // the paper has no vertex-centric BlockRank
    },
    AlgoEntry {
        name: "labelprop",
        description: "synchronous label propagation, aggregator-terminated",
        gopher: Some(gopher_labelprop),
        vertex: Some(vertex_labelprop),
    },
];

/// All registered algorithms, in display order.
pub fn entries() -> &'static [AlgoEntry] {
    ENTRIES
}

/// Look an algorithm up by name.
pub fn find(name: &str) -> Option<&'static AlgoEntry> {
    ENTRIES.iter().find(|e| e.name == name)
}

/// Registered algorithm names (for error messages and help output).
pub fn names() -> Vec<&'static str> {
    ENTRIES.iter().map(|e| e.name).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup_by_name() {
        assert!(find("cc").is_some());
        assert!(find("pagerank").is_some());
        assert!(find("frobnicate").is_none());
        assert_eq!(names().len(), ENTRIES.len());
    }

    #[test]
    fn capability_matrix_shape() {
        // Every algorithm has a sub-graph centric implementation; only
        // blockrank lacks a vertex-centric one.
        for e in entries() {
            assert!(e.gopher.is_some(), "{} missing gopher impl", e.name);
            if e.name == "blockrank" {
                assert!(e.vertex.is_none());
            } else {
                assert!(e.vertex.is_some(), "{} missing vertex impl", e.name);
            }
        }
    }

    #[test]
    fn directory_matches_distributed_graph() {
        use crate::gofs::subgraph::discover;
        use crate::partition::{Partitioner, RangePartitioner};
        let g = crate::graph::gen::chain(12);
        let parts = RangePartitioner.partition(&g, 3);
        let dg = discover(&g, &parts).unwrap();
        let dir = GopherTarget::Mem(&dg).directory();
        assert_eq!(dir.len(), 3);
        assert_eq!(dir.iter().sum::<u32>() as usize, dg.num_subgraphs());
    }
}
