//! The unified job layer: one builder-driven entry point over both
//! engines (the §3.2 "single programming abstraction" made concrete at
//! the API surface).
//!
//! Historically the crate exposed two disjoint run surfaces —
//! `gopher::run`/`run_on_store` returning per-sub-graph states, and
//! `pregel::run_vertex` returning a per-vertex value vector — with
//! engine-specific knobs validated ad hoc in the CLI. This module
//! replaces all of that as the way to run anything:
//!
//! ```no_run
//! // (no_run: doctest binaries miss the xla rpath in this image)
//! use goffish::graph::gen;
//! use goffish::job::{EngineKind, Job, JobSource};
//! use goffish::partition::MultilevelPartitioner;
//!
//! # fn main() -> anyhow::Result<()> {
//! let g = gen::road(40, 0.95, 0.01, 42);
//! let out = Job::builder()
//!     .algo("cc")
//!     .engine(EngineKind::Gopher)
//!     .cores(4)
//!     .build()?                       // knob/engine validation happens HERE
//!     .run(JobSource::Graph {
//!         graph: &g,
//!         partitioner: &MultilevelPartitioner::default(),
//!         partitions: 4,
//!     })?;
//! println!("{} vertex values, {} supersteps",
//!          out.values.len(), out.metrics.num_supersteps());
//! # Ok(())
//! # }
//! ```
//!
//! # Engine / knob compatibility matrix
//!
//! Validated by [`JobBuilder::build`], which returns a typed
//! [`JobError`] instead of failing mid-run:
//!
//! | knob                  | Gopher | Vertex | on violation |
//! |-----------------------|--------|--------|--------------|
//! | `algo(...)`           | per [`crate::algos::registry`] entry | per entry | [`JobError::UnsupportedEngine`] (e.g. `blockrank` is Gopher-only) |
//! | `epsilon(...)`        | ✓ (aggregator-driven PageRank convergence) | ✗ | [`JobError::IncompatibleKnob`] |
//! | `combiners(false)`    | ✓ (disables the transport batcher fold) | ✗ (the baseline always folds) | [`JobError::IncompatibleKnob`] |
//! | `fabric` / `cores` / `max_supersteps` | ✓ | ✓ | — |
//! | `supersteps` / `source_vertex` / `kernel` | ✓ | ✓ (kernel is Gopher-only at run time, ignored by vertex programs) | — |
//! | `load_attributes(...)` | ✓ (store-backed loads read exactly the declared attribute columns; a packed v3 store seeks past the rest) | ✗ (the baseline reassembles the whole graph) | [`JobError::IncompatibleKnob`] |
//! | `checkpoint_every` / `checkpoint_dir` / `resume_from` | ✓ | ✓ | [`JobError::CheckpointConfig`] (inconsistent knobs), [`JobError::NoCheckpoint`] / [`JobError::CheckpointMismatch`] (bad resume target) |
//! | `checkpoint_mode` / `checkpoint_compress` / `confined_recovery` | ✓ | ✓ | [`JobError::CheckpointConfig`] (async/compress without checkpointing, confined without `resume_from`); none are result-affecting, so all three are excluded from the checkpoint label |
//! | `incremental_from(...)` | ✓ (store-backed sources only — checked at run time) | ✗ (no sub-graph structure to scope by) | [`JobError::IncompatibleKnob`] |
//! | `mmap(false)` / `dense_index(false)` | ✓ | ✓ | — (never result-affecting: mmap selects the store read path, dense_index the vertex-lookup mechanics) |
//! | `trace(path)` | ✓ | ✓ | — (never result-affecting: spans only observe the run; writes a Chrome trace-event JSON timeline after it) |
//!
//! # Sources
//!
//! A built [`Job`] runs against any [`JobSource`]:
//!
//! * [`JobSource::InMemory`] — an already-discovered
//!   [`DistributedGraph`]. The vertex engine reassembles it via
//!   [`crate::gofs::reassemble`] and hash-scatters, Giraph-style.
//! * [`JobSource::Store`] — an on-disk GoFS [`Store`]; data-local
//!   loading on Gopher, reassemble + hash scatter on the vertex engine.
//! * [`JobSource::Graph`] — a full [`Graph`] plus a partitioner; the
//!   job layer partitions (and, for Gopher, discovers sub-graphs)
//!   before running.
//!
//! # Output
//!
//! Both engines land in one [`JobOutput`]: per-vertex `values` (from
//! the programs' `emit` hooks, sorted by global vertex id), the full
//! [`JobMetrics`], and the coordinator's per-superstep aggregator
//! traces.

mod builder;

pub use builder::{EngineKind, JobBuilder, JobError};

use anyhow::Result;

use crate::algos::registry::GopherTarget;
use crate::ckpt;
use crate::coordinator::AggregatorTrace;
use crate::gofs::{self, AttrProjection, DistributedGraph, Store};
use crate::gopher::{self, FabricKind, GopherConfig};
use crate::graph::{Graph, VertexId};
use crate::metrics::JobMetrics;
use crate::partition::{HashPartitioner, Partitioner};
use crate::pregel::{self, PregelConfig, VertexProgram};

/// The uniform result of any job, on any engine, from any source.
#[derive(Debug)]
pub struct JobOutput {
    /// Per-vertex result values from the program's `emit` hook, sorted
    /// by global vertex id. Empty only for programs that keep the
    /// default no-op emit (none of the built-in algorithms do).
    pub values: Vec<(VertexId, f64)>,
    /// Full execution metrics (supersteps, bytes, walls, traces).
    pub metrics: JobMetrics,
    /// Per-superstep global aggregator traces (coordinator layer), one
    /// per aggregator the program registered. Mirrors
    /// `metrics.aggregators` for direct access.
    pub aggregators: Vec<AggregatorTrace>,
}

impl JobOutput {
    /// Wrap a Gopher engine result (values already harvested + sorted
    /// by the engine).
    pub(crate) fn from_gopher<S>(res: gopher::RunResult<S>) -> JobOutput {
        JobOutput {
            values: res.values,
            aggregators: res.metrics.aggregators.clone(),
            metrics: res.metrics,
        }
    }

    /// Wrap a vertex engine result, emitting per-vertex values in
    /// global id order (the engine already merges values that way).
    pub(crate) fn from_vertex<P: VertexProgram>(
        prog: &P,
        res: pregel::VertexRunResult<P::Value>,
    ) -> JobOutput {
        let mut values = Vec::with_capacity(res.values.len());
        for (v, val) in res.values.iter().enumerate() {
            values.extend(prog.emit(v as VertexId, val));
        }
        JobOutput {
            values,
            aggregators: res.metrics.aggregators.clone(),
            metrics: res.metrics,
        }
    }
}

/// What a [`Job`] runs against.
pub enum JobSource<'a> {
    /// An already-discovered in-memory distributed graph.
    InMemory(&'a DistributedGraph),
    /// An on-disk GoFS store.
    Store(&'a Store),
    /// A full graph plus a partitioner to scatter it with.
    Graph {
        /// The graph to run over.
        graph: &'a Graph,
        /// Partitioner used to scatter it.
        partitioner: &'a dyn Partitioner,
        /// Number of partitions (workers).
        partitions: usize,
    },
}

/// A validated, runnable job. Construct via [`Job::builder`]; all
/// knob/engine compatibility checks already passed in
/// [`JobBuilder::build`], so [`Job::run`] only surfaces execution
/// errors.
pub struct Job {
    pub(crate) entry: &'static crate::algos::registry::AlgoEntry,
    pub(crate) engine: EngineKind,
    pub(crate) params: crate::algos::registry::AlgoParams,
    pub(crate) fabric: FabricKind,
    pub(crate) cores: usize,
    pub(crate) combiners: bool,
    pub(crate) max_supersteps: usize,
    pub(crate) load_attributes: Vec<String>,
    /// Job identity recorded in checkpoint manifests (`algo/engine`).
    pub(crate) label: String,
    /// `(every, dir)` from the builder's checkpoint knobs.
    pub(crate) checkpoint: Option<(usize, std::path::PathBuf)>,
    /// Sync (in-barrier persist) or async (background flusher); see
    /// [`JobBuilder::checkpoint_mode`].
    pub(crate) checkpoint_mode: ckpt::CheckpointMode,
    /// Run-length pack checkpoint sections; see
    /// [`JobBuilder::checkpoint_compress`].
    pub(crate) checkpoint_compress: bool,
    /// Resolved at build time (latest valid committed epoch).
    pub(crate) resume: Option<ckpt::ResumePoint>,
    /// Failure-injection testing hook.
    pub(crate) fail_at: Option<ckpt::FailPoint>,
    /// Scope output to sub-graphs dirty since this store generation
    /// (see [`JobBuilder::incremental_from`]).
    pub(crate) incremental_from: Option<u64>,
    /// Live run-control handle threaded into the engine managers
    /// (supervised runs: progress + cancellation; see `serve`).
    pub(crate) control: Option<crate::coordinator::RunControl>,
    /// Memory-map packed partition files on store-backed loads
    /// (default true; see [`JobBuilder::mmap`]).
    pub(crate) mmap: bool,
    /// Dense vertex-index lookup in the compute loop (default true;
    /// see [`JobBuilder::dense_index`]).
    pub(crate) dense_index: bool,
    /// Write a Chrome trace-event JSON span timeline of each run to
    /// this path (see [`JobBuilder::trace`]); `None` leaves tracing
    /// disabled (zero-cost in the superstep hot path).
    pub(crate) trace: Option<std::path::PathBuf>,
    /// Precomputed per-partition vertex indexes shared by a resident
    /// store (see [`Job::with_vertex_indexes`]); `None` lets the
    /// engine build its own at worker init.
    pub(crate) vertex_indexes:
        Option<std::sync::Arc<Vec<Vec<crate::util::index::VertexIndex>>>>,
}

impl std::fmt::Debug for Job {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Job")
            .field("algo", &self.entry.name)
            .field("engine", &self.engine)
            .field("fabric", &self.fabric)
            .field("cores", &self.cores)
            .field("combiners", &self.combiners)
            .field("max_supersteps", &self.max_supersteps)
            .finish_non_exhaustive()
    }
}

impl Job {
    /// Start describing a job.
    pub fn builder() -> JobBuilder {
        JobBuilder::default()
    }

    /// The registered algorithm name this job will run.
    pub fn algo_name(&self) -> &'static str {
        self.entry.name
    }

    /// The engine this job will run on.
    pub fn engine(&self) -> EngineKind {
        self.engine
    }

    /// Attach precomputed per-partition, per-sub-graph vertex indexes
    /// (the resident `serve` store builds them once per snapshot and
    /// shares them across every job on it). The engine uses them only
    /// when dense indexing is enabled and the shape matches the loaded
    /// graph; they are never result-affecting.
    pub fn with_vertex_indexes(
        mut self,
        indexes: std::sync::Arc<Vec<Vec<crate::util::index::VertexIndex>>>,
    ) -> Self {
        self.vertex_indexes = Some(indexes);
        self
    }

    /// Execute against a source. The same built job can run against
    /// several sources (it holds no per-run state; a resumed job
    /// re-resolves its epoch at each run, since an earlier run of this
    /// same job may have committed past — and pruned — the epoch
    /// resolved at build time).
    ///
    /// A job built with [`JobBuilder::incremental_from`] additionally
    /// requires a [`JobSource::Store`]: the run consults
    /// `Store::dirty_since` first, skips execution entirely when no
    /// sub-graph changed, and otherwise filters the output values to
    /// vertices in dirty sub-graphs (the computation itself still
    /// covers the whole graph — see the builder docs for why).
    pub fn run(&self, source: JobSource<'_>) -> Result<JobOutput> {
        let Some(since) = self.incremental_from else {
            return self.run_full(source);
        };
        let store = match source {
            JobSource::Store(s) => s,
            _ => anyhow::bail!(
                "incremental_from requires a store-backed source \
                 (dirty-sub-graph tracking lives in the GoFS store)"
            ),
        };
        let dirty = store.dirty_since(since)?;
        if dirty.is_empty() {
            return Ok(JobOutput {
                values: Vec::new(),
                metrics: JobMetrics::default(),
                aggregators: Vec::new(),
            });
        }
        let mut out = self.run_full(JobSource::Store(store))?;
        let locs = store.vertex_locations()?;
        let dirty: std::collections::BTreeSet<_> = dirty.into_iter().collect();
        out.values.retain(|&(v, _)| dirty.contains(&locs[v as usize]));
        Ok(out)
    }

    /// The unconditional execution path behind [`Job::run`].
    fn run_full(&self, source: JobSource<'_>) -> Result<JobOutput> {
        let checkpoint = self.checkpoint.as_ref().map(|(every, dir)| {
            ckpt::CheckpointConfig {
                every: *every,
                dir: dir.clone(),
                label: self.label.clone(),
                mode: self.checkpoint_mode,
                compress: self.checkpoint_compress,
            }
        });
        let resume = match &self.resume {
            None => None,
            Some(rp) => {
                let reader = ckpt::CheckpointReader::open(&rp.dir)?;
                let epoch = if reader.manifest().epochs.contains(&rp.epoch) {
                    rp.epoch
                } else {
                    reader.latest_valid()?
                };
                Some(ckpt::ResumePoint {
                    dir: rp.dir.clone(),
                    epoch,
                    confined: rp.confined,
                })
            }
        };
        // One sink per run: spans from every worker/manager land in it,
        // and the timeline is serialized after the run completes. A
        // disabled tracer is `None` all the way down — the engines then
        // skip every span at the cost of one branch each.
        let tracer = if self.trace.is_some() {
            crate::obs::trace::Tracer::enabled()
        } else {
            crate::obs::trace::Tracer::default()
        };
        let out = match self.engine {
            EngineKind::Gopher => {
                let cfg = GopherConfig {
                    cores_per_worker: self.cores,
                    fabric: self.fabric,
                    combiners: self.combiners,
                    max_supersteps: self.max_supersteps,
                    load_attributes: if self.load_attributes.is_empty() {
                        AttrProjection::None
                    } else {
                        AttrProjection::Only(self.load_attributes.clone())
                    },
                    checkpoint,
                    resume,
                    fail_at: self.fail_at,
                    control: self.control.clone(),
                    mmap: self.mmap,
                    dense_index: self.dense_index,
                    vertex_indexes: self.vertex_indexes.clone(),
                    trace: tracer.clone(),
                    ..Default::default()
                };
                let run = self.entry.gopher.expect("validated at build time");
                match source {
                    JobSource::InMemory(dg) => {
                        run(&self.params, &GopherTarget::Mem(dg), &cfg)
                    }
                    JobSource::Store(store) => {
                        run(&self.params, &GopherTarget::Disk(store), &cfg)
                    }
                    JobSource::Graph { graph, partitioner, partitions } => {
                        let parts = partitioner.partition(graph, partitions);
                        let dg = gofs::subgraph::discover(graph, &parts)?;
                        run(&self.params, &GopherTarget::Mem(&dg), &cfg)
                    }
                }
            }
            EngineKind::Vertex => {
                let cfg = PregelConfig {
                    cores_per_worker: self.cores,
                    fabric: self.fabric,
                    max_supersteps: self.max_supersteps,
                    checkpoint,
                    resume,
                    fail_at: self.fail_at,
                    control: self.control.clone(),
                    dense_index: self.dense_index,
                    trace: tracer.clone(),
                    ..Default::default()
                };
                let run = self.entry.vertex.expect("validated at build time");
                match source {
                    JobSource::Graph { graph, partitioner, partitions } => {
                        let parts = partitioner.partition(graph, partitions);
                        run(&self.params, graph, &parts, &cfg)
                    }
                    JobSource::Store(store) => {
                        // Giraph-style: rebuild the flat edge list from
                        // the store and hash-scatter it.
                        let (dg, _, _) = store.load_all_with(&gofs::LoadOptions {
                            mmap: self.mmap,
                            ..Default::default()
                        })?;
                        let g = gofs::reassemble(&dg)?;
                        let parts = HashPartitioner::default()
                            .partition(&g, store.meta().num_partitions as usize);
                        run(&self.params, &g, &parts, &cfg)
                    }
                    JobSource::InMemory(dg) => {
                        let g = gofs::reassemble(dg)?;
                        let parts = HashPartitioner::default()
                            .partition(&g, dg.num_partitions().max(1));
                        run(&self.params, &g, &parts, &cfg)
                    }
                }
            }
        };
        let mut out = out?;
        if let Some(path) = &self.trace {
            tracer.write_file(path)?;
            out.metrics.phases = tracer.phase_totals();
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;
    use crate::partition::MultilevelPartitioner;

    #[test]
    fn graph_source_runs_both_engines() {
        let g = gen::road(10, 0.9, 0.02, 5);
        let part = MultilevelPartitioner::default();
        let source = || JobSource::Graph {
            graph: &g,
            partitioner: &part,
            partitions: 2,
        };
        let a = Job::builder()
            .algo("cc")
            .build()
            .unwrap()
            .run(source())
            .unwrap();
        let b = Job::builder()
            .algo("cc")
            .engine(EngineKind::Vertex)
            .build()
            .unwrap()
            .run(source())
            .unwrap();
        assert_eq!(a.values.len(), g.num_vertices());
        assert_eq!(a.values, b.values);
        // values are sorted by global vertex id on both engines.
        for (i, &(v, _)) in a.values.iter().enumerate() {
            assert_eq!(v as usize, i);
        }
    }

    #[test]
    fn projected_store_run_matches_unprojected() {
        let g = gen::road(12, 0.9, 0.02, 7);
        let part = MultilevelPartitioner::default();
        let parts = part.partition(&g, 2);
        let root = std::env::temp_dir()
            .join("goffish_job_tests")
            .join(format!("projected_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&root);
        let (store, dg) = Store::create(&root, "g", &g, &parts).unwrap();
        for sg in dg.subgraphs() {
            let vals: Vec<f32> = sg.vertices.iter().map(|&v| v as f32).collect();
            store.write_attribute(sg.id, "rank", &vals).unwrap();
        }
        let plain = Job::builder()
            .algo("cc")
            .build()
            .unwrap()
            .run(JobSource::Store(&store))
            .unwrap();
        let projected = Job::builder()
            .algo("cc")
            .load_attributes(["rank"])
            .build()
            .unwrap()
            .run(JobSource::Store(&store))
            .unwrap();
        // Same answers; the projected run read the extra attribute slices.
        assert_eq!(plain.values, projected.values);
        assert!(projected.metrics.load_bytes > plain.metrics.load_bytes);
    }

    #[test]
    fn incremental_run_scopes_output_and_generations_isolate() {
        use crate::gofs::{AppendBatch, SliceFormat};

        let g = gen::road(8, 0.9, 0.02, 13);
        let part = MultilevelPartitioner::default();
        let parts = part.partition(&g, 3);
        let root = std::env::temp_dir()
            .join("goffish_job_tests")
            .join(format!("incremental_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&root);
        let (store, _) =
            Store::create_with_format(&root, "g", &g, &parts, SliceFormat::V3Packed)
                .unwrap();
        let job = Job::builder().algo("cc").build().unwrap();
        let before = job.run(JobSource::Store(&store)).unwrap();

        // incremental_from demands a store-backed source.
        let inc = Job::builder().algo("cc").incremental_from(0).build().unwrap();
        let err = inc
            .run(JobSource::Graph { graph: &g, partitioner: &part, partitions: 3 })
            .unwrap_err();
        assert!(format!("{err:#}").contains("store-backed"), "{err:#}");

        // Append a new vertex plus one cross-partition edge to it.
        let n = g.num_vertices() as u64;
        let newp = HashPartitioner::default().bucket(n, 3);
        let a = (0..g.num_vertices() as u32).find(|&v| parts.of(v) != newp).unwrap();
        let mut head = Store::open(&root).unwrap();
        let committed = head
            .append(&AppendBatch {
                new_vertices: 1,
                edges: vec![(a as u64, n, None)],
                ..Default::default()
            })
            .unwrap();
        assert_eq!(committed, 1);

        // Generation isolation: the handle pinned before the append
        // reruns to the identical output.
        let again = job.run(JobSource::Store(&store)).unwrap();
        assert_eq!(before.values, again.values);

        // A head handle sees the append; the incremental run's values
        // are exactly the full run's, restricted to dirty sub-graphs.
        let head = Store::open(&root).unwrap();
        let full = job.run(JobSource::Store(&head)).unwrap();
        assert_eq!(full.values.len(), n as usize + 1);
        let out = inc.run(JobSource::Store(&head)).unwrap();
        let dirty: std::collections::BTreeSet<_> =
            head.dirty_since(0).unwrap().into_iter().collect();
        let locs = head.vertex_locations().unwrap();
        let expect: Vec<_> = full
            .values
            .iter()
            .copied()
            .filter(|&(v, _)| dirty.contains(&locs[v as usize]))
            .collect();
        assert_eq!(out.values, expect);
        assert!(!out.values.is_empty());
        assert!(out.values.len() < full.values.len());

        // Nothing dirty since the head generation: the run is skipped.
        let quiet = Job::builder().algo("cc").incremental_from(1).build().unwrap();
        let out = quiet.run(JobSource::Store(&head)).unwrap();
        assert!(out.values.is_empty());
        assert!(out.metrics.supersteps.is_empty());
        let _ = std::fs::remove_dir_all(&root);
    }

    /// E2E trace validity: a traced run writes a Chrome trace-event
    /// file that (a) re-parses under the strict `serve::json` parser,
    /// (b) carries exactly `num_supersteps()` superstep spans per
    /// worker lane, (c) nests every phase span inside a same-lane
    /// superstep span, and (d) keeps each lane's per-superstep phase
    /// sums within the enclosing superstep span's duration.
    #[test]
    fn traced_run_writes_a_valid_chrome_trace() {
        use crate::serve::json::JsonValue;

        let g = gen::road(12, 0.9, 0.02, 11);
        let part = MultilevelPartitioner::default();
        let dir = std::env::temp_dir()
            .join("goffish_job_tests")
            .join(format!("trace_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace.json");
        let partitions = 2usize;
        let out = Job::builder()
            .algo("cc")
            .trace(&path)
            .build()
            .unwrap()
            .run(JobSource::Graph { graph: &g, partitioner: &part, partitions })
            .unwrap();
        let n_ss = out.metrics.num_supersteps();
        assert!(n_ss > 0);
        // The report gained its per-phase breakdown.
        assert!(out.metrics.phases.is_some());
        assert!(out.metrics.report("cc").contains("phases["), "{}", out.metrics.report("cc"));

        let text = std::fs::read_to_string(&path).unwrap();
        let v = JsonValue::parse(&text).unwrap();
        let rows = v.get("traceEvents").unwrap().as_array().unwrap();
        assert!(!rows.is_empty());
        // Decode (tid, name, ts, dur) tuples once.
        let ev: Vec<(u32, String, f64, f64)> = rows
            .iter()
            .map(|r| {
                (
                    r.get("tid").unwrap().as_f64().unwrap() as u32,
                    r.get("name").unwrap().as_str().unwrap().to_string(),
                    r.get("ts").unwrap().as_f64().unwrap(),
                    r.get("dur").unwrap().as_f64().unwrap(),
                )
            })
            .collect();
        for p in 0..partitions as u32 {
            let lane = p + 1;
            // One load span and exactly num_supersteps superstep spans
            // per worker lane.
            assert_eq!(
                ev.iter().filter(|e| e.0 == lane && e.1 == "load").count(),
                1
            );
            let steps: Vec<_> = ev
                .iter()
                .filter(|e| e.0 == lane && e.1 == "superstep")
                .collect();
            assert_eq!(steps.len(), n_ss, "lane {lane}");
            // Every phase span on this lane nests inside some superstep
            // span on the same lane.
            for phase in ev.iter().filter(|e| {
                e.0 == lane
                    && matches!(e.1.as_str(), "compute" | "route" | "drain" | "barrier")
            }) {
                assert!(
                    steps.iter().any(|s| phase.2 >= s.2 && phase.2 + phase.3 <= s.2 + s.3),
                    "phase {:?} not nested in any superstep span on lane {lane}",
                    phase
                );
            }
            // Per-lane phase sums never exceed the lane's superstep walls.
            let phase_sum: f64 = ev
                .iter()
                .filter(|e| {
                    e.0 == lane
                        && matches!(e.1.as_str(), "compute" | "route" | "drain" | "barrier")
                })
                .map(|e| e.3)
                .sum();
            let step_sum: f64 = steps.iter().map(|s| s.3).sum();
            // Span endpoints are floored to whole microseconds against a
            // common origin, so nested phase durations telescope and the
            // bound holds exactly; keep +n_ss slack anyway so a future
            // change in rounding can't make this flaky.
            assert!(
                phase_sum <= step_sum + n_ss as f64,
                "lane {lane}: phases {phase_sum}us > supersteps {step_sum}us"
            );
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn output_mirrors_aggregator_traces() {
        let g = gen::social(120, 3, 0.0, 8);
        let part = MultilevelPartitioner::default();
        let out = Job::builder()
            .algo("pagerank")
            .epsilon(0.05)
            .supersteps(60)
            .build()
            .unwrap()
            .run(JobSource::Graph { graph: &g, partitioner: &part, partitions: 2 })
            .unwrap();
        assert!(!out.aggregators.is_empty());
        assert_eq!(out.aggregators.len(), out.metrics.aggregators.len());
        assert_eq!(
            out.aggregators[0].values,
            out.metrics.aggregators[0].values
        );
    }
}
