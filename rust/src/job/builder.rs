//! [`JobBuilder`]: fluent job description with build-time validation.
//!
//! The builder accepts every knob either engine understands and defers
//! nothing to run time that can be checked up front: unknown algorithm
//! names, engines an algorithm does not implement, and Gopher-only
//! knobs on the vertex engine all fail [`JobBuilder::build`] with a
//! typed [`JobError`] (the CLI's old scattered `bail!`s, promoted to an
//! API contract).

use std::fmt;
use std::path::PathBuf;

use crate::algos::pagerank::RankKernel;
use crate::algos::registry::{self, AlgoParams};
use crate::ckpt;
use crate::gopher::FabricKind;
use crate::graph::VertexId;

use super::Job;

/// Which BSP engine executes the job.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    /// The sub-graph centric Gopher engine (paper §4.2).
    Gopher,
    /// The vertex-centric Giraph-style baseline.
    Vertex,
}

impl fmt::Display for EngineKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            EngineKind::Gopher => "gopher",
            EngineKind::Vertex => "vertex",
        })
    }
}

/// Build-time job validation failure.
#[derive(Clone, Debug, PartialEq)]
pub enum JobError {
    /// [`JobBuilder::algo`] was never called.
    MissingAlgo,
    /// No registry entry under this name.
    UnknownAlgo {
        /// The name that failed to resolve.
        algo: String,
        /// The names that *are* registered.
        known: Vec<&'static str>,
    },
    /// The algorithm has no implementation for the requested engine.
    UnsupportedEngine {
        /// The algorithm that lacks the implementation.
        algo: String,
        /// The engine that was requested.
        engine: EngineKind,
    },
    /// The knob is not meaningful on the requested engine.
    IncompatibleKnob {
        /// The offending builder knob.
        knob: &'static str,
        /// The engine it is incompatible with.
        engine: EngineKind,
        /// Why, and what to do instead.
        hint: &'static str,
    },
    /// Inconsistent checkpointing knobs (e.g. a cadence without a
    /// directory, or a zero cadence).
    CheckpointConfig {
        /// What is inconsistent.
        reason: &'static str,
    },
    /// `resume_from` names a directory with no recoverable checkpoint:
    /// missing/unreadable manifest, no committed epoch, or every
    /// committed epoch failed checksum validation.
    NoCheckpoint {
        /// The directory that was named.
        dir: String,
        /// Why nothing in it is recoverable.
        reason: String,
    },
    /// `resume_from` names a checkpoint written by a different job:
    /// another algorithm/engine, or the same one with different
    /// result-affecting parameters (source, supersteps, epsilon,
    /// combiners, kernel, cores).
    CheckpointMismatch {
        /// The directory that was named.
        dir: String,
        /// This job's manifest label.
        expected: String,
        /// The label found in the directory.
        found: String,
    },
}

impl fmt::Display for JobError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            JobError::MissingAlgo => {
                write!(f, "no algorithm named; call JobBuilder::algo(...)")
            }
            JobError::UnknownAlgo { algo, known } => {
                write!(f, "unknown algorithm {algo:?}; known: {}", known.join(", "))
            }
            JobError::UnsupportedEngine { algo, engine } => {
                write!(f, "algorithm {algo:?} has no {engine}-engine implementation")
            }
            JobError::IncompatibleKnob { knob, engine, hint } => {
                write!(
                    f,
                    "knob `{knob}` is not supported on the {engine} engine ({hint})"
                )
            }
            JobError::CheckpointConfig { reason } => {
                write!(f, "invalid checkpoint configuration: {reason}")
            }
            JobError::NoCheckpoint { dir, reason } => {
                write!(f, "no recoverable checkpoint in {dir}: {reason}")
            }
            JobError::CheckpointMismatch { dir, expected, found } => {
                write!(
                    f,
                    "checkpoint in {dir} belongs to job {found:?}, not {expected:?}"
                )
            }
        }
    }
}

impl std::error::Error for JobError {}

/// Fluent description of a job; see [`crate::job`] for the
/// engine/knob compatibility matrix that [`JobBuilder::build`] enforces.
#[derive(Clone)]
pub struct JobBuilder {
    algo: Option<String>,
    engine: EngineKind,
    fabric: FabricKind,
    cores: usize,
    combiners: Option<bool>,
    epsilon: Option<f32>,
    max_supersteps: usize,
    supersteps: usize,
    source_vertex: VertexId,
    kernel: RankKernel,
    load_attributes: Vec<String>,
    checkpoint_every: Option<usize>,
    checkpoint_dir: Option<PathBuf>,
    checkpoint_mode: ckpt::CheckpointMode,
    checkpoint_compress: bool,
    resume_from: Option<PathBuf>,
    confined_recovery: bool,
    kill_at: Option<ckpt::FailPoint>,
    control: Option<crate::coordinator::RunControl>,
    incremental_from: Option<u64>,
    mmap: bool,
    dense_index: bool,
    trace: Option<PathBuf>,
}

impl Default for JobBuilder {
    fn default() -> Self {
        Self {
            algo: None,
            engine: EngineKind::Gopher,
            fabric: FabricKind::InProc,
            cores: 4,
            combiners: None,
            epsilon: None,
            max_supersteps: 10_000,
            supersteps: crate::algos::pagerank::DEFAULT_SUPERSTEPS,
            source_vertex: 0,
            kernel: RankKernel::Scalar,
            load_attributes: Vec::new(),
            checkpoint_every: None,
            checkpoint_dir: None,
            checkpoint_mode: ckpt::CheckpointMode::Sync,
            checkpoint_compress: false,
            resume_from: None,
            confined_recovery: false,
            kill_at: None,
            control: None,
            incremental_from: None,
            mmap: true,
            dense_index: true,
            trace: None,
        }
    }
}

impl JobBuilder {
    /// Algorithm name (a [`crate::algos::registry`] entry). Required.
    pub fn algo(mut self, name: impl Into<String>) -> Self {
        self.algo = Some(name.into());
        self
    }

    /// Engine to run on (default: [`EngineKind::Gopher`]).
    pub fn engine(mut self, engine: EngineKind) -> Self {
        self.engine = engine;
        self
    }

    /// Data fabric between workers (default: in-process).
    pub fn fabric(mut self, fabric: FabricKind) -> Self {
        self.fabric = fabric;
        self
    }

    /// Compute threads per worker (default: 4).
    pub fn cores(mut self, cores: usize) -> Self {
        self.cores = cores;
        self
    }

    /// Enable/disable message combiners. Gopher-only when `false`
    /// (default: enabled on both engines).
    pub fn combiners(mut self, on: bool) -> Self {
        self.combiners = Some(on);
        self
    }

    /// Aggregator-driven PageRank convergence threshold. Gopher-only.
    pub fn epsilon(mut self, eps: f32) -> Self {
        self.epsilon = Some(eps);
        self
    }

    /// Safety cap on supersteps (default: 10 000).
    pub fn max_supersteps(mut self, n: usize) -> Self {
        self.max_supersteps = n;
        self
    }

    /// Fixed iteration count (PageRank) / round cap (label propagation).
    pub fn supersteps(mut self, n: usize) -> Self {
        self.supersteps = n;
        self
    }

    /// Source vertex for traversal algorithms (BFS, SSSP; default 0).
    pub fn source_vertex(mut self, v: VertexId) -> Self {
        self.source_vertex = v;
        self
    }

    /// Numeric kernel for rank-update hot loops (scalar or AOT XLA).
    pub fn kernel(mut self, kernel: RankKernel) -> Self {
        self.kernel = kernel;
        self
    }

    /// Attribute projection for store-backed Gopher runs: the load path
    /// reads exactly these attribute columns alongside topology (paper
    /// §4.1's "a graph with 10 attributes … only loads the slice it
    /// needs"), exposing them via `SubgraphContext::attribute`. On a
    /// per-file (v1/v2) store undeclared attribute slices are never
    /// opened; on a packed (v3) store the loader physically `seek`s
    /// past undeclared columns inside `partition.gfsp`, and
    /// `JobMetrics::load_bytes` counts only the section bytes actually
    /// streamed. Gopher-only; a no-op for in-memory sources.
    pub fn load_attributes<I, S>(mut self, names: I) -> Self
    where
        I: IntoIterator<Item = S>,
        S: Into<String>,
    {
        self.load_attributes = names.into_iter().map(Into::into).collect();
        self
    }

    /// Checkpoint every `n` supersteps into
    /// [`JobBuilder::checkpoint_dir`] (or, when resuming, back into the
    /// [`JobBuilder::resume_from`] directory). See [`crate::ckpt`] for
    /// the epoch layout and commit semantics.
    pub fn checkpoint_every(mut self, n: usize) -> Self {
        self.checkpoint_every = Some(n);
        self
    }

    /// Directory checkpoints are written to (requires
    /// [`JobBuilder::checkpoint_every`]).
    pub fn checkpoint_dir(mut self, dir: impl Into<PathBuf>) -> Self {
        self.checkpoint_dir = Some(dir.into());
        self
    }

    /// How epoch snapshots reach disk (default: sync). Sync persists
    /// inside the barrier; async double-buffers the encoded snapshot at
    /// the barrier and a background flusher thread writes it while the
    /// next superstep computes (the CLI's `--checkpoint-mode` flag).
    /// Not result-affecting — both modes commit identical epochs — so
    /// it is excluded from the checkpoint label: a sync-written
    /// directory resumes fine under async and vice versa.
    pub fn checkpoint_mode(mut self, mode: ckpt::CheckpointMode) -> Self {
        self.checkpoint_mode = mode;
        self
    }

    /// Run-length pack checkpoint section bodies (default: off; the
    /// CLI's `--checkpoint-compress` flag). Checksums cover the packed
    /// bytes, so `store verify`-style scrubbing still works. Not
    /// result-affecting and excluded from the checkpoint label —
    /// readers dispatch on each file's own version byte, so compressed
    /// and uncompressed epochs can coexist in one directory.
    pub fn checkpoint_compress(mut self, on: bool) -> Self {
        self.checkpoint_compress = on;
        self
    }

    /// Confined recovery (requires [`JobBuilder::resume_from`]): restart
    /// only the worker named by the checkpoint directory's
    /// `FAILED_WORKER` marker from its snapshot, replaying its in-flight
    /// messages from the surviving senders' logs instead of rebuilding
    /// every worker's queues from snapshots. Byte-exact with a global
    /// rollback — deterministic replay makes the two indistinguishable —
    /// so it is excluded from the checkpoint label.
    pub fn confined_recovery(mut self, on: bool) -> Self {
        self.confined_recovery = on;
        self
    }

    /// Resume from the latest *valid* committed epoch in a checkpoint
    /// directory (corrupt epochs fall back to the previous one).
    /// Validated at build time: a directory with no recoverable epoch
    /// is [`JobError::NoCheckpoint`], one written by a different
    /// algorithm/engine is [`JobError::CheckpointMismatch`]. The run
    /// must use the same source and partitioning as the original.
    pub fn resume_from(mut self, dir: impl Into<PathBuf>) -> Self {
        self.resume_from = Some(dir.into());
        self
    }

    /// Failure-injection testing hook: kill `worker` at the start of
    /// `superstep`, exactly like a crashed host — the job aborts with an
    /// error, after which a `resume_from` run recovers it. Drives the
    /// kill-and-resume recovery tests and the CLI `--kill-at` flag.
    pub fn kill_at(mut self, superstep: usize, worker: u32) -> Self {
        self.kill_at = Some(ckpt::FailPoint { superstep, worker });
        self
    }

    /// Scope the job's output to the sub-graphs mutated since store
    /// generation `since` (see `Store::dirty_since` and
    /// `Store::append`). The run still executes the full computation —
    /// dirty sub-graphs can change values anywhere downstream, so
    /// correctness demands it — but `JobOutput::values` is filtered to
    /// vertices living in dirty sub-graphs, which is what an
    /// incremental consumer re-ingests. When nothing changed since
    /// `since`, the run is skipped entirely and the output is empty.
    /// Requires a store-backed source ([`crate::job::JobSource::Store`],
    /// where the dirty tracking lives) and the Gopher engine; not part
    /// of the checkpoint label because the computation itself is
    /// unchanged.
    pub fn incremental_from(mut self, since: u64) -> Self {
        self.incremental_from = Some(since);
        self
    }

    /// Memory-map packed partition files on store-backed runs instead
    /// of seek+read (default: true; the CLI's `--no-mmap` flag). Not
    /// result-affecting — both paths decode the same checksummed
    /// sections — so it is excluded from the checkpoint label.
    pub fn mmap(mut self, on: bool) -> Self {
        self.mmap = on;
        self
    }

    /// Resolve vertex lookups in the compute loop through a dense
    /// remap index (default: true; the CLI's `--no-dense-index` flag
    /// forces the sorted-search fallback). Not result-affecting — the
    /// index variants are interchangeable by construction — so it is
    /// excluded from the checkpoint label.
    pub fn dense_index(mut self, on: bool) -> Self {
        self.dense_index = on;
        self
    }

    /// Record a structured span trace of the run ([`crate::obs::trace`])
    /// and write it to `path` as Chrome trace-event JSON (loadable in
    /// Perfetto / `chrome://tracing`; the CLI's `run --trace` flag).
    /// Every worker records its load and per-superstep
    /// compute/route/drain/barrier phases, plus checkpoint
    /// writes/commits; [`crate::metrics::JobMetrics`] additionally gets
    /// its `phases` breakdown populated. Not result-affecting — spans
    /// only observe the run — so, like `mmap`/`dense_index`, it is
    /// excluded from the checkpoint label. See `docs/OBSERVABILITY.md`
    /// for the span taxonomy.
    pub fn trace(mut self, path: impl Into<PathBuf>) -> Self {
        self.trace = Some(path.into());
        self
    }

    /// Attach a live run-control handle
    /// ([`crate::coordinator::RunControl`]): the engine manager
    /// publishes each completed superstep through it and honors a
    /// cancellation request at the next barrier, erroring the run out
    /// as cancelled. Not result-affecting (it is excluded from the
    /// checkpoint label). This is how the `serve` layer supervises
    /// resident jobs; engine-agnostic.
    pub fn control(mut self, ctl: crate::coordinator::RunControl) -> Self {
        self.control = Some(ctl);
        self
    }

    /// The checkpoint-manifest identity of this job description: the
    /// algorithm/engine plus every knob that can change results —
    /// source vertex, iteration counts, epsilon, combiners, kernel, and
    /// cores (the vertex engine's chunk layout follows it). Resume
    /// refuses a directory whose label differs, so a checkpoint can
    /// never silently answer for different parameters.
    fn label(&self, algo: &str) -> String {
        format!(
            "{algo}/{} src={} ss={} eps={} comb={} kernel={} cores={}",
            self.engine,
            self.source_vertex,
            self.supersteps,
            self.epsilon.map_or("-".to_string(), |e| e.to_string()),
            self.combiners.unwrap_or(true),
            match self.kernel {
                RankKernel::Scalar => "scalar",
                RankKernel::Xla(_) => "xla",
            },
            self.cores,
        )
    }

    /// Validate the description against the registry and the engine
    /// compatibility matrix, producing a runnable [`Job`].
    pub fn build(self) -> Result<Job, JobError> {
        let name = self.algo.ok_or(JobError::MissingAlgo)?;
        let entry = registry::find(&name).ok_or_else(|| JobError::UnknownAlgo {
            algo: name.clone(),
            known: registry::names(),
        })?;
        let supported = match self.engine {
            EngineKind::Gopher => entry.gopher.is_some(),
            EngineKind::Vertex => entry.vertex.is_some(),
        };
        if !supported {
            return Err(JobError::UnsupportedEngine { algo: name, engine: self.engine });
        }
        if self.engine == EngineKind::Vertex {
            if self.epsilon.is_some() {
                return Err(JobError::IncompatibleKnob {
                    knob: "epsilon",
                    engine: self.engine,
                    hint: "aggregator-driven PageRank convergence is Gopher-only",
                });
            }
            if self.combiners == Some(false) {
                return Err(JobError::IncompatibleKnob {
                    knob: "combiners",
                    engine: self.engine,
                    hint: "the vertex baseline always folds same-target messages; \
                           only Gopher can disable its combiner",
                });
            }
            if !self.load_attributes.is_empty() {
                return Err(JobError::IncompatibleKnob {
                    knob: "load_attributes",
                    engine: self.engine,
                    hint: "attribute projection is a GoFS/Gopher load-path feature; \
                           the vertex baseline reassembles the whole graph",
                });
            }
            if self.incremental_from.is_some() {
                return Err(JobError::IncompatibleKnob {
                    knob: "incremental_from",
                    engine: self.engine,
                    hint: "dirty-sub-graph scoping is a GoFS/Gopher feature; the \
                           vertex baseline has no sub-graph structure to scope by",
                });
            }
        }
        // ---- fault-tolerance knobs (engine-agnostic, but validated up
        // front like everything else: bad cadences, dangling dirs, and
        // unrecoverable resume targets all fail here, not mid-run).
        let label = self.label(entry.name);
        if self.checkpoint_every == Some(0) {
            return Err(JobError::CheckpointConfig {
                reason: "checkpoint_every must be >= 1",
            });
        }
        let checkpoint = match (self.checkpoint_every, &self.checkpoint_dir) {
            (None, None) => None,
            (None, Some(_)) => {
                return Err(JobError::CheckpointConfig {
                    reason: "checkpoint_dir without checkpoint_every does nothing; \
                             set a cadence",
                });
            }
            (Some(n), Some(dir)) => Some((n, dir.clone())),
            // A resumed job keeps checkpointing into the directory it
            // resumed from unless told otherwise.
            (Some(n), None) => match &self.resume_from {
                Some(dir) => Some((n, dir.clone())),
                None => {
                    return Err(JobError::CheckpointConfig {
                        reason: "checkpoint_every needs checkpoint_dir (or resume_from \
                                 to reuse that directory)",
                    });
                }
            },
        };
        if checkpoint.is_none() && self.checkpoint_mode == ckpt::CheckpointMode::Async {
            return Err(JobError::CheckpointConfig {
                reason: "checkpoint_mode async without checkpointing does nothing; \
                         set checkpoint_every + checkpoint_dir",
            });
        }
        if checkpoint.is_none() && self.checkpoint_compress {
            return Err(JobError::CheckpointConfig {
                reason: "checkpoint_compress without checkpointing does nothing; \
                         set checkpoint_every + checkpoint_dir",
            });
        }
        if self.confined_recovery && self.resume_from.is_none() {
            return Err(JobError::CheckpointConfig {
                reason: "confined_recovery only applies to a resumed run; \
                         set resume_from",
            });
        }
        let resume = match &self.resume_from {
            None => None,
            Some(dir) => {
                let dir_str = dir.display().to_string();
                let reader = ckpt::CheckpointReader::open(dir).map_err(|e| {
                    JobError::NoCheckpoint { dir: dir_str.clone(), reason: format!("{e:#}") }
                })?;
                let found = reader.manifest().label.clone();
                if found != label {
                    return Err(JobError::CheckpointMismatch {
                        dir: dir_str,
                        expected: label,
                        found,
                    });
                }
                let epoch = reader.latest_valid().map_err(|e| {
                    JobError::NoCheckpoint { dir: dir_str.clone(), reason: format!("{e:#}") }
                })?;
                Some(ckpt::ResumePoint {
                    dir: dir.clone(),
                    epoch,
                    confined: self.confined_recovery,
                })
            }
        };
        Ok(Job {
            entry,
            engine: self.engine,
            params: AlgoParams {
                source: self.source_vertex,
                supersteps: self.supersteps,
                epsilon: self.epsilon,
                kernel: self.kernel,
            },
            fabric: self.fabric,
            cores: self.cores,
            combiners: self.combiners.unwrap_or(true),
            max_supersteps: self.max_supersteps,
            load_attributes: self.load_attributes,
            label,
            checkpoint,
            checkpoint_mode: self.checkpoint_mode,
            checkpoint_compress: self.checkpoint_compress,
            resume,
            fail_at: self.kill_at,
            control: self.control,
            incremental_from: self.incremental_from,
            mmap: self.mmap,
            dense_index: self.dense_index,
            trace: self.trace,
            vertex_indexes: None,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_a_default_gopher_job() {
        let job = Job::builder().algo("cc").build().unwrap();
        assert_eq!(job.algo_name(), "cc");
        assert_eq!(job.engine(), EngineKind::Gopher);
        assert!(job.combiners);
    }

    #[test]
    fn missing_and_unknown_algos_are_typed() {
        assert_eq!(Job::builder().build().unwrap_err(), JobError::MissingAlgo);
        match Job::builder().algo("nope").build().unwrap_err() {
            JobError::UnknownAlgo { algo, known } => {
                assert_eq!(algo, "nope");
                assert!(known.contains(&"pagerank"));
            }
            other => panic!("wrong error: {other:?}"),
        }
    }

    #[test]
    fn vertex_engine_rejects_gopher_knobs_at_build_time() {
        let err = Job::builder()
            .algo("pagerank")
            .engine(EngineKind::Vertex)
            .epsilon(1e-3)
            .build()
            .unwrap_err();
        assert!(
            matches!(err, JobError::IncompatibleKnob { knob: "epsilon", .. }),
            "{err}"
        );
        let err = Job::builder()
            .algo("cc")
            .engine(EngineKind::Vertex)
            .combiners(false)
            .build()
            .unwrap_err();
        assert!(
            matches!(err, JobError::IncompatibleKnob { knob: "combiners", .. }),
            "{err}"
        );
        let err = Job::builder()
            .algo("cc")
            .engine(EngineKind::Vertex)
            .load_attributes(["rank"])
            .build()
            .unwrap_err();
        assert!(
            matches!(err, JobError::IncompatibleKnob { knob: "load_attributes", .. }),
            "{err}"
        );
        let err = Job::builder()
            .algo("cc")
            .engine(EngineKind::Vertex)
            .incremental_from(0)
            .build()
            .unwrap_err();
        assert!(
            matches!(err, JobError::IncompatibleKnob { knob: "incremental_from", .. }),
            "{err}"
        );
        // Fine on Gopher (source-kind validation happens at run time).
        assert!(Job::builder().algo("cc").incremental_from(3).build().is_ok());
        // An *empty* projection is the default and fine anywhere.
        assert!(Job::builder()
            .algo("cc")
            .engine(EngineKind::Vertex)
            .load_attributes(Vec::<String>::new())
            .build()
            .is_ok());
        // And the projection is fine on Gopher.
        assert!(Job::builder()
            .algo("cc")
            .load_attributes(["rank", "weight"])
            .build()
            .is_ok());
        // Explicitly *enabling* combiners is fine anywhere.
        assert!(Job::builder()
            .algo("cc")
            .engine(EngineKind::Vertex)
            .combiners(true)
            .build()
            .is_ok());
        // And both knobs are fine on Gopher.
        assert!(Job::builder()
            .algo("pagerank")
            .epsilon(1e-3)
            .combiners(false)
            .build()
            .is_ok());
    }

    #[test]
    fn checkpoint_knobs_validated_at_build_time() {
        // Cadence without a directory (and no resume dir to reuse).
        let err = Job::builder().algo("cc").checkpoint_every(2).build().unwrap_err();
        assert!(matches!(err, JobError::CheckpointConfig { .. }), "{err}");
        // Directory without a cadence.
        let err = Job::builder()
            .algo("cc")
            .checkpoint_dir("/tmp/nowhere")
            .build()
            .unwrap_err();
        assert!(matches!(err, JobError::CheckpointConfig { .. }), "{err}");
        // Zero cadence.
        let err = Job::builder()
            .algo("cc")
            .checkpoint_every(0)
            .checkpoint_dir("/tmp/nowhere")
            .build()
            .unwrap_err();
        assert!(matches!(err, JobError::CheckpointConfig { .. }), "{err}");
        // Consistent knobs build fine (no IO happens until run).
        assert!(Job::builder()
            .algo("cc")
            .checkpoint_every(2)
            .checkpoint_dir("/tmp/nowhere")
            .build()
            .is_ok());
    }

    #[test]
    fn async_compress_and_confined_knobs_validated_at_build_time() {
        // Async mode / compression without checkpointing do nothing.
        let err = Job::builder()
            .algo("cc")
            .checkpoint_mode(crate::ckpt::CheckpointMode::Async)
            .build()
            .unwrap_err();
        assert!(matches!(err, JobError::CheckpointConfig { .. }), "{err}");
        let err = Job::builder()
            .algo("cc")
            .checkpoint_compress(true)
            .build()
            .unwrap_err();
        assert!(matches!(err, JobError::CheckpointConfig { .. }), "{err}");
        // Confined recovery only makes sense on a resumed run.
        let err = Job::builder().algo("cc").confined_recovery(true).build().unwrap_err();
        assert!(
            matches!(&err, JobError::CheckpointConfig { reason }
                     if reason.contains("resume_from")),
            "{err}"
        );
        // All three knobs together on a checkpointing job build fine.
        assert!(Job::builder()
            .algo("cc")
            .checkpoint_every(2)
            .checkpoint_dir("/tmp/nowhere")
            .checkpoint_mode(crate::ckpt::CheckpointMode::Async)
            .checkpoint_compress(true)
            .build()
            .is_ok());
    }

    #[test]
    fn resume_from_missing_or_empty_dir_is_typed() {
        // A directory that does not exist.
        let err = Job::builder()
            .algo("cc")
            .resume_from("/nonexistent/ckpt")
            .build()
            .unwrap_err();
        match &err {
            JobError::NoCheckpoint { dir, .. } => {
                assert!(dir.contains("/nonexistent/ckpt"))
            }
            other => panic!("wrong error: {other:?}"),
        }
        assert!(format!("{err}").contains("no recoverable checkpoint"));

        // An initialized checkpoint dir with no committed epoch.
        let dir = std::env::temp_dir()
            .join("goffish_job_builder")
            .join(format!("empty_ckpt_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let cc_label = Job::builder().algo("cc").label("cc");
        crate::ckpt::CheckpointWriter::create(&dir, &cc_label, 2, false).unwrap();
        let err = Job::builder().algo("cc").resume_from(&dir).build().unwrap_err();
        assert!(
            matches!(&err, JobError::NoCheckpoint { reason, .. }
                     if reason.contains("no committed epoch")),
            "{err}"
        );
        // A different algorithm — or the same one with different
        // result-affecting knobs — is a mismatch, not a silent resume.
        let err = Job::builder().algo("sssp").resume_from(&dir).build().unwrap_err();
        assert!(matches!(err, JobError::CheckpointMismatch { .. }), "{err}");
        let err = Job::builder()
            .algo("cc")
            .source_vertex(5)
            .resume_from(&dir)
            .build()
            .unwrap_err();
        assert!(matches!(err, JobError::CheckpointMismatch { .. }), "{err}");
    }

    #[test]
    fn unsupported_engine_is_typed() {
        let err = Job::builder()
            .algo("blockrank")
            .engine(EngineKind::Vertex)
            .build()
            .unwrap_err();
        assert_eq!(
            err,
            JobError::UnsupportedEngine {
                algo: "blockrank".to_string(),
                engine: EngineKind::Vertex
            }
        );
        assert!(format!("{err}").contains("blockrank"));
    }
}
