//! [`JobBuilder`]: fluent job description with build-time validation.
//!
//! The builder accepts every knob either engine understands and defers
//! nothing to run time that can be checked up front: unknown algorithm
//! names, engines an algorithm does not implement, and Gopher-only
//! knobs on the vertex engine all fail [`JobBuilder::build`] with a
//! typed [`JobError`] (the CLI's old scattered `bail!`s, promoted to an
//! API contract).

use std::fmt;

use crate::algos::pagerank::RankKernel;
use crate::algos::registry::{self, AlgoParams};
use crate::gopher::FabricKind;
use crate::graph::VertexId;

use super::Job;

/// Which BSP engine executes the job.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    /// The sub-graph centric Gopher engine (paper §4.2).
    Gopher,
    /// The vertex-centric Giraph-style baseline.
    Vertex,
}

impl fmt::Display for EngineKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            EngineKind::Gopher => "gopher",
            EngineKind::Vertex => "vertex",
        })
    }
}

/// Build-time job validation failure.
#[derive(Clone, Debug, PartialEq)]
pub enum JobError {
    /// [`JobBuilder::algo`] was never called.
    MissingAlgo,
    /// No registry entry under this name.
    UnknownAlgo {
        algo: String,
        /// The names that *are* registered.
        known: Vec<&'static str>,
    },
    /// The algorithm has no implementation for the requested engine.
    UnsupportedEngine { algo: String, engine: EngineKind },
    /// The knob is not meaningful on the requested engine.
    IncompatibleKnob {
        knob: &'static str,
        engine: EngineKind,
        hint: &'static str,
    },
}

impl fmt::Display for JobError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            JobError::MissingAlgo => {
                write!(f, "no algorithm named; call JobBuilder::algo(...)")
            }
            JobError::UnknownAlgo { algo, known } => {
                write!(f, "unknown algorithm {algo:?}; known: {}", known.join(", "))
            }
            JobError::UnsupportedEngine { algo, engine } => {
                write!(f, "algorithm {algo:?} has no {engine}-engine implementation")
            }
            JobError::IncompatibleKnob { knob, engine, hint } => {
                write!(
                    f,
                    "knob `{knob}` is not supported on the {engine} engine ({hint})"
                )
            }
        }
    }
}

impl std::error::Error for JobError {}

/// Fluent description of a job; see [`crate::job`] for the
/// engine/knob compatibility matrix that [`JobBuilder::build`] enforces.
#[derive(Clone)]
pub struct JobBuilder {
    algo: Option<String>,
    engine: EngineKind,
    fabric: FabricKind,
    cores: usize,
    combiners: Option<bool>,
    epsilon: Option<f32>,
    max_supersteps: usize,
    supersteps: usize,
    source_vertex: VertexId,
    kernel: RankKernel,
    load_attributes: Vec<String>,
}

impl Default for JobBuilder {
    fn default() -> Self {
        Self {
            algo: None,
            engine: EngineKind::Gopher,
            fabric: FabricKind::InProc,
            cores: 4,
            combiners: None,
            epsilon: None,
            max_supersteps: 10_000,
            supersteps: crate::algos::pagerank::DEFAULT_SUPERSTEPS,
            source_vertex: 0,
            kernel: RankKernel::Scalar,
            load_attributes: Vec::new(),
        }
    }
}

impl JobBuilder {
    /// Algorithm name (a [`crate::algos::registry`] entry). Required.
    pub fn algo(mut self, name: impl Into<String>) -> Self {
        self.algo = Some(name.into());
        self
    }

    /// Engine to run on (default: [`EngineKind::Gopher`]).
    pub fn engine(mut self, engine: EngineKind) -> Self {
        self.engine = engine;
        self
    }

    /// Data fabric between workers (default: in-process).
    pub fn fabric(mut self, fabric: FabricKind) -> Self {
        self.fabric = fabric;
        self
    }

    /// Compute threads per worker (default: 4).
    pub fn cores(mut self, cores: usize) -> Self {
        self.cores = cores;
        self
    }

    /// Enable/disable message combiners. Gopher-only when `false`
    /// (default: enabled on both engines).
    pub fn combiners(mut self, on: bool) -> Self {
        self.combiners = Some(on);
        self
    }

    /// Aggregator-driven PageRank convergence threshold. Gopher-only.
    pub fn epsilon(mut self, eps: f32) -> Self {
        self.epsilon = Some(eps);
        self
    }

    /// Safety cap on supersteps (default: 10 000).
    pub fn max_supersteps(mut self, n: usize) -> Self {
        self.max_supersteps = n;
        self
    }

    /// Fixed iteration count (PageRank) / round cap (label propagation).
    pub fn supersteps(mut self, n: usize) -> Self {
        self.supersteps = n;
        self
    }

    /// Source vertex for traversal algorithms (BFS, SSSP; default 0).
    pub fn source_vertex(mut self, v: VertexId) -> Self {
        self.source_vertex = v;
        self
    }

    /// Numeric kernel for rank-update hot loops (scalar or AOT XLA).
    pub fn kernel(mut self, kernel: RankKernel) -> Self {
        self.kernel = kernel;
        self
    }

    /// Attribute projection for store-backed Gopher runs: the load path
    /// reads exactly these attribute slices alongside topology (paper
    /// §4.1's "a graph with 10 attributes … only loads the slice it
    /// needs"), exposing them via `SubgraphContext::attribute`.
    /// Gopher-only; a no-op for in-memory sources.
    pub fn load_attributes<I, S>(mut self, names: I) -> Self
    where
        I: IntoIterator<Item = S>,
        S: Into<String>,
    {
        self.load_attributes = names.into_iter().map(Into::into).collect();
        self
    }

    /// Validate the description against the registry and the engine
    /// compatibility matrix, producing a runnable [`Job`].
    pub fn build(self) -> Result<Job, JobError> {
        let name = self.algo.ok_or(JobError::MissingAlgo)?;
        let entry = registry::find(&name).ok_or_else(|| JobError::UnknownAlgo {
            algo: name.clone(),
            known: registry::names(),
        })?;
        let supported = match self.engine {
            EngineKind::Gopher => entry.gopher.is_some(),
            EngineKind::Vertex => entry.vertex.is_some(),
        };
        if !supported {
            return Err(JobError::UnsupportedEngine { algo: name, engine: self.engine });
        }
        if self.engine == EngineKind::Vertex {
            if self.epsilon.is_some() {
                return Err(JobError::IncompatibleKnob {
                    knob: "epsilon",
                    engine: self.engine,
                    hint: "aggregator-driven PageRank convergence is Gopher-only",
                });
            }
            if self.combiners == Some(false) {
                return Err(JobError::IncompatibleKnob {
                    knob: "combiners",
                    engine: self.engine,
                    hint: "the vertex baseline always folds same-target messages; \
                           only Gopher can disable its combiner",
                });
            }
            if !self.load_attributes.is_empty() {
                return Err(JobError::IncompatibleKnob {
                    knob: "load_attributes",
                    engine: self.engine,
                    hint: "attribute projection is a GoFS/Gopher load-path feature; \
                           the vertex baseline reassembles the whole graph",
                });
            }
        }
        Ok(Job {
            entry,
            engine: self.engine,
            params: AlgoParams {
                source: self.source_vertex,
                supersteps: self.supersteps,
                epsilon: self.epsilon,
                kernel: self.kernel,
            },
            fabric: self.fabric,
            cores: self.cores,
            combiners: self.combiners.unwrap_or(true),
            max_supersteps: self.max_supersteps,
            load_attributes: self.load_attributes,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_a_default_gopher_job() {
        let job = Job::builder().algo("cc").build().unwrap();
        assert_eq!(job.algo_name(), "cc");
        assert_eq!(job.engine(), EngineKind::Gopher);
        assert!(job.combiners);
    }

    #[test]
    fn missing_and_unknown_algos_are_typed() {
        assert_eq!(Job::builder().build().unwrap_err(), JobError::MissingAlgo);
        match Job::builder().algo("nope").build().unwrap_err() {
            JobError::UnknownAlgo { algo, known } => {
                assert_eq!(algo, "nope");
                assert!(known.contains(&"pagerank"));
            }
            other => panic!("wrong error: {other:?}"),
        }
    }

    #[test]
    fn vertex_engine_rejects_gopher_knobs_at_build_time() {
        let err = Job::builder()
            .algo("pagerank")
            .engine(EngineKind::Vertex)
            .epsilon(1e-3)
            .build()
            .unwrap_err();
        assert!(
            matches!(err, JobError::IncompatibleKnob { knob: "epsilon", .. }),
            "{err}"
        );
        let err = Job::builder()
            .algo("cc")
            .engine(EngineKind::Vertex)
            .combiners(false)
            .build()
            .unwrap_err();
        assert!(
            matches!(err, JobError::IncompatibleKnob { knob: "combiners", .. }),
            "{err}"
        );
        let err = Job::builder()
            .algo("cc")
            .engine(EngineKind::Vertex)
            .load_attributes(["rank"])
            .build()
            .unwrap_err();
        assert!(
            matches!(err, JobError::IncompatibleKnob { knob: "load_attributes", .. }),
            "{err}"
        );
        // An *empty* projection is the default and fine anywhere.
        assert!(Job::builder()
            .algo("cc")
            .engine(EngineKind::Vertex)
            .load_attributes(Vec::<String>::new())
            .build()
            .is_ok());
        // And the projection is fine on Gopher.
        assert!(Job::builder()
            .algo("cc")
            .load_attributes(["rank", "weight"])
            .build()
            .is_ok());
        // Explicitly *enabling* combiners is fine anywhere.
        assert!(Job::builder()
            .algo("cc")
            .engine(EngineKind::Vertex)
            .combiners(true)
            .build()
            .is_ok());
        // And both knobs are fine on Gopher.
        assert!(Job::builder()
            .algo("pagerank")
            .epsilon(1e-3)
            .combiners(false)
            .build()
            .is_ok());
    }

    #[test]
    fn unsupported_engine_is_typed() {
        let err = Job::builder()
            .algo("blockrank")
            .engine(EngineKind::Vertex)
            .build()
            .unwrap_err();
        assert_eq!(
            err,
            JobError::UnsupportedEngine {
                algo: "blockrank".to_string(),
                engine: EngineKind::Vertex
            }
        );
        assert!(format!("{err}").contains("blockrank"));
    }
}
