//! Execution metrics shared by both BSP engines.
//!
//! Everything the paper's evaluation section plots is captured here:
//! makespan split into load + compute (Fig 4a/4b), superstep counts
//! (Fig 4c), per-sub-graph compute-time distributions per partition
//! (Fig 5), message/byte counters (the §3.3 "messages exchanged"
//! argument), combiner savings, and per-superstep global aggregator
//! traces from the coordinator layer.

use crate::coordinator::AggregatorTrace;
use crate::util::stats::Summary;

/// Metrics for one superstep, merged across workers.
#[derive(Clone, Debug, Default)]
pub struct SuperstepMetrics {
    /// Wall-clock of the whole superstep: the slowest worker's own
    /// clock over compute + route + drain, measured worker-side. For
    /// superstep 1 this starts *after* that worker finished loading, so
    /// load time is never folded into a superstep wall.
    pub wall_seconds: f64,
    /// Per-partition: wall time of that worker's compute phase.
    pub partition_compute_seconds: Vec<f64>,
    /// Per-partition: per-unit (sub-graph or vertex batch) compute times.
    pub unit_times: Vec<Vec<f64>>,
    /// Data messages sent this superstep (all workers).
    pub messages: u64,
    /// Encoded data bytes sent this superstep (all workers).
    pub bytes: u64,
    /// Units (sub-graphs / vertices) that ran compute this superstep.
    pub active_units: u64,
    /// Messages eliminated by combiners before encoding (these are
    /// counted in `messages` but never hit the wire).
    pub combined_messages: u64,
}

impl SuperstepMetrics {
    /// Box-whisker summary of one partition's unit times (Fig 5 rows).
    pub fn partition_summary(&self, p: usize) -> Option<Summary> {
        Summary::from(&self.unit_times[p])
    }

    /// The straggler ratio the paper's §6.5 discusses: slowest partition
    /// compute time / next-slowest. Uses IEEE total order so a NaN
    /// partition time (a worker whose clock produced garbage) sorts
    /// deterministically instead of panicking the metrics path.
    pub fn straggler_ratio(&self) -> f64 {
        let mut t = self.partition_compute_seconds.clone();
        t.sort_by(|a, b| b.total_cmp(a));
        if t.len() < 2 || t[1] == 0.0 {
            return 1.0;
        }
        t[0] / t[1]
    }
}

/// One committed checkpoint epoch's cost (fault-tolerance subsystem,
/// `crate::ckpt`).
#[derive(Clone, Debug, Default)]
pub struct CheckpointMetrics {
    /// Absolute superstep the epoch snapshots (resumed runs keep
    /// counting from the restored superstep).
    pub superstep: usize,
    /// Wall clock of the slowest worker's snapshot work at the barrier
    /// (workers run concurrently, so the slowest gates the superstep).
    /// In sync mode this is the persist + fsync; in async mode it is
    /// only the encode/double-buffer stall — the write itself happens
    /// off the barrier on the flusher thread.
    pub seconds: f64,
    /// Snapshot bytes written across all workers.
    pub bytes: u64,
}

/// Metrics for a whole job.
///
/// On a resumed run (`Job::builder().resume_from(...)`), `supersteps`
/// and `checkpoints` cover only the supersteps executed *after* the
/// restart, while `aggregators` traces are restored from the checkpoint
/// and cover the whole logical run — that is what makes a resumed job's
/// `JobOutput` comparable to an uninterrupted one.
#[derive(Clone, Debug, Default)]
pub struct JobMetrics {
    pub supersteps: Vec<SuperstepMetrics>,
    /// Per-epoch checkpoint wall/bytes traces, one entry per superstep
    /// that checkpointed (empty when checkpointing is off).
    pub checkpoints: Vec<CheckpointMetrics>,
    /// Time loading the graph from storage into memory objects (Fig 4b).
    pub load_seconds: f64,
    /// Bytes read at load.
    pub load_bytes: u64,
    /// Files read at load.
    pub load_files: u64,
    /// Total compute wall time: Σ over supersteps of
    /// [`SuperstepMetrics::wall_seconds`]. Because superstep walls are
    /// accounted per-superstep on the worker side (the clock starts
    /// after the worker's load completes), `load_seconds` and
    /// `compute_seconds` are disjoint and [`JobMetrics::makespan_seconds`]
    /// adds them without double counting — the engines used to measure
    /// superstep 1 from the manager (whose clock started before workers
    /// finished loading) and papered over the overshoot with a
    /// `min(compute, job wall)` clamp.
    pub compute_seconds: f64,
    /// Per-superstep global aggregator values (coordinator layer), one
    /// trace per aggregator the program registered.
    pub aggregators: Vec<AggregatorTrace>,
    /// In-superstep phase totals (compute/route/drain/barrier seconds
    /// summed over all workers and supersteps), populated only when the
    /// job ran with tracing (`Job::builder().trace(path)`); see
    /// [`crate::obs::trace::PhaseTotals`].
    pub phases: Option<crate::obs::trace::PhaseTotals>,
    /// Checkpoint epochs whose pruning failed and is still pending
    /// retry at job end (see `ckpt::CheckpointWriter::prune_epochs`):
    /// non-zero means stale `epoch_N/` directories remain on disk and
    /// the next commit against this directory will retry them.
    pub ckpt_prune_failures: u64,
}

impl JobMetrics {
    pub fn num_supersteps(&self) -> usize {
        self.supersteps.len()
    }

    /// End-to-end makespan: load + compute (the Fig 4a quantity).
    pub fn makespan_seconds(&self) -> f64 {
        self.load_seconds + self.compute_seconds
    }

    pub fn total_messages(&self) -> u64 {
        self.supersteps.iter().map(|s| s.messages).sum()
    }

    pub fn total_bytes(&self) -> u64 {
        self.supersteps.iter().map(|s| s.bytes).sum()
    }

    /// Messages folded away by combiners across the whole job.
    pub fn total_combined(&self) -> u64 {
        self.supersteps.iter().map(|s| s.combined_messages).sum()
    }

    /// The trace of a named global aggregator, if the program registered
    /// one under that name.
    pub fn aggregator(&self, name: &str) -> Option<&AggregatorTrace> {
        self.aggregators.iter().find(|t| t.name == name)
    }

    /// Total wall clock spent writing checkpoints (sum over epochs of
    /// the slowest worker's write).
    pub fn checkpoint_seconds(&self) -> f64 {
        self.checkpoints.iter().map(|c| c.seconds).sum()
    }

    /// Total checkpoint bytes written across all epochs and workers.
    pub fn checkpoint_bytes(&self) -> u64 {
        self.checkpoints.iter().map(|c| c.bytes).sum()
    }

    /// One-line report used by examples and benches.
    pub fn report(&self, label: &str) -> String {
        let mut line = format!(
            "{label}: makespan={:.4}s (load={:.4}s compute={:.4}s) supersteps={} \
             msgs={} bytes={} combined={}",
            self.makespan_seconds(),
            self.load_seconds,
            self.compute_seconds,
            self.num_supersteps(),
            self.total_messages(),
            self.total_bytes(),
            self.total_combined(),
        );
        if !self.checkpoints.is_empty() {
            line.push_str(&format!(
                " ckpt[{} epochs {:.4}s {}B]",
                self.checkpoints.len(),
                self.checkpoint_seconds(),
                self.checkpoint_bytes(),
            ));
        }
        if self.ckpt_prune_failures > 0 {
            line.push_str(&format!(
                " ckpt_prune_failures={}",
                self.ckpt_prune_failures,
            ));
        }
        if let Some(p) = &self.phases {
            line.push_str(&format!(
                " phases[compute={:.4}s route={:.4}s drain={:.4}s barrier={:.4}s]",
                p.compute_seconds, p.route_seconds, p.drain_seconds, p.barrier_seconds,
            ));
        }
        line
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ss(walls: &[f64], msgs: u64) -> SuperstepMetrics {
        SuperstepMetrics {
            wall_seconds: walls.iter().cloned().fold(0.0, f64::max),
            partition_compute_seconds: walls.to_vec(),
            unit_times: walls.iter().map(|&w| vec![w]).collect(),
            messages: msgs,
            bytes: msgs * 8,
            active_units: walls.len() as u64,
            combined_messages: msgs / 2,
        }
    }

    #[test]
    fn makespan_adds_load_and_compute() {
        let m = JobMetrics {
            supersteps: vec![ss(&[0.1, 0.2], 5), ss(&[0.3, 0.1], 2)],
            load_seconds: 1.0,
            compute_seconds: 0.5,
            ..Default::default()
        };
        assert!((m.makespan_seconds() - 1.5).abs() < 1e-12);
        assert_eq!(m.total_messages(), 7);
        assert_eq!(m.total_bytes(), 56);
        assert_eq!(m.total_combined(), 3);
        assert_eq!(m.num_supersteps(), 2);
    }

    #[test]
    fn aggregator_traces_surface_by_name() {
        let m = JobMetrics {
            aggregators: vec![crate::coordinator::AggregatorTrace {
                name: "pr_l1_delta".to_string(),
                values: vec![0.5, 0.1, 0.01],
            }],
            ..Default::default()
        };
        let t = m.aggregator("pr_l1_delta").expect("trace present");
        assert_eq!(t.values.len(), 3);
        assert_eq!(t.last(), Some(0.01));
        assert!(m.aggregator("missing").is_none());
        assert!(m.report("x").contains("combined=0"));
    }

    #[test]
    fn straggler_ratio_identifies_slow_partition() {
        let s = ss(&[0.1, 0.1, 0.5, 0.2], 0);
        assert!((s.straggler_ratio() - 2.5).abs() < 1e-9);
        let uniform = ss(&[0.1, 0.1], 0);
        assert!((uniform.straggler_ratio() - 1.0).abs() < 1e-9);
        let single = ss(&[0.1], 0);
        assert_eq!(single.straggler_ratio(), 1.0);
    }

    /// Regression: a NaN partition time used to panic the
    /// `partial_cmp().unwrap()` sort; IEEE total order sorts NaN above
    /// every finite value, so the result is finite-or-NaN, never a
    /// panic.
    #[test]
    fn straggler_ratio_survives_nan_times() {
        let s = ss(&[0.1, f64::NAN, 0.2], 0);
        let r = s.straggler_ratio();
        // total_cmp puts the NaN first (descending), so the ratio is
        // NaN/0.2 = NaN — garbage in, garbage out, but no panic.
        assert!(r.is_nan(), "{r}");
        let all_nan = ss(&[f64::NAN, f64::NAN], 0);
        assert!(all_nan.straggler_ratio().is_nan());
        // Finite inputs are unaffected by the sort change.
        let s = ss(&[0.4, 0.1, 0.2], 0);
        assert!((s.straggler_ratio() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn partition_summary_present() {
        let s = ss(&[0.25, 0.5], 0);
        let sum = s.partition_summary(1).unwrap();
        assert_eq!(sum.count, 1);
        assert_eq!(sum.median, 0.5);
    }

    #[test]
    fn report_contains_key_fields() {
        let m = JobMetrics::default();
        let r = m.report("cc/rn");
        assert!(r.contains("cc/rn"));
        assert!(r.contains("supersteps=0"));
        // No checkpointing → no ckpt clause.
        assert!(!r.contains("ckpt["));
    }

    #[test]
    fn report_notes_pending_prune_failures() {
        let m = JobMetrics { ckpt_prune_failures: 2, ..Default::default() };
        assert!(m.report("cc/rn").contains("ckpt_prune_failures=2"));
        // Clean runs never mention pruning.
        assert!(!JobMetrics::default().report("cc/rn").contains("ckpt_prune_failures"));
    }

    #[test]
    fn report_breaks_down_phases_when_traced() {
        let m = JobMetrics {
            phases: Some(crate::obs::trace::PhaseTotals {
                compute_seconds: 0.5,
                route_seconds: 0.25,
                drain_seconds: 0.125,
                barrier_seconds: 0.0625,
            }),
            ..Default::default()
        };
        let r = m.report("cc");
        assert!(r.contains("phases[compute=0.5000s"), "{r}");
        assert!(r.contains("barrier=0.0625s]"), "{r}");
        // Untraced jobs keep the old line shape.
        assert!(!JobMetrics::default().report("cc").contains("phases["));
    }

    #[test]
    fn checkpoint_traces_aggregate_and_report() {
        let m = JobMetrics {
            checkpoints: vec![
                CheckpointMetrics { superstep: 2, seconds: 0.25, bytes: 100 },
                CheckpointMetrics { superstep: 4, seconds: 0.5, bytes: 300 },
            ],
            ..Default::default()
        };
        assert!((m.checkpoint_seconds() - 0.75).abs() < 1e-12);
        assert_eq!(m.checkpoint_bytes(), 400);
        let r = m.report("cc");
        assert!(r.contains("ckpt[2 epochs"), "{r}");
        assert!(r.contains("400B"), "{r}");
    }
}
