//! Checkpoint/recovery subsystem — superstep snapshots co-designed with
//! GoFS (the fault-tolerance layer Pregel-family systems pair with
//! synchronous barriers).
//!
//! # What gets persisted
//!
//! Every `every` supersteps, at the barrier **after** the superstep's
//! drain phase, each worker writes one *partition snapshot* file — its
//! per-unit program states (via the programs'
//! `save_state`/`restore_state` hooks, see [`StateCodec`]), halted
//! flags, and the in-flight message queues destined for the next
//! superstep — and the manager, once every worker has synced cleanly,
//! writes the *coordinator snapshot* (the full per-superstep global
//! aggregator history) and **commits** the epoch by atomically
//! rewriting the manifest. Both engines (`gopher` and `pregel`) thread
//! the same machinery through their barrier. When a job runs with
//! tracing ([`crate::obs::trace`]), both sides show up on the timeline:
//! each worker's snapshot write is a `ckpt_write` span on its lane and
//! the manager's manifest commit a `ckpt_commit` span on lane 0.
//!
//! # Sync vs async persistence
//!
//! [`CheckpointMode`] picks *when* the bytes hit disk. `Sync` is the
//! PR 4 behaviour: each worker writes (and fsyncs) its snapshot inside
//! the barrier, and the manager commits before broadcasting resume —
//! the write stalls every checkpointed superstep. `Async`
//! double-buffers instead: the worker only *encodes* its snapshot at
//! the barrier (a `ckpt_buffer` span — the whole remaining stall) and
//! hands the bytes to a background [`CheckpointFlusher`] thread that
//! persists them (`ckpt_flush` spans on its own trace lane) while the
//! next superstep computes; the manager enqueues the epoch commit on
//! the same channel. Because every worker enqueues its snapshot
//! *before* syncing and the manager enqueues the commit only *after*
//! all syncs, the single-consumer FIFO guarantees all partition writes
//! land before their commit — the torn-write rule of the manifest is
//! preserved. Flush errors surface at the next barrier (or at the
//! run's end, which joins the flusher). Either mode writes the same
//! bytes: the mode is not part of the job label, so a sync-written
//! checkpoint resumes under async and vice versa.
//!
//! # Sender-side message logs and confined recovery
//!
//! Alongside its snapshot, each worker persists a *send log*
//! (`sendlog_p.ckpt`): every batch frame it put on the fabric during
//! the checkpointed superstep (self-deliveries included), tagged with
//! the destination worker. Logs make **confined recovery** possible: a
//! resume with [`ResumePoint::confined`] restarts only the dead worker
//! from its snapshot and rebuilds its in-flight inbox by replaying the
//! epoch's frames destined to it from *all* senders' logs — instead of
//! trusting the dead worker's own snapshot queues, which a real
//! cluster loses with the worker's memory. Deterministic replay
//! (sender-sorted inboxes, per-sender FIFO fabrics) makes the rebuilt
//! inbox byte-identical to the uninterrupted run's. The manager
//! records *which* worker died in a `FAILED_WORKER` marker next to the
//! manifest; confined resume requires it.
//!
//! # Compression
//!
//! With [`CheckpointConfig::compress`] every section body is packed
//! with a byte-oriented run-length scheme before framing and the file
//! carries [`VERSION_COMPRESSED`]. Checksums cover the packed bodies,
//! so `store verify` scrubs compressed checkpoints unchanged; readers
//! accept both versions.
//!
//! # On-disk layout
//!
//! The files reuse the GoFS v2 sectioned framing ([`crate::gofs::section`]):
//! a version byte, a section directory, and a per-section FNV checksum,
//! so corruption errors name the rotten section and `store verify` can
//! scrub a checkpoint directory exactly like a store.
//!
//! ```text
//! <dir>/MANIFEST             label, partitions, committed epoch list
//! <dir>/epoch_4/part_0.ckpt  partition snapshot (sections: meta, states, halted, inbox)
//! <dir>/epoch_4/part_1.ckpt
//! <dir>/epoch_4/coord.ckpt   coordinator snapshot (sections: meta, agg_history)
//! ```
//!
//! # Commit and recovery semantics
//!
//! A torn write can never be resumed from: snapshot files land via
//! write-to-temp + rename, and an epoch exists only once the manifest
//! (itself renamed into place) lists it — a crash mid-epoch leaves the
//! manifest pointing at the previous committed epoch. The reader walks
//! the committed epochs newest-first and checksum-validates every file,
//! falling back to the previous epoch when the latest has rotted. The
//! last [`KEEP_EPOCHS`] epochs are retained; older ones are pruned at
//! commit.
//!
//! # Determinism
//!
//! Recovery parity (a resumed job's `JobOutput` byte-identical to an
//! uninterrupted run) requires deterministic replay, which the engines
//! guarantee by sender-tagging message frames and stably sorting each
//! unit's inbox by sender before compute, and by folding worker
//! aggregator partials in worker order at the barrier. Checkpoint
//! encodings are deterministic too ([`StateCodec`] serializes maps in
//! key order), so identical runs write identical snapshot bytes.

mod state;

pub use state::StateCodec;

use std::fs;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::gofs::section;
use crate::gopher::api::MsgCodec;
use crate::util::codec::{Decoder, Encoder};

/// Checkpoint file magic ("GoFFish ChecKpoint").
pub const MAGIC: &[u8; 4] = b"GFCK";
/// Checkpoint format version byte (plain section bodies).
pub const VERSION: u8 = 1;
/// Checkpoint format version byte for files whose section bodies are
/// run-length packed ([`CheckpointConfig::compress`]). Section
/// checksums cover the packed bodies, so scrubbing is version-blind.
pub const VERSION_COMPRESSED: u8 = 2;
/// Committed epochs retained per directory (older ones are pruned at
/// commit; 2 = latest + the fallback for a rotted latest).
pub const KEEP_EPOCHS: usize = 2;

const KIND_PARTITION: u8 = 0;
const KIND_COORD: u8 = 1;
const KIND_SENDLOG: u8 = 2;

const SEC_META: u8 = 0;
const SEC_STATES: u8 = 1;
const SEC_HALTED: u8 = 2;
const SEC_INBOX: u8 = 3;
const SEC_AGG_HISTORY: u8 = 4;
const SEC_SENDLOG: u8 = 5;

fn section_name(id: u8) -> &'static str {
    match id {
        SEC_META => "meta",
        SEC_STATES => "states",
        SEC_HALTED => "halted",
        SEC_INBOX => "inbox",
        SEC_AGG_HISTORY => "agg_history",
        SEC_SENDLOG => "sendlog",
        _ => "unknown",
    }
}

// ----------------------------------------------- section body compression
//
// A dependency-free PackBits-style byte RLE: checkpoint columns (halted
// flags, zero-heavy little-endian floats, varint runs) are full of
// repeated bytes, and the scheme never expands a body by more than
// 1/128 plus the length prefix. Token stream after a varint raw length:
// `0x00..=0x7F` = literal run of `c + 1` bytes follows; `0x80..=0xFF` =
// the next byte repeated `(c - 0x80) + 3` times.

fn rle_flush_literals(out: &mut Vec<u8>, mut lit: &[u8]) {
    while !lit.is_empty() {
        let take = lit.len().min(128);
        out.push((take - 1) as u8);
        out.extend_from_slice(&lit[..take]);
        lit = &lit[take..];
    }
}

fn rle_compress(raw: &[u8]) -> Vec<u8> {
    let mut e = Encoder::with_capacity(raw.len() / 2 + 8);
    e.put_varint(raw.len() as u64);
    let mut out = e.into_bytes();
    let n = raw.len();
    let mut i = 0usize;
    let mut lit = 0usize;
    while i < n {
        let mut j = i + 1;
        while j < n && raw[j] == raw[i] && j - i < 130 {
            j += 1;
        }
        if j - i >= 3 {
            rle_flush_literals(&mut out, &raw[lit..i]);
            out.push(0x80 + (j - i - 3) as u8);
            out.push(raw[i]);
            lit = j;
        }
        i = j;
    }
    rle_flush_literals(&mut out, &raw[lit..n]);
    out
}

fn rle_decompress(packed: &[u8]) -> Result<Vec<u8>> {
    let mut pos = 0usize;
    let mut raw_len: u64 = 0;
    let mut shift = 0u32;
    loop {
        let Some(&b) = packed.get(pos) else {
            bail!("run-length body: truncated length prefix");
        };
        pos += 1;
        raw_len |= ((b & 0x7f) as u64) << shift;
        if b & 0x80 == 0 {
            break;
        }
        shift += 7;
        ensure!(shift < 64, "run-length body: length prefix overflows");
    }
    let raw_len = raw_len as usize;
    // A crafted length cannot force a huge allocation: every token
    // yields bounded output, so the token stream caps the capacity.
    let mut out =
        Vec::with_capacity(raw_len.min((packed.len() - pos).saturating_mul(130)));
    while pos < packed.len() {
        let c = packed[pos];
        pos += 1;
        if c < 0x80 {
            let take = c as usize + 1;
            ensure!(
                pos + take <= packed.len(),
                "run-length body: truncated literal run"
            );
            out.extend_from_slice(&packed[pos..pos + take]);
            pos += take;
        } else {
            ensure!(pos < packed.len(), "run-length body: truncated repeat run");
            out.resize(out.len() + (c as usize - 0x80) + 3, packed[pos]);
            pos += 1;
        }
    }
    ensure!(
        out.len() == raw_len,
        "run-length body decodes to {} bytes, header says {raw_len}",
        out.len()
    );
    Ok(out)
}

/// Frame section bodies, packing them first when `compress` is set (the
/// file then carries [`VERSION_COMPRESSED`] so readers know to unpack).
fn frame_sections(kind: u8, sections: &[(u8, Vec<u8>)], compress: bool) -> Vec<u8> {
    if !compress {
        return section::frame(MAGIC, VERSION, kind, sections);
    }
    let packed: Vec<(u8, Vec<u8>)> =
        sections.iter().map(|(id, body)| (*id, rle_compress(body))).collect();
    section::frame(MAGIC, VERSION_COMPRESSED, kind, &packed)
}

/// The version byte a checkpoint file claims (both accepted versions
/// map to themselves; anything else is left for `unframe` to reject
/// with its own error message).
fn claimed_version(bytes: &[u8]) -> u8 {
    match bytes.get(4) {
        Some(&VERSION_COMPRESSED) => VERSION_COMPRESSED,
        _ => VERSION,
    }
}

/// A checkpoint file's section table plus whether bodies need
/// unpacking. `get` hides the difference from the decode paths.
struct CkptSections<'a> {
    table: section::SectionTable<'a>,
    compressed: bool,
}

impl CkptSections<'_> {
    fn get(&self, id: u8) -> Result<std::borrow::Cow<'_, [u8]>> {
        let body = self.table.get(id)?;
        if self.compressed {
            Ok(std::borrow::Cow::Owned(rle_decompress(body).with_context(
                || format!("unpack section `{}`", section_name(id)),
            )?))
        } else {
            Ok(std::borrow::Cow::Borrowed(body))
        }
    }
}

fn open_sections<'a>(
    bytes: &'a [u8],
    kind: u8,
    what: &'static str,
) -> Result<CkptSections<'a>> {
    let version = claimed_version(bytes);
    let table =
        section::unframe(bytes, MAGIC, version, kind, section_name).context(what)?;
    Ok(CkptSections { table, compressed: version == VERSION_COMPRESSED })
}

// ------------------------------------------------------------- knob types

/// When checkpoint bytes are persisted relative to the barrier (see the
/// module docs): `Sync` writes inside it, `Async` double-buffers and
/// lets a background [`CheckpointFlusher`] write while the next
/// superstep computes. Not result-affecting, so it is excluded from
/// the job label — checkpoints written in either mode resume in either.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum CheckpointMode {
    /// Persist snapshots inside the barrier (PR 4 behaviour).
    #[default]
    Sync,
    /// Encode at the barrier, persist + commit on a background thread.
    Async,
}

impl std::str::FromStr for CheckpointMode {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<CheckpointMode> {
        match s {
            "sync" => Ok(CheckpointMode::Sync),
            "async" => Ok(CheckpointMode::Async),
            other => bail!("unknown checkpoint mode {other:?} (use sync|async)"),
        }
    }
}

impl std::fmt::Display for CheckpointMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            CheckpointMode::Sync => "sync",
            CheckpointMode::Async => "async",
        })
    }
}

/// Engine-side checkpointing knob (built by the job layer from
/// `JobBuilder::checkpoint_every` / `checkpoint_dir`).
#[derive(Clone, Debug)]
pub struct CheckpointConfig {
    /// Snapshot every N supersteps (>= 1).
    pub every: usize,
    /// Checkpoint directory (shared by all workers + the manager).
    pub dir: PathBuf,
    /// Job identity recorded in the manifest: `algo/engine` plus every
    /// result-affecting knob (see `JobBuilder::label`); resume refuses
    /// a directory written by a different job *or* different
    /// parameters.
    pub label: String,
    /// Sync (in-barrier) or async (double-buffered) persistence. Like
    /// `mmap`/`dense_index`, never result-affecting and therefore not
    /// part of the label.
    pub mode: CheckpointMode,
    /// Run-length pack every section body before framing
    /// ([`VERSION_COMPRESSED`] files). Not result-affecting either.
    pub compress: bool,
}

/// A validated resume target: resolved by the job layer (falling back
/// past corrupt epochs) and handed to the engine.
#[derive(Clone, Debug)]
pub struct ResumePoint {
    /// The checkpoint directory to resume from.
    pub dir: PathBuf,
    /// The committed epoch (= superstep) to restart after.
    pub epoch: u64,
    /// Confined recovery: rebuild only the dead worker (named by the
    /// directory's `FAILED_WORKER` marker) from its snapshot, replaying
    /// its in-flight messages from every sender's epoch log instead of
    /// its own snapshot queues. Requires the marker and the epoch's
    /// send logs; survivors restore exactly as in global recovery.
    pub confined: bool,
}

/// Failure-injection testing hook: the named worker aborts at the start
/// of the named superstep, exactly like a killed host.
#[derive(Clone, Copy, Debug)]
pub struct FailPoint {
    /// Superstep at whose start the worker dies.
    pub superstep: usize,
    /// The worker (partition id) that dies.
    pub worker: u32,
}

/// One queued in-flight message as both engines hold it worker-side:
/// the sending worker (the stable-sort key that makes replay
/// deterministic), the optional target vertex (Gopher's
/// `send_to_subgraph_vertex`; unused by the vertex engine), and the
/// payload.
#[derive(Clone, Debug)]
pub struct InboxEntry<M> {
    /// The sending worker (stable-sort key for deterministic replay).
    pub sender: u32,
    /// Optional target vertex within the receiving unit.
    pub vertex: Option<u32>,
    /// The message payload.
    pub payload: M,
}

// ----------------------------------------------------- partition snapshot

/// A decoded partition snapshot.
pub struct PartitionSnapshot<S, M> {
    /// The committed epoch (= superstep) this snapshot captures.
    pub epoch: u64,
    /// The partition (worker) the snapshot belongs to.
    pub partition: u32,
    /// Per-unit restored program state (sub-graph or vertex order).
    pub states: Vec<S>,
    /// Per-unit halt votes at the snapshot barrier.
    pub halted: Vec<bool>,
    /// Per-unit queued messages for superstep `epoch + 1`.
    pub inbox: Vec<Vec<InboxEntry<M>>>,
}

const PART_META_LEN: usize = 16;

/// Encode one worker's barrier snapshot. `save_state` writes unit `i`'s
/// program state (the `SubgraphProgram::save_state` /
/// `VertexProgram::save_state` hook), `halted(i)` reports its vote.
pub fn encode_partition<M: MsgCodec>(
    epoch: u64,
    partition: u32,
    n_units: usize,
    mut save_state: impl FnMut(usize, &mut Encoder),
    halted: impl Fn(usize) -> bool,
    inbox: &[Vec<InboxEntry<M>>],
    compress: bool,
) -> Vec<u8> {
    debug_assert_eq!(inbox.len(), n_units);
    let mut meta = Vec::with_capacity(PART_META_LEN);
    meta.extend_from_slice(&epoch.to_le_bytes());
    meta.extend_from_slice(&partition.to_le_bytes());
    meta.extend_from_slice(&(n_units as u32).to_le_bytes());

    let mut se = Encoder::new();
    for i in 0..n_units {
        save_state(i, &mut se);
    }

    let halted_col: Vec<u8> = (0..n_units).map(|i| halted(i) as u8).collect();

    let mut ie = Encoder::new();
    for unit in inbox {
        ie.put_varint(unit.len() as u64);
        for m in unit {
            ie.put_varint(m.sender as u64);
            match m.vertex {
                Some(v) => {
                    ie.put_u8(1);
                    ie.put_varint(v as u64);
                }
                None => ie.put_u8(0),
            }
            m.payload.encode(&mut ie);
        }
    }

    frame_sections(
        KIND_PARTITION,
        &[
            (SEC_META, meta),
            (SEC_STATES, se.into_bytes()),
            (SEC_HALTED, halted_col),
            (SEC_INBOX, ie.into_bytes()),
        ],
        compress,
    )
}

/// Decode one worker's snapshot, validating it against the run being
/// resumed. `restore_state` rebuilds unit `i`'s program state (the
/// programs' `restore_state` hook). `R` is a named generic (not `impl
/// Trait`) so engine call sites can turbofish `S`/`M`.
pub fn decode_partition<S, M, R>(
    bytes: &[u8],
    expect_epoch: u64,
    expect_partition: u32,
    expect_units: usize,
    mut restore_state: R,
) -> Result<PartitionSnapshot<S, M>>
where
    M: MsgCodec,
    R: FnMut(usize, &mut Decoder) -> Result<S>,
{
    let table = open_sections(bytes, KIND_PARTITION, "partition snapshot")?;

    let meta = table.get(SEC_META)?;
    ensure!(
        meta.len() == PART_META_LEN,
        "section `meta` has {} bytes, expected {PART_META_LEN}",
        meta.len()
    );
    let epoch = u64::from_le_bytes(meta[0..8].try_into().unwrap());
    let partition = u32::from_le_bytes(meta[8..12].try_into().unwrap());
    let n_units = u32::from_le_bytes(meta[12..16].try_into().unwrap()) as usize;
    ensure!(
        epoch == expect_epoch,
        "snapshot is for epoch {epoch}, resuming epoch {expect_epoch}"
    );
    ensure!(
        partition == expect_partition,
        "snapshot holds partition {partition}, expected {expect_partition}"
    );
    ensure!(
        n_units == expect_units,
        "snapshot holds {n_units} units, this worker owns {expect_units} \
         (resume must use the same store/partitioning as the original run)"
    );

    let states_body = table.get(SEC_STATES)?;
    let mut sd = Decoder::new(&states_body);
    let mut states = Vec::with_capacity(n_units);
    for i in 0..n_units {
        states.push(
            restore_state(i, &mut sd)
                .with_context(|| format!("restore state of unit {i}"))?,
        );
    }
    ensure!(
        sd.is_at_end(),
        "section `states` has {} trailing bytes",
        sd.remaining()
    );

    let halted_col = table.get(SEC_HALTED)?;
    ensure!(
        halted_col.len() == n_units,
        "section `halted` has {} flags, expected {n_units}",
        halted_col.len()
    );
    let halted: Vec<bool> = halted_col.iter().map(|&b| b != 0).collect();

    let inbox_body = table.get(SEC_INBOX)?;
    let mut id = Decoder::new(&inbox_body);
    let mut inbox = Vec::with_capacity(n_units);
    for _ in 0..n_units {
        let n = id.get_varint()? as usize;
        let mut unit = Vec::with_capacity(n.min(id.remaining() + 1));
        for _ in 0..n {
            let sender = id.get_varint()? as u32;
            let vertex = if id.get_u8()? != 0 {
                Some(id.get_varint()? as u32)
            } else {
                None
            };
            unit.push(InboxEntry { sender, vertex, payload: M::decode(&mut id)? });
        }
        inbox.push(unit);
    }
    ensure!(
        id.is_at_end(),
        "section `inbox` has {} trailing bytes",
        id.remaining()
    );

    Ok(PartitionSnapshot { epoch, partition, states, halted, inbox })
}

// --------------------------------------------------- coordinator snapshot

/// The manager-side snapshot: the coordinator's full per-superstep
/// global aggregator history (entry `s` = globals folded at barrier
/// `s+1`). Its last entry is what resumed workers observe as the
/// previous barrier's globals.
pub struct CoordSnapshot {
    /// The committed epoch (= superstep) this snapshot captures.
    pub epoch: u64,
    /// Per-superstep global aggregator vectors.
    pub history: Vec<Vec<f64>>,
}

const COORD_META_LEN: usize = 16;

/// Encode the manager's barrier snapshot (see [`CoordSnapshot`]).
pub fn encode_coordinator(
    epoch: u64,
    naggs: usize,
    history: &[Vec<f64>],
    compress: bool,
) -> Vec<u8> {
    let mut meta = Vec::with_capacity(COORD_META_LEN);
    meta.extend_from_slice(&epoch.to_le_bytes());
    meta.extend_from_slice(&(naggs as u32).to_le_bytes());
    meta.extend_from_slice(&(history.len() as u32).to_le_bytes());
    let mut col = Vec::with_capacity(history.len() * naggs * 8);
    for step in history {
        debug_assert_eq!(step.len(), naggs);
        for &v in step {
            col.extend_from_slice(&v.to_le_bytes());
        }
    }
    frame_sections(KIND_COORD, &[(SEC_META, meta), (SEC_AGG_HISTORY, col)], compress)
}

/// Decode a coordinator snapshot, validating the aggregator count
/// against the resuming run's program.
pub fn decode_coordinator(bytes: &[u8], expect_naggs: usize) -> Result<CoordSnapshot> {
    let table = open_sections(bytes, KIND_COORD, "coordinator snapshot")?;
    let meta = table.get(SEC_META)?;
    ensure!(
        meta.len() == COORD_META_LEN,
        "section `meta` has {} bytes, expected {COORD_META_LEN}",
        meta.len()
    );
    let epoch = u64::from_le_bytes(meta[0..8].try_into().unwrap());
    let naggs = u32::from_le_bytes(meta[8..12].try_into().unwrap()) as usize;
    let nsteps = u32::from_le_bytes(meta[12..16].try_into().unwrap()) as usize;
    ensure!(
        naggs == expect_naggs,
        "snapshot folded {naggs} aggregators, program registers {expect_naggs}"
    );
    let col = table.get(SEC_AGG_HISTORY)?;
    ensure!(
        col.len() == nsteps * naggs * 8,
        "section `agg_history` has {} bytes, expected {}",
        col.len(),
        nsteps * naggs * 8
    );
    let mut history = Vec::with_capacity(nsteps);
    for s in 0..nsteps {
        let row = &col[s * naggs * 8..(s + 1) * naggs * 8];
        history.push(
            row.chunks_exact(8)
                .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
                .collect(),
        );
    }
    Ok(CoordSnapshot { epoch, history })
}

// ---------------------------------------------------------------- send log
//
// One `sendlog_p.ckpt` per worker per checkpointed epoch: every batch
// frame the worker put on the fabric during that superstep (self-
// deliveries encoded too, even though they bypass the fabric), tagged
// with the destination worker. The ckpt layer treats each entry as an
// opaque `(dest, frame)` pair — the engines own the frame wire format
// and decode replayed frames with their own `decode_batch`.

const SENDLOG_META_LEN: usize = 16;

/// Encode one worker's send log for a checkpointed epoch. `entries` are
/// `(destination worker, batch frame bytes)` in send order.
pub fn encode_sendlog(
    epoch: u64,
    partition: u32,
    entries: &[(u32, Vec<u8>)],
    compress: bool,
) -> Vec<u8> {
    let mut meta = Vec::with_capacity(SENDLOG_META_LEN);
    meta.extend_from_slice(&epoch.to_le_bytes());
    meta.extend_from_slice(&partition.to_le_bytes());
    meta.extend_from_slice(&(entries.len() as u32).to_le_bytes());
    let mut le = Encoder::new();
    for (dest, frame) in entries {
        le.put_varint(*dest as u64);
        le.put_bytes(frame);
    }
    frame_sections(
        KIND_SENDLOG,
        &[(SEC_META, meta), (SEC_SENDLOG, le.into_bytes())],
        compress,
    )
}

/// Decode a send log, validating it against the epoch/worker being
/// replayed. Entries come back in send order.
pub fn decode_sendlog(
    bytes: &[u8],
    expect_epoch: u64,
    expect_partition: u32,
) -> Result<Vec<(u32, Vec<u8>)>> {
    let table = open_sections(bytes, KIND_SENDLOG, "send log")?;
    let meta = table.get(SEC_META)?;
    ensure!(
        meta.len() == SENDLOG_META_LEN,
        "section `meta` has {} bytes, expected {SENDLOG_META_LEN}",
        meta.len()
    );
    let epoch = u64::from_le_bytes(meta[0..8].try_into().unwrap());
    let partition = u32::from_le_bytes(meta[8..12].try_into().unwrap());
    let n_entries = u32::from_le_bytes(meta[12..16].try_into().unwrap()) as usize;
    ensure!(
        epoch == expect_epoch,
        "send log is for epoch {epoch}, replaying epoch {expect_epoch}"
    );
    ensure!(
        partition == expect_partition,
        "send log belongs to worker {partition}, expected {expect_partition}"
    );
    let body = table.get(SEC_SENDLOG)?;
    let mut ld = Decoder::new(&body);
    let mut entries = Vec::with_capacity(n_entries.min(ld.remaining() + 1));
    for _ in 0..n_entries {
        let dest = ld.get_varint()? as u32;
        let frame = ld.get_bytes()?.to_vec();
        entries.push((dest, frame));
    }
    ensure!(
        ld.is_at_end(),
        "section `sendlog` has {} trailing bytes",
        ld.remaining()
    );
    Ok(entries)
}

// --------------------------------------------------------------- manifest

/// The commit record of a checkpoint directory: only epochs listed here
/// are recoverable (the atomic-rename commit point).
#[derive(Clone, Debug, PartialEq)]
pub struct Manifest {
    /// Job identity (`algo/engine` + result-affecting knobs).
    pub label: String,
    /// Cluster shape the checkpoint was written with.
    pub partitions: u32,
    /// Committed epochs, ascending.
    pub epochs: Vec<u64>,
}

fn manifest_path(dir: &Path) -> PathBuf {
    dir.join("MANIFEST")
}

fn epoch_dir(dir: &Path, epoch: u64) -> PathBuf {
    dir.join(format!("epoch_{epoch}"))
}

// Durable write-then-rename — now shared with the GoFS packed-partition
// rewrite (crate::util::fsio::persist).
use crate::util::fsio::persist;

fn write_manifest(dir: &Path, m: &Manifest) -> Result<()> {
    let epochs: Vec<String> = m.epochs.iter().map(|e| e.to_string()).collect();
    let text = format!(
        "label={}\npartitions={}\nepochs={}\n",
        m.label,
        m.partitions,
        epochs.join(",")
    );
    persist(&dir.join("MANIFEST.tmp"), &manifest_path(dir), text.as_bytes())
}

fn read_manifest(dir: &Path) -> Result<Manifest> {
    let path = manifest_path(dir);
    let text = fs::read_to_string(&path)
        .with_context(|| format!("read {}", path.display()))?;
    let mut label = None;
    let mut partitions = None;
    let mut epochs = None;
    for line in text.lines() {
        let Some((k, v)) = line.split_once('=') else { continue };
        match k {
            "label" => label = Some(v.to_string()),
            "partitions" => partitions = Some(v.parse()?),
            "epochs" => {
                epochs = Some(
                    v.split(',')
                        .filter(|s| !s.is_empty())
                        .map(|s| s.parse::<u64>())
                        .collect::<Result<Vec<_>, _>>()?,
                )
            }
            _ => {}
        }
    }
    let (Some(label), Some(partitions), Some(epochs)) = (label, partitions, epochs)
    else {
        bail!("{} missing required keys", path.display());
    };
    Ok(Manifest { label, partitions, epochs })
}

// ----------------------------------------------------------------- writer

/// Writes epoch snapshots and commits them through the manifest.
/// Workers call [`CheckpointWriter::write_partition`] concurrently; only
/// the manager calls [`CheckpointWriter::commit`].
pub struct CheckpointWriter {
    dir: PathBuf,
    manifest: Mutex<Manifest>,
    /// Uncommitted epoch directories whose prune failed (permissions,
    /// open handles). Re-attempted at every commit so a transient
    /// failure cannot desynchronize the retained-epoch set from disk
    /// forever; each failed attempt bumps
    /// `goffish_ckpt_prune_failures_total`.
    pending_prunes: Mutex<Vec<u64>>,
}

impl CheckpointWriter {
    /// Open (or initialize) a checkpoint directory. An existing
    /// directory must belong to the same job (`label`) and cluster
    /// shape (`partitions`). With `continue_epochs` (a resumed job
    /// committing back into the directory it resumed from) the
    /// committed-epoch history is kept so new epochs extend it; a fresh
    /// run (`continue_epochs: false`) *resets* any stale epoch list —
    /// otherwise the old run's higher-numbered epochs would outrank
    /// every new one at prune time, and a later resume would restore
    /// the previous run's state.
    pub fn create(
        dir: &Path,
        label: &str,
        partitions: u32,
        continue_epochs: bool,
    ) -> Result<CheckpointWriter> {
        fs::create_dir_all(dir)
            .with_context(|| format!("create checkpoint dir {}", dir.display()))?;
        let mut stale = Vec::new();
        let manifest = if manifest_path(dir).exists() {
            let mut m = read_manifest(dir)?;
            ensure!(
                m.label == label,
                "checkpoint dir {} belongs to job {:?}, not {:?}",
                dir.display(),
                m.label,
                label
            );
            ensure!(
                m.partitions == partitions,
                "checkpoint dir {} was written with {} partitions, job has {}",
                dir.display(),
                m.partitions,
                partitions
            );
            if !continue_epochs && !m.epochs.is_empty() {
                stale = std::mem::take(&mut m.epochs);
                write_manifest(dir, &m)?;
            }
            m
        } else {
            let m = Manifest {
                label: label.to_string(),
                partitions,
                epochs: Vec::new(),
            };
            write_manifest(dir, &m)?;
            m
        };
        if !continue_epochs {
            // A fresh run cannot be resumed confined into: drop any
            // stale failure marker along with the stale epochs.
            let _ = fs::remove_file(failed_marker_path(dir));
        }
        let w = CheckpointWriter {
            dir: dir.to_path_buf(),
            manifest: Mutex::new(manifest),
            pending_prunes: Mutex::new(Vec::new()),
        };
        w.prune_epochs(stale);
        Ok(w)
    }

    /// Remove uncommitted epoch directories — best-effort but
    /// *accounted*. The epochs are already out of the manifest, so a
    /// failed removal (permissions, open handle) cannot corrupt
    /// recovery; what it must not do is vanish silently. Failures land
    /// in [`CheckpointWriter::pending_prunes`], bump the
    /// `goffish_ckpt_prune_failures_total` counter, and are re-attempted
    /// at every subsequent commit.
    fn prune_epochs(&self, epochs: Vec<u64>) {
        let mut pending = self.pending_prunes.lock().unwrap();
        for e in epochs {
            if !pending.contains(&e) {
                pending.push(e);
            }
        }
        pending.retain(|&e| {
            let dir = epoch_dir(&self.dir, e);
            if !dir.exists() {
                return false;
            }
            match fs::remove_dir_all(&dir) {
                Ok(()) => false,
                Err(_) => {
                    crate::obs::registry::global().counter_add(
                        "goffish_ckpt_prune_failures_total",
                        "Failed checkpoint epoch-prune attempts \
                         (leftovers are re-tried at the next commit).",
                        &[],
                        1,
                    );
                    true
                }
            }
        });
    }

    /// How many pruned-but-still-on-disk epoch directories are awaiting
    /// a retry (surfaces in the job report's checkpoint clause).
    pub fn pending_prune_count(&self) -> usize {
        self.pending_prunes.lock().unwrap().len()
    }

    /// Durably (temp + fsync + rename) write worker `p`'s snapshot for
    /// `epoch`. Returns the byte count (the checkpoint-size metric).
    pub fn write_partition(&self, epoch: u64, p: u32, bytes: &[u8]) -> Result<u64> {
        let dir = epoch_dir(&self.dir, epoch);
        fs::create_dir_all(&dir)
            .with_context(|| format!("create {}", dir.display()))?;
        persist(
            &dir.join(format!("part_{p}.ckpt.tmp")),
            &dir.join(format!("part_{p}.ckpt")),
            bytes,
        )?;
        Ok(bytes.len() as u64)
    }

    /// Commit `epoch`: write the coordinator snapshot, list the epoch in
    /// the manifest (the atomic commit point), and prune epochs beyond
    /// [`KEEP_EPOCHS`]. Call only after every worker's
    /// [`CheckpointWriter::write_partition`] for this epoch succeeded.
    pub fn commit(&self, epoch: u64, coord_bytes: &[u8]) -> Result<()> {
        let dir = epoch_dir(&self.dir, epoch);
        fs::create_dir_all(&dir)
            .with_context(|| format!("create {}", dir.display()))?;
        persist(&dir.join("coord.ckpt.tmp"), &dir.join("coord.ckpt"), coord_bytes)?;

        let mut m = self.manifest.lock().unwrap();
        if !m.epochs.contains(&epoch) {
            m.epochs.push(epoch);
            m.epochs.sort_unstable();
        }
        let pruned: Vec<u64> = if m.epochs.len() > KEEP_EPOCHS {
            m.epochs.drain(..m.epochs.len() - KEEP_EPOCHS).collect()
        } else {
            Vec::new()
        };
        write_manifest(&self.dir, &m)?;
        drop(m);
        // Old epochs are already uncommitted (manifest rewritten);
        // pruning retries earlier leftovers too and records failures.
        self.prune_epochs(pruned);
        Ok(())
    }

    /// Durably write worker `p`'s send log for `epoch` (alongside its
    /// snapshot; read back only by confined recovery).
    pub fn write_sendlog(&self, epoch: u64, p: u32, bytes: &[u8]) -> Result<u64> {
        let dir = epoch_dir(&self.dir, epoch);
        fs::create_dir_all(&dir)
            .with_context(|| format!("create {}", dir.display()))?;
        persist(
            &dir.join(format!("sendlog_{p}.ckpt.tmp")),
            &dir.join(format!("sendlog_{p}.ckpt")),
            bytes,
        )?;
        Ok(bytes.len() as u64)
    }

    /// Record which worker a failed run lost, next to the manifest
    /// (atomic rename like everything else here) — the input confined
    /// recovery needs to know whom to rebuild.
    pub fn write_failed_marker(&self, worker: u32) -> Result<()> {
        let text = format!("worker={worker}\n");
        persist(
            &self.dir.join("FAILED_WORKER.tmp"),
            &failed_marker_path(&self.dir),
            text.as_bytes(),
        )
    }

    /// Drop the failure marker after a clean completion (best-effort:
    /// a stale marker only means a later confined resume rebuilds a
    /// worker that did not need it, which replay makes harmless).
    pub fn clear_failed_marker(&self) {
        let _ = fs::remove_file(failed_marker_path(&self.dir));
    }
}

fn failed_marker_path(dir: &Path) -> PathBuf {
    dir.join("FAILED_WORKER")
}

/// Read the `FAILED_WORKER` marker of a checkpoint directory, if
/// present.
pub fn read_failed_marker(dir: &Path) -> Result<Option<u32>> {
    let path = failed_marker_path(dir);
    let text = match fs::read_to_string(&path) {
        Ok(t) => t,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
        Err(e) => return Err(e).with_context(|| format!("read {}", path.display())),
    };
    for line in text.lines() {
        if let Some(v) = line.strip_prefix("worker=") {
            return Ok(Some(v.trim().parse().with_context(|| {
                format!("parse worker id in {}", path.display())
            })?));
        }
    }
    bail!("{} has no worker= line", path.display())
}

// ---------------------------------------------------------- async flusher

/// What workers and the manager hand the background writer in
/// [`CheckpointMode::Async`].
enum FlushMsg {
    /// One worker's encoded snapshot for an epoch.
    Partition { epoch: u64, partition: u32, bytes: Vec<u8> },
    /// One worker's encoded send log for an epoch.
    Sendlog { epoch: u64, partition: u32, bytes: Vec<u8> },
    /// The manager's epoch commit (coordinator snapshot included).
    /// Correct ordering is free: every worker enqueues its files
    /// *before* syncing the barrier and the manager enqueues the commit
    /// only *after* collecting all syncs, so the single-consumer FIFO
    /// processes every partition write before its commit.
    Commit { epoch: u64, coord: Vec<u8> },
}

/// The background persistence thread of [`CheckpointMode::Async`]: a
/// single consumer draining [`FlushMsg`]s while the next superstep
/// computes. The first flush error poisons the flusher — it keeps
/// draining (so senders never block) without touching disk again, and
/// the error surfaces through [`CheckpointFlusher::take_error`] at the
/// next barrier or through [`CheckpointFlusher::finish`] at run end.
pub struct CheckpointFlusher {
    tx: Option<std::sync::mpsc::Sender<FlushMsg>>,
    handle: Option<std::thread::JoinHandle<()>>,
    /// Snapshots/logs/commits enqueued but not yet durably on disk —
    /// published as the `goffish_ckpt_inflight` gauge.
    inflight: Arc<std::sync::atomic::AtomicU64>,
    error: Arc<Mutex<Option<anyhow::Error>>>,
}

impl CheckpointFlusher {
    /// Spawn the flusher thread. `lane` is its trace lane (engines use
    /// `k + 1`, the first lane after the workers'); its writes show up
    /// as `ckpt_flush` spans there.
    pub fn spawn(
        writer: Arc<CheckpointWriter>,
        tracer: &crate::obs::trace::Tracer,
        lane: u32,
    ) -> Result<CheckpointFlusher> {
        use std::sync::atomic::Ordering;
        let (tx, rx) = std::sync::mpsc::channel::<FlushMsg>();
        let inflight = Arc::new(std::sync::atomic::AtomicU64::new(0));
        let error: Arc<Mutex<Option<anyhow::Error>>> = Arc::new(Mutex::new(None));
        let (inflight_t, error_t) = (inflight.clone(), error.clone());
        let tracer = tracer.clone();
        let handle = std::thread::Builder::new()
            .name("ckpt-flush".into())
            .spawn(move || {
                let rec = tracer.recorder(lane);
                for msg in rx {
                    let poisoned = error_t.lock().unwrap().is_some();
                    if !poisoned {
                        let res = match &msg {
                            FlushMsg::Partition { epoch, partition, bytes } => {
                                let _span = rec.as_ref().map(|r| {
                                    r.span_n("ckpt_flush", "ckpt", "epoch", *epoch as f64)
                                });
                                writer
                                    .write_partition(*epoch, *partition, bytes)
                                    .map(|_| ())
                            }
                            FlushMsg::Sendlog { epoch, partition, bytes } => {
                                let _span = rec.as_ref().map(|r| {
                                    r.span_n("ckpt_flush", "ckpt", "epoch", *epoch as f64)
                                });
                                writer.write_sendlog(*epoch, *partition, bytes).map(|_| ())
                            }
                            FlushMsg::Commit { epoch, coord } => {
                                let _span = rec.as_ref().map(|r| {
                                    r.span_n("ckpt_commit", "ckpt", "epoch", *epoch as f64)
                                });
                                writer.commit(*epoch, coord)
                            }
                        };
                        if let Err(e) = res {
                            *error_t.lock().unwrap() = Some(e);
                        }
                    }
                    inflight_t.fetch_sub(1, Ordering::Relaxed);
                }
                if let Some(r) = rec {
                    r.flush();
                }
            })
            .context("spawn ckpt-flush thread")?;
        Ok(CheckpointFlusher { tx: Some(tx), handle: Some(handle), inflight, error })
    }

    fn enqueue(&self, msg: FlushMsg) {
        use std::sync::atomic::Ordering;
        self.inflight.fetch_add(1, Ordering::Relaxed);
        // The receiver only hangs up when the flusher thread is gone;
        // its error (if any) surfaces via take_error/finish.
        if self.tx.as_ref().unwrap().send(msg).is_err() {
            self.inflight.fetch_sub(1, Ordering::Relaxed);
        }
    }

    /// Hand the flusher one worker's encoded snapshot (worker-side, at
    /// the barrier — this call is the whole remaining stall).
    pub fn enqueue_partition(&self, epoch: u64, partition: u32, bytes: Vec<u8>) {
        self.enqueue(FlushMsg::Partition { epoch, partition, bytes });
    }

    /// Hand the flusher one worker's encoded send log.
    pub fn enqueue_sendlog(&self, epoch: u64, partition: u32, bytes: Vec<u8>) {
        self.enqueue(FlushMsg::Sendlog { epoch, partition, bytes });
    }

    /// Hand the flusher an epoch commit (manager-side, after all
    /// workers synced).
    pub fn enqueue_commit(&self, epoch: u64, coord: Vec<u8>) {
        self.enqueue(FlushMsg::Commit { epoch, coord });
    }

    /// Flush operations enqueued but not yet completed (the
    /// `goffish_ckpt_inflight` gauge).
    pub fn inflight(&self) -> u64 {
        self.inflight.load(std::sync::atomic::Ordering::Relaxed)
    }

    /// Take the first flush error, if one happened (checked by the
    /// manager at every barrier so a dead disk aborts the run promptly
    /// instead of at join time).
    pub fn take_error(&self) -> Option<anyhow::Error> {
        self.error.lock().unwrap().take()
    }

    /// Drain the queue, join the thread, and surface any flush error.
    pub fn finish(mut self) -> Result<()> {
        self.join();
        match self.error.lock().unwrap().take() {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    fn join(&mut self) {
        drop(self.tx.take());
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for CheckpointFlusher {
    fn drop(&mut self) {
        self.join();
    }
}

// ----------------------------------------------------------------- reader

/// Reads committed epochs, newest-first, with checksum validation and
/// fallback past corrupt epochs.
pub struct CheckpointReader {
    dir: PathBuf,
    manifest: Manifest,
}

impl CheckpointReader {
    /// Open a checkpoint directory (reads its manifest).
    pub fn open(dir: &Path) -> Result<CheckpointReader> {
        let manifest = read_manifest(dir)
            .with_context(|| format!("open checkpoint dir {}", dir.display()))?;
        Ok(CheckpointReader { dir: dir.to_path_buf(), manifest })
    }

    /// The directory's commit record.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Path of worker `p`'s snapshot in `epoch` (workers read their own
    /// file, data-local style).
    pub fn partition_path(&self, epoch: u64, p: u32) -> PathBuf {
        epoch_dir(&self.dir, epoch).join(format!("part_{p}.ckpt"))
    }

    /// Path of worker `p`'s send log in `epoch` (present only for
    /// epochs written since send logs existed; confined recovery
    /// requires them, global recovery never reads them).
    pub fn sendlog_path(&self, epoch: u64, p: u32) -> PathBuf {
        epoch_dir(&self.dir, epoch).join(format!("sendlog_{p}.ckpt"))
    }

    /// Read *and* checksum-scrub every file of a committed epoch in one
    /// pass — including each file's kind byte, the one header byte no
    /// section checksum covers, so a rotted kind falls back like any
    /// other corruption instead of surviving validation and failing
    /// mid-resume. The error names the corrupt file and section.
    ///
    /// Returning the bytes is the point: resume hands each worker its
    /// already-validated snapshot ([`WorkerResume::bytes`]) instead of
    /// validating the whole epoch and then re-reading every file from
    /// disk a second time.
    pub fn read_valid_epoch(&self, epoch: u64) -> Result<ValidatedEpoch> {
        ensure!(
            self.manifest.epochs.contains(&epoch),
            "epoch {epoch} is not committed in {}",
            self.dir.display()
        );
        let read_checked = |path: PathBuf, kind: u8| -> Result<Vec<u8>> {
            let bytes =
                fs::read(&path).with_context(|| format!("read {}", path.display()))?;
            let report = scrub_file_of_kind(&bytes, kind)
                .with_context(|| format!("scrub {}", path.display()))?;
            for (name, clean) in report {
                ensure!(
                    clean,
                    "checkpoint file {}: section `{name}` corrupt (checksum mismatch)",
                    path.display()
                );
            }
            Ok(bytes)
        };
        let mut partitions = Vec::with_capacity(self.manifest.partitions as usize);
        for p in 0..self.manifest.partitions {
            partitions
                .push(Arc::new(read_checked(self.partition_path(epoch, p), KIND_PARTITION)?));
        }
        let coord =
            read_checked(epoch_dir(&self.dir, epoch).join("coord.ckpt"), KIND_COORD)?;
        Ok(ValidatedEpoch { epoch, partitions, coord })
    }

    /// Checksum-scrub every file of a committed epoch, discarding the
    /// bytes (see [`CheckpointReader::read_valid_epoch`]).
    pub fn validate_epoch(&self, epoch: u64) -> Result<()> {
        self.read_valid_epoch(epoch).map(|_| ())
    }

    /// The newest committed epoch that validates end to end, falling
    /// back past corrupt epochs (the torn-write / bit-rot recovery
    /// rule). Errors only when no committed epoch survives.
    pub fn latest_valid(&self) -> Result<u64> {
        self.latest_valid_epoch().map(|e| e.epoch)
    }

    /// Like [`CheckpointReader::latest_valid`], but keeps the validated
    /// bytes so the caller never re-reads what the scrub already pulled
    /// off disk.
    pub fn latest_valid_epoch(&self) -> Result<ValidatedEpoch> {
        let mut last_err = None;
        for &e in self.manifest.epochs.iter().rev() {
            match self.read_valid_epoch(e) {
                Ok(v) => return Ok(v),
                Err(err) => last_err = Some(err),
            }
        }
        // `epochs` empty covers both a genuinely fresh directory and a
        // truncated/hand-edited manifest (`epochs=` with no entries) —
        // either way a typed error, never a panic.
        match last_err {
            Some(err) => Err(anyhow!(
                "no valid committed epoch in {}: {err:#}",
                self.dir.display()
            )),
            None => Err(anyhow!("no committed epoch in {}", self.dir.display())),
        }
    }

    /// Load the coordinator snapshot of a committed epoch.
    pub fn load_coordinator(&self, epoch: u64, expect_naggs: usize) -> Result<CoordSnapshot> {
        let path = epoch_dir(&self.dir, epoch).join("coord.ckpt");
        let bytes = fs::read(&path).with_context(|| format!("read {}", path.display()))?;
        let snap = decode_coordinator(&bytes, expect_naggs)
            .with_context(|| format!("decode {}", path.display()))?;
        ensure!(
            snap.epoch == epoch,
            "coordinator snapshot at {} is for epoch {}, expected {epoch}",
            path.display(),
            snap.epoch
        );
        Ok(snap)
    }
}

// --------------------------------------------------------- engine helpers
//
// Both engines thread identical checkpoint plumbing through their
// drivers; these helpers keep the shape (and the validation it
// performs) in one place so the engines cannot drift apart — the
// recovery-parity contract depends on them staying in lockstep.

/// Build the epoch writer for a run, continuing the directory's history
/// only when the run resumes from that same directory (canonicalized
/// comparison) — any other run starts the history fresh.
pub fn create_writer(
    ck: &CheckpointConfig,
    resume: Option<&ResumePoint>,
    partitions: u32,
) -> Result<CheckpointWriter> {
    ensure!(ck.every >= 1, "checkpoint every must be >= 1");
    let continuing = resume.is_some_and(|r| {
        // Created before the comparison so `same_dir` can canonicalize
        // both sides.
        let _ = fs::create_dir_all(&ck.dir);
        same_dir(&r.dir, &ck.dir)
    });
    CheckpointWriter::create(&ck.dir, &ck.label, partitions, continuing)
}

/// A committed epoch with every snapshot file read *and*
/// checksum-validated exactly once (see
/// [`CheckpointReader::read_valid_epoch`]). Partition bytes are
/// `Arc`-shared so each worker thread can hold its snapshot without
/// copying.
pub struct ValidatedEpoch {
    /// The committed epoch number (= the superstep it snapshots).
    pub epoch: u64,
    /// Per-worker partition snapshot bytes, indexed by partition id.
    pub partitions: Vec<Arc<Vec<u8>>>,
    /// Coordinator snapshot bytes.
    pub coord: Vec<u8>,
}

/// Everything [`open_resume`] loads for a resuming run: the open
/// reader, the decoded coordinator snapshot, and the validated snapshot
/// bytes of the epoch being resumed.
pub struct ResumeState {
    /// Reader over the checkpoint directory being resumed from.
    pub reader: CheckpointReader,
    /// Decoded coordinator snapshot (aggregator history).
    pub coord: CoordSnapshot,
    /// The validated epoch, bytes included.
    pub epoch: ValidatedEpoch,
    /// Confined-recovery instructions ([`ResumePoint::confined`]):
    /// which worker to rebuild and the replay frames destined to it.
    pub confined: Option<ConfinedResume>,
}

/// What confined recovery rebuilds: the dead worker (from the
/// directory's `FAILED_WORKER` marker) and every epoch frame destined
/// to it, gathered from all senders' logs in sender order (per-sender
/// FIFO within) — exactly the order the stable sender-sort of the
/// inboxes normalizes to, which is what makes the replayed inbox
/// byte-identical to the snapshot one.
pub struct ConfinedResume {
    /// The worker being rebuilt.
    pub dead_worker: u32,
    /// Batch frames destined to it, sender-ordered.
    pub frames: Vec<Vec<u8>>,
}

/// Per-worker resume instructions, derived from [`open_resume`]'s
/// result by [`worker_resume`]: the worker's already-validated snapshot
/// bytes for the epoch being resumed, plus the globals folded at that
/// epoch's barrier (what the worker observes as the previous barrier's
/// aggregates).
pub struct WorkerResume {
    /// The snapshot file the bytes came from (error context only — the
    /// file is *not* re-read).
    pub path: PathBuf,
    /// The worker's snapshot bytes, read + checksummed once by
    /// [`open_resume`].
    pub bytes: Arc<Vec<u8>>,
    /// The epoch being resumed.
    pub epoch: u64,
    /// Globals folded at the resumed epoch's barrier.
    pub globals: Vec<f64>,
    /// Confined recovery only, dead worker only: batch frames to
    /// rebuild the in-flight inbox from (the snapshot's own inbox
    /// section is ignored — a real cluster loses it with the worker's
    /// memory). `None` everywhere else: survivors and global recovery
    /// restore the snapshot queues as before.
    pub replay: Option<Vec<Vec<u8>>>,
}

/// Build worker `p`'s resume instructions (shared by both engines).
pub fn worker_resume(rs: &ResumeState, p: u32) -> WorkerResume {
    let replay = match &rs.confined {
        Some(c) if c.dead_worker == p => Some(c.frames.clone()),
        _ => None,
    };
    WorkerResume {
        path: rs.reader.partition_path(rs.epoch.epoch, p),
        bytes: rs.epoch.partitions[p as usize].clone(),
        epoch: rs.epoch.epoch,
        globals: rs.coord.history.last().cloned().unwrap_or_default(),
        replay,
    }
}

/// Open a resume target: read + checksum-validate the whole epoch in
/// one pass, decode its coordinator snapshot, and validate the cluster
/// shape and aggregator count against the resuming run.
pub fn open_resume(rp: &ResumePoint, partitions: usize, naggs: usize) -> Result<ResumeState> {
    let reader = CheckpointReader::open(&rp.dir)?;
    ensure!(
        reader.manifest().partitions as usize == partitions,
        "checkpoint at {} was written with {} partitions, this run has {partitions}",
        rp.dir.display(),
        reader.manifest().partitions
    );
    let epoch = reader.read_valid_epoch(rp.epoch)?;
    let coord_path = epoch_dir(&rp.dir, rp.epoch).join("coord.ckpt");
    let coord = decode_coordinator(&epoch.coord, naggs)
        .with_context(|| format!("decode {}", coord_path.display()))?;
    ensure!(
        coord.epoch == rp.epoch,
        "coordinator snapshot at {} is for epoch {}, expected {}",
        coord_path.display(),
        coord.epoch,
        rp.epoch
    );
    ensure!(
        coord.history.len() == rp.epoch as usize,
        "coordinator snapshot covers {} supersteps, expected {}",
        coord.history.len(),
        rp.epoch
    );
    let confined = if rp.confined {
        Some(open_confined(&reader, &rp.dir, rp.epoch, partitions as u32)?)
    } else {
        None
    };
    Ok(ResumeState { reader, coord, epoch, confined })
}

/// Load what confined recovery needs: the `FAILED_WORKER` marker (a
/// typed error when absent — without it there is nothing to confine
/// to) and every sender's scrubbed send log for the epoch, filtered to
/// frames destined to the dead worker, in sender order.
fn open_confined(
    reader: &CheckpointReader,
    dir: &Path,
    epoch: u64,
    partitions: u32,
) -> Result<ConfinedResume> {
    let Some(dead_worker) = read_failed_marker(dir)? else {
        bail!(
            "confined recovery needs the FAILED_WORKER marker in {}, and there \
             is none — the checkpointed run did not record a worker failure \
             (resume without --confined-recovery instead)",
            dir.display()
        );
    };
    ensure!(
        dead_worker < partitions,
        "FAILED_WORKER marker in {} names worker {dead_worker}, but the \
         checkpoint only has {partitions} partitions",
        dir.display()
    );
    let mut frames = Vec::new();
    for p in 0..partitions {
        let path = reader.sendlog_path(epoch, p);
        let bytes = fs::read(&path).with_context(|| {
            format!(
                "read send log {} (confined recovery needs every sender's log; \
                 pre-sendlog checkpoints only support global recovery)",
                path.display()
            )
        })?;
        let report = scrub_file_of_kind(&bytes, KIND_SENDLOG)
            .with_context(|| format!("scrub {}", path.display()))?;
        for (name, clean) in report {
            ensure!(
                clean,
                "send log {}: section `{name}` corrupt (checksum mismatch)",
                path.display()
            );
        }
        let entries = decode_sendlog(&bytes, epoch, p)
            .with_context(|| format!("decode {}", path.display()))?;
        frames.extend(
            entries.into_iter().filter(|(dest, _)| *dest == dead_worker).map(|(_, f)| f),
        );
    }
    Ok(ConfinedResume { dead_worker, frames })
}

// ------------------------------------------------------------------ scrub

/// Per-section checksum report for one checkpoint file, validating the
/// kind byte (the one header byte no section checksum covers) against
/// what the file's place in the epoch layout says it must be.
fn scrub_file_of_kind(bytes: &[u8], want_kind: u8) -> Result<Vec<(&'static str, bool)>> {
    // Checksums cover the (possibly packed) section bodies, so the
    // scrub itself is version-blind — it only needs the right version
    // byte to satisfy `unframe`'s header check.
    let version = claimed_version(bytes);
    Ok(section::unframe(bytes, MAGIC, version, want_kind, section_name)?.scrub())
}

/// Whether two paths name the same directory, resolving symlinks and
/// relative spellings when both exist (falling back to lexical
/// equality). Guards the continue-vs-reset decision in
/// [`CheckpointWriter::create`] callers: a resume back into
/// `./ckpt` spelled as `ckpt` must not be mistaken for a fresh run.
pub fn same_dir(a: &Path, b: &Path) -> bool {
    match (fs::canonicalize(a), fs::canonicalize(b)) {
        (Ok(ca), Ok(cb)) => ca == cb,
        _ => a == b,
    }
}

pub use crate::gofs::section::ScrubSummary;

/// Full checksum scrub of every committed epoch in a checkpoint
/// directory — the checkpoint half of `store verify` (the store half is
/// [`crate::gofs::Store::scrub`]; both accumulate the shared
/// [`ScrubSummary`]).
pub fn scrub_dir(dir: &Path) -> Result<ScrubSummary> {
    let reader = CheckpointReader::open(dir)?;
    let mut sum = ScrubSummary::default();
    for &e in &reader.manifest.epochs {
        let mut paths: Vec<(String, PathBuf, u8)> = (0..reader.manifest.partitions)
            .map(|p| {
                (
                    format!("epoch_{e}/part_{p}.ckpt"),
                    reader.partition_path(e, p),
                    KIND_PARTITION,
                )
            })
            .collect();
        paths.push((
            format!("epoch_{e}/coord.ckpt"),
            epoch_dir(dir, e).join("coord.ckpt"),
            KIND_COORD,
        ));
        // Send logs are optional (absent in pre-sendlog checkpoints):
        // scrub the ones that exist, never demand them.
        for p in 0..reader.manifest.partitions {
            let path = reader.sendlog_path(e, p);
            if path.exists() {
                paths.push((format!("epoch_{e}/sendlog_{p}.ckpt"), path, KIND_SENDLOG));
            }
        }
        for (rel, path, kind) in paths {
            match fs::read(&path) {
                Ok(bytes) => sum.record(&rel, scrub_file_of_kind(&bytes, kind)),
                Err(err) => sum.record_unreadable(&rel, err),
            }
        }
    }
    Ok(sum)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join("goffish_ckpt_tests")
            .join(format!("{name}_{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        dir
    }

    fn sample_inbox() -> Vec<Vec<InboxEntry<f32>>> {
        vec![
            vec![
                InboxEntry { sender: 1, vertex: Some(7), payload: 2.5 },
                InboxEntry { sender: 0, vertex: None, payload: -1.0 },
            ],
            Vec::new(),
            vec![InboxEntry { sender: 2, vertex: None, payload: f32::INFINITY }],
        ]
    }

    fn sample_partition_mode(epoch: u64, p: u32, compress: bool) -> Vec<u8> {
        let states = [3.0f32, 1.5, -8.25];
        let halted = [true, false, true];
        encode_partition(
            epoch,
            p,
            3,
            |i, e| states[i].encode_state(e),
            |i| halted[i],
            &sample_inbox(),
            compress,
        )
    }

    fn sample_partition(epoch: u64, p: u32) -> Vec<u8> {
        sample_partition_mode(epoch, p, false)
    }

    #[test]
    fn partition_snapshot_round_trip() {
        let bytes = sample_partition(4, 1);
        let snap = decode_partition::<f32, f32, _>(&bytes, 4, 1, 3, |_, d| {
            f32::decode_state(d)
        })
        .unwrap();
        assert_eq!(snap.epoch, 4);
        assert_eq!(snap.partition, 1);
        assert_eq!(snap.states, vec![3.0, 1.5, -8.25]);
        assert_eq!(snap.halted, vec![true, false, true]);
        assert_eq!(snap.inbox.len(), 3);
        assert_eq!(snap.inbox[0].len(), 2);
        assert_eq!(snap.inbox[0][0].sender, 1);
        assert_eq!(snap.inbox[0][0].vertex, Some(7));
        assert_eq!(snap.inbox[0][0].payload, 2.5);
        assert_eq!(snap.inbox[0][1].vertex, None);
        assert!(snap.inbox[1].is_empty());
        assert_eq!(snap.inbox[2][0].payload, f32::INFINITY);
        // Mismatched expectations are rejected.
        assert!(decode_partition::<f32, f32, _>(&bytes, 5, 1, 3, |_, d| f32::decode_state(d)).is_err());
        assert!(decode_partition::<f32, f32, _>(&bytes, 4, 2, 3, |_, d| f32::decode_state(d)).is_err());
        assert!(decode_partition::<f32, f32, _>(&bytes, 4, 1, 2, |_, d| f32::decode_state(d)).is_err());
    }

    #[test]
    fn coordinator_snapshot_round_trip() {
        let history = vec![vec![1.0, f64::INFINITY], vec![0.5, 3.0], vec![0.25, 2.0]];
        let bytes = encode_coordinator(3, 2, &history, false);
        let snap = decode_coordinator(&bytes, 2).unwrap();
        assert_eq!(snap.epoch, 3);
        assert_eq!(snap.history, history);
        assert!(decode_coordinator(&bytes, 1).is_err());
        // Aggregator-free jobs have empty-but-counted history entries.
        let bytes = encode_coordinator(2, 0, &[vec![], vec![]], false);
        let snap = decode_coordinator(&bytes, 0).unwrap();
        assert_eq!(snap.history, vec![Vec::<f64>::new(); 2]);
    }

    #[test]
    fn writer_commits_epochs_and_prunes() {
        let dir = tmp("commit_prune");
        let w = CheckpointWriter::create(&dir, "cc/gopher", 2, false).unwrap();
        // Fresh dir: manifest exists, no committed epoch.
        let r = CheckpointReader::open(&dir).unwrap();
        assert!(r.latest_valid().is_err());

        for epoch in [1u64, 2, 3] {
            for p in 0..2 {
                w.write_partition(epoch, p, &sample_partition(epoch, p)).unwrap();
            }
            w.commit(epoch, &encode_coordinator(epoch, 0, &vec![vec![]; epoch as usize], false))
                .unwrap();
        }
        let r = CheckpointReader::open(&dir).unwrap();
        // KEEP_EPOCHS retention: epoch 1 pruned, 2 and 3 committed.
        assert_eq!(r.manifest().epochs, vec![2, 3]);
        assert!(!epoch_dir(&dir, 1).exists());
        assert_eq!(r.latest_valid().unwrap(), 3);
        assert_eq!(r.manifest().label, "cc/gopher");

        // A resumed job (continue_epochs) extends the history…
        let w2 = CheckpointWriter::create(&dir, "cc/gopher", 2, true).unwrap();
        for p in 0..2 {
            w2.write_partition(4, p, &sample_partition(4, p)).unwrap();
        }
        w2.commit(4, &encode_coordinator(4, 0, &vec![vec![]; 4], false)).unwrap();
        assert_eq!(CheckpointReader::open(&dir).unwrap().manifest().epochs, vec![3, 4]);
        // …but a different job or cluster shape is refused.
        assert!(CheckpointWriter::create(&dir, "sssp/gopher", 2, false).is_err());
        assert!(CheckpointWriter::create(&dir, "cc/gopher", 3, false).is_err());
    }

    #[test]
    fn fresh_run_resets_stale_epochs() {
        // A non-resumed run reusing a checkpoint dir must not let the
        // previous run's higher-numbered epochs outrank (and prune) its
        // own: the epoch history is reset at create time.
        let dir = tmp("reset_stale");
        let w = CheckpointWriter::create(&dir, "cc/gopher", 1, false).unwrap();
        for epoch in [6u64, 8] {
            w.write_partition(epoch, 0, &sample_partition(epoch, 0)).unwrap();
            w.commit(epoch, &encode_coordinator(epoch, 0, &vec![vec![]; epoch as usize], false))
                .unwrap();
        }
        drop(w);
        let w = CheckpointWriter::create(&dir, "cc/gopher", 1, false).unwrap();
        assert!(
            CheckpointReader::open(&dir).unwrap().latest_valid().is_err(),
            "stale epochs must be gone before the fresh run commits"
        );
        assert!(!epoch_dir(&dir, 8).exists());
        w.write_partition(2, 0, &sample_partition(2, 0)).unwrap();
        w.commit(2, &encode_coordinator(2, 0, &vec![vec![]; 2], false)).unwrap();
        let r = CheckpointReader::open(&dir).unwrap();
        assert_eq!(r.manifest().epochs, vec![2]);
        assert_eq!(r.latest_valid().unwrap(), 2);
    }

    #[test]
    fn corrupt_latest_epoch_falls_back_and_names_the_section() {
        let dir = tmp("fallback");
        let w = CheckpointWriter::create(&dir, "cc/gopher", 1, false).unwrap();
        for epoch in [2u64, 4] {
            w.write_partition(epoch, 0, &sample_partition(epoch, 0)).unwrap();
            w.commit(epoch, &encode_coordinator(epoch, 0, &vec![vec![]; epoch as usize], false))
                .unwrap();
        }
        let r = CheckpointReader::open(&dir).unwrap();
        assert_eq!(r.latest_valid().unwrap(), 4);

        // Flip a byte inside epoch 4's states section.
        let path = r.partition_path(4, 0);
        let mut bytes = fs::read(&path).unwrap();
        let ranges = {
            let table =
                section::unframe(&bytes, MAGIC, VERSION, KIND_PARTITION, section_name)
                    .unwrap();
            table.ranges()
        };
        let states = ranges.iter().find(|(n, _)| *n == "states").unwrap().1.clone();
        bytes[states.start + 1] ^= 0x55;
        fs::write(&path, &bytes).unwrap();

        // Direct validation names the section…
        let err = r.validate_epoch(4).unwrap_err();
        assert!(format!("{err:#}").contains("states"), "{err:#}");
        // …and recovery falls back to the previous committed epoch.
        assert_eq!(r.latest_valid().unwrap(), 2);

        // The scrubber reports the same damage.
        let sum = scrub_dir(&dir).unwrap();
        assert_eq!(sum.corrupt.len(), 1);
        assert!(sum.corrupt[0].contains("epoch_4/part_0.ckpt"), "{:?}", sum.corrupt);
        assert!(sum.corrupt[0].contains("states"));
        assert!(sum.files >= 4);

        // Corrupting epoch 2 as well exhausts the fallback chain.
        let path2 = r.partition_path(2, 0);
        let mut b2 = fs::read(&path2).unwrap();
        let last = b2.len() - 1;
        b2[last] ^= 0xff;
        fs::write(&path2, &b2).unwrap();
        assert!(r.latest_valid().is_err());
    }

    #[test]
    fn read_valid_epoch_hands_back_exact_file_bytes() {
        // The resume path must decode from the bytes the validation
        // pass already read (no second read): assert those bytes are
        // exactly what sits on disk.
        let dir = tmp("read_valid");
        let w = CheckpointWriter::create(&dir, "cc/gopher", 2, false).unwrap();
        for p in 0..2 {
            w.write_partition(3, p, &sample_partition(3, p)).unwrap();
        }
        w.commit(3, &encode_coordinator(3, 0, &vec![vec![]; 3], false)).unwrap();
        let r = CheckpointReader::open(&dir).unwrap();
        let v = r.read_valid_epoch(3).unwrap();
        assert_eq!(v.epoch, 3);
        assert_eq!(v.partitions.len(), 2);
        for p in 0..2u32 {
            let disk = fs::read(r.partition_path(3, p)).unwrap();
            assert_eq!(*v.partitions[p as usize], disk);
        }
        let coord_disk = fs::read(epoch_dir(&dir, 3).join("coord.ckpt")).unwrap();
        assert_eq!(v.coord, coord_disk);
        assert_eq!(r.latest_valid_epoch().unwrap().epoch, r.latest_valid().unwrap());

        // Worker resume instructions carry the validated bytes through.
        let rs = open_resume(
            &ResumePoint { dir: dir.clone(), epoch: 3, confined: false },
            2,
            0,
        )
        .unwrap();
        let wr = worker_resume(&rs, 1);
        assert_eq!(wr.epoch, 3);
        assert_eq!(*wr.bytes, fs::read(&wr.path).unwrap());
        assert!(wr.globals.is_empty());
    }

    #[test]
    fn missing_manifest_is_an_error() {
        let dir = tmp("no_manifest");
        fs::create_dir_all(&dir).unwrap();
        assert!(CheckpointReader::open(&dir).is_err());
        assert!(scrub_dir(&dir).is_err());
    }

    #[test]
    fn rle_round_trips_and_rejects_garbage() {
        let cases: Vec<Vec<u8>> = vec![
            Vec::new(),
            vec![7],
            vec![0; 1000],                      // one long run (needs splitting at 130)
            (0..=255u8).collect(),              // pure literals (needs splitting at 128)
            vec![1, 1, 2, 2, 2, 3, 3, 3, 3, 0], // short runs around the threshold
            {
                let mut v = vec![0u8; 300];
                v.extend((0..200).map(|i| (i * 37 % 251) as u8));
                v.extend_from_slice(&[9; 130]);
                v.push(1);
                v
            },
        ];
        for raw in cases {
            let packed = rle_compress(&raw);
            assert_eq!(rle_decompress(&packed).unwrap(), raw, "len {}", raw.len());
        }
        // Runs actually compress.
        assert!(rle_compress(&[0u8; 1000]).len() < 30);
        // Truncations and length lies are errors, not panics.
        assert!(rle_decompress(&[]).is_err());
        let packed = rle_compress(&[5u8; 50]);
        assert!(rle_decompress(&packed[..packed.len() - 1]).is_err());
        let mut lying = rle_compress(&[5u8; 50]);
        lying[0] = 49; // claim one byte fewer than the tokens produce
        assert!(rle_decompress(&lying).is_err());
    }

    #[test]
    fn compressed_snapshots_round_trip_and_scrub() {
        // Same logical content, VERSION_COMPRESSED on disk: decode,
        // validation, resume, and the scrubber all handle it.
        let dir = tmp("compressed");
        let bytes = sample_partition_mode(4, 0, true);
        assert_eq!(bytes[4], VERSION_COMPRESSED);
        let plain = sample_partition_mode(4, 0, false);
        assert!(bytes.len() != plain.len() || bytes != plain);
        let snap =
            decode_partition::<f32, f32, _>(&bytes, 4, 0, 3, |_, d| f32::decode_state(d))
                .unwrap();
        assert_eq!(snap.states, vec![3.0, 1.5, -8.25]);
        assert_eq!(snap.inbox[0][0].payload, 2.5);

        let history = vec![vec![0.5, 3.0]; 4];
        let cb = encode_coordinator(4, 2, &history, true);
        assert_eq!(decode_coordinator(&cb, 2).unwrap().history, history);

        let w = CheckpointWriter::create(&dir, "cc/gopher", 1, false).unwrap();
        w.write_partition(4, 0, &bytes).unwrap();
        w.write_sendlog(4, 0, &encode_sendlog(4, 0, &[(0, vec![1, 2, 3])], true))
            .unwrap();
        w.commit(4, &cb).unwrap();
        let r = CheckpointReader::open(&dir).unwrap();
        assert_eq!(r.latest_valid().unwrap(), 4);
        let sum = scrub_dir(&dir).unwrap();
        assert!(sum.corrupt.is_empty(), "{:?}", sum.corrupt);
        assert_eq!(sum.files, 3); // partition + coord + sendlog

        // Corruption inside a packed body is still caught by checksum.
        let path = r.partition_path(4, 0);
        let mut b = fs::read(&path).unwrap();
        let mid = b.len() / 2;
        b[mid] ^= 0x55;
        fs::write(&path, &b).unwrap();
        assert!(r.validate_epoch(4).is_err());
    }

    #[test]
    fn sendlog_round_trips_and_validates() {
        let entries: Vec<(u32, Vec<u8>)> =
            vec![(1, vec![0xde, 0xad]), (0, Vec::new()), (1, vec![7; 40])];
        for compress in [false, true] {
            let bytes = encode_sendlog(9, 2, &entries, compress);
            assert_eq!(decode_sendlog(&bytes, 9, 2).unwrap(), entries);
            assert!(decode_sendlog(&bytes, 8, 2).is_err());
            assert!(decode_sendlog(&bytes, 9, 1).is_err());
        }
        // Empty logs (quiescent superstep) are fine.
        let bytes = encode_sendlog(3, 0, &[], false);
        assert!(decode_sendlog(&bytes, 3, 0).unwrap().is_empty());
    }

    #[test]
    fn flusher_persists_and_commits_in_order() {
        let dir = tmp("flusher");
        let w = Arc::new(CheckpointWriter::create(&dir, "cc/gopher", 2, false).unwrap());
        let f = CheckpointFlusher::spawn(
            w.clone(),
            &crate::obs::trace::Tracer::default(),
            3,
        )
        .unwrap();
        for epoch in [1u64, 2] {
            for p in 0..2 {
                f.enqueue_partition(epoch, p, sample_partition(epoch, p));
                f.enqueue_sendlog(epoch, p, encode_sendlog(epoch, p, &[], false));
            }
            f.enqueue_commit(
                epoch,
                encode_coordinator(epoch, 0, &vec![vec![]; epoch as usize], false),
            );
        }
        f.finish().unwrap();
        let r = CheckpointReader::open(&dir).unwrap();
        assert_eq!(r.manifest().epochs, vec![1, 2]);
        assert_eq!(r.latest_valid().unwrap(), 2);
        assert!(r.sendlog_path(2, 1).exists());
    }

    #[test]
    fn flusher_surfaces_write_errors() {
        let dir = tmp("flusher_err");
        let w = Arc::new(CheckpointWriter::create(&dir, "cc/gopher", 1, false).unwrap());
        // A regular file where the epoch dir must go makes every write
        // for that epoch fail.
        fs::write(epoch_dir(&dir, 5), b"not a directory").unwrap();
        let f = CheckpointFlusher::spawn(
            w.clone(),
            &crate::obs::trace::Tracer::default(),
            2,
        )
        .unwrap();
        f.enqueue_partition(5, 0, sample_partition(5, 0));
        let err = f.finish().unwrap_err();
        assert!(format!("{err:#}").contains("epoch_5"), "{err:#}");
        // Nothing was committed.
        assert!(CheckpointReader::open(&dir).unwrap().latest_valid().is_err());
    }

    #[test]
    fn failed_prunes_are_recorded_and_retried() {
        let dir = tmp("prune_retry");
        let w = CheckpointWriter::create(&dir, "cc/gopher", 1, false).unwrap();
        for epoch in [1u64, 2] {
            w.write_partition(epoch, 0, &sample_partition(epoch, 0)).unwrap();
            w.commit(epoch, &encode_coordinator(epoch, 0, &vec![vec![]; epoch as usize], false))
                .unwrap();
        }
        // Make epoch 1's prune fail: swap its directory for a regular
        // file (remove_dir_all refuses non-directories on every
        // platform, even for root).
        fs::remove_dir_all(epoch_dir(&dir, 1)).unwrap();
        fs::write(epoch_dir(&dir, 1), b"immovable").unwrap();
        w.write_partition(3, 0, &sample_partition(3, 0)).unwrap();
        w.commit(3, &encode_coordinator(3, 0, &vec![vec![]; 3], false)).unwrap();
        // Epoch 1 left the manifest but its removal failed: recorded,
        // not swallowed.
        assert_eq!(CheckpointReader::open(&dir).unwrap().manifest().epochs, vec![2, 3]);
        assert!(epoch_dir(&dir, 1).exists());
        assert_eq!(w.pending_prune_count(), 1);
        // Once the obstacle clears, the next commit retires the
        // leftover.
        fs::remove_file(epoch_dir(&dir, 1)).unwrap();
        w.write_partition(4, 0, &sample_partition(4, 0)).unwrap();
        w.commit(4, &encode_coordinator(4, 0, &vec![vec![]; 4], false)).unwrap();
        assert_eq!(w.pending_prune_count(), 0);
        assert!(!epoch_dir(&dir, 2).exists());
    }

    #[cfg(unix)]
    #[test]
    fn read_only_epoch_dir_prune_failure_is_recorded() {
        use std::os::unix::fs::PermissionsExt;
        let dir = tmp("prune_readonly");
        let w = CheckpointWriter::create(&dir, "cc/gopher", 1, false).unwrap();
        for epoch in [1u64, 2, 3] {
            w.write_partition(epoch, 0, &sample_partition(epoch, 0)).unwrap();
            if epoch == 1 {
                // Strip write permission so the unlink inside fails.
                fs::set_permissions(
                    epoch_dir(&dir, 1),
                    fs::Permissions::from_mode(0o555),
                )
                .unwrap();
                // Root ignores permission bits; skip the assertions when
                // the sandbox runs privileged.
                if fs::File::create(epoch_dir(&dir, 1).join("probe")).is_ok() {
                    fs::set_permissions(
                        epoch_dir(&dir, 1),
                        fs::Permissions::from_mode(0o755),
                    )
                    .unwrap();
                    return;
                }
            }
            w.commit(epoch, &encode_coordinator(epoch, 0, &vec![vec![]; epoch as usize], false))
                .unwrap();
        }
        assert!(epoch_dir(&dir, 1).exists());
        assert_eq!(w.pending_prune_count(), 1);
        // Restore permissions; the next commit clears the backlog.
        fs::set_permissions(epoch_dir(&dir, 1), fs::Permissions::from_mode(0o755))
            .unwrap();
        w.write_partition(4, 0, &sample_partition(4, 0)).unwrap();
        w.commit(4, &encode_coordinator(4, 0, &vec![vec![]; 4], false)).unwrap();
        assert_eq!(w.pending_prune_count(), 0);
        assert!(!epoch_dir(&dir, 1).exists());
    }

    #[test]
    fn crafted_manifest_with_no_epochs_is_an_error_not_a_panic() {
        // A hand-edited/truncated manifest whose epoch list is empty (or
        // lists only epochs that no longer exist) must produce the typed
        // checkpoint error, never the old `expect` panic.
        let dir = tmp("crafted_manifest");
        fs::create_dir_all(&dir).unwrap();
        fs::write(
            manifest_path(&dir),
            b"label=cc/gopher\npartitions=1\nepochs=\n",
        )
        .unwrap();
        let r = CheckpointReader::open(&dir).unwrap();
        let err = r.latest_valid_epoch().unwrap_err();
        assert!(format!("{err:#}").contains("no committed epoch"), "{err:#}");
        // An epoch listed but missing on disk takes the other branch.
        fs::write(
            manifest_path(&dir),
            b"label=cc/gopher\npartitions=1\nepochs=7\n",
        )
        .unwrap();
        let r = CheckpointReader::open(&dir).unwrap();
        let err = r.latest_valid_epoch().unwrap_err();
        assert!(format!("{err:#}").contains("no valid committed epoch"), "{err:#}");
    }

    #[test]
    fn failed_marker_round_trips_and_resets() {
        let dir = tmp("marker");
        let w = CheckpointWriter::create(&dir, "cc/gopher", 2, false).unwrap();
        assert_eq!(read_failed_marker(&dir).unwrap(), None);
        w.write_failed_marker(1).unwrap();
        assert_eq!(read_failed_marker(&dir).unwrap(), Some(1));
        w.clear_failed_marker();
        assert_eq!(read_failed_marker(&dir).unwrap(), None);
        // A fresh (non-continuing) create drops a stale marker.
        w.write_failed_marker(0).unwrap();
        drop(w);
        let _w = CheckpointWriter::create(&dir, "cc/gopher", 2, false).unwrap();
        assert_eq!(read_failed_marker(&dir).unwrap(), None);
    }

    #[test]
    fn confined_resume_replays_the_dead_workers_frames() {
        let dir = tmp("confined");
        let w = CheckpointWriter::create(&dir, "cc/gopher", 2, false).unwrap();
        for p in 0..2 {
            w.write_partition(3, p, &sample_partition(3, p)).unwrap();
        }
        // Worker 0 sent one frame to each side; worker 1 sent two to
        // worker 1 (self-deliveries are logged too).
        w.write_sendlog(
            3,
            0,
            &encode_sendlog(3, 0, &[(1, vec![0xa0]), (0, vec![0xa1])], false),
        )
        .unwrap();
        w.write_sendlog(
            3,
            1,
            &encode_sendlog(3, 1, &[(1, vec![0xb0]), (1, vec![0xb1])], false),
        )
        .unwrap();
        w.commit(3, &encode_coordinator(3, 0, &vec![vec![]; 3], false)).unwrap();

        // Without the marker, confined resume is a typed error…
        let rp = ResumePoint { dir: dir.clone(), epoch: 3, confined: true };
        let err = open_resume(&rp, 2, 0).unwrap_err();
        assert!(format!("{err:#}").contains("FAILED_WORKER"), "{err:#}");

        // …with it, the dead worker gets the frames destined to it in
        // sender order, and only the dead worker replays.
        w.write_failed_marker(1).unwrap();
        let rs = open_resume(&rp, 2, 0).unwrap();
        let c = rs.confined.as_ref().unwrap();
        assert_eq!(c.dead_worker, 1);
        assert_eq!(c.frames, vec![vec![0xa0], vec![0xb0], vec![0xb1]]);
        assert_eq!(
            worker_resume(&rs, 1).replay,
            Some(vec![vec![0xa0], vec![0xb0], vec![0xb1]])
        );
        assert_eq!(worker_resume(&rs, 0).replay, None);

        // Global resume of the same directory ignores marker + logs.
        let rs = open_resume(
            &ResumePoint { dir: dir.clone(), epoch: 3, confined: false },
            2,
            0,
        )
        .unwrap();
        assert!(rs.confined.is_none());
        assert_eq!(worker_resume(&rs, 1).replay, None);

        // A missing send log is a typed error (pre-sendlog checkpoint).
        fs::remove_file(
            CheckpointReader::open(&dir).unwrap().sendlog_path(3, 0),
        )
        .unwrap();
        let err = open_resume(&rp, 2, 0).unwrap_err();
        assert!(format!("{err:#}").contains("send log"), "{err:#}");
    }
}
