//! Checkpoint/recovery subsystem — superstep snapshots co-designed with
//! GoFS (the fault-tolerance layer Pregel-family systems pair with
//! synchronous barriers).
//!
//! # What gets persisted
//!
//! Every `every` supersteps, at the barrier **after** the superstep's
//! drain phase, each worker writes one *partition snapshot* file — its
//! per-unit program states (via the programs'
//! `save_state`/`restore_state` hooks, see [`StateCodec`]), halted
//! flags, and the in-flight message queues destined for the next
//! superstep — and the manager, once every worker has synced cleanly,
//! writes the *coordinator snapshot* (the full per-superstep global
//! aggregator history) and **commits** the epoch by atomically
//! rewriting the manifest. Both engines (`gopher` and `pregel`) thread
//! the same machinery through their barrier. When a job runs with
//! tracing ([`crate::obs::trace`]), both sides show up on the timeline:
//! each worker's snapshot write is a `ckpt_write` span on its lane and
//! the manager's manifest commit a `ckpt_commit` span on lane 0.
//!
//! # On-disk layout
//!
//! The files reuse the GoFS v2 sectioned framing ([`crate::gofs::section`]):
//! a version byte, a section directory, and a per-section FNV checksum,
//! so corruption errors name the rotten section and `store verify` can
//! scrub a checkpoint directory exactly like a store.
//!
//! ```text
//! <dir>/MANIFEST             label, partitions, committed epoch list
//! <dir>/epoch_4/part_0.ckpt  partition snapshot (sections: meta, states, halted, inbox)
//! <dir>/epoch_4/part_1.ckpt
//! <dir>/epoch_4/coord.ckpt   coordinator snapshot (sections: meta, agg_history)
//! ```
//!
//! # Commit and recovery semantics
//!
//! A torn write can never be resumed from: snapshot files land via
//! write-to-temp + rename, and an epoch exists only once the manifest
//! (itself renamed into place) lists it — a crash mid-epoch leaves the
//! manifest pointing at the previous committed epoch. The reader walks
//! the committed epochs newest-first and checksum-validates every file,
//! falling back to the previous epoch when the latest has rotted. The
//! last [`KEEP_EPOCHS`] epochs are retained; older ones are pruned at
//! commit.
//!
//! # Determinism
//!
//! Recovery parity (a resumed job's `JobOutput` byte-identical to an
//! uninterrupted run) requires deterministic replay, which the engines
//! guarantee by sender-tagging message frames and stably sorting each
//! unit's inbox by sender before compute, and by folding worker
//! aggregator partials in worker order at the barrier. Checkpoint
//! encodings are deterministic too ([`StateCodec`] serializes maps in
//! key order), so identical runs write identical snapshot bytes.

mod state;

pub use state::StateCodec;

use std::fs;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::gofs::section;
use crate::gopher::api::MsgCodec;
use crate::util::codec::{Decoder, Encoder};

/// Checkpoint file magic ("GoFFish ChecKpoint").
pub const MAGIC: &[u8; 4] = b"GFCK";
/// Checkpoint format version byte.
pub const VERSION: u8 = 1;
/// Committed epochs retained per directory (older ones are pruned at
/// commit; 2 = latest + the fallback for a rotted latest).
pub const KEEP_EPOCHS: usize = 2;

const KIND_PARTITION: u8 = 0;
const KIND_COORD: u8 = 1;

const SEC_META: u8 = 0;
const SEC_STATES: u8 = 1;
const SEC_HALTED: u8 = 2;
const SEC_INBOX: u8 = 3;
const SEC_AGG_HISTORY: u8 = 4;

fn section_name(id: u8) -> &'static str {
    match id {
        SEC_META => "meta",
        SEC_STATES => "states",
        SEC_HALTED => "halted",
        SEC_INBOX => "inbox",
        SEC_AGG_HISTORY => "agg_history",
        _ => "unknown",
    }
}

// ------------------------------------------------------------- knob types

/// Engine-side checkpointing knob (built by the job layer from
/// `JobBuilder::checkpoint_every` / `checkpoint_dir`).
#[derive(Clone, Debug)]
pub struct CheckpointConfig {
    /// Snapshot every N supersteps (>= 1).
    pub every: usize,
    /// Checkpoint directory (shared by all workers + the manager).
    pub dir: PathBuf,
    /// Job identity recorded in the manifest: `algo/engine` plus every
    /// result-affecting knob (see `JobBuilder::label`); resume refuses
    /// a directory written by a different job *or* different
    /// parameters.
    pub label: String,
}

/// A validated resume target: resolved by the job layer (falling back
/// past corrupt epochs) and handed to the engine.
#[derive(Clone, Debug)]
pub struct ResumePoint {
    /// The checkpoint directory to resume from.
    pub dir: PathBuf,
    /// The committed epoch (= superstep) to restart after.
    pub epoch: u64,
}

/// Failure-injection testing hook: the named worker aborts at the start
/// of the named superstep, exactly like a killed host.
#[derive(Clone, Copy, Debug)]
pub struct FailPoint {
    /// Superstep at whose start the worker dies.
    pub superstep: usize,
    /// The worker (partition id) that dies.
    pub worker: u32,
}

/// One queued in-flight message as both engines hold it worker-side:
/// the sending worker (the stable-sort key that makes replay
/// deterministic), the optional target vertex (Gopher's
/// `send_to_subgraph_vertex`; unused by the vertex engine), and the
/// payload.
#[derive(Clone, Debug)]
pub struct InboxEntry<M> {
    /// The sending worker (stable-sort key for deterministic replay).
    pub sender: u32,
    /// Optional target vertex within the receiving unit.
    pub vertex: Option<u32>,
    /// The message payload.
    pub payload: M,
}

// ----------------------------------------------------- partition snapshot

/// A decoded partition snapshot.
pub struct PartitionSnapshot<S, M> {
    /// The committed epoch (= superstep) this snapshot captures.
    pub epoch: u64,
    /// The partition (worker) the snapshot belongs to.
    pub partition: u32,
    /// Per-unit restored program state (sub-graph or vertex order).
    pub states: Vec<S>,
    /// Per-unit halt votes at the snapshot barrier.
    pub halted: Vec<bool>,
    /// Per-unit queued messages for superstep `epoch + 1`.
    pub inbox: Vec<Vec<InboxEntry<M>>>,
}

const PART_META_LEN: usize = 16;

/// Encode one worker's barrier snapshot. `save_state` writes unit `i`'s
/// program state (the `SubgraphProgram::save_state` /
/// `VertexProgram::save_state` hook), `halted(i)` reports its vote.
pub fn encode_partition<M: MsgCodec>(
    epoch: u64,
    partition: u32,
    n_units: usize,
    mut save_state: impl FnMut(usize, &mut Encoder),
    halted: impl Fn(usize) -> bool,
    inbox: &[Vec<InboxEntry<M>>],
) -> Vec<u8> {
    debug_assert_eq!(inbox.len(), n_units);
    let mut meta = Vec::with_capacity(PART_META_LEN);
    meta.extend_from_slice(&epoch.to_le_bytes());
    meta.extend_from_slice(&partition.to_le_bytes());
    meta.extend_from_slice(&(n_units as u32).to_le_bytes());

    let mut se = Encoder::new();
    for i in 0..n_units {
        save_state(i, &mut se);
    }

    let halted_col: Vec<u8> = (0..n_units).map(|i| halted(i) as u8).collect();

    let mut ie = Encoder::new();
    for unit in inbox {
        ie.put_varint(unit.len() as u64);
        for m in unit {
            ie.put_varint(m.sender as u64);
            match m.vertex {
                Some(v) => {
                    ie.put_u8(1);
                    ie.put_varint(v as u64);
                }
                None => ie.put_u8(0),
            }
            m.payload.encode(&mut ie);
        }
    }

    section::frame(
        MAGIC,
        VERSION,
        KIND_PARTITION,
        &[
            (SEC_META, meta),
            (SEC_STATES, se.into_bytes()),
            (SEC_HALTED, halted_col),
            (SEC_INBOX, ie.into_bytes()),
        ],
    )
}

/// Decode one worker's snapshot, validating it against the run being
/// resumed. `restore_state` rebuilds unit `i`'s program state (the
/// programs' `restore_state` hook). `R` is a named generic (not `impl
/// Trait`) so engine call sites can turbofish `S`/`M`.
pub fn decode_partition<S, M, R>(
    bytes: &[u8],
    expect_epoch: u64,
    expect_partition: u32,
    expect_units: usize,
    mut restore_state: R,
) -> Result<PartitionSnapshot<S, M>>
where
    M: MsgCodec,
    R: FnMut(usize, &mut Decoder) -> Result<S>,
{
    let table = section::unframe(bytes, MAGIC, VERSION, KIND_PARTITION, section_name)
        .context("partition snapshot")?;

    let meta = table.get(SEC_META)?;
    ensure!(
        meta.len() == PART_META_LEN,
        "section `meta` has {} bytes, expected {PART_META_LEN}",
        meta.len()
    );
    let epoch = u64::from_le_bytes(meta[0..8].try_into().unwrap());
    let partition = u32::from_le_bytes(meta[8..12].try_into().unwrap());
    let n_units = u32::from_le_bytes(meta[12..16].try_into().unwrap()) as usize;
    ensure!(
        epoch == expect_epoch,
        "snapshot is for epoch {epoch}, resuming epoch {expect_epoch}"
    );
    ensure!(
        partition == expect_partition,
        "snapshot holds partition {partition}, expected {expect_partition}"
    );
    ensure!(
        n_units == expect_units,
        "snapshot holds {n_units} units, this worker owns {expect_units} \
         (resume must use the same store/partitioning as the original run)"
    );

    let mut sd = Decoder::new(table.get(SEC_STATES)?);
    let mut states = Vec::with_capacity(n_units);
    for i in 0..n_units {
        states.push(
            restore_state(i, &mut sd)
                .with_context(|| format!("restore state of unit {i}"))?,
        );
    }
    ensure!(
        sd.is_at_end(),
        "section `states` has {} trailing bytes",
        sd.remaining()
    );

    let halted_col = table.get(SEC_HALTED)?;
    ensure!(
        halted_col.len() == n_units,
        "section `halted` has {} flags, expected {n_units}",
        halted_col.len()
    );
    let halted: Vec<bool> = halted_col.iter().map(|&b| b != 0).collect();

    let mut id = Decoder::new(table.get(SEC_INBOX)?);
    let mut inbox = Vec::with_capacity(n_units);
    for _ in 0..n_units {
        let n = id.get_varint()? as usize;
        let mut unit = Vec::with_capacity(n.min(id.remaining() + 1));
        for _ in 0..n {
            let sender = id.get_varint()? as u32;
            let vertex = if id.get_u8()? != 0 {
                Some(id.get_varint()? as u32)
            } else {
                None
            };
            unit.push(InboxEntry { sender, vertex, payload: M::decode(&mut id)? });
        }
        inbox.push(unit);
    }
    ensure!(
        id.is_at_end(),
        "section `inbox` has {} trailing bytes",
        id.remaining()
    );

    Ok(PartitionSnapshot { epoch, partition, states, halted, inbox })
}

// --------------------------------------------------- coordinator snapshot

/// The manager-side snapshot: the coordinator's full per-superstep
/// global aggregator history (entry `s` = globals folded at barrier
/// `s+1`). Its last entry is what resumed workers observe as the
/// previous barrier's globals.
pub struct CoordSnapshot {
    /// The committed epoch (= superstep) this snapshot captures.
    pub epoch: u64,
    /// Per-superstep global aggregator vectors.
    pub history: Vec<Vec<f64>>,
}

const COORD_META_LEN: usize = 16;

/// Encode the manager's barrier snapshot (see [`CoordSnapshot`]).
pub fn encode_coordinator(epoch: u64, naggs: usize, history: &[Vec<f64>]) -> Vec<u8> {
    let mut meta = Vec::with_capacity(COORD_META_LEN);
    meta.extend_from_slice(&epoch.to_le_bytes());
    meta.extend_from_slice(&(naggs as u32).to_le_bytes());
    meta.extend_from_slice(&(history.len() as u32).to_le_bytes());
    let mut col = Vec::with_capacity(history.len() * naggs * 8);
    for step in history {
        debug_assert_eq!(step.len(), naggs);
        for &v in step {
            col.extend_from_slice(&v.to_le_bytes());
        }
    }
    section::frame(
        MAGIC,
        VERSION,
        KIND_COORD,
        &[(SEC_META, meta), (SEC_AGG_HISTORY, col)],
    )
}

/// Decode a coordinator snapshot, validating the aggregator count
/// against the resuming run's program.
pub fn decode_coordinator(bytes: &[u8], expect_naggs: usize) -> Result<CoordSnapshot> {
    let table = section::unframe(bytes, MAGIC, VERSION, KIND_COORD, section_name)
        .context("coordinator snapshot")?;
    let meta = table.get(SEC_META)?;
    ensure!(
        meta.len() == COORD_META_LEN,
        "section `meta` has {} bytes, expected {COORD_META_LEN}",
        meta.len()
    );
    let epoch = u64::from_le_bytes(meta[0..8].try_into().unwrap());
    let naggs = u32::from_le_bytes(meta[8..12].try_into().unwrap()) as usize;
    let nsteps = u32::from_le_bytes(meta[12..16].try_into().unwrap()) as usize;
    ensure!(
        naggs == expect_naggs,
        "snapshot folded {naggs} aggregators, program registers {expect_naggs}"
    );
    let col = table.get(SEC_AGG_HISTORY)?;
    ensure!(
        col.len() == nsteps * naggs * 8,
        "section `agg_history` has {} bytes, expected {}",
        col.len(),
        nsteps * naggs * 8
    );
    let mut history = Vec::with_capacity(nsteps);
    for s in 0..nsteps {
        let row = &col[s * naggs * 8..(s + 1) * naggs * 8];
        history.push(
            row.chunks_exact(8)
                .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
                .collect(),
        );
    }
    Ok(CoordSnapshot { epoch, history })
}

// --------------------------------------------------------------- manifest

/// The commit record of a checkpoint directory: only epochs listed here
/// are recoverable (the atomic-rename commit point).
#[derive(Clone, Debug, PartialEq)]
pub struct Manifest {
    /// Job identity (`algo/engine` + result-affecting knobs).
    pub label: String,
    /// Cluster shape the checkpoint was written with.
    pub partitions: u32,
    /// Committed epochs, ascending.
    pub epochs: Vec<u64>,
}

fn manifest_path(dir: &Path) -> PathBuf {
    dir.join("MANIFEST")
}

fn epoch_dir(dir: &Path, epoch: u64) -> PathBuf {
    dir.join(format!("epoch_{epoch}"))
}

// Durable write-then-rename — now shared with the GoFS packed-partition
// rewrite (crate::util::fsio::persist).
use crate::util::fsio::persist;

fn write_manifest(dir: &Path, m: &Manifest) -> Result<()> {
    let epochs: Vec<String> = m.epochs.iter().map(|e| e.to_string()).collect();
    let text = format!(
        "label={}\npartitions={}\nepochs={}\n",
        m.label,
        m.partitions,
        epochs.join(",")
    );
    persist(&dir.join("MANIFEST.tmp"), &manifest_path(dir), text.as_bytes())
}

fn read_manifest(dir: &Path) -> Result<Manifest> {
    let path = manifest_path(dir);
    let text = fs::read_to_string(&path)
        .with_context(|| format!("read {}", path.display()))?;
    let mut label = None;
    let mut partitions = None;
    let mut epochs = None;
    for line in text.lines() {
        let Some((k, v)) = line.split_once('=') else { continue };
        match k {
            "label" => label = Some(v.to_string()),
            "partitions" => partitions = Some(v.parse()?),
            "epochs" => {
                epochs = Some(
                    v.split(',')
                        .filter(|s| !s.is_empty())
                        .map(|s| s.parse::<u64>())
                        .collect::<Result<Vec<_>, _>>()?,
                )
            }
            _ => {}
        }
    }
    let (Some(label), Some(partitions), Some(epochs)) = (label, partitions, epochs)
    else {
        bail!("{} missing required keys", path.display());
    };
    Ok(Manifest { label, partitions, epochs })
}

// ----------------------------------------------------------------- writer

/// Writes epoch snapshots and commits them through the manifest.
/// Workers call [`CheckpointWriter::write_partition`] concurrently; only
/// the manager calls [`CheckpointWriter::commit`].
pub struct CheckpointWriter {
    dir: PathBuf,
    manifest: Mutex<Manifest>,
}

impl CheckpointWriter {
    /// Open (or initialize) a checkpoint directory. An existing
    /// directory must belong to the same job (`label`) and cluster
    /// shape (`partitions`). With `continue_epochs` (a resumed job
    /// committing back into the directory it resumed from) the
    /// committed-epoch history is kept so new epochs extend it; a fresh
    /// run (`continue_epochs: false`) *resets* any stale epoch list —
    /// otherwise the old run's higher-numbered epochs would outrank
    /// every new one at prune time, and a later resume would restore
    /// the previous run's state.
    pub fn create(
        dir: &Path,
        label: &str,
        partitions: u32,
        continue_epochs: bool,
    ) -> Result<CheckpointWriter> {
        fs::create_dir_all(dir)
            .with_context(|| format!("create checkpoint dir {}", dir.display()))?;
        let manifest = if manifest_path(dir).exists() {
            let mut m = read_manifest(dir)?;
            ensure!(
                m.label == label,
                "checkpoint dir {} belongs to job {:?}, not {:?}",
                dir.display(),
                m.label,
                label
            );
            ensure!(
                m.partitions == partitions,
                "checkpoint dir {} was written with {} partitions, job has {}",
                dir.display(),
                m.partitions,
                partitions
            );
            if !continue_epochs && !m.epochs.is_empty() {
                let stale = std::mem::take(&mut m.epochs);
                write_manifest(dir, &m)?;
                for e in stale {
                    let _ = fs::remove_dir_all(epoch_dir(dir, e));
                }
            }
            m
        } else {
            let m = Manifest {
                label: label.to_string(),
                partitions,
                epochs: Vec::new(),
            };
            write_manifest(dir, &m)?;
            m
        };
        Ok(CheckpointWriter { dir: dir.to_path_buf(), manifest: Mutex::new(manifest) })
    }

    /// Durably (temp + fsync + rename) write worker `p`'s snapshot for
    /// `epoch`. Returns the byte count (the checkpoint-size metric).
    pub fn write_partition(&self, epoch: u64, p: u32, bytes: &[u8]) -> Result<u64> {
        let dir = epoch_dir(&self.dir, epoch);
        fs::create_dir_all(&dir)
            .with_context(|| format!("create {}", dir.display()))?;
        persist(
            &dir.join(format!("part_{p}.ckpt.tmp")),
            &dir.join(format!("part_{p}.ckpt")),
            bytes,
        )?;
        Ok(bytes.len() as u64)
    }

    /// Commit `epoch`: write the coordinator snapshot, list the epoch in
    /// the manifest (the atomic commit point), and prune epochs beyond
    /// [`KEEP_EPOCHS`]. Call only after every worker's
    /// [`CheckpointWriter::write_partition`] for this epoch succeeded.
    pub fn commit(&self, epoch: u64, coord_bytes: &[u8]) -> Result<()> {
        let dir = epoch_dir(&self.dir, epoch);
        fs::create_dir_all(&dir)
            .with_context(|| format!("create {}", dir.display()))?;
        persist(&dir.join("coord.ckpt.tmp"), &dir.join("coord.ckpt"), coord_bytes)?;

        let mut m = self.manifest.lock().unwrap();
        if !m.epochs.contains(&epoch) {
            m.epochs.push(epoch);
            m.epochs.sort_unstable();
        }
        let pruned: Vec<u64> = if m.epochs.len() > KEEP_EPOCHS {
            m.epochs.drain(..m.epochs.len() - KEEP_EPOCHS).collect()
        } else {
            Vec::new()
        };
        write_manifest(&self.dir, &m)?;
        drop(m);
        // Old epochs are already uncommitted (manifest rewritten), so
        // pruning them is best-effort cleanup.
        for e in pruned {
            let _ = fs::remove_dir_all(epoch_dir(&self.dir, e));
        }
        Ok(())
    }
}

// ----------------------------------------------------------------- reader

/// Reads committed epochs, newest-first, with checksum validation and
/// fallback past corrupt epochs.
pub struct CheckpointReader {
    dir: PathBuf,
    manifest: Manifest,
}

impl CheckpointReader {
    /// Open a checkpoint directory (reads its manifest).
    pub fn open(dir: &Path) -> Result<CheckpointReader> {
        let manifest = read_manifest(dir)
            .with_context(|| format!("open checkpoint dir {}", dir.display()))?;
        Ok(CheckpointReader { dir: dir.to_path_buf(), manifest })
    }

    /// The directory's commit record.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Path of worker `p`'s snapshot in `epoch` (workers read their own
    /// file, data-local style).
    pub fn partition_path(&self, epoch: u64, p: u32) -> PathBuf {
        epoch_dir(&self.dir, epoch).join(format!("part_{p}.ckpt"))
    }

    /// Read *and* checksum-scrub every file of a committed epoch in one
    /// pass — including each file's kind byte, the one header byte no
    /// section checksum covers, so a rotted kind falls back like any
    /// other corruption instead of surviving validation and failing
    /// mid-resume. The error names the corrupt file and section.
    ///
    /// Returning the bytes is the point: resume hands each worker its
    /// already-validated snapshot ([`WorkerResume::bytes`]) instead of
    /// validating the whole epoch and then re-reading every file from
    /// disk a second time.
    pub fn read_valid_epoch(&self, epoch: u64) -> Result<ValidatedEpoch> {
        ensure!(
            self.manifest.epochs.contains(&epoch),
            "epoch {epoch} is not committed in {}",
            self.dir.display()
        );
        let read_checked = |path: PathBuf, kind: u8| -> Result<Vec<u8>> {
            let bytes =
                fs::read(&path).with_context(|| format!("read {}", path.display()))?;
            let report = scrub_file_of_kind(&bytes, kind)
                .with_context(|| format!("scrub {}", path.display()))?;
            for (name, clean) in report {
                ensure!(
                    clean,
                    "checkpoint file {}: section `{name}` corrupt (checksum mismatch)",
                    path.display()
                );
            }
            Ok(bytes)
        };
        let mut partitions = Vec::with_capacity(self.manifest.partitions as usize);
        for p in 0..self.manifest.partitions {
            partitions
                .push(Arc::new(read_checked(self.partition_path(epoch, p), KIND_PARTITION)?));
        }
        let coord =
            read_checked(epoch_dir(&self.dir, epoch).join("coord.ckpt"), KIND_COORD)?;
        Ok(ValidatedEpoch { epoch, partitions, coord })
    }

    /// Checksum-scrub every file of a committed epoch, discarding the
    /// bytes (see [`CheckpointReader::read_valid_epoch`]).
    pub fn validate_epoch(&self, epoch: u64) -> Result<()> {
        self.read_valid_epoch(epoch).map(|_| ())
    }

    /// The newest committed epoch that validates end to end, falling
    /// back past corrupt epochs (the torn-write / bit-rot recovery
    /// rule). Errors only when no committed epoch survives.
    pub fn latest_valid(&self) -> Result<u64> {
        self.latest_valid_epoch().map(|e| e.epoch)
    }

    /// Like [`CheckpointReader::latest_valid`], but keeps the validated
    /// bytes so the caller never re-reads what the scrub already pulled
    /// off disk.
    pub fn latest_valid_epoch(&self) -> Result<ValidatedEpoch> {
        if self.manifest.epochs.is_empty() {
            bail!("no committed epoch in {}", self.dir.display());
        }
        let mut last_err = None;
        for &e in self.manifest.epochs.iter().rev() {
            match self.read_valid_epoch(e) {
                Ok(v) => return Ok(v),
                Err(err) => last_err = Some(err),
            }
        }
        Err(anyhow!(
            "no valid committed epoch in {}: {:#}",
            self.dir.display(),
            last_err.expect("at least one epoch was checked")
        ))
    }

    /// Load the coordinator snapshot of a committed epoch.
    pub fn load_coordinator(&self, epoch: u64, expect_naggs: usize) -> Result<CoordSnapshot> {
        let path = epoch_dir(&self.dir, epoch).join("coord.ckpt");
        let bytes = fs::read(&path).with_context(|| format!("read {}", path.display()))?;
        let snap = decode_coordinator(&bytes, expect_naggs)
            .with_context(|| format!("decode {}", path.display()))?;
        ensure!(
            snap.epoch == epoch,
            "coordinator snapshot at {} is for epoch {}, expected {epoch}",
            path.display(),
            snap.epoch
        );
        Ok(snap)
    }
}

// --------------------------------------------------------- engine helpers
//
// Both engines thread identical checkpoint plumbing through their
// drivers; these helpers keep the shape (and the validation it
// performs) in one place so the engines cannot drift apart — the
// recovery-parity contract depends on them staying in lockstep.

/// Build the epoch writer for a run, continuing the directory's history
/// only when the run resumes from that same directory (canonicalized
/// comparison) — any other run starts the history fresh.
pub fn create_writer(
    ck: &CheckpointConfig,
    resume: Option<&ResumePoint>,
    partitions: u32,
) -> Result<CheckpointWriter> {
    ensure!(ck.every >= 1, "checkpoint every must be >= 1");
    let continuing = resume.is_some_and(|r| {
        // Created before the comparison so `same_dir` can canonicalize
        // both sides.
        let _ = fs::create_dir_all(&ck.dir);
        same_dir(&r.dir, &ck.dir)
    });
    CheckpointWriter::create(&ck.dir, &ck.label, partitions, continuing)
}

/// A committed epoch with every snapshot file read *and*
/// checksum-validated exactly once (see
/// [`CheckpointReader::read_valid_epoch`]). Partition bytes are
/// `Arc`-shared so each worker thread can hold its snapshot without
/// copying.
pub struct ValidatedEpoch {
    /// The committed epoch number (= the superstep it snapshots).
    pub epoch: u64,
    /// Per-worker partition snapshot bytes, indexed by partition id.
    pub partitions: Vec<Arc<Vec<u8>>>,
    /// Coordinator snapshot bytes.
    pub coord: Vec<u8>,
}

/// Everything [`open_resume`] loads for a resuming run: the open
/// reader, the decoded coordinator snapshot, and the validated snapshot
/// bytes of the epoch being resumed.
pub struct ResumeState {
    /// Reader over the checkpoint directory being resumed from.
    pub reader: CheckpointReader,
    /// Decoded coordinator snapshot (aggregator history).
    pub coord: CoordSnapshot,
    /// The validated epoch, bytes included.
    pub epoch: ValidatedEpoch,
}

/// Per-worker resume instructions, derived from [`open_resume`]'s
/// result by [`worker_resume`]: the worker's already-validated snapshot
/// bytes for the epoch being resumed, plus the globals folded at that
/// epoch's barrier (what the worker observes as the previous barrier's
/// aggregates).
pub struct WorkerResume {
    /// The snapshot file the bytes came from (error context only — the
    /// file is *not* re-read).
    pub path: PathBuf,
    /// The worker's snapshot bytes, read + checksummed once by
    /// [`open_resume`].
    pub bytes: Arc<Vec<u8>>,
    /// The epoch being resumed.
    pub epoch: u64,
    /// Globals folded at the resumed epoch's barrier.
    pub globals: Vec<f64>,
}

/// Build worker `p`'s resume instructions (shared by both engines).
pub fn worker_resume(rs: &ResumeState, p: u32) -> WorkerResume {
    WorkerResume {
        path: rs.reader.partition_path(rs.epoch.epoch, p),
        bytes: rs.epoch.partitions[p as usize].clone(),
        epoch: rs.epoch.epoch,
        globals: rs.coord.history.last().cloned().unwrap_or_default(),
    }
}

/// Open a resume target: read + checksum-validate the whole epoch in
/// one pass, decode its coordinator snapshot, and validate the cluster
/// shape and aggregator count against the resuming run.
pub fn open_resume(rp: &ResumePoint, partitions: usize, naggs: usize) -> Result<ResumeState> {
    let reader = CheckpointReader::open(&rp.dir)?;
    ensure!(
        reader.manifest().partitions as usize == partitions,
        "checkpoint at {} was written with {} partitions, this run has {partitions}",
        rp.dir.display(),
        reader.manifest().partitions
    );
    let epoch = reader.read_valid_epoch(rp.epoch)?;
    let coord_path = epoch_dir(&rp.dir, rp.epoch).join("coord.ckpt");
    let coord = decode_coordinator(&epoch.coord, naggs)
        .with_context(|| format!("decode {}", coord_path.display()))?;
    ensure!(
        coord.epoch == rp.epoch,
        "coordinator snapshot at {} is for epoch {}, expected {}",
        coord_path.display(),
        coord.epoch,
        rp.epoch
    );
    ensure!(
        coord.history.len() == rp.epoch as usize,
        "coordinator snapshot covers {} supersteps, expected {}",
        coord.history.len(),
        rp.epoch
    );
    Ok(ResumeState { reader, coord, epoch })
}

// ------------------------------------------------------------------ scrub

/// Per-section checksum report for one checkpoint file, validating the
/// kind byte (the one header byte no section checksum covers) against
/// what the file's place in the epoch layout says it must be.
fn scrub_file_of_kind(bytes: &[u8], want_kind: u8) -> Result<Vec<(&'static str, bool)>> {
    Ok(section::unframe(bytes, MAGIC, VERSION, want_kind, section_name)?.scrub())
}

/// Whether two paths name the same directory, resolving symlinks and
/// relative spellings when both exist (falling back to lexical
/// equality). Guards the continue-vs-reset decision in
/// [`CheckpointWriter::create`] callers: a resume back into
/// `./ckpt` spelled as `ckpt` must not be mistaken for a fresh run.
pub fn same_dir(a: &Path, b: &Path) -> bool {
    match (fs::canonicalize(a), fs::canonicalize(b)) {
        (Ok(ca), Ok(cb)) => ca == cb,
        _ => a == b,
    }
}

pub use crate::gofs::section::ScrubSummary;

/// Full checksum scrub of every committed epoch in a checkpoint
/// directory — the checkpoint half of `store verify` (the store half is
/// [`crate::gofs::Store::scrub`]; both accumulate the shared
/// [`ScrubSummary`]).
pub fn scrub_dir(dir: &Path) -> Result<ScrubSummary> {
    let reader = CheckpointReader::open(dir)?;
    let mut sum = ScrubSummary::default();
    for &e in &reader.manifest.epochs {
        let mut paths: Vec<(String, PathBuf, u8)> = (0..reader.manifest.partitions)
            .map(|p| {
                (
                    format!("epoch_{e}/part_{p}.ckpt"),
                    reader.partition_path(e, p),
                    KIND_PARTITION,
                )
            })
            .collect();
        paths.push((
            format!("epoch_{e}/coord.ckpt"),
            epoch_dir(dir, e).join("coord.ckpt"),
            KIND_COORD,
        ));
        for (rel, path, kind) in paths {
            match fs::read(&path) {
                Ok(bytes) => sum.record(&rel, scrub_file_of_kind(&bytes, kind)),
                Err(err) => sum.record_unreadable(&rel, err),
            }
        }
    }
    Ok(sum)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join("goffish_ckpt_tests")
            .join(format!("{name}_{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        dir
    }

    fn sample_inbox() -> Vec<Vec<InboxEntry<f32>>> {
        vec![
            vec![
                InboxEntry { sender: 1, vertex: Some(7), payload: 2.5 },
                InboxEntry { sender: 0, vertex: None, payload: -1.0 },
            ],
            Vec::new(),
            vec![InboxEntry { sender: 2, vertex: None, payload: f32::INFINITY }],
        ]
    }

    fn sample_partition(epoch: u64, p: u32) -> Vec<u8> {
        let states = [3.0f32, 1.5, -8.25];
        let halted = [true, false, true];
        encode_partition(
            epoch,
            p,
            3,
            |i, e| states[i].encode_state(e),
            |i| halted[i],
            &sample_inbox(),
        )
    }

    #[test]
    fn partition_snapshot_round_trip() {
        let bytes = sample_partition(4, 1);
        let snap = decode_partition::<f32, f32, _>(&bytes, 4, 1, 3, |_, d| {
            f32::decode_state(d)
        })
        .unwrap();
        assert_eq!(snap.epoch, 4);
        assert_eq!(snap.partition, 1);
        assert_eq!(snap.states, vec![3.0, 1.5, -8.25]);
        assert_eq!(snap.halted, vec![true, false, true]);
        assert_eq!(snap.inbox.len(), 3);
        assert_eq!(snap.inbox[0].len(), 2);
        assert_eq!(snap.inbox[0][0].sender, 1);
        assert_eq!(snap.inbox[0][0].vertex, Some(7));
        assert_eq!(snap.inbox[0][0].payload, 2.5);
        assert_eq!(snap.inbox[0][1].vertex, None);
        assert!(snap.inbox[1].is_empty());
        assert_eq!(snap.inbox[2][0].payload, f32::INFINITY);
        // Mismatched expectations are rejected.
        assert!(decode_partition::<f32, f32, _>(&bytes, 5, 1, 3, |_, d| f32::decode_state(d)).is_err());
        assert!(decode_partition::<f32, f32, _>(&bytes, 4, 2, 3, |_, d| f32::decode_state(d)).is_err());
        assert!(decode_partition::<f32, f32, _>(&bytes, 4, 1, 2, |_, d| f32::decode_state(d)).is_err());
    }

    #[test]
    fn coordinator_snapshot_round_trip() {
        let history = vec![vec![1.0, f64::INFINITY], vec![0.5, 3.0], vec![0.25, 2.0]];
        let bytes = encode_coordinator(3, 2, &history);
        let snap = decode_coordinator(&bytes, 2).unwrap();
        assert_eq!(snap.epoch, 3);
        assert_eq!(snap.history, history);
        assert!(decode_coordinator(&bytes, 1).is_err());
        // Aggregator-free jobs have empty-but-counted history entries.
        let bytes = encode_coordinator(2, 0, &[vec![], vec![]]);
        let snap = decode_coordinator(&bytes, 0).unwrap();
        assert_eq!(snap.history, vec![Vec::<f64>::new(); 2]);
    }

    #[test]
    fn writer_commits_epochs_and_prunes() {
        let dir = tmp("commit_prune");
        let w = CheckpointWriter::create(&dir, "cc/gopher", 2, false).unwrap();
        // Fresh dir: manifest exists, no committed epoch.
        let r = CheckpointReader::open(&dir).unwrap();
        assert!(r.latest_valid().is_err());

        for epoch in [1u64, 2, 3] {
            for p in 0..2 {
                w.write_partition(epoch, p, &sample_partition(epoch, p)).unwrap();
            }
            w.commit(epoch, &encode_coordinator(epoch, 0, &vec![vec![]; epoch as usize]))
                .unwrap();
        }
        let r = CheckpointReader::open(&dir).unwrap();
        // KEEP_EPOCHS retention: epoch 1 pruned, 2 and 3 committed.
        assert_eq!(r.manifest().epochs, vec![2, 3]);
        assert!(!epoch_dir(&dir, 1).exists());
        assert_eq!(r.latest_valid().unwrap(), 3);
        assert_eq!(r.manifest().label, "cc/gopher");

        // A resumed job (continue_epochs) extends the history…
        let w2 = CheckpointWriter::create(&dir, "cc/gopher", 2, true).unwrap();
        for p in 0..2 {
            w2.write_partition(4, p, &sample_partition(4, p)).unwrap();
        }
        w2.commit(4, &encode_coordinator(4, 0, &vec![vec![]; 4])).unwrap();
        assert_eq!(CheckpointReader::open(&dir).unwrap().manifest().epochs, vec![3, 4]);
        // …but a different job or cluster shape is refused.
        assert!(CheckpointWriter::create(&dir, "sssp/gopher", 2, false).is_err());
        assert!(CheckpointWriter::create(&dir, "cc/gopher", 3, false).is_err());
    }

    #[test]
    fn fresh_run_resets_stale_epochs() {
        // A non-resumed run reusing a checkpoint dir must not let the
        // previous run's higher-numbered epochs outrank (and prune) its
        // own: the epoch history is reset at create time.
        let dir = tmp("reset_stale");
        let w = CheckpointWriter::create(&dir, "cc/gopher", 1, false).unwrap();
        for epoch in [6u64, 8] {
            w.write_partition(epoch, 0, &sample_partition(epoch, 0)).unwrap();
            w.commit(epoch, &encode_coordinator(epoch, 0, &vec![vec![]; epoch as usize]))
                .unwrap();
        }
        drop(w);
        let w = CheckpointWriter::create(&dir, "cc/gopher", 1, false).unwrap();
        assert!(
            CheckpointReader::open(&dir).unwrap().latest_valid().is_err(),
            "stale epochs must be gone before the fresh run commits"
        );
        assert!(!epoch_dir(&dir, 8).exists());
        w.write_partition(2, 0, &sample_partition(2, 0)).unwrap();
        w.commit(2, &encode_coordinator(2, 0, &vec![vec![]; 2])).unwrap();
        let r = CheckpointReader::open(&dir).unwrap();
        assert_eq!(r.manifest().epochs, vec![2]);
        assert_eq!(r.latest_valid().unwrap(), 2);
    }

    #[test]
    fn corrupt_latest_epoch_falls_back_and_names_the_section() {
        let dir = tmp("fallback");
        let w = CheckpointWriter::create(&dir, "cc/gopher", 1, false).unwrap();
        for epoch in [2u64, 4] {
            w.write_partition(epoch, 0, &sample_partition(epoch, 0)).unwrap();
            w.commit(epoch, &encode_coordinator(epoch, 0, &vec![vec![]; epoch as usize]))
                .unwrap();
        }
        let r = CheckpointReader::open(&dir).unwrap();
        assert_eq!(r.latest_valid().unwrap(), 4);

        // Flip a byte inside epoch 4's states section.
        let path = r.partition_path(4, 0);
        let mut bytes = fs::read(&path).unwrap();
        let ranges = {
            let table =
                section::unframe(&bytes, MAGIC, VERSION, KIND_PARTITION, section_name)
                    .unwrap();
            table.ranges()
        };
        let states = ranges.iter().find(|(n, _)| *n == "states").unwrap().1.clone();
        bytes[states.start + 1] ^= 0x55;
        fs::write(&path, &bytes).unwrap();

        // Direct validation names the section…
        let err = r.validate_epoch(4).unwrap_err();
        assert!(format!("{err:#}").contains("states"), "{err:#}");
        // …and recovery falls back to the previous committed epoch.
        assert_eq!(r.latest_valid().unwrap(), 2);

        // The scrubber reports the same damage.
        let sum = scrub_dir(&dir).unwrap();
        assert_eq!(sum.corrupt.len(), 1);
        assert!(sum.corrupt[0].contains("epoch_4/part_0.ckpt"), "{:?}", sum.corrupt);
        assert!(sum.corrupt[0].contains("states"));
        assert!(sum.files >= 4);

        // Corrupting epoch 2 as well exhausts the fallback chain.
        let path2 = r.partition_path(2, 0);
        let mut b2 = fs::read(&path2).unwrap();
        let last = b2.len() - 1;
        b2[last] ^= 0xff;
        fs::write(&path2, &b2).unwrap();
        assert!(r.latest_valid().is_err());
    }

    #[test]
    fn read_valid_epoch_hands_back_exact_file_bytes() {
        // The resume path must decode from the bytes the validation
        // pass already read (no second read): assert those bytes are
        // exactly what sits on disk.
        let dir = tmp("read_valid");
        let w = CheckpointWriter::create(&dir, "cc/gopher", 2, false).unwrap();
        for p in 0..2 {
            w.write_partition(3, p, &sample_partition(3, p)).unwrap();
        }
        w.commit(3, &encode_coordinator(3, 0, &vec![vec![]; 3])).unwrap();
        let r = CheckpointReader::open(&dir).unwrap();
        let v = r.read_valid_epoch(3).unwrap();
        assert_eq!(v.epoch, 3);
        assert_eq!(v.partitions.len(), 2);
        for p in 0..2u32 {
            let disk = fs::read(r.partition_path(3, p)).unwrap();
            assert_eq!(*v.partitions[p as usize], disk);
        }
        let coord_disk = fs::read(epoch_dir(&dir, 3).join("coord.ckpt")).unwrap();
        assert_eq!(v.coord, coord_disk);
        assert_eq!(r.latest_valid_epoch().unwrap().epoch, r.latest_valid().unwrap());

        // Worker resume instructions carry the validated bytes through.
        let rs = open_resume(&ResumePoint { dir: dir.clone(), epoch: 3 }, 2, 0).unwrap();
        let wr = worker_resume(&rs, 1);
        assert_eq!(wr.epoch, 3);
        assert_eq!(*wr.bytes, fs::read(&wr.path).unwrap());
        assert!(wr.globals.is_empty());
    }

    #[test]
    fn missing_manifest_is_an_error() {
        let dir = tmp("no_manifest");
        fs::create_dir_all(&dir).unwrap();
        assert!(CheckpointReader::open(&dir).is_err());
        assert!(scrub_dir(&dir).is_err());
    }
}
