//! [`StateCodec`] — the checkpoint serialization contract for program
//! state.
//!
//! Both program traits bound their per-unit state on it
//! (`SubgraphProgram::State`, `VertexProgram::Value`), which is what
//! lets the default `save_state`/`restore_state` hooks work out of the
//! box for *value-only* algorithms (states that are plain values or
//! containers of them — CC's `u32` label, SSSP's distance vector, a
//! vertex rank). Programs whose state embeds rebuildable machinery
//! (e.g. PageRank's registered XLA adjacency block) override the hooks
//! and reconstruct that part from the topology on restore.
//!
//! Encodings must be **deterministic**: a checkpoint participates in
//! the byte-identical recovery-parity guarantee, so unordered
//! containers are serialized in sorted key order.

use std::collections::HashMap;

use anyhow::{ensure, Result};

use crate::util::codec::{Decoder, Encoder};

/// Deterministic, self-delimiting binary codec for checkpointed state.
pub trait StateCodec: Sized {
    /// Append this value's deterministic encoding to `e`.
    fn encode_state(&self, e: &mut Encoder);
    /// Decode one value from `d` (exactly what [`StateCodec::encode_state`] wrote).
    fn decode_state(d: &mut Decoder) -> Result<Self>;
}

impl StateCodec for () {
    fn encode_state(&self, _e: &mut Encoder) {}
    fn decode_state(_d: &mut Decoder) -> Result<Self> {
        Ok(())
    }
}

impl StateCodec for bool {
    fn encode_state(&self, e: &mut Encoder) {
        e.put_u8(*self as u8);
    }
    fn decode_state(d: &mut Decoder) -> Result<Self> {
        Ok(d.get_u8()? != 0)
    }
}

impl StateCodec for u8 {
    fn encode_state(&self, e: &mut Encoder) {
        e.put_u8(*self);
    }
    fn decode_state(d: &mut Decoder) -> Result<Self> {
        d.get_u8()
    }
}

impl StateCodec for u32 {
    fn encode_state(&self, e: &mut Encoder) {
        e.put_varint(*self as u64);
    }
    fn decode_state(d: &mut Decoder) -> Result<Self> {
        let v = d.get_varint()?;
        ensure!(v <= u32::MAX as u64, "u32 state overflow: {v}");
        Ok(v as u32)
    }
}

impl StateCodec for u64 {
    fn encode_state(&self, e: &mut Encoder) {
        e.put_varint(*self);
    }
    fn decode_state(d: &mut Decoder) -> Result<Self> {
        d.get_varint()
    }
}

impl StateCodec for usize {
    fn encode_state(&self, e: &mut Encoder) {
        e.put_varint(*self as u64);
    }
    fn decode_state(d: &mut Decoder) -> Result<Self> {
        Ok(d.get_varint()? as usize)
    }
}

impl StateCodec for i64 {
    fn encode_state(&self, e: &mut Encoder) {
        e.put_signed(*self);
    }
    fn decode_state(d: &mut Decoder) -> Result<Self> {
        d.get_signed()
    }
}

impl StateCodec for f32 {
    fn encode_state(&self, e: &mut Encoder) {
        e.put_f32(*self);
    }
    fn decode_state(d: &mut Decoder) -> Result<Self> {
        d.get_f32()
    }
}

impl StateCodec for f64 {
    fn encode_state(&self, e: &mut Encoder) {
        e.put_f64(*self);
    }
    fn decode_state(d: &mut Decoder) -> Result<Self> {
        d.get_f64()
    }
}

impl StateCodec for String {
    fn encode_state(&self, e: &mut Encoder) {
        e.put_str(self);
    }
    fn decode_state(d: &mut Decoder) -> Result<Self> {
        Ok(d.get_str()?.to_string())
    }
}

impl<T: StateCodec> StateCodec for Vec<T> {
    fn encode_state(&self, e: &mut Encoder) {
        e.put_varint(self.len() as u64);
        for x in self {
            x.encode_state(e);
        }
    }
    fn decode_state(d: &mut Decoder) -> Result<Self> {
        let n = d.get_varint()? as usize;
        // Checkpoint sections are checksum-validated before decode, so a
        // wild length means a codec bug, not bit rot — still, cap the
        // pre-allocation to what the buffer could plausibly hold.
        let mut out = Vec::with_capacity(n.min(d.remaining() + 1));
        for _ in 0..n {
            out.push(T::decode_state(d)?);
        }
        Ok(out)
    }
}

impl<T: StateCodec> StateCodec for Option<T> {
    fn encode_state(&self, e: &mut Encoder) {
        match self {
            None => e.put_u8(0),
            Some(x) => {
                e.put_u8(1);
                x.encode_state(e);
            }
        }
    }
    fn decode_state(d: &mut Decoder) -> Result<Self> {
        match d.get_u8()? {
            0 => Ok(None),
            1 => Ok(Some(T::decode_state(d)?)),
            t => anyhow::bail!("bad Option state tag {t}"),
        }
    }
}

impl<A: StateCodec, B: StateCodec> StateCodec for (A, B) {
    fn encode_state(&self, e: &mut Encoder) {
        self.0.encode_state(e);
        self.1.encode_state(e);
    }
    fn decode_state(d: &mut Decoder) -> Result<Self> {
        Ok((A::decode_state(d)?, B::decode_state(d)?))
    }
}

impl<A: StateCodec, B: StateCodec, C: StateCodec> StateCodec for (A, B, C) {
    fn encode_state(&self, e: &mut Encoder) {
        self.0.encode_state(e);
        self.1.encode_state(e);
        self.2.encode_state(e);
    }
    fn decode_state(d: &mut Decoder) -> Result<Self> {
        Ok((A::decode_state(d)?, B::decode_state(d)?, C::decode_state(d)?))
    }
}

/// Maps serialize in sorted key order — iteration order must not leak
/// into checkpoint bytes (the determinism contract).
impl<K, V> StateCodec for HashMap<K, V>
where
    K: StateCodec + Ord + Clone + std::hash::Hash + Eq,
    V: StateCodec + Clone,
{
    fn encode_state(&self, e: &mut Encoder) {
        let mut pairs: Vec<(&K, &V)> = self.iter().collect();
        pairs.sort_by(|a, b| a.0.cmp(b.0));
        e.put_varint(pairs.len() as u64);
        for (k, v) in pairs {
            k.encode_state(e);
            v.encode_state(e);
        }
    }
    fn decode_state(d: &mut Decoder) -> Result<Self> {
        let n = d.get_varint()? as usize;
        let mut out = HashMap::with_capacity(n.min(d.remaining() + 1));
        for _ in 0..n {
            let k = K::decode_state(d)?;
            let v = V::decode_state(d)?;
            out.insert(k, v);
        }
        Ok(out)
    }
}

impl StateCodec for crate::gofs::SubgraphId {
    fn encode_state(&self, e: &mut Encoder) {
        e.put_varint(self.partition as u64);
        e.put_varint(self.index as u64);
    }
    fn decode_state(d: &mut Decoder) -> Result<Self> {
        Ok(crate::gofs::SubgraphId {
            partition: d.get_varint()? as u32,
            index: d.get_varint()? as u32,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rt<T: StateCodec + PartialEq + std::fmt::Debug>(v: T) {
        let mut e = Encoder::new();
        v.encode_state(&mut e);
        let bytes = e.into_bytes();
        let mut d = Decoder::new(&bytes);
        assert_eq!(T::decode_state(&mut d).unwrap(), v);
        assert!(d.is_at_end(), "trailing bytes after {v:?}");
    }

    #[test]
    fn scalar_round_trips() {
        rt(());
        rt(true);
        rt(7u8);
        rt(u32::MAX);
        rt(u64::MAX);
        rt(123usize);
        rt(-42i64);
        rt(1.5f32);
        rt(f32::INFINITY);
        rt(-2.5f64);
        rt("label".to_string());
    }

    #[test]
    fn container_round_trips() {
        rt(vec![1u32, 2, 3]);
        rt(Vec::<f32>::new());
        rt(Some(vec![(1u32, 2.5f32)]));
        rt(Option::<u32>::None);
        rt((4u32, f32::NEG_INFINITY, vec![7u64]));
        rt(vec![(Some(3u32), 9u32), (None, 1)]);
        rt(crate::gofs::SubgraphId { partition: 3, index: 9 });
    }

    #[test]
    fn hashmap_bytes_are_key_sorted() {
        let mut a = HashMap::new();
        let mut b = HashMap::new();
        for (k, v) in [(5u32, 1.0f32), (1, 2.0), (9, 3.0)] {
            a.insert(k, v);
        }
        for (k, v) in [(9u32, 3.0f32), (5, 1.0), (1, 2.0)] {
            b.insert(k, v);
        }
        let enc = |m: &HashMap<u32, f32>| {
            let mut e = Encoder::new();
            m.encode_state(&mut e);
            e.into_bytes()
        };
        // Insertion order must not leak into the bytes.
        assert_eq!(enc(&a), enc(&b));
        rt(a);
    }

    #[test]
    fn corrupt_tags_rejected() {
        let mut d = Decoder::new(&[9u8]);
        assert!(Option::<u32>::decode_state(&mut d).is_err());
    }
}
