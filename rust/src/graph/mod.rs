//! Graph substrate: CSR storage, builders, IO, generators, properties.
//!
//! Everything downstream (partitioners, GoFS, both BSP engines) works on
//! [`csr::Graph`]: a compact CSR with dense `u32` vertex ids, optional
//! f32 edge weights, and both out- and in-adjacency so directed and
//! undirected views are O(1) away.

pub mod csr;
pub mod builder;
pub mod io;
pub mod gen;
pub mod props;

pub use builder::GraphBuilder;
pub use csr::{Graph, VertexId};
