//! Edge-list IO: the SNAP-style text format the paper's datasets ship in.
//!
//! Format: one `src dst [weight]` per line, `#` comments, whitespace
//! separated. External ids may be sparse; they are remapped densely.

use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::Path;

use anyhow::{Context, Result};

use super::builder::GraphBuilder;
use super::csr::Graph;

/// Read a (possibly weighted) edge list.
pub fn read_edge_list(path: &Path, directed: bool) -> Result<Graph> {
    let f = File::open(path).with_context(|| format!("open {}", path.display()))?;
    let mut builder = GraphBuilder::new(directed);
    let mut weighted_builder: Option<GraphBuilder> = None;
    let mut line_no = 0usize;

    for line in BufReader::new(f).lines() {
        let line = line?;
        line_no += 1;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut parts = line.split_whitespace();
        let u: u64 = parts
            .next()
            .context("missing src")?
            .parse()
            .with_context(|| format!("line {line_no}: bad src"))?;
        let v: u64 = parts
            .next()
            .with_context(|| format!("line {line_no}: missing dst"))?
            .parse()
            .with_context(|| format!("line {line_no}: bad dst"))?;
        match parts.next() {
            Some(wtok) => {
                let w: f32 = wtok
                    .parse()
                    .with_context(|| format!("line {line_no}: bad weight"))?;
                let wb = weighted_builder.get_or_insert_with(|| GraphBuilder::new(directed));
                // Weighted path keeps its own builder: the format must be
                // uniformly weighted or uniformly unweighted.
                let ui = intern_pair(wb, u, v);
                wb.add_weighted_edge(ui.0, ui.1, w);
            }
            None => {
                builder.add_edge_ext(u, v);
            }
        }
    }

    if let Some(wb) = weighted_builder {
        anyhow::ensure!(
            builder.num_edges() == 0,
            "mixed weighted/unweighted lines in {}",
            path.display()
        );
        return wb.build();
    }
    builder.build()
}

fn intern_pair(_b: &mut GraphBuilder, u: u64, v: u64) -> (u32, u32) {
    // Weighted edge lists in this repo always use dense ids (they are
    // produced by `write_edge_list`), so no remap table is needed.
    (u as u32, v as u32)
}

/// Write a graph back out as an edge list (weights included when present).
pub fn write_edge_list(g: &Graph, path: &Path) -> Result<()> {
    let f = File::create(path).with_context(|| format!("create {}", path.display()))?;
    let mut w = BufWriter::new(f);
    writeln!(
        w,
        "# goffish edge list: {} vertices, {} edges, directed={}",
        g.num_vertices(),
        g.num_edges(),
        g.directed()
    )?;
    for (u, v, ei) in g.edges() {
        if g.has_weights() {
            writeln!(w, "{u} {v} {}", g.weight(ei))?;
        } else {
            writeln!(w, "{u} {v}")?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("goffish_io_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn round_trip_unweighted() {
        let g = Graph::from_edges(4, &[(0, 1), (1, 2), (2, 3)], None, true).unwrap();
        let p = tmp("rt_unweighted.txt");
        write_edge_list(&g, &p).unwrap();
        let g2 = read_edge_list(&p, true).unwrap();
        assert_eq!(g2.num_vertices(), 4);
        assert_eq!(g2.num_edges(), 3);
    }

    #[test]
    fn round_trip_weighted() {
        let g = Graph::from_edges(3, &[(0, 1), (1, 2)], Some(vec![1.5, 2.5]), true).unwrap();
        let p = tmp("rt_weighted.txt");
        write_edge_list(&g, &p).unwrap();
        let g2 = read_edge_list(&p, true).unwrap();
        assert!(g2.has_weights());
        assert_eq!(g2.num_edges(), 2);
        let (_, ei) = g2.out_edges(0).next().unwrap();
        assert_eq!(g2.weight(ei), 1.5);
    }

    #[test]
    fn comments_and_blanks_skipped() {
        let p = tmp("comments.txt");
        std::fs::write(&p, "# header\n\n0 1\n# mid\n1 2\n").unwrap();
        let g = read_edge_list(&p, false).unwrap();
        assert_eq!(g.num_edges(), 2);
    }

    #[test]
    fn sparse_external_ids_remapped() {
        let p = tmp("sparse.txt");
        std::fs::write(&p, "1000000 5\n5 70000\n").unwrap();
        let g = read_edge_list(&p, true).unwrap();
        assert_eq!(g.num_vertices(), 3);
    }

    #[test]
    fn bad_line_is_error() {
        let p = tmp("bad.txt");
        std::fs::write(&p, "0 x\n").unwrap();
        assert!(read_edge_list(&p, true).is_err());
    }

    #[test]
    fn missing_file_is_error() {
        assert!(read_edge_list(Path::new("/nonexistent/graph.txt"), true).is_err());
    }
}
