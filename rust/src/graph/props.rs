//! Structural graph properties: BFS, WCC count, diameter, degree stats.
//!
//! These are the Table-1 columns (Vertices / Edges / Diameter / WCC) plus
//! the oracles the test suite checks engine output against.

use std::collections::VecDeque;

use crate::util::dsu::Dsu;
use crate::util::rng::Rng;

use super::csr::{Graph, VertexId};

/// BFS hop distances from `source` over the *undirected* view;
/// `u32::MAX` marks unreachable vertices.
pub fn bfs_distances(g: &Graph, source: VertexId) -> Vec<u32> {
    let mut dist = vec![u32::MAX; g.num_vertices()];
    let mut q = VecDeque::new();
    dist[source as usize] = 0;
    q.push_back(source);
    while let Some(u) = q.pop_front() {
        let du = dist[u as usize];
        for v in g.undirected_neighbors(u) {
            if dist[v as usize] == u32::MAX {
                dist[v as usize] = du + 1;
                q.push_back(v);
            }
        }
    }
    dist
}

/// Weakly-connected-component labels (dense, `0..wcc_count`).
pub fn wcc_labels(g: &Graph) -> Vec<u32> {
    let mut dsu = Dsu::new(g.num_vertices());
    for (u, v, _) in g.edges() {
        dsu.union(u, v);
    }
    dsu.labels()
}

/// Number of weakly connected components.
pub fn wcc_count(g: &Graph) -> usize {
    let mut dsu = Dsu::new(g.num_vertices());
    for (u, v, _) in g.edges() {
        dsu.union(u, v);
    }
    dsu.components()
}

/// Eccentricity-based diameter estimate via repeated double-sweep BFS:
/// from `sweeps` random starts, BFS to the farthest vertex, then BFS
/// again from there; the best second-sweep eccentricity lower-bounds the
/// true diameter tightly on real-world graphs. Exact on trees/paths.
pub fn diameter_estimate(g: &Graph, sweeps: usize, seed: u64) -> u32 {
    let n = g.num_vertices();
    if n == 0 {
        return 0;
    }
    let mut rng = Rng::new(seed);
    let mut best = 0u32;
    for _ in 0..sweeps.max(1) {
        let s = rng.index(n) as VertexId;
        let d1 = bfs_distances(g, s);
        let far = argmax_finite(&d1).unwrap_or(s);
        let d2 = bfs_distances(g, far);
        if let Some(f2) = argmax_finite(&d2) {
            best = best.max(d2[f2 as usize]);
        }
    }
    best
}

/// Exact diameter (max finite eccentricity) — O(V·E), small graphs only.
pub fn diameter_exact(g: &Graph) -> u32 {
    let mut best = 0;
    for v in 0..g.num_vertices() as VertexId {
        let d = bfs_distances(g, v);
        for &x in &d {
            if x != u32::MAX {
                best = best.max(x);
            }
        }
    }
    best
}

fn argmax_finite(dist: &[u32]) -> Option<VertexId> {
    let mut best: Option<(u32, VertexId)> = None;
    for (v, &d) in dist.iter().enumerate() {
        if d != u32::MAX && best.is_none_or(|(bd, _)| d > bd) {
            best = Some((d, v as VertexId));
        }
    }
    best.map(|(_, v)| v)
}

/// Degree distribution stats over total (in+out) degree.
#[derive(Clone, Copy, Debug)]
pub struct DegreeStats {
    pub min: usize,
    pub max: usize,
    pub mean: f64,
}

pub fn degree_stats(g: &Graph) -> DegreeStats {
    let n = g.num_vertices();
    if n == 0 {
        return DegreeStats { min: 0, max: 0, mean: 0.0 };
    }
    let mut min = usize::MAX;
    let mut max = 0usize;
    let mut sum = 0usize;
    for v in 0..n as VertexId {
        let d = g.out_degree(v) + g.in_degree(v);
        min = min.min(d);
        max = max.max(d);
        sum += d;
    }
    DegreeStats { min, max, mean: sum as f64 / n as f64 }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;

    #[test]
    fn bfs_on_chain() {
        let g = gen::chain(5);
        let d = bfs_distances(&g, 0);
        assert_eq!(d, vec![0, 1, 2, 3, 4]);
        let d2 = bfs_distances(&g, 2);
        assert_eq!(d2, vec![2, 1, 0, 1, 2]);
    }

    #[test]
    fn bfs_unreachable_marked() {
        let g = Graph::from_edges(4, &[(0, 1)], None, false).unwrap();
        let d = bfs_distances(&g, 0);
        assert_eq!(d[1], 1);
        assert_eq!(d[2], u32::MAX);
        assert_eq!(d[3], u32::MAX);
    }

    #[test]
    fn bfs_follows_undirected_view_on_directed_graph() {
        let g = Graph::from_edges(3, &[(1, 0), (1, 2)], None, true).unwrap();
        let d = bfs_distances(&g, 0);
        assert_eq!(d, vec![0, 1, 2]);
    }

    #[test]
    fn wcc_counts() {
        let g = Graph::from_edges(6, &[(0, 1), (1, 2), (3, 4)], None, false).unwrap();
        assert_eq!(wcc_count(&g), 3); // {0,1,2}, {3,4}, {5}
        let labels = wcc_labels(&g);
        assert_eq!(labels[0], labels[2]);
        assert_eq!(labels[3], labels[4]);
        assert_ne!(labels[0], labels[5]);
    }

    #[test]
    fn diameter_exact_vs_estimate_on_tree() {
        let g = gen::chain(30);
        assert_eq!(diameter_exact(&g), 29);
        assert_eq!(diameter_estimate(&g, 2, 5), 29); // double-sweep exact on paths
    }

    #[test]
    fn diameter_on_grid() {
        let g = gen::grid(6, 9);
        assert_eq!(diameter_exact(&g), 5 + 8);
        let est = diameter_estimate(&g, 4, 3);
        assert!(est >= 11 && est <= 13, "est={est}");
    }

    #[test]
    fn degree_stats_star() {
        let g = gen::star(10);
        let s = degree_stats(&g);
        assert_eq!(s.max, 9);
        assert_eq!(s.min, 1);
        assert!((s.mean - 18.0 / 10.0).abs() < 1e-9);
    }

    #[test]
    fn empty_graph_props() {
        let g = Graph::from_edges(0, &[], None, false).unwrap();
        assert_eq!(wcc_count(&g), 0);
        assert_eq!(diameter_estimate(&g, 3, 1), 0);
    }
}
