//! Compact CSR graph.
//!
//! * Dense `u32` vertex ids `0..n`.
//! * Out-adjacency and in-adjacency CSR (both always present: directed
//!   algorithms need out-edges, GoFS sub-graph discovery and undirected
//!   traversals need the union).
//! * Optional per-edge f32 weights, aligned with the out-CSR; the in-CSR
//!   carries an index back into the out-edge array so weights are never
//!   duplicated.
//! * Graphs are immutable after construction (the paper's GoFS is
//!   write-once-read-many), which keeps every downstream layer copy-free.

use anyhow::{ensure, Result};

pub type VertexId = u32;

/// Immutable CSR graph.
#[derive(Clone, Debug)]
pub struct Graph {
    directed: bool,
    /// Out-CSR: `out_offsets[v]..out_offsets[v+1]` indexes `out_targets`.
    out_offsets: Vec<u64>,
    out_targets: Vec<VertexId>,
    /// In-CSR: `in_offsets[v]..in_offsets[v+1]` indexes `in_sources`.
    in_offsets: Vec<u64>,
    in_sources: Vec<VertexId>,
    /// For each in-edge, its position in the out-edge array (weight lookup).
    in_edge_idx: Vec<u64>,
    /// Optional weights, parallel to `out_targets`.
    weights: Option<Vec<f32>>,
}

impl Graph {
    /// Build from an edge list. `edges` are `(src, dst)` pairs with ids
    /// `< num_vertices`; `weights`, when given, is parallel to `edges`.
    pub fn from_edges(
        num_vertices: usize,
        edges: &[(VertexId, VertexId)],
        weights: Option<Vec<f32>>,
        directed: bool,
    ) -> Result<Graph> {
        if let Some(w) = &weights {
            ensure!(w.len() == edges.len(), "weights/edges length mismatch");
        }
        let n = num_vertices;
        for &(u, v) in edges {
            ensure!(
                (u as usize) < n && (v as usize) < n,
                "edge ({u},{v}) out of range for {n} vertices"
            );
        }

        // Counting sort into out-CSR (stable: preserves input edge order
        // within a source, which keeps weights aligned).
        let mut out_deg = vec![0u64; n + 1];
        for &(u, _) in edges {
            out_deg[u as usize + 1] += 1;
        }
        let mut out_offsets = out_deg;
        for i in 0..n {
            out_offsets[i + 1] += out_offsets[i];
        }
        let mut out_targets = vec![0 as VertexId; edges.len()];
        let mut out_w = weights.as_ref().map(|_| vec![0f32; edges.len()]);
        let mut cursor = out_offsets.clone();
        for (i, &(u, v)) in edges.iter().enumerate() {
            let pos = cursor[u as usize] as usize;
            out_targets[pos] = v;
            if let (Some(ow), Some(w)) = (&mut out_w, &weights) {
                ow[pos] = w[i];
            }
            cursor[u as usize] += 1;
        }

        // In-CSR, with back-pointers into the out-edge array.
        let mut in_deg = vec![0u64; n + 1];
        for &t in &out_targets {
            in_deg[t as usize + 1] += 1;
        }
        let mut in_offsets = in_deg;
        for i in 0..n {
            in_offsets[i + 1] += in_offsets[i];
        }
        let mut in_sources = vec![0 as VertexId; edges.len()];
        let mut in_edge_idx = vec![0u64; edges.len()];
        let mut icursor = in_offsets.clone();
        for u in 0..n {
            let (s, e) = (out_offsets[u] as usize, out_offsets[u + 1] as usize);
            for ei in s..e {
                let v = out_targets[ei] as usize;
                let pos = icursor[v] as usize;
                in_sources[pos] = u as VertexId;
                in_edge_idx[pos] = ei as u64;
                icursor[v] += 1;
            }
        }

        Ok(Graph {
            directed,
            out_offsets,
            out_targets,
            in_offsets,
            in_sources,
            in_edge_idx,
            weights: out_w,
        })
    }

    pub fn directed(&self) -> bool {
        self.directed
    }

    pub fn num_vertices(&self) -> usize {
        self.out_offsets.len() - 1
    }

    /// Number of *stored* edges (for undirected graphs each edge is
    /// stored once; use [`Graph::undirected_neighbors`] to see both ends).
    pub fn num_edges(&self) -> usize {
        self.out_targets.len()
    }

    pub fn has_weights(&self) -> bool {
        self.weights.is_some()
    }

    pub fn out_degree(&self, v: VertexId) -> usize {
        (self.out_offsets[v as usize + 1] - self.out_offsets[v as usize]) as usize
    }

    pub fn in_degree(&self, v: VertexId) -> usize {
        (self.in_offsets[v as usize + 1] - self.in_offsets[v as usize]) as usize
    }

    /// Out-neighbours of `v`.
    pub fn out_neighbors(&self, v: VertexId) -> &[VertexId] {
        let (s, e) = (
            self.out_offsets[v as usize] as usize,
            self.out_offsets[v as usize + 1] as usize,
        );
        &self.out_targets[s..e]
    }

    /// In-neighbours of `v`.
    pub fn in_neighbors(&self, v: VertexId) -> &[VertexId] {
        let (s, e) = (
            self.in_offsets[v as usize] as usize,
            self.in_offsets[v as usize + 1] as usize,
        );
        &self.in_sources[s..e]
    }

    /// Out-edges of `v` as `(target, edge_index)` pairs; `edge_index`
    /// addresses [`Graph::weight`].
    pub fn out_edges(&self, v: VertexId) -> impl Iterator<Item = (VertexId, u64)> + '_ {
        let s = self.out_offsets[v as usize];
        self.out_neighbors(v)
            .iter()
            .enumerate()
            .map(move |(i, &t)| (t, s + i as u64))
    }

    /// In-edges of `v` as `(source, edge_index)` pairs.
    pub fn in_edges(&self, v: VertexId) -> impl Iterator<Item = (VertexId, u64)> + '_ {
        let (s, e) = (
            self.in_offsets[v as usize] as usize,
            self.in_offsets[v as usize + 1] as usize,
        );
        (s..e).map(move |i| (self.in_sources[i], self.in_edge_idx[i]))
    }

    /// Weight of edge `edge_index` (1.0 when the graph is unweighted).
    pub fn weight(&self, edge_index: u64) -> f32 {
        match &self.weights {
            Some(w) => w[edge_index as usize],
            None => 1.0,
        }
    }

    /// Neighbours under the undirected view (out ∪ in). Yields duplicates
    /// for reciprocal edge pairs; traversals treat them idempotently.
    pub fn undirected_neighbors(&self, v: VertexId) -> impl Iterator<Item = VertexId> + '_ {
        self.out_neighbors(v)
            .iter()
            .copied()
            .chain(self.in_neighbors(v).iter().copied())
    }

    /// Undirected edges (neighbour, edge_index) across both directions.
    pub fn undirected_edges(&self, v: VertexId) -> impl Iterator<Item = (VertexId, u64)> + '_ {
        self.out_edges(v).chain(self.in_edges(v))
    }

    /// All stored edges as `(src, dst, edge_index)`.
    pub fn edges(&self) -> impl Iterator<Item = (VertexId, VertexId, u64)> + '_ {
        (0..self.num_vertices() as VertexId)
            .flat_map(move |u| self.out_edges(u).map(move |(v, ei)| (u, v, ei)))
    }

    /// Total bytes of the topology (used by the sim disk model).
    pub fn topology_bytes(&self) -> u64 {
        (self.out_offsets.len() * 8
            + self.out_targets.len() * 4
            + self.in_offsets.len() * 8
            + self.in_sources.len() * 4
            + self.in_edge_idx.len() * 8
            + self.weights.as_ref().map_or(0, |w| w.len() * 4)) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diamond() -> Graph {
        // 0 -> 1 -> 3, 0 -> 2 -> 3
        Graph::from_edges(4, &[(0, 1), (0, 2), (1, 3), (2, 3)], None, true).unwrap()
    }

    #[test]
    fn degrees_and_neighbors() {
        let g = diamond();
        assert_eq!(g.num_vertices(), 4);
        assert_eq!(g.num_edges(), 4);
        assert_eq!(g.out_degree(0), 2);
        assert_eq!(g.in_degree(0), 0);
        assert_eq!(g.out_neighbors(0), &[1, 2]);
        assert_eq!(g.in_neighbors(3), &[1, 2]);
        assert_eq!(g.out_degree(3), 0);
    }

    #[test]
    fn weights_align_with_edges() {
        let edges = [(2u32, 0u32), (0, 1), (1, 2)];
        let g = Graph::from_edges(3, &edges, Some(vec![5.0, 7.0, 9.0]), true).unwrap();
        // Find weight of edge 0->1 via out_edges.
        let (t, ei) = g.out_edges(0).next().unwrap();
        assert_eq!(t, 1);
        assert_eq!(g.weight(ei), 7.0);
        // In-edge back-pointer gives the same weight.
        let (s, ei_in) = g.in_edges(1).next().unwrap();
        assert_eq!(s, 0);
        assert_eq!(g.weight(ei_in), 7.0);
    }

    #[test]
    fn unweighted_defaults_to_one() {
        let g = diamond();
        for (_, _, ei) in g.edges() {
            assert_eq!(g.weight(ei), 1.0);
        }
    }

    #[test]
    fn out_of_range_edge_rejected() {
        assert!(Graph::from_edges(2, &[(0, 5)], None, true).is_err());
    }

    #[test]
    fn weight_length_mismatch_rejected() {
        assert!(Graph::from_edges(2, &[(0, 1)], Some(vec![]), true).is_err());
    }

    #[test]
    fn undirected_view_sees_both_ends() {
        let g = diamond();
        let n0: Vec<_> = g.undirected_neighbors(3).collect();
        assert_eq!(n0, vec![1, 2]); // in-neighbours only; no out
        let n1: Vec<_> = g.undirected_neighbors(1).collect();
        assert_eq!(n1, vec![3, 0]);
    }

    #[test]
    fn edges_iterator_complete() {
        let g = diamond();
        let es: Vec<_> = g.edges().map(|(u, v, _)| (u, v)).collect();
        assert_eq!(es, vec![(0, 1), (0, 2), (1, 3), (2, 3)]);
    }

    #[test]
    fn empty_graph() {
        let g = Graph::from_edges(0, &[], None, false).unwrap();
        assert_eq!(g.num_vertices(), 0);
        assert_eq!(g.num_edges(), 0);
    }

    #[test]
    fn self_loop_and_multi_edge_allowed() {
        let g = Graph::from_edges(2, &[(0, 0), (0, 1), (0, 1)], None, true).unwrap();
        assert_eq!(g.out_degree(0), 3);
        assert_eq!(g.in_degree(1), 2);
    }
}
