//! Incremental graph builder (dedup, id remapping, weight attachment).

use std::collections::HashMap;

use anyhow::Result;

use super::csr::{Graph, VertexId};

/// Accumulates edges (with optional external string/u64 ids) and builds an
/// immutable [`Graph`] with dense internal ids.
pub struct GraphBuilder {
    directed: bool,
    dedup: bool,
    edges: Vec<(VertexId, VertexId)>,
    weights: Vec<f32>,
    weighted: bool,
    /// External id -> dense id (only used via `add_edge_ext`).
    ext_ids: HashMap<u64, VertexId>,
    /// Dense id -> external id, parallel to growth of `ext_ids`.
    ext_rev: Vec<u64>,
    num_vertices: usize,
}

impl GraphBuilder {
    pub fn new(directed: bool) -> Self {
        Self {
            directed,
            dedup: false,
            edges: Vec::new(),
            weights: Vec::new(),
            weighted: false,
            ext_ids: HashMap::new(),
            ext_rev: Vec::new(),
            num_vertices: 0,
        }
    }

    /// Drop duplicate (src,dst) pairs and self-loops at build time.
    pub fn dedup(mut self, yes: bool) -> Self {
        self.dedup = yes;
        self
    }

    /// Ensure ids `0..n` exist even if isolated.
    pub fn reserve_vertices(&mut self, n: usize) {
        self.num_vertices = self.num_vertices.max(n);
    }

    pub fn add_edge(&mut self, u: VertexId, v: VertexId) {
        assert!(!self.weighted, "mixing weighted and unweighted edges");
        self.num_vertices = self.num_vertices.max(u.max(v) as usize + 1);
        self.edges.push((u, v));
    }

    pub fn add_weighted_edge(&mut self, u: VertexId, v: VertexId, w: f32) {
        assert!(
            self.weights.len() == self.edges.len(),
            "mixing weighted and unweighted edges"
        );
        self.weighted = true;
        self.num_vertices = self.num_vertices.max(u.max(v) as usize + 1);
        self.edges.push((u, v));
        self.weights.push(w);
    }

    /// Add an edge between external (sparse) ids, remapping to dense ids.
    pub fn add_edge_ext(&mut self, u_ext: u64, v_ext: u64) {
        let u = self.intern(u_ext);
        let v = self.intern(v_ext);
        self.add_edge(u, v);
    }

    fn intern(&mut self, ext: u64) -> VertexId {
        if let Some(&id) = self.ext_ids.get(&ext) {
            return id;
        }
        let id = self.ext_rev.len() as VertexId;
        self.ext_ids.insert(ext, id);
        self.ext_rev.push(ext);
        self.num_vertices = self.num_vertices.max(id as usize + 1);
        id
    }

    /// External-id mapping table, if `add_edge_ext` was used.
    pub fn external_ids(&self) -> &[u64] {
        &self.ext_rev
    }

    pub fn num_edges(&self) -> usize {
        self.edges.len()
    }

    pub fn build(mut self) -> Result<Graph> {
        if self.dedup {
            let weighted = self.weighted;
            let mut seen = std::collections::HashSet::new();
            let mut edges = Vec::with_capacity(self.edges.len());
            let mut weights = Vec::new();
            for (i, &(u, v)) in self.edges.iter().enumerate() {
                if u == v {
                    continue;
                }
                // For undirected graphs treat (u,v) and (v,u) as the same.
                let key = if self.directed || u <= v { (u, v) } else { (v, u) };
                if seen.insert(key) {
                    edges.push((u, v));
                    if weighted {
                        weights.push(self.weights[i]);
                    }
                }
            }
            self.edges = edges;
            self.weights = weights;
        }
        let w = if self.weighted { Some(self.weights) } else { None };
        Graph::from_edges(self.num_vertices, &self.edges, w, self.directed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_simple() {
        let mut b = GraphBuilder::new(true);
        b.add_edge(0, 1);
        b.add_edge(1, 2);
        let g = b.build().unwrap();
        assert_eq!(g.num_vertices(), 3);
        assert_eq!(g.num_edges(), 2);
    }

    #[test]
    fn reserve_isolated_vertices() {
        let mut b = GraphBuilder::new(false);
        b.add_edge(0, 1);
        b.reserve_vertices(10);
        let g = b.build().unwrap();
        assert_eq!(g.num_vertices(), 10);
    }

    #[test]
    fn dedup_drops_duplicates_and_loops() {
        let mut b = GraphBuilder::new(false).dedup(true);
        b.add_edge(0, 1);
        b.add_edge(1, 0); // same undirected edge
        b.add_edge(0, 1); // duplicate
        b.add_edge(2, 2); // self-loop
        let g = b.build().unwrap();
        assert_eq!(g.num_edges(), 1);
        assert_eq!(g.num_vertices(), 3);
    }

    #[test]
    fn dedup_directed_keeps_reciprocal() {
        let mut b = GraphBuilder::new(true).dedup(true);
        b.add_edge(0, 1);
        b.add_edge(1, 0);
        let g = b.build().unwrap();
        assert_eq!(g.num_edges(), 2);
    }

    #[test]
    fn external_ids_remap_densely() {
        let mut b = GraphBuilder::new(true);
        b.add_edge_ext(1_000_000, 42);
        b.add_edge_ext(42, 7);
        let g = b.build().unwrap();
        assert_eq!(g.num_vertices(), 3);
        assert_eq!(b_ext(&[1_000_000, 42, 7]), b_ext(&[1_000_000, 42, 7]));
        fn b_ext(x: &[u64]) -> Vec<u64> {
            x.to_vec()
        }
    }

    #[test]
    fn weighted_build() {
        let mut b = GraphBuilder::new(true);
        b.add_weighted_edge(0, 1, 2.5);
        b.add_weighted_edge(1, 2, 1.5);
        let g = b.build().unwrap();
        assert!(g.has_weights());
        let (_, ei) = g.out_edges(0).next().unwrap();
        assert_eq!(g.weight(ei), 2.5);
    }

    #[test]
    #[should_panic(expected = "mixing")]
    fn mixing_weighted_unweighted_panics() {
        let mut b = GraphBuilder::new(true);
        b.add_edge(0, 1);
        b.add_weighted_edge(1, 2, 1.0);
    }
}
