//! Synthetic graph generators: laptop-scale analogs of the paper's
//! evaluation datasets (Table 1) plus classic test generators.
//!
//! The paper's three graphs are discriminated by diameter, degree skew
//! and component count — the variables these generators target directly
//! (see DESIGN.md §3):
//!
//! * [`road`] — RN analog: sparse 2-D lattice with dropped edges and rare
//!   shortcuts. Uniform small degrees, *huge* diameter, many WCCs.
//! * [`trace`] — TR analog: hub-and-spoke internet forest: a backbone
//!   core, ISP routers under it, traceroute chains under those, plus one
//!   mega-hub ("timeout" vertex) wired to a large share of all vertices.
//!   Power-law-ish degrees, tiny diameter, single WCC.
//! * [`social`] — LJ analog: preferential attachment (Barabási-Albert
//!   style) giant component plus a dust of tiny components. Power-law
//!   degrees, small diameter, dense.

use crate::util::rng::Rng;

use super::builder::GraphBuilder;
use super::csr::{Graph, VertexId};

/// RN analog: `side x side` 2-D lattice, undirected.
///
/// Each lattice edge survives with probability `keep` (default caller
/// value ~0.97): dropped edges split the lattice into many components and
/// stretch shortest paths, reproducing the California road network's
/// huge-diameter / many-WCC shape. A sprinkle of short "highway" chords
/// (probability `shortcut` per vertex, to a vertex a few rows away) keeps
/// local structure road-like without collapsing the diameter.
pub fn road(side: usize, keep: f64, shortcut: f64, seed: u64) -> Graph {
    let mut rng = Rng::new(seed);
    let n = side * side;
    // "Island" vertices — disconnected spurs/roundabouts of real road
    // data — give the RN shape its many small WCCs (the paper's RN has
    // 2,638). Probability scales with the edge-drop rate.
    let iso_p = (1.0 - keep) * 0.5;
    let isolated: Vec<bool> = (0..n).map(|_| rng.chance(iso_p)).collect();
    let mut b = GraphBuilder::new(false).dedup(true);
    b.reserve_vertices(n);
    let id = |r: usize, c: usize| (r * side + c) as VertexId;
    let ok = |v: VertexId| !isolated[v as usize];
    for r in 0..side {
        for c in 0..side {
            let v = id(r, c);
            if c + 1 < side && rng.chance(keep) && ok(v) && ok(id(r, c + 1)) {
                b.add_edge(v, id(r, c + 1));
            }
            if r + 1 < side && rng.chance(keep) && ok(v) && ok(id(r + 1, c)) {
                b.add_edge(v, id(r + 1, c));
            }
            if rng.chance(shortcut) {
                // Short-range chord: jump 2..5 rows/cols away ("highway").
                let dr = rng.range_u64(2, 5) as usize;
                let dc = rng.range_u64(0, 3) as usize;
                let (nr, nc) = (r + dr, c + dc);
                if nr < side && nc < side && ok(v) && ok(id(nr, nc)) {
                    b.add_edge(v, id(nr, nc));
                }
            }
        }
    }
    b.build().expect("road generator produced invalid graph")
}

/// TR analog: traceroute-forest with a mega-hub, directed.
///
/// Structure: `core` backbone routers form a random small-world ring;
/// each remaining vertex attaches under a uniformly chosen existing
/// vertex, building shallow trees (traceroute paths). Finally one
/// designated vertex (id 0, the "trace timeout" marker of the paper's TR
/// graph) receives edges from a `hub_frac` share of all vertices,
/// giving it the O(millions)-degree shape that broke HDFS loading.
pub fn trace(n: usize, core: usize, hub_frac: f64, seed: u64) -> Graph {
    assert!(core >= 3 && core < n);
    let mut rng = Rng::new(seed);
    let mut b = GraphBuilder::new(true).dedup(true);
    b.reserve_vertices(n);
    // Backbone ring + random chords (small-world core).
    for i in 0..core {
        b.add_edge(i as VertexId, ((i + 1) % core) as VertexId);
        if rng.chance(0.3) {
            let j = rng.index(core);
            if j != i {
                b.add_edge(i as VertexId, j as VertexId);
            }
        }
    }
    // Attach the remaining vertices under earlier ones: biased toward the
    // core so trees stay shallow (log depth), like hop-limited traceroutes.
    for v in core..n {
        let parent = if rng.chance(0.5) {
            rng.index(core)
        } else {
            rng.index(v)
        };
        b.add_edge(parent as VertexId, v as VertexId);
    }
    // Mega-hub: vertex 0 observes a fraction of all vertices (timeouts).
    for v in 1..n {
        if rng.chance(hub_frac) {
            b.add_edge(v as VertexId, 0);
        }
    }
    b.build().expect("trace generator produced invalid graph")
}

/// LJ analog: preferential-attachment giant component + component dust,
/// directed.
///
/// `m` out-edges per new vertex, targets chosen by degree-proportional
/// sampling (edge-endpoint trick). `dust_frac` of the vertices are held
/// out of the giant component and wired into random 2..6-vertex islands,
/// matching LiveJournal's 1877 WCCs.
pub fn social(n: usize, m: usize, dust_frac: f64, seed: u64) -> Graph {
    assert!(m >= 1 && n > m + 1);
    let mut rng = Rng::new(seed);
    let n_dust = ((n as f64) * dust_frac) as usize;
    let n_core = n - n_dust;
    let mut b = GraphBuilder::new(true).dedup(true);
    b.reserve_vertices(n);
    // Endpoint list for degree-proportional sampling.
    let mut endpoints: Vec<VertexId> = Vec::with_capacity(2 * m * n_core);
    // Seed clique over the first m+1 vertices.
    for i in 0..=m {
        for j in 0..i {
            b.add_edge(i as VertexId, j as VertexId);
            endpoints.push(i as VertexId);
            endpoints.push(j as VertexId);
        }
    }
    for v in (m + 1)..n_core {
        for _ in 0..m {
            let t = *rng.choose(&endpoints);
            if t != v as VertexId {
                b.add_edge(v as VertexId, t);
                endpoints.push(v as VertexId);
                endpoints.push(t);
            }
        }
    }
    // Dust: tiny random islands among the held-out vertices.
    let mut v = n_core;
    while v < n {
        let island = (2 + rng.index(5)).min(n - v);
        for i in 1..island {
            b.add_edge((v + i) as VertexId, (v + rng.index(i)) as VertexId);
        }
        v += island;
    }
    b.build().expect("social generator produced invalid graph")
}

/// Erdős–Rényi G(n, p), directed or undirected (expected p·n·(n-1) edges).
pub fn erdos_renyi(n: usize, p: f64, directed: bool, seed: u64) -> Graph {
    let mut rng = Rng::new(seed);
    let mut b = GraphBuilder::new(directed).dedup(true);
    b.reserve_vertices(n);
    // Geometric skipping for sparse p.
    if p > 0.0 {
        let ln_q = (1.0 - p).ln();
        let total = (n * n) as u64;
        let mut i: u64 = 0;
        loop {
            let r = rng.f64().max(1e-300);
            let skip = if p >= 1.0 { 1 } else { (r.ln() / ln_q).floor() as u64 + 1 };
            i += skip;
            if i > total {
                break;
            }
            let u = ((i - 1) / n as u64) as VertexId;
            let v = ((i - 1) % n as u64) as VertexId;
            if u != v && (directed || u < v) {
                b.add_edge(u, v);
            }
        }
    }
    b.build().expect("erdos_renyi generator produced invalid graph")
}

/// Deterministic `rows x cols` lattice (undirected, fully connected).
pub fn grid(rows: usize, cols: usize) -> Graph {
    let mut b = GraphBuilder::new(false);
    b.reserve_vertices(rows * cols);
    let id = |r: usize, c: usize| (r * cols + c) as VertexId;
    for r in 0..rows {
        for c in 0..cols {
            if c + 1 < cols {
                b.add_edge(id(r, c), id(r, c + 1));
            }
            if r + 1 < rows {
                b.add_edge(id(r, c), id(r + 1, c));
            }
        }
    }
    b.build().expect("grid generator produced invalid graph")
}

/// Path graph 0-1-…-(n-1) (worst case for vertex-centric supersteps).
pub fn chain(n: usize) -> Graph {
    let mut b = GraphBuilder::new(false);
    b.reserve_vertices(n);
    for i in 0..n.saturating_sub(1) {
        b.add_edge(i as VertexId, i as VertexId + 1);
    }
    b.build().expect("chain generator produced invalid graph")
}

/// Star: vertex 0 at the centre of n-1 spokes.
pub fn star(n: usize) -> Graph {
    let mut b = GraphBuilder::new(false);
    b.reserve_vertices(n);
    for i in 1..n {
        b.add_edge(0, i as VertexId);
    }
    b.build().expect("star generator produced invalid graph")
}

// ----------------------------------------------------------------------
// Evaluation dataset analogs (Table 1 of the paper, laptop scale).
// The discriminating shape is preserved: RN = huge diameter, sparse,
// many WCCs; TR = mega-hub, tiny diameter, 1 WCC; LJ = dense power-law,
// tiny diameter, giant WCC + dust. `scale` = 1.0 gives the default bench
// size (~40k/60k/30k vertices); tests use smaller scales.

/// California road network analog (paper: 1.97M vertices, diam 849,
/// 2638 WCCs).
pub fn rn_analog(scale: f64, seed: u64) -> Graph {
    let side = ((200.0 * scale.sqrt()) as usize).max(8);
    road(side, 0.97, 0.003, seed)
}

/// Internet-traceroute analog (paper TR: 19.4M vertices, diam 25, 1 WCC,
/// one O(millions)-degree vertex).
pub fn tr_analog(scale: f64, seed: u64) -> Graph {
    let n = ((60_000.0 * scale) as usize).max(100);
    trace(n, (n / 400).max(10), 0.25, seed)
}

/// LiveJournal analog (paper LJ: 4.8M vertices, 68M edges, diam 10,
/// 1877 WCCs, power-law).
pub fn lj_analog(scale: f64, seed: u64) -> Graph {
    let n = ((30_000.0 * scale) as usize).max(100);
    social(n, 12, 0.02, seed)
}

/// Attach uniform random f32 weights in `[lo, hi)` to a graph's edges
/// (used to build weighted SSSP inputs from the analogs).
pub fn with_random_weights(g: &Graph, lo: f32, hi: f32, seed: u64) -> Graph {
    let mut rng = Rng::new(seed);
    let edges: Vec<(VertexId, VertexId)> = g.edges().map(|(u, v, _)| (u, v)).collect();
    let weights: Vec<f32> = (0..edges.len())
        .map(|_| lo + rng.f32() * (hi - lo))
        .collect();
    Graph::from_edges(g.num_vertices(), &edges, Some(weights), g.directed())
        .expect("reweighting preserved validity")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::props;

    #[test]
    fn road_shape() {
        let g = road(40, 0.97, 0.01, 1);
        assert_eq!(g.num_vertices(), 1600);
        // Sparse: average degree around 2 (stored edges ~ 2 per vertex).
        let avg = g.num_edges() as f64 / g.num_vertices() as f64;
        assert!(avg > 1.5 && avg < 2.5, "avg={avg}");
        // Long diameter relative to size.
        let d = props::diameter_estimate(&g, 3, 7);
        assert!(d > 40, "road diameter estimate too small: {d}");
    }

    #[test]
    fn road_determinism() {
        let a = road(20, 0.95, 0.01, 7);
        let b = road(20, 0.95, 0.01, 7);
        assert_eq!(a.num_edges(), b.num_edges());
        let ea: Vec<_> = a.edges().map(|(u, v, _)| (u, v)).collect();
        let eb: Vec<_> = b.edges().map(|(u, v, _)| (u, v)).collect();
        assert_eq!(ea, eb);
    }

    #[test]
    fn trace_shape() {
        let g = trace(5000, 50, 0.3, 2);
        assert_eq!(g.num_vertices(), 5000);
        // Mega-hub has huge in-degree.
        let hub_deg = g.in_degree(0) + g.out_degree(0);
        assert!(hub_deg > 1000, "hub degree {hub_deg}");
        // Single weak component.
        assert_eq!(props::wcc_count(&g), 1);
        // Small diameter.
        let d = props::diameter_estimate(&g, 3, 11);
        assert!(d < 30, "trace diameter {d}");
    }

    #[test]
    fn social_shape() {
        let g = social(4000, 8, 0.02, 3);
        assert_eq!(g.num_vertices(), 4000);
        // Dense relative to road: avg stored degree ~= m.
        let avg = g.num_edges() as f64 / g.num_vertices() as f64;
        assert!(avg > 5.0, "avg={avg}");
        // Power-law-ish: max degree far above average.
        let max_deg = (0..g.num_vertices() as u32)
            .map(|v| g.in_degree(v) + g.out_degree(v))
            .max()
            .unwrap();
        assert!(max_deg > 100, "max_deg={max_deg}");
        // Dust creates many components but one giant.
        let wcc = props::wcc_count(&g);
        assert!(wcc > 10, "wcc={wcc}");
        // Small-world diameter on the giant component.
        let d = props::diameter_estimate(&g, 3, 13);
        assert!(d < 12, "social diameter {d}");
    }

    #[test]
    fn erdos_renyi_edge_count_near_expectation() {
        let n = 500;
        let p = 0.01;
        let g = erdos_renyi(n, p, true, 4);
        let expected = (n * (n - 1)) as f64 * p;
        let got = g.num_edges() as f64;
        assert!(
            (got - expected).abs() < expected * 0.25,
            "got {got}, expected ~{expected}"
        );
    }

    #[test]
    fn grid_chain_star_shapes() {
        let g = grid(5, 7);
        assert_eq!(g.num_vertices(), 35);
        assert_eq!(g.num_edges(), 5 * 6 + 4 * 7);
        let c = chain(10);
        assert_eq!(c.num_edges(), 9);
        assert_eq!(props::diameter_estimate(&c, 2, 1), 9);
        let s = star(11);
        assert_eq!(s.num_edges(), 10);
        assert_eq!(s.out_degree(0), 10);
    }

    #[test]
    fn random_weights_in_range() {
        let g = with_random_weights(&chain(100), 1.0, 5.0, 9);
        assert!(g.has_weights());
        for (_, _, ei) in g.edges() {
            let w = g.weight(ei);
            assert!((1.0..5.0).contains(&w));
        }
    }
}
