//! The Gopher BSP engine: manager/worker superstep loop (paper §4.2).
//!
//! Execution shape (one worker thread per partition/host, one manager):
//!
//! 1. **Load** — each worker loads its partition's sub-graphs (from a
//!    [`crate::gofs::Store`] in `run_on_store`, data-local; or handed an
//!    in-memory [`DistributedGraph`] in `run`).
//! 2. **Superstep** — worker invokes `compute` on every *active*
//!    sub-graph (not halted, or has input messages) on a core-sized
//!    thread pool; outgoing messages are aggregated per destination host
//!    and flushed over the data fabric, ending with an EOS marker per
//!    peer; the worker then drains its inbox until it has EOS from every
//!    peer (BSP delivery guarantee), and reports a *sync* to the manager.
//! 3. **Manager** — once all workers sync, folds the workers' partial
//!    aggregator vectors into the global values (the coordinator layer,
//!    paper §4.2), then decides: if nobody sent a message and every
//!    sub-graph voted to halt → *terminate*; else broadcast *resume*
//!    carrying the folded global aggregates, which programs read the
//!    next superstep via [`SubgraphContext::aggregated`].
//!
//! The route phase runs outgoing envelopes through the transport
//! [`transport::Batcher`], which folds same-destination messages with
//! the program's combiner before anything is encoded — the Giraph-style
//! communication reduction, applied at the sub-graph granularity.
//!
//! The data plane is byte-encoded even in-process so the TCP fabric and
//! the byte accounting share one code path.

use std::collections::BTreeMap;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::ckpt::{self, InboxEntry, WorkerResume};
use crate::coordinator::{Aggregators, Coordinator};
use crate::gofs::{
    AttrProjection, DistributedGraph, LoadOptions, LoadStats, PartitionAttributes,
    Store, Subgraph, SubgraphId,
};
use crate::graph::VertexId;
use crate::metrics::{CheckpointMetrics, JobMetrics, SuperstepMetrics};
use crate::util::codec::{Decoder, Encoder};
use crate::util::index::VertexIndex;
use crate::util::pool;

use super::api::{
    IncomingMessage, MsgCodec, Outgoing, SubgraphContext, SubgraphProgram,
};
use super::transport::{self, Fabric, FabricKind};

/// Engine configuration.
#[derive(Clone, Debug)]
pub struct GopherConfig {
    /// Compute threads per worker (paper testbed: 8 cores/host).
    pub cores_per_worker: usize,
    /// Data fabric between workers.
    pub fabric: FabricKind,
    /// Safety cap on supersteps.
    pub max_supersteps: usize,
    /// Flush a destination batch once it reaches this many bytes.
    pub batch_flush_bytes: usize,
    /// Fold same-destination messages with the program's combiner before
    /// they hit the wire (no-op for programs without a combiner).
    pub combiners: bool,
    /// Attribute projection for store-backed runs: which attribute
    /// slices each worker loads alongside its topology (paper §4.1's
    /// "only loads the slice it needs"). Ignored for in-memory sources.
    pub load_attributes: AttrProjection,
    /// Checkpointing: every `every` supersteps each worker snapshots
    /// its states/halted-flags/in-flight queues (plus its send log) and
    /// the manager commits the epoch (see [`crate::ckpt`]). The
    /// config's [`ckpt::CheckpointMode`] picks whether the persistence
    /// happens inside the barrier (sync) or on a background flusher
    /// thread while the next superstep computes (async).
    pub checkpoint: Option<ckpt::CheckpointConfig>,
    /// Restart after a committed epoch instead of superstep 1. The run
    /// must use the same source/partitioning as the checkpointed one.
    /// With [`ckpt::ResumePoint::confined`], only the failed worker
    /// (per the directory's marker) rebuilds its inbox from the
    /// senders' logs; everyone restores states the same way either way.
    pub resume: Option<ckpt::ResumePoint>,
    /// Failure-injection testing hook: the named worker aborts at the
    /// start of the named superstep.
    pub fail_at: Option<ckpt::FailPoint>,
    /// Live run-control handle: the manager publishes each completed
    /// superstep through it and honors a cancellation request at the
    /// next barrier (the job then errors out as cancelled). `None` for
    /// unsupervised runs; the `serve` layer attaches one per job.
    pub control: Option<crate::coordinator::RunControl>,
    /// Memory-map packed partition files on store-backed runs instead
    /// of seek+read (default true; forwarded to
    /// [`LoadOptions::mmap`]). Never affects results — pinned by the
    /// CLI smoke's mmap/no-mmap TSV comparison.
    pub mmap: bool,
    /// Resolve global→local vertex ids in the compute loop through a
    /// dense [`VertexIndex`] built at worker init (default true);
    /// `false` forces the sorted-search fallback everywhere. Either
    /// way results are identical — this is a lookup-mechanics knob,
    /// kept for A/B benchmarking and the parity tests.
    pub dense_index: bool,
    /// Precomputed per-partition, per-sub-graph vertex indexes (the
    /// resident `serve` store builds them once and shares them across
    /// jobs). Used only when `dense_index` is set and the shape
    /// matches the loaded graph; otherwise workers build their own.
    pub vertex_indexes: Option<Arc<Vec<Vec<VertexIndex>>>>,
    /// Span tracing ([`crate::obs::trace`]): when enabled, every worker
    /// records load + per-superstep compute/route/drain/barrier phase
    /// spans (and checkpoint writes), the manager records epoch
    /// commits. Default disabled: the hot path then pays one `Option`
    /// branch per would-be span and allocates nothing (pinned by the
    /// `trace_overhead` bench rows). Never result-affecting, so — like
    /// `mmap`/`dense_index` — it is excluded from the checkpoint label.
    pub trace: crate::obs::trace::Tracer,
}

impl Default for GopherConfig {
    fn default() -> Self {
        Self {
            cores_per_worker: 4,
            fabric: FabricKind::InProc,
            max_supersteps: 10_000,
            batch_flush_bytes: 256 << 10,
            combiners: true,
            load_attributes: AttrProjection::None,
            checkpoint: None,
            resume: None,
            fail_at: None,
            control: None,
            mmap: true,
            dense_index: true,
            vertex_indexes: None,
            trace: crate::obs::trace::Tracer::default(),
        }
    }
}

/// Result of a Gopher job.
pub struct RunResult<S> {
    /// Final per-sub-graph program states.
    pub states: BTreeMap<SubgraphId, S>,
    /// Per-vertex values harvested via [`SubgraphProgram::emit`] after
    /// the final superstep, sorted by global vertex id (empty for
    /// programs that keep the default no-op emit).
    pub values: Vec<(VertexId, f64)>,
    pub metrics: JobMetrics,
}

// ------------------------------------------------------------ wire format

const TAG_BATCH: u8 = 0;
const TAG_EOS: u8 = 1;

/// Batch frames carry the sending worker's id so receivers can stably
/// sort their inboxes by sender before compute — per-sender order is
/// FIFO on every fabric, so the sort makes delivery order (and thus
/// floating-point fold order) deterministic across runs. Deterministic
/// replay is what makes checkpoint recovery parity byte-exact.
fn encode_batch<M: MsgCodec>(
    sender: u32,
    envelopes: &[(u32, Option<u32>, M)],
) -> Vec<u8> {
    let mut e = Encoder::with_capacity(8 + envelopes.len() * 8);
    e.put_u8(TAG_BATCH);
    e.put_varint(sender as u64);
    e.put_varint(envelopes.len() as u64);
    for (sg_index, vertex, payload) in envelopes {
        e.put_varint(*sg_index as u64);
        match vertex {
            Some(v) => {
                e.put_u8(1);
                e.put_varint(*v as u64);
            }
            None => e.put_u8(0),
        }
        payload.encode(&mut e);
    }
    e.into_bytes()
}

type DecodedBatch<M> = (u32, Vec<(u32, Option<u32>, M)>);

fn decode_batch<M: MsgCodec>(bytes: &[u8]) -> Result<DecodedBatch<M>> {
    let mut d = Decoder::new(bytes);
    let tag = d.get_u8()?;
    if tag != TAG_BATCH {
        bail!("expected batch frame, got tag {tag}");
    }
    let sender = d.get_varint()? as u32;
    let n = d.get_varint()? as usize;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let sg_index = d.get_varint()? as u32;
        let has_vertex = d.get_u8()? != 0;
        let vertex = if has_vertex { Some(d.get_varint()? as u32) } else { None };
        let payload = M::decode(&mut d)?;
        out.push((sg_index, vertex, payload));
    }
    Ok((sender, out))
}

fn eos_frame() -> Vec<u8> {
    vec![TAG_EOS]
}

// --------------------------------------------------------- control plane

struct WorkerSync {
    worker: u32,
    /// Data messages sent this superstep (including self-sends).
    sent: u64,
    /// Encoded bytes put on the fabric this superstep.
    bytes: u64,
    /// Wall clock of this worker's compute phase, used by the manager
    /// to publish a live straggler ratio through `RunControl`.
    compute_seconds: f64,
    /// All local sub-graphs voted to halt and hold no pending messages.
    quiescent: bool,
    /// Worker failed: manager must abort the job after this superstep.
    failed: bool,
    /// Worker-local partial aggregator values for this superstep.
    agg: Vec<f64>,
}

enum ManagerCmd {
    /// Continue with the globally folded aggregator values.
    Resume(Vec<f64>),
    Terminate,
}

// ----------------------------------------------------------- worker body

struct WorkerOutput<S> {
    states: Vec<(SubgraphId, S)>,
    /// Per-vertex values from the program's `emit` hook (this worker's
    /// sub-graphs only; the driver merges and sorts).
    emitted: Vec<(VertexId, f64)>,
    per_superstep: Vec<WorkerSuperstep>,
    load: LoadStats,
}

struct WorkerSuperstep {
    /// Wall clock of this worker's whole superstep (compute + route +
    /// drain + checkpoint), measured worker-side so superstep 1 never
    /// includes load.
    wall_seconds: f64,
    compute_seconds: f64,
    unit_times: Vec<f64>,
    messages: u64,
    bytes: u64,
    active_units: u64,
    /// Messages eliminated by the combiner before encoding.
    combined: u64,
    /// Wall/bytes of this worker's checkpoint write (0 on supersteps
    /// that did not checkpoint).
    ckpt_seconds: f64,
    ckpt_bytes: u64,
}

/// Worker entry point: runs the superstep loop; on error, unblocks peers
/// (EOS) and the manager (failed sync) before surfacing the error, so a
/// failing worker aborts the job instead of deadlocking the barrier.
#[allow(clippy::too_many_arguments)]
fn worker_body<P, F>(
    program: &P,
    fabric: F,
    cfg: &GopherConfig,
    aggs: &Aggregators,
    subgraphs: Vec<Subgraph>,
    attrs: PartitionAttributes,
    load: LoadStats,
    directory: &[u32],
    writer: Option<&ckpt::CheckpointWriter>,
    flusher: Option<&ckpt::CheckpointFlusher>,
    resume: Option<WorkerResume>,
    sync_tx: Sender<WorkerSync>,
    cmd_rx: Receiver<ManagerCmd>,
) -> Result<WorkerOutput<P::State>>
where
    P: SubgraphProgram,
    F: Fabric,
{
    let me = fabric.id();
    let k = fabric.num_workers();
    match worker_loop(
        program, &fabric, cfg, aggs, subgraphs, &attrs, directory, writer, flusher,
        resume, &sync_tx, &cmd_rx,
    ) {
        Ok((states, emitted, per_superstep)) => {
            Ok(WorkerOutput { states, emitted, per_superstep, load })
        }
        Err(e) => {
            // Best-effort cleanup: peers may be blocked draining for our
            // EOS, and the manager for our sync.
            for p in 0..k as u32 {
                if p != me {
                    let _ = fabric.send(p, eos_frame());
                }
            }
            let _ = sync_tx.send(WorkerSync {
                worker: me,
                sent: 0,
                bytes: 0,
                compute_seconds: 0.0,
                quiescent: true,
                failed: true,
                agg: Vec::new(),
            });
            let _ = cmd_rx.recv(); // wait for terminate
            Err(e)
        }
    }
}

type LoopOutput<S> = (
    Vec<(SubgraphId, S)>,
    Vec<(VertexId, f64)>,
    Vec<WorkerSuperstep>,
);

#[allow(clippy::too_many_arguments)]
fn worker_loop<P, F>(
    program: &P,
    fabric: &F,
    cfg: &GopherConfig,
    aggs: &Aggregators,
    subgraphs: Vec<Subgraph>,
    attrs: &PartitionAttributes,
    directory: &[u32],
    writer: Option<&ckpt::CheckpointWriter>,
    flusher: Option<&ckpt::CheckpointFlusher>,
    resume: Option<WorkerResume>,
    sync_tx: &Sender<WorkerSync>,
    cmd_rx: &Receiver<ManagerCmd>,
) -> Result<LoopOutput<P::State>>
where
    P: SubgraphProgram,
    F: Fabric,
{
    let me = fabric.id();
    let k = fabric.num_workers();
    let n_local = subgraphs.len();

    // Compact global→local vertex indexes for the compute loop: borrow
    // the resident store's precomputed set when the shape matches
    // (serve builds them once per snapshot and shares across jobs),
    // else build here — one pass over each sorted vertex list.
    // `dense_index: false` forces the sorted-search fallback, the A/B
    // knob the parity tests exercise.
    let built: Vec<VertexIndex>;
    let indexes: &[VertexIndex] = match cfg.vertex_indexes.as_ref().filter(|pre| {
        cfg.dense_index && pre.get(me as usize).is_some_and(|v| v.len() == n_local)
    }) {
        Some(pre) => &pre[me as usize],
        None => {
            built = subgraphs
                .iter()
                .map(|sg| {
                    if cfg.dense_index {
                        VertexIndex::build(&sg.vertices)
                    } else {
                        VertexIndex::sorted(&sg.vertices)
                    }
                })
                .collect();
            &built
        }
    };

    // Fresh start, or rebuild states/halted/queues from this worker's
    // snapshot of the epoch being resumed.
    type Rebuilt<S, M> = (Vec<S>, Vec<bool>, Vec<Vec<InboxEntry<M>>>, usize, Option<Vec<f64>>);
    let (init_states, init_halted, init_inbox, start_superstep, init_globals): Rebuilt<
        P::State,
        P::Msg,
    > = match resume {
        Some(r) => {
            // The snapshot bytes were read + checksum-validated exactly
            // once by `ckpt::open_resume`; decode straight from the
            // shared buffer instead of re-reading the file per worker.
            let snap = ckpt::decode_partition::<P::State, P::Msg, _>(
                &r.bytes,
                r.epoch,
                me,
                n_local,
                |i, d| program.restore_state(&subgraphs[i], d),
            )
            .with_context(|| format!("decode checkpoint {}", r.path.display()))?;
            let queues = match &r.replay {
                // Confined recovery, dead worker: the snapshot's own
                // queues stand in for state this worker's memory lost —
                // rebuild them from the senders' logged frames instead.
                // Frames arrive sender-ordered with per-sender FIFO
                // intact, and the stable sender-sort before compute
                // normalizes them exactly as it would the snapshot
                // queues, so replay is byte-identical.
                Some(frames) => {
                    let mut queues: Vec<Vec<InboxEntry<P::Msg>>> =
                        (0..n_local).map(|_| Vec::new()).collect();
                    for frame in frames {
                        let (sender, msgs) = decode_batch::<P::Msg>(frame)?;
                        for (sgi, vertex, payload) in msgs {
                            let slot =
                                queues.get_mut(sgi as usize).with_context(|| {
                                    format!(
                                        "replayed message for unknown sub-graph \
                                         index {sgi} on worker {me}"
                                    )
                                })?;
                            slot.push(InboxEntry { sender, vertex, payload });
                        }
                    }
                    queues
                }
                None => snap.inbox,
            };
            (
                snap.states,
                snap.halted,
                queues,
                r.epoch as usize + 1,
                Some(r.globals),
            )
        }
        None => (
            subgraphs.iter().map(|sg| program.init(sg)).collect(),
            vec![false; n_local],
            (0..n_local).map(|_| Vec::new()).collect(),
            1,
            None,
        ),
    };

    // Per-sub-graph mutable cells (pool jobs touch disjoint indices; the
    // mutexes are uncontended).
    let states: Vec<Mutex<P::State>> = init_states.into_iter().map(Mutex::new).collect();
    let halted: Vec<AtomicBool> = init_halted.into_iter().map(AtomicBool::new).collect();
    let mut inbox: Vec<Vec<InboxEntry<P::Msg>>> = init_inbox;

    let mut per_superstep = Vec::new();
    let mut superstep = start_superstep;
    // Folded global aggregator values from the previous superstep's
    // barrier (None before the first barrier; restored on resume).
    let mut agg_global: Option<Vec<f64>> = init_globals;
    // Adaptive parallelism: when the previous superstep's compute was
    // negligible, thread fan-out costs more than it saves (CC/SSSP
    // supersteps after the first are sync-bound — the paper's §6.3
    // "superstep time is dominated by the synchronization overhead").
    // See EXPERIMENTS.md §Perf for the measured effect.
    const PARALLEL_THRESHOLD_SECONDS: f64 = 200e-6;
    let mut last_compute = f64::INFINITY;

    // Span recorder for this worker's lane (tid = worker id + 1; tid 0
    // is the manager). `None` when tracing is disabled, in which case
    // every would-be span below costs one `Option` branch and nothing
    // else — no clock read, no allocation.
    let rec = cfg.trace.recorder(me + 1);

    loop {
        // Failure injection (testing hook): die exactly like a killed
        // host — peers and the manager are unblocked by `worker_body`'s
        // cleanup path, and the job aborts with this error.
        if let Some(fp) = &cfg.fail_at {
            if superstep == fp.superstep && me == fp.worker {
                bail!("injected worker failure: worker {me} killed at superstep {superstep}");
            }
        }
        let t_step = Instant::now();
        // The superstep span stays open through the barrier so every
        // phase span below nests inside it (drops just before the
        // manager's verdict is applied).
        let span_step = rec
            .as_ref()
            .map(|r| r.span_n("superstep", "superstep", "superstep", superstep as f64));
        // Deliveries of the previous superstep, stably sorted by sending
        // worker (see `encode_batch`): deterministic replay.
        let queued: Vec<Vec<InboxEntry<P::Msg>>> =
            std::mem::replace(&mut inbox, (0..n_local).map(|_| Vec::new()).collect());
        let cur_inbox: Vec<Vec<IncomingMessage<P::Msg>>> = queued
            .into_iter()
            .map(|mut unit| {
                unit.sort_by_key(|m| m.sender);
                unit.into_iter()
                    .map(|m| IncomingMessage { vertex: m.vertex, payload: m.payload })
                    .collect()
            })
            .collect();
        // Active set: not halted, or has input messages (paper §4.2).
        let active: Vec<usize> = (0..n_local)
            .filter(|&i| !halted[i].load(Ordering::Relaxed) || !cur_inbox[i].is_empty())
            .collect();

        // ---- compute phase (thread pool over active sub-graphs)
        let cores = if last_compute < PARALLEL_THRESHOLD_SECONDS {
            1
        } else {
            cfg.cores_per_worker
        };
        // Each unit's compute yields (outgoing envelopes, aggregator
        // contributions); both are harvested after the pool joins.
        type UnitOut<M> = (Vec<Outgoing<M>>, Vec<f64>);
        let outs: Vec<Mutex<UnitOut<P::Msg>>> = (0..active.len())
            .map(|_| Mutex::new((Vec::new(), Vec::new())))
            .collect();
        let span_compute = rec.as_ref().map(|r| r.span("compute", "phase"));
        let t0 = Instant::now();
        let unit_times = pool::run_indexed(cores, active.len(), |j| {
            let i = active[j];
            let sg = &subgraphs[i];
            // Empty column maps collapse to None so `ctx.attrs.is_some()`
            // means "a projection loaded columns for this sub-graph".
            let unit_attrs = attrs.get(i).filter(|m| !m.is_empty());
            let mut ctx =
                SubgraphContext::new(superstep, sg, aggs, agg_global.as_deref(), unit_attrs)
                    .with_index(indexes.get(i));
            let mut state = states[i].lock().unwrap();
            program.compute(&mut state, sg, &mut ctx, &cur_inbox[i]);
            halted[i].store(ctx.halted, Ordering::Relaxed);
            *outs[j].lock().unwrap() = (ctx.out, ctx.agg_local);
        })?;
        let compute_seconds = t0.elapsed().as_secs_f64();
        last_compute = compute_seconds;
        drop(span_compute);

        // ---- route phase: batch per destination through the combining
        // transport batcher, folding aggregator partials as we harvest.
        let span_route = rec.as_ref().map(|r| r.span("route", "phase"));
        let mut sent_msgs = 0u64;
        let mut sent_bytes = 0u64;
        let mut agg_partial = aggs.identity_values();
        let mut batcher: transport::Batcher<P::Msg> =
            transport::Batcher::new(k, cfg.batch_flush_bytes, cfg.combiners);
        let combine = |a: &P::Msg, b: &P::Msg| program.combine(a, b);
        // On checkpoint supersteps, log every outgoing frame with its
        // destination: the epoch's send log is what lets a later
        // confined recovery replay the dead worker's in-flight
        // messages from the senders' side.
        let log_sends = cfg
            .checkpoint
            .as_ref()
            .is_some_and(|ck| superstep % ck.every == 0);
        let mut sendlog: Option<Vec<(u32, Vec<u8>)>> = log_sends.then(Vec::new);
        let deliver = |p: usize,
                       batch: Vec<(u32, Option<u32>, P::Msg)>,
                       inbox: &mut Vec<Vec<InboxEntry<P::Msg>>>,
                       sendlog: &mut Option<Vec<(u32, Vec<u8>)>>|
         -> Result<u64> {
            if batch.is_empty() {
                return Ok(0);
            }
            if p as u32 == me {
                // Self-delivery bypasses the fabric (but still counts).
                // The send log gets the encoded frame anyway: confined
                // replay must cover self-sent messages too.
                if let Some(log) = sendlog {
                    log.push((me, encode_batch(me, &batch)));
                }
                for (sgi, vertex, payload) in batch {
                    inbox[sgi as usize].push(InboxEntry { sender: me, vertex, payload });
                }
                return Ok(0);
            }
            let frame = encode_batch(me, &batch);
            let len = frame.len() as u64;
            if let Some(log) = sendlog {
                log.push((p as u32, frame.clone()));
            }
            fabric.send(p as u32, frame)?;
            Ok(len)
        };

        for cell in &outs {
            let guard = cell.lock().unwrap();
            let (envs, partial) = &*guard;
            aggs.fold_into(&mut agg_partial, partial);
            for out in envs.iter() {
                match out {
                    Outgoing::Direct(env) => {
                        sent_msgs += 1;
                        let p = env.target.partition as usize;
                        if let Some(batch) = batcher.push(
                            p,
                            env.target.index,
                            env.vertex,
                            env.payload.clone(),
                            &combine,
                        ) {
                            sent_bytes += deliver(p, batch, &mut inbox, &mut sendlog)?;
                        }
                    }
                    Outgoing::Broadcast(m) => {
                        for (p, &count) in directory.iter().enumerate() {
                            for idx in 0..count {
                                sent_msgs += 1;
                                if let Some(batch) =
                                    batcher.push(p, idx, None, m.clone(), &combine)
                                {
                                    sent_bytes +=
                                        deliver(p, batch, &mut inbox, &mut sendlog)?;
                                }
                            }
                        }
                    }
                }
            }
        }
        for p in 0..k {
            let batch = batcher.take(p);
            sent_bytes += deliver(p, batch, &mut inbox, &mut sendlog)?;
        }
        let combined = batcher.combined;
        // End-of-superstep markers to every peer.
        for p in 0..k as u32 {
            if p != me {
                fabric.send(p, eos_frame())?;
            }
        }
        drop(span_route);

        // ---- drain phase: collect batches until EOS from all peers
        let span_drain = rec.as_ref().map(|r| r.span("drain", "phase"));
        let mut eos_seen = 0usize;
        while eos_seen < k - 1 {
            let frame = fabric.recv()?;
            match frame.first() {
                Some(&TAG_EOS) => eos_seen += 1,
                Some(&TAG_BATCH) => {
                    let (sender, msgs) = decode_batch::<P::Msg>(&frame)?;
                    for (sgi, vertex, payload) in msgs {
                        let slot = inbox
                            .get_mut(sgi as usize)
                            .with_context(|| format!("message for unknown sub-graph index {sgi} on worker {me}"))?;
                        slot.push(InboxEntry { sender, vertex, payload });
                    }
                }
                other => bail!("bad frame tag {other:?}"),
            }
        }
        drop(span_drain);

        // ---- checkpoint phase: snapshot this worker's barrier state
        // (states after compute, halted votes, and the queues already
        // drained for superstep+1) before reporting the sync. The
        // manager commits the epoch once every worker synced cleanly.
        let mut ckpt_seconds = 0.0;
        let mut ckpt_bytes = 0u64;
        if let (Some(w), Some(ck)) = (writer, cfg.checkpoint.as_ref()) {
            if superstep % ck.every == 0 {
                let t_ck = Instant::now();
                // Snapshot the queues in their canonical (sender-sorted)
                // order: arrival interleaving across peers is the one
                // nondeterministic input left, and the consumer sorts
                // anyway, so sorting here makes identical runs write
                // identical snapshot bytes (stable sort keeps the
                // per-sender FIFO intact).
                for unit in &mut inbox {
                    unit.sort_by_key(|m| m.sender);
                }
                let encode = |compress: bool| {
                    ckpt::encode_partition(
                        superstep as u64,
                        me,
                        n_local,
                        |i, e| program.save_state(&states[i].lock().unwrap(), e),
                        |i| halted[i].load(Ordering::Relaxed),
                        &inbox,
                        compress,
                    )
                };
                let log = sendlog.take().unwrap_or_default();
                let log_bytes =
                    ckpt::encode_sendlog(superstep as u64, me, &log, ck.compress);
                match flusher {
                    // Async: the barrier pays only for the encode (the
                    // `ckpt_buffer` span is the whole remaining stall);
                    // the flusher persists on its own thread while the
                    // next superstep computes.
                    Some(f) => {
                        let _span_ckpt =
                            rec.as_ref().map(|r| r.span("ckpt_buffer", "ckpt"));
                        let snapshot = encode(ck.compress);
                        ckpt_bytes = snapshot.len() as u64;
                        f.enqueue_partition(superstep as u64, me, snapshot);
                        f.enqueue_sendlog(superstep as u64, me, log_bytes);
                    }
                    // Sync: persist (and fsync) inside the barrier.
                    None => {
                        let _span_ckpt =
                            rec.as_ref().map(|r| r.span("ckpt_write", "ckpt"));
                        let snapshot = encode(ck.compress);
                        ckpt_bytes = w.write_partition(superstep as u64, me, &snapshot)?;
                        w.write_sendlog(superstep as u64, me, &log_bytes)?;
                    }
                }
                ckpt_seconds = t_ck.elapsed().as_secs_f64();
            }
        }

        per_superstep.push(WorkerSuperstep {
            wall_seconds: t_step.elapsed().as_secs_f64(),
            compute_seconds,
            unit_times,
            messages: sent_msgs,
            bytes: sent_bytes,
            active_units: active.len() as u64,
            combined,
            ckpt_seconds,
            ckpt_bytes,
        });

        // ---- sync with the manager
        let quiescent = (0..n_local)
            .all(|i| halted[i].load(Ordering::Relaxed) && inbox[i].is_empty());
        let span_barrier = rec.as_ref().map(|r| r.span("barrier", "phase"));
        sync_tx
            .send(WorkerSync {
                worker: me,
                sent: sent_msgs,
                bytes: sent_bytes,
                compute_seconds,
                quiescent,
                failed: false,
                agg: agg_partial,
            })
            .map_err(|_| anyhow::anyhow!("manager hung up"))?;
        let cmd = cmd_rx.recv().context("manager command channel closed")?;
        drop(span_barrier);
        drop(span_step);
        match cmd {
            ManagerCmd::Resume(globals) => {
                agg_global = Some(globals);
                superstep += 1;
            }
            ManagerCmd::Terminate => break,
        }
        if superstep > cfg.max_supersteps {
            bail!("exceeded max_supersteps={}", cfg.max_supersteps);
        }
    }

    let mut out_states = Vec::with_capacity(subgraphs.len());
    let mut emitted: Vec<(VertexId, f64)> = Vec::new();
    for (sg, cell) in subgraphs.iter().zip(states) {
        let state = cell.into_inner().unwrap();
        emitted.extend(program.emit(&state, sg));
        out_states.push((sg.id, state));
    }
    Ok((out_states, emitted, per_superstep))
}

// ---------------------------------------------------------------- driver

enum PartitionSource<'a> {
    InMemory(&'a DistributedGraph),
    OnDisk(&'a Store),
}

fn run_inner<P: SubgraphProgram>(
    source: PartitionSource<'_>,
    program: &P,
    cfg: &GopherConfig,
) -> Result<RunResult<P::State>> {
    let (k, directory): (usize, Vec<u32>) = match &source {
        PartitionSource::InMemory(dg) => (
            dg.num_partitions(),
            dg.partitions.iter().map(|p| p.len() as u32).collect(),
        ),
        PartitionSource::OnDisk(store) => (
            store.meta().num_partitions as usize,
            store.meta().subgraph_counts.clone(),
        ),
    };
    anyhow::ensure!(k >= 1, "no partitions");

    // Coordinator layer: one registry shared by workers, one folding
    // coordinator owned by the manager.
    let aggs = Aggregators::new(program.aggregators());

    // Checkpoint plumbing (shared helpers — see ckpt::create_writer /
    // ckpt::open_resume): one writer shared by workers + manager, and
    // (on resume) the coordinator snapshot of the epoch being resumed.
    let writer = match &cfg.checkpoint {
        Some(ck) => {
            Some(Arc::new(ckpt::create_writer(ck, cfg.resume.as_ref(), k as u32)?))
        }
        None => None,
    };
    // Async mode: one background flusher (trace lane k+1, the first
    // after the workers') persists what workers/manager enqueue.
    let flusher = match (&writer, &cfg.checkpoint) {
        (Some(w), Some(ck)) if ck.mode == ckpt::CheckpointMode::Async => {
            Some(ckpt::CheckpointFlusher::spawn(w.clone(), &cfg.trace, k as u32 + 1)?)
        }
        _ => None,
    };
    let resume_state: Option<ckpt::ResumeState> = match &cfg.resume {
        Some(rp) => Some(ckpt::open_resume(rp, k, aggs.len())?),
        None => None,
    };
    let base_superstep = cfg.resume.as_ref().map(|r| r.epoch as usize).unwrap_or(0);

    let (sync_tx, sync_rx) = channel::<WorkerSync>();
    let mut cmd_txs: Vec<Sender<ManagerCmd>> = Vec::with_capacity(k);
    let mut cmd_rxs: Vec<Receiver<ManagerCmd>> = Vec::with_capacity(k);
    for _ in 0..k {
        let (tx, rx) = channel();
        cmd_txs.push(tx);
        cmd_rxs.push(rx);
    }

    // Build fabrics up front (TCP does its mesh handshake here).
    enum Fabrics {
        InProc(Vec<transport::InProcFabric>),
        Tcp(Vec<transport::TcpFabric>),
    }
    let fabrics = match cfg.fabric {
        FabricKind::InProc => Fabrics::InProc(transport::in_proc(k)),
        FabricKind::Tcp => Fabrics::Tcp(transport::tcp(k)?),
    };

    let result: Result<(Vec<WorkerOutput<P::State>>, JobMetrics)> =
        std::thread::scope(|scope| {
            // ---- workers
            let mut handles = Vec::with_capacity(k);
            let writer_ref = writer.as_deref();
            let flusher_ref = flusher.as_ref();
            let resume_ref = resume_state.as_ref();
            let mut spawn_worker = |p: usize, fab_any: FabricAny| {
                let sync_tx = sync_tx.clone();
                let cmd_rx = cmd_rxs.remove(0);
                let source = &source;
                let directory = &directory;
                let aggs = &aggs;
                // Per-worker resume instructions (this worker's already
                // validated snapshot bytes + the globals folded at the
                // resumed barrier).
                let worker_resume = resume_ref.map(|rs| ckpt::worker_resume(rs, p as u32));
                handles.push(scope.spawn(move || -> Result<WorkerOutput<P::State>> {
                    let t_load = Instant::now();
                    // Load span on this worker's lane; the recorder is
                    // dropped (flushed) before the superstep loop opens
                    // its own recorder for the same tid.
                    let load_rec = cfg.trace.recorder(p as u32 + 1);
                    let load_span = load_rec.as_ref().map(|r| r.span("load", "load"));
                    let loaded = match source {
                        PartitionSource::InMemory(dg) => Ok((
                            dg.partitions[p].clone(),
                            PartitionAttributes::new(),
                            LoadStats {
                                files: 0,
                                bytes: 0,
                                seconds: t_load.elapsed().as_secs_f64(),
                            },
                        )),
                        // Data-local, projection-aware load: this worker
                        // touches only its own host directory, and only
                        // the attribute slices the job declared.
                        PartitionSource::OnDisk(store) => store.load_partition_with(
                            p as u32,
                            &LoadOptions {
                                attributes: cfg.load_attributes.clone(),
                                cores: cfg.cores_per_worker,
                                mmap: cfg.mmap,
                                ..Default::default()
                            },
                        ),
                    };
                    let (subgraphs, attrs, load) = match loaded {
                        Ok(x) => x,
                        Err(e) => {
                            // Load failure happens before the first
                            // superstep: unblock peers (they will drain
                            // for our EOS) and the manager, then abort.
                            let (me, k) = match &fab_any {
                                FabricAny::InProc(f) => (f.id(), f.num_workers()),
                                FabricAny::Tcp(f) => (f.id(), f.num_workers()),
                            };
                            for peer in 0..k as u32 {
                                if peer != me {
                                    let _ = match &fab_any {
                                        FabricAny::InProc(f) => f.send(peer, eos_frame()),
                                        FabricAny::Tcp(f) => f.send(peer, eos_frame()),
                                    };
                                }
                            }
                            let _ = sync_tx.send(WorkerSync {
                                worker: me,
                                sent: 0,
                                bytes: 0,
                                compute_seconds: 0.0,
                                quiescent: true,
                                failed: true,
                                agg: Vec::new(),
                            });
                            let _ = cmd_rx.recv();
                            return Err(e);
                        }
                    };
                    drop(load_span);
                    drop(load_rec);
                    match fab_any {
                        FabricAny::InProc(f) => worker_body(
                            program, f, cfg, aggs, subgraphs, attrs, load, directory,
                            writer_ref, flusher_ref, worker_resume, sync_tx, cmd_rx,
                        ),
                        FabricAny::Tcp(f) => worker_body(
                            program, f, cfg, aggs, subgraphs, attrs, load, directory,
                            writer_ref, flusher_ref, worker_resume, sync_tx, cmd_rx,
                        ),
                    }
                }));
            };
            enum FabricAny {
                InProc(transport::InProcFabric),
                Tcp(transport::TcpFabric),
            }
            match fabrics {
                Fabrics::InProc(fs) => {
                    for (p, f) in fs.into_iter().enumerate() {
                        spawn_worker(p, FabricAny::InProc(f));
                    }
                }
                Fabrics::Tcp(fs) => {
                    for (p, f) in fs.into_iter().enumerate() {
                        spawn_worker(p, FabricAny::Tcp(f));
                    }
                }
            }
            drop(sync_tx);

            // ---- manager loop (sync barrier + coordinator fold)
            let mut coordinator = match resume_ref {
                Some(rs) => {
                    Coordinator::with_history(aggs.clone(), rs.coord.history.clone())
                }
                None => Coordinator::new(aggs.clone()),
            };
            let mut superstep = base_superstep;
            let mut commit_err: Option<anyhow::Error> = None;
            let mut cancelled = false;
            // First worker that reported failure this run (recorded in
            // the checkpoint dir's FAILED_WORKER marker at abort so a
            // later --confined-recovery resume knows whom to rebuild).
            let mut failed_worker: Option<u32> = None;
            // Manager lane spans (tid 0) + cumulative counters for the
            // live-progress publication below.
            let mgr_rec = cfg.trace.recorder(0);
            let mut cum_msgs = 0u64;
            let mut cum_bytes = 0u64;
            loop {
                let mut sent_total = 0u64;
                let mut bytes_total = 0u64;
                let mut computes = vec![0.0f64; k];
                let mut all_quiescent = true;
                let mut any_failed = false;
                // Indexed by worker id, so the global fold order is
                // independent of sync arrival order (deterministic
                // replay; arbitrary-order folds would round f64 sums
                // differently run to run).
                let mut partials: Vec<Vec<f64>> = vec![Vec::new(); k];
                let mut seen = 0usize;
                while seen < k {
                    match sync_rx.recv() {
                        Ok(s) => {
                            sent_total += s.sent;
                            bytes_total += s.bytes;
                            computes[s.worker as usize] = s.compute_seconds;
                            all_quiescent &= s.quiescent;
                            if s.failed {
                                any_failed = true;
                                failed_worker.get_or_insert(s.worker);
                            }
                            partials[s.worker as usize] = s.agg;
                            seen += 1;
                        }
                        Err(_) => {
                            // A worker died: surface its error via join.
                            for h in handles {
                                match h.join() {
                                    Ok(Ok(_)) => {}
                                    Ok(Err(e)) => return Err(e),
                                    Err(p) => std::panic::resume_unwind(p),
                                }
                            }
                            bail!("worker exited mid-superstep without error");
                        }
                    }
                }
                superstep += 1;
                let globals = coordinator.fold_superstep(&partials);
                // Commit the epoch before workers proceed
                // (barrier-synchronous checkpointing): every worker
                // wrote its snapshot before syncing, so a clean barrier
                // means the epoch is complete.
                if let (Some(w), Some(ck)) = (&writer, &cfg.checkpoint) {
                    if superstep % ck.every == 0 && !any_failed {
                        let coord_bytes = ckpt::encode_coordinator(
                            superstep as u64,
                            aggs.len(),
                            coordinator.history(),
                            ck.compress,
                        );
                        match &flusher {
                            // Async: every worker enqueued its snapshot
                            // before syncing, so the FIFO commit lands
                            // after them; an earlier flush error
                            // surfaces here, at the next barrier.
                            Some(f) => {
                                f.enqueue_commit(superstep as u64, coord_bytes);
                                if let Some(e) = f.take_error() {
                                    commit_err = Some(e);
                                }
                            }
                            None => {
                                let _span_commit = mgr_rec
                                    .as_ref()
                                    .map(|r| r.span("ckpt_commit", "ckpt"));
                                if let Err(e) = w.commit(superstep as u64, &coord_bytes)
                                {
                                    commit_err = Some(e);
                                }
                            }
                        }
                    }
                }
                // Run-control hook: publish progress for external
                // observers and honor a cancellation request — workers
                // are terminated at this barrier, so a cancelled job
                // stops within one superstep of the request.
                cum_msgs += sent_total;
                cum_bytes += bytes_total;
                if let Some(ctl) = &cfg.control {
                    ctl.publish_superstep(superstep);
                    let straggler = SuperstepMetrics {
                        partition_compute_seconds: computes,
                        ..Default::default()
                    }
                    .straggler_ratio();
                    ctl.publish_progress(cum_msgs, cum_bytes, straggler);
                    ctl.publish_ckpt_inflight(
                        flusher.as_ref().map_or(0, |f| f.inflight()),
                    );
                    cancelled = ctl.is_cancelled();
                }
                let done = (all_quiescent && sent_total == 0)
                    || any_failed
                    || commit_err.is_some()
                    || cancelled;
                if done && any_failed {
                    if let (Some(w), Some(fw)) = (&writer, failed_worker) {
                        // Best-effort: a missing marker only downgrades a
                        // later resume from confined to global; a stale
                        // one is harmless (replay equals the snapshot
                        // queues), so neither failure mode is worth
                        // aborting the abort for.
                        let _ = w.write_failed_marker(fw);
                    }
                }
                for tx in &cmd_txs {
                    // A worker that already errored may have dropped its rx.
                    let _ = tx.send(if done {
                        ManagerCmd::Terminate
                    } else {
                        ManagerCmd::Resume(globals.clone())
                    });
                }
                if done {
                    break;
                }
            }

            // ---- join workers, merge metrics
            let mut outputs = Vec::with_capacity(k);
            for h in handles {
                match h.join() {
                    Ok(Ok(out)) => outputs.push(out),
                    Ok(Err(e)) => return Err(e),
                    Err(p) => std::panic::resume_unwind(p),
                }
            }
            if let Some(e) = commit_err {
                // The writer's own context already names the epoch/file.
                return Err(e);
            }
            if cancelled {
                bail!("job cancelled at superstep {superstep}");
            }
            // Workers superstep in lockstep (the barrier), so every
            // output holds the same number of per-superstep records.
            let n_steps =
                outputs.first().map(|o| o.per_superstep.len()).unwrap_or(0);
            let mut metrics = JobMetrics {
                load_seconds: outputs
                    .iter()
                    .map(|o| o.load.seconds)
                    .fold(0.0, f64::max),
                load_bytes: outputs.iter().map(|o| o.load.bytes).sum(),
                load_files: outputs.iter().map(|o| o.load.files).sum(),
                ..Default::default()
            };
            for s in 0..n_steps {
                let mut sm = SuperstepMetrics::default();
                let mut ck_seconds = 0.0f64;
                let mut ck_bytes = 0u64;
                for out in &outputs {
                    let ws = &out.per_superstep[s];
                    sm.partition_compute_seconds.push(ws.compute_seconds);
                    sm.unit_times.push(ws.unit_times.clone());
                    sm.messages += ws.messages;
                    sm.bytes += ws.bytes;
                    sm.active_units += ws.active_units;
                    sm.combined_messages += ws.combined;
                    // Superstep wall = the slowest worker's own clock
                    // (starts after load, so `makespan_seconds` never
                    // double-counts `load_seconds` — see metrics docs).
                    sm.wall_seconds = sm.wall_seconds.max(ws.wall_seconds);
                    // Checkpoint wall = slowest worker's write (writes
                    // run concurrently); bytes are summed.
                    ck_seconds = ck_seconds.max(ws.ckpt_seconds);
                    ck_bytes += ws.ckpt_bytes;
                }
                if ck_bytes > 0 {
                    metrics.checkpoints.push(CheckpointMetrics {
                        superstep: base_superstep + s + 1,
                        seconds: ck_seconds,
                        bytes: ck_bytes,
                    });
                }
                metrics.compute_seconds += sm.wall_seconds;
                metrics.supersteps.push(sm);
            }
            metrics.aggregators = coordinator.into_traces();
            metrics.ckpt_prune_failures =
                writer.as_ref().map_or(0, |w| w.pending_prune_count() as u64);
            Ok((outputs, metrics))
        });
    // Always drain + join the flusher, then let a worker/manager error
    // outrank a flush error (the flush error for a failed run is
    // usually downstream noise of the same fault).
    let flush_result = match flusher {
        Some(f) => f.finish(),
        None => Ok(()),
    };
    let (outputs, metrics) = result?;
    flush_result.context("background checkpoint flush")?;
    if let Some(w) = &writer {
        // Clean completion: drop any failure marker left by an earlier
        // run of this directory.
        w.clear_failed_marker();
    }

    let mut states = BTreeMap::new();
    let mut values: Vec<(VertexId, f64)> = Vec::new();
    for out in outputs {
        values.extend(out.emitted);
        for (id, st) in out.states {
            states.insert(id, st);
        }
    }
    values.sort_by_key(|&(v, _)| v);
    Ok(RunResult { states, values, metrics })
}

/// Run a program over an in-memory distributed graph.
pub fn run<P: SubgraphProgram>(
    dg: &DistributedGraph,
    program: &P,
    cfg: &GopherConfig,
) -> Result<RunResult<P::State>> {
    run_inner(PartitionSource::InMemory(dg), program, cfg)
}

/// Run a program over an on-disk GoFS store (data-local loading; load
/// time lands in `metrics.load_seconds` — the Fig 4(b) quantity).
pub fn run_on_store<P: SubgraphProgram>(
    store: &Store,
    program: &P,
    cfg: &GopherConfig,
) -> Result<RunResult<P::State>> {
    run_inner(PartitionSource::OnDisk(store), program, cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gofs::subgraph::discover;
    use crate::graph::csr::Graph;
    use crate::graph::gen;
    use crate::partition::{Partitioner, Partitioning, RangePartitioner};

    /// Max-value program (paper Algorithm 2): the canonical example.
    struct MaxValue;

    impl SubgraphProgram for MaxValue {
        type Msg = f32;
        type State = f32;

        fn init(&self, _sg: &Subgraph) -> f32 {
            f32::NEG_INFINITY
        }

        fn compute(
            &self,
            state: &mut f32,
            sg: &Subgraph,
            ctx: &mut SubgraphContext<'_, f32>,
            msgs: &[IncomingMessage<f32>],
        ) {
            let mut changed = false;
            if ctx.superstep() == 1 {
                // Local max over the sub-graph's vertex "values" (use the
                // global vertex id as the value, like connected components).
                *state = sg.vertices.iter().map(|&v| v as f32).fold(f32::NEG_INFINITY, f32::max);
                changed = true;
            }
            for m in msgs {
                if m.payload > *state {
                    *state = m.payload;
                    changed = true;
                }
            }
            if changed {
                ctx.send_to_all_neighbors(*state);
            } else {
                ctx.vote_to_halt();
            }
        }

        fn combine(&self, a: &f32, b: &f32) -> Option<f32> {
            Some(a.max(*b))
        }
    }

    fn run_max(
        g: &Graph,
        parts: Partitioning,
        fabric: FabricKind,
    ) -> (RunResult<f32>, usize) {
        let dg = discover(g, &parts).unwrap();
        let cfg = GopherConfig { fabric, cores_per_worker: 2, ..Default::default() };
        let res = run(&dg, &MaxValue, &cfg).unwrap();
        let steps = res.metrics.num_supersteps();
        (res, steps)
    }

    #[test]
    fn max_value_converges_chain() {
        let g = gen::chain(20);
        let parts = RangePartitioner.partition(&g, 4);
        let (res, steps) = run_max(&g, parts, FabricKind::InProc);
        for (_, &v) in &res.states {
            assert_eq!(v, 19.0);
        }
        // 4 connected sub-graphs in a row: value 19 must flow 3 meta-hops
        // + 1 final quiescent superstep.
        assert!(steps >= 4 && steps <= 6, "steps={steps}");
    }

    #[test]
    fn max_value_over_tcp_matches_in_proc() {
        let g = gen::road(12, 0.92, 0.02, 11);
        let parts = RangePartitioner.partition(&g, 3);
        let (a, _) = run_max(&g, parts.clone(), FabricKind::InProc);
        let (b, _) = run_max(&g, parts, FabricKind::Tcp);
        let va: Vec<f32> = a.states.values().cloned().collect();
        let vb: Vec<f32> = b.states.values().cloned().collect();
        assert_eq!(va, vb);
    }

    #[test]
    fn single_partition_no_messages() {
        let g = gen::chain(10);
        let parts = Partitioning::new(1, vec![0; 10]);
        let (res, steps) = run_max(&g, parts, FabricKind::InProc);
        assert_eq!(steps, 2); // compute, then quiescent vote
        assert_eq!(*res.states.values().next().unwrap(), 9.0);
        // Messages only to neighbours; one sub-graph has none.
        assert_eq!(res.metrics.total_messages(), 0);
    }

    #[test]
    fn disconnected_subgraphs_halt_independently() {
        // Two separate chains on two partitions each.
        let mut edges = Vec::new();
        for i in 0..9u32 {
            edges.push((i, i + 1));
        }
        for i in 10..19u32 {
            edges.push((i, i + 1));
        }
        let g = Graph::from_edges(20, &edges, None, false).unwrap();
        let assign = (0..20u32).map(|v| if v < 10 { v / 5 } else { 2 + (v - 10) / 5 }).collect();
        let parts = Partitioning::new(4, assign);
        let (res, _) = run_max(&g, parts, FabricKind::InProc);
        for (id, &v) in &res.states {
            let expect = if id.partition < 2 { 9.0 } else { 19.0 };
            assert_eq!(v, expect, "sub-graph {id}");
        }
    }

    #[test]
    fn metrics_shape_consistent() {
        let g = gen::grid(8, 8);
        let parts = RangePartitioner.partition(&g, 2);
        let (res, steps) = run_max(&g, parts, FabricKind::InProc);
        assert_eq!(res.metrics.supersteps.len(), steps);
        for sm in &res.metrics.supersteps {
            assert_eq!(sm.partition_compute_seconds.len(), 2);
            assert_eq!(sm.unit_times.len(), 2);
        }
        assert!(res.metrics.total_bytes() > 0);
        assert!(res.metrics.makespan_seconds() > 0.0);
    }

    /// Broadcast program: superstep 1, sub-graph P0S0 broadcasts; all
    /// sub-graphs record receipt at superstep 2.
    struct Broadcaster;
    impl SubgraphProgram for Broadcaster {
        type Msg = u32;
        type State = Vec<u32>;
        fn init(&self, _sg: &Subgraph) -> Vec<u32> {
            Vec::new()
        }
        fn compute(
            &self,
            state: &mut Vec<u32>,
            sg: &Subgraph,
            ctx: &mut SubgraphContext<'_, u32>,
            msgs: &[IncomingMessage<u32>],
        ) {
            if ctx.superstep() == 1 && sg.id.partition == 0 && sg.id.index == 0 {
                ctx.send_to_all_subgraphs(77);
            }
            for m in msgs {
                state.push(m.payload);
            }
            ctx.vote_to_halt();
        }
    }

    #[test]
    fn broadcast_reaches_every_subgraph() {
        let g = gen::road(10, 0.9, 0.02, 13);
        let parts = RangePartitioner.partition(&g, 3);
        let dg = discover(&g, &parts).unwrap();
        let res = run(&dg, &Broadcaster, &GopherConfig::default()).unwrap();
        assert!(res.states.len() >= 3);
        for (id, st) in &res.states {
            assert_eq!(st, &vec![77], "sub-graph {id} missed the broadcast");
        }
    }

    /// Vertex-targeted message program: P0S0 sends to a specific vertex.
    struct VertexPing {
        target_sg: SubgraphId,
        target_vertex: u32,
    }
    impl SubgraphProgram for VertexPing {
        type Msg = u32;
        type State = Vec<(Option<u32>, u32)>;
        fn init(&self, _sg: &Subgraph) -> Self::State {
            Vec::new()
        }
        fn compute(
            &self,
            state: &mut Self::State,
            sg: &Subgraph,
            ctx: &mut SubgraphContext<'_, u32>,
            msgs: &[IncomingMessage<u32>],
        ) {
            if ctx.superstep() == 1 && sg.id.partition == 0 && sg.id.index == 0 {
                ctx.send_to_subgraph_vertex(self.target_sg, self.target_vertex, 5);
            }
            for m in msgs {
                state.push((m.vertex, m.payload));
            }
            ctx.vote_to_halt();
        }
    }

    #[test]
    fn vertex_targeted_delivery() {
        let g = gen::chain(8);
        let parts = Partitioning::new(2, vec![0, 0, 0, 0, 1, 1, 1, 1]);
        let dg = discover(&g, &parts).unwrap();
        let target = dg.partitions[1][0].id;
        let prog = VertexPing { target_sg: target, target_vertex: 6 };
        let res = run(&dg, &prog, &GopherConfig::default()).unwrap();
        assert_eq!(res.states[&target], vec![(Some(6), 5)]);
    }

    #[test]
    fn combiners_cut_bytes_without_changing_results() {
        // Star split by range: worker 1 holds 10 singleton sub-graphs
        // whose superstep-1 messages all target the hub sub-graph on
        // worker 0 — guaranteed cross-worker combining for MaxValue.
        let g = gen::star(20);
        let parts = RangePartitioner.partition(&g, 2);
        let dg = discover(&g, &parts).unwrap();
        let on = run(&dg, &MaxValue, &GopherConfig::default()).unwrap();
        let off_cfg = GopherConfig { combiners: false, ..Default::default() };
        let off = run(&dg, &MaxValue, &off_cfg).unwrap();
        let a: Vec<f32> = on.states.values().cloned().collect();
        let b: Vec<f32> = off.states.values().cloned().collect();
        assert_eq!(a, b);
        assert!(a.iter().all(|&v| v == 19.0));
        assert_eq!(off.metrics.total_combined(), 0);
        assert!(on.metrics.total_combined() > 0, "no combining happened");
        assert!(
            on.metrics.total_bytes() < off.metrics.total_bytes(),
            "combined run must ship fewer bytes: {} vs {}",
            on.metrics.total_bytes(),
            off.metrics.total_bytes()
        );
    }

    /// Registers a Sum aggregator counting active sub-graphs; every
    /// sub-graph keeps itself alive with a self-send until the global
    /// count has been observed for `stop_after` supersteps.
    struct CountedRounds {
        stop_after: usize,
    }

    impl SubgraphProgram for CountedRounds {
        type Msg = ();
        type State = ();

        fn init(&self, _sg: &Subgraph) {}

        fn aggregators(&self) -> Vec<crate::coordinator::AggregatorSpec> {
            vec![crate::coordinator::AggregatorSpec::new(
                "active",
                crate::coordinator::AggOp::Sum,
            )]
        }

        fn compute(
            &self,
            _state: &mut (),
            sg: &Subgraph,
            ctx: &mut SubgraphContext<'_, ()>,
            _msgs: &[IncomingMessage<()>],
        ) {
            let slot = ctx.aggregator("active").expect("registered");
            ctx.aggregate(slot, 1.0);
            if ctx.superstep() == 1 {
                // Aggregator visibility: nothing folded before barrier 1.
                assert_eq!(ctx.aggregated(slot), None);
            } else {
                // Every sub-graph was active every previous superstep.
                assert!(ctx.aggregated(slot).is_some());
            }
            if ctx.superstep() >= self.stop_after {
                ctx.vote_to_halt();
            } else {
                ctx.send_to_subgraph(sg.id, ());
            }
        }
    }

    #[test]
    fn aggregators_fold_across_workers_and_trace_in_metrics() {
        let g = gen::road(10, 0.9, 0.02, 17);
        let parts = RangePartitioner.partition(&g, 3);
        let dg = discover(&g, &parts).unwrap();
        let n_sg = dg.num_subgraphs() as f64;
        let res = run(&dg, &CountedRounds { stop_after: 4 }, &GopherConfig::default())
            .unwrap();
        assert_eq!(res.metrics.num_supersteps(), 4);
        let trace = res.metrics.aggregator("active").expect("trace recorded");
        assert_eq!(trace.values.len(), 4);
        for v in &trace.values {
            assert_eq!(*v, n_sg, "every sub-graph contributes 1 per superstep");
        }
    }

    #[test]
    fn aggregators_fold_over_tcp_fabric_too() {
        let g = gen::chain(9);
        let parts = RangePartitioner.partition(&g, 3);
        let dg = discover(&g, &parts).unwrap();
        let cfg = GopherConfig { fabric: FabricKind::Tcp, ..Default::default() };
        let res = run(&dg, &CountedRounds { stop_after: 3 }, &cfg).unwrap();
        let trace = res.metrics.aggregator("active").expect("trace recorded");
        assert_eq!(trace.values.len(), 3);
        assert!(trace.values.iter().all(|&v| v == dg.num_subgraphs() as f64));
    }

    #[test]
    fn max_supersteps_enforced() {
        /// Never halts, always messages.
        struct Chatty;
        impl SubgraphProgram for Chatty {
            type Msg = ();
            type State = ();
            fn init(&self, _sg: &Subgraph) {}
            fn compute(
                &self,
                _state: &mut (),
                _sg: &Subgraph,
                ctx: &mut SubgraphContext<'_, ()>,
                _msgs: &[IncomingMessage<()>],
            ) {
                ctx.send_to_all_neighbors(());
            }
        }
        let g = gen::chain(6);
        let parts = Partitioning::new(2, vec![0, 0, 0, 1, 1, 1]);
        let dg = discover(&g, &parts).unwrap();
        let cfg = GopherConfig { max_supersteps: 5, ..Default::default() };
        assert!(run(&dg, &Chatty, &cfg).is_err());
    }

    /// Records whether the projected "rank" attribute column was visible
    /// in compute (and its length).
    struct AttrProbe;
    impl SubgraphProgram for AttrProbe {
        type Msg = ();
        type State = Option<usize>;
        fn init(&self, _sg: &Subgraph) -> Option<usize> {
            None
        }
        fn compute(
            &self,
            state: &mut Option<usize>,
            _sg: &Subgraph,
            ctx: &mut SubgraphContext<'_, ()>,
            _msgs: &[IncomingMessage<()>],
        ) {
            *state = ctx.attribute("rank").map(|col| col.len());
            ctx.vote_to_halt();
        }
    }

    #[test]
    fn projected_attributes_reach_compute() {
        let g = gen::road(10, 0.9, 0.02, 23);
        let parts = RangePartitioner.partition(&g, 2);
        let root = std::env::temp_dir()
            .join("goffish_engine_tests")
            .join(format!("attr_probe_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&root);
        let (store, dg) = Store::create(&root, "g", &g, &parts).unwrap();
        for sg in dg.subgraphs() {
            let vals: Vec<f32> = sg.vertices.iter().map(|&v| v as f32).collect();
            store.write_attribute(sg.id, "rank", &vals).unwrap();
        }

        // Without a projection the column never loads.
        let res = run_on_store(&store, &AttrProbe, &GopherConfig::default()).unwrap();
        assert!(res.states.values().all(|s| s.is_none()));
        let bytes_unprojected = res.metrics.load_bytes;

        // With the projection every sub-graph sees its aligned column,
        // and the load path read strictly more bytes (the extra slices).
        let cfg = GopherConfig {
            load_attributes: AttrProjection::Only(vec!["rank".into()]),
            ..Default::default()
        };
        let res = run_on_store(&store, &AttrProbe, &cfg).unwrap();
        for (id, state) in &res.states {
            assert_eq!(
                *state,
                Some(dg.subgraph(*id).num_vertices()),
                "sub-graph {id} missing its projected column"
            );
        }
        assert!(res.metrics.load_bytes > bytes_unprojected);
    }
}
