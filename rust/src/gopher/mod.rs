//! Gopher — the sub-graph centric BSP execution engine (paper §4.2).
//!
//! One *worker* per partition/host, one *manager*. Each superstep every
//! worker invokes the user's [`api::SubgraphProgram::compute`] on its
//! active sub-graphs using a core-sized thread pool, batches outgoing
//! messages per destination host, flushes them over the data fabric
//! ([`transport`]), and then runs the sync/resume/terminate control
//! protocol with the manager. Messages are always *encoded* on the
//! fabric (the in-process fabric too) so byte accounting is honest and
//! the TCP fabric is exercised by the same code path.
//!
//! The coordinator layer rides the same barrier: programs register
//! global aggregators ([`crate::coordinator`]) that workers report into
//! at sync and the manager folds and re-broadcasts with *resume*, and
//! may define a message combiner that the transport batching path uses
//! to fold same-destination messages before they are encoded.

pub mod api;
pub mod transport;
pub mod engine;

pub use api::{IncomingMessage, MsgCodec, SubgraphContext, SubgraphProgram};
pub use engine::{run, run_on_store, GopherConfig, RunResult};
pub use transport::FabricKind;
