//! Data fabrics: how worker-to-worker message batches travel.
//!
//! The engine talks to a [`Fabric`]: `send(to, bytes)` delivers an opaque
//! batch to peer `to`; `recv()` blocks for the next batch addressed to
//! this worker. Two implementations:
//!
//! * [`InProcFabric`] — `std::sync::mpsc` channels (the default; models
//!   the Floe dataflow channels of the paper at zero syscall cost).
//! * [`TcpFabric`] — real loopback TCP sockets with length-prefixed
//!   frames, one acceptor + k-1 outbound connections per worker. This is
//!   the fabric shape the paper's deployment used (workers on separate
//!   hosts exchanging batches over Ethernet).
//!
//! Batches are already-encoded byte vectors; the engine handles batching
//! policy, EOS markers and accounting.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Mutex;

use anyhow::{bail, Context, Result};

/// Which fabric to run a job on.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum FabricKind {
    #[default]
    InProc,
    Tcp,
}

/// A worker's handle onto the data fabric.
pub trait Fabric: Send {
    /// Deliver an opaque batch to worker `to`.
    fn send(&self, to: u32, bytes: Vec<u8>) -> Result<()>;
    /// Block until the next batch arrives.
    fn recv(&self) -> Result<Vec<u8>>;
    /// This worker's id.
    fn id(&self) -> u32;
    /// Number of workers on the fabric.
    fn num_workers(&self) -> usize;
}

// ------------------------------------------------------------- in-process

/// Build a k-worker in-process fabric.
pub fn in_proc(k: usize) -> Vec<InProcFabric> {
    let mut senders = Vec::with_capacity(k);
    let mut receivers = Vec::with_capacity(k);
    for _ in 0..k {
        let (tx, rx) = channel::<Vec<u8>>();
        senders.push(tx);
        receivers.push(rx);
    }
    receivers
        .into_iter()
        .enumerate()
        .map(|(i, rx)| InProcFabric {
            id: i as u32,
            peers: senders.clone(),
            inbox: rx,
        })
        .collect()
}

pub struct InProcFabric {
    id: u32,
    peers: Vec<Sender<Vec<u8>>>,
    inbox: Receiver<Vec<u8>>,
}

impl Fabric for InProcFabric {
    fn send(&self, to: u32, bytes: Vec<u8>) -> Result<()> {
        self.peers[to as usize]
            .send(bytes)
            .map_err(|_| anyhow::anyhow!("peer {to} hung up"))
    }

    fn recv(&self) -> Result<Vec<u8>> {
        self.inbox.recv().context("fabric channel closed")
    }

    fn id(&self) -> u32 {
        self.id
    }

    fn num_workers(&self) -> usize {
        self.peers.len()
    }
}

// -------------------------------------------------------------------- tcp

/// Build a k-worker loopback TCP fabric. Each worker gets a listener on
/// an OS-assigned port; a full mesh of connections is established before
/// returning. Frames are `u32-le length || payload`.
pub fn tcp(k: usize) -> Result<Vec<TcpFabric>> {
    // Bind all listeners first so every address is known.
    let listeners: Vec<TcpListener> = (0..k)
        .map(|_| TcpListener::bind("127.0.0.1:0").context("bind"))
        .collect::<Result<_>>()?;
    let addrs: Vec<std::net::SocketAddr> =
        listeners.iter().map(|l| l.local_addr().unwrap()).collect();

    // Connect the full mesh: worker i dials every j (including none to
    // itself). Accepted sockets are matched to dialers by a hello byte
    // carrying the dialer id.
    let mut outs: Vec<Vec<Option<TcpStream>>> = (0..k).map(|_| (0..k).map(|_| None).collect()).collect();
    let mut ins: Vec<Vec<Option<TcpStream>>> = (0..k).map(|_| (0..k).map(|_| None).collect()).collect();

    std::thread::scope(|scope| -> Result<()> {
        // Acceptor threads.
        let mut handles = Vec::new();
        for (i, listener) in listeners.iter().enumerate() {
            handles.push(scope.spawn(move || -> Result<Vec<(u32, TcpStream)>> {
                let mut got = Vec::new();
                for _ in 0..k - 1 {
                    let (mut s, _) = listener.accept().context("accept")?;
                    let mut hello = [0u8; 4];
                    s.read_exact(&mut hello).context("hello")?;
                    got.push((u32::from_le_bytes(hello), s));
                }
                let _ = i;
                Ok(got)
            }));
        }
        // Dial from the scope's main thread.
        for i in 0..k {
            for (j, addr) in addrs.iter().enumerate() {
                if i == j {
                    continue;
                }
                let mut s = TcpStream::connect(addr).context("connect")?;
                s.set_nodelay(true).ok();
                s.write_all(&(i as u32).to_le_bytes()).context("send hello")?;
                outs[i][j] = Some(s);
            }
        }
        for (j, h) in handles.into_iter().enumerate() {
            for (from, s) in h.join().expect("acceptor panicked")? {
                ins[j][from as usize] = Some(s);
            }
        }
        Ok(())
    })?;

    // Each worker: spawn one reader thread per inbound socket, funneling
    // into a single mpsc inbox.
    let mut fabrics = Vec::with_capacity(k);
    for (i, in_row) in ins.into_iter().enumerate() {
        let (tx, rx) = channel::<Result<Vec<u8>>>();
        for stream in in_row.into_iter().flatten() {
            let tx = tx.clone();
            std::thread::spawn(move || {
                let mut stream = stream;
                loop {
                    match read_frame(&mut stream) {
                        Ok(Some(frame)) => {
                            if tx.send(Ok(frame)).is_err() {
                                return;
                            }
                        }
                        Ok(None) => return, // clean EOF
                        Err(e) => {
                            let _ = tx.send(Err(e));
                            return;
                        }
                    }
                }
            });
        }
        fabrics.push(TcpFabric {
            id: i as u32,
            outs: outs[i]
                .iter_mut()
                .map(|o| o.take().map(Mutex::new))
                .collect(),
            inbox: rx,
            k,
        });
    }
    Ok(fabrics)
}

fn read_frame(s: &mut TcpStream) -> Result<Option<Vec<u8>>> {
    let mut len = [0u8; 4];
    match s.read_exact(&mut len) {
        Ok(()) => {}
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Ok(None),
        Err(e) => return Err(e.into()),
    }
    let n = u32::from_le_bytes(len) as usize;
    let mut buf = vec![0u8; n];
    s.read_exact(&mut buf).context("frame body")?;
    Ok(Some(buf))
}

pub struct TcpFabric {
    id: u32,
    outs: Vec<Option<Mutex<TcpStream>>>,
    inbox: Receiver<Result<Vec<u8>>>,
    k: usize,
}

impl Fabric for TcpFabric {
    fn send(&self, to: u32, bytes: Vec<u8>) -> Result<()> {
        let Some(sock) = &self.outs[to as usize] else {
            bail!("no socket to worker {to} (self-send goes via local buffer)");
        };
        let mut s = sock.lock().unwrap();
        s.write_all(&(bytes.len() as u32).to_le_bytes())?;
        s.write_all(&bytes)?;
        Ok(())
    }

    fn recv(&self) -> Result<Vec<u8>> {
        self.inbox.recv().context("tcp inbox closed")?
    }

    fn id(&self) -> u32 {
        self.id
    }

    fn num_workers(&self) -> usize {
        self.k
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn exercise(fabrics: Vec<impl Fabric + 'static>) {
        let k = fabrics.len();
        std::thread::scope(|scope| {
            for f in fabrics {
                scope.spawn(move || {
                    let me = f.id();
                    // Send one tagged batch to every peer.
                    for to in 0..k as u32 {
                        if to != me {
                            f.send(to, vec![me as u8, to as u8, 0xAB]).unwrap();
                        }
                    }
                    // Receive k-1 batches addressed to me.
                    for _ in 0..k - 1 {
                        let b = f.recv().unwrap();
                        assert_eq!(b.len(), 3);
                        assert_eq!(b[1], me as u8, "batch misrouted");
                        assert_eq!(b[2], 0xAB);
                    }
                });
            }
        });
    }

    #[test]
    fn in_proc_mesh_routes_correctly() {
        exercise(in_proc(4));
    }

    #[test]
    fn tcp_mesh_routes_correctly() {
        exercise(tcp(3).unwrap());
    }

    #[test]
    fn tcp_large_frame() {
        let fabrics = tcp(2).unwrap();
        let payload = vec![0x5Au8; 1 << 20];
        let expect = payload.clone();
        let mut it = fabrics.into_iter();
        let a = it.next().unwrap();
        let b = it.next().unwrap();
        std::thread::scope(|scope| {
            scope.spawn(move || a.send(1, payload).unwrap());
            let got = b.recv().unwrap();
            assert_eq!(got, expect);
        });
    }

    #[test]
    fn in_proc_ids_and_size() {
        let f = in_proc(5);
        assert_eq!(f.len(), 5);
        for (i, fab) in f.iter().enumerate() {
            assert_eq!(fab.id(), i as u32);
            assert_eq!(fab.num_workers(), 5);
        }
    }
}
