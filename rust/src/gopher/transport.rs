//! Data fabrics: how worker-to-worker message batches travel.
//!
//! The engine talks to a [`Fabric`]: `send(to, bytes)` delivers an opaque
//! batch to peer `to`; `recv()` blocks for the next batch addressed to
//! this worker. Two implementations:
//!
//! * [`InProcFabric`] — `std::sync::mpsc` channels (the default; models
//!   the Floe dataflow channels of the paper at zero syscall cost).
//! * [`TcpFabric`] — real loopback TCP sockets with length-prefixed
//!   frames, one acceptor + k-1 outbound connections per worker. This is
//!   the fabric shape the paper's deployment used (workers on separate
//!   hosts exchanging batches over Ethernet).
//!
//! Batches are already-encoded byte vectors; the engine handles EOS
//! markers and byte accounting, while [`Batcher`] implements the
//! batching *policy*: per-destination accumulation with optional
//! Giraph-style message combining (fold same-destination messages
//! before the `batch_flush_bytes` flush ever encodes them).

use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Mutex;

use anyhow::{bail, Context, Result};

/// Which fabric to run a job on.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum FabricKind {
    #[default]
    InProc,
    Tcp,
}

/// A worker's handle onto the data fabric.
pub trait Fabric: Send {
    /// Deliver an opaque batch to worker `to`.
    fn send(&self, to: u32, bytes: Vec<u8>) -> Result<()>;
    /// Block until the next batch arrives.
    fn recv(&self) -> Result<Vec<u8>>;
    /// This worker's id.
    fn id(&self) -> u32;
    /// Number of workers on the fabric.
    fn num_workers(&self) -> usize;
}

// --------------------------------------------------------------- batching

/// An outgoing envelope before encoding: destination sub-graph index on
/// the target worker, optional target vertex, payload.
pub(crate) type PendingEnvelope<M> = (u32, Option<u32>, M);

/// Rough encoded size of one envelope (varints + small payload): the
/// flush threshold converts `batch_flush_bytes` into an envelope count.
const ENVELOPE_BYTES_ESTIMATE: usize = 16;

/// Per-destination batch accumulator with optional message combining.
///
/// The engine pushes every outgoing envelope through here. When
/// combining is on, an envelope whose `(sub-graph, vertex)` key already
/// has a pending envelope for the same destination worker is folded
/// into it via the program's combiner — so the wire (and the local
/// inbox) sees one message where Giraph without a combiner would see
/// many. `push` returns a full batch once a destination crosses the
/// flush threshold; `take` drains what remains at superstep end.
pub(crate) struct Batcher<M> {
    flush_envelopes: usize,
    combining: bool,
    pending: Vec<Vec<PendingEnvelope<M>>>,
    /// Per destination: (sub-graph, vertex) -> slot in `pending`.
    slots: Vec<HashMap<(u32, Option<u32>), usize>>,
    /// Messages eliminated by combining (for `JobMetrics`).
    pub combined: u64,
}

impl<M> Batcher<M> {
    pub fn new(num_workers: usize, flush_bytes: usize, combining: bool) -> Batcher<M> {
        Batcher {
            flush_envelopes: (flush_bytes / ENVELOPE_BYTES_ESTIMATE).max(1),
            combining,
            pending: (0..num_workers).map(|_| Vec::new()).collect(),
            slots: (0..num_workers).map(|_| HashMap::new()).collect(),
            combined: 0,
        }
    }

    /// Queue an envelope for worker `to`, combining when possible.
    /// Returns a batch to deliver when `to`'s buffer is full.
    pub fn push<C>(
        &mut self,
        to: usize,
        sg_index: u32,
        vertex: Option<u32>,
        payload: M,
        combine: C,
    ) -> Option<Vec<PendingEnvelope<M>>>
    where
        C: Fn(&M, &M) -> Option<M>,
    {
        if self.combining {
            let key = (sg_index, vertex);
            if let Some(&slot) = self.slots[to].get(&key) {
                let folded = combine(&self.pending[to][slot].2, &payload);
                if let Some(m) = folded {
                    self.pending[to][slot].2 = m;
                    self.combined += 1;
                    return None;
                }
            } else {
                self.slots[to].insert(key, self.pending[to].len());
            }
        }
        self.pending[to].push((sg_index, vertex, payload));
        if self.pending[to].len() >= self.flush_envelopes {
            self.slots[to].clear();
            return Some(std::mem::take(&mut self.pending[to]));
        }
        None
    }

    /// Drain the remaining envelopes for worker `to`.
    pub fn take(&mut self, to: usize) -> Vec<PendingEnvelope<M>> {
        self.slots[to].clear();
        std::mem::take(&mut self.pending[to])
    }
}

// ------------------------------------------------------------- in-process

/// Build a k-worker in-process fabric.
pub fn in_proc(k: usize) -> Vec<InProcFabric> {
    let mut senders = Vec::with_capacity(k);
    let mut receivers = Vec::with_capacity(k);
    for _ in 0..k {
        let (tx, rx) = channel::<Vec<u8>>();
        senders.push(tx);
        receivers.push(rx);
    }
    receivers
        .into_iter()
        .enumerate()
        .map(|(i, rx)| InProcFabric {
            id: i as u32,
            peers: senders.clone(),
            inbox: rx,
        })
        .collect()
}

pub struct InProcFabric {
    id: u32,
    peers: Vec<Sender<Vec<u8>>>,
    inbox: Receiver<Vec<u8>>,
}

impl Fabric for InProcFabric {
    fn send(&self, to: u32, bytes: Vec<u8>) -> Result<()> {
        self.peers[to as usize]
            .send(bytes)
            .map_err(|_| anyhow::anyhow!("peer {to} hung up"))
    }

    fn recv(&self) -> Result<Vec<u8>> {
        self.inbox.recv().context("fabric channel closed")
    }

    fn id(&self) -> u32 {
        self.id
    }

    fn num_workers(&self) -> usize {
        self.peers.len()
    }
}

// -------------------------------------------------------------------- tcp

/// Build a k-worker loopback TCP fabric. Each worker gets a listener on
/// an OS-assigned port; a full mesh of connections is established before
/// returning. Frames are `u32-le length || payload`.
pub fn tcp(k: usize) -> Result<Vec<TcpFabric>> {
    // Bind all listeners first so every address is known.
    let listeners: Vec<TcpListener> = (0..k)
        .map(|_| TcpListener::bind("127.0.0.1:0").context("bind"))
        .collect::<Result<_>>()?;
    let addrs: Vec<std::net::SocketAddr> =
        listeners.iter().map(|l| l.local_addr().unwrap()).collect();

    // Connect the full mesh: worker i dials every j (including none to
    // itself). Accepted sockets are matched to dialers by a hello byte
    // carrying the dialer id.
    let mut outs: Vec<Vec<Option<TcpStream>>> = (0..k).map(|_| (0..k).map(|_| None).collect()).collect();
    let mut ins: Vec<Vec<Option<TcpStream>>> = (0..k).map(|_| (0..k).map(|_| None).collect()).collect();

    std::thread::scope(|scope| -> Result<()> {
        // Acceptor threads.
        let mut handles = Vec::new();
        for (i, listener) in listeners.iter().enumerate() {
            handles.push(scope.spawn(move || -> Result<Vec<(u32, TcpStream)>> {
                let mut got = Vec::new();
                for _ in 0..k - 1 {
                    let (mut s, _) = listener.accept().context("accept")?;
                    let mut hello = [0u8; 4];
                    s.read_exact(&mut hello).context("hello")?;
                    got.push((u32::from_le_bytes(hello), s));
                }
                let _ = i;
                Ok(got)
            }));
        }
        // Dial from the scope's main thread.
        for i in 0..k {
            for (j, addr) in addrs.iter().enumerate() {
                if i == j {
                    continue;
                }
                let mut s = TcpStream::connect(addr).context("connect")?;
                s.set_nodelay(true).ok();
                s.write_all(&(i as u32).to_le_bytes()).context("send hello")?;
                outs[i][j] = Some(s);
            }
        }
        for (j, h) in handles.into_iter().enumerate() {
            for (from, s) in h.join().expect("acceptor panicked")? {
                ins[j][from as usize] = Some(s);
            }
        }
        Ok(())
    })?;

    // Each worker: spawn one reader thread per inbound socket, funneling
    // into a single mpsc inbox.
    let mut fabrics = Vec::with_capacity(k);
    for (i, in_row) in ins.into_iter().enumerate() {
        let (tx, rx) = channel::<Result<Vec<u8>>>();
        for stream in in_row.into_iter().flatten() {
            let tx = tx.clone();
            std::thread::spawn(move || {
                let mut stream = stream;
                loop {
                    match read_frame(&mut stream) {
                        Ok(Some(frame)) => {
                            if tx.send(Ok(frame)).is_err() {
                                return;
                            }
                        }
                        Ok(None) => return, // clean EOF
                        Err(e) => {
                            let _ = tx.send(Err(e));
                            return;
                        }
                    }
                }
            });
        }
        fabrics.push(TcpFabric {
            id: i as u32,
            outs: outs[i]
                .iter_mut()
                .map(|o| o.take().map(Mutex::new))
                .collect(),
            inbox: rx,
            k,
        });
    }
    Ok(fabrics)
}

fn read_frame(s: &mut TcpStream) -> Result<Option<Vec<u8>>> {
    let mut len = [0u8; 4];
    match s.read_exact(&mut len) {
        Ok(()) => {}
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Ok(None),
        Err(e) => return Err(e.into()),
    }
    let n = u32::from_le_bytes(len) as usize;
    let mut buf = vec![0u8; n];
    s.read_exact(&mut buf).context("frame body")?;
    Ok(Some(buf))
}

pub struct TcpFabric {
    id: u32,
    outs: Vec<Option<Mutex<TcpStream>>>,
    inbox: Receiver<Result<Vec<u8>>>,
    k: usize,
}

impl Fabric for TcpFabric {
    fn send(&self, to: u32, bytes: Vec<u8>) -> Result<()> {
        let Some(sock) = &self.outs[to as usize] else {
            bail!("no socket to worker {to} (self-send goes via local buffer)");
        };
        let mut s = sock.lock().unwrap();
        s.write_all(&(bytes.len() as u32).to_le_bytes())?;
        s.write_all(&bytes)?;
        Ok(())
    }

    fn recv(&self) -> Result<Vec<u8>> {
        self.inbox.recv().context("tcp inbox closed")?
    }

    fn id(&self) -> u32 {
        self.id
    }

    fn num_workers(&self) -> usize {
        self.k
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn exercise(fabrics: Vec<impl Fabric + 'static>) {
        let k = fabrics.len();
        std::thread::scope(|scope| {
            for f in fabrics {
                scope.spawn(move || {
                    let me = f.id();
                    // Send one tagged batch to every peer.
                    for to in 0..k as u32 {
                        if to != me {
                            f.send(to, vec![me as u8, to as u8, 0xAB]).unwrap();
                        }
                    }
                    // Receive k-1 batches addressed to me.
                    for _ in 0..k - 1 {
                        let b = f.recv().unwrap();
                        assert_eq!(b.len(), 3);
                        assert_eq!(b[1], me as u8, "batch misrouted");
                        assert_eq!(b[2], 0xAB);
                    }
                });
            }
        });
    }

    #[test]
    fn in_proc_mesh_routes_correctly() {
        exercise(in_proc(4));
    }

    #[test]
    fn tcp_mesh_routes_correctly() {
        exercise(tcp(3).unwrap());
    }

    #[test]
    fn tcp_large_frame() {
        let fabrics = tcp(2).unwrap();
        let payload = vec![0x5Au8; 1 << 20];
        let expect = payload.clone();
        let mut it = fabrics.into_iter();
        let a = it.next().unwrap();
        let b = it.next().unwrap();
        std::thread::scope(|scope| {
            scope.spawn(move || a.send(1, payload).unwrap());
            let got = b.recv().unwrap();
            assert_eq!(got, expect);
        });
    }

    #[test]
    fn in_proc_ids_and_size() {
        let f = in_proc(5);
        assert_eq!(f.len(), 5);
        for (i, fab) in f.iter().enumerate() {
            assert_eq!(fab.id(), i as u32);
            assert_eq!(fab.num_workers(), 5);
        }
    }

    fn max_combine(a: &u32, b: &u32) -> Option<u32> {
        Some(*a.max(b))
    }

    #[test]
    fn batcher_combines_same_destination() {
        let mut b = Batcher::<u32>::new(2, 1 << 20, true);
        assert!(b.push(1, 0, None, 5, max_combine).is_none());
        assert!(b.push(1, 0, None, 9, max_combine).is_none());
        assert!(b.push(1, 0, None, 7, max_combine).is_none());
        // Different vertex key: not combined with the mailbox messages.
        assert!(b.push(1, 0, Some(3), 2, max_combine).is_none());
        assert_eq!(b.combined, 2);
        let batch = b.take(1);
        assert_eq!(batch, vec![(0, None, 9), (0, Some(3), 2)]);
        assert!(b.take(1).is_empty());
        assert!(b.take(0).is_empty());
    }

    #[test]
    fn batcher_without_combining_keeps_every_message() {
        let mut b = Batcher::<u32>::new(1, 1 << 20, false);
        for v in [5u32, 9, 7] {
            assert!(b.push(0, 0, None, v, max_combine).is_none());
        }
        assert_eq!(b.combined, 0);
        assert_eq!(b.take(0).len(), 3);
    }

    #[test]
    fn batcher_respects_none_combiner() {
        let none = |_: &u32, _: &u32| -> Option<u32> { None };
        let mut b = Batcher::<u32>::new(1, 1 << 20, true);
        assert!(b.push(0, 2, None, 1, none).is_none());
        assert!(b.push(0, 2, None, 2, none).is_none());
        assert_eq!(b.combined, 0);
        assert_eq!(b.take(0).len(), 2);
    }

    #[test]
    fn batcher_flushes_at_threshold() {
        // flush_bytes 32 -> 2 envelopes per batch.
        let mut b = Batcher::<u32>::new(1, 32, true);
        assert!(b.push(0, 0, None, 1, max_combine).is_none());
        let batch = b.push(0, 1, None, 2, max_combine).expect("flush at threshold");
        assert_eq!(batch.len(), 2);
        // Post-flush, the same keys accumulate fresh (slots were cleared).
        assert!(b.push(0, 0, None, 3, max_combine).is_none());
        assert_eq!(b.take(0), vec![(0, None, 3)]);
    }
}
