//! The sub-graph centric programming abstraction (paper §3.2).
//!
//! Users implement [`SubgraphProgram`]: a `compute` invoked once per
//! sub-graph per superstep with shared-memory access to the whole
//! sub-graph, plus the paper's messaging surface:
//!
//! * `SendToAllSubGraphNeighbors` → [`SubgraphContext::send_to_all_neighbors`]
//! * `SendToSubGraph`            → [`SubgraphContext::send_to_subgraph`]
//! * `SendToSubGraphVertex`      → [`SubgraphContext::send_to_subgraph_vertex`]
//! * `SendToAllSubGraphs`        → [`SubgraphContext::send_to_all_subgraphs`]
//! * `VoteToHalt`                → [`SubgraphContext::vote_to_halt`]
//!
//! plus the coordinator surface (paper §4.2's manager-side layer):
//!
//! * [`SubgraphProgram::aggregators`] registers global aggregators;
//!   [`SubgraphContext::aggregate`] contributes to them and
//!   [`SubgraphContext::aggregated`] reads the previous superstep's
//!   folded global values (Pregel aggregator visibility).
//! * [`SubgraphProgram::combine`] is the Giraph-style message combiner:
//!   same-destination messages are folded in the transport batching path
//!   before they hit the wire (see `transport::Batcher`).

use std::collections::BTreeMap;

use anyhow::Result;

use crate::ckpt::StateCodec;
use crate::coordinator::{Aggregators, AggregatorSpec};
use crate::gofs::{Subgraph, SubgraphId};
use crate::graph::VertexId;
use crate::util::codec::{Decoder, Encoder};
use crate::util::index::VertexIndex;

/// Wire codec for message payloads (needed because the data fabric is
/// byte-oriented — including the in-process fabric, for honest byte
/// accounting and a single code path with TCP).
pub trait MsgCodec: Sized {
    fn encode(&self, e: &mut Encoder);
    fn decode(d: &mut Decoder) -> Result<Self>;
}

impl MsgCodec for f32 {
    fn encode(&self, e: &mut Encoder) {
        e.put_f32(*self);
    }
    fn decode(d: &mut Decoder) -> Result<Self> {
        d.get_f32()
    }
}

impl MsgCodec for f64 {
    fn encode(&self, e: &mut Encoder) {
        e.put_f64(*self);
    }
    fn decode(d: &mut Decoder) -> Result<Self> {
        d.get_f64()
    }
}

impl MsgCodec for u32 {
    fn encode(&self, e: &mut Encoder) {
        e.put_varint(*self as u64);
    }
    fn decode(d: &mut Decoder) -> Result<Self> {
        Ok(d.get_varint()? as u32)
    }
}

impl MsgCodec for u64 {
    fn encode(&self, e: &mut Encoder) {
        e.put_varint(*self);
    }
    fn decode(d: &mut Decoder) -> Result<Self> {
        d.get_varint()
    }
}

impl MsgCodec for () {
    fn encode(&self, _e: &mut Encoder) {}
    fn decode(_d: &mut Decoder) -> Result<Self> {
        Ok(())
    }
}

impl<A: MsgCodec, B: MsgCodec> MsgCodec for (A, B) {
    fn encode(&self, e: &mut Encoder) {
        self.0.encode(e);
        self.1.encode(e);
    }
    fn decode(d: &mut Decoder) -> Result<Self> {
        Ok((A::decode(d)?, B::decode(d)?))
    }
}

/// An incoming data message delivered to a sub-graph at superstep start.
#[derive(Clone, Debug, PartialEq)]
pub struct IncomingMessage<M> {
    /// Target vertex (global id) when sent via `send_to_subgraph_vertex`.
    pub vertex: Option<VertexId>,
    pub payload: M,
}

/// Outgoing envelope collected during compute (crate-internal).
#[derive(Clone, Debug)]
pub(crate) struct Envelope<M> {
    pub target: SubgraphId,
    pub vertex: Option<VertexId>,
    pub payload: M,
}

/// Broadcast marker used by `send_to_all_subgraphs`.
#[derive(Clone, Debug)]
pub(crate) enum Outgoing<M> {
    Direct(Envelope<M>),
    Broadcast(M),
}

/// Per-(sub-graph, superstep) execution context.
pub struct SubgraphContext<'a, M> {
    pub(crate) superstep: usize,
    pub(crate) sg: &'a Subgraph,
    pub(crate) out: Vec<Outgoing<M>>,
    pub(crate) halted: bool,
    /// Aggregator registry for this job (empty when none registered).
    pub(crate) aggs: &'a Aggregators,
    /// Previous superstep's folded global values (None at superstep 1:
    /// nothing has crossed the barrier yet).
    pub(crate) agg_global: Option<&'a [f64]>,
    /// This unit's contributions, folded locally as they arrive.
    pub(crate) agg_local: Vec<f64>,
    /// Attribute columns projected in at load time
    /// (`Job::builder().load_attributes(...)` on a store-backed run);
    /// `None` when no columns were loaded for this sub-graph (no
    /// projection declared, or an in-memory source).
    pub(crate) attrs: Option<&'a BTreeMap<String, Vec<f32>>>,
    /// Compact global-id → local-slot index built by the engine at
    /// worker init (dense remap, or sorted fallback for sparse ids).
    /// `None` falls back to `Subgraph::local_id`'s binary search.
    pub(crate) index: Option<&'a VertexIndex>,
}

impl<'a, M: Clone> SubgraphContext<'a, M> {
    pub(crate) fn new(
        superstep: usize,
        sg: &'a Subgraph,
        aggs: &'a Aggregators,
        agg_global: Option<&'a [f64]>,
        attrs: Option<&'a BTreeMap<String, Vec<f32>>>,
    ) -> Self {
        Self {
            superstep,
            sg,
            out: Vec::new(),
            halted: false,
            aggs,
            agg_global,
            agg_local: aggs.identity_values(),
            attrs,
            index: None,
        }
    }

    /// Attach the engine-built vertex index (builder-style, so the
    /// `new` signature — and every test constructing a bare context —
    /// stays unchanged).
    pub(crate) fn with_index(mut self, index: Option<&'a VertexIndex>) -> Self {
        self.index = index;
        self
    }

    /// Current superstep (1-based, as in the paper's pseudocode).
    pub fn superstep(&self) -> usize {
        self.superstep
    }

    /// Local slot of a global vertex id within this sub-graph, or
    /// `None` if the vertex lives elsewhere. Uses the engine's compact
    /// [`VertexIndex`] (O(1) dense remap where ids allow) when one is
    /// attached, falling back to [`Subgraph::local_id`]'s binary
    /// search — the variants are interchangeable by construction, so
    /// results never depend on which one answered.
    #[inline]
    pub fn local_vertex(&self, global: VertexId) -> Option<u32> {
        match self.index {
            Some(idx) => idx.get(global),
            None => self.sg.local_id(global),
        }
    }

    /// A projected per-vertex attribute column (local-vertex order,
    /// aligned with `Subgraph::vertices`). `None` unless the job loaded
    /// the attribute from a GoFS store via
    /// `Job::builder().load_attributes(...)` — the projection is the
    /// load-path contract: undeclared attributes were never read.
    pub fn attribute(&self, name: &str) -> Option<&[f32]> {
        self.attrs.and_then(|m| m.get(name)).map(|v| v.as_slice())
    }

    /// Slot index of a named aggregator registered by the program.
    pub fn aggregator(&self, name: &str) -> Option<usize> {
        self.aggs.index_of(name)
    }

    /// Contribute to aggregator slot `idx`; contributions fold with the
    /// slot's monoid, worker-locally first and globally at the barrier.
    pub fn aggregate(&mut self, idx: usize, value: f64) {
        let op = self.aggs.specs()[idx].op;
        self.agg_local[idx] = op.fold(self.agg_local[idx], value);
    }

    /// The global value of aggregator slot `idx` folded at the end of
    /// the *previous* superstep. `None` during superstep 1.
    pub fn aggregated(&self, idx: usize) -> Option<f64> {
        self.agg_global.map(|g| g[idx])
    }

    /// Send to a specific sub-graph (its whole-sub-graph mailbox).
    pub fn send_to_subgraph(&mut self, target: SubgraphId, payload: M) {
        self.out.push(Outgoing::Direct(Envelope { target, vertex: None, payload }));
    }

    /// Send to a specific vertex of a specific sub-graph.
    pub fn send_to_subgraph_vertex(
        &mut self,
        target: SubgraphId,
        vertex: VertexId,
        payload: M,
    ) {
        self.out.push(Outgoing::Direct(Envelope {
            target,
            vertex: Some(vertex),
            payload,
        }));
    }

    /// Send to every neighbouring sub-graph (across remote edges, both
    /// directions — neighbours are by definition on other partitions).
    pub fn send_to_all_neighbors(&mut self, payload: M) {
        for nb in self.sg.neighbor_subgraphs() {
            self.send_to_subgraph(nb, payload.clone());
        }
    }

    /// Global broadcast — costly, use sparingly (paper §3.2).
    pub fn send_to_all_subgraphs(&mut self, payload: M) {
        self.out.push(Outgoing::Broadcast(payload));
    }

    /// Vote to halt: skip this sub-graph next superstep unless messages
    /// arrive for it.
    pub fn vote_to_halt(&mut self) {
        self.halted = true;
    }
}

/// A sub-graph centric program. `State` persists across supersteps (the
/// paper's "the method is stateful for each sub-graph").
///
/// `State: StateCodec` is the fault-tolerance contract: it is what lets
/// the default [`SubgraphProgram::save_state`] /
/// [`SubgraphProgram::restore_state`] hooks checkpoint any value-only
/// state with zero per-program code (see [`crate::ckpt`]).
pub trait SubgraphProgram: Sync {
    type Msg: MsgCodec + Clone + Send + Sync + 'static;
    type State: StateCodec + Send + 'static;

    /// Build the initial per-sub-graph state (before superstep 1).
    fn init(&self, sg: &Subgraph) -> Self::State;

    /// One superstep of computation on one sub-graph.
    fn compute(
        &self,
        state: &mut Self::State,
        sg: &Subgraph,
        ctx: &mut SubgraphContext<'_, Self::Msg>,
        msgs: &[IncomingMessage<Self::Msg>],
    );

    /// Global aggregators this program uses. Folded by the manager at
    /// every superstep barrier; read back via
    /// [`SubgraphContext::aggregated`] the following superstep.
    fn aggregators(&self) -> Vec<AggregatorSpec> {
        Vec::new()
    }

    /// Giraph-style combiner: fold two payloads bound for the same
    /// destination (same sub-graph mailbox, or same target vertex) into
    /// one before they are encoded onto the wire. Return `None`
    /// (default) to disable combining for this program. The fold must be
    /// associative and commutative, and the receiver's `compute` must
    /// treat a folded message like the sequence it replaces.
    fn combine(&self, _a: &Self::Msg, _b: &Self::Msg) -> Option<Self::Msg> {
        None
    }

    /// Per-vertex result extraction for the unified job layer
    /// ([`crate::job`]): map the final sub-graph state to
    /// `(global vertex id, value)` pairs. The engine harvests these after
    /// the last superstep and surfaces them, sorted by vertex id, as
    /// `RunResult::values` / `JobOutput::values` — the uniform output
    /// shape shared with the vertex engine. The default (empty) opts the
    /// program out of per-vertex output.
    fn emit(&self, _state: &Self::State, _sg: &Subgraph) -> Vec<(VertexId, f64)> {
        Vec::new()
    }

    /// Serialize one sub-graph's state into a checkpoint
    /// ([`crate::ckpt`]); called at the barrier for every local
    /// sub-graph when checkpointing is on. The default encodes the
    /// whole state via its [`StateCodec`] impl — sufficient for
    /// value-only algorithms. Override (with
    /// [`SubgraphProgram::restore_state`]) to persist less, e.g. when
    /// part of the state is rebuildable from topology.
    fn save_state(&self, state: &Self::State, e: &mut Encoder) {
        state.encode_state(e)
    }

    /// Rebuild one sub-graph's state from a checkpoint. Must consume
    /// exactly the bytes the matching [`SubgraphProgram::save_state`]
    /// wrote, and must reproduce the state *bit-exactly* (recovery
    /// parity is a byte-identical-output guarantee). The default decodes
    /// via [`StateCodec`]; programs whose state embeds derived machinery
    /// (e.g. PageRank's registered XLA adjacency block) override this
    /// and reconstruct that part from `sg`.
    fn restore_state(&self, _sg: &Subgraph, d: &mut Decoder) -> Result<Self::State> {
        Self::State::decode_state(d)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gofs::subgraph::discover;
    use crate::graph::csr::Graph;
    use crate::partition::Partitioning;

    fn sg_pair() -> crate::gofs::DistributedGraph {
        let g = Graph::from_edges(4, &[(0, 1), (2, 3), (1, 2)], None, false).unwrap();
        let parts = Partitioning::new(2, vec![0, 0, 1, 1]);
        discover(&g, &parts).unwrap()
    }

    #[test]
    fn context_collects_sends() {
        let dg = sg_pair();
        let sg = &dg.partitions[0][0];
        let aggs = Aggregators::default();
        let mut ctx = SubgraphContext::<f32>::new(1, sg, &aggs, None, None);
        assert_eq!(ctx.attribute("anything"), None);
        ctx.send_to_all_neighbors(2.5);
        ctx.send_to_subgraph_vertex(dg.partitions[1][0].id, 3, 1.5);
        ctx.send_to_all_subgraphs(9.0);
        assert_eq!(ctx.out.len(), 3); // 1 neighbour + 1 direct + 1 broadcast
        assert!(!ctx.halted);
        ctx.vote_to_halt();
        assert!(ctx.halted);
    }

    #[test]
    fn context_aggregator_surface() {
        use crate::coordinator::AggOp;
        let dg = sg_pair();
        let sg = &dg.partitions[0][0];
        let aggs = Aggregators::new(vec![
            AggregatorSpec::new("delta", AggOp::Sum),
            AggregatorSpec::new("low", AggOp::Min),
        ]);

        // Superstep 1: nothing folded yet; contributions fold locally.
        let mut ctx = SubgraphContext::<f32>::new(1, sg, &aggs, None, None);
        assert_eq!(ctx.aggregator("delta"), Some(0));
        assert_eq!(ctx.aggregator("nope"), None);
        assert_eq!(ctx.aggregated(0), None);
        ctx.aggregate(0, 2.0);
        ctx.aggregate(0, 3.0);
        ctx.aggregate(1, 7.0);
        ctx.aggregate(1, 4.0);
        assert_eq!(ctx.agg_local, vec![5.0, 4.0]);

        // Superstep 2: folded globals are visible.
        let global = vec![5.0, 4.0];
        let ctx2 = SubgraphContext::<f32>::new(2, sg, &aggs, Some(&global), None);
        assert_eq!(ctx2.aggregated(0), Some(5.0));
        assert_eq!(ctx2.aggregated(1), Some(4.0));
    }

    #[test]
    fn context_exposes_projected_attributes() {
        let dg = sg_pair();
        let sg = &dg.partitions[0][0];
        let aggs = Aggregators::default();
        let mut cols = BTreeMap::new();
        cols.insert("rank".to_string(), vec![0.5f32, 1.5]);
        let ctx = SubgraphContext::<f32>::new(1, sg, &aggs, None, Some(&cols));
        assert_eq!(ctx.attribute("rank"), Some(&[0.5f32, 1.5][..]));
        assert_eq!(ctx.attribute("missing"), None);
    }

    #[test]
    fn msg_codec_round_trips() {
        fn rt<M: MsgCodec + PartialEq + std::fmt::Debug>(m: M) {
            let mut e = Encoder::new();
            m.encode(&mut e);
            let bytes = e.into_bytes();
            let mut d = Decoder::new(&bytes);
            assert_eq!(M::decode(&mut d).unwrap(), m);
            assert!(d.is_at_end());
        }
        rt(1.5f32);
        rt(-2.5f64);
        rt(17u32);
        rt(u64::MAX);
        rt(());
        rt((42u32, 1.25f32));
        rt((7u64, (1u32, 2.0f32)));
    }
}
