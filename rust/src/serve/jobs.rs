//! The resident job registry: specs in, supervised executions out.
//!
//! A submitted job moves through a small state machine:
//!
//! ```text
//!   queued ──▶ running ──▶ done
//!     │           │    └──▶ failed
//!     └──────────▶└───────▶ cancelled
//! ```
//!
//! * **Admission is bounded.** Accepted jobs enter a
//!   [`std::sync::mpsc::sync_channel`] whose capacity is the server's
//!   `--queue` knob; when it is full, [`Jobs::submit`] refuses with
//!   [`SubmitError::QueueFull`] (HTTP 503) instead of buffering
//!   without limit.
//! * **Validation happens at submit.** The spec is run through
//!   [`crate::job::JobBuilder::build`] once at POST time, so unknown
//!   algorithms and engine/knob mismatches come back as an immediate
//!   400 with the builder's typed message — the same errors the CLI
//!   prints — rather than a job that materializes already failed.
//! * **Execution is supervised.** Each entry owns a
//!   [`RunControl`]; the executor threads rebuild the job from its
//!   spec (attaching that handle) and run it against the resident
//!   graph. The engine managers publish the superstep through the
//!   handle at every barrier and honor cancellation there, which is
//!   what bounds `DELETE /v1/jobs/{id}` latency to one superstep.

use std::collections::BTreeMap;
use std::sync::mpsc::{Receiver, SyncSender};
use std::sync::{mpsc, Arc, Mutex};

use crate::coordinator::RunControl;
use crate::job::{EngineKind, Job, JobError, JobOutput, JobSource};
use crate::metrics::JobMetrics;

use super::json::JsonValue;
use super::ResidentGraph;

/// A job description as submitted over the API (`POST /v1/jobs` body).
#[derive(Clone, Debug)]
pub(crate) struct JobSpec {
    /// Registered algorithm name (`algo`).
    pub algo: String,
    /// Engine to run on (`engine`: `"gopher"` | `"vertex"`).
    pub engine: EngineKind,
    /// Source vertex for BFS/SSSP (`source`).
    pub source: u32,
    /// Fixed iteration count / round cap (`supersteps`).
    pub supersteps: Option<usize>,
    /// PageRank convergence threshold (`epsilon`, Gopher only).
    pub epsilon: Option<f32>,
    /// Combiner toggle (`combiners`, Gopher only).
    pub combiners: Option<bool>,
    /// Superstep budget (`max_supersteps`).
    pub max_supersteps: Option<usize>,
    /// Cores per simulated worker (`cores`; defaults to the server's).
    pub cores: usize,
}

/// A non-negative integral JSON number, or `None`.
fn as_uint(v: &JsonValue) -> Option<u64> {
    match v.as_f64() {
        Some(n) if n >= 0.0 && n.fract() == 0.0 && n <= 2f64.powi(53) => {
            Some(n as u64)
        }
        _ => None,
    }
}

impl JobSpec {
    /// Decode a spec from a request body. Errors are client-facing 400
    /// messages. Unknown fields are rejected so that a misspelled knob
    /// fails loudly instead of silently running with defaults.
    pub fn from_json(v: &JsonValue, default_cores: usize) -> Result<JobSpec, String> {
        let kvs = match v {
            JsonValue::Obj(kvs) => kvs,
            _ => return Err("request body must be a JSON object".to_string()),
        };
        let mut spec = JobSpec {
            algo: String::new(),
            engine: EngineKind::Gopher,
            source: 0,
            supersteps: None,
            epsilon: None,
            combiners: None,
            max_supersteps: None,
            cores: default_cores,
        };
        for (k, val) in kvs {
            match k.as_str() {
                "algo" => {
                    spec.algo = val
                        .as_str()
                        .ok_or("field \"algo\" must be a string")?
                        .to_string();
                }
                "engine" => match val.as_str() {
                    Some("gopher") => spec.engine = EngineKind::Gopher,
                    Some("vertex") => spec.engine = EngineKind::Vertex,
                    _ => {
                        return Err(
                            "field \"engine\" must be \"gopher\" or \"vertex\"".to_string()
                        )
                    }
                },
                "source" => {
                    spec.source = as_uint(val)
                        .filter(|&n| n <= u64::from(u32::MAX))
                        .ok_or("field \"source\" must be a vertex id")?
                        as u32;
                }
                "supersteps" => {
                    spec.supersteps = Some(
                        as_uint(val).ok_or("field \"supersteps\" must be a non-negative integer")?
                            as usize,
                    );
                }
                "max_supersteps" => {
                    spec.max_supersteps = Some(
                        as_uint(val)
                            .ok_or("field \"max_supersteps\" must be a non-negative integer")?
                            as usize,
                    );
                }
                "cores" => {
                    spec.cores = as_uint(val)
                        .filter(|&n| n >= 1)
                        .ok_or("field \"cores\" must be a positive integer")?
                        as usize;
                }
                "epsilon" => {
                    spec.epsilon = Some(
                        val.as_f64().ok_or("field \"epsilon\" must be a number")? as f32,
                    );
                }
                "combiners" => {
                    spec.combiners =
                        Some(val.as_bool().ok_or("field \"combiners\" must be a boolean")?);
                }
                other => return Err(format!("unknown field {other:?} in job spec")),
            }
        }
        if spec.algo.is_empty() {
            return Err("field \"algo\" is required".to_string());
        }
        Ok(spec)
    }

    /// Build a runnable [`Job`] from this spec, attaching a supervision
    /// handle. Called once at submit for validation (result dropped)
    /// and again inside the executor thread that runs it.
    pub fn build_job(&self, ctl: RunControl) -> Result<Job, JobError> {
        let mut b = Job::builder()
            .algo(self.algo.as_str())
            .engine(self.engine)
            .cores(self.cores)
            .source_vertex(self.source)
            .control(ctl);
        if let Some(n) = self.supersteps {
            b = b.supersteps(n);
        }
        if let Some(n) = self.max_supersteps {
            b = b.max_supersteps(n);
        }
        if let Some(eps) = self.epsilon {
            b = b.epsilon(eps);
        }
        if let Some(on) = self.combiners {
            b = b.combiners(on);
        }
        b.build()
    }
}

/// Lifecycle state of one registered job.
pub(crate) enum JobState {
    /// Accepted, waiting for an executor slot.
    Queued,
    /// An executor thread is running it.
    Running,
    /// Finished successfully; the output is held for paging.
    Done(Box<JobOutput>),
    /// Finished successfully, but the per-vertex values were dropped by
    /// result retention (`--keep-results N`): the summary metrics and
    /// aggregator traces survive, `GET .../results` answers 410.
    /// Reported as `done` (+ a `results_evicted` flag) over the API.
    Evicted {
        /// Retained execution metrics (incl. aggregator traces).
        metrics: Box<JobMetrics>,
        /// How many values the evicted output held.
        num_values: usize,
    },
    /// The run errored (message retained).
    Failed(String),
    /// Cancelled — either dequeued-and-skipped, or stopped at a
    /// superstep barrier mid-run.
    Cancelled,
}

impl JobState {
    /// Status string as reported over the API.
    pub fn name(&self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Done(_) | JobState::Evicted { .. } => "done",
            JobState::Failed(_) => "failed",
            JobState::Cancelled => "cancelled",
        }
    }

}

/// One registered job: immutable identity + spec, a live supervision
/// handle, and the mutable state.
pub(crate) struct JobEntry {
    /// Server-assigned id (monotonic per server instance).
    pub id: u64,
    /// The spec as submitted (executors rebuild the job from it).
    pub spec: JobSpec,
    /// Supervision handle shared with the engine manager: superstep
    /// progress out, cancellation in.
    pub control: RunControl,
    /// Current lifecycle state.
    pub state: Mutex<JobState>,
}

/// Why a submit was refused.
pub(crate) enum SubmitError {
    /// The spec failed validation (client error → 400).
    Invalid(String),
    /// The admission queue is full, or the server is shutting down
    /// (→ 503; retry later).
    QueueFull,
}

/// What a cancel request achieved.
pub(crate) enum CancelOutcome {
    /// No job under that id.
    NotFound,
    /// Cancellation took (or will take) effect: the job was queued
    /// (skipped outright), running (stops at the next barrier), or
    /// already cancelled (idempotent).
    Accepted,
    /// The job already finished; nothing to cancel (→ 409). Carries
    /// the terminal status name.
    AlreadyFinished(&'static str),
}

struct Inner {
    next_id: u64,
    map: BTreeMap<u64, Arc<JobEntry>>,
}

/// The registry: id → entry, plus the bounded admission queue feeding
/// the executor pool.
pub(crate) struct Jobs {
    inner: Mutex<Inner>,
    tx: Mutex<Option<SyncSender<Arc<JobEntry>>>>,
    /// Result retention cap (`--keep-results`); `None` keeps all.
    keep: Mutex<Option<usize>>,
}

impl Jobs {
    /// Create a registry with an admission queue of `queue` slots.
    /// Returns the receiver the executor pool drains.
    pub fn new(queue: usize) -> (Jobs, Receiver<Arc<JobEntry>>) {
        let (tx, rx) = mpsc::sync_channel(queue.max(1));
        let jobs = Jobs {
            inner: Mutex::new(Inner { next_id: 1, map: BTreeMap::new() }),
            tx: Mutex::new(Some(tx)),
            keep: Mutex::new(None),
        };
        (jobs, rx)
    }

    /// Set the result retention cap (see [`JobState::Evicted`]).
    pub fn set_keep_results(&self, n: Option<usize>) {
        *self.keep.lock().expect("keep lock") = n;
    }

    /// Enforce the retention cap: while more than `keep` jobs hold full
    /// results, the *oldest* (lowest id) done jobs drop their values —
    /// metrics and aggregator traces are kept. Executors call this
    /// after every job completion; with no cap set it is a no-op.
    pub fn enforce_retention(&self) {
        let Some(keep) = *self.keep.lock().expect("keep lock") else {
            return;
        };
        // Snapshot the id-ordered entries, then evict outside any
        // registry-wide lock (state locks nest inside nothing here).
        let entries = self.list();
        let holding: Vec<&Arc<JobEntry>> = entries
            .iter()
            .filter(|e| {
                matches!(&*e.state.lock().expect("job state lock"), JobState::Done(_))
            })
            .collect();
        for entry in holding.iter().take(holding.len().saturating_sub(keep)) {
            let mut st = entry.state.lock().expect("job state lock");
            if let JobState::Done(out) = &*st {
                *st = JobState::Evicted {
                    metrics: Box::new(out.metrics.clone()),
                    num_values: out.values.len(),
                };
                crate::obs::registry::global().counter_add(
                    "goffish_result_evictions_total",
                    "Job results dropped by --keep-results retention (410 thereafter).",
                    &[],
                    1,
                );
            }
        }
    }

    /// Validate and enqueue a job. On success the entry is registered
    /// (visible to `GET /v1/jobs`) and queued for execution.
    pub fn submit(&self, spec: JobSpec) -> Result<Arc<JobEntry>, SubmitError> {
        spec.build_job(RunControl::new())
            .map_err(|e| SubmitError::Invalid(e.to_string()))?;
        let entry = {
            let mut inner = self.inner.lock().expect("jobs lock");
            let id = inner.next_id;
            inner.next_id += 1;
            let entry = Arc::new(JobEntry {
                id,
                spec,
                control: RunControl::new(),
                state: Mutex::new(JobState::Queued),
            });
            inner.map.insert(id, entry.clone());
            entry
        };
        let refused = {
            let tx = self.tx.lock().expect("jobs tx lock");
            match tx.as_ref() {
                None => true, // shutting down
                Some(tx) => tx.try_send(entry.clone()).is_err(),
            }
        };
        if refused {
            self.inner.lock().expect("jobs lock").map.remove(&entry.id);
            crate::obs::registry::global().counter_add(
                "goffish_admission_rejections_total",
                "Job submissions refused with 503 because the admission queue was full.",
                &[],
                1,
            );
            return Err(SubmitError::QueueFull);
        }
        Ok(entry)
    }

    /// Look a job up by id.
    pub fn get(&self, id: u64) -> Option<Arc<JobEntry>> {
        self.inner.lock().expect("jobs lock").map.get(&id).cloned()
    }

    /// All registered jobs, in id order.
    pub fn list(&self) -> Vec<Arc<JobEntry>> {
        self.inner.lock().expect("jobs lock").map.values().cloned().collect()
    }

    /// Number of registered jobs.
    pub fn count(&self) -> usize {
        self.inner.lock().expect("jobs lock").map.len()
    }

    /// Request cancellation of a job.
    pub fn cancel(&self, id: u64) -> CancelOutcome {
        let Some(entry) = self.get(id) else {
            return CancelOutcome::NotFound;
        };
        let mut st = entry.state.lock().expect("job state lock");
        match &*st {
            JobState::Queued => {
                entry.control.cancel();
                *st = JobState::Cancelled;
                CancelOutcome::Accepted
            }
            JobState::Running => {
                entry.control.cancel();
                CancelOutcome::Accepted
            }
            JobState::Cancelled => CancelOutcome::Accepted,
            JobState::Done(_) | JobState::Evicted { .. } => {
                CancelOutcome::AlreadyFinished("done")
            }
            JobState::Failed(_) => CancelOutcome::AlreadyFinished("failed"),
        }
    }

    /// Close the admission queue (shutdown): executors exit after
    /// draining what was already accepted; new submits get 503.
    pub fn close(&self) {
        self.tx.lock().expect("jobs tx lock").take();
    }
}

/// One executor thread: drain the admission queue until it closes.
///
/// The receiver sits behind a mutex so `--workers N` threads can share
/// it; whichever thread wins the lock takes the next job. Cancelled
/// queued entries are skipped without running.
pub(crate) fn executor_loop(
    rx: Arc<Mutex<Receiver<Arc<JobEntry>>>>,
    resident: Arc<ResidentGraph>,
    registry: Arc<Jobs>,
) {
    loop {
        let next = {
            let rx = rx.lock().expect("executor queue lock");
            rx.recv()
        };
        let Ok(entry) = next else {
            return; // queue closed: shutdown
        };
        {
            let mut st = entry.state.lock().expect("job state lock");
            if matches!(*st, JobState::Queued) {
                *st = JobState::Running;
            } else {
                continue; // cancelled while queued
            }
        }
        // Rebuild from the spec inside this thread (the spec is plain
        // data; a built Job need not cross threads) with the entry's
        // live supervision handle attached.
        let job = match entry.spec.build_job(entry.control.clone()) {
            Ok(job) => job,
            Err(e) => {
                *entry.state.lock().expect("job state lock") =
                    JobState::Failed(e.to_string());
                continue;
            }
        };
        // The job pins the snapshot current at its start: a refresh
        // swapping the resident graph mid-run never changes data under
        // an executing job (generation isolation, serve-level).
        let snapshot = resident.snapshot();
        // Hand the job the snapshot's precomputed vertex indexes: repeat
        // jobs against a resident graph skip the per-run index build.
        let job = job.with_vertex_indexes(snapshot.vertex_indexes());
        let outcome = job.run(JobSource::InMemory(snapshot.graph()));
        {
            let mut st = entry.state.lock().expect("job state lock");
            *st = match outcome {
                Ok(out) => JobState::Done(Box::new(out)),
                Err(_) if entry.control.is_cancelled() => JobState::Cancelled,
                Err(e) => JobState::Failed(format!("{e:#}")),
            };
        }
        registry.enforce_retention();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(algo: &str) -> JobSpec {
        JobSpec {
            algo: algo.to_string(),
            engine: EngineKind::Gopher,
            source: 0,
            supersteps: None,
            epsilon: None,
            combiners: None,
            max_supersteps: None,
            cores: 2,
        }
    }

    #[test]
    fn spec_decodes_and_rejects() {
        let v = JsonValue::parse(
            "{\"algo\":\"sssp\",\"engine\":\"vertex\",\"source\":7,\"supersteps\":5,\
             \"max_supersteps\":100,\"cores\":3}",
        )
        .unwrap();
        let s = JobSpec::from_json(&v, 4).unwrap();
        assert_eq!(s.algo, "sssp");
        assert_eq!(s.engine, EngineKind::Vertex);
        assert_eq!(s.source, 7);
        assert_eq!(s.supersteps, Some(5));
        assert_eq!(s.max_supersteps, Some(100));
        assert_eq!(s.cores, 3);

        // Defaults: engine gopher, server cores.
        let s = JobSpec::from_json(&JsonValue::parse("{\"algo\":\"cc\"}").unwrap(), 4)
            .unwrap();
        assert_eq!(s.engine, EngineKind::Gopher);
        assert_eq!(s.cores, 4);

        for bad in [
            "[]",
            "{}",
            "{\"algo\":1}",
            "{\"algo\":\"cc\",\"engine\":\"quantum\"}",
            "{\"algo\":\"cc\",\"source\":-1}",
            "{\"algo\":\"cc\",\"source\":1.5}",
            "{\"algo\":\"cc\",\"cores\":0}",
            "{\"algo\":\"cc\",\"combiners\":\"yes\"}",
            "{\"algo\":\"cc\",\"frobnicate\":true}",
        ] {
            let v = JsonValue::parse(bad).unwrap();
            assert!(JobSpec::from_json(&v, 4).is_err(), "accepted {bad}");
        }
    }

    #[test]
    fn submit_validates_through_the_builder() {
        let (jobs, _rx) = Jobs::new(4);
        // Unknown algorithm → Invalid with the builder's message.
        match jobs.submit(spec("frobnicate")) {
            Err(SubmitError::Invalid(msg)) => {
                assert!(msg.contains("unknown algorithm"), "{msg}");
            }
            _ => panic!("expected Invalid"),
        }
        // Engine/knob mismatch (epsilon on the vertex engine).
        let mut s = spec("pagerank");
        s.engine = EngineKind::Vertex;
        s.epsilon = Some(0.1);
        assert!(matches!(jobs.submit(s), Err(SubmitError::Invalid(_))));
        // Rejected submits never register.
        assert_eq!(jobs.count(), 0);
    }

    #[test]
    fn admission_queue_is_bounded() {
        let (jobs, rx) = Jobs::new(2);
        // No executor draining: the third accepted submit finds the
        // 2-slot channel full.
        assert!(jobs.submit(spec("cc")).is_ok());
        assert!(jobs.submit(spec("cc")).is_ok());
        assert!(matches!(jobs.submit(spec("cc")), Err(SubmitError::QueueFull)));
        // The refused job was unregistered again.
        assert_eq!(jobs.count(), 2);
        assert_eq!(jobs.list().len(), 2);
        // After shutdown, submits are refused outright.
        jobs.close();
        assert!(matches!(jobs.submit(spec("cc")), Err(SubmitError::QueueFull)));
        drop(rx);
    }

    #[test]
    fn cancel_state_machine() {
        let (jobs, _rx) = Jobs::new(4);
        let entry = jobs.submit(spec("cc")).unwrap();
        assert!(matches!(jobs.cancel(99), CancelOutcome::NotFound));
        // Queued → cancelled without running; idempotent thereafter.
        assert!(matches!(jobs.cancel(entry.id), CancelOutcome::Accepted));
        assert!(entry.control.is_cancelled());
        assert!(matches!(jobs.cancel(entry.id), CancelOutcome::Accepted));
        assert_eq!(entry.state.lock().unwrap().name(), "cancelled");
        // Terminal states refuse.
        *entry.state.lock().unwrap() = JobState::Failed("boom".into());
        assert!(matches!(
            jobs.cancel(entry.id),
            CancelOutcome::AlreadyFinished("failed")
        ));
    }

    #[test]
    fn retention_evicts_oldest_done_jobs_only() {
        let (jobs, _rx) = Jobs::new(8);
        let done = |n: usize| {
            let mut metrics = JobMetrics::default();
            metrics.supersteps.push(Default::default());
            JobState::Done(Box::new(JobOutput {
                values: (0..n as u32).map(|v| (v, 0.0)).collect(),
                metrics,
                aggregators: Vec::new(),
            }))
        };
        let e1 = jobs.submit(spec("cc")).unwrap();
        let e2 = jobs.submit(spec("cc")).unwrap();
        let e3 = jobs.submit(spec("cc")).unwrap();
        let e4 = jobs.submit(spec("cc")).unwrap();
        *e1.state.lock().unwrap() = done(3);
        *e2.state.lock().unwrap() = JobState::Failed("boom".into());
        *e3.state.lock().unwrap() = done(5);
        *e4.state.lock().unwrap() = done(7);

        // No cap: everything keeps its values.
        jobs.enforce_retention();
        assert!(matches!(&*e1.state.lock().unwrap(), JobState::Done(_)));

        // Cap 1: of the three done jobs the two oldest are evicted;
        // the failed job is not a retention candidate at all.
        jobs.set_keep_results(Some(1));
        jobs.enforce_retention();
        match &*e1.state.lock().unwrap() {
            JobState::Evicted { metrics, num_values } => {
                assert_eq!(*num_values, 3);
                assert_eq!(metrics.num_supersteps(), 1);
            }
            other => panic!("expected e1 evicted, got {}", other.name()),
        }
        assert!(matches!(
            &*e3.state.lock().unwrap(),
            JobState::Evicted { num_values: 5, .. }
        ));
        assert!(matches!(&*e4.state.lock().unwrap(), JobState::Done(_)));
        assert!(matches!(&*e2.state.lock().unwrap(), JobState::Failed(_)));
        // Both terminal flavours still read as "done" / cancel-409.
        assert_eq!(e1.state.lock().unwrap().name(), "done");
        assert!(matches!(
            jobs.cancel(e1.id),
            CancelOutcome::AlreadyFinished("done")
        ));
        // Idempotent under a re-run.
        jobs.enforce_retention();
        assert!(matches!(&*e4.state.lock().unwrap(), JobState::Done(_)));
    }
}
