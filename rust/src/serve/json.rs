//! Hand-rolled JSON — the serve layer's wire format.
//!
//! The crate deliberately carries no serialization dependency (the
//! container builds offline), so the HTTP API's request/response bodies
//! go through this ~300-line value type instead: [`JsonValue`] with a
//! deterministic renderer and a recursive-descent parser.
//!
//! Two properties matter to the server and are worth naming:
//!
//! * **Deterministic rendering.** Objects are backed by an ordered
//!   `Vec<(String, JsonValue)>`, not a hash map, so the same value
//!   always renders to the same bytes — that is what makes paged
//!   `/v1/jobs/{id}/results` responses byte-stable across requests.
//! * **TSV-compatible numbers.** Finite numbers render through Rust's
//!   shortest-roundtrip `{}` formatting for `f64` — the exact
//!   formatting the CLI's `--output` TSV writer uses — so a result
//!   value fetched over the API prints identically to the same value
//!   in a `goffish run --output` file, and `Display → parse` recovers
//!   the original bits. Non-finite numbers render as `null` (JSON has
//!   no representation for them).
//!
//! The parser is strict enough for an API surface: it rejects trailing
//! garbage, caps nesting depth, and understands the full escape set
//! including `\uXXXX` surrogate pairs.

use anyhow::{bail, ensure, Result};

/// Maximum nesting depth the parser accepts (defense against
/// stack-overflow via `[[[[…`).
const MAX_DEPTH: usize = 64;

/// A parsed or to-be-rendered JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum JsonValue {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (always carried as `f64`).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<JsonValue>),
    /// An object. Backed by an ordered `Vec`, not a map: insertion
    /// order is rendering order, which keeps responses byte-stable.
    Obj(Vec<(String, JsonValue)>),
}

impl JsonValue {
    /// Object field lookup (first match); `None` on non-objects.
    pub fn get(&self, key: &str) -> Option<&JsonValue> {
        match self {
            JsonValue::Obj(kvs) => {
                kvs.iter().find(|(k, _)| k == key).map(|(_, v)| v)
            }
            _ => None,
        }
    }

    /// The number, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            JsonValue::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// The string slice, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            JsonValue::Str(s) => Some(s.as_str()),
            _ => None,
        }
    }

    /// The boolean, if this is a boolean.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            JsonValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The element slice, if this is an array.
    pub fn as_array(&self) -> Option<&[JsonValue]> {
        match self {
            JsonValue::Arr(xs) => Some(xs.as_slice()),
            _ => None,
        }
    }

    /// Render to a compact JSON string (no whitespace).
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write_into(&mut out);
        out
    }

    fn write_into(&self, out: &mut String) {
        match self {
            JsonValue::Null => out.push_str("null"),
            JsonValue::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            JsonValue::Num(n) => {
                if n.is_finite() {
                    out.push_str(&n.to_string());
                } else {
                    out.push_str("null");
                }
            }
            JsonValue::Str(s) => escape_into(s, out),
            JsonValue::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write_into(out);
                }
                out.push(']');
            }
            JsonValue::Obj(kvs) => {
                out.push('{');
                for (i, (k, v)) in kvs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    escape_into(k, out);
                    out.push(':');
                    v.write_into(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse a complete JSON document (trailing garbage is an error).
    pub fn parse(input: &str) -> Result<JsonValue> {
        let mut p = Parser { b: input.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value(0)?;
        p.skip_ws();
        ensure!(
            p.pos == p.b.len(),
            "trailing bytes after JSON value at offset {}",
            p.pos
        );
        Ok(v)
    }
}

fn escape_into(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl Parser<'_> {
    fn skip_ws(&mut self) {
        while matches!(self.b.get(self.pos), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        match self.peek() {
            Some(got) if got == c => {
                self.pos += 1;
                Ok(())
            }
            got => bail!(
                "expected {:?} at offset {}, found {:?}",
                c as char,
                self.pos,
                got.map(|g| g as char)
            ),
        }
    }

    fn value(&mut self, depth: usize) -> Result<JsonValue> {
        ensure!(depth < MAX_DEPTH, "JSON nested deeper than {MAX_DEPTH}");
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", JsonValue::Null),
            Some(b't') => self.literal("true", JsonValue::Bool(true)),
            Some(b'f') => self.literal("false", JsonValue::Bool(false)),
            Some(b'"') => Ok(JsonValue::Str(self.string()?)),
            Some(b'[') => self.array(depth),
            Some(b'{') => self.object(depth),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            got => bail!(
                "expected a JSON value at offset {}, found {:?}",
                self.pos,
                got.map(|g| g as char)
            ),
        }
    }

    fn literal(&mut self, word: &str, v: JsonValue) -> Result<JsonValue> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("bad literal at offset {} (expected {word})", self.pos)
        }
    }

    fn number(&mut self) -> Result<JsonValue> {
        let start = self.pos;
        while matches!(
            self.peek(),
            Some(b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        ) {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).expect("ascii");
        match text.parse::<f64>() {
            Ok(n) if n.is_finite() => Ok(JsonValue::Num(n)),
            _ => bail!("bad number {text:?} at offset {start}"),
        }
    }

    fn array(&mut self, depth: usize) -> Result<JsonValue> {
        self.expect(b'[')?;
        let mut xs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(JsonValue::Arr(xs));
        }
        loop {
            xs.push(self.value(depth + 1)?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(JsonValue::Arr(xs));
                }
                got => bail!(
                    "expected ',' or ']' at offset {}, found {:?}",
                    self.pos,
                    got.map(|g| g as char)
                ),
            }
        }
    }

    fn object(&mut self, depth: usize) -> Result<JsonValue> {
        self.expect(b'{')?;
        let mut kvs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(JsonValue::Obj(kvs));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value(depth + 1)?;
            kvs.push((k, v));
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(JsonValue::Obj(kvs));
                }
                got => bail!(
                    "expected ',' or '}}' at offset {}, found {:?}",
                    self.pos,
                    got.map(|g| g as char)
                ),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let start = self.pos;
            // Fast path: run of plain bytes up to the next quote/escape.
            while matches!(self.peek(), Some(c) if c != b'"' && c != b'\\' && c >= 0x20)
            {
                self.pos += 1;
            }
            if self.pos > start {
                let chunk = std::str::from_utf8(&self.b[start..self.pos])
                    .map_err(|_| anyhow::anyhow!("invalid UTF-8 in string"))?;
                out.push_str(chunk);
            }
            match self.peek() {
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    self.escape_into(&mut out)?;
                }
                Some(c) => bail!("raw control byte {c:#04x} in string"),
                None => bail!("unterminated string"),
            }
        }
    }

    fn escape_into(&mut self, out: &mut String) -> Result<()> {
        let c = match self.peek() {
            Some(c) => c,
            None => bail!("unterminated escape"),
        };
        self.pos += 1;
        match c {
            b'"' => out.push('"'),
            b'\\' => out.push('\\'),
            b'/' => out.push('/'),
            b'b' => out.push('\u{0008}'),
            b'f' => out.push('\u{000C}'),
            b'n' => out.push('\n'),
            b'r' => out.push('\r'),
            b't' => out.push('\t'),
            b'u' => {
                let hi = self.hex4()?;
                let code = if (0xD800..=0xDBFF).contains(&hi) {
                    // Surrogate pair: a low surrogate escape must follow.
                    ensure!(
                        self.peek() == Some(b'\\'),
                        "lone high surrogate \\u{hi:04x}"
                    );
                    self.pos += 1;
                    self.expect(b'u')?;
                    let lo = self.hex4()?;
                    ensure!(
                        (0xDC00..=0xDFFF).contains(&lo),
                        "bad low surrogate \\u{lo:04x}"
                    );
                    0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                } else {
                    ensure!(
                        !(0xDC00..=0xDFFF).contains(&hi),
                        "lone low surrogate \\u{hi:04x}"
                    );
                    hi
                };
                match char::from_u32(code) {
                    Some(ch) => out.push(ch),
                    None => bail!("invalid code point U+{code:X}"),
                }
            }
            c => bail!("unknown escape \\{}", c as char),
        }
        Ok(())
    }

    fn hex4(&mut self) -> Result<u32> {
        let mut v: u32 = 0;
        for _ in 0..4 {
            let c = match self.peek() {
                Some(c) => c,
                None => bail!("truncated \\u escape"),
            };
            let d = match c {
                b'0'..=b'9' => u32::from(c - b'0'),
                b'a'..=b'f' => u32::from(c - b'a') + 10,
                b'A'..=b'F' => u32::from(c - b'A') + 10,
                _ => bail!("bad hex digit {:?} in \\u escape", c as char),
            };
            v = (v << 4) | d;
            self.pos += 1;
        }
        Ok(v)
    }
}

#[cfg(test)]
mod tests {
    use super::JsonValue::{self, Arr, Bool, Null, Num, Obj, Str};

    fn rt(v: &JsonValue) {
        let rendered = v.render();
        let back = JsonValue::parse(&rendered).unwrap();
        assert_eq!(&back, v, "round-trip through {rendered}");
        // Rendering is deterministic.
        assert_eq!(back.render(), rendered);
    }

    #[test]
    fn value_round_trips() {
        rt(&Null);
        rt(&Bool(true));
        rt(&Num(0.0));
        rt(&Num(-15.0));
        rt(&Num(0.1));
        rt(&Num(1e-12));
        rt(&Str(String::new()));
        rt(&Str("line\n\"quote\"\\tab\t\u{1F600}é".to_string()));
        rt(&Arr(vec![Num(1.0), Arr(vec![]), Obj(vec![])]));
        rt(&Obj(vec![
            ("a".to_string(), Num(1.5)),
            ("b".to_string(), Arr(vec![Bool(false), Null])),
        ]));
    }

    #[test]
    fn numbers_render_like_the_tsv_writer() {
        // The CLI TSV writer prints values with `{}`; integral f64s must
        // render identically here so API results diff clean against it.
        assert_eq!(Num(15.0).render(), "15");
        assert_eq!(Num(2.5).render(), "2.5");
        assert_eq!(format!("{}", 15.0f64), "15");
        // Display → parse is exact for finite doubles.
        let x = 0.1f64 + 0.2f64;
        let back: f64 = x.to_string().parse().unwrap();
        assert_eq!(back.to_bits(), x.to_bits());
    }

    #[test]
    fn non_finite_numbers_become_null() {
        assert_eq!(Num(f64::NAN).render(), "null");
        assert_eq!(Num(f64::INFINITY).render(), "null");
    }

    #[test]
    fn parses_whitespace_and_escapes() {
        let v = JsonValue::parse(
            " { \"k\" : [ 1 , -2.5e2 , \"a\\u0041\\n\" , true , null ] } ",
        )
        .unwrap();
        let arr = v.get("k").unwrap().as_array().unwrap();
        assert_eq!(arr[0].as_f64(), Some(1.0));
        assert_eq!(arr[1].as_f64(), Some(-250.0));
        assert_eq!(arr[2].as_str(), Some("aA\n"));
        assert_eq!(arr[3].as_bool(), Some(true));
        assert_eq!(arr[4], Null);
        // Surrogate pair → astral code point.
        let s = JsonValue::parse("\"\\ud83d\\ude00\"").unwrap();
        assert_eq!(s.as_str(), Some("\u{1F600}"));
    }

    #[test]
    fn rejects_malformed_documents() {
        for bad in [
            "", "{", "[1,", "{\"a\":}", "tru", "1 2", "\"\\x\"", "\"\\u12\"",
            "\"\\ud800\"", "nan", "1e999", "{\"a\" 1}", "\"unterminated",
        ] {
            assert!(JsonValue::parse(bad).is_err(), "accepted {bad:?}");
        }
        // Depth cap.
        let deep = "[".repeat(100) + &"]".repeat(100);
        assert!(JsonValue::parse(&deep).is_err());
    }

    #[test]
    fn object_lookup_and_accessors() {
        let v = JsonValue::parse("{\"a\":1,\"b\":\"x\"}").unwrap();
        assert_eq!(v.get("a").and_then(JsonValue::as_f64), Some(1.0));
        assert_eq!(v.get("b").and_then(JsonValue::as_str), Some("x"));
        assert!(v.get("c").is_none());
        assert!(Null.get("a").is_none());
        assert!(Num(1.0).as_str().is_none());
        assert!(Str("x".into()).as_array().is_none());
    }
}
