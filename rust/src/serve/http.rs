//! Minimal HTTP/1.1 plumbing for the job server.
//!
//! Hand-rolled on `std::io` for the same reason the JSON layer is: no
//! dependencies. This is deliberately not a general HTTP
//! implementation — it supports exactly what the documented API needs:
//!
//! * one request per connection (`Connection: close` on every
//!   response, so clients never have to reason about keep-alive);
//! * `Content-Length` bodies only (no chunked transfer encoding);
//! * percent-decoded query strings;
//! * hard caps on request-line/header/body sizes, so a misbehaving
//!   client cannot balloon server memory.

use std::io::{BufRead, Read, Write};
use std::time::{Duration, Instant};

use anyhow::{ensure, Context, Result};

/// Longest accepted request/header line, in bytes.
const MAX_LINE: usize = 8 * 1024;
/// Most headers accepted per request.
const MAX_HEADERS: usize = 100;
/// Largest accepted request body, in bytes.
const MAX_BODY: usize = 4 * 1024 * 1024;
/// Wall-clock budget for reading one whole request. The socket's read
/// timeout bounds each *individual* read; this bounds the *loops* — a
/// slow-loris peer trickling one header line (or one body byte) per
/// read stays under the per-read timeout forever, but not under this.
const READ_DEADLINE: Duration = Duration::from_secs(30);

/// One parsed HTTP request.
#[derive(Debug)]
pub(crate) struct Request {
    /// Request method, as sent (`GET`, `POST`, `DELETE`, …).
    pub method: String,
    /// Percent-decoded path component (no query string).
    pub path: String,
    /// Percent-decoded query parameters, in order of appearance.
    pub query: Vec<(String, String)>,
    /// Raw request body (`Content-Length` bytes).
    pub body: Vec<u8>,
}

impl Request {
    /// First value of a query parameter, if present.
    pub fn query_get(&self, key: &str) -> Option<&str> {
        self.query
            .iter()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
    }

    /// Integer query parameter with a default; `Err` carries a
    /// client-facing message for a 400 response.
    pub fn query_usize(&self, key: &str, default: usize) -> Result<usize, String> {
        match self.query_get(key) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| format!("query parameter {key:?} expects an integer, got {s:?}")),
        }
    }
}

/// Read one request from the connection. `Ok(None)` means the client
/// closed the connection cleanly before sending anything.
pub(crate) fn read_request(r: &mut dyn BufRead) -> Result<Option<Request>> {
    read_request_before(r, Instant::now() + READ_DEADLINE)
}

/// [`read_request`] against an explicit deadline: every header-loop and
/// body-loop iteration re-checks it, so the whole request read is
/// bounded even when each individual read stays under the socket
/// timeout (tests drive this directly with a near-expired deadline).
fn read_request_before(r: &mut dyn BufRead, deadline: Instant) -> Result<Option<Request>> {
    let mut line = String::new();
    let n = r
        .take_line(&mut line)
        .context("read request line")?;
    if n == 0 {
        return Ok(None);
    }
    let line = line.trim_end_matches(['\r', '\n']);
    let mut parts = line.split(' ');
    let method = parts.next().unwrap_or("").to_string();
    let target = parts.next().unwrap_or("");
    let version = parts.next().unwrap_or("");
    ensure!(
        !method.is_empty() && !target.is_empty() && version.starts_with("HTTP/1."),
        "malformed request line {line:?}"
    );

    let mut content_length: usize = 0;
    for i in 0.. {
        ensure!(i < MAX_HEADERS, "too many request headers");
        ensure!(
            Instant::now() < deadline,
            "stalled client: request headers not complete within the read deadline"
        );
        let mut h = String::new();
        let n = r.take_line(&mut h).context("read header")?;
        ensure!(n > 0, "connection closed inside headers");
        let h = h.trim_end_matches(['\r', '\n']);
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            if k.trim().eq_ignore_ascii_case("content-length") {
                content_length = v
                    .trim()
                    .parse()
                    .with_context(|| format!("bad Content-Length {:?}", v.trim()))?;
            }
        }
    }
    ensure!(
        content_length <= MAX_BODY,
        "request body of {content_length} bytes exceeds the {MAX_BODY} byte cap"
    );
    let mut body = vec![0u8; content_length];
    let mut filled = 0usize;
    while filled < content_length {
        ensure!(
            Instant::now() < deadline,
            "stalled client: request body not complete within the read deadline"
        );
        let n = r.read(&mut body[filled..]).context("read request body")?;
        ensure!(n > 0, "connection closed inside the request body");
        filled += n;
    }

    let (raw_path, raw_query) = match target.split_once('?') {
        Some((p, q)) => (p, q),
        None => (target, ""),
    };
    let query = raw_query
        .split('&')
        .filter(|kv| !kv.is_empty())
        .map(|kv| match kv.split_once('=') {
            Some((k, v)) => (percent_decode(k), percent_decode(v)),
            None => (percent_decode(kv), String::new()),
        })
        .collect();

    Ok(Some(Request {
        method,
        path: percent_decode(raw_path),
        query,
        body,
    }))
}

/// Length-capped line reader (a `read_line` that refuses to buffer an
/// unbounded line from a hostile peer).
trait TakeLine {
    fn take_line(&mut self, out: &mut String) -> std::io::Result<usize>;
}

impl<R: BufRead + ?Sized> TakeLine for R {
    fn take_line(&mut self, out: &mut String) -> std::io::Result<usize> {
        let mut buf = Vec::new();
        let mut limited = Read::take(&mut *self, MAX_LINE as u64 + 1);
        let n = limited.read_until(b'\n', &mut buf)?;
        if n > MAX_LINE {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                "line too long",
            ));
        }
        let s = String::from_utf8(buf).map_err(|_| {
            std::io::Error::new(std::io::ErrorKind::InvalidData, "non-UTF-8 line")
        })?;
        out.push_str(&s);
        Ok(n)
    }
}

/// Decode `%XX` sequences and `+` (space). Invalid sequences pass
/// through literally — the router will simply not match them.
fn percent_decode(s: &str) -> String {
    let b = s.as_bytes();
    let mut out = Vec::with_capacity(b.len());
    let mut i = 0;
    while i < b.len() {
        match b[i] {
            b'%' if i + 2 < b.len() => match (hexval(b[i + 1]), hexval(b[i + 2])) {
                (Some(hi), Some(lo)) => {
                    out.push((hi << 4) | lo);
                    i += 3;
                }
                _ => {
                    out.push(b'%');
                    i += 1;
                }
            },
            b'%' => {
                // Too close to end-of-string to decode: pass through.
                out.push(b'%');
                i += 1;
            }
            b'+' => {
                out.push(b' ');
                i += 1;
            }
            c => {
                out.push(c);
                i += 1;
            }
        }
    }
    String::from_utf8_lossy(&out).into_owned()
}

fn hexval(c: u8) -> Option<u8> {
    match c {
        b'0'..=b'9' => Some(c - b'0'),
        b'a'..=b'f' => Some(c - b'a' + 10),
        b'A'..=b'F' => Some(c - b'A' + 10),
        _ => None,
    }
}

/// Standard reason phrase for the status codes the API uses.
fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        202 => "Accepted",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        409 => "Conflict",
        410 => "Gone",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Unknown",
    }
}

/// Write a complete response and flush. Every response closes the
/// connection (`Connection: close`).
pub(crate) fn write_response(
    w: &mut dyn Write,
    status: u16,
    content_type: &str,
    body: &[u8],
) -> std::io::Result<()> {
    use std::fmt::Write as _;
    let mut head = String::new();
    let _ = write!(
        head,
        "HTTP/1.1 {status} {}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        reason(status),
        body.len()
    );
    w.write_all(head.as_bytes())?;
    w.write_all(body)?;
    w.flush()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn req(raw: &str) -> Result<Option<Request>> {
        read_request(&mut Cursor::new(raw.as_bytes().to_vec()))
    }

    #[test]
    fn parses_get_with_query() {
        let r = req("GET /v1/jobs/3/results?offset=10&limit=2&format=tsv HTTP/1.1\r\nHost: x\r\n\r\n")
            .unwrap()
            .unwrap();
        assert_eq!(r.method, "GET");
        assert_eq!(r.path, "/v1/jobs/3/results");
        assert_eq!(r.query_get("offset"), Some("10"));
        assert_eq!(r.query_usize("limit", 0).unwrap(), 2);
        assert_eq!(r.query_get("format"), Some("tsv"));
        assert_eq!(r.query_usize("missing", 7).unwrap(), 7);
        assert!(r.query_usize("format", 0).is_err());
        assert!(r.body.is_empty());
    }

    #[test]
    fn parses_post_body_by_content_length() {
        let r = req("POST /v1/jobs HTTP/1.1\r\nContent-Type: application/json\r\nContent-Length: 14\r\n\r\n{\"algo\":\"cc\"}\nEXTRA")
            .unwrap()
            .unwrap();
        assert_eq!(r.method, "POST");
        assert_eq!(r.body, b"{\"algo\":\"cc\"}\n");
    }

    #[test]
    fn percent_decoding() {
        let r = req("GET /v1/jobs?name=a%20b+c&odd=%zz&tail=%2 HTTP/1.1\r\n\r\n")
            .unwrap()
            .unwrap();
        assert_eq!(r.query_get("name"), Some("a b c"));
        assert_eq!(r.query_get("odd"), Some("%zz"));
        assert_eq!(r.query_get("tail"), Some("%2"));
    }

    #[test]
    fn clean_eof_is_none() {
        assert!(req("").unwrap().is_none());
    }

    #[test]
    fn malformed_requests_rejected() {
        assert!(req("GARBAGE\r\n\r\n").is_err());
        assert!(req("GET /x HTTP/1.1\r\nContent-Length: nope\r\n\r\n").is_err());
        assert!(req("GET /x HTTP/1.1\r\nContent-Length: 10\r\n\r\nshort").is_err());
        // Truncated mid-headers.
        assert!(req("GET /x HTTP/1.1\r\nHost: y\r\n").is_err());
        // Body cap.
        let huge = format!("POST /x HTTP/1.1\r\nContent-Length: {}\r\n\r\n", MAX_BODY + 1);
        assert!(req(&huge).is_err());
    }

    /// A peer that sends `fast` bytes immediately, then trickles one
    /// byte per read — the slow-loris shape: every individual read
    /// succeeds quickly (so a per-read socket timeout never fires), but
    /// the request as a whole never completes.
    struct Trickle {
        data: Vec<u8>,
        fast: usize,
        pos: usize,
    }

    impl Read for Trickle {
        fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
            if self.pos >= self.data.len() || buf.is_empty() {
                return Ok(0);
            }
            let n = if self.pos < self.fast {
                (self.fast - self.pos).min(buf.len())
            } else {
                std::thread::sleep(Duration::from_millis(5));
                1
            };
            buf[..n].copy_from_slice(&self.data[self.pos..self.pos + n]);
            self.pos += n;
            Ok(n)
        }
    }

    #[test]
    fn stalled_header_client_hits_the_read_deadline() {
        let mut data = b"GET /x HTTP/1.1\r\n".to_vec();
        for _ in 0..200 {
            data.extend_from_slice(b"X-Pad: y\r\n");
        }
        let mut r = std::io::BufReader::new(Trickle { data, fast: 0, pos: 0 });
        let err = read_request_before(&mut r, Instant::now() + Duration::from_millis(20))
            .unwrap_err();
        assert!(
            format!("{err:#}").contains("stalled client"),
            "wrong error: {err:#}"
        );
    }

    #[test]
    fn stalled_body_client_hits_the_read_deadline() {
        // Headers arrive instantly; the declared body trickles.
        let head = b"POST /x HTTP/1.1\r\nContent-Length: 1000\r\n\r\n".to_vec();
        let fast = head.len();
        let mut data = head;
        data.extend_from_slice(&[b'a'; 1000]);
        let mut r = std::io::BufReader::new(Trickle { data, fast, pos: 0 });
        let err = read_request_before(&mut r, Instant::now() + Duration::from_millis(20))
            .unwrap_err();
        assert!(
            format!("{err:#}").contains("request body not complete"),
            "wrong error: {err:#}"
        );
    }

    #[test]
    fn fast_clients_never_see_the_deadline() {
        // The same shapes delivered promptly parse fine through the
        // public entry point (30 s budget).
        let r = req("POST /x HTTP/1.1\r\nContent-Length: 4\r\n\r\nbody").unwrap().unwrap();
        assert_eq!(r.body, b"body");
    }

    #[test]
    fn response_bytes_are_well_formed() {
        let mut out = Vec::new();
        write_response(&mut out, 404, "application/json", b"{\"error\":\"x\"}").unwrap();
        let s = String::from_utf8(out).unwrap();
        assert!(s.starts_with("HTTP/1.1 404 Not Found\r\n"), "{s}");
        assert!(s.contains("Content-Length: 13\r\n"));
        assert!(s.contains("Connection: close\r\n"));
        assert!(s.ends_with("\r\n\r\n{\"error\":\"x\"}"));
    }
}
