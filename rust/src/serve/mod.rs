//! `goffish serve` — a resident job server over loaded GoFS stores.
//!
//! The CLI's `run` command pays the full store load on every
//! invocation; for the interactive regime the paper's analytics
//! clusters actually live in (many small jobs against one big loaded
//! graph) that is the dominant cost. This module keeps the expensive
//! part resident and makes job submission cheap:
//!
//! * [`ResidentGraph`] opens a GoFS store **once**, loads every
//!   partition into an in-memory [`DistributedGraph`], and keeps both
//!   for the server's lifetime. Every job then runs
//!   [`crate::job::JobSource::InMemory`] against it — no per-job disk
//!   I/O at all.
//! * [`Server`] accepts jobs over a minimal HTTP/1.1 API (hand-rolled
//!   on [`std::net::TcpListener`]; the crate takes no dependencies).
//!   Submitted specs go through the same [`crate::job::JobBuilder`]
//!   validation as the CLI, run on a bounded executor pool, and expose
//!   per-superstep progress, cancellation, and paged results. The full
//!   endpoint reference lives in `docs/API.md`.
//!
//! Because both engines are deterministic (sender-sorted inboxes,
//! worker-ordered folds), a job run through the server produces
//! **byte-identical** results to the same job run cold by the CLI —
//! `GET /v1/jobs/{id}/results?format=tsv` diffs clean against
//! `goffish run --output`. The integration tests
//! (`tests/serve_api.rs`) and the CI serve smoke both pin that parity.
//!
//! # Lifecycle and supervision
//!
//! Jobs are registered in an id-ordered registry and move through
//! `queued → running → done | failed | cancelled` (see
//! [`crate::serve`]'s `jobs` submodule). Each job carries a
//! [`crate::coordinator::RunControl`]: the engine manager publishes
//! the superstep through it at every barrier and honors a cancel
//! request there, so `DELETE /v1/jobs/{id}` stops a running job within
//! one superstep — the engine errors out with `job cancelled at
//! superstep N` and the registry records the state as `cancelled`.
//!
//! # Generations, refresh, and retention
//!
//! Stores are mutable across *generations* (see [`crate::gofs`]):
//! `goffish ingest`/[`Store::append`] commit new generations while a
//! server is up. The server's snapshot is pinned — executors take the
//! current [`ResidentState`] at job start, so an in-flight job never
//! sees data change under it. `POST /v1/graphs/{name}/refresh` moves
//! the resident snapshot to the store's head generation between jobs
//! (a no-op when already at head).
//!
//! Long-lived servers also bound memory with `--keep-results N`
//! ([`ServeOptions::keep_results`]): once more than `N` jobs are done,
//! the oldest done jobs drop their per-vertex values. Their status
//! stays `done` and their [`crate::metrics::JobMetrics`] (plus
//! aggregator traces) remain queryable at `GET /v1/metrics`, but
//! `GET /v1/jobs/{id}/results` answers `410 Gone`.
//!
//! # Shutdown
//!
//! [`Server::shutdown`] stops accepting connections, closes the
//! admission queue (in-flight and already-queued jobs drain), and
//! joins every thread — tests get a clean teardown; the CLI instead
//! parks in [`Server::serve_forever`] until killed.

use std::io::BufReader;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::gofs::{DistributedGraph, LoadStats, Store};

mod http;
pub mod json;
mod jobs;

use http::Request;
use jobs::{executor_loop, CancelOutcome, JobEntry, JobSpec, JobState, Jobs, SubmitError};
use json::JsonValue;

/// Idle-connection guard: a peer that stalls mid-request is dropped.
const IO_TIMEOUT: Duration = Duration::from_secs(30);

const JSON_CT: &str = "application/json";
const TSV_CT: &str = "text/tab-separated-values";
/// Prometheus text exposition format (what standard scrapers accept).
const PROM_CT: &str = "text/plain; version=0.0.4; charset=utf-8";

/// One loaded snapshot of a GoFS store: a store handle pinned to the
/// generation it opened, the in-memory [`DistributedGraph`] built from
/// it, and the load accounting. Snapshots are immutable and shared via
/// `Arc` — a job holds the one it started with for its whole run, so a
/// concurrent [`ResidentGraph::refresh`] never changes data under it
/// (the serve-level face of GoFS generation isolation).
pub struct ResidentState {
    store: Store,
    graph: DistributedGraph,
    load: LoadStats,
    /// Per-partition, per-sub-graph vertex indexes, built once per
    /// snapshot and shared (via [`crate::job::Job::with_vertex_indexes`])
    /// by every job on it — repeated jobs on a resident store skip the
    /// per-run index build entirely.
    indexes: Arc<Vec<Vec<crate::util::index::VertexIndex>>>,
}

impl ResidentState {
    fn load(root: &Path) -> Result<ResidentState> {
        let store = Store::open(root)?;
        let (graph, load) = store
            .load_all()
            .with_context(|| format!("load store at {}", root.display()))?;
        // Mirror the graph's partition layout exactly — worker p of a
        // job run against this snapshot indexes `indexes[p][i]` for
        // its i-th sub-graph.
        let indexes: Vec<Vec<crate::util::index::VertexIndex>> = graph
            .partitions
            .iter()
            .map(|sgs| {
                sgs.iter()
                    .map(|sg| crate::util::index::VertexIndex::build(&sg.vertices))
                    .collect()
            })
            .collect();
        Ok(ResidentState { store, graph, load, indexes: Arc::new(indexes) })
    }

    /// The underlying store handle (metadata: name, format, counts,
    /// pinned generation).
    pub fn store(&self) -> &Store {
        &self.store
    }

    /// The loaded distributed graph jobs run against.
    pub fn graph(&self) -> &DistributedGraph {
        &self.graph
    }

    /// Byte/file/wall accounting of this snapshot's load.
    pub fn load(&self) -> &LoadStats {
        &self.load
    }

    /// The snapshot's precomputed vertex indexes (shared by every job
    /// run against it).
    pub fn vertex_indexes(&self) -> Arc<Vec<Vec<crate::util::index::VertexIndex>>> {
        self.indexes.clone()
    }
}

/// A GoFS store loaded and kept in memory for the server's lifetime.
/// Jobs run against the current [`ResidentState`] snapshot via
/// [`crate::job::JobSource::InMemory`], so submitting a job costs no
/// disk I/O. `POST /v1/graphs/{name}/refresh` swaps the snapshot to
/// the store's head generation between jobs; executors take their
/// snapshot at job start, so in-flight runs are untouched.
pub struct ResidentGraph {
    root: PathBuf,
    state: RwLock<Arc<ResidentState>>,
}

impl ResidentGraph {
    /// Open a store directory and load every partition into memory.
    pub fn open(root: &Path) -> Result<ResidentGraph> {
        let state = Arc::new(ResidentState::load(root)?);
        Ok(ResidentGraph { root: root.to_path_buf(), state: RwLock::new(state) })
    }

    /// The current snapshot. Hold the returned `Arc` for as long as a
    /// consistent view is needed; later refreshes don't disturb it.
    pub fn snapshot(&self) -> Arc<ResidentState> {
        self.state.read().expect("resident state lock").clone()
    }

    /// Re-open the store and, if its head generation moved past the
    /// resident snapshot's, load it and swap the snapshot. Returns
    /// `(previous_generation, head_generation)`; equal values mean the
    /// refresh was a no-op (nothing was reloaded).
    pub fn refresh(&self) -> Result<(u64, u64)> {
        let pinned = self.snapshot().store().meta().generation;
        let head = Store::open(&self.root)?.meta().generation;
        if head != pinned {
            let state = Arc::new(ResidentState::load(&self.root)?);
            *self.state.write().expect("resident state lock") = state;
        }
        Ok((pinned, head))
    }
}

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServeOptions {
    /// TCP port to bind on 127.0.0.1 (0 picks an ephemeral port —
    /// read it back via [`Server::addr`]).
    pub port: u16,
    /// Executor threads: how many jobs run concurrently.
    pub workers: usize,
    /// Admission queue slots; a submit beyond this is refused with 503.
    pub queue: usize,
    /// Default cores-per-worker for jobs that don't specify `cores`.
    pub cores: usize,
    /// Result retention: keep full `JobOutput` values for at most this
    /// many done jobs. When a job finishing pushes the count over the
    /// cap, the oldest done jobs drop their values (status stays
    /// `done`, metrics stay queryable, `GET .../results` turns 410).
    /// `None` keeps everything until shutdown.
    pub keep_results: Option<usize>,
    /// Print one access-log line per request to stdout
    /// (`method path status micros req=<id>`); `serve --access-log`.
    pub access_log: bool,
}

impl Default for ServeOptions {
    fn default() -> ServeOptions {
        ServeOptions {
            port: 8080,
            workers: 2,
            queue: 16,
            cores: 4,
            keep_results: None,
            access_log: false,
        }
    }
}

/// Shared state every connection handler sees.
struct Ctx {
    jobs: Arc<Jobs>,
    resident: Arc<ResidentGraph>,
    default_cores: usize,
    access_log: bool,
}

/// A running job server. Construct with [`Server::start`]; stop with
/// [`Server::shutdown`] (tests) or park in [`Server::serve_forever`]
/// (the CLI).
pub struct Server {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
    execs: Vec<JoinHandle<()>>,
    jobs: Arc<Jobs>,
}

impl Server {
    /// Bind 127.0.0.1:`port`, spawn the executor pool and the accept
    /// loop, and return immediately.
    pub fn start(resident: ResidentGraph, opts: &ServeOptions) -> Result<Server> {
        let resident = Arc::new(resident);
        let (jobs, rx) = Jobs::new(opts.queue);
        jobs.set_keep_results(opts.keep_results);
        let jobs = Arc::new(jobs);
        let rx = Arc::new(Mutex::new(rx));
        let mut execs = Vec::new();
        for i in 0..opts.workers.max(1) {
            let rx = rx.clone();
            let res = resident.clone();
            let registry = jobs.clone();
            execs.push(
                std::thread::Builder::new()
                    .name(format!("serve-exec-{i}"))
                    .spawn(move || executor_loop(rx, res, registry))
                    .context("spawn executor thread")?,
            );
        }
        let listener = TcpListener::bind(("127.0.0.1", opts.port))
            .with_context(|| format!("bind 127.0.0.1:{}", opts.port))?;
        let addr = listener.local_addr().context("server local addr")?;
        let stop = Arc::new(AtomicBool::new(false));
        let ctx = Arc::new(Ctx {
            jobs: jobs.clone(),
            resident,
            default_cores: opts.cores.max(1),
            access_log: opts.access_log,
        });
        let accept = {
            let stop = stop.clone();
            std::thread::Builder::new()
                .name("serve-accept".to_string())
                .spawn(move || accept_loop(&listener, &stop, &ctx))
                .context("spawn accept thread")?
        };
        Ok(Server { addr, stop, accept: Some(accept), execs, jobs })
    }

    /// The bound address (resolves port 0 to the actual port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting, close the admission queue, and join every
    /// thread. Queued and running jobs drain before this returns.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // Wake the blocking accept() with a throwaway connection.
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        self.jobs.close();
        for h in self.execs.drain(..) {
            let _ = h.join();
        }
    }

    /// Block this thread for the server's lifetime (the CLI's mode:
    /// runs until the process is killed).
    pub fn serve_forever(mut self) {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

fn accept_loop(listener: &TcpListener, stop: &AtomicBool, ctx: &Arc<Ctx>) {
    loop {
        let conn = listener.accept();
        if stop.load(Ordering::SeqCst) {
            return;
        }
        if let Ok((stream, _)) = conn {
            let ctx = ctx.clone();
            let _ = std::thread::Builder::new()
                .name("serve-conn".to_string())
                .spawn(move || handle_connection(&stream, &ctx));
        }
    }
}

/// Process-wide request ids for access-log correlation (monotonic,
/// never reset — ids stay unique across the server's lifetime).
static NEXT_REQUEST_ID: AtomicU64 = AtomicU64::new(1);

fn handle_connection(stream: &TcpStream, ctx: &Ctx) {
    // A socket without timeouts can pin this thread forever on a peer
    // that stalls mid-request (or never reads the response), so a
    // failed setsockopt is grounds to drop the connection, not to
    // serve it untimed. The per-read/write timeouts bound each IO
    // call; `http::read_request` additionally bounds the whole
    // header/body read loop with a deadline (slow-loris clients stay
    // under the per-read timeout forever, but not under the deadline).
    if let Err(e) = stream
        .set_read_timeout(Some(IO_TIMEOUT))
        .and_then(|()| stream.set_write_timeout(Some(IO_TIMEOUT)))
    {
        crate::obs::registry::global().counter_add(
            "goffish_http_socket_config_failures_total",
            "Connections dropped because socket timeouts could not be armed.",
            &[],
            1,
        );
        eprintln!("[serve] dropping connection: cannot arm socket timeouts: {e}");
        return;
    }
    let request_id = NEXT_REQUEST_ID.fetch_add(1, Ordering::Relaxed);
    let start = Instant::now();
    let mut reader = BufReader::new(stream);
    let (method, path, reply) = match http::read_request(&mut reader) {
        Ok(Some(req)) => {
            let reply = route(&req, ctx);
            (req.method, req.path, reply)
        }
        Ok(None) => return, // peer closed without sending a request
        Err(e) => ("-".to_string(), "-".to_string(), error(400, &format!("{e:#}"))),
    };
    let (status, ctype, body) = reply;
    let mut w = stream;
    let _ = http::write_response(&mut w, status, ctype, &body);
    let micros = start.elapsed().as_micros() as u64;
    record_request(&method, &path, status, micros);
    if ctx.access_log {
        println!("[access] {method} {path} {status} {micros}us req={request_id}");
    }
}

/// Collapse a raw request path onto the fixed endpoint table so metric
/// label cardinality stays bounded no matter what clients send.
fn route_pattern(path: &str) -> &'static str {
    let segs: Vec<&str> = path.split('/').filter(|s| !s.is_empty()).collect();
    match segs.as_slice() {
        ["v1", "healthz"] => "/v1/healthz",
        ["v1", "graphs"] => "/v1/graphs",
        ["v1", "graphs", _, "refresh"] => "/v1/graphs/{name}/refresh",
        ["v1", "metrics"] => "/v1/metrics",
        ["v1", "jobs"] => "/v1/jobs",
        ["v1", "jobs", _] => "/v1/jobs/{id}",
        ["v1", "jobs", _, "results"] => "/v1/jobs/{id}/results",
        _ => "other",
    }
}

/// Register one served request into the process-wide metric registry:
/// a `{method, route, status}` counter and a per-route latency
/// histogram (see `docs/OBSERVABILITY.md` for the naming conventions).
fn record_request(method: &str, path: &str, status: u16, micros: u64) {
    let reg = crate::obs::registry::global();
    let method = match method {
        "GET" => "GET",
        "POST" => "POST",
        "DELETE" => "DELETE",
        _ => "other",
    };
    let route = route_pattern(path);
    let status = status.to_string();
    reg.counter_add(
        "goffish_http_requests_total",
        "HTTP requests served, by method, route pattern, and status.",
        &[("method", method), ("route", route), ("status", &status)],
        1,
    );
    reg.observe(
        "goffish_http_request_seconds",
        "HTTP request wall time from first byte read to last byte written.",
        &[("route", route)],
        crate::obs::registry::LATENCY_BUCKETS,
        micros as f64 / 1e6,
    );
}

type Reply = (u16, &'static str, Vec<u8>);

/// Build an object from `(&str, value)` pairs (key order = wire order).
fn obj(fields: Vec<(&str, JsonValue)>) -> JsonValue {
    JsonValue::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn json_ok(status: u16, v: JsonValue) -> Reply {
    (status, JSON_CT, v.render().into_bytes())
}

fn error(status: u16, msg: &str) -> Reply {
    let v = obj(vec![("error", JsonValue::Str(msg.to_string()))]);
    (status, JSON_CT, v.render().into_bytes())
}

fn route(req: &Request, ctx: &Ctx) -> Reply {
    let segs: Vec<&str> = req.path.split('/').filter(|s| !s.is_empty()).collect();
    match (req.method.as_str(), segs.as_slice()) {
        ("GET", ["v1", "healthz"]) => json_ok(200, health_json(ctx)),
        ("GET", ["v1", "graphs"]) => {
            json_ok(200, JsonValue::Arr(vec![graph_json(&ctx.resident.snapshot())]))
        }
        ("POST", ["v1", "graphs", name, "refresh"]) => refresh_graph(ctx, name),
        ("GET", ["v1", "metrics"]) => match req.query_get("format") {
            None | Some("json") => {
                let list = ctx.jobs.list().iter().map(|e| metrics_json(e)).collect();
                json_ok(200, JsonValue::Arr(list))
            }
            Some("prometheus") => metrics_prometheus(ctx),
            Some(f) => {
                error(400, &format!("unknown format {f:?} (expected json or prometheus)"))
            }
        },
        ("GET", ["v1", "jobs"]) => {
            let list = ctx.jobs.list().iter().map(|e| job_json(e)).collect();
            json_ok(200, JsonValue::Arr(list))
        }
        ("POST", ["v1", "jobs"]) => post_job(req, ctx),
        ("GET", ["v1", "jobs", id]) => with_id(id, |id| match ctx.jobs.get(id) {
            Some(e) => json_ok(200, job_json(&e)),
            None => error(404, &format!("no job {id}")),
        }),
        ("DELETE", ["v1", "jobs", id]) => with_id(id, |id| delete_job(ctx, id)),
        ("GET", ["v1", "jobs", id, "results"]) => {
            with_id(id, |id| job_results(req, ctx, id))
        }
        _ => {
            let known = matches!(
                segs.as_slice(),
                ["v1", "healthz"]
                    | ["v1", "graphs"]
                    | ["v1", "graphs", _, "refresh"]
                    | ["v1", "metrics"]
                    | ["v1", "jobs"]
                    | ["v1", "jobs", _]
                    | ["v1", "jobs", _, "results"]
            );
            if known {
                error(405, &format!("method {} not allowed here", req.method))
            } else {
                error(404, &format!("no such endpoint {}", req.path))
            }
        }
    }
}

fn with_id(raw: &str, f: impl FnOnce(u64) -> Reply) -> Reply {
    match raw.parse::<u64>() {
        Ok(id) => f(id),
        Err(_) => error(400, &format!("job id must be an integer, got {raw:?}")),
    }
}

fn post_job(req: &Request, ctx: &Ctx) -> Reply {
    let text = match std::str::from_utf8(&req.body) {
        Ok(t) => t,
        Err(_) => return error(400, "request body must be UTF-8 JSON"),
    };
    let v = match JsonValue::parse(text) {
        Ok(v) => v,
        Err(e) => return error(400, &format!("bad JSON body: {e:#}")),
    };
    let spec = match JobSpec::from_json(&v, ctx.default_cores) {
        Ok(s) => s,
        Err(msg) => return error(400, &msg),
    };
    match ctx.jobs.submit(spec) {
        Ok(entry) => json_ok(202, job_json(&entry)),
        Err(SubmitError::Invalid(msg)) => error(400, &msg),
        Err(SubmitError::QueueFull) => {
            error(503, "admission queue full; retry after a job finishes")
        }
    }
}

fn delete_job(ctx: &Ctx, id: u64) -> Reply {
    match ctx.jobs.cancel(id) {
        CancelOutcome::NotFound => error(404, &format!("no job {id}")),
        CancelOutcome::AlreadyFinished(st) => {
            error(409, &format!("job {id} already finished ({st}); nothing to cancel"))
        }
        CancelOutcome::Accepted => {
            let e = ctx.jobs.get(id).expect("cancelled job stays registered");
            json_ok(200, job_json(&e))
        }
    }
}

/// `POST /v1/graphs/{name}/refresh`: swap the resident snapshot to the
/// store's head generation. 404 for a name the server does not hold;
/// in-flight jobs keep the snapshot they started with.
fn refresh_graph(ctx: &Ctx, name: &str) -> Reply {
    let held = ctx.resident.snapshot().store().meta().name.clone();
    if name != held {
        return error(404, &format!("no resident graph {name:?} (serving {held:?})"));
    }
    match ctx.resident.refresh() {
        Ok((pinned, head)) => {
            let mut fields = vec![
                ("refreshed".to_string(), JsonValue::Bool(head != pinned)),
                ("previous_generation".to_string(), JsonValue::Num(pinned as f64)),
            ];
            // Then the graph listing's fields verbatim (incl. the new
            // generation), so clients need not re-GET /v1/graphs.
            if let JsonValue::Obj(gf) = graph_json(&ctx.resident.snapshot()) {
                fields.extend(gf);
            }
            json_ok(200, JsonValue::Obj(fields))
        }
        Err(e) => error(500, &format!("refresh failed: {e:#}")),
    }
}

fn job_results(req: &Request, ctx: &Ctx, id: u64) -> Reply {
    let Some(entry) = ctx.jobs.get(id) else {
        return error(404, &format!("no job {id}"));
    };
    let offset = match req.query_usize("offset", 0) {
        Ok(v) => v,
        Err(msg) => return error(400, &msg),
    };
    let limit = match req.query_usize("limit", 1000) {
        Ok(v) => v,
        Err(msg) => return error(400, &msg),
    };
    let tsv = match req.query_get("format") {
        None | Some("json") => false,
        Some("tsv") => true,
        Some(f) => {
            return error(400, &format!("unknown format {f:?} (expected json or tsv)"))
        }
    };
    let st = entry.state.lock().expect("job state lock");
    let out = match &*st {
        JobState::Done(out) => out,
        JobState::Evicted { .. } => {
            return error(
                410,
                &format!(
                    "job {id}'s results were evicted by the server's \
                     --keep-results retention; metrics remain at /v1/metrics"
                ),
            )
        }
        JobState::Failed(msg) => return error(409, &format!("job {id} failed: {msg}")),
        other => {
            return error(
                409,
                &format!("job {id} is {}; results exist only for done jobs", other.name()),
            )
        }
    };
    let total = out.values.len();
    let lo = offset.min(total);
    let hi = lo.saturating_add(limit).min(total);
    let page = &out.values[lo..hi];
    if tsv {
        // Byte-identical to the CLI's `run --output` TSV for the same
        // rows: `vertex<TAB>value`, `{}`-formatted.
        use std::fmt::Write as _;
        let mut body = String::with_capacity(page.len() * 12);
        for (v, x) in page {
            let _ = writeln!(body, "{v}\t{x}");
        }
        (200, TSV_CT, body.into_bytes())
    } else {
        let values = page
            .iter()
            .map(|&(v, x)| {
                JsonValue::Arr(vec![JsonValue::Num(f64::from(v)), JsonValue::Num(x)])
            })
            .collect();
        json_ok(
            200,
            obj(vec![
                ("id", JsonValue::Num(id as f64)),
                ("total", JsonValue::Num(total as f64)),
                ("offset", JsonValue::Num(lo as f64)),
                ("count", JsonValue::Num(page.len() as f64)),
                ("values", JsonValue::Arr(values)),
            ]),
        )
    }
}

/// `GET /v1/metrics?format=prometheus`: refresh the scrape-time gauges
/// from live server state (jobs by state, resident generation, one
/// series per job with live progress for running jobs), then render
/// the whole process registry — HTTP counters included — as the
/// Prometheus text format.
fn metrics_prometheus(ctx: &Ctx) -> Reply {
    let reg = crate::obs::registry::global();
    let snap = ctx.resident.snapshot();
    let graph = snap.store().meta().name.clone();
    reg.gauge_set(
        "goffish_graph_generation",
        "Store generation the resident graph snapshot is pinned to.",
        &[("graph", &graph)],
        snap.store().meta().generation as f64,
    );
    // Jobs by state: every state is always exposed (zeros included) so
    // the series set — and hence the exposition shape — is scrape-stable.
    let mut by_state =
        [("queued", 0u64), ("running", 0), ("done", 0), ("failed", 0), ("cancelled", 0)];
    for e in ctx.jobs.list() {
        let st = e.state.lock().expect("job state lock");
        let name = st.name();
        for slot in by_state.iter_mut() {
            if slot.0 == name {
                slot.1 += 1;
            }
        }
        let id = e.id.to_string();
        let labels = [("algo", e.spec.algo.as_str()), ("job", id.as_str())];
        reg.gauge_set(
            "goffish_job_superstep",
            "Superstep the engine manager last published (live while running).",
            &labels,
            e.control.superstep() as f64,
        );
        // Finished jobs report their final totals; queued/running jobs
        // report what the manager has published so far.
        let (messages, bytes) = match &*st {
            JobState::Done(out) => {
                (out.metrics.total_messages(), out.metrics.total_bytes())
            }
            JobState::Evicted { metrics, .. } => {
                (metrics.total_messages(), metrics.total_bytes())
            }
            _ => (e.control.messages(), e.control.bytes()),
        };
        reg.counter_set(
            "goffish_job_messages_total",
            "Messages the job has sent across all supersteps so far.",
            &labels,
            messages,
        );
        reg.counter_set(
            "goffish_job_bytes_total",
            "Encoded message bytes the job has sent so far.",
            &labels,
            bytes,
        );
        // Straggler ratio of the last completed superstep: live from the
        // barrier publication while running, the final superstep's value
        // once the job ends — always set, so the series never freezes on
        // a stale mid-run reading after the state leaves Running.
        let straggler = match &*st {
            JobState::Done(out) => {
                out.metrics.supersteps.last().map_or(1.0, |s| s.straggler_ratio())
            }
            JobState::Evicted { metrics, .. } => {
                metrics.supersteps.last().map_or(1.0, |s| s.straggler_ratio())
            }
            _ => e.control.straggler_ratio(),
        };
        reg.gauge_set(
            "goffish_job_straggler_ratio",
            "Slowest/next-slowest compute-time ratio of the job's last completed superstep.",
            &labels,
            straggler,
        );
        // Epochs handed to the async checkpoint flusher and not yet
        // persisted (0 for sync-mode and finished jobs — the flusher
        // drains before the run returns).
        reg.gauge_set(
            "goffish_ckpt_inflight",
            "Checkpoint writes enqueued on the async flusher and not yet persisted.",
            &labels,
            e.control.ckpt_inflight() as f64,
        );
    }
    for (state, n) in by_state {
        reg.gauge_set(
            "goffish_jobs",
            "Jobs registered on this server, by state.",
            &[("state", state)],
            n as f64,
        );
    }
    (200, PROM_CT, reg.render_prometheus().into_bytes())
}

fn job_json(e: &JobEntry) -> JsonValue {
    let st = e.state.lock().expect("job state lock");
    let mut fields = vec![
        ("id", JsonValue::Num(e.id as f64)),
        ("algo", JsonValue::Str(e.spec.algo.clone())),
        ("engine", JsonValue::Str(e.spec.engine.to_string())),
        ("status", JsonValue::Str(st.name().to_string())),
        ("superstep", JsonValue::Num(e.control.superstep() as f64)),
    ];
    match &*st {
        JobState::Done(out) => {
            fields.push((
                "supersteps",
                JsonValue::Num(out.metrics.num_supersteps() as f64),
            ));
            fields.push((
                "makespan_seconds",
                JsonValue::Num(out.metrics.makespan_seconds()),
            ));
            fields.push(("messages", JsonValue::Num(out.metrics.total_messages() as f64)));
            fields.push(("bytes", JsonValue::Num(out.metrics.total_bytes() as f64)));
            fields.push(("num_values", JsonValue::Num(out.values.len() as f64)));
        }
        JobState::Evicted { metrics, num_values } => {
            fields.push(("supersteps", JsonValue::Num(metrics.num_supersteps() as f64)));
            fields.push((
                "makespan_seconds",
                JsonValue::Num(metrics.makespan_seconds()),
            ));
            fields.push(("messages", JsonValue::Num(metrics.total_messages() as f64)));
            fields.push(("bytes", JsonValue::Num(metrics.total_bytes() as f64)));
            fields.push(("num_values", JsonValue::Num(*num_values as f64)));
            fields.push(("results_evicted", JsonValue::Bool(true)));
        }
        JobState::Failed(msg) => {
            fields.push(("error", JsonValue::Str(msg.clone())));
        }
        JobState::Running => {
            // Live progress, as last published by the engine manager at
            // a superstep barrier (superstep itself is always present
            // above; these only make sense mid-run).
            fields.push(("messages", JsonValue::Num(e.control.messages() as f64)));
            fields.push(("bytes", JsonValue::Num(e.control.bytes() as f64)));
            fields.push((
                "straggler_ratio",
                JsonValue::Num(e.control.straggler_ratio()),
            ));
        }
        _ => {}
    }
    obj(fields)
}

/// One job's entry for `GET /v1/metrics`: identity + the full
/// [`crate::metrics::JobMetrics`] summary and per-superstep aggregator
/// traces. Metrics survive result eviction.
fn metrics_json(e: &JobEntry) -> JsonValue {
    let st = e.state.lock().expect("job state lock");
    let mut fields = vec![
        ("id", JsonValue::Num(e.id as f64)),
        ("algo", JsonValue::Str(e.spec.algo.clone())),
        ("engine", JsonValue::Str(e.spec.engine.to_string())),
        ("status", JsonValue::Str(st.name().to_string())),
    ];
    let metrics = match &*st {
        JobState::Done(out) => Some(&out.metrics),
        JobState::Evicted { metrics, .. } => Some(&**metrics),
        _ => None,
    };
    if let Some(m) = metrics {
        let aggs = m
            .aggregators
            .iter()
            .map(|t| {
                obj(vec![
                    ("name", JsonValue::Str(t.name.clone())),
                    (
                        "values",
                        JsonValue::Arr(
                            t.values.iter().map(|&v| JsonValue::Num(v)).collect(),
                        ),
                    ),
                ])
            })
            .collect();
        fields.extend([
            ("supersteps", JsonValue::Num(m.num_supersteps() as f64)),
            ("load_seconds", JsonValue::Num(m.load_seconds)),
            ("load_bytes", JsonValue::Num(m.load_bytes as f64)),
            ("compute_seconds", JsonValue::Num(m.compute_seconds)),
            ("makespan_seconds", JsonValue::Num(m.makespan_seconds())),
            ("messages", JsonValue::Num(m.total_messages() as f64)),
            ("bytes", JsonValue::Num(m.total_bytes() as f64)),
            ("combined_messages", JsonValue::Num(m.total_combined() as f64)),
            ("aggregators", JsonValue::Arr(aggs)),
        ]);
    }
    obj(fields)
}

fn graph_json(r: &ResidentState) -> JsonValue {
    let m = r.store().meta();
    obj(vec![
        ("name", JsonValue::Str(m.name.clone())),
        ("format", JsonValue::Str(m.format.to_string())),
        ("generation", JsonValue::Num(m.generation as f64)),
        ("partitions", JsonValue::Num(f64::from(m.num_partitions))),
        ("subgraphs", JsonValue::Num(r.graph().num_subgraphs() as f64)),
        ("vertices", JsonValue::Num(m.num_vertices as f64)),
        ("edges", JsonValue::Num(m.num_edges as f64)),
        ("load_seconds", JsonValue::Num(r.load().seconds)),
        ("load_bytes", JsonValue::Num(r.load().bytes as f64)),
        ("load_files", JsonValue::Num(r.load().files as f64)),
    ])
}

fn health_json(ctx: &Ctx) -> JsonValue {
    obj(vec![
        ("ok", JsonValue::Bool(true)),
        ("graph", JsonValue::Str(ctx.resident.snapshot().store().meta().name.clone())),
        ("jobs", JsonValue::Num(ctx.jobs.count() as f64)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;
    use crate::partition::{Partitioner, RangePartitioner};

    /// A context with no executor pool: submitted jobs stay queued.
    /// The receiver is returned so the admission channel stays open.
    fn test_ctx(name: &str) -> (Ctx, std::sync::mpsc::Receiver<Arc<JobEntry>>) {
        let g = gen::chain(8);
        let parts = RangePartitioner.partition(&g, 2);
        let root = std::env::temp_dir()
            .join("goffish_serve_mod")
            .join(format!("{name}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&root);
        Store::create(&root, "tiny", &g, &parts).unwrap();
        let resident = ResidentGraph::open(&root).unwrap();
        let (jobs, rx) = Jobs::new(4);
        let ctx = Ctx {
            jobs: Arc::new(jobs),
            resident: Arc::new(resident),
            default_cores: 2,
            access_log: false,
        };
        (ctx, rx)
    }

    fn get(path: &str) -> Request {
        Request {
            method: "GET".to_string(),
            path: path.to_string(),
            query: Vec::new(),
            body: Vec::new(),
        }
    }

    #[test]
    fn routing_table_and_error_codes() {
        let (ctx, _rx) = test_ctx("routes");
        let (st, _, _) = route(&get("/v1/healthz"), &ctx);
        assert_eq!(st, 200);
        let (st, _, body) = route(&get("/v1/graphs"), &ctx);
        assert_eq!(st, 200);
        let v = JsonValue::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        let g0 = &v.as_array().unwrap()[0];
        assert_eq!(g0.get("name").unwrap().as_str(), Some("tiny"));
        assert_eq!(g0.get("vertices").unwrap().as_f64(), Some(8.0));

        // Unknown endpoint vs wrong method on a known one.
        let (st, _, _) = route(&get("/v2/nope"), &ctx);
        assert_eq!(st, 404);
        let mut del = get("/v1/healthz");
        del.method = "DELETE".to_string();
        let (st, _, _) = route(&del, &ctx);
        assert_eq!(st, 405);

        // Non-numeric and missing job ids.
        let (st, _, _) = route(&get("/v1/jobs/banana"), &ctx);
        assert_eq!(st, 400);
        let (st, _, _) = route(&get("/v1/jobs/42"), &ctx);
        assert_eq!(st, 404);
        let (st, _, _) = route(&get("/v1/jobs/42/results"), &ctx);
        assert_eq!(st, 404);
    }

    #[test]
    fn post_validation_errors_are_400s() {
        let (ctx, _rx) = test_ctx("post400");
        let post = |body: &str| Request {
            method: "POST".to_string(),
            path: "/v1/jobs".to_string(),
            query: Vec::new(),
            body: body.as_bytes().to_vec(),
        };
        let (st, _, body) = route(&post("not json"), &ctx);
        assert_eq!(st, 400);
        assert!(String::from_utf8(body).unwrap().contains("bad JSON body"));
        let (st, _, _) = route(&post("{\"algo\":\"frobnicate\"}"), &ctx);
        assert_eq!(st, 400);
        let (st, _, _) = route(&post("{\"algo\":\"blockrank\",\"engine\":\"vertex\"}"), &ctx);
        assert_eq!(st, 400);
        // Nothing registered by failed submits.
        assert_eq!(ctx.jobs.count(), 0);
    }

    #[test]
    fn results_of_unfinished_job_conflict() {
        let (ctx, _rx) = test_ctx("results409");
        // Submit without any executor pool: the job stays queued.
        let post = Request {
            method: "POST".to_string(),
            path: "/v1/jobs".to_string(),
            query: Vec::new(),
            body: b"{\"algo\":\"cc\"}".to_vec(),
        };
        let (st, _, body) = route(&post, &ctx);
        assert_eq!(st, 202);
        let v = JsonValue::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        assert_eq!(v.get("status").unwrap().as_str(), Some("queued"));
        let id = v.get("id").unwrap().as_f64().unwrap() as u64;
        let (st, _, _) = route(&get(&format!("/v1/jobs/{id}/results")), &ctx);
        assert_eq!(st, 409);
        // Queued jobs cancel instantly.
        let mut del = get(&format!("/v1/jobs/{id}"));
        del.method = "DELETE".to_string();
        let (st, _, body) = route(&del, &ctx);
        assert_eq!(st, 200);
        let v = JsonValue::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        assert_eq!(v.get("status").unwrap().as_str(), Some("cancelled"));
        // A second DELETE stays 200 (idempotent); results now 409 too.
        let (st, _, _) = route(&del, &ctx);
        assert_eq!(st, 200);
    }

    #[test]
    fn refresh_route_checks_name_and_reports_generation() {
        let (ctx, _rx) = test_ctx("refresh");
        let post = |path: &str| Request {
            method: "POST".to_string(),
            path: path.to_string(),
            query: Vec::new(),
            body: Vec::new(),
        };
        // The server holds "tiny"; any other name is a 404.
        let (st, _, body) = route(&post("/v1/graphs/nope/refresh"), &ctx);
        assert_eq!(st, 404);
        assert!(String::from_utf8(body).unwrap().contains("\"tiny\""));
        // Refreshing the held graph with no newer generation is a
        // 200 no-op that still reports the full graph listing.
        let (st, _, body) = route(&post("/v1/graphs/tiny/refresh"), &ctx);
        assert_eq!(st, 200);
        let v = JsonValue::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        assert_eq!(v.get("refreshed").unwrap().as_bool(), Some(false));
        assert_eq!(v.get("previous_generation").unwrap().as_f64(), Some(0.0));
        assert_eq!(v.get("generation").unwrap().as_f64(), Some(0.0));
        assert_eq!(v.get("name").unwrap().as_str(), Some("tiny"));
        // GET on the refresh path is a method error, not an unknown path.
        let (st, _, _) = route(&get("/v1/graphs/tiny/refresh"), &ctx);
        assert_eq!(st, 405);
    }

    #[test]
    fn prometheus_exposition_matches_json_metrics() {
        let (ctx, _rx) = test_ctx("prom_parity");
        let post = Request {
            method: "POST".to_string(),
            path: "/v1/jobs".to_string(),
            query: Vec::new(),
            body: b"{\"algo\":\"cc\"}".to_vec(),
        };
        let (st, _, body) = route(&post, &ctx);
        assert_eq!(st, 202);
        let v = JsonValue::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        let id = v.get("id").unwrap().as_f64().unwrap() as u64;

        // Force a finished job with known totals (2 supersteps,
        // 5 + 2 messages, 80 + 32 bytes), values already evicted.
        let entry = ctx.jobs.get(id).unwrap();
        let mut metrics = crate::metrics::JobMetrics::default();
        for (m, b) in [(5u64, 80u64), (2, 32)] {
            metrics.supersteps.push(crate::metrics::SuperstepMetrics {
                messages: m,
                bytes: b,
                ..Default::default()
            });
        }
        *entry.state.lock().unwrap() =
            JobState::Evicted { metrics: Box::new(metrics), num_values: 8 };

        // The JSON report for the same job.
        let (st, ct, body) = route(&get("/v1/metrics"), &ctx);
        assert_eq!((st, ct), (200, JSON_CT));
        let v = JsonValue::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        let m = &v.as_array().unwrap()[0];
        let json_msgs = m.get("messages").unwrap().as_f64().unwrap();
        let json_bytes = m.get("bytes").unwrap().as_f64().unwrap();
        assert_eq!((json_msgs, json_bytes), (7.0, 112.0));

        // The prometheus exposition must agree, value for value.
        let mut prom = get("/v1/metrics");
        prom.query.push(("format".to_string(), "prometheus".to_string()));
        let (st, ct, body) = route(&prom, &ctx);
        assert_eq!((st, ct), (200, PROM_CT));
        let text = String::from_utf8(body).unwrap();
        let labels = format!("{{algo=\"cc\",job=\"{id}\"}}");
        assert!(
            text.contains(&format!("goffish_job_messages_total{labels} {json_msgs}")),
            "{text}"
        );
        assert!(
            text.contains(&format!("goffish_job_bytes_total{labels} {json_bytes}")),
            "{text}"
        );
        assert!(text.contains(&format!("goffish_job_superstep{labels} 0")), "{text}");
        assert!(text.contains("goffish_jobs{state=\"done\"} 1"), "{text}");
        assert!(text.contains("goffish_graph_generation{graph=\"tiny\"} 0"), "{text}");

        // Unknown formats are 400s, and the default stays JSON (the CI
        // smoke greps `"supersteps"` out of the default response).
        let mut bad = get("/v1/metrics");
        bad.query.push(("format".to_string(), "xml".to_string()));
        let (st, _, _) = route(&bad, &ctx);
        assert_eq!(st, 400);
    }

    #[test]
    fn route_patterns_bound_label_cardinality() {
        assert_eq!(route_pattern("/v1/jobs/17"), "/v1/jobs/{id}");
        assert_eq!(route_pattern("/v1/jobs/17/results"), "/v1/jobs/{id}/results");
        assert_eq!(route_pattern("/v1/graphs/tiny/refresh"), "/v1/graphs/{name}/refresh");
        assert_eq!(route_pattern("/v1/metrics"), "/v1/metrics");
        assert_eq!(route_pattern("/anything/else"), "other");
        assert_eq!(route_pattern("-"), "other");
    }

    #[test]
    fn metrics_route_survives_result_eviction() {
        let (ctx, _rx) = test_ctx("metrics410");
        let post = Request {
            method: "POST".to_string(),
            path: "/v1/jobs".to_string(),
            query: Vec::new(),
            body: b"{\"algo\":\"cc\"}".to_vec(),
        };
        let (st, _, body) = route(&post, &ctx);
        assert_eq!(st, 202);
        let v = JsonValue::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        let id = v.get("id").unwrap().as_f64().unwrap() as u64;

        // Force the retention outcome directly: a done job whose
        // values were dropped but whose metrics were kept.
        let entry = ctx.jobs.get(id).unwrap();
        let mut metrics = crate::metrics::JobMetrics::default();
        metrics.aggregators.push(crate::coordinator::AggregatorTrace {
            name: "frontier".to_string(),
            values: vec![3.0, 1.0, 0.0],
        });
        *entry.state.lock().unwrap() =
            JobState::Evicted { metrics: Box::new(metrics), num_values: 8 };

        // Results are gone (410, pointing at /v1/metrics)…
        let (st, _, body) = route(&get(&format!("/v1/jobs/{id}/results")), &ctx);
        assert_eq!(st, 410);
        assert!(String::from_utf8(body).unwrap().contains("/v1/metrics"));
        // …the job listing flags the eviction but stays "done"…
        let (st, _, body) = route(&get(&format!("/v1/jobs/{id}")), &ctx);
        assert_eq!(st, 200);
        let v = JsonValue::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        assert_eq!(v.get("status").unwrap().as_str(), Some("done"));
        assert_eq!(v.get("results_evicted").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("num_values").unwrap().as_f64(), Some(8.0));
        // …and /v1/metrics still serves the full metric set including
        // the per-superstep aggregator trace.
        let (st, _, body) = route(&get("/v1/metrics"), &ctx);
        assert_eq!(st, 200);
        let v = JsonValue::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        let m = &v.as_array().unwrap()[0];
        assert_eq!(m.get("status").unwrap().as_str(), Some("done"));
        assert!(m.get("supersteps").is_some());
        assert!(m.get("makespan_seconds").is_some());
        let aggs = m.get("aggregators").unwrap().as_array().unwrap();
        assert_eq!(aggs[0].get("name").unwrap().as_str(), Some("frontier"));
        assert_eq!(aggs[0].get("values").unwrap().as_array().unwrap().len(), 3);
    }
}
