//! GoFFish — a sub-graph centric framework for large-scale graph analytics.
//!
//! Reproduction of Simmhan et al., "GoFFish: A Sub-Graph Centric Framework
//! for Large-Scale Graph Analytics" (2013) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the GoFFish system itself: the `gofs`
//!   distributed sub-graph aware graph store, the `gopher` sub-graph centric
//!   BSP engine, a Giraph-like `pregel` vertex-centric baseline, the unified
//!   `job` layer (one builder-driven entry point + `algos::registry` over
//!   both engines), graph substrates (`graph`, `partition`), the simulated
//!   commodity cluster (`sim`), and the benchmark/metrics machinery
//!   (`metrics`, `bench`).
//! * **Layer 2** — JAX compute graphs for the per-sub-graph numeric hot
//!   spots (PageRank rank updates, min-plus SSSP relaxation), lowered
//!   ahead-of-time to HLO text (`python/compile/model.py`).
//! * **Layer 1** — Pallas kernels implementing the blocked rank-update /
//!   relaxation inner loops (`python/compile/kernels/`), called from L2 and
//!   validated against pure-jnp oracles.
//!
//! Python never runs on the request path: `runtime` loads the AOT HLO
//! artifacts via PJRT and executes them from Gopher's superstep hot loop.

// Public API documentation is enforced module-by-module: modules that
// have had a docs audit warn on any undocumented public item; the rest
// carry an explicit allow until their audit lands. Burn-down: remove an
// `#[allow]` below after documenting that module's public surface.
#![warn(missing_docs)]

#[allow(missing_docs)]
pub mod util;
#[allow(missing_docs)]
pub mod graph;
#[allow(missing_docs)]
pub mod partition;
pub mod gofs;
pub mod ingest;
pub mod ckpt;
#[allow(missing_docs)]
pub mod coordinator;
#[allow(missing_docs)]
pub mod gopher;
#[allow(missing_docs)]
pub mod pregel;
#[allow(missing_docs)]
pub mod algos;
pub mod job;
#[allow(missing_docs)]
pub mod runtime;
pub mod serve;
#[allow(missing_docs)]
pub mod sim;
#[allow(missing_docs)]
pub mod metrics;
pub mod obs;
#[allow(missing_docs)]
pub mod bench;
#[allow(missing_docs)]
pub mod cli;
#[allow(missing_docs)]
pub mod testing;
