//! Process-wide metric registry with Prometheus text exposition.
//!
//! Dependency-free and deliberately small: named **counters**,
//! **gauges**, and **fixed-bucket histograms**, each series keyed by a
//! label set. The registry renders the Prometheus text format
//! (`# HELP` / `# TYPE` + samples) that standard scrapers ingest —
//! the serve layer exposes it at `GET /v1/metrics?format=prometheus`.
//!
//! Design points:
//!
//! * **Byte-stable exposition.** Families live in a `BTreeMap` keyed
//!   by metric name and series in a `BTreeMap` keyed by their rendered
//!   label set, so two scrapes of the same state produce identical
//!   bytes — the golden test below pins the exact format.
//! * **Register-on-first-touch.** [`Registry::counter_add`] & friends
//!   carry the help text; the first call for a name creates the family.
//!   Updating a name with the wrong kind is ignored (never panics on
//!   the serve path).
//! * **Const-constructible.** [`global`] hands out a `'static` registry
//!   backed by `static Registry` (const `Mutex` + `BTreeMap`), so the
//!   HTTP layer needs no init hook. Tests that want isolation build
//!   their own `Registry::new()`.
//!
//! Naming conventions (see `docs/OBSERVABILITY.md` for the full list):
//! everything is prefixed `goffish_`, counters end in `_total`,
//! histograms carry base-unit `_seconds`, and label cardinality is
//! bounded (HTTP paths are normalized route patterns, never raw URLs).

use std::collections::BTreeMap;
use std::sync::Mutex;

/// Fixed latency buckets (seconds) for HTTP request histograms: spans
/// sub-millisecond cache hits to multi-second resident-job queries.
pub const LATENCY_BUCKETS: &[f64] = &[0.001, 0.005, 0.025, 0.1, 0.5, 2.5];

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Kind {
    Counter,
    Gauge,
    Histogram,
}

impl Kind {
    fn name(self) -> &'static str {
        match self {
            Kind::Counter => "counter",
            Kind::Gauge => "gauge",
            Kind::Histogram => "histogram",
        }
    }
}

#[derive(Clone, Debug)]
enum Series {
    Counter(u64),
    Gauge(f64),
    Histogram { bounds: &'static [f64], counts: Vec<u64>, sum: f64, count: u64 },
}

struct Family {
    help: &'static str,
    kind: Kind,
    /// Rendered label set (`k1="v1",k2="v2"`, insertion-key sorted by
    /// the BTreeMap) → series.
    series: BTreeMap<String, Series>,
}

/// A metric registry; see the module docs. Use [`global`] for the
/// process-wide instance.
pub struct Registry {
    inner: Mutex<BTreeMap<&'static str, Family>>,
}

static GLOBAL: Registry = Registry::new();

/// The process-wide registry every layer registers into.
pub fn global() -> &'static Registry {
    &GLOBAL
}

/// Render one label set as it appears inside `{}`. Values are escaped
/// per the exposition format (`\\`, `\"`, `\n`).
fn label_set(labels: &[(&str, &str)]) -> String {
    let mut out = String::new();
    for (i, (k, v)) in labels.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(k);
        out.push_str("=\"");
        for c in v.chars() {
            match c {
                '\\' => out.push_str("\\\\"),
                '"' => out.push_str("\\\""),
                '\n' => out.push_str("\\n"),
                c => out.push(c),
            }
        }
        out.push('"');
    }
    out
}

/// `f64` → exposition text: integral values drop the fraction, `+Inf`
/// spells the histogram's last bucket bound.
fn num(v: f64) -> String {
    if v == f64::INFINITY {
        "+Inf".to_string()
    } else {
        v.to_string()
    }
}

impl Registry {
    /// An empty registry (const: usable in `static`s).
    pub const fn new() -> Registry {
        Registry { inner: Mutex::new(BTreeMap::new()) }
    }

    fn update(
        &self,
        name: &'static str,
        help: &'static str,
        kind: Kind,
        labels: &[(&str, &str)],
        f: impl FnOnce(&mut Series),
        init: impl FnOnce() -> Series,
    ) {
        let mut inner = self.inner.lock().expect("metric registry lock");
        let fam = inner
            .entry(name)
            .or_insert_with(|| Family { help, kind, series: BTreeMap::new() });
        if fam.kind != kind {
            // A name re-registered with a different kind is a caller
            // bug, but the serve path must never panic over telemetry:
            // the update is dropped and the original family stands.
            return;
        }
        let series = fam.series.entry(label_set(labels)).or_insert_with(init);
        f(series);
    }

    /// Add to a counter (creating it at 0 first).
    pub fn counter_add(
        &self,
        name: &'static str,
        help: &'static str,
        labels: &[(&str, &str)],
        v: u64,
    ) {
        self.update(
            name,
            help,
            Kind::Counter,
            labels,
            |s| {
                if let Series::Counter(c) = s {
                    *c += v;
                }
            },
            || Series::Counter(0),
        );
    }

    /// Set a counter to an absolute cumulative value — for series whose
    /// source already accumulates (e.g. per-job message totals read
    /// from the engine at scrape time).
    pub fn counter_set(
        &self,
        name: &'static str,
        help: &'static str,
        labels: &[(&str, &str)],
        v: u64,
    ) {
        self.update(
            name,
            help,
            Kind::Counter,
            labels,
            |s| {
                if let Series::Counter(c) = s {
                    *c = v;
                }
            },
            || Series::Counter(0),
        );
    }

    /// Set a gauge.
    pub fn gauge_set(
        &self,
        name: &'static str,
        help: &'static str,
        labels: &[(&str, &str)],
        v: f64,
    ) {
        self.update(
            name,
            help,
            Kind::Gauge,
            labels,
            |s| {
                if let Series::Gauge(g) = s {
                    *g = v;
                }
            },
            || Series::Gauge(0.0),
        );
    }

    /// Record one observation into a fixed-bucket histogram. `bounds`
    /// must be ascending; an implicit `+Inf` bucket is always appended.
    /// The bounds of the *first* observation for a series win.
    pub fn observe(
        &self,
        name: &'static str,
        help: &'static str,
        labels: &[(&str, &str)],
        bounds: &'static [f64],
        v: f64,
    ) {
        self.update(
            name,
            help,
            Kind::Histogram,
            labels,
            |s| {
                if let Series::Histogram { bounds, counts, sum, count } = s {
                    for (i, b) in bounds.iter().enumerate() {
                        if v <= *b {
                            counts[i] += 1;
                        }
                    }
                    *counts.last_mut().expect("+Inf bucket") += 1;
                    *sum += v;
                    *count += 1;
                }
            },
            || Series::Histogram {
                bounds,
                counts: vec![0; bounds.len() + 1],
                sum: 0.0,
                count: 0,
            },
        );
    }

    /// Render the Prometheus text exposition format (version 0.0.4).
    pub fn render_prometheus(&self) -> String {
        let inner = self.inner.lock().expect("metric registry lock");
        let mut out = String::new();
        for (name, fam) in inner.iter() {
            out.push_str(&format!("# HELP {name} {}\n", fam.help));
            out.push_str(&format!("# TYPE {name} {}\n", fam.kind.name()));
            for (labels, series) in &fam.series {
                match series {
                    Series::Counter(c) => {
                        if labels.is_empty() {
                            out.push_str(&format!("{name} {c}\n"));
                        } else {
                            out.push_str(&format!("{name}{{{labels}}} {c}\n"));
                        }
                    }
                    Series::Gauge(g) => {
                        if labels.is_empty() {
                            out.push_str(&format!("{name} {}\n", num(*g)));
                        } else {
                            out.push_str(&format!("{name}{{{labels}}} {}\n", num(*g)));
                        }
                    }
                    Series::Histogram { bounds, counts, sum, count } => {
                        for (i, c) in counts.iter().enumerate() {
                            let le = bounds.get(i).copied().unwrap_or(f64::INFINITY);
                            let sep = if labels.is_empty() { "" } else { "," };
                            out.push_str(&format!(
                                "{name}_bucket{{{labels}{sep}le=\"{}\"}} {c}\n",
                                num(le)
                            ));
                        }
                        if labels.is_empty() {
                            out.push_str(&format!("{name}_sum {}\n", num(*sum)));
                            out.push_str(&format!("{name}_count {count}\n"));
                        } else {
                            out.push_str(&format!("{name}_sum{{{labels}}} {}\n", num(*sum)));
                            out.push_str(&format!("{name}_count{{{labels}}} {count}\n"));
                        }
                    }
                }
            }
        }
        out
    }
}

impl Default for Registry {
    fn default() -> Registry {
        Registry::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The golden test the issue asks for: exact text-format bytes for
    /// a fixed registry.
    #[test]
    fn prometheus_exposition_golden_bytes() {
        let r = Registry::new();
        r.counter_add(
            "goffish_http_requests_total",
            "HTTP requests served.",
            &[("method", "GET"), ("path", "/v1/jobs"), ("status", "200")],
            3,
        );
        r.counter_add(
            "goffish_http_requests_total",
            "HTTP requests served.",
            &[("method", "GET"), ("path", "/v1/jobs"), ("status", "200")],
            2,
        );
        r.gauge_set("goffish_jobs", "Jobs by state.", &[("state", "running")], 2.0);
        r.observe(
            "goffish_http_request_seconds",
            "Request latency.",
            &[],
            &[0.001, 0.01],
            0.005,
        );
        let expected = "\
# HELP goffish_http_request_seconds Request latency.
# TYPE goffish_http_request_seconds histogram
goffish_http_request_seconds_bucket{le=\"0.001\"} 0
goffish_http_request_seconds_bucket{le=\"0.01\"} 1
goffish_http_request_seconds_bucket{le=\"+Inf\"} 1
goffish_http_request_seconds_sum 0.005
goffish_http_request_seconds_count 1
# HELP goffish_http_requests_total HTTP requests served.
# TYPE goffish_http_requests_total counter
goffish_http_requests_total{method=\"GET\",path=\"/v1/jobs\",status=\"200\"} 5
# HELP goffish_jobs Jobs by state.
# TYPE goffish_jobs gauge
goffish_jobs{state=\"running\"} 2
";
        assert_eq!(r.render_prometheus(), expected);
        // Byte-stable: a second render is identical.
        assert_eq!(r.render_prometheus(), expected);
    }

    #[test]
    fn histogram_buckets_are_cumulative() {
        let r = Registry::new();
        let b: &'static [f64] = &[0.1, 1.0];
        for v in [0.05, 0.5, 5.0] {
            r.observe("h_seconds", "h", &[("path", "/x")], b, v);
        }
        let text = r.render_prometheus();
        assert!(text.contains("h_seconds_bucket{path=\"/x\",le=\"0.1\"} 1\n"), "{text}");
        assert!(text.contains("h_seconds_bucket{path=\"/x\",le=\"1\"} 2\n"), "{text}");
        assert!(text.contains("h_seconds_bucket{path=\"/x\",le=\"+Inf\"} 3\n"), "{text}");
        assert!(text.contains("h_seconds_count{path=\"/x\"} 3\n"), "{text}");
    }

    #[test]
    fn counter_set_and_label_escaping() {
        let r = Registry::new();
        r.counter_set("jobs_msgs_total", "m", &[("job", "1")], 42);
        r.counter_set("jobs_msgs_total", "m", &[("job", "1")], 99);
        r.gauge_set("g", "g", &[("k", "a\"b\\c\nd")], 1.0);
        let text = r.render_prometheus();
        assert!(text.contains("jobs_msgs_total{job=\"1\"} 99\n"), "{text}");
        assert!(text.contains("g{k=\"a\\\"b\\\\c\\nd\"} 1\n"), "{text}");
    }

    #[test]
    fn kind_conflicts_are_ignored_not_fatal() {
        let r = Registry::new();
        r.counter_add("x_total", "x", &[], 1);
        // Re-registering the name as a gauge is dropped, not fatal.
        r.gauge_set("x_total", "x", &[], 5.0);
        let text = r.render_prometheus();
        assert!(text.contains("# TYPE x_total counter\n"), "{text}");
        assert!(text.contains("x_total 1\n"), "{text}");
    }

    #[test]
    fn global_registry_is_shared() {
        global().counter_add("obs_registry_selftest_total", "self-test", &[], 1);
        assert!(global().render_prometheus().contains("obs_registry_selftest_total"));
    }
}
