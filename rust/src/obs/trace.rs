//! Span tracing with Chrome trace-event JSON output.
//!
//! The design keeps the disabled path free: a [`Tracer`] is an
//! `Option<Arc<TraceSink>>`, engine call sites hold an
//! `Option<Recorder>`, and every span open is
//! `rec.as_ref().map(|r| r.span(...))` — one branch, no allocation,
//! no clock read when tracing is off (the `trace_overhead` bench rows
//! pin this). When tracing is on:
//!
//! * Each thread (engine worker or manager) gets its own [`Recorder`]
//!   from [`Tracer::recorder`], buffering events locally so workers
//!   never contend on a lock inside a superstep; the buffer drains into
//!   the shared sink when the recorder drops (or on explicit
//!   [`Recorder::flush`]).
//! * [`SpanGuard`] is RAII over a monotonic [`Instant`]: opening a span
//!   stamps the start, dropping it appends one complete (`"ph":"X"`)
//!   event. Span names and categories are `&'static str` and the
//!   optional argument is a fixed `(key, f64)` pair, so recording a
//!   span allocates nothing.
//! * [`TraceSink::to_json`] renders the standard Chrome trace-event
//!   object — load the file in Perfetto (<https://ui.perfetto.dev>) or
//!   `chrome://tracing`. Nesting is implicit: spans on the same `tid`
//!   whose `[ts, ts+dur]` ranges contain one another render as a stack.
//!
//! The span taxonomy (who opens what, on which tid) is documented in
//! `docs/OBSERVABILITY.md`.

use std::cell::RefCell;
use std::fmt;
use std::path::Path;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::serve::json::JsonValue;

/// One completed span: a Chrome trace-event `"ph":"X"` record.
#[derive(Clone, Debug)]
pub struct Event {
    /// Span name (`"compute"`, `"superstep"`, …).
    pub name: &'static str,
    /// Category (`"phase"`, `"load"`, `"ckpt"`, `"ingest"`, …).
    pub cat: &'static str,
    /// Thread lane: 0 = manager, worker `p` records on `p + 1`.
    pub tid: u32,
    /// Microseconds since the sink's origin.
    pub ts_us: u64,
    /// Span duration in microseconds.
    pub dur_us: u64,
    /// Optional fixed argument (rendered under `"args"`).
    pub arg: Option<(&'static str, f64)>,
}

/// Totals of the four in-superstep phases across all workers, summed
/// from a trace. Attached to [`crate::metrics::JobMetrics::phases`]
/// when a job ran with tracing, so `report()` can break a superstep
/// wall down into where the time actually went.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct PhaseTotals {
    /// Σ `compute` span seconds (all workers, all supersteps).
    pub compute_seconds: f64,
    /// Σ `route` span seconds.
    pub route_seconds: f64,
    /// Σ `drain` span seconds.
    pub drain_seconds: f64,
    /// Σ `barrier` span seconds (sync send through resume receive).
    pub barrier_seconds: f64,
}

/// The per-job event collector every [`Recorder`] drains into.
pub struct TraceSink {
    origin: Instant,
    events: Mutex<Vec<Event>>,
}

impl TraceSink {
    fn new() -> TraceSink {
        TraceSink { origin: Instant::now(), events: Mutex::new(Vec::new()) }
    }

    fn absorb(&self, mut buf: Vec<Event>) {
        self.events.lock().expect("trace sink lock").append(&mut buf);
    }

    /// Snapshot of every recorded event, in flush order.
    pub fn events(&self) -> Vec<Event> {
        self.events.lock().expect("trace sink lock").clone()
    }

    /// Sum phase-span durations by name (see [`PhaseTotals`]).
    pub fn phase_totals(&self) -> PhaseTotals {
        let mut t = PhaseTotals::default();
        for e in self.events.lock().expect("trace sink lock").iter() {
            let secs = e.dur_us as f64 / 1e6;
            match e.name {
                "compute" => t.compute_seconds += secs,
                "route" => t.route_seconds += secs,
                "drain" => t.drain_seconds += secs,
                "barrier" => t.barrier_seconds += secs,
                _ => {}
            }
        }
        t
    }

    /// Render the Chrome trace-event object: `{"traceEvents":[...]}`.
    /// Events are sorted by `(tid, ts)` so output is deterministic for
    /// a given set of recorded spans.
    pub fn to_json(&self) -> JsonValue {
        let mut events = self.events();
        events.sort_by(|a, b| (a.tid, a.ts_us, a.dur_us).cmp(&(b.tid, b.ts_us, b.dur_us)));
        let rows = events
            .iter()
            .map(|e| {
                let mut fields = vec![
                    ("name".to_string(), JsonValue::Str(e.name.to_string())),
                    ("cat".to_string(), JsonValue::Str(e.cat.to_string())),
                    ("ph".to_string(), JsonValue::Str("X".to_string())),
                    ("ts".to_string(), JsonValue::Num(e.ts_us as f64)),
                    ("dur".to_string(), JsonValue::Num(e.dur_us as f64)),
                    ("pid".to_string(), JsonValue::Num(1.0)),
                    ("tid".to_string(), JsonValue::Num(f64::from(e.tid))),
                ];
                if let Some((k, v)) = e.arg {
                    fields.push((
                        "args".to_string(),
                        JsonValue::Obj(vec![(k.to_string(), JsonValue::Num(v))]),
                    ));
                }
                JsonValue::Obj(fields)
            })
            .collect();
        JsonValue::Obj(vec![("traceEvents".to_string(), JsonValue::Arr(rows))])
    }

    /// Write the trace file (see [`TraceSink::to_json`]).
    pub fn write_file(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().render())
            .with_context(|| format!("write trace to {}", path.display()))
    }
}

/// A per-job tracing handle: `Default` is disabled (a no-op that costs
/// one branch per would-be span); [`Tracer::enabled`] allocates the
/// shared [`TraceSink`]. Cloning shares the sink, so engine configs can
/// carry it by value.
#[derive(Clone, Default)]
pub struct Tracer(Option<Arc<TraceSink>>);

impl fmt::Debug for Tracer {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(if self.0.is_some() { "Tracer(on)" } else { "Tracer(off)" })
    }
}

impl Tracer {
    /// A tracer with a live sink.
    pub fn enabled() -> Tracer {
        Tracer(Some(Arc::new(TraceSink::new())))
    }

    /// Whether spans are being recorded.
    pub fn is_enabled(&self) -> bool {
        self.0.is_some()
    }

    /// A thread-local recorder on lane `tid`, or `None` when disabled —
    /// call sites keep the `Option` and never touch the clock when off.
    pub fn recorder(&self, tid: u32) -> Option<Recorder> {
        self.0.as_ref().map(|sink| Recorder {
            sink: sink.clone(),
            tid,
            buf: RefCell::new(Vec::new()),
        })
    }

    /// The shared sink, when enabled.
    pub fn sink(&self) -> Option<&Arc<TraceSink>> {
        self.0.as_ref()
    }

    /// Phase totals recorded so far (`None` when disabled).
    pub fn phase_totals(&self) -> Option<PhaseTotals> {
        self.0.as_ref().map(|s| s.phase_totals())
    }

    /// Write the Chrome-trace file; a no-op when disabled.
    pub fn write_file(&self, path: &Path) -> Result<()> {
        match &self.0 {
            Some(sink) => sink.write_file(path),
            None => Ok(()),
        }
    }
}

/// One thread's event buffer. Spans open with [`Recorder::span`] /
/// [`Recorder::span_n`]; completed spans accumulate locally and drain
/// into the sink on drop or [`Recorder::flush`].
pub struct Recorder {
    sink: Arc<TraceSink>,
    tid: u32,
    buf: RefCell<Vec<Event>>,
}

impl Recorder {
    /// Open a span; it closes (records) when the guard drops.
    pub fn span<'a>(&'a self, name: &'static str, cat: &'static str) -> SpanGuard<'a> {
        SpanGuard { rec: self, name, cat, start: Instant::now(), arg: None }
    }

    /// Open a span carrying one numeric argument (e.g. the superstep
    /// number).
    pub fn span_n<'a>(
        &'a self,
        name: &'static str,
        cat: &'static str,
        key: &'static str,
        value: f64,
    ) -> SpanGuard<'a> {
        SpanGuard { rec: self, name, cat, start: Instant::now(), arg: Some((key, value)) }
    }

    /// Drain buffered events into the sink now (drop does this too).
    pub fn flush(&self) {
        let buf = std::mem::take(&mut *self.buf.borrow_mut());
        if !buf.is_empty() {
            self.sink.absorb(buf);
        }
    }

    fn record(&self, name: &'static str, cat: &'static str, start: Instant, arg: Option<(&'static str, f64)>) {
        // Floor both endpoints against the sink origin and derive the
        // duration from the floored pair, so the rendered end
        // (`ts + dur`) is exactly the floored end time. Flooring the
        // start and the duration independently would let a child span
        // that closes nanoseconds before its parent render an end 1us
        // *past* the parent's, breaking nesting in the output.
        let ts_us = start.saturating_duration_since(self.sink.origin).as_micros() as u64;
        let end_us = self.sink.origin.elapsed().as_micros() as u64;
        let dur_us = end_us.saturating_sub(ts_us);
        self.buf.borrow_mut().push(Event { name, cat, tid: self.tid, ts_us, dur_us, arg });
    }
}

impl Drop for Recorder {
    fn drop(&mut self) {
        let buf = std::mem::take(self.buf.get_mut());
        if !buf.is_empty() {
            self.sink.absorb(buf);
        }
    }
}

/// RAII span: created by [`Recorder::span`], records one complete
/// trace event when dropped.
pub struct SpanGuard<'a> {
    rec: &'a Recorder,
    name: &'static str,
    cat: &'static str,
    start: Instant,
    arg: Option<(&'static str, f64)>,
}

impl Drop for SpanGuard<'_> {
    fn drop(&mut self) {
        self.rec.record(self.name, self.cat, self.start, self.arg);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_tracer_hands_out_no_recorders() {
        let t = Tracer::default();
        assert!(!t.is_enabled());
        assert!(t.recorder(0).is_none());
        assert!(t.sink().is_none());
        assert!(t.phase_totals().is_none());
        // The engine idiom: one Option branch, nothing else.
        let rec = t.recorder(1);
        let _g = rec.as_ref().map(|r| r.span("compute", "phase"));
    }

    #[test]
    fn spans_nest_and_flush_into_the_sink() {
        let t = Tracer::enabled();
        {
            let rec = t.recorder(1).unwrap();
            let ss = rec.span_n("superstep", "superstep", "superstep", 1.0);
            {
                let _c = rec.span("compute", "phase");
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
            {
                let _r = rec.span("route", "phase");
            }
            drop(ss);
        } // recorder drop flushes
        let sink = t.sink().unwrap();
        let events = sink.events();
        assert_eq!(events.len(), 3);
        let ss = events.iter().find(|e| e.name == "superstep").unwrap();
        let compute = events.iter().find(|e| e.name == "compute").unwrap();
        assert_eq!(ss.arg, Some(("superstep", 1.0)));
        // The phase span nests inside the superstep span.
        assert!(compute.ts_us >= ss.ts_us);
        assert!(compute.ts_us + compute.dur_us <= ss.ts_us + ss.dur_us);
        // Phase totals sum only the four phase names, and stay within
        // the enclosing superstep wall.
        let totals = sink.phase_totals();
        assert!(totals.compute_seconds > 0.0);
        assert_eq!(totals.barrier_seconds, 0.0);
        let phase_sum = totals.compute_seconds + totals.route_seconds;
        assert!(phase_sum <= ss.dur_us as f64 / 1e6 + 1e-9);
    }

    #[test]
    fn to_json_round_trips_through_the_strict_parser() {
        let t = Tracer::enabled();
        {
            let rec = t.recorder(0).unwrap();
            let _g = rec.span("ckpt_commit", "ckpt");
        }
        let text = t.sink().unwrap().to_json().render();
        let v = JsonValue::parse(&text).unwrap();
        let rows = v.get("traceEvents").unwrap().as_array().unwrap();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].get("name").unwrap().as_str(), Some("ckpt_commit"));
        assert_eq!(rows[0].get("ph").unwrap().as_str(), Some("X"));
        assert_eq!(rows[0].get("pid").unwrap().as_f64(), Some(1.0));
        assert_eq!(rows[0].get("tid").unwrap().as_f64(), Some(0.0));
    }

    #[test]
    fn json_output_is_deterministically_ordered() {
        let t = Tracer::enabled();
        // Record on two lanes in interleaved order.
        let r2 = t.recorder(2).unwrap();
        let r1 = t.recorder(1).unwrap();
        drop(r2.span("drain", "phase"));
        drop(r1.span("compute", "phase"));
        r2.flush();
        r1.flush();
        let json = t.sink().unwrap().to_json().render();
        // Sorted by tid: lane 1 renders before lane 2 regardless of
        // flush order.
        let i1 = json.find("\"tid\":1").unwrap();
        let i2 = json.find("\"tid\":2").unwrap();
        assert!(i1 < i2, "{json}");
    }
}
