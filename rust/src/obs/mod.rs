//! Observability: structured tracing and live metrics exposition.
//!
//! The paper's entire evaluation (Fig 4a–c, Fig 5, §6.5's straggler
//! analysis) is built on per-superstep, per-partition timing and
//! message accounting. [`crate::metrics::JobMetrics`] captures all of
//! it — but only after the job ends, as an in-memory struct. This
//! module is the live half, in two dependency-free pieces:
//!
//! * [`trace`] — per-job span recording. Both engines open spans for
//!   load, each superstep's compute/route/drain/barrier phases per
//!   worker, and checkpoint write/commit; the ingest pipeline spans its
//!   two passes. A [`trace::TraceSink`] serializes everything to Chrome
//!   trace-event JSON (one `{"traceEvents":[...]}` file loadable in
//!   Perfetto / `chrome://tracing`), rendered with the crate's own
//!   [`crate::serve::json::JsonValue`] writer. Enabled per job via
//!   `Job::builder().trace(path)` / CLI `run --trace out.json`; when
//!   disabled (the default) the hot path pays one `Option` branch and
//!   zero allocations.
//! * [`registry`] — a process-wide registry of named counters, gauges,
//!   and fixed-bucket histograms with Prometheus text exposition. The
//!   serve layer registers HTTP request/latency/rejection/eviction
//!   series and per-job engine progress (live superstep, cumulative
//!   messages/bytes, straggler ratio — published by the engine managers
//!   through [`crate::coordinator::RunControl`] at every barrier), and
//!   serves it all at `GET /v1/metrics?format=prometheus`.
//!
//! Neither half is ever result-affecting: tracing and metrics are
//! observation-only knobs, excluded from the checkpoint label exactly
//! like `mmap`/`dense_index`. Naming conventions, the span taxonomy,
//! and scrape examples live in `docs/OBSERVABILITY.md`.

pub mod registry;
pub mod trace;
