//! Generic sectioned-file framing — the on-disk layout introduced by
//! the GoFS v2 slice format, extracted so other subsystems can reuse it
//! (the checkpoint store `crate::ckpt` is the second user).
//!
//! A sectioned file is `magic(4), version(1), kind(1), nsections(1)`,
//! then one fixed 20-byte directory entry per section (`id u8, pad[3],
//! len u64 LE, fnv u64 LE`), then the section bodies back to back in
//! directory order. Every section carries its own FNV-1a 64 checksum,
//! which buys two properties the whole-file-checksum v1 framing lacked:
//!
//! * a reader that skips a section never pays to checksum it
//!   (projection-friendly), and
//! * corruption errors *name* the corrupt section, so scrubbers
//!   ([`scrub`], the `store verify` CLI) can report exactly what rotted.
//!
//! Callers own their magic, version byte, kind bytes, and section-id →
//! name mapping; this module owns the layout and the checksum rules.

use anyhow::{anyhow, ensure, Result};

/// Fixed header: magic(4) + version + kind + nsections.
pub const HEADER_LEN: usize = 7;
/// One directory entry: id u8 + pad[3] + len u64 LE + fnv u64 LE.
pub const DIR_ENTRY_LEN: usize = 20;

/// Section-id → human name mapping (error messages, scrub reports).
pub type SectionNames = fn(u8) -> &'static str;

/// FNV-1a 64-bit checksum over a byte run.
pub fn checksum(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Frame `sections` into one sectioned file.
pub fn frame(magic: &[u8; 4], version: u8, kind: u8, sections: &[(u8, Vec<u8>)]) -> Vec<u8> {
    let body: usize = sections.iter().map(|(_, b)| b.len()).sum();
    let mut out = Vec::with_capacity(HEADER_LEN + sections.len() * DIR_ENTRY_LEN + body);
    out.extend_from_slice(magic);
    out.push(version);
    out.push(kind);
    out.push(sections.len() as u8);
    for (id, body) in sections {
        out.push(*id);
        out.extend_from_slice(&[0u8; 3]);
        out.extend_from_slice(&(body.len() as u64).to_le_bytes());
        out.extend_from_slice(&checksum(body).to_le_bytes());
    }
    for (_, body) in sections {
        out.extend_from_slice(body);
    }
    out
}

/// Parsed (but not yet checksum-validated) section table over a
/// borrowed sectioned file.
pub struct SectionTable<'a> {
    bytes: &'a [u8],
    /// `(id, body byte range, recorded checksum)` in directory order.
    entries: Vec<(u8, std::ops::Range<usize>, u64)>,
    names: SectionNames,
}

impl<'a> SectionTable<'a> {
    /// Fetch one section, validating *only its own* checksum — untouched
    /// sections are never checksummed (the skip-what-you-don't-read
    /// property of the layout).
    pub fn get(&self, id: u8) -> Result<&'a [u8]> {
        let (_, range, sum) = self
            .entries
            .iter()
            .find(|(i, _, _)| *i == id)
            .ok_or_else(|| anyhow!("missing section `{}`", (self.names)(id)))?;
        let body = &self.bytes[range.clone()];
        ensure!(
            checksum(body) == *sum,
            "section `{}` corrupt (checksum mismatch)",
            (self.names)(id)
        );
        Ok(body)
    }

    /// Number of sections in the directory.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// `(name, body byte range)` per section, in file order.
    pub fn ranges(&self) -> Vec<(&'static str, std::ops::Range<usize>)> {
        self.entries
            .iter()
            .map(|(id, r, _)| ((self.names)(*id), r.clone()))
            .collect()
    }

    /// Checksum every section: `(name, clean?)` per directory entry.
    pub fn scrub(&self) -> Vec<(&'static str, bool)> {
        self.entries
            .iter()
            .map(|(id, r, sum)| {
                ((self.names)(*id), checksum(&self.bytes[r.clone()]) == *sum)
            })
            .collect()
    }
}

/// Parse the directory of a sectioned file, validating the structure
/// (magic, version, kind, lengths) but not the per-section checksums —
/// those are checked on [`SectionTable::get`] / [`SectionTable::scrub`].
pub fn unframe<'a>(
    bytes: &'a [u8],
    magic: &[u8; 4],
    version: u8,
    want_kind: u8,
    names: SectionNames,
) -> Result<SectionTable<'a>> {
    ensure!(bytes.len() >= HEADER_LEN, "file too short ({} bytes)", bytes.len());
    ensure!(&bytes[..4] == magic, "bad magic");
    ensure!(bytes[4] == version, "unsupported version {}", bytes[4]);
    ensure!(
        bytes[5] == want_kind,
        "wrong file kind: want {want_kind}, got {}",
        bytes[5]
    );
    let n = bytes[6] as usize;
    let dir_end = HEADER_LEN + n * DIR_ENTRY_LEN;
    ensure!(bytes.len() >= dir_end, "truncated inside section directory");
    let mut entries = Vec::with_capacity(n);
    let mut off = dir_end;
    for s in 0..n {
        let e = HEADER_LEN + s * DIR_ENTRY_LEN;
        let id = bytes[e];
        let len = u64::from_le_bytes(bytes[e + 4..e + 12].try_into().unwrap()) as usize;
        let sum = u64::from_le_bytes(bytes[e + 12..e + 20].try_into().unwrap());
        ensure!(
            bytes.len() - off >= len,
            "section `{}` truncated: directory says {len} bytes, {} remain",
            names(id),
            bytes.len() - off
        );
        entries.push((id, off..off + len, sum));
        off += len;
    }
    ensure!(
        off == bytes.len(),
        "{} trailing bytes after last section",
        bytes.len() - off
    );
    Ok(SectionTable { bytes, entries, names })
}

/// Structure-parse a sectioned file of any kind and checksum every
/// section: `(name, clean?)` per entry. The scrubber surface behind
/// `store verify`.
pub fn scrub(
    bytes: &[u8],
    magic: &[u8; 4],
    version: u8,
    names: SectionNames,
) -> Result<Vec<(&'static str, bool)>> {
    ensure!(bytes.len() >= HEADER_LEN, "file too short ({} bytes)", bytes.len());
    let table = unframe(bytes, magic, version, bytes[5], names)?;
    Ok(table.scrub())
}

/// Accumulated result of a multi-file checksum scrub — shared by the
/// GoFS store scrubber ([`crate::gofs::Store::scrub`]) and the
/// checkpoint-directory scrubber (`crate::ckpt::scrub_dir`), merged by
/// the `store verify` CLI.
#[derive(Debug, Default)]
pub struct ScrubSummary {
    pub files: u64,
    pub sections: u64,
    /// Human-readable ``"<file>: section `<name>`"`` descriptions.
    pub corrupt: Vec<String>,
}

impl ScrubSummary {
    /// Record one file's per-section scrub report — or its structural
    /// parse error, which counts as corruption too. Section labels may
    /// be static names (`"targets"`) or owned strings (the packed
    /// scrubber's `sg_3.targets` style).
    pub fn record<S: AsRef<str>>(&mut self, file: &str, report: Result<Vec<(S, bool)>>) {
        self.files += 1;
        match report {
            Ok(entries) => {
                for (sec, clean) in entries {
                    self.sections += 1;
                    if !clean {
                        self.corrupt
                            .push(format!("{file}: section `{}`", sec.as_ref()));
                    }
                }
            }
            Err(e) => self.corrupt.push(format!("{file}: {e:#}")),
        }
    }

    /// Record a file that could not even be read.
    pub fn record_unreadable(&mut self, file: &str, err: impl std::fmt::Display) {
        self.files += 1;
        self.corrupt.push(format!("{file}: unreadable ({err})"));
    }

    /// Fold another summary into this one (optionally prefixing its
    /// corruption descriptions, e.g. with the scrubbed root).
    pub fn absorb(&mut self, other: ScrubSummary, prefix: &str) {
        self.files += other.files;
        self.sections += other.sections;
        self.corrupt
            .extend(other.corrupt.into_iter().map(|c| format!("{prefix}{c}")));
    }

    pub fn is_clean(&self) -> bool {
        self.corrupt.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MAGIC: &[u8; 4] = b"TEST";

    fn names(id: u8) -> &'static str {
        match id {
            0 => "alpha",
            1 => "beta",
            _ => "unknown",
        }
    }

    fn sample() -> Vec<u8> {
        frame(
            MAGIC,
            1,
            7,
            &[(0, vec![1, 2, 3]), (1, vec![9; 40])],
        )
    }

    #[test]
    fn frame_unframe_round_trip() {
        let bytes = sample();
        let t = unframe(&bytes, MAGIC, 1, 7, names).unwrap();
        assert_eq!(t.len(), 2);
        assert_eq!(t.get(0).unwrap(), &[1, 2, 3]);
        assert_eq!(t.get(1).unwrap(), &[9; 40][..]);
        assert!(format!("{:#}", t.get(2).unwrap_err()).contains("unknown"));
    }

    #[test]
    fn header_mismatches_rejected() {
        let bytes = sample();
        assert!(unframe(&bytes, b"XXXX", 1, 7, names).is_err());
        assert!(unframe(&bytes, MAGIC, 2, 7, names).is_err());
        assert!(unframe(&bytes, MAGIC, 1, 8, names).is_err());
        assert!(unframe(&bytes[..5], MAGIC, 1, 7, names).is_err());
        assert!(unframe(&bytes[..bytes.len() - 1], MAGIC, 1, 7, names).is_err());
    }

    #[test]
    fn corruption_names_the_section() {
        let mut bytes = sample();
        let t = unframe(&bytes, MAGIC, 1, 7, names).unwrap();
        let ranges = t.ranges();
        let beta = ranges.iter().find(|(n, _)| *n == "beta").unwrap().1.clone();
        drop(t);
        bytes[beta.start + 5] ^= 0x55;
        let t = unframe(&bytes, MAGIC, 1, 7, names).unwrap();
        assert!(t.get(0).is_ok(), "untouched section still clean");
        let err = t.get(1).unwrap_err();
        assert!(format!("{err:#}").contains("beta"), "{err:#}");
        let report = scrub(&bytes, MAGIC, 1, names).unwrap();
        assert_eq!(report, vec![("alpha", true), ("beta", false)]);
    }

    #[test]
    fn ranges_cover_file_exactly() {
        let bytes = sample();
        let t = unframe(&bytes, MAGIC, 1, 7, names).unwrap();
        let mut pos = HEADER_LEN + t.len() * DIR_ENTRY_LEN;
        for (_, r) in t.ranges() {
            assert_eq!(r.start, pos);
            pos = r.end;
        }
        assert_eq!(pos, bytes.len());
    }
}
