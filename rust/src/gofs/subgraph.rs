//! Sub-graph discovery: partitions → weakly-connected components with
//! resolved remote edges.
//!
//! Definition (paper §3.2): a sub-graph `S` in partition `P_i` is a
//! maximal set of local vertices such that every pair is connected by an
//! undirected path through local edges, together with its boundary
//! *remote edges*. Two sub-graphs never share a vertex; sub-graphs on the
//! same partition sharing an edge are by definition one sub-graph.

use std::collections::{BTreeMap, BTreeSet};

use anyhow::{ensure, Result};

use crate::graph::csr::{Graph, VertexId};
use crate::partition::Partitioning;
use crate::util::dsu::Dsu;

/// Attribute columns loaded alongside one partition's sub-graphs
/// (`Store::load_partition_with` with a non-empty projection): indexed
/// by sub-graph index within the partition; each map is attribute name
/// → per-local-vertex f32 column, aligned with `Subgraph::vertices`.
pub type PartitionAttributes = Vec<BTreeMap<String, Vec<f32>>>;

/// Globally unique sub-graph identifier.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct SubgraphId {
    pub partition: u32,
    pub index: u32,
}

impl std::fmt::Display for SubgraphId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "P{}S{}", self.partition, self.index)
    }
}

/// A resolved remote edge endpoint: the vertex lives on another
/// partition, in a known sub-graph (resolved at store-build time).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RemoteRef {
    /// Local endpoint (index into `Subgraph::vertices`).
    pub local: u32,
    /// Global id of the remote vertex.
    pub target_global: VertexId,
    /// Partition holding the remote vertex.
    pub partition: u32,
    /// Sub-graph index within that partition.
    pub subgraph: u32,
    /// Edge weight (1.0 for unweighted graphs).
    pub weight: f32,
}

/// One sub-graph: local topology (a dense-id [`Graph`]) plus boundary
/// remote edges in both directions.
#[derive(Clone, Debug)]
pub struct Subgraph {
    pub id: SubgraphId,
    /// Global ids of local vertices, sorted ascending; position = local id.
    pub vertices: Vec<VertexId>,
    /// Local topology over local ids (directed iff the source graph is).
    pub local: Graph,
    /// Out remote edges: local vertex -> remote target.
    pub remote_out: Vec<RemoteRef>,
    /// In remote edges: remote source -> local vertex (`local` field is
    /// the local *destination*; `target_global` is the remote source).
    pub remote_in: Vec<RemoteRef>,
    /// |V| of the full distributed graph (PageRank et al. need it).
    pub num_global_vertices: u64,
}

impl Subgraph {
    pub fn num_vertices(&self) -> usize {
        self.vertices.len()
    }

    /// Local id of a global vertex, if it lives here.
    pub fn local_id(&self, global: VertexId) -> Option<u32> {
        self.vertices.binary_search(&global).ok().map(|i| i as u32)
    }

    /// Global out-degree of a local vertex (local + remote out-edges):
    /// what a vertex-centric PageRank would see.
    pub fn global_out_degree(&self, local: u32) -> usize {
        self.local.out_degree(local)
            + self
                .remote_out
                .iter()
                .filter(|r| r.local == local)
                .count()
    }

    /// Distinct neighbouring sub-graphs (across remote edges, both
    /// directions) — the meta-vertex adjacency of the paper's §3.3.
    pub fn neighbor_subgraphs(&self) -> Vec<SubgraphId> {
        let mut set = BTreeSet::new();
        for r in self.remote_out.iter().chain(&self.remote_in) {
            set.insert(SubgraphId { partition: r.partition, index: r.subgraph });
        }
        set.into_iter().collect()
    }
}

/// The fully discovered distributed graph: `partitions[p]` holds the
/// sub-graphs of partition `p`.
#[derive(Clone, Debug)]
pub struct DistributedGraph {
    pub partitions: Vec<Vec<Subgraph>>,
    pub num_global_vertices: u64,
    pub directed: bool,
}

impl DistributedGraph {
    pub fn num_partitions(&self) -> usize {
        self.partitions.len()
    }

    pub fn num_subgraphs(&self) -> usize {
        self.partitions.iter().map(|p| p.len()).sum()
    }

    pub fn subgraph(&self, id: SubgraphId) -> &Subgraph {
        &self.partitions[id.partition as usize][id.index as usize]
    }

    /// All sub-graphs in deterministic order.
    pub fn subgraphs(&self) -> impl Iterator<Item = &Subgraph> {
        self.partitions.iter().flatten()
    }

    /// Meta-graph: one vertex per sub-graph, an (undirected, deduped)
    /// edge wherever two sub-graphs share a remote edge. Its diameter
    /// bounds traversal supersteps (paper §3.3).
    pub fn meta_graph(&self) -> Graph {
        let mut index: BTreeMap<SubgraphId, u32> = BTreeMap::new();
        for sg in self.subgraphs() {
            let next = index.len() as u32;
            index.insert(sg.id, next);
        }
        let mut b = crate::graph::GraphBuilder::new(false).dedup(true);
        b.reserve_vertices(index.len());
        for sg in self.subgraphs() {
            let me = index[&sg.id];
            for nb in sg.neighbor_subgraphs() {
                b.add_edge(me, index[&nb]);
            }
        }
        b.build().expect("meta graph build")
    }
}

/// Discover all sub-graphs of `g` under `parts`.
///
/// Two passes: (1) per-partition union-find over local edges assigns each
/// vertex a `(partition, subgraph-index)`; (2) sub-graph topologies and
/// *resolved* remote refs are materialised.
pub fn discover(g: &Graph, parts: &Partitioning) -> Result<DistributedGraph> {
    ensure!(
        g.num_vertices() == parts.num_vertices(),
        "partitioning covers {} vertices, graph has {}",
        parts.num_vertices(),
        g.num_vertices()
    );
    let n = g.num_vertices();
    let k = parts.k();

    // Pass 1: per-partition weak connectivity via one global DSU that only
    // unions same-partition endpoints.
    let mut dsu = Dsu::new(n);
    for (u, v, _) in g.edges() {
        if parts.of(u) == parts.of(v) {
            dsu.union(u, v);
        }
    }

    // Assign (partition, index) per DSU root, index dense per partition.
    let mut sg_of_vertex = vec![(0u32, 0u32); n]; // (partition, subgraph idx)
    let mut root_index: BTreeMap<(u32, u32), u32> = BTreeMap::new(); // (part, root) -> idx
    let mut counts_per_part = vec![0u32; k];
    for v in 0..n as u32 {
        let p = parts.of(v);
        let root = dsu.find(v);
        let idx = *root_index.entry((p, root)).or_insert_with(|| {
            let i = counts_per_part[p as usize];
            counts_per_part[p as usize] += 1;
            i
        });
        sg_of_vertex[v as usize] = (p, idx);
    }

    // Collect members per sub-graph (sorted by global id by construction).
    let mut members: BTreeMap<(u32, u32), Vec<VertexId>> = BTreeMap::new();
    for v in 0..n as u32 {
        let (p, i) = sg_of_vertex[v as usize];
        members.entry((p, i)).or_default().push(v);
    }

    // Pass 2: build each sub-graph.
    let mut partitions: Vec<Vec<Subgraph>> = vec![Vec::new(); k];
    for ((p, idx), verts) in &members {
        let local_of: BTreeMap<VertexId, u32> = verts
            .iter()
            .enumerate()
            .map(|(i, &v)| (v, i as u32))
            .collect();
        let mut local_edges: Vec<(u32, u32)> = Vec::new();
        let mut local_weights: Vec<f32> = Vec::new();
        let mut remote_out: Vec<RemoteRef> = Vec::new();
        let mut remote_in: Vec<RemoteRef> = Vec::new();

        for (&gv, &lv) in &local_of {
            for (t, ei) in g.out_edges(gv) {
                let w = g.weight(ei);
                match local_of.get(&t) {
                    Some(&lt) => {
                        local_edges.push((lv, lt));
                        local_weights.push(w);
                    }
                    None => {
                        let (tp, ti) = sg_of_vertex[t as usize];
                        // Same-partition different-subgraph is impossible
                        // by construction (they'd be unioned).
                        debug_assert_ne!(tp, *p);
                        remote_out.push(RemoteRef {
                            local: lv,
                            target_global: t,
                            partition: tp,
                            subgraph: ti,
                            weight: w,
                        });
                    }
                }
            }
            for (s, ei) in g.in_edges(gv) {
                if !local_of.contains_key(&s) {
                    let (sp, si) = sg_of_vertex[s as usize];
                    remote_in.push(RemoteRef {
                        local: lv,
                        target_global: s,
                        partition: sp,
                        subgraph: si,
                        weight: g.weight(ei),
                    });
                }
            }
        }

        let local = Graph::from_edges(
            verts.len(),
            &local_edges,
            if g.has_weights() { Some(local_weights) } else { None },
            g.directed(),
        )?;
        partitions[*p as usize].push(Subgraph {
            id: SubgraphId { partition: *p, index: *idx },
            vertices: verts.clone(),
            local,
            remote_out,
            remote_in,
            num_global_vertices: n as u64,
        });
    }
    // Sub-graphs were inserted in BTreeMap order of (p, idx): idx order OK.
    for (p, sgs) in partitions.iter().enumerate() {
        for (i, sg) in sgs.iter().enumerate() {
            ensure!(
                sg.id.partition as usize == p && sg.id.index as usize == i,
                "sub-graph ordering invariant violated"
            );
        }
    }

    Ok(DistributedGraph {
        partitions,
        num_global_vertices: n as u64,
        directed: g.directed(),
    })
}

/// Rebuild a global [`Graph`] from a distributed one — the inverse of
/// [`discover`]. The vertex-centric baseline (which, Giraph-style, owns
/// the whole edge list) and the unified job layer's store→vertex path
/// use this to turn GoFS data back into a flat graph.
pub fn reassemble(dg: &DistributedGraph) -> Result<Graph> {
    let mut edges = Vec::new();
    let mut weights = Vec::new();
    let mut weighted = false;
    for sg in dg.subgraphs() {
        for (u, v, ei) in sg.local.edges() {
            edges.push((sg.vertices[u as usize], sg.vertices[v as usize]));
            weights.push(sg.local.weight(ei));
            weighted |= sg.local.has_weights();
        }
        for r in &sg.remote_out {
            edges.push((sg.vertices[r.local as usize], r.target_global));
            weights.push(r.weight);
        }
    }
    Graph::from_edges(
        dg.num_global_vertices as usize,
        &edges,
        if weighted { Some(weights) } else { None },
        dg.directed,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;
    use crate::partition::{Partitioner, RangePartitioner};

    fn two_part_fig1() -> (Graph, Partitioning) {
        // Mirror of the paper's Fig. 1 idea: a graph split in two where one
        // partition holds two sub-graphs and the other holds one.
        // Partition 0: {0,1,2} chain + {3,4} pair (disconnected locally).
        // Partition 1: {5,6,7} chain, with remote edges 2-5 and 4-6.
        let edges = [
            (0u32, 1u32),
            (1, 2),
            (3, 4),
            (5, 6),
            (6, 7),
            (2, 5), // remote
            (4, 6), // remote
        ];
        let g = Graph::from_edges(8, &edges, None, false).unwrap();
        let parts = Partitioning::new(2, vec![0, 0, 0, 0, 0, 1, 1, 1]);
        (g, parts)
    }

    #[test]
    fn discovery_counts_and_membership() {
        let (g, parts) = two_part_fig1();
        let dg = discover(&g, &parts).unwrap();
        assert_eq!(dg.num_partitions(), 2);
        assert_eq!(dg.partitions[0].len(), 2);
        assert_eq!(dg.partitions[1].len(), 1);
        assert_eq!(dg.num_subgraphs(), 3);
        // Each vertex appears in exactly one sub-graph.
        let mut seen = vec![false; 8];
        for sg in dg.subgraphs() {
            for &v in &sg.vertices {
                assert!(!seen[v as usize], "vertex {v} in two sub-graphs");
                seen[v as usize] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn local_topology_correct() {
        let (g, parts) = two_part_fig1();
        let dg = discover(&g, &parts).unwrap();
        let sg0 = &dg.partitions[0][0]; // {0,1,2}
        assert_eq!(sg0.vertices, vec![0, 1, 2]);
        assert_eq!(sg0.local.num_edges(), 2);
        let sg1 = &dg.partitions[0][1]; // {3,4}
        assert_eq!(sg1.vertices, vec![3, 4]);
        assert_eq!(sg1.local.num_edges(), 1);
    }

    #[test]
    fn remote_edges_resolved() {
        let (g, parts) = two_part_fig1();
        let dg = discover(&g, &parts).unwrap();
        let sg0 = &dg.partitions[0][0]; // {0,1,2} has out-remote 2->5
        assert_eq!(sg0.remote_out.len(), 1);
        let r = sg0.remote_out[0];
        assert_eq!(r.target_global, 5);
        assert_eq!(r.partition, 1);
        assert_eq!(r.subgraph, 0);
        assert_eq!(sg0.vertices[r.local as usize], 2);
        // And partition 1's sub-graph sees both incoming remotes.
        let sgr = &dg.partitions[1][0];
        assert_eq!(sgr.remote_in.len(), 2);
        assert_eq!(sgr.remote_out.len(), 0);
    }

    #[test]
    fn neighbor_subgraphs_meta_adjacency() {
        let (g, parts) = two_part_fig1();
        let dg = discover(&g, &parts).unwrap();
        let sg0 = &dg.partitions[0][0];
        let sg1 = &dg.partitions[0][1];
        let sgr = &dg.partitions[1][0];
        assert_eq!(sg0.neighbor_subgraphs(), vec![sgr.id]);
        assert_eq!(sg1.neighbor_subgraphs(), vec![sgr.id]);
        assert_eq!(sgr.neighbor_subgraphs(), vec![sg0.id, sg1.id]);
    }

    #[test]
    fn meta_graph_shape() {
        let (g, parts) = two_part_fig1();
        let dg = discover(&g, &parts).unwrap();
        let meta = dg.meta_graph();
        assert_eq!(meta.num_vertices(), 3);
        assert_eq!(meta.num_edges(), 2); // star centred on partition 1's sg
    }

    #[test]
    fn same_partition_subgraphs_never_share_edge() {
        // Property from the paper: if two sub-graphs on the same partition
        // shared an edge they'd be merged.
        let g = gen::road(20, 0.95, 0.01, 3);
        let parts = RangePartitioner.partition(&g, 4);
        let dg = discover(&g, &parts).unwrap();
        for (u, v, _) in g.edges() {
            let (pu, su) = {
                let sg = dg
                    .subgraphs()
                    .find(|sg| sg.local_id(u).is_some())
                    .unwrap();
                (sg.id.partition, sg.id.index)
            };
            let (pv, sv) = {
                let sg = dg
                    .subgraphs()
                    .find(|sg| sg.local_id(v).is_some())
                    .unwrap();
                (sg.id.partition, sg.id.index)
            };
            if pu == pv {
                assert_eq!(su, sv, "edge ({u},{v}) crosses sub-graphs within partition {pu}");
            }
        }
    }

    #[test]
    fn global_out_degree_counts_remote() {
        let (g, parts) = two_part_fig1();
        let dg = discover(&g, &parts).unwrap();
        let sg0 = &dg.partitions[0][0];
        let local2 = sg0.local_id(2).unwrap();
        // Vertex 2: no local out-edges (1->2 is incoming), one remote 2->5.
        assert_eq!(sg0.global_out_degree(local2), 1);
    }

    #[test]
    fn weighted_graph_preserves_weights() {
        let g = Graph::from_edges(
            4,
            &[(0, 1), (1, 2), (2, 3)],
            Some(vec![1.5, 2.5, 3.5]),
            true,
        )
        .unwrap();
        let parts = Partitioning::new(2, vec![0, 0, 1, 1]);
        let dg = discover(&g, &parts).unwrap();
        let sg0 = &dg.partitions[0][0];
        let (_, ei) = sg0.local.out_edges(0).next().unwrap();
        assert_eq!(sg0.local.weight(ei), 1.5);
        assert_eq!(sg0.remote_out[0].weight, 2.5);
    }

    #[test]
    fn mismatched_partitioning_rejected() {
        let g = gen::chain(5);
        let parts = Partitioning::new(2, vec![0, 0, 1]);
        assert!(discover(&g, &parts).is_err());
    }

    #[test]
    fn reassemble_preserves_counts_and_weights() {
        let g = gen::with_random_weights(&gen::road(10, 0.9, 0.02, 3), 1.0, 5.0, 4);
        let p = crate::partition::MultilevelPartitioner::default().partition(&g, 3);
        let dg = discover(&g, &p).unwrap();
        let g2 = reassemble(&dg).unwrap();
        assert_eq!(g2.num_vertices(), g.num_vertices());
        assert_eq!(g2.num_edges(), g.num_edges());
        assert_eq!(g2.directed(), g.directed());
        assert_eq!(g2.has_weights(), g.has_weights());
    }

    #[test]
    fn trivial_subgraphs_degenerate_to_vertices() {
        // Hash-partition a chain into many parts: most sub-graphs are
        // single vertices (the paper's degenerate case).
        let g = gen::chain(16);
        let parts = crate::partition::HashPartitioner::default().partition(&g, 8);
        let dg = discover(&g, &parts).unwrap();
        assert!(dg.num_subgraphs() >= 8);
        let total: usize = dg.subgraphs().map(|s| s.num_vertices()).sum();
        assert_eq!(total, 16);
    }
}
