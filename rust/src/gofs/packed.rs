//! GoFS v3 "packed" partition files: one sectioned file per partition.
//!
//! The v2 format made slices columnar but still spread a partition
//! across many files (one topology slice per sub-graph plus one file
//! per attribute column), so an `AttrProjection` saved decode work, not
//! seeks. The packed format takes the co-design the rest of the way
//! (paper §4.3's "balance the disk latency against sequential bytes
//! read"): **every** section of **every** sub-graph in a partition —
//! topology columns and attribute columns alike — lives in a single
//! `partition.gfsp` file, fronted by a length-addressed directory. A
//! projected load reads the directory once, then `seek`s straight past
//! every section it does not want; nine of ten attribute columns cost
//! one intra-file seek each instead of a file open plus streamed bytes.
//!
//! On-disk layout (all integers little-endian):
//!
//! ```text
//! prelude (24 bytes):
//!   magic    "GFSP"                                   4
//!   version  3                                        1
//!   kind     2 (KIND_PACKED)                          1
//!   pad      0                                        2
//!   dir_len  u64 — byte length of the directory block 8
//!   dir_fnv  u64 — FNV-1a 64 of the directory block   8
//! directory block (dir_len bytes):
//!   n_entries u32
//!   per entry (24 fixed bytes + name):
//!     sg       u32 — owning sub-graph index
//!     sec      u8  — section id (the v2 section namespace)
//!     name_len u8  — attribute-name length (0 for topology sections)
//!     pad      u16
//!     len      u64 — body length
//!     fnv      u64 — FNV-1a 64 of the body
//!     name     name_len bytes (utf-8 attribute name)
//! bodies: back to back in directory order, starting at 24 + dir_len.
//!   entry i's body offset = 24 + dir_len + Σ len of entries < i
//! ```
//!
//! Integrity is layered exactly like v2, plus one level: the directory
//! block carries its own checksum (`dir_fnv`, validated before any
//! offset it lists is trusted), and every section body carries an FNV
//! that is only verified when that section is actually read — skipped
//! sections are never checksummed, read, or decoded. Corruption
//! reports name the sub-graph and section (`sg_3.targets`,
//! `sg_0.attr.rank`); structural rot (magic, version, kind byte,
//! directory) is an error naming what broke.

use std::fs::File;
use std::io::Read;
use std::ops::Range;

use anyhow::{anyhow, ensure, Context, Result};

use super::section::checksum;
use super::slice;

/// Packed-file magic (distinct from the `GFSL` per-sub-graph slices).
pub const MAGIC: &[u8; 4] = b"GFSP";
/// Version byte of the packed layout (the GoFS format lineage: v1
/// codec slices, v2 columnar slices, v3 packed partitions).
pub const VERSION: u8 = 3;
/// Kind byte: a packed file holds a whole partition, not one slice.
pub const KIND_PACKED: u8 = 2;
/// Fixed prelude: magic + version + kind + pad + dir_len + dir_fnv.
pub const PRELUDE_LEN: usize = 24;
/// Fixed part of one directory entry (the attribute name follows).
pub const ENTRY_FIXED_LEN: usize = 24;
/// The single packed file each `host<p>/` directory holds.
pub const PARTITION_FILE: &str = "partition.gfsp";

/// One section of a packed partition file, as listed in its directory.
#[derive(Clone, Debug, PartialEq)]
pub struct Entry {
    /// Sub-graph index within the partition.
    pub subgraph: u32,
    /// Section id (the v2 section namespace in [`slice`]).
    pub section: u8,
    /// Attribute name; empty for topology sections.
    pub name: String,
    /// Body length in bytes (the "length-addressed" part: offsets are
    /// prefix sums of these, so the directory fully determines what a
    /// projected read may skip).
    pub len: u64,
    /// FNV-1a 64 of the body.
    pub checksum: u64,
    /// Absolute file offset of the body (computed while parsing).
    pub offset: u64,
}

impl Entry {
    /// Human label used by scrub reports and corruption errors:
    /// `sg_<i>.<section>` for topology, `sg_<i>.attr.<name>` for
    /// attribute columns (mirroring the v2 slice file names).
    pub fn label(&self) -> String {
        if self.name.is_empty() {
            format!("sg_{}.{}", self.subgraph, slice::section_name(self.section))
        } else {
            format!("sg_{}.attr.{}", self.subgraph, self.name)
        }
    }

    /// Byte range of the body within the file.
    pub fn range(&self) -> Range<usize> {
        self.offset as usize..(self.offset + self.len) as usize
    }
}

/// Parsed directory of a packed partition file.
#[derive(Clone, Debug)]
pub struct Directory {
    /// Entries in file order (body offsets ascending).
    pub entries: Vec<Entry>,
    /// Bytes of metadata in front of the bodies (prelude + directory
    /// block); the first body starts here.
    pub body_start: u64,
}

impl Directory {
    /// Total body bytes the directory lists.
    pub fn body_bytes(&self) -> u64 {
        self.entries.iter().map(|e| e.len).sum()
    }
}

/// Serialize a full packed partition file. Each element supplies the
/// owning sub-graph index, section id, attribute name (empty for
/// topology sections), and body bytes; bodies land in the given order.
pub fn encode(sections: &[(u32, u8, String, Vec<u8>)]) -> Result<Vec<u8>> {
    let mut dir = Vec::with_capacity(4 + sections.len() * (ENTRY_FIXED_LEN + 8));
    dir.extend_from_slice(&(sections.len() as u32).to_le_bytes());
    for (sg, sec, name, body) in sections {
        ensure!(
            name.len() <= u8::MAX as usize,
            "attribute name {name:?} longer than 255 bytes"
        );
        // An empty name is the directory's topology marker, so the
        // invariant "named ⟺ values section" is enforced at the format
        // boundary — a nameless attribute column could never be
        // projected, replaced, or read back.
        ensure!(
            (*sec == slice::SEC_VALUES) == !name.is_empty(),
            "packed entry for sub-graph {sg}: a name must be set exactly for \
             `values` sections (section {sec}, name {name:?})"
        );
        dir.extend_from_slice(&sg.to_le_bytes());
        dir.push(*sec);
        dir.push(name.len() as u8);
        dir.extend_from_slice(&[0u8; 2]);
        dir.extend_from_slice(&(body.len() as u64).to_le_bytes());
        dir.extend_from_slice(&checksum(body).to_le_bytes());
        dir.extend_from_slice(name.as_bytes());
    }
    let body_len: usize = sections.iter().map(|(_, _, _, b)| b.len()).sum();
    let mut out = Vec::with_capacity(PRELUDE_LEN + dir.len() + body_len);
    out.extend_from_slice(MAGIC);
    out.push(VERSION);
    out.push(KIND_PACKED);
    out.extend_from_slice(&[0u8; 2]);
    out.extend_from_slice(&(dir.len() as u64).to_le_bytes());
    out.extend_from_slice(&checksum(&dir).to_le_bytes());
    out.extend_from_slice(&dir);
    for (_, _, _, body) in sections {
        out.extend_from_slice(body);
    }
    Ok(out)
}

/// Validate the fixed prelude; returns `(dir_len, dir_fnv)`.
fn parse_prelude(bytes: &[u8]) -> Result<(u64, u64)> {
    ensure!(
        bytes.len() >= PRELUDE_LEN,
        "packed file too short ({} bytes)",
        bytes.len()
    );
    ensure!(&bytes[..4] == MAGIC, "bad packed-file magic");
    ensure!(
        bytes[4] == VERSION,
        "unsupported packed-file version {}",
        bytes[4]
    );
    ensure!(
        bytes[5] == KIND_PACKED,
        "wrong packed-file kind byte {} (want {KIND_PACKED})",
        bytes[5]
    );
    let dir_len = u64::from_le_bytes(bytes[8..16].try_into().unwrap());
    let dir_fnv = u64::from_le_bytes(bytes[16..24].try_into().unwrap());
    Ok((dir_len, dir_fnv))
}

/// Parse the directory out of `bytes`, which must hold at least the
/// prelude + directory block. The directory checksum is validated
/// before any offset it lists is trusted (a flipped byte anywhere in
/// the directory is caught here); per-section body checksums are *not*
/// checked — those are verified if and when a section is read.
pub fn parse_directory(bytes: &[u8]) -> Result<Directory> {
    let (dir_len, dir_fnv) = parse_prelude(bytes)?;
    let dir_end = PRELUDE_LEN
        .checked_add(usize::try_from(dir_len).ok().unwrap_or(usize::MAX))
        .unwrap_or(usize::MAX);
    ensure!(
        bytes.len() >= dir_end,
        "packed file truncated inside section directory"
    );
    let dir = &bytes[PRELUDE_LEN..dir_end];
    ensure!(
        checksum(dir) == dir_fnv,
        "packed section directory corrupt (checksum mismatch)"
    );
    ensure!(dir.len() >= 4, "packed section directory too short");
    let n = u32::from_le_bytes(dir[0..4].try_into().unwrap()) as usize;
    // The count is untrusted until proven to fit: every entry occupies
    // at least ENTRY_FIXED_LEN directory bytes, so an inflated count
    // is an error here, not a count-sized allocation below.
    ensure!(
        n <= (dir.len() - 4) / ENTRY_FIXED_LEN,
        "packed directory claims {n} entries, block has room for {}",
        (dir.len() - 4) / ENTRY_FIXED_LEN
    );
    let mut entries = Vec::with_capacity(n);
    let mut pos = 4usize;
    let mut offset = dir_end as u64;
    for i in 0..n {
        ensure!(
            dir.len() - pos >= ENTRY_FIXED_LEN,
            "packed directory entry {i} truncated"
        );
        let e = &dir[pos..pos + ENTRY_FIXED_LEN];
        let subgraph = u32::from_le_bytes(e[0..4].try_into().unwrap());
        let section = e[4];
        let name_len = e[5] as usize;
        let len = u64::from_le_bytes(e[8..16].try_into().unwrap());
        let sum = u64::from_le_bytes(e[16..24].try_into().unwrap());
        pos += ENTRY_FIXED_LEN;
        ensure!(
            dir.len() - pos >= name_len,
            "packed directory entry {i} name truncated"
        );
        let name = std::str::from_utf8(&dir[pos..pos + name_len])
            .context("packed directory attribute name not utf-8")?
            .to_string();
        pos += name_len;
        entries.push(Entry { subgraph, section, name, len, checksum: sum, offset });
        // Listed lengths are data, not trusted input: a crafted or
        // rotted-yet-checksum-consistent directory must surface as an
        // error, never as wrapped offsets or a giant allocation.
        offset = offset.checked_add(len).ok_or_else(|| {
            anyhow!("packed directory entry {i} overflows file offsets")
        })?;
    }
    ensure!(pos == dir.len(), "packed directory has trailing bytes");
    Ok(Directory { entries, body_start: dir_end as u64 })
}

/// Parse a complete in-memory packed file: the directory, plus the
/// structural check that the file holds exactly the bodies it lists.
pub fn parse(bytes: &[u8]) -> Result<Directory> {
    let dir = parse_directory(bytes)?;
    // Cannot overflow: parse_directory accumulated the same sum with
    // checked arithmetic. Compared for exact equality so truncated or
    // padded bodies are structural errors.
    let total = dir.body_start + dir.body_bytes();
    ensure!(
        bytes.len() as u64 == total,
        "packed file is {} bytes, directory accounts for {total}",
        bytes.len()
    );
    Ok(dir)
}

/// Read just the prelude + directory from an open file — the only
/// metadata a seek-skipping loader touches before section bodies. The
/// listed extents are validated against the real file size up front,
/// so every later `seek` + read (and every `vec![0; len]` buffer) is
/// bounded by bytes that actually exist on disk.
pub fn read_directory(file: &mut File) -> Result<Directory> {
    let file_len = file.metadata().context("stat packed file")?.len();
    let mut prelude = [0u8; PRELUDE_LEN];
    file.read_exact(&mut prelude).context("read packed prelude")?;
    let (dir_len, _) = parse_prelude(&prelude)?;
    ensure!(
        dir_len <= file_len.saturating_sub(PRELUDE_LEN as u64),
        "packed directory length {dir_len} exceeds file size {file_len}"
    );
    let mut buf = prelude.to_vec();
    buf.resize(PRELUDE_LEN + dir_len as usize, 0);
    file.read_exact(&mut buf[PRELUDE_LEN..])
        .context("read packed directory")?;
    let dir = parse_directory(&buf)?;
    let total = dir
        .body_start
        .checked_add(dir.body_bytes())
        .ok_or_else(|| anyhow!("packed directory overflows file offsets"))?;
    ensure!(
        total == file_len,
        "packed file is {file_len} bytes, directory accounts for {total}"
    );
    Ok(dir)
}

/// Full checksum scrub of one packed partition file: `(label, clean?)`
/// per directory entry. Structural damage — bad magic/version/kind
/// byte, a corrupt or truncated directory, bodies that don't match the
/// directory total — is an `Err` naming what broke.
pub fn scrub(bytes: &[u8]) -> Result<Vec<(String, bool)>> {
    let dir = parse(bytes)?;
    Ok(dir
        .entries
        .iter()
        .map(|e| (e.label(), checksum(&bytes[e.range()]) == e.checksum))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Two sub-graphs' worth of fake sections plus one attribute column.
    fn sample_sections() -> Vec<(u32, u8, String, Vec<u8>)> {
        vec![
            (0, 0, String::new(), vec![1, 2, 3]),
            (0, 3, String::new(), vec![9; 40]),
            (1, 0, String::new(), vec![7; 5]),
            (1, 3, String::new(), vec![]),
            (1, 7, "rank".to_string(), vec![0, 0, 128, 63]),
        ]
    }

    #[test]
    fn encode_parse_round_trip() {
        let bytes = encode(&sample_sections()).unwrap();
        let dir = parse(&bytes).unwrap();
        assert_eq!(dir.entries.len(), 5);
        assert_eq!(dir.entries[0].label(), "sg_0.meta");
        assert_eq!(dir.entries[1].label(), "sg_0.targets");
        assert_eq!(dir.entries[4].label(), "sg_1.attr.rank");
        // Offsets are prefix sums of the listed lengths.
        let mut pos = dir.body_start;
        for (e, (_, _, _, body)) in dir.entries.iter().zip(sample_sections()) {
            assert_eq!(e.offset, pos);
            assert_eq!(e.len as usize, body.len());
            assert_eq!(&bytes[e.range()], &body[..]);
            pos += e.len;
        }
        assert_eq!(pos, bytes.len() as u64);
        // Every body checksums clean.
        assert!(scrub(&bytes).unwrap().iter().all(|(_, ok)| *ok));
    }

    #[test]
    fn body_corruption_is_localized_to_its_entry() {
        let bytes = encode(&sample_sections()).unwrap();
        let dir = parse(&bytes).unwrap();
        let victim = dir.entries[1].clone();
        let mut bad = bytes.clone();
        bad[victim.range().start + 2] ^= 0x55;
        let report = scrub(&bad).unwrap();
        for (label, ok) in report {
            assert_eq!(ok, label != victim.label(), "{label}");
        }
    }

    #[test]
    fn directory_corruption_is_structural() {
        let bytes = encode(&sample_sections()).unwrap();
        // Any flip inside the directory block fails its checksum.
        for off in [PRELUDE_LEN, PRELUDE_LEN + 7, PRELUDE_LEN + 30] {
            let mut bad = bytes.clone();
            bad[off] ^= 0x55;
            let err = parse(&bad).unwrap_err();
            assert!(format!("{err:#}").contains("directory"), "{err:#}");
        }
        // Magic / version / kind byte rot is named as such.
        let mut bad = bytes.clone();
        bad[0] = b'X';
        assert!(format!("{:#}", parse(&bad).unwrap_err()).contains("magic"));
        let mut bad = bytes.clone();
        bad[4] = 9;
        assert!(format!("{:#}", parse(&bad).unwrap_err()).contains("version"));
        let mut bad = bytes.clone();
        bad[5] = 0;
        assert!(format!("{:#}", parse(&bad).unwrap_err()).contains("kind"));
    }

    #[test]
    fn truncation_detected_everywhere() {
        let bytes = encode(&sample_sections()).unwrap();
        for cut in [0, 5, PRELUDE_LEN, PRELUDE_LEN + 10, bytes.len() - 1] {
            assert!(parse(&bytes[..cut]).is_err(), "cut at {cut} must fail");
        }
    }

    #[test]
    fn crafted_directory_lengths_are_errors_not_allocations() {
        // A directory whose checksum is *internally consistent* but
        // whose listed lengths are absurd (hand-crafted or a very
        // unlucky multi-bit rot) must surface as a structural error —
        // never wrapped offsets, out-of-bounds indexing, or a
        // directory-driven giant allocation.
        let bytes = encode(&sample_sections()).unwrap();
        let dir_len =
            u64::from_le_bytes(bytes[8..16].try_into().unwrap()) as usize;
        // Patch entry 0's `len` field (directory offset 4 + 8) and
        // re-seal the directory checksum so only the length lies.
        let craft = |new_len: u64| -> Vec<u8> {
            let mut b = bytes.clone();
            let len_at = PRELUDE_LEN + 4 + 8;
            b[len_at..len_at + 8].copy_from_slice(&new_len.to_le_bytes());
            let fnv = checksum(&b[PRELUDE_LEN..PRELUDE_LEN + dir_len]);
            b[16..24].copy_from_slice(&fnv.to_le_bytes());
            b
        };
        // Offsets that overflow u64.
        let overflow = craft(u64::MAX);
        assert!(format!("{:#}", parse(&overflow).unwrap_err()).contains("overflow"));
        // An inflated entry count (resealed the same way) errors before
        // any count-sized allocation happens.
        let mut counted = bytes.clone();
        counted[PRELUDE_LEN..PRELUDE_LEN + 4]
            .copy_from_slice(&u32::MAX.to_le_bytes());
        let fnv = checksum(&counted[PRELUDE_LEN..PRELUDE_LEN + dir_len]);
        counted[16..24].copy_from_slice(&fnv.to_le_bytes());
        let err = format!("{:#}", parse(&counted).unwrap_err());
        assert!(err.contains("entries"), "{err}");
        // Lengths that exceed the actual file.
        let huge = craft(1 << 40);
        let err = format!("{:#}", parse(&huge).unwrap_err());
        assert!(err.contains("accounts for"), "{err}");
        // The file-backed reader rejects it before any body read too.
        let dir = std::env::temp_dir()
            .join(format!("goffish_packed_craft_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(PARTITION_FILE);
        std::fs::write(&path, &huge).unwrap();
        let mut f = File::open(&path).unwrap();
        assert!(read_directory(&mut f).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn empty_file_round_trips() {
        let bytes = encode(&[]).unwrap();
        let dir = parse(&bytes).unwrap();
        assert!(dir.entries.is_empty());
        assert_eq!(dir.body_start, bytes.len() as u64);
    }

    #[test]
    fn overlong_attribute_name_rejected() {
        let long = "x".repeat(300);
        assert!(encode(&[(0, 7, long, vec![1])]).is_err());
    }

    #[test]
    fn read_directory_from_file_matches_in_memory_parse() {
        let bytes = encode(&sample_sections()).unwrap();
        let dir = std::env::temp_dir()
            .join(format!("goffish_packed_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(PARTITION_FILE);
        std::fs::write(&path, &bytes).unwrap();
        let mut f = File::open(&path).unwrap();
        let from_file = read_directory(&mut f).unwrap();
        let from_mem = parse(&bytes).unwrap();
        assert_eq!(from_file.entries, from_mem.entries);
        assert_eq!(from_file.body_start, from_mem.body_start);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
