//! Slice files: the unit of GoFS storage (paper §4.1).
//!
//! Each sub-graph maps to one *topology slice* (local vertices, local
//! edges, resolved remote edges) and any number of *attribute slices*
//! (named per-vertex value arrays). Keeping topology and attributes in
//! separate files lets an algorithm read exactly the bytes it needs —
//! the paper's "a graph with 10 attributes … needs to only load that
//! slice" co-design point, and the "Edge Imp." variant of Fig 4(b).
//!
//! Three on-disk formats exist, dispatched on magic + version byte:
//!
//! * **v1** — one `GFSL` file per slice: codec-encoded payload
//!   (varints, delta ids) followed by a single whole-payload FNV-1a 64
//!   checksum. Compact, but strictly sequential to decode and
//!   all-or-nothing to validate.
//! * **v2** (default) — one `GFSL` file per slice holding fixed-width
//!   little-endian *columnar sections* (vertex ids, CSR offsets, edge
//!   targets, weights, remote-ref tables) behind a section directory
//!   in the header. Every section carries its own FNV checksum, so a
//!   section can be validated and decoded independently — corruption
//!   errors name the section, and a reader that skips a section never
//!   pays to checksum it.
//! * **v3 "packed"** ([`SliceFormat::V3Packed`]) — no per-slice files
//!   at all: every section of every sub-graph in a partition, topology
//!   and attribute columns alike, lives in one `partition.gfsp` file
//!   behind a length-addressed directory, and a projected load `seek`s
//!   past the sections it does not want. The per-sub-graph section
//!   *bodies* are byte-identical to v2's (this module builds and
//!   decodes them for both formats, via the crate-internal
//!   `topology_sections` / `decode_topology_from` helpers); the packed
//!   container layout lives in
//!   [`crate::gofs::packed`], and because a packed store has no
//!   per-sub-graph files, [`encode_topology`]/[`encode_attribute`] are
//!   defined only for v1/v2 — `Store` routes v3 to the packed writer.
//!
//! v1 encoding is frozen: stores written by older code stay loadable
//! byte-for-byte (pinned by a golden test in `tests/gofs_roundtrip.rs`).

use anyhow::{bail, ensure, Context, Result};

use crate::graph::csr::Graph;
use crate::util::codec::{Decoder, Encoder};

use super::section::{self, SectionTable};
use super::subgraph::{RemoteRef, Subgraph, SubgraphId};

const MAGIC: &[u8; 4] = b"GFSL";
const VERSION_V1: u8 = 1;
const VERSION_V2: u8 = 2;
const KIND_TOPOLOGY: u8 = 0;
const KIND_ATTRIBUTE: u8 = 1;

/// On-disk store format. v2 (columnar sections) is the default; v1
/// remains writable for compatibility tooling and readable forever;
/// v3 packs each partition into a single seek-skippable file.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum SliceFormat {
    /// Sequential codec payload + whole-payload checksum, one file per
    /// slice.
    V1,
    /// Columnar fixed-width sections + per-section checksums, one file
    /// per slice.
    #[default]
    V2,
    /// One packed `partition.gfsp` per partition: all sub-graphs'
    /// sections behind one length-addressed directory
    /// ([`crate::gofs::packed`]); projected loads seek past unwanted
    /// sections instead of reading them.
    V3Packed,
}

impl SliceFormat {
    pub fn as_str(&self) -> &'static str {
        match self {
            SliceFormat::V1 => "v1",
            SliceFormat::V2 => "v2",
            SliceFormat::V3Packed => "v3",
        }
    }

    /// Parse a CLI/meta spelling ("v1"/"v2"/"v3").
    pub fn parse(s: &str) -> Option<SliceFormat> {
        match s {
            "v1" => Some(SliceFormat::V1),
            "v2" => Some(SliceFormat::V2),
            "v3" => Some(SliceFormat::V3Packed),
            _ => None,
        }
    }
}

impl std::fmt::Display for SliceFormat {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

use super::section::checksum;

// ------------------------------------------------------------- v1 framing

fn frame_v1(kind: u8, payload: Vec<u8>) -> Vec<u8> {
    let mut out = Vec::with_capacity(payload.len() + 16);
    out.extend_from_slice(MAGIC);
    out.push(VERSION_V1);
    out.push(kind);
    let mut e = Encoder::new();
    e.put_varint(payload.len() as u64);
    e.put_varint(checksum(&payload));
    out.extend_from_slice(&e.into_bytes());
    out.extend_from_slice(&payload);
    out
}

fn unframe_v1(bytes: &[u8], want_kind: u8) -> Result<&[u8]> {
    ensure!(bytes.len() >= 6, "slice too short ({} bytes)", bytes.len());
    ensure!(&bytes[..4] == MAGIC, "bad slice magic");
    ensure!(bytes[4] == VERSION_V1, "unsupported slice version {}", bytes[4]);
    ensure!(
        bytes[5] == want_kind,
        "wrong slice kind: want {want_kind}, got {}",
        bytes[5]
    );
    let mut d = Decoder::new(&bytes[6..]);
    let len = d.get_varint()? as usize;
    let sum = d.get_varint()?;
    let consumed = bytes.len() - 6 - d.remaining();
    let payload = &bytes[6 + consumed..];
    ensure!(
        payload.len() == len,
        "slice payload truncated: header says {len}, have {}",
        payload.len()
    );
    ensure!(checksum(payload) == sum, "slice checksum mismatch (corrupted)");
    Ok(payload)
}

// ------------------------------------------------------------- v2 framing

/// Section ids of the columnar layout (shared by v2 slices and the v3
/// packed directory).
pub(crate) const SEC_META: u8 = 0;
pub(crate) const SEC_VERTICES: u8 = 1;
pub(crate) const SEC_OFFSETS: u8 = 2;
pub(crate) const SEC_TARGETS: u8 = 3;
pub(crate) const SEC_WEIGHTS: u8 = 4;
pub(crate) const SEC_REMOTE_OUT: u8 = 5;
pub(crate) const SEC_REMOTE_IN: u8 = 6;
pub(crate) const SEC_VALUES: u8 = 7;

pub(crate) fn section_name(id: u8) -> &'static str {
    match id {
        SEC_META => "meta",
        SEC_VERTICES => "vertices",
        SEC_OFFSETS => "offsets",
        SEC_TARGETS => "targets",
        SEC_WEIGHTS => "weights",
        SEC_REMOTE_OUT => "remote_out",
        SEC_REMOTE_IN => "remote_in",
        SEC_VALUES => "values",
        _ => "unknown",
    }
}

/// v2 framing is the shared sectioned-file layout ([`section`]): `MAGIC,
/// version, kind, nsections`, then one 20-byte directory entry per
/// section (`id u8, pad[3], len u64 LE, fnv u64 LE`), then the section
/// bodies back to back in directory order.
fn frame_v2(kind: u8, sections: &[(u8, Vec<u8>)]) -> Vec<u8> {
    section::frame(MAGIC, VERSION_V2, kind, sections)
}

fn unframe_v2(bytes: &[u8], want_kind: u8) -> Result<SectionTable<'_>> {
    section::unframe(bytes, MAGIC, VERSION_V2, want_kind, section_name)
}

/// Section layout of a v2 slice: `(name, byte range)` per directory
/// entry, in file order. Test/tooling surface (per-section corruption
/// drills, layout dumps).
pub fn section_ranges(bytes: &[u8]) -> Result<Vec<(&'static str, std::ops::Range<usize>)>> {
    ensure!(bytes.len() >= section::HEADER_LEN, "slice too short");
    ensure!(&bytes[..4] == MAGIC, "bad slice magic");
    ensure!(bytes[4] == VERSION_V2, "not a v2 slice (version {})", bytes[4]);
    Ok(unframe_v2(bytes, bytes[5])?.ranges())
}

/// What a slice file must contain, derived from its filename (the
/// scrubber's expectation — the kind byte is the one header byte no
/// checksum covers, so it is validated against the layout, exactly as
/// the checkpoint scrubber does).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SliceKind {
    Topology,
    Attribute,
}

/// Full checksum scrub of a slice of either format: `(section name,
/// clean?)` per section — `[("payload", _)]` for the whole-payload v1
/// framing. Structural damage (bad magic, truncation, a kind byte that
/// contradicts `want`) is an `Err`; bit rot inside an intact structure
/// is a `false` entry. Feeds the `store verify` CLI subcommand.
pub fn scrub(bytes: &[u8], want: SliceKind) -> Result<Vec<(&'static str, bool)>> {
    let want_kind = match want {
        SliceKind::Topology => KIND_TOPOLOGY,
        SliceKind::Attribute => KIND_ATTRIBUTE,
    };
    ensure!(bytes.len() >= 6, "slice too short ({} bytes)", bytes.len());
    ensure!(&bytes[..4] == MAGIC, "bad slice magic");
    match bytes[4] {
        VERSION_V1 => {
            ensure!(
                bytes[5] == want_kind,
                "wrong slice kind: want {want_kind}, got {}",
                bytes[5]
            );
            let mut d = Decoder::new(&bytes[6..]);
            let len = d.get_varint()? as usize;
            let sum = d.get_varint()?;
            let consumed = bytes.len() - 6 - d.remaining();
            let payload = &bytes[6 + consumed..];
            ensure!(
                payload.len() == len,
                "slice payload truncated: header says {len}, have {}",
                payload.len()
            );
            Ok(vec![("payload", checksum(payload) == sum)])
        }
        VERSION_V2 => {
            Ok(unframe_v2(bytes, want_kind)?.scrub())
        }
        v => bail!("unsupported slice version {v}"),
    }
}

// -------------------------------------------- fixed-width column helpers

fn put_u32s(out: &mut Vec<u8>, vals: impl Iterator<Item = u32>) {
    for v in vals {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

fn get_u32s(body: &[u8], section: u8) -> Result<Vec<u32>> {
    ensure!(
        body.len() % 4 == 0,
        "section `{}` length {} not a multiple of 4",
        section_name(section),
        body.len()
    );
    Ok(body
        .chunks_exact(4)
        .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

fn get_u64s(body: &[u8], section: u8) -> Result<Vec<u64>> {
    ensure!(
        body.len() % 8 == 0,
        "section `{}` length {} not a multiple of 8",
        section_name(section),
        body.len()
    );
    Ok(body
        .chunks_exact(8)
        .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

fn get_f32s(body: &[u8], section: u8) -> Result<Vec<f32>> {
    ensure!(
        body.len() % 4 == 0,
        "section `{}` length {} not a multiple of 4",
        section_name(section),
        body.len()
    );
    Ok(body
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

/// Fixed 20-byte remote-ref record: `local, target_global, partition,
/// subgraph` (u32 LE) + `weight` (f32 LE).
const REMOTE_RECORD_LEN: usize = 20;

fn encode_remote_v2(refs: &[RemoteRef]) -> Vec<u8> {
    let mut out = Vec::with_capacity(refs.len() * REMOTE_RECORD_LEN);
    for r in refs {
        out.extend_from_slice(&r.local.to_le_bytes());
        out.extend_from_slice(&r.target_global.to_le_bytes());
        out.extend_from_slice(&r.partition.to_le_bytes());
        out.extend_from_slice(&r.subgraph.to_le_bytes());
        out.extend_from_slice(&r.weight.to_le_bytes());
    }
    out
}

fn decode_remote_v2(body: &[u8], section: u8) -> Result<Vec<RemoteRef>> {
    ensure!(
        body.len() % REMOTE_RECORD_LEN == 0,
        "section `{}` length {} not a multiple of {REMOTE_RECORD_LEN}",
        section_name(section),
        body.len()
    );
    Ok(body
        .chunks_exact(REMOTE_RECORD_LEN)
        .map(|c| RemoteRef {
            local: u32::from_le_bytes(c[0..4].try_into().unwrap()),
            target_global: u32::from_le_bytes(c[4..8].try_into().unwrap()),
            partition: u32::from_le_bytes(c[8..12].try_into().unwrap()),
            subgraph: u32::from_le_bytes(c[12..16].try_into().unwrap()),
            weight: f32::from_le_bytes(c[16..20].try_into().unwrap()),
        })
        .collect())
}

// ------------------------------------------------------------ v1 payload

fn put_remote_v1(e: &mut Encoder, refs: &[RemoteRef]) {
    e.put_varint(refs.len() as u64);
    for r in refs {
        e.put_varint(r.local as u64);
        e.put_varint(r.target_global as u64);
        e.put_varint(r.partition as u64);
        e.put_varint(r.subgraph as u64);
        e.put_f32(r.weight);
    }
}

fn get_remote_v1(d: &mut Decoder) -> Result<Vec<RemoteRef>> {
    let n = d.get_varint()? as usize;
    ensure!(n <= d.remaining(), "remote edge count {n} exceeds buffer");
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        out.push(RemoteRef {
            local: d.get_varint()? as u32,
            target_global: d.get_varint()? as u32,
            partition: d.get_varint()? as u32,
            subgraph: d.get_varint()? as u32,
            weight: d.get_f32()?,
        });
    }
    Ok(out)
}

fn encode_topology_v1(sg: &Subgraph) -> Vec<u8> {
    let mut e = Encoder::with_capacity(
        16 + sg.vertices.len() * 3 + sg.local.num_edges() * 4,
    );
    e.put_varint(sg.id.partition as u64);
    e.put_varint(sg.id.index as u64);
    e.put_varint(sg.num_global_vertices);
    e.put_u8(sg.local.directed() as u8);
    e.put_u8(sg.local.has_weights() as u8);
    e.put_sorted_ids(&sg.vertices.iter().map(|&v| v as u64).collect::<Vec<_>>());
    // Local edges, grouped by source (delta-friendly, CSR order).
    e.put_varint(sg.local.num_edges() as u64);
    for (u, v, ei) in sg.local.edges() {
        e.put_varint(u as u64);
        e.put_varint(v as u64);
        if sg.local.has_weights() {
            e.put_f32(sg.local.weight(ei));
        }
    }
    put_remote_v1(&mut e, &sg.remote_out);
    put_remote_v1(&mut e, &sg.remote_in);
    frame_v1(KIND_TOPOLOGY, e.into_bytes())
}

fn decode_topology_v1(bytes: &[u8]) -> Result<Subgraph> {
    let payload = unframe_v1(bytes, KIND_TOPOLOGY).context("topology slice")?;
    let mut d = Decoder::new(payload);
    let partition = d.get_varint()? as u32;
    let index = d.get_varint()? as u32;
    let num_global_vertices = d.get_varint()?;
    let directed = d.get_u8()? != 0;
    let weighted = d.get_u8()? != 0;
    let vertices: Vec<u32> = d
        .get_sorted_ids()?
        .into_iter()
        .map(|v| v as u32)
        .collect();
    let ne = d.get_varint()? as usize;
    ensure!(ne <= d.remaining(), "edge count {ne} exceeds buffer");
    let mut edges = Vec::with_capacity(ne);
    let mut weights = if weighted { Some(Vec::with_capacity(ne)) } else { None };
    for _ in 0..ne {
        let u = d.get_varint()? as u32;
        let v = d.get_varint()? as u32;
        edges.push((u, v));
        if let Some(w) = &mut weights {
            w.push(d.get_f32()?);
        }
    }
    let remote_out = get_remote_v1(&mut d)?;
    let remote_in = get_remote_v1(&mut d)?;
    if !d.is_at_end() {
        bail!("topology slice has {} trailing bytes", d.remaining());
    }
    let local = Graph::from_edges(vertices.len(), &edges, weights, directed)?;
    Ok(Subgraph {
        id: SubgraphId { partition, index },
        vertices,
        local,
        remote_out,
        remote_in,
        num_global_vertices,
    })
}

// ------------------------------------------------------------ v2 payload

/// v2 topology meta section: `partition u32, index u32, nverts u32,
/// nedges u64, n_remote_out u32, n_remote_in u32, num_global u64,
/// flags u8 (bit0 directed, bit1 weighted)`.
const TOPO_META_LEN: usize = 37;

/// The columnar section bodies of one sub-graph's topology — the v2
/// slice payload and, unchanged, the per-sub-graph unit of the v3
/// packed layout (only the container differs between the two formats).
pub(crate) fn topology_sections(sg: &Subgraph) -> Vec<(u8, Vec<u8>)> {
    let n = sg.local.num_vertices();
    let ne = sg.local.num_edges();
    let weighted = sg.local.has_weights();

    let mut meta = Vec::with_capacity(TOPO_META_LEN);
    meta.extend_from_slice(&sg.id.partition.to_le_bytes());
    meta.extend_from_slice(&sg.id.index.to_le_bytes());
    meta.extend_from_slice(&(n as u32).to_le_bytes());
    meta.extend_from_slice(&(ne as u64).to_le_bytes());
    meta.extend_from_slice(&(sg.remote_out.len() as u32).to_le_bytes());
    meta.extend_from_slice(&(sg.remote_in.len() as u32).to_le_bytes());
    meta.extend_from_slice(&sg.num_global_vertices.to_le_bytes());
    meta.push((sg.local.directed() as u8) | ((weighted as u8) << 1));

    let mut verts = Vec::with_capacity(n * 4);
    put_u32s(&mut verts, sg.vertices.iter().copied());

    // CSR columns: offsets (n+1 × u64), targets (ne × u32), weights.
    let mut offsets = Vec::with_capacity((n + 1) * 8);
    let mut targets = Vec::with_capacity(ne * 4);
    let mut wcol = Vec::with_capacity(if weighted { ne * 4 } else { 0 });
    let mut acc = 0u64;
    offsets.extend_from_slice(&acc.to_le_bytes());
    for v in 0..n as u32 {
        for (t, ei) in sg.local.out_edges(v) {
            targets.extend_from_slice(&t.to_le_bytes());
            if weighted {
                wcol.extend_from_slice(&sg.local.weight(ei).to_le_bytes());
            }
            acc += 1;
        }
        offsets.extend_from_slice(&acc.to_le_bytes());
    }

    let mut sections = vec![
        (SEC_META, meta),
        (SEC_VERTICES, verts),
        (SEC_OFFSETS, offsets),
        (SEC_TARGETS, targets),
    ];
    if weighted {
        sections.push((SEC_WEIGHTS, wcol));
    }
    sections.push((SEC_REMOTE_OUT, encode_remote_v2(&sg.remote_out)));
    sections.push((SEC_REMOTE_IN, encode_remote_v2(&sg.remote_in)));
    sections
}

fn encode_topology_v2(sg: &Subgraph) -> Vec<u8> {
    frame_v2(KIND_TOPOLOGY, &topology_sections(sg))
}

fn decode_topology_v2(bytes: &[u8]) -> Result<Subgraph> {
    let table = unframe_v2(bytes, KIND_TOPOLOGY).context("topology slice")?;
    decode_topology_from(|id| table.get(id))
}

/// Decode a sub-graph from its columnar sections; `get` resolves a
/// section id to its (already checksum-verified) body. Shared by the
/// v2 per-slice decoder and the v3 packed loader — the latter hands in
/// closures that *borrow* section bodies straight out of a single read
/// buffer, so nothing is copied before materialization.
pub(crate) fn decode_topology_from<'a, F>(get: F) -> Result<Subgraph>
where
    F: Fn(u8) -> Result<&'a [u8]>,
{
    let meta = get(SEC_META)?;
    ensure!(
        meta.len() == TOPO_META_LEN,
        "section `meta` has {} bytes, expected {TOPO_META_LEN}",
        meta.len()
    );
    let partition = u32::from_le_bytes(meta[0..4].try_into().unwrap());
    let index = u32::from_le_bytes(meta[4..8].try_into().unwrap());
    let n = u32::from_le_bytes(meta[8..12].try_into().unwrap()) as usize;
    let ne = u64::from_le_bytes(meta[12..20].try_into().unwrap()) as usize;
    let n_remote_out = u32::from_le_bytes(meta[20..24].try_into().unwrap()) as usize;
    let n_remote_in = u32::from_le_bytes(meta[24..28].try_into().unwrap()) as usize;
    let num_global_vertices = u64::from_le_bytes(meta[28..36].try_into().unwrap());
    let flags = meta[36];
    let directed = flags & 1 != 0;
    let weighted = flags & 2 != 0;

    let vertices = get_u32s(get(SEC_VERTICES)?, SEC_VERTICES)?;
    ensure!(
        vertices.len() == n,
        "section `vertices` holds {} ids, meta says {n}",
        vertices.len()
    );
    ensure!(
        vertices.windows(2).all(|w| w[0] < w[1]),
        "section `vertices` ids not strictly ascending"
    );

    let offsets = get_u64s(get(SEC_OFFSETS)?, SEC_OFFSETS)?;
    ensure!(
        offsets.len() == n + 1,
        "section `offsets` holds {} entries, expected {}",
        offsets.len(),
        n + 1
    );
    ensure!(
        offsets[0] == 0 && offsets[n] as usize == ne,
        "section `offsets` endpoints inconsistent with meta"
    );
    ensure!(
        offsets.windows(2).all(|w| w[0] <= w[1]),
        "section `offsets` not monotone"
    );

    let targets = get_u32s(get(SEC_TARGETS)?, SEC_TARGETS)?;
    ensure!(
        targets.len() == ne,
        "section `targets` holds {} edges, meta says {ne}",
        targets.len()
    );

    let weights = if weighted {
        let w = get_f32s(get(SEC_WEIGHTS)?, SEC_WEIGHTS)?;
        ensure!(
            w.len() == ne,
            "section `weights` holds {} entries, meta says {ne}",
            w.len()
        );
        Some(w)
    } else {
        None
    };

    let mut edges = Vec::with_capacity(ne);
    for v in 0..n {
        for i in offsets[v] as usize..offsets[v + 1] as usize {
            edges.push((v as u32, targets[i]));
        }
    }

    let remote_out = decode_remote_v2(get(SEC_REMOTE_OUT)?, SEC_REMOTE_OUT)?;
    ensure!(
        remote_out.len() == n_remote_out,
        "section `remote_out` holds {} refs, meta says {n_remote_out}",
        remote_out.len()
    );
    let remote_in = decode_remote_v2(get(SEC_REMOTE_IN)?, SEC_REMOTE_IN)?;
    ensure!(
        remote_in.len() == n_remote_in,
        "section `remote_in` holds {} refs, meta says {n_remote_in}",
        remote_in.len()
    );

    let local = Graph::from_edges(n, &edges, weights, directed)?;
    Ok(Subgraph {
        id: SubgraphId { partition, index },
        vertices,
        local,
        remote_out,
        remote_in,
        num_global_vertices,
    })
}

// ------------------------------------------------------------ public API

/// Encode a sub-graph's topology slice in the given format.
///
/// # Panics
///
/// For [`SliceFormat::V3Packed`]: a packed store has no per-sub-graph
/// slice files — its writer packs the topology sections for the whole
/// partition into one file (see [`crate::gofs::packed`]; `Store`
/// routes v3 there and never reaches this function).
pub fn encode_topology(sg: &Subgraph, format: SliceFormat) -> Vec<u8> {
    match format {
        SliceFormat::V1 => encode_topology_v1(sg),
        SliceFormat::V2 => encode_topology_v2(sg),
        SliceFormat::V3Packed => {
            panic!("v3 packed stores have no per-sub-graph slices; use gofs::packed")
        }
    }
}

/// Decode a topology slice of either format (version-byte dispatch).
pub fn decode_topology(bytes: &[u8]) -> Result<Subgraph> {
    ensure!(bytes.len() >= 6, "slice too short ({} bytes)", bytes.len());
    ensure!(&bytes[..4] == MAGIC, "bad slice magic");
    match bytes[4] {
        VERSION_V1 => decode_topology_v1(bytes),
        VERSION_V2 => decode_topology_v2(bytes),
        v => bail!("unsupported slice version {v}"),
    }
}

fn encode_attribute_v1(id: SubgraphId, name: &str, values: &[f32]) -> Vec<u8> {
    let mut e = Encoder::with_capacity(16 + name.len() + values.len() * 4);
    e.put_varint(id.partition as u64);
    e.put_varint(id.index as u64);
    e.put_str(name);
    e.put_varint(values.len() as u64);
    for &v in values {
        e.put_f32(v);
    }
    frame_v1(KIND_ATTRIBUTE, e.into_bytes())
}

fn decode_attribute_v1(bytes: &[u8]) -> Result<(SubgraphId, String, Vec<f32>)> {
    let payload = unframe_v1(bytes, KIND_ATTRIBUTE).context("attribute slice")?;
    let mut d = Decoder::new(payload);
    let partition = d.get_varint()? as u32;
    let index = d.get_varint()? as u32;
    let name = d.get_str()?.to_string();
    let n = d.get_varint()? as usize;
    ensure!(n * 4 <= d.remaining(), "attribute count {n} exceeds buffer");
    let mut values = Vec::with_capacity(n);
    for _ in 0..n {
        values.push(d.get_f32()?);
    }
    ensure!(d.is_at_end(), "attribute slice has trailing bytes");
    Ok((SubgraphId { partition, index }, name, values))
}

/// Encode a bare f32 attribute column — the body of a `values`
/// section. v2 wraps it in a sectioned slice file with a meta section;
/// the v3 packed layout stores it directly (sub-graph index and
/// attribute name live in the packed directory entry).
pub(crate) fn f32_column(values: &[f32]) -> Vec<u8> {
    let mut vals = Vec::with_capacity(values.len() * 4);
    for &v in values {
        vals.extend_from_slice(&v.to_le_bytes());
    }
    vals
}

/// Decode a bare f32 attribute column (a `values` section body).
pub(crate) fn decode_f32_column(body: &[u8]) -> Result<Vec<f32>> {
    get_f32s(body, SEC_VALUES)
}

/// v2 attribute meta section: `partition u32, index u32, count u32,
/// name_len u32, name bytes`.
fn encode_attribute_v2(id: SubgraphId, name: &str, values: &[f32]) -> Vec<u8> {
    let mut meta = Vec::with_capacity(16 + name.len());
    meta.extend_from_slice(&id.partition.to_le_bytes());
    meta.extend_from_slice(&id.index.to_le_bytes());
    meta.extend_from_slice(&(values.len() as u32).to_le_bytes());
    meta.extend_from_slice(&(name.len() as u32).to_le_bytes());
    meta.extend_from_slice(name.as_bytes());
    frame_v2(KIND_ATTRIBUTE, &[(SEC_META, meta), (SEC_VALUES, f32_column(values))])
}

fn decode_attribute_v2(bytes: &[u8]) -> Result<(SubgraphId, String, Vec<f32>)> {
    let table = unframe_v2(bytes, KIND_ATTRIBUTE).context("attribute slice")?;
    let meta = table.get(SEC_META)?;
    ensure!(meta.len() >= 16, "section `meta` has {} bytes, need >= 16", meta.len());
    let partition = u32::from_le_bytes(meta[0..4].try_into().unwrap());
    let index = u32::from_le_bytes(meta[4..8].try_into().unwrap());
    let count = u32::from_le_bytes(meta[8..12].try_into().unwrap()) as usize;
    let name_len = u32::from_le_bytes(meta[12..16].try_into().unwrap()) as usize;
    ensure!(
        meta.len() == 16 + name_len,
        "section `meta` has {} bytes, expected {}",
        meta.len(),
        16 + name_len
    );
    let name = std::str::from_utf8(&meta[16..])
        .context("attribute name not utf-8")?
        .to_string();
    let values = get_f32s(table.get(SEC_VALUES)?, SEC_VALUES)?;
    ensure!(
        values.len() == count,
        "section `values` holds {} entries, meta says {count}",
        values.len()
    );
    Ok((SubgraphId { partition, index }, name, values))
}

/// Encode a named per-vertex f32 attribute slice for one sub-graph.
///
/// # Panics
///
/// For [`SliceFormat::V3Packed`], like [`encode_topology`]: attribute
/// columns of a packed store live inside `partition.gfsp` (`Store`
/// appends them via a directory rewrite, never through this function).
pub fn encode_attribute(
    id: SubgraphId,
    name: &str,
    values: &[f32],
    format: SliceFormat,
) -> Vec<u8> {
    match format {
        SliceFormat::V1 => encode_attribute_v1(id, name, values),
        SliceFormat::V2 => encode_attribute_v2(id, name, values),
        SliceFormat::V3Packed => {
            panic!("v3 packed stores have no per-sub-graph slices; use gofs::packed")
        }
    }
}

/// Decode an attribute slice of either format: `(id, name, values)`.
pub fn decode_attribute(bytes: &[u8]) -> Result<(SubgraphId, String, Vec<f32>)> {
    ensure!(bytes.len() >= 6, "slice too short ({} bytes)", bytes.len());
    ensure!(&bytes[..4] == MAGIC, "bad slice magic");
    match bytes[4] {
        VERSION_V1 => decode_attribute_v1(bytes),
        VERSION_V2 => decode_attribute_v2(bytes),
        v => bail!("unsupported slice version {v}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gofs::subgraph::discover;
    use crate::graph::gen;
    use crate::partition::{Partitioner, RangePartitioner};

    const BOTH: [SliceFormat; 2] = [SliceFormat::V1, SliceFormat::V2];

    fn sample_subgraphs(weighted: bool) -> Vec<Subgraph> {
        let base = gen::road(12, 0.9, 0.02, 5);
        let g = if weighted {
            gen::with_random_weights(&base, 1.0, 10.0, 6)
        } else {
            base
        };
        let parts = RangePartitioner.partition(&g, 3);
        let dg = discover(&g, &parts).unwrap();
        dg.subgraphs().cloned().collect()
    }

    fn assert_subgraph_eq(a: &Subgraph, b: &Subgraph) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.vertices, b.vertices);
        assert_eq!(a.num_global_vertices, b.num_global_vertices);
        assert_eq!(a.local.num_vertices(), b.local.num_vertices());
        assert_eq!(a.local.num_edges(), b.local.num_edges());
        let ea: Vec<_> = a.local.edges().map(|(u, v, ei)| (u, v, a.local.weight(ei))).collect();
        let eb: Vec<_> = b.local.edges().map(|(u, v, ei)| (u, v, b.local.weight(ei))).collect();
        assert_eq!(ea, eb);
        assert_eq!(a.remote_out, b.remote_out);
        assert_eq!(a.remote_in, b.remote_in);
    }

    #[test]
    fn topology_round_trip_unweighted() {
        for fmt in BOTH {
            for sg in sample_subgraphs(false) {
                let bytes = encode_topology(&sg, fmt);
                let back = decode_topology(&bytes).unwrap();
                assert_subgraph_eq(&sg, &back);
            }
        }
    }

    #[test]
    fn topology_round_trip_weighted() {
        for fmt in BOTH {
            for sg in sample_subgraphs(true) {
                let bytes = encode_topology(&sg, fmt);
                let back = decode_topology(&bytes).unwrap();
                assert_subgraph_eq(&sg, &back);
            }
        }
    }

    #[test]
    fn v1_and_v2_decode_identically() {
        for sg in sample_subgraphs(true) {
            let a = decode_topology(&encode_topology(&sg, SliceFormat::V1)).unwrap();
            let b = decode_topology(&encode_topology(&sg, SliceFormat::V2)).unwrap();
            assert_subgraph_eq(&a, &b);
        }
    }

    #[test]
    fn attribute_round_trip() {
        for fmt in BOTH {
            let id = SubgraphId { partition: 2, index: 7 };
            let vals = vec![1.0f32, -2.5, 0.0, f32::INFINITY];
            let bytes = encode_attribute(id, "rank", &vals, fmt);
            let (id2, name, vals2) = decode_attribute(&bytes).unwrap();
            assert_eq!(id2, id);
            assert_eq!(name, "rank");
            assert_eq!(vals2, vals);
        }
    }

    #[test]
    fn truncation_detected() {
        for fmt in BOTH {
            let sg = &sample_subgraphs(false)[0];
            let bytes = encode_topology(sg, fmt);
            for cut in [6, bytes.len() / 2, bytes.len() - 1] {
                assert!(
                    decode_topology(&bytes[..cut]).is_err(),
                    "{fmt}: cut at {cut} must fail"
                );
            }
        }
    }

    #[test]
    fn corruption_detected() {
        for fmt in BOTH {
            let sg = &sample_subgraphs(false)[0];
            let mut bytes = encode_topology(sg, fmt);
            let mid = bytes.len() / 2;
            bytes[mid] ^= 0xff;
            assert!(decode_topology(&bytes).is_err(), "{fmt}");
        }
    }

    #[test]
    fn v2_corruption_errors_name_the_section() {
        let sg = &sample_subgraphs(true)[0];
        let bytes = encode_topology(sg, SliceFormat::V2);
        let sections = section_ranges(&bytes).unwrap();
        assert!(sections.iter().any(|(n, _)| *n == "weights"));
        for (name, range) in sections {
            if range.is_empty() {
                continue;
            }
            let mut bad = bytes.clone();
            bad[range.start + range.len() / 2] ^= 0x55;
            let err = decode_topology(&bad).unwrap_err();
            assert!(
                format!("{err:#}").contains(name),
                "flipping `{name}` produced error not naming it: {err:#}"
            );
        }
    }

    #[test]
    fn wrong_kind_rejected() {
        for fmt in BOTH {
            let bytes =
                encode_attribute(SubgraphId { partition: 0, index: 0 }, "x", &[1.0], fmt);
            assert!(decode_topology(&bytes).is_err(), "{fmt}");
        }
    }

    #[test]
    fn bad_magic_and_version_rejected() {
        let sg = &sample_subgraphs(false)[0];
        let mut bytes = encode_topology(sg, SliceFormat::V2);
        bytes[0] = b'X';
        assert!(decode_topology(&bytes).is_err());
        let mut bytes = encode_topology(sg, SliceFormat::V2);
        bytes[4] = 9;
        assert!(decode_topology(&bytes).is_err());
    }

    #[test]
    fn empty_subgraph_round_trip() {
        for fmt in BOTH {
            let g = Graph::from_edges(1, &[], None, false).unwrap();
            let sg = Subgraph {
                id: SubgraphId { partition: 0, index: 0 },
                vertices: vec![0],
                local: g,
                remote_out: vec![],
                remote_in: vec![],
                num_global_vertices: 1,
            };
            let back = decode_topology(&encode_topology(&sg, fmt)).unwrap();
            assert_subgraph_eq(&sg, &back);
        }
    }

    #[test]
    fn format_parse_display_round_trip() {
        assert_eq!(SliceFormat::parse("v1"), Some(SliceFormat::V1));
        assert_eq!(SliceFormat::parse("v2"), Some(SliceFormat::V2));
        assert_eq!(SliceFormat::parse("v3"), Some(SliceFormat::V3Packed));
        assert_eq!(SliceFormat::parse("v4"), None);
        assert_eq!(SliceFormat::default(), SliceFormat::V2);
        for fmt in [SliceFormat::V1, SliceFormat::V2, SliceFormat::V3Packed] {
            assert_eq!(SliceFormat::parse(fmt.as_str()), Some(fmt));
        }
    }

    #[test]
    fn packed_sections_decode_like_v2_slices() {
        // The packed layout reuses the v2 section bodies verbatim:
        // decoding them through `decode_topology_from` over borrowed
        // bodies must reproduce the sub-graph exactly.
        for sg in sample_subgraphs(true) {
            let sections = topology_sections(&sg);
            let back = decode_topology_from(|id| {
                sections
                    .iter()
                    .find(|(s, _)| *s == id)
                    .map(|(_, b)| b.as_slice())
                    .ok_or_else(|| {
                        anyhow::anyhow!("missing section `{}`", section_name(id))
                    })
            })
            .unwrap();
            assert_subgraph_eq(&sg, &back);
            // And the v2 slice of the same sub-graph is these bodies,
            // reframed.
            let v2 = decode_topology(&encode_topology(&sg, SliceFormat::V2)).unwrap();
            assert_subgraph_eq(&back, &v2);
        }
    }

    #[test]
    fn f32_column_round_trip() {
        let vals = vec![0.0f32, -1.5, 7.25, f32::MAX];
        assert_eq!(decode_f32_column(&f32_column(&vals)).unwrap(), vals);
        assert!(decode_f32_column(&[1, 2, 3]).is_err());
    }

    #[test]
    fn section_ranges_cover_v2_file_exactly() {
        let sg = &sample_subgraphs(true)[0];
        let bytes = encode_topology(sg, SliceFormat::V2);
        let sections = section_ranges(&bytes).unwrap();
        // Directory order, contiguous, ending at EOF.
        let mut pos = section::HEADER_LEN + sections.len() * section::DIR_ENTRY_LEN;
        for (_, r) in &sections {
            assert_eq!(r.start, pos);
            pos = r.end;
        }
        assert_eq!(pos, bytes.len());
        // v1 slices are not sectioned.
        assert!(section_ranges(&encode_topology(sg, SliceFormat::V1)).is_err());
    }

    #[test]
    fn scrub_reports_corruption_by_section_in_both_formats() {
        let sg = &sample_subgraphs(true)[0];
        // Clean files scrub clean.
        for fmt in BOTH {
            let bytes = encode_topology(sg, fmt);
            let report = scrub(&bytes, SliceKind::Topology).unwrap();
            assert!(!report.is_empty());
            assert!(report.iter().all(|(_, ok)| *ok), "{fmt}: {report:?}");
        }
        // v1: any payload flip lands on the single "payload" entry.
        let mut v1 = encode_topology(sg, SliceFormat::V1);
        let mid = v1.len() - 3;
        v1[mid] ^= 0x55;
        assert_eq!(
            scrub(&v1, SliceKind::Topology).unwrap(),
            vec![("payload", false)]
        );
        // v2: a flip in `targets` dirties exactly that section.
        let v2 = encode_topology(sg, SliceFormat::V2);
        let ranges = section_ranges(&v2).unwrap();
        let (name, r) = ranges
            .iter()
            .find(|(n, r)| *n == "targets" && !r.is_empty())
            .expect("targets section present")
            .clone();
        let mut bad = v2.clone();
        bad[r.start + r.len() / 2] ^= 0x55;
        let report = scrub(&bad, SliceKind::Topology).unwrap();
        for (n, ok) in &report {
            assert_eq!(*ok, *n != name, "section {n}");
        }
        // Structural damage is an error, not a report…
        assert!(scrub(&v2[..5], SliceKind::Topology).is_err());
        // …and so is a rotted kind byte — the one header byte no
        // section checksum covers (the loader would reject it too).
        for fmt in BOTH {
            let mut bytes = encode_topology(sg, fmt);
            bytes[5] = 1; // claims to be an attribute slice
            assert!(scrub(&bytes, SliceKind::Topology).is_err(), "{fmt}");
        }
    }
}
