//! Slice files: the unit of GoFS storage (paper §4.1).
//!
//! Each sub-graph maps to one *topology slice* (local vertices, local
//! edges, resolved remote edges) and any number of *attribute slices*
//! (named per-vertex value arrays). Keeping topology and attributes in
//! separate files lets an algorithm read exactly the bytes it needs —
//! the paper's "a graph with 10 attributes … needs to only load that
//! slice" co-design point, and the "Edge Imp." variant of Fig 4(b).
//!
//! Framing: `MAGIC, version, kind` header, then codec-encoded payload,
//! then a crc32-style checksum (FNV-1a 64 truncated — no crc crate in
//! the vendor set) so truncation/corruption is detected at load.

use anyhow::{bail, ensure, Context, Result};

use crate::graph::csr::Graph;
use crate::util::codec::{Decoder, Encoder};

use super::subgraph::{RemoteRef, Subgraph, SubgraphId};

const MAGIC: &[u8; 4] = b"GFSL";
const VERSION: u8 = 1;
const KIND_TOPOLOGY: u8 = 0;
const KIND_ATTRIBUTE: u8 = 1;

/// FNV-1a 64-bit checksum over the payload.
fn checksum(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

fn frame(kind: u8, payload: Vec<u8>) -> Vec<u8> {
    let mut out = Vec::with_capacity(payload.len() + 16);
    out.extend_from_slice(MAGIC);
    out.push(VERSION);
    out.push(kind);
    let mut e = Encoder::new();
    e.put_varint(payload.len() as u64);
    e.put_varint(checksum(&payload));
    out.extend_from_slice(&e.into_bytes());
    out.extend_from_slice(&payload);
    out
}

fn unframe(bytes: &[u8], want_kind: u8) -> Result<&[u8]> {
    ensure!(bytes.len() >= 6, "slice too short ({} bytes)", bytes.len());
    ensure!(&bytes[..4] == MAGIC, "bad slice magic");
    ensure!(bytes[4] == VERSION, "unsupported slice version {}", bytes[4]);
    ensure!(
        bytes[5] == want_kind,
        "wrong slice kind: want {want_kind}, got {}",
        bytes[5]
    );
    let mut d = Decoder::new(&bytes[6..]);
    let len = d.get_varint()? as usize;
    let sum = d.get_varint()?;
    let consumed = bytes.len() - 6 - d.remaining();
    let payload = &bytes[6 + consumed..];
    ensure!(
        payload.len() == len,
        "slice payload truncated: header says {len}, have {}",
        payload.len()
    );
    ensure!(checksum(payload) == sum, "slice checksum mismatch (corrupted)");
    Ok(payload)
}

fn put_remote(e: &mut Encoder, refs: &[RemoteRef]) {
    e.put_varint(refs.len() as u64);
    for r in refs {
        e.put_varint(r.local as u64);
        e.put_varint(r.target_global as u64);
        e.put_varint(r.partition as u64);
        e.put_varint(r.subgraph as u64);
        e.put_f32(r.weight);
    }
}

fn get_remote(d: &mut Decoder) -> Result<Vec<RemoteRef>> {
    let n = d.get_varint()? as usize;
    ensure!(n <= d.remaining(), "remote edge count {n} exceeds buffer");
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        out.push(RemoteRef {
            local: d.get_varint()? as u32,
            target_global: d.get_varint()? as u32,
            partition: d.get_varint()? as u32,
            subgraph: d.get_varint()? as u32,
            weight: d.get_f32()?,
        });
    }
    Ok(out)
}

/// Encode a sub-graph's topology slice.
pub fn encode_topology(sg: &Subgraph) -> Vec<u8> {
    let mut e = Encoder::with_capacity(
        16 + sg.vertices.len() * 3 + sg.local.num_edges() * 4,
    );
    e.put_varint(sg.id.partition as u64);
    e.put_varint(sg.id.index as u64);
    e.put_varint(sg.num_global_vertices);
    e.put_u8(sg.local.directed() as u8);
    e.put_u8(sg.local.has_weights() as u8);
    e.put_sorted_ids(&sg.vertices.iter().map(|&v| v as u64).collect::<Vec<_>>());
    // Local edges, grouped by source (delta-friendly, CSR order).
    e.put_varint(sg.local.num_edges() as u64);
    for (u, v, ei) in sg.local.edges() {
        e.put_varint(u as u64);
        e.put_varint(v as u64);
        if sg.local.has_weights() {
            e.put_f32(sg.local.weight(ei));
        }
    }
    put_remote(&mut e, &sg.remote_out);
    put_remote(&mut e, &sg.remote_in);
    frame(KIND_TOPOLOGY, e.into_bytes())
}

/// Decode a topology slice.
pub fn decode_topology(bytes: &[u8]) -> Result<Subgraph> {
    let payload = unframe(bytes, KIND_TOPOLOGY).context("topology slice")?;
    let mut d = Decoder::new(payload);
    let partition = d.get_varint()? as u32;
    let index = d.get_varint()? as u32;
    let num_global_vertices = d.get_varint()?;
    let directed = d.get_u8()? != 0;
    let weighted = d.get_u8()? != 0;
    let vertices: Vec<u32> = d
        .get_sorted_ids()?
        .into_iter()
        .map(|v| v as u32)
        .collect();
    let ne = d.get_varint()? as usize;
    ensure!(ne <= d.remaining(), "edge count {ne} exceeds buffer");
    let mut edges = Vec::with_capacity(ne);
    let mut weights = if weighted { Some(Vec::with_capacity(ne)) } else { None };
    for _ in 0..ne {
        let u = d.get_varint()? as u32;
        let v = d.get_varint()? as u32;
        edges.push((u, v));
        if let Some(w) = &mut weights {
            w.push(d.get_f32()?);
        }
    }
    let remote_out = get_remote(&mut d)?;
    let remote_in = get_remote(&mut d)?;
    if !d.is_at_end() {
        bail!("topology slice has {} trailing bytes", d.remaining());
    }
    let local = Graph::from_edges(vertices.len(), &edges, weights, directed)?;
    Ok(Subgraph {
        id: SubgraphId { partition, index },
        vertices,
        local,
        remote_out,
        remote_in,
        num_global_vertices,
    })
}

/// Encode a named per-vertex f32 attribute slice for one sub-graph.
pub fn encode_attribute(id: SubgraphId, name: &str, values: &[f32]) -> Vec<u8> {
    let mut e = Encoder::with_capacity(16 + name.len() + values.len() * 4);
    e.put_varint(id.partition as u64);
    e.put_varint(id.index as u64);
    e.put_str(name);
    e.put_varint(values.len() as u64);
    for &v in values {
        e.put_f32(v);
    }
    frame(KIND_ATTRIBUTE, e.into_bytes())
}

/// Decode an attribute slice: `(id, name, values)`.
pub fn decode_attribute(bytes: &[u8]) -> Result<(SubgraphId, String, Vec<f32>)> {
    let payload = unframe(bytes, KIND_ATTRIBUTE).context("attribute slice")?;
    let mut d = Decoder::new(payload);
    let partition = d.get_varint()? as u32;
    let index = d.get_varint()? as u32;
    let name = d.get_str()?.to_string();
    let n = d.get_varint()? as usize;
    ensure!(n * 4 <= d.remaining(), "attribute count {n} exceeds buffer");
    let mut values = Vec::with_capacity(n);
    for _ in 0..n {
        values.push(d.get_f32()?);
    }
    ensure!(d.is_at_end(), "attribute slice has trailing bytes");
    Ok((SubgraphId { partition, index }, name, values))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gofs::subgraph::discover;
    use crate::graph::gen;
    use crate::partition::{Partitioner, RangePartitioner};

    fn sample_subgraphs(weighted: bool) -> Vec<Subgraph> {
        let base = gen::road(12, 0.9, 0.02, 5);
        let g = if weighted {
            gen::with_random_weights(&base, 1.0, 10.0, 6)
        } else {
            base
        };
        let parts = RangePartitioner.partition(&g, 3);
        let dg = discover(&g, &parts).unwrap();
        dg.subgraphs().cloned().collect()
    }

    fn assert_subgraph_eq(a: &Subgraph, b: &Subgraph) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.vertices, b.vertices);
        assert_eq!(a.num_global_vertices, b.num_global_vertices);
        assert_eq!(a.local.num_vertices(), b.local.num_vertices());
        assert_eq!(a.local.num_edges(), b.local.num_edges());
        let ea: Vec<_> = a.local.edges().map(|(u, v, ei)| (u, v, a.local.weight(ei))).collect();
        let eb: Vec<_> = b.local.edges().map(|(u, v, ei)| (u, v, b.local.weight(ei))).collect();
        assert_eq!(ea, eb);
        assert_eq!(a.remote_out, b.remote_out);
        assert_eq!(a.remote_in, b.remote_in);
    }

    #[test]
    fn topology_round_trip_unweighted() {
        for sg in sample_subgraphs(false) {
            let bytes = encode_topology(&sg);
            let back = decode_topology(&bytes).unwrap();
            assert_subgraph_eq(&sg, &back);
        }
    }

    #[test]
    fn topology_round_trip_weighted() {
        for sg in sample_subgraphs(true) {
            let bytes = encode_topology(&sg);
            let back = decode_topology(&bytes).unwrap();
            assert_subgraph_eq(&sg, &back);
        }
    }

    #[test]
    fn attribute_round_trip() {
        let id = SubgraphId { partition: 2, index: 7 };
        let vals = vec![1.0f32, -2.5, 0.0, f32::INFINITY];
        let bytes = encode_attribute(id, "rank", &vals);
        let (id2, name, vals2) = decode_attribute(&bytes).unwrap();
        assert_eq!(id2, id);
        assert_eq!(name, "rank");
        assert_eq!(vals2, vals);
    }

    #[test]
    fn truncation_detected() {
        let sg = &sample_subgraphs(false)[0];
        let bytes = encode_topology(sg);
        for cut in [6, bytes.len() / 2, bytes.len() - 1] {
            assert!(
                decode_topology(&bytes[..cut]).is_err(),
                "cut at {cut} must fail"
            );
        }
    }

    #[test]
    fn corruption_detected() {
        let sg = &sample_subgraphs(false)[0];
        let mut bytes = encode_topology(sg);
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xff;
        assert!(decode_topology(&bytes).is_err());
    }

    #[test]
    fn wrong_kind_rejected() {
        let bytes = encode_attribute(SubgraphId { partition: 0, index: 0 }, "x", &[1.0]);
        assert!(decode_topology(&bytes).is_err());
    }

    #[test]
    fn bad_magic_rejected() {
        let sg = &sample_subgraphs(false)[0];
        let mut bytes = encode_topology(sg);
        bytes[0] = b'X';
        assert!(decode_topology(&bytes).is_err());
    }

    #[test]
    fn empty_subgraph_round_trip() {
        let g = Graph::from_edges(1, &[], None, false).unwrap();
        let sg = Subgraph {
            id: SubgraphId { partition: 0, index: 0 },
            vertices: vec![0],
            local: g,
            remote_out: vec![],
            remote_in: vec![],
            num_global_vertices: 1,
        };
        let back = decode_topology(&encode_topology(&sg)).unwrap();
        assert_subgraph_eq(&sg, &back);
    }
}
