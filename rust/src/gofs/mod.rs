//! GoFS — the Graph-oriented File System (paper §4.1).
//!
//! A distributed, write-once-read-many graph store co-designed with the
//! Gopher engine. Input graphs are k-way partitioned (one partition per
//! host); within each partition the weakly-connected components — the
//! **sub-graphs** of the paper's abstraction — are discovered and laid
//! out on disk in one of three formats: per-sub-graph *slice files*
//! (v1 codec payloads or v2 columnar sections; one topology slice per
//! sub-graph plus separate attribute slices) or the v3 *packed* layout
//! (one `partition.gfsp` per partition holding every section of every
//! sub-graph behind a seek-skippable directory — see [`packed`]).
//! Remote edges resolve to a (partition, sub-graph, vertex) triple at
//! store-build time, so no network resolution is ever needed at load
//! or run time.
//!
//! Packed (v3) stores additionally mutate by **generation**:
//! [`Store::append`] commits an [`AppendBatch`] as a new numbered
//! generation (fresh packed files + an atomic `meta.txt` rename), open
//! handles stay pinned to the generation they opened, and
//! [`Store::dirty_since`] reports which sub-graphs later generations
//! touched. See [`store`] and the streaming builder in
//! [`crate::ingest`].

// `packed` is docs-audited (see the crate-level missing_docs note in
// lib.rs); the older per-file format modules still carry allows.
pub mod packed;
#[allow(missing_docs)]
pub mod section;
#[allow(missing_docs)]
pub mod subgraph;
#[allow(missing_docs)]
pub mod slice;
#[allow(missing_docs)]
pub mod store;

pub use slice::SliceFormat;
pub use subgraph::{
    reassemble, DistributedGraph, PartitionAttributes, RemoteRef, Subgraph, SubgraphId,
};
pub use store::{AppendBatch, AttrProjection, LoadOptions, LoadStats, Store, StoreMeta};
