//! GoFS on-disk store: partition + discover + write slices, then load.
//!
//! Layout (`<root>` is the store directory, one `host<p>` subdirectory
//! per partition — the simulated per-machine local filesystem):
//!
//! ```text
//! <root>/meta.txt
//! <root>/host0/sg_0.topo.slice
//! <root>/host0/sg_0.attr.<name>.slice
//! <root>/host1/…
//! ```
//!
//! The store is write-once-read-many (paper §4.1): `create` builds it
//! from a graph + partitioning, `open` + `load_partition` serve Gopher.
//! Loading accounts files/bytes so the `sim` layer can model cluster
//! disk/network time for the Fig-4(b) loading benchmark.

use std::fs;
use std::path::{Path, PathBuf};
use std::time::Instant;

use anyhow::{bail, ensure, Context, Result};

use crate::graph::csr::Graph;
use crate::partition::Partitioning;

use super::slice;
use super::subgraph::{discover, DistributedGraph, Subgraph, SubgraphId};

/// Store-wide metadata (the `meta.txt` contents).
#[derive(Clone, Debug, PartialEq)]
pub struct StoreMeta {
    pub name: String,
    pub num_vertices: u64,
    pub num_edges: u64,
    pub directed: bool,
    pub weighted: bool,
    pub num_partitions: u32,
    /// Sub-graph count per partition.
    pub subgraph_counts: Vec<u32>,
}

/// Byte/file accounting for one load (feeds `sim::disk`).
#[derive(Clone, Copy, Debug, Default)]
pub struct LoadStats {
    pub files: u64,
    pub bytes: u64,
    pub seconds: f64,
}

/// Handle to an on-disk GoFS store.
pub struct Store {
    root: PathBuf,
    meta: StoreMeta,
}

impl Store {
    /// Partition `g`, discover sub-graphs, and write the whole store.
    pub fn create(
        root: &Path,
        name: &str,
        g: &Graph,
        parts: &Partitioning,
    ) -> Result<(Store, DistributedGraph)> {
        ensure!(
            !root.exists() || fs::read_dir(root)?.next().is_none(),
            "store root {} exists and is not empty (GoFS is write-once)",
            root.display()
        );
        let dg = discover(g, parts)?;
        fs::create_dir_all(root)?;
        for (p, sgs) in dg.partitions.iter().enumerate() {
            let host_dir = root.join(format!("host{p}"));
            fs::create_dir_all(&host_dir)?;
            for sg in sgs {
                let bytes = slice::encode_topology(sg);
                fs::write(host_dir.join(format!("sg_{}.topo.slice", sg.id.index)), bytes)?;
            }
        }
        let meta = StoreMeta {
            name: name.to_string(),
            num_vertices: g.num_vertices() as u64,
            num_edges: g.num_edges() as u64,
            directed: g.directed(),
            weighted: g.has_weights(),
            num_partitions: parts.k() as u32,
            subgraph_counts: dg.partitions.iter().map(|p| p.len() as u32).collect(),
        };
        write_meta(&root.join("meta.txt"), &meta)?;
        Ok((Store { root: root.to_path_buf(), meta }, dg))
    }

    /// Open an existing store.
    pub fn open(root: &Path) -> Result<Store> {
        let meta = read_meta(&root.join("meta.txt"))
            .with_context(|| format!("open store at {}", root.display()))?;
        Ok(Store { root: root.to_path_buf(), meta })
    }

    pub fn meta(&self) -> &StoreMeta {
        &self.meta
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    fn host_dir(&self, p: u32) -> PathBuf {
        self.root.join(format!("host{p}"))
    }

    /// Load all sub-graphs of partition `p` (data-local read: only this
    /// host's directory is touched — the GoFS co-design point).
    pub fn load_partition(&self, p: u32) -> Result<(Vec<Subgraph>, LoadStats)> {
        ensure!(p < self.meta.num_partitions, "partition {p} out of range");
        let t0 = Instant::now();
        let mut stats = LoadStats::default();
        let count = self.meta.subgraph_counts[p as usize];
        let mut sgs = Vec::with_capacity(count as usize);
        for i in 0..count {
            let path = self.host_dir(p).join(format!("sg_{i}.topo.slice"));
            let bytes =
                fs::read(&path).with_context(|| format!("read {}", path.display()))?;
            stats.files += 1;
            stats.bytes += bytes.len() as u64;
            let sg = slice::decode_topology(&bytes)
                .with_context(|| format!("decode {}", path.display()))?;
            ensure!(
                sg.id == SubgraphId { partition: p, index: i },
                "slice {} holds wrong sub-graph {}",
                path.display(),
                sg.id
            );
            sgs.push(sg);
        }
        stats.seconds = t0.elapsed().as_secs_f64();
        Ok((sgs, stats))
    }

    /// Load the entire distributed graph (all partitions).
    pub fn load_all(&self) -> Result<(DistributedGraph, LoadStats)> {
        let mut partitions = Vec::new();
        let mut total = LoadStats::default();
        for p in 0..self.meta.num_partitions {
            let (sgs, st) = self.load_partition(p)?;
            partitions.push(sgs);
            total.files += st.files;
            total.bytes += st.bytes;
            total.seconds += st.seconds;
        }
        Ok((
            DistributedGraph {
                partitions,
                num_global_vertices: self.meta.num_vertices,
                directed: self.meta.directed,
            },
            total,
        ))
    }

    /// Write a named per-vertex attribute for one sub-graph.
    pub fn write_attribute(&self, id: SubgraphId, name: &str, values: &[f32]) -> Result<()> {
        let path = self
            .host_dir(id.partition)
            .join(format!("sg_{}.attr.{name}.slice", id.index));
        fs::write(&path, slice::encode_attribute(id, name, values))
            .with_context(|| format!("write {}", path.display()))
    }

    /// Read a named attribute for one sub-graph.
    pub fn read_attribute(&self, id: SubgraphId, name: &str) -> Result<(Vec<f32>, LoadStats)> {
        let t0 = Instant::now();
        let path = self
            .host_dir(id.partition)
            .join(format!("sg_{}.attr.{name}.slice", id.index));
        let bytes = fs::read(&path).with_context(|| format!("read {}", path.display()))?;
        let (got_id, got_name, values) = slice::decode_attribute(&bytes)?;
        ensure!(got_id == id && got_name == name, "attribute slice mismatch");
        Ok((
            values,
            LoadStats { files: 1, bytes: bytes.len() as u64, seconds: t0.elapsed().as_secs_f64() },
        ))
    }
}

fn write_meta(path: &Path, meta: &StoreMeta) -> Result<()> {
    let counts: Vec<String> =
        meta.subgraph_counts.iter().map(|c| c.to_string()).collect();
    let text = format!(
        "name={}\nvertices={}\nedges={}\ndirected={}\nweighted={}\npartitions={}\nsubgraphs={}\n",
        meta.name,
        meta.num_vertices,
        meta.num_edges,
        meta.directed,
        meta.weighted,
        meta.num_partitions,
        counts.join(",")
    );
    fs::write(path, text).with_context(|| format!("write {}", path.display()))
}

fn read_meta(path: &Path) -> Result<StoreMeta> {
    let text = fs::read_to_string(path)?;
    let mut name = None;
    let mut vertices = None;
    let mut edges = None;
    let mut directed = None;
    let mut weighted = None;
    let mut partitions = None;
    let mut subgraphs = None;
    for line in text.lines() {
        let Some((k, v)) = line.split_once('=') else { continue };
        match k {
            "name" => name = Some(v.to_string()),
            "vertices" => vertices = Some(v.parse()?),
            "edges" => edges = Some(v.parse()?),
            "directed" => directed = Some(v == "true"),
            "weighted" => weighted = Some(v == "true"),
            "partitions" => partitions = Some(v.parse()?),
            "subgraphs" => {
                subgraphs = Some(
                    v.split(',')
                        .filter(|s| !s.is_empty())
                        .map(|s| s.parse::<u32>())
                        .collect::<Result<Vec<_>, _>>()?,
                )
            }
            _ => {}
        }
    }
    let (Some(name), Some(num_vertices), Some(num_edges), Some(directed), Some(weighted), Some(num_partitions), Some(subgraph_counts)) =
        (name, vertices, edges, directed, weighted, partitions, subgraphs)
    else {
        bail!("meta.txt missing required keys");
    };
    ensure!(
        subgraph_counts.len() == num_partitions as usize,
        "meta.txt subgraph counts do not match partition count"
    );
    Ok(StoreMeta {
        name,
        num_vertices,
        num_edges,
        directed,
        weighted,
        num_partitions,
        subgraph_counts,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;
    use crate::partition::{MultilevelPartitioner, Partitioner};

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join("goffish_store_tests")
            .join(format!("{name}_{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn create_open_load_round_trip() {
        let g = gen::road(16, 0.93, 0.02, 8);
        let parts = MultilevelPartitioner::default().partition(&g, 3);
        let root = tmp("round_trip");
        let (store, dg) = Store::create(&root, "rn", &g, &parts).unwrap();
        assert_eq!(store.meta().num_partitions, 3);

        let reopened = Store::open(&root).unwrap();
        assert_eq!(reopened.meta(), store.meta());
        let (dg2, stats) = reopened.load_all().unwrap();
        assert_eq!(dg2.num_subgraphs(), dg.num_subgraphs());
        assert!(stats.bytes > 0 && stats.files as usize == dg.num_subgraphs());
        // Vertex sets identical.
        let verts = |d: &DistributedGraph| -> Vec<Vec<u32>> {
            d.subgraphs().map(|s| s.vertices.clone()).collect()
        };
        assert_eq!(verts(&dg), verts(&dg2));
    }

    #[test]
    fn load_partition_is_data_local() {
        let g = gen::grid(10, 10);
        let parts = MultilevelPartitioner::default().partition(&g, 2);
        let root = tmp("data_local");
        let (store, _) = Store::create(&root, "grid", &g, &parts).unwrap();
        // Remove the other host's directory: partition 0 must still load.
        fs::remove_dir_all(root.join("host1")).unwrap();
        assert!(store.load_partition(0).is_ok());
        assert!(store.load_partition(1).is_err());
    }

    #[test]
    fn write_once_enforced() {
        let g = gen::chain(10);
        let parts = MultilevelPartitioner::default().partition(&g, 2);
        let root = tmp("write_once");
        Store::create(&root, "c", &g, &parts).unwrap();
        assert!(Store::create(&root, "c2", &g, &parts).is_err());
    }

    #[test]
    fn attributes_round_trip() {
        let g = gen::chain(12);
        let parts = MultilevelPartitioner::default().partition(&g, 2);
        let root = tmp("attrs");
        let (store, dg) = Store::create(&root, "c", &g, &parts).unwrap();
        let sg = dg.subgraphs().next().unwrap();
        let vals: Vec<f32> = (0..sg.num_vertices()).map(|i| i as f32 * 0.5).collect();
        store.write_attribute(sg.id, "rank", &vals).unwrap();
        let (back, st) = store.read_attribute(sg.id, "rank").unwrap();
        assert_eq!(back, vals);
        assert_eq!(st.files, 1);
        assert!(store.read_attribute(sg.id, "missing").is_err());
    }

    #[test]
    fn open_missing_store_fails() {
        assert!(Store::open(Path::new("/nonexistent/store")).is_err());
    }

    #[test]
    fn corrupted_slice_detected_at_load() {
        let g = gen::chain(20);
        let parts = MultilevelPartitioner::default().partition(&g, 2);
        let root = tmp("corrupt");
        let (store, _) = Store::create(&root, "c", &g, &parts).unwrap();
        // Flip a byte in one slice.
        let slice_path = root.join("host0").join("sg_0.topo.slice");
        let mut bytes = fs::read(&slice_path).unwrap();
        let mid = bytes.len() - 3;
        bytes[mid] ^= 0x55;
        fs::write(&slice_path, bytes).unwrap();
        assert!(store.load_partition(0).is_err());
    }

    #[test]
    fn partition_out_of_range() {
        let g = gen::chain(5);
        let parts = MultilevelPartitioner::default().partition(&g, 2);
        let root = tmp("oob");
        let (store, _) = Store::create(&root, "c", &g, &parts).unwrap();
        assert!(store.load_partition(5).is_err());
    }
}
