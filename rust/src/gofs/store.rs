//! GoFS on-disk store: partition + discover + write slices, then load.
//!
//! Layout (`<root>` is the store directory, one `host<p>` subdirectory
//! per partition — the simulated per-machine local filesystem):
//!
//! ```text
//! <root>/meta.txt
//! <root>/host0/sg_0.topo.slice
//! <root>/host0/sg_0.attr.<name>.slice
//! <root>/host1/…
//! ```
//!
//! The store is write-once-read-many (paper §4.1): `create` builds it
//! from a graph + partitioning (slice format v2 by default, v1 via
//! [`Store::create_with_format`]), `open` + the load paths serve Gopher.
//!
//! Loading is parallel at two levels, mirroring the paper's cluster:
//! [`Store::load_all`] runs one loader thread per partition (each
//! simulated host reads only its own directory, concurrently — the
//! "maximizes cumulative disk read bandwidth" co-design point), and
//! within a partition a worker pool decodes sub-graph slices in
//! parallel. [`LoadOptions`] selects sequential loading (for A/B
//! benchmarking) and **attribute projection**: the paper's "graph with
//! 10 attributes … only loads the slice it needs" scenario, where a job
//! declares the attributes it reads and the load path touches only
//! those slice files.

use std::collections::BTreeMap;
use std::fs;
use std::path::{Path, PathBuf};
use std::sync::Mutex;
use std::time::Instant;

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::graph::csr::Graph;
use crate::partition::Partitioning;
use crate::util::pool;

use super::slice::{self, SliceFormat};
use super::subgraph::{
    discover, DistributedGraph, PartitionAttributes, Subgraph, SubgraphId,
};

/// Store-wide metadata (the `meta.txt` contents).
#[derive(Clone, Debug, PartialEq)]
pub struct StoreMeta {
    pub name: String,
    pub num_vertices: u64,
    pub num_edges: u64,
    pub directed: bool,
    pub weighted: bool,
    pub num_partitions: u32,
    /// Sub-graph count per partition.
    pub subgraph_counts: Vec<u32>,
    /// Slice format the store was written with (v1 when the key is
    /// absent from `meta.txt` — stores written before the format knob).
    pub format: SliceFormat,
}

/// Byte/file accounting for one load (feeds `sim::disk`).
#[derive(Clone, Copy, Debug, Default)]
pub struct LoadStats {
    /// Slice files opened — summed across parallel load units.
    pub files: u64,
    /// Bytes read — summed across parallel load units.
    pub bytes: u64,
    /// Wall-clock seconds of the load. For the (default) parallel
    /// multi-partition load this is the **max** across partitions (each
    /// simulated host loads its own slices concurrently, so the slowest
    /// host gates the job), *not* the sum of per-partition times; a
    /// `LoadOptions { sequential: true, .. }` load reports the sum,
    /// which *is* its wall clock.
    pub seconds: f64,
}

/// Which attribute slices a load touches (the projection).
#[derive(Clone, Debug, Default, PartialEq)]
pub enum AttrProjection {
    /// Topology only — no attribute slice is opened.
    #[default]
    None,
    /// Every attribute slice present in the host directory.
    All,
    /// Exactly the named attributes; a missing slice is an error.
    Only(Vec<String>),
}

/// Knobs for [`Store::load_partition_with`] / [`Store::load_all_with`].
#[derive(Clone, Debug, Default)]
pub struct LoadOptions {
    /// Attribute projection (default: topology only).
    pub attributes: AttrProjection,
    /// Load strictly sequentially (one slice at a time, one partition at
    /// a time) — the pre-v2 behaviour, kept for A/B benchmarking and the
    /// parallel-equivalence tests.
    pub sequential: bool,
    /// Decode threads per partition (0 = auto: detected cores for a
    /// single-partition load, 1 when partitions already load in
    /// parallel).
    pub cores: usize,
}

/// Handle to an on-disk GoFS store.
pub struct Store {
    root: PathBuf,
    meta: StoreMeta,
}

/// What one slice-load job produced (crate-internal).
enum Loaded {
    Topo(u32, Subgraph),
    Attr(u32, String, Vec<f32>),
}

/// One planned slice read.
enum SlicePlan {
    Topo { index: u32, path: PathBuf },
    Attr { index: u32, name: String, path: PathBuf },
}

/// Result slot of one parallel slice-load job.
type LoadCell = Mutex<Option<Result<(Loaded, u64)>>>;

impl Store {
    /// Partition `g`, discover sub-graphs, and write the whole store in
    /// the default slice format (v2).
    pub fn create(
        root: &Path,
        name: &str,
        g: &Graph,
        parts: &Partitioning,
    ) -> Result<(Store, DistributedGraph)> {
        Self::create_with_format(root, name, g, parts, SliceFormat::default())
    }

    /// Partition `g`, discover sub-graphs, and write the whole store in
    /// an explicit slice format.
    pub fn create_with_format(
        root: &Path,
        name: &str,
        g: &Graph,
        parts: &Partitioning,
        format: SliceFormat,
    ) -> Result<(Store, DistributedGraph)> {
        ensure!(
            !root.exists() || fs::read_dir(root)?.next().is_none(),
            "store root {} exists and is not empty (GoFS is write-once)",
            root.display()
        );
        let dg = discover(g, parts)?;
        fs::create_dir_all(root)?;
        for (p, sgs) in dg.partitions.iter().enumerate() {
            let host_dir = root.join(format!("host{p}"));
            fs::create_dir_all(&host_dir)?;
            for sg in sgs {
                let bytes = slice::encode_topology(sg, format);
                fs::write(host_dir.join(format!("sg_{}.topo.slice", sg.id.index)), bytes)?;
            }
        }
        let meta = StoreMeta {
            name: name.to_string(),
            num_vertices: g.num_vertices() as u64,
            num_edges: g.num_edges() as u64,
            directed: g.directed(),
            weighted: g.has_weights(),
            num_partitions: parts.k() as u32,
            subgraph_counts: dg.partitions.iter().map(|p| p.len() as u32).collect(),
            format,
        };
        write_meta(&root.join("meta.txt"), &meta)?;
        Ok((Store { root: root.to_path_buf(), meta }, dg))
    }

    /// Open an existing store.
    pub fn open(root: &Path) -> Result<Store> {
        let meta = read_meta(&root.join("meta.txt"))
            .with_context(|| format!("open store at {}", root.display()))?;
        Ok(Store { root: root.to_path_buf(), meta })
    }

    pub fn meta(&self) -> &StoreMeta {
        &self.meta
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    fn host_dir(&self, p: u32) -> PathBuf {
        self.root.join(format!("host{p}"))
    }

    fn attr_path(&self, p: u32, index: u32, name: &str) -> PathBuf {
        self.host_dir(p).join(format!("sg_{index}.attr.{name}.slice"))
    }

    /// Load all sub-graphs of partition `p` (data-local read: only this
    /// host's directory is touched — the GoFS co-design point). Topology
    /// only, slices decoded in parallel.
    pub fn load_partition(&self, p: u32) -> Result<(Vec<Subgraph>, LoadStats)> {
        let (sgs, _, stats) = self.load_partition_with(p, &LoadOptions::default())?;
        Ok((sgs, stats))
    }

    /// Load partition `p` with explicit options: attribute projection
    /// and sequential/parallel decode. The returned
    /// [`PartitionAttributes`] is indexed by sub-graph index and holds
    /// exactly the projected columns.
    pub fn load_partition_with(
        &self,
        p: u32,
        opts: &LoadOptions,
    ) -> Result<(Vec<Subgraph>, PartitionAttributes, LoadStats)> {
        ensure!(p < self.meta.num_partitions, "partition {p} out of range");
        let t0 = Instant::now();
        let count = self.meta.subgraph_counts[p as usize] as usize;
        let host = self.host_dir(p);

        // Plan every slice file this load touches — the projection *is*
        // the plan: undeclared attribute slices are never opened.
        let mut plans: Vec<SlicePlan> = (0..count)
            .map(|i| SlicePlan::Topo {
                index: i as u32,
                path: host.join(format!("sg_{i}.topo.slice")),
            })
            .collect();
        match &opts.attributes {
            AttrProjection::None => {}
            AttrProjection::Only(names) => {
                for i in 0..count as u32 {
                    for name in names {
                        plans.push(SlicePlan::Attr {
                            index: i,
                            name: name.clone(),
                            path: self.attr_path(p, i, name),
                        });
                    }
                }
            }
            AttrProjection::All => {
                let mut found: Vec<(u32, String)> = Vec::new();
                for entry in fs::read_dir(&host)
                    .with_context(|| format!("list {}", host.display()))?
                {
                    let fname = entry?.file_name().to_string_lossy().into_owned();
                    if let Some((idx, name)) = parse_attr_filename(&fname) {
                        if (idx as usize) < count {
                            found.push((idx, name));
                        }
                    }
                }
                found.sort();
                plans.extend(found.into_iter().map(|(index, name)| {
                    let path = self.attr_path(p, index, &name);
                    SlicePlan::Attr { index, name, path }
                }));
            }
        }

        // Decode the planned slices on a worker pool (sub-graph slices
        // are independent files — the v2 point that each is validated
        // and decoded on its own).
        let cores = if opts.sequential {
            1
        } else if opts.cores == 0 {
            pool::num_cores()
        } else {
            opts.cores
        };
        let cells: Vec<LoadCell> = (0..plans.len()).map(|_| Mutex::new(None)).collect();
        pool::run_indexed(cores, plans.len(), |j| {
            let r = load_one(&plans[j], p);
            *cells[j].lock().unwrap() = Some(r);
        })?;

        let mut stats = LoadStats::default();
        let mut sgs: Vec<Option<Subgraph>> = (0..count).map(|_| None).collect();
        let mut attrs: PartitionAttributes = vec![BTreeMap::new(); count];
        for cell in cells {
            let result = cell
                .into_inner()
                .unwrap()
                .expect("pool runs every load job");
            let (loaded, bytes) = result?;
            stats.files += 1;
            stats.bytes += bytes;
            match loaded {
                Loaded::Topo(i, sg) => sgs[i as usize] = Some(sg),
                Loaded::Attr(i, name, vals) => {
                    attrs[i as usize].insert(name, vals);
                }
            }
        }
        let sgs: Vec<Subgraph> = sgs
            .into_iter()
            .enumerate()
            .map(|(i, s)| s.ok_or_else(|| anyhow!("sub-graph {i} never loaded")))
            .collect::<Result<_>>()?;
        stats.seconds = t0.elapsed().as_secs_f64();
        Ok((sgs, attrs, stats))
    }

    /// Load the entire distributed graph (all partitions, one loader
    /// thread per partition).
    pub fn load_all(&self) -> Result<(DistributedGraph, LoadStats)> {
        let (dg, _, stats) = self.load_all_with(&LoadOptions::default())?;
        Ok((dg, stats))
    }

    /// Load every partition with explicit options. Unless
    /// `opts.sequential`, partitions load on one thread each — the
    /// paper's per-host parallel ingest — and `LoadStats::seconds`
    /// reports the slowest partition (the parallel load's wall clock);
    /// a sequential load reports the sum (its wall clock). `files` and
    /// `bytes` are sums either way.
    pub fn load_all_with(
        &self,
        opts: &LoadOptions,
    ) -> Result<(DistributedGraph, Vec<PartitionAttributes>, LoadStats)> {
        let k = self.meta.num_partitions;
        let results: Vec<Result<(Vec<Subgraph>, PartitionAttributes, LoadStats)>> =
            if opts.sequential || k <= 1 {
                (0..k).map(|p| self.load_partition_with(p, opts)).collect()
            } else {
                // One loader thread per partition; within each, default
                // to single-threaded decode so the two levels don't
                // oversubscribe the machine.
                let per_part = LoadOptions {
                    cores: opts.cores.max(1),
                    ..opts.clone()
                };
                std::thread::scope(|scope| {
                    let handles: Vec<_> = (0..k)
                        .map(|p| {
                            let per_part = &per_part;
                            scope.spawn(move || self.load_partition_with(p, per_part))
                        })
                        .collect();
                    handles
                        .into_iter()
                        .map(|h| match h.join() {
                            Ok(r) => r,
                            Err(payload) => std::panic::resume_unwind(payload),
                        })
                        .collect()
                })
            };

        let parallel = !opts.sequential && k > 1;
        let mut partitions = Vec::with_capacity(k as usize);
        let mut attrs = Vec::with_capacity(k as usize);
        let mut total = LoadStats::default();
        for r in results {
            let (sgs, pa, st) = r?;
            partitions.push(sgs);
            attrs.push(pa);
            total.files += st.files;
            total.bytes += st.bytes;
            // Honest wall clock either way: hosts load concurrently on
            // the parallel path (slowest host gates), one after another
            // on the sequential path (times add up).
            total.seconds = if parallel {
                total.seconds.max(st.seconds)
            } else {
                total.seconds + st.seconds
            };
        }
        Ok((
            DistributedGraph {
                partitions,
                num_global_vertices: self.meta.num_vertices,
                directed: self.meta.directed,
            },
            attrs,
            total,
        ))
    }

    /// Write a named per-vertex attribute for one sub-graph (in the
    /// store's slice format).
    pub fn write_attribute(&self, id: SubgraphId, name: &str, values: &[f32]) -> Result<()> {
        let path = self.attr_path(id.partition, id.index, name);
        fs::write(&path, slice::encode_attribute(id, name, values, self.meta.format))
            .with_context(|| format!("write {}", path.display()))
    }

    /// Full checksum scrub of every slice file in the store: validates
    /// every section of every topology and attribute slice (v1's
    /// whole-payload checksum counts as one `payload` section),
    /// reporting corrupt sections by name. The on-demand form of
    /// background scrubbing, surfaced as `goffish store verify`.
    pub fn scrub(&self) -> Result<super::section::ScrubSummary> {
        let mut sum = super::section::ScrubSummary::default();
        for p in 0..self.meta.num_partitions {
            let host = self.host_dir(p);
            let mut names: Vec<String> = fs::read_dir(&host)
                .with_context(|| format!("list {}", host.display()))?
                .collect::<std::io::Result<Vec<_>>>()?
                .into_iter()
                .map(|e| e.file_name().to_string_lossy().into_owned())
                .filter(|n| n.ends_with(".slice"))
                .collect();
            names.sort();
            for name in names {
                let rel = format!("host{p}/{name}");
                // The filename says what the file must contain; the
                // scrub validates the kind byte against it.
                let want = if name.contains(".topo.") {
                    slice::SliceKind::Topology
                } else {
                    slice::SliceKind::Attribute
                };
                match fs::read(host.join(&name)) {
                    Ok(bytes) => sum.record(&rel, slice::scrub(&bytes, want)),
                    Err(e) => sum.record_unreadable(&rel, e),
                }
            }
        }
        Ok(sum)
    }

    /// Read a named attribute for one sub-graph.
    pub fn read_attribute(&self, id: SubgraphId, name: &str) -> Result<(Vec<f32>, LoadStats)> {
        let t0 = Instant::now();
        let path = self.attr_path(id.partition, id.index, name);
        let bytes = fs::read(&path).with_context(|| format!("read {}", path.display()))?;
        let (got_id, got_name, values) = slice::decode_attribute(&bytes)?;
        ensure!(got_id == id && got_name == name, "attribute slice mismatch");
        Ok((
            values,
            LoadStats { files: 1, bytes: bytes.len() as u64, seconds: t0.elapsed().as_secs_f64() },
        ))
    }
}

/// Read + decode + verify one planned slice.
fn load_one(plan: &SlicePlan, p: u32) -> Result<(Loaded, u64)> {
    match plan {
        SlicePlan::Topo { index, path } => {
            let bytes =
                fs::read(path).with_context(|| format!("read {}", path.display()))?;
            let sg = slice::decode_topology(&bytes)
                .with_context(|| format!("decode {}", path.display()))?;
            ensure!(
                sg.id == SubgraphId { partition: p, index: *index },
                "slice {} holds wrong sub-graph {}",
                path.display(),
                sg.id
            );
            Ok((Loaded::Topo(*index, sg), bytes.len() as u64))
        }
        SlicePlan::Attr { index, name, path } => {
            let bytes = fs::read(path)
                .with_context(|| format!("read attribute slice {}", path.display()))?;
            let (id, got_name, values) = slice::decode_attribute(&bytes)
                .with_context(|| format!("decode {}", path.display()))?;
            ensure!(
                id == SubgraphId { partition: p, index: *index } && got_name == *name,
                "attribute slice mismatch at {}",
                path.display()
            );
            Ok((Loaded::Attr(*index, name.clone(), values), bytes.len() as u64))
        }
    }
}

/// Parse `sg_<idx>.attr.<name>.slice` file names.
fn parse_attr_filename(fname: &str) -> Option<(u32, String)> {
    let rest = fname.strip_prefix("sg_")?.strip_suffix(".slice")?;
    let (idx, name) = rest.split_once(".attr.")?;
    Some((idx.parse().ok()?, name.to_string()))
}

fn write_meta(path: &Path, meta: &StoreMeta) -> Result<()> {
    let counts: Vec<String> =
        meta.subgraph_counts.iter().map(|c| c.to_string()).collect();
    let text = format!(
        "name={}\nvertices={}\nedges={}\ndirected={}\nweighted={}\npartitions={}\nsubgraphs={}\nformat={}\n",
        meta.name,
        meta.num_vertices,
        meta.num_edges,
        meta.directed,
        meta.weighted,
        meta.num_partitions,
        counts.join(","),
        meta.format
    );
    fs::write(path, text).with_context(|| format!("write {}", path.display()))
}

fn read_meta(path: &Path) -> Result<StoreMeta> {
    let text = fs::read_to_string(path)?;
    let mut name = None;
    let mut vertices = None;
    let mut edges = None;
    let mut directed = None;
    let mut weighted = None;
    let mut partitions = None;
    let mut subgraphs = None;
    // Stores written before the format knob carry no `format=` key and
    // are v1 by construction.
    let mut format = SliceFormat::V1;
    for line in text.lines() {
        let Some((k, v)) = line.split_once('=') else { continue };
        match k {
            "name" => name = Some(v.to_string()),
            "vertices" => vertices = Some(v.parse()?),
            "edges" => edges = Some(v.parse()?),
            "directed" => directed = Some(v == "true"),
            "weighted" => weighted = Some(v == "true"),
            "partitions" => partitions = Some(v.parse()?),
            "subgraphs" => {
                subgraphs = Some(
                    v.split(',')
                        .filter(|s| !s.is_empty())
                        .map(|s| s.parse::<u32>())
                        .collect::<Result<Vec<_>, _>>()?,
                )
            }
            "format" => {
                format = SliceFormat::parse(v)
                    .ok_or_else(|| anyhow!("meta.txt has unknown slice format {v:?}"))?
            }
            _ => {}
        }
    }
    let (Some(name), Some(num_vertices), Some(num_edges), Some(directed), Some(weighted), Some(num_partitions), Some(subgraph_counts)) =
        (name, vertices, edges, directed, weighted, partitions, subgraphs)
    else {
        bail!("meta.txt missing required keys");
    };
    ensure!(
        subgraph_counts.len() == num_partitions as usize,
        "meta.txt subgraph counts do not match partition count"
    );
    Ok(StoreMeta {
        name,
        num_vertices,
        num_edges,
        directed,
        weighted,
        num_partitions,
        subgraph_counts,
        format,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;
    use crate::partition::{MultilevelPartitioner, Partitioner};

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join("goffish_store_tests")
            .join(format!("{name}_{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn create_open_load_round_trip() {
        for fmt in [SliceFormat::V1, SliceFormat::V2] {
            let g = gen::road(16, 0.93, 0.02, 8);
            let parts = MultilevelPartitioner::default().partition(&g, 3);
            let root = tmp(&format!("round_trip_{fmt}"));
            let (store, dg) = Store::create_with_format(&root, "rn", &g, &parts, fmt).unwrap();
            assert_eq!(store.meta().num_partitions, 3);
            assert_eq!(store.meta().format, fmt);

            let reopened = Store::open(&root).unwrap();
            assert_eq!(reopened.meta(), store.meta());
            let (dg2, stats) = reopened.load_all().unwrap();
            assert_eq!(dg2.num_subgraphs(), dg.num_subgraphs());
            assert!(stats.bytes > 0 && stats.files as usize == dg.num_subgraphs());
            // Vertex sets identical.
            let verts = |d: &DistributedGraph| -> Vec<Vec<u32>> {
                d.subgraphs().map(|s| s.vertices.clone()).collect()
            };
            assert_eq!(verts(&dg), verts(&dg2));
        }
    }

    #[test]
    fn default_format_is_v2() {
        let g = gen::chain(8);
        let parts = MultilevelPartitioner::default().partition(&g, 2);
        let root = tmp("default_v2");
        let (store, _) = Store::create(&root, "c", &g, &parts).unwrap();
        assert_eq!(store.meta().format, SliceFormat::V2);
        // The version byte on disk says so too.
        let bytes = fs::read(root.join("host0").join("sg_0.topo.slice")).unwrap();
        assert_eq!(bytes[4], 2);
    }

    #[test]
    fn meta_without_format_key_reads_as_v1() {
        let g = gen::chain(8);
        let parts = MultilevelPartitioner::default().partition(&g, 2);
        let root = tmp("legacy_meta");
        Store::create_with_format(&root, "c", &g, &parts, SliceFormat::V1).unwrap();
        // Strip the format line, as a pre-knob store would look.
        let meta_path = root.join("meta.txt");
        let text: String = fs::read_to_string(&meta_path)
            .unwrap()
            .lines()
            .filter(|l| !l.starts_with("format="))
            .map(|l| format!("{l}\n"))
            .collect();
        fs::write(&meta_path, text).unwrap();
        let store = Store::open(&root).unwrap();
        assert_eq!(store.meta().format, SliceFormat::V1);
        assert!(store.load_all().is_ok());
    }

    #[test]
    fn load_partition_is_data_local() {
        let g = gen::grid(10, 10);
        let parts = MultilevelPartitioner::default().partition(&g, 2);
        let root = tmp("data_local");
        let (store, _) = Store::create(&root, "grid", &g, &parts).unwrap();
        // Remove the other host's directory: partition 0 must still load.
        fs::remove_dir_all(root.join("host1")).unwrap();
        assert!(store.load_partition(0).is_ok());
        assert!(store.load_partition(1).is_err());
    }

    #[test]
    fn write_once_enforced() {
        let g = gen::chain(10);
        let parts = MultilevelPartitioner::default().partition(&g, 2);
        let root = tmp("write_once");
        Store::create(&root, "c", &g, &parts).unwrap();
        assert!(Store::create(&root, "c2", &g, &parts).is_err());
    }

    #[test]
    fn attributes_round_trip() {
        for fmt in [SliceFormat::V1, SliceFormat::V2] {
            let g = gen::chain(12);
            let parts = MultilevelPartitioner::default().partition(&g, 2);
            let root = tmp(&format!("attrs_{fmt}"));
            let (store, dg) = Store::create_with_format(&root, "c", &g, &parts, fmt).unwrap();
            let sg = dg.subgraphs().next().unwrap();
            let vals: Vec<f32> = (0..sg.num_vertices()).map(|i| i as f32 * 0.5).collect();
            store.write_attribute(sg.id, "rank", &vals).unwrap();
            let (back, st) = store.read_attribute(sg.id, "rank").unwrap();
            assert_eq!(back, vals);
            assert_eq!(st.files, 1);
            assert!(store.read_attribute(sg.id, "missing").is_err());
        }
    }

    #[test]
    fn projection_loads_declared_attributes_only() {
        let g = gen::road(14, 0.9, 0.02, 9);
        let parts = MultilevelPartitioner::default().partition(&g, 2);
        let root = tmp("projection");
        let (store, dg) = Store::create(&root, "g", &g, &parts).unwrap();
        for sg in dg.subgraphs() {
            for a in 0..4 {
                let vals: Vec<f32> =
                    sg.vertices.iter().map(|&v| v as f32 + a as f32).collect();
                store.write_attribute(sg.id, &format!("attr{a}"), &vals).unwrap();
            }
        }

        let full = LoadOptions { attributes: AttrProjection::All, ..Default::default() };
        let only = LoadOptions {
            attributes: AttrProjection::Only(vec!["attr1".into()]),
            ..Default::default()
        };
        let none = LoadOptions::default();
        let (_, attrs_full, st_full) = store.load_all_with(&full).map(flatten3).unwrap();
        let (_, attrs_only, st_only) = store.load_all_with(&only).map(flatten3).unwrap();
        let (_, attrs_none, st_none) = store.load_all_with(&none).map(flatten3).unwrap();

        // The projection is visible in bytes touched, strictly ordered.
        assert!(st_none.bytes < st_only.bytes, "{} vs {}", st_none.bytes, st_only.bytes);
        assert!(st_only.bytes < st_full.bytes, "{} vs {}", st_only.bytes, st_full.bytes);
        // And in which columns came back.
        for (i, sg) in dg.subgraphs().enumerate() {
            assert_eq!(attrs_full[i].len(), 4);
            assert_eq!(attrs_only[i].len(), 1);
            assert!(attrs_none[i].is_empty());
            let col = &attrs_only[i]["attr1"];
            let want: Vec<f32> = sg.vertices.iter().map(|&v| v as f32 + 1.0).collect();
            assert_eq!(col, &want);
        }
        // Declaring a missing attribute is an error, not a silent skip.
        let bad = LoadOptions {
            attributes: AttrProjection::Only(vec!["nope".into()]),
            ..Default::default()
        };
        assert!(store.load_partition_with(0, &bad).is_err());
    }

    /// Flatten per-partition attribute maps into sub-graph order for
    /// easy comparison with `dg.subgraphs()`.
    fn flatten3(
        x: (DistributedGraph, Vec<PartitionAttributes>, LoadStats),
    ) -> (DistributedGraph, PartitionAttributes, LoadStats) {
        let (dg, attrs, st) = x;
        (dg, attrs.into_iter().flatten().collect(), st)
    }

    #[test]
    fn parallel_and_sequential_loads_agree() {
        let g = gen::road(18, 0.92, 0.02, 21);
        let parts = MultilevelPartitioner::default().partition(&g, 4);
        let root = tmp("par_eq_seq");
        let (store, _) = Store::create(&root, "g", &g, &parts).unwrap();
        let seq = LoadOptions { sequential: true, ..Default::default() };
        let (dg_s, _, st_s) = store.load_all_with(&seq).unwrap();
        let (dg_p, _, st_p) = store.load_all_with(&LoadOptions::default()).unwrap();
        assert_eq!(st_s.files, st_p.files);
        assert_eq!(st_s.bytes, st_p.bytes);
        let shape = |d: &DistributedGraph| -> Vec<(Vec<u32>, usize, usize, usize)> {
            d.subgraphs()
                .map(|s| {
                    (s.vertices.clone(), s.local.num_edges(), s.remote_out.len(), s.remote_in.len())
                })
                .collect()
        };
        assert_eq!(shape(&dg_s), shape(&dg_p));
    }

    #[test]
    fn open_missing_store_fails() {
        assert!(Store::open(Path::new("/nonexistent/store")).is_err());
    }

    #[test]
    fn corrupted_slice_detected_at_load() {
        for fmt in [SliceFormat::V1, SliceFormat::V2] {
            let g = gen::chain(20);
            let parts = MultilevelPartitioner::default().partition(&g, 2);
            let root = tmp(&format!("corrupt_{fmt}"));
            let (store, _) = Store::create_with_format(&root, "c", &g, &parts, fmt).unwrap();
            // Flip a byte in one slice.
            let slice_path = root.join("host0").join("sg_0.topo.slice");
            let mut bytes = fs::read(&slice_path).unwrap();
            let mid = bytes.len() - 3;
            bytes[mid] ^= 0x55;
            fs::write(&slice_path, bytes).unwrap();
            assert!(store.load_partition(0).is_err(), "{fmt}");
        }
    }

    #[test]
    fn scrub_reports_clean_then_corrupt_by_file_and_section() {
        let g = gen::chain(16);
        let parts = MultilevelPartitioner::default().partition(&g, 2);
        let root = tmp("scrub");
        let (store, dg) = Store::create(&root, "c", &g, &parts).unwrap();
        let sg = dg.subgraphs().next().unwrap();
        store
            .write_attribute(sg.id, "rank", &vec![1.0; sg.num_vertices()])
            .unwrap();

        let sum = store.scrub().unwrap();
        assert!(sum.is_clean(), "{:?}", sum.corrupt);
        assert!(sum.files >= 3, "topology slices + attribute slice");
        assert!(sum.sections > sum.files, "v2 slices are multi-section");

        // Flip one byte in a topology slice: the report names the file.
        let victim = root.join("host0").join("sg_0.topo.slice");
        let mut bytes = fs::read(&victim).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0x55;
        fs::write(&victim, bytes).unwrap();
        let sum = store.scrub().unwrap();
        assert_eq!(sum.corrupt.len(), 1, "{:?}", sum.corrupt);
        assert!(sum.corrupt[0].contains("host0/sg_0.topo.slice"));
        assert!(sum.corrupt[0].contains("section `"));
    }

    #[test]
    fn partition_out_of_range() {
        let g = gen::chain(5);
        let parts = MultilevelPartitioner::default().partition(&g, 2);
        let root = tmp("oob");
        let (store, _) = Store::create(&root, "c", &g, &parts).unwrap();
        assert!(store.load_partition(5).is_err());
    }

    #[test]
    fn attr_filename_parsing() {
        assert_eq!(parse_attr_filename("sg_3.attr.rank.slice"), Some((3, "rank".into())));
        assert_eq!(
            parse_attr_filename("sg_0.attr.with.dots.slice"),
            Some((0, "with.dots".into()))
        );
        assert_eq!(parse_attr_filename("sg_0.topo.slice"), None);
        assert_eq!(parse_attr_filename("meta.txt"), None);
        assert_eq!(parse_attr_filename("sg_x.attr.rank.slice"), None);
    }
}
