//! GoFS on-disk store: partition + discover + write slices, then load.
//!
//! Layout (`<root>` is the store directory, one `host<p>` subdirectory
//! per partition — the simulated per-machine local filesystem):
//!
//! ```text
//! <root>/meta.txt
//! <root>/host0/sg_0.topo.slice          (v1/v2: one file per slice)
//! <root>/host0/sg_0.attr.<name>.slice
//! <root>/host1/…
//!
//! <root>/meta.txt                        (v3: one packed file per host)
//! <root>/host0/partition.gfsp
//! <root>/host1/partition.gfsp
//! ```
//!
//! The store is write-once-read-many (paper §4.1): `create` builds it
//! from a graph + partitioning (slice format v2 by default; v1 or the
//! v3 packed layout via [`Store::create_with_format`]), `open` + the
//! load paths serve Gopher. A v3 store packs every sub-graph's
//! sections — topology *and* attribute columns — into one
//! `partition.gfsp` per host behind a length-addressed directory
//! ([`super::packed`]), so a projected load physically `seek`s past
//! every section it does not want instead of opening and discarding
//! files.
//!
//! Packed stores additionally relax write-once into
//! **write-once-per-generation**: [`Store::append`] commits a batch of
//! new vertices, new edges, and attribute updates as generation `G+1`
//! by writing fresh `partition.g<G+1>.gfsp` files for the touched
//! partitions and atomically renaming a new `meta.txt` over the old
//! one. Earlier generation files are never rewritten, so a handle
//! opened before the append (pinned at its open-time generation) keeps
//! reading an unchanged snapshot while a fresh [`Store::open`] sees
//! the head. Each generation records which [`SubgraphId`]s it touched
//! in a `gen_<G>.txt` manifest; [`Store::dirty_since`] unions them so
//! incremental re-runs can scope recompute to changed sub-graphs.
//!
//! Loading is parallel at two levels, mirroring the paper's cluster:
//! [`Store::load_all`] runs one loader thread per partition (each
//! simulated host reads only its own directory, concurrently — the
//! "maximizes cumulative disk read bandwidth" co-design point), and
//! within a partition a worker pool decodes sub-graph slices in
//! parallel. [`LoadOptions`] selects sequential loading (for A/B
//! benchmarking) and **attribute projection**: the paper's "graph with
//! 10 attributes … only loads the slice it needs" scenario, where a job
//! declares the attributes it reads and the load path touches only
//! those slice files.

use std::collections::{BTreeMap, BTreeSet};
use std::fs;
use std::io::{Read, Seek, SeekFrom};
use std::path::{Path, PathBuf};
use std::sync::Mutex;
use std::time::Instant;

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::graph::csr::Graph;
use crate::partition::{HashPartitioner, Partitioning};
use crate::util::fsio;
use crate::util::mmap::Mapping;
use crate::util::pool;

use super::packed;
use super::section::checksum;
use super::slice::{self, SliceFormat};
use super::subgraph::{
    discover, DistributedGraph, PartitionAttributes, RemoteRef, Subgraph, SubgraphId,
};

/// Store-wide metadata (the `meta.txt` contents).
#[derive(Clone, Debug, PartialEq)]
pub struct StoreMeta {
    pub name: String,
    pub num_vertices: u64,
    pub num_edges: u64,
    pub directed: bool,
    pub weighted: bool,
    pub num_partitions: u32,
    /// Sub-graph count per partition.
    pub subgraph_counts: Vec<u32>,
    /// Slice format the store was written with (v1 when the key is
    /// absent from `meta.txt` — stores written before the format knob).
    pub format: SliceFormat,
    /// Mutation generation (0 when the key is absent — stores written
    /// before stores could mutate, and every freshly created store).
    /// Each successful [`Store::append`] bumps it by one; an open
    /// handle is pinned to the generation it read here.
    pub generation: u64,
}

/// Byte/file accounting for one load (feeds `sim::disk`).
#[derive(Clone, Copy, Debug, Default)]
pub struct LoadStats {
    /// Files opened — summed across parallel load units. Per-file
    /// formats open one file per slice; a v3 packed partition counts
    /// as **one** file however many sub-graphs it holds (the
    /// seeks-vs-bytes trade the packed layout exists for).
    pub files: u64,
    /// Bytes read — summed across parallel load units. For the
    /// per-file formats (v1/v2) this counts whole slice files; for a
    /// packed (v3) store it counts exactly the section bodies
    /// streamed — the sum of the directory-listed lengths of the
    /// sections actually read. The fixed prelude + directory (a few
    /// hundred metadata bytes per partition, read once before any
    /// seek) is accounted as per-file/seek overhead in
    /// [`crate::sim::DiskModel::packed_read_seconds`], not payload.
    ///
    /// The **mmap path counts identically**: bytes is the sum of the
    /// directory-listed lengths of the sections the projection decodes
    /// — *not* resident pages, not the mapped file length. Mapping the
    /// whole file is free until a page is touched, and the decode only
    /// touches the pages of wanted sections, so the directory-listed
    /// sum stays the honest measure of data consumed — and it keeps
    /// mmap-vs-read byte accounting comparable (pinned equal by
    /// `mmap_and_read_loads_report_equal_stats`).
    pub bytes: u64,
    /// Wall-clock seconds of the load. For the (default) parallel
    /// multi-partition load this is the **max** across partitions (each
    /// simulated host loads its own slices concurrently, so the slowest
    /// host gates the job), *not* the sum of per-partition times; a
    /// `LoadOptions { sequential: true, .. }` load reports the sum,
    /// which *is* its wall clock.
    pub seconds: f64,
}

/// Which attribute slices a load touches (the projection).
#[derive(Clone, Debug, Default, PartialEq)]
pub enum AttrProjection {
    /// Topology only — no attribute slice is opened.
    #[default]
    None,
    /// Every attribute slice present in the host directory.
    All,
    /// Exactly the named attributes; a missing slice is an error.
    Only(Vec<String>),
}

/// Knobs for [`Store::load_partition_with`] / [`Store::load_all_with`].
#[derive(Clone, Debug)]
pub struct LoadOptions {
    /// Attribute projection (default: topology only).
    pub attributes: AttrProjection,
    /// Load strictly sequentially (one slice at a time, one partition at
    /// a time) — the pre-v2 behaviour, kept for A/B benchmarking and the
    /// parallel-equivalence tests.
    pub sequential: bool,
    /// Decode threads per partition (0 = auto: detected cores for a
    /// single-partition load, 1 when partitions already load in
    /// parallel).
    pub cores: usize,
    /// Map `partition.gfsp` with [`crate::util::mmap::Mapping`] and
    /// decode sections straight out of the mapping (default: true).
    /// Only the packed (v3) format has a mapped path; per-file formats
    /// ignore the flag. `false` forces the seek+read path — kept as an
    /// A/B knob and for the byte-accounting regression tests.
    pub mmap: bool,
}

impl Default for LoadOptions {
    fn default() -> Self {
        LoadOptions {
            attributes: AttrProjection::default(),
            sequential: false,
            cores: 0,
            mmap: true,
        }
    }
}

impl LoadOptions {
    /// Resolve the decode-thread count for one partition's load — the
    /// single definition both the per-file and packed load paths use.
    fn effective_cores(&self) -> usize {
        if self.sequential {
            1
        } else if self.cores == 0 {
            pool::num_cores()
        } else {
            self.cores
        }
    }
}

/// One batch of mutations for [`Store::append`]. A batch is committed
/// atomically as a single new generation.
#[derive(Clone, Debug, Default)]
pub struct AppendBatch {
    /// Number of new vertices. Global ids are assigned densely from the
    /// store's current vertex count; each new vertex is hash-placed
    /// (via [`HashPartitioner::bucket`]) and becomes its own singleton
    /// sub-graph on its partition.
    pub new_vertices: u64,
    /// New edges over global ids — existing vertices or ones appended
    /// by this very batch. Weights are required on a weighted store and
    /// rejected on an unweighted one. An edge whose endpoints live in
    /// two *different* sub-graphs of the *same* partition is rejected:
    /// it would merge them, and append never restructures existing
    /// sub-graphs (rebuild the store to re-discover).
    pub edges: Vec<(u64, u64, Option<f32>)>,
    /// Attribute columns to write or replace, exactly as
    /// [`Store::write_attributes`] takes them — but versioned: the new
    /// column lands in the new generation's file, so pinned handles
    /// keep reading the old column.
    pub attributes: Vec<(SubgraphId, String, Vec<f32>)>,
}

impl AppendBatch {
    fn is_empty(&self) -> bool {
        self.new_vertices == 0 && self.edges.is_empty() && self.attributes.is_empty()
    }
}

/// Routed edge mutations for one sub-graph (append-internal). Edges
/// are kept as global-id triples and resolved to local indices against
/// the decoded sub-graph at rewrite time.
#[derive(Default)]
struct SubgraphDelta {
    /// Both endpoints in this sub-graph.
    local: Vec<(u64, u64, f32)>,
    /// Source here, target on another partition.
    remote_out: Vec<(u64, u64, f32)>,
    /// Target here, source on another partition.
    remote_in: Vec<(u64, u64, f32)>,
}

/// Handle to an on-disk GoFS store.
pub struct Store {
    root: PathBuf,
    meta: StoreMeta,
}

/// What one slice-load job produced (crate-internal).
enum Loaded {
    Topo(u32, Subgraph),
    Attr(u32, String, Vec<f32>),
}

/// One planned slice read.
enum SlicePlan {
    Topo { index: u32, path: PathBuf },
    Attr { index: u32, name: String, path: PathBuf },
}

/// Result slot of one parallel slice-load job.
type LoadCell = Mutex<Option<Result<(Loaded, u64)>>>;

impl Store {
    /// Partition `g`, discover sub-graphs, and write the whole store in
    /// the default slice format (v2).
    pub fn create(
        root: &Path,
        name: &str,
        g: &Graph,
        parts: &Partitioning,
    ) -> Result<(Store, DistributedGraph)> {
        Self::create_with_format(root, name, g, parts, SliceFormat::default())
    }

    /// Partition `g`, discover sub-graphs, and write the whole store in
    /// an explicit slice format.
    pub fn create_with_format(
        root: &Path,
        name: &str,
        g: &Graph,
        parts: &Partitioning,
        format: SliceFormat,
    ) -> Result<(Store, DistributedGraph)> {
        ensure!(
            !root.exists() || fs::read_dir(root)?.next().is_none(),
            "store root {} exists and is not empty (GoFS is write-once)",
            root.display()
        );
        let dg = discover(g, parts)?;
        fs::create_dir_all(root)?;
        for (p, sgs) in dg.partitions.iter().enumerate() {
            write_partition_files(&root.join(format!("host{p}")), sgs, format)?;
        }
        let meta = StoreMeta {
            name: name.to_string(),
            num_vertices: g.num_vertices() as u64,
            num_edges: g.num_edges() as u64,
            directed: g.directed(),
            weighted: g.has_weights(),
            num_partitions: parts.k() as u32,
            subgraph_counts: dg.partitions.iter().map(|p| p.len() as u32).collect(),
            format,
            generation: 0,
        };
        write_meta(&root.join("meta.txt"), &meta)?;
        Ok((Store { root: root.to_path_buf(), meta }, dg))
    }

    /// Open an existing store.
    pub fn open(root: &Path) -> Result<Store> {
        let meta = read_meta(&root.join("meta.txt"))
            .with_context(|| format!("open store at {}", root.display()))?;
        Ok(Store { root: root.to_path_buf(), meta })
    }

    pub fn meta(&self) -> &StoreMeta {
        &self.meta
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    fn host_dir(&self, p: u32) -> PathBuf {
        self.root.join(format!("host{p}"))
    }

    fn attr_path(&self, p: u32, index: u32, name: &str) -> PathBuf {
        self.host_dir(p).join(format!("sg_{index}.attr.{name}.slice"))
    }

    /// Packed partition file this handle reads for partition `p`: the
    /// newest `partition.g<G>.gfsp` at or below the handle's pinned
    /// generation, falling back to the generation-0
    /// `partition.gfsp`. An append only ever creates files *above* the
    /// pinned generation and never rewrites one at or below it, so the
    /// path this resolves — and the bytes behind it — cannot change
    /// underneath a running job.
    fn packed_path(&self, p: u32) -> PathBuf {
        let host = self.host_dir(p);
        for g in (1..=self.meta.generation).rev() {
            let path = host.join(generation_file(g));
            if path.exists() {
                return path;
            }
        }
        host.join(packed::PARTITION_FILE)
    }

    /// Load all sub-graphs of partition `p` (data-local read: only this
    /// host's directory is touched — the GoFS co-design point). Topology
    /// only, slices decoded in parallel.
    pub fn load_partition(&self, p: u32) -> Result<(Vec<Subgraph>, LoadStats)> {
        let (sgs, _, stats) = self.load_partition_with(p, &LoadOptions::default())?;
        Ok((sgs, stats))
    }

    /// Load partition `p` with explicit options: attribute projection
    /// and sequential/parallel decode. The returned
    /// [`PartitionAttributes`] is indexed by sub-graph index and holds
    /// exactly the projected columns.
    pub fn load_partition_with(
        &self,
        p: u32,
        opts: &LoadOptions,
    ) -> Result<(Vec<Subgraph>, PartitionAttributes, LoadStats)> {
        ensure!(p < self.meta.num_partitions, "partition {p} out of range");
        if self.meta.format == SliceFormat::V3Packed {
            return self.load_partition_packed(p, opts);
        }
        let t0 = Instant::now();
        let count = self.meta.subgraph_counts[p as usize] as usize;
        let host = self.host_dir(p);

        // Plan every slice file this load touches — the projection *is*
        // the plan: undeclared attribute slices are never opened.
        let mut plans: Vec<SlicePlan> = (0..count)
            .map(|i| SlicePlan::Topo {
                index: i as u32,
                path: host.join(format!("sg_{i}.topo.slice")),
            })
            .collect();
        match &opts.attributes {
            AttrProjection::None => {}
            AttrProjection::Only(names) => {
                for i in 0..count as u32 {
                    for name in names {
                        plans.push(SlicePlan::Attr {
                            index: i,
                            name: name.clone(),
                            path: self.attr_path(p, i, name),
                        });
                    }
                }
            }
            AttrProjection::All => {
                let mut found: Vec<(u32, String)> = Vec::new();
                for entry in fs::read_dir(&host)
                    .with_context(|| format!("list {}", host.display()))?
                {
                    let fname = entry?.file_name().to_string_lossy().into_owned();
                    if let Some((idx, name)) = parse_attr_filename(&fname) {
                        if (idx as usize) < count {
                            found.push((idx, name));
                        }
                    }
                }
                found.sort();
                plans.extend(found.into_iter().map(|(index, name)| {
                    let path = self.attr_path(p, index, &name);
                    SlicePlan::Attr { index, name, path }
                }));
            }
        }

        // Decode the planned slices on a worker pool (sub-graph slices
        // are independent files — the v2 point that each is validated
        // and decoded on its own).
        let cores = opts.effective_cores();
        let cells: Vec<LoadCell> = (0..plans.len()).map(|_| Mutex::new(None)).collect();
        pool::run_indexed(cores, plans.len(), |j| {
            let r = load_one(&plans[j], p);
            *cells[j].lock().unwrap() = Some(r);
        })?;

        let mut stats = LoadStats::default();
        let mut sgs: Vec<Option<Subgraph>> = (0..count).map(|_| None).collect();
        let mut attrs: PartitionAttributes = vec![BTreeMap::new(); count];
        for cell in cells {
            let result = cell
                .into_inner()
                .unwrap()
                .expect("pool runs every load job");
            let (loaded, bytes) = result?;
            stats.files += 1;
            stats.bytes += bytes;
            match loaded {
                Loaded::Topo(i, sg) => sgs[i as usize] = Some(sg),
                Loaded::Attr(i, name, vals) => {
                    attrs[i as usize].insert(name, vals);
                }
            }
        }
        let sgs: Vec<Subgraph> = sgs
            .into_iter()
            .enumerate()
            .map(|(i, s)| s.ok_or_else(|| anyhow!("sub-graph {i} never loaded")))
            .collect::<Result<_>>()?;
        stats.seconds = t0.elapsed().as_secs_f64();
        Ok((sgs, attrs, stats))
    }

    /// Packed (v3) partition load. Default (`opts.mmap`): map the file
    /// once and decode wanted sections *borrowing straight from the
    /// mapping* — no seeks, no copies before materialization, and only
    /// the pages of wanted sections plus the directory ever fault in.
    /// With `mmap: false`: read the directory, then `seek` past
    /// everything the projection does not want, coalescing each
    /// sub-graph's wanted sections into contiguous runs (topology
    /// sections are adjacent by construction) read in one `read_exact`
    /// each. Both paths share one section decoder and report identical
    /// `LoadStats`: `bytes` counts exactly the directory-listed
    /// lengths of the sections decoded — a projected load provably
    /// touches fewer bytes than any per-file format can.
    fn load_partition_packed(
        &self,
        p: u32,
        opts: &LoadOptions,
    ) -> Result<(Vec<Subgraph>, PartitionAttributes, LoadStats)> {
        let t0 = Instant::now();
        let count = self.meta.subgraph_counts[p as usize] as usize;
        let path = self.packed_path(p);
        let map = if opts.mmap {
            Some(Mapping::map(&path).with_context(|| format!("map {}", path.display()))?)
        } else {
            None
        };
        let dir = match &map {
            Some(m) => {
                packed::parse(m).with_context(|| format!("decode {}", path.display()))?
            }
            None => {
                let mut f = fs::File::open(&path)
                    .with_context(|| format!("read {}", path.display()))?;
                packed::read_directory(&mut f)
                    .with_context(|| format!("decode {}", path.display()))?
            }
        };

        // The projection *is* the plan: unwanted `values` sections are
        // never read, checksummed, or decoded — just seeked past.
        let mut plans: Vec<Vec<packed::Entry>> = vec![Vec::new(); count];
        for e in &dir.entries {
            ensure!(
                (e.subgraph as usize) < count,
                "{} directory names sub-graph {} of {count}",
                path.display(),
                e.subgraph
            );
            let wanted = if e.name.is_empty() {
                true // topology sections always load
            } else {
                match &opts.attributes {
                    AttrProjection::None => false,
                    AttrProjection::All => true,
                    AttrProjection::Only(names) => names.iter().any(|n| n == &e.name),
                }
            };
            if wanted {
                plans[e.subgraph as usize].push(e.clone());
            }
        }
        // A declared-but-missing attribute is an error, not a silent
        // skip (parity with the per-file formats, where the open fails).
        if let AttrProjection::Only(names) = &opts.attributes {
            for name in names {
                for (i, plan) in plans.iter().enumerate() {
                    ensure!(
                        plan.iter().any(|e| e.name == *name),
                        "store has no attribute `{name}` for sub-graph {i} in {}",
                        path.display()
                    );
                }
            }
        }

        let cores = opts.effective_cores();
        type PackedCell = Mutex<Option<Result<(Subgraph, BTreeMap<String, Vec<f32>>, u64)>>>;
        let cells: Vec<PackedCell> = (0..count).map(|_| Mutex::new(None)).collect();
        pool::run_indexed(cores, count, |i| {
            let r = match &map {
                Some(m) => load_packed_subgraph_mapped(
                    &path,
                    m,
                    p,
                    i as u32,
                    &plans[i],
                    self.meta.num_vertices,
                ),
                None => load_packed_subgraph(
                    &path,
                    p,
                    i as u32,
                    &plans[i],
                    self.meta.num_vertices,
                ),
            };
            *cells[i].lock().unwrap() = Some(r);
        })?;

        // One physical file per partition, however many sub-graphs.
        let mut stats = LoadStats { files: 1, ..Default::default() };
        let mut sgs = Vec::with_capacity(count);
        let mut attrs: PartitionAttributes = Vec::with_capacity(count);
        for (i, cell) in cells.into_iter().enumerate() {
            let (sg, cols, bytes) = cell
                .into_inner()
                .unwrap()
                .expect("pool runs every load job")
                .with_context(|| format!("load sub-graph {i} of {}", path.display()))?;
            stats.bytes += bytes;
            sgs.push(sg);
            attrs.push(cols);
        }
        stats.seconds = t0.elapsed().as_secs_f64();
        Ok((sgs, attrs, stats))
    }

    /// Load the entire distributed graph (all partitions, one loader
    /// thread per partition).
    pub fn load_all(&self) -> Result<(DistributedGraph, LoadStats)> {
        let (dg, _, stats) = self.load_all_with(&LoadOptions::default())?;
        Ok((dg, stats))
    }

    /// Load every partition with explicit options. Unless
    /// `opts.sequential`, partitions load on one thread each — the
    /// paper's per-host parallel ingest — and `LoadStats::seconds`
    /// reports the slowest partition (the parallel load's wall clock);
    /// a sequential load reports the sum (its wall clock). `files` and
    /// `bytes` are sums either way.
    pub fn load_all_with(
        &self,
        opts: &LoadOptions,
    ) -> Result<(DistributedGraph, Vec<PartitionAttributes>, LoadStats)> {
        let k = self.meta.num_partitions;
        let results: Vec<Result<(Vec<Subgraph>, PartitionAttributes, LoadStats)>> =
            if opts.sequential || k <= 1 {
                (0..k).map(|p| self.load_partition_with(p, opts)).collect()
            } else {
                // One loader thread per partition; within each, default
                // to single-threaded decode so the two levels don't
                // oversubscribe the machine.
                let per_part = LoadOptions {
                    cores: opts.cores.max(1),
                    ..opts.clone()
                };
                std::thread::scope(|scope| {
                    let handles: Vec<_> = (0..k)
                        .map(|p| {
                            let per_part = &per_part;
                            scope.spawn(move || self.load_partition_with(p, per_part))
                        })
                        .collect();
                    handles
                        .into_iter()
                        .map(|h| match h.join() {
                            Ok(r) => r,
                            Err(payload) => std::panic::resume_unwind(payload),
                        })
                        .collect()
                })
            };

        let parallel = !opts.sequential && k > 1;
        let mut partitions = Vec::with_capacity(k as usize);
        let mut attrs = Vec::with_capacity(k as usize);
        let mut total = LoadStats::default();
        for r in results {
            let (sgs, pa, st) = r?;
            partitions.push(sgs);
            attrs.push(pa);
            total.files += st.files;
            total.bytes += st.bytes;
            // Honest wall clock either way: hosts load concurrently on
            // the parallel path (slowest host gates), one after another
            // on the sequential path (times add up).
            total.seconds = if parallel {
                total.seconds.max(st.seconds)
            } else {
                total.seconds + st.seconds
            };
        }
        Ok((
            DistributedGraph {
                partitions,
                num_global_vertices: self.meta.num_vertices,
                directed: self.meta.directed,
            },
            attrs,
            total,
        ))
    }

    /// Write a named per-vertex attribute for one sub-graph (in the
    /// store's format). Equivalent to a one-element
    /// [`Store::write_attributes`] batch — prefer the batch when
    /// writing many columns to a packed store (one partition-file
    /// rewrite instead of one per column).
    pub fn write_attribute(&self, id: SubgraphId, name: &str, values: &[f32]) -> Result<()> {
        self.write_attributes(&[(id, name.to_string(), values.to_vec())])
    }

    /// Write a batch of named per-vertex attribute columns. For the
    /// per-file formats each column lands in its own
    /// `sg_<i>.attr.<name>.slice` file; for a packed (v3) store each
    /// touched partition's `partition.gfsp` is rewritten **once**: the
    /// new `values` sections are appended to its body and the
    /// length-addressed directory is rewritten to list them (columns
    /// re-written under an existing name are replaced, matching the
    /// per-file formats' overwrite semantics; within one batch the
    /// last write of a name wins). The rewrite re-verifies every
    /// retained section's checksum (corruption is refused, never
    /// laundered into a re-checksummed file) and commits durably —
    /// temp file, fsync, rename ([`crate::util::fsio::persist`]) — so
    /// neither a torn write nor a machine death can corrupt the
    /// previous contents. Attribute names must be non-empty (the
    /// packed directory uses the empty name as its topology marker).
    pub fn write_attributes(&self, items: &[(SubgraphId, String, Vec<f32>)]) -> Result<()> {
        // Validation is format-independent: an empty name is
        // meaningless everywhere (and would collide with the packed
        // directory's empty-name-means-topology sentinel), and an
        // out-of-range target must fail loudly on every format — a
        // v1/v2 store would otherwise happily write a slice file no
        // load could ever see.
        for (id, name, _) in items {
            ensure!(
                !name.is_empty(),
                "attribute name for {id} must be non-empty"
            );
            ensure!(
                id.partition < self.meta.num_partitions,
                "partition {} out of range",
                id.partition
            );
            ensure!(
                id.index < self.meta.subgraph_counts[id.partition as usize],
                "sub-graph {id} out of range"
            );
        }
        if self.meta.format != SliceFormat::V3Packed {
            for (id, name, values) in items {
                let path = self.attr_path(id.partition, id.index, name);
                fs::write(
                    &path,
                    slice::encode_attribute(*id, name, values, self.meta.format),
                )
                .with_context(|| format!("write {}", path.display()))?;
            }
            return Ok(());
        }
        let mut by_part: BTreeMap<u32, Vec<&(SubgraphId, String, Vec<f32>)>> =
            BTreeMap::new();
        for item in items {
            by_part.entry(item.0.partition).or_default().push(item);
        }
        for (p, batch) in by_part {
            // Within one batch, later writes win — exactly what the
            // per-file formats do when a second fs::write overwrites
            // the first — so the directory never lists a name twice.
            let mut batch_last: Vec<&(SubgraphId, String, Vec<f32>)> = Vec::new();
            for item in batch {
                batch_last.retain(|prev| {
                    !(prev.0.index == item.0.index && prev.1 == item.1)
                });
                batch_last.push(item);
            }
            let path = self.packed_path(p);
            let bytes =
                fs::read(&path).with_context(|| format!("read {}", path.display()))?;
            let dir = packed::parse(&bytes)
                .with_context(|| format!("decode {}", path.display()))?;
            let mut sections: Vec<(u32, u8, String, Vec<u8>)> = Vec::new();
            for e in &dir.entries {
                let replaced = !e.name.is_empty()
                    && batch_last
                        .iter()
                        .any(|(id, n, _)| id.index == e.subgraph && *n == e.name);
                if replaced {
                    continue;
                }
                // Retained bodies are re-verified before the rewrite:
                // recomputing a fresh checksum over rotted bytes would
                // *launder* on-disk corruption into a file that scrubs
                // clean forever after. Refuse instead, naming the
                // section, and leave the original file untouched.
                let body = &bytes[e.range()];
                ensure!(
                    checksum(body) == e.checksum,
                    "section `{}` of {} corrupt (checksum mismatch); refusing to \
                     rewrite the packed file over it",
                    e.label(),
                    path.display()
                );
                sections.push((e.subgraph, e.section, e.name.clone(), body.to_vec()));
            }
            for (id, name, values) in batch_last {
                sections.push((
                    id.index,
                    slice::SEC_VALUES,
                    name.clone(),
                    slice::f32_column(values),
                ));
            }
            // Durable commit (fsync before rename, like the checkpoint
            // manifest): a machine death mid-rewrite must leave either
            // the old packed file or the new one, never a torn file.
            let tmp = path.with_extension("gfsp.tmp");
            fsio::persist(&tmp, &path, &packed::encode(&sections)?)?;
        }
        Ok(())
    }

    /// Full checksum scrub of every data file in the store: validates
    /// every section of every topology and attribute slice (v1's
    /// whole-payload checksum counts as one `payload` section) and,
    /// for packed stores, every section of every `partition.gfsp`
    /// behind its directory checksum — reporting corrupt sections by
    /// name (`sg_0.targets`, `sg_1.attr.rank`). The on-demand form of
    /// background scrubbing, surfaced as `goffish store verify`.
    pub fn scrub(&self) -> Result<super::section::ScrubSummary> {
        let mut sum = super::section::ScrubSummary::default();
        for p in 0..self.meta.num_partitions {
            let host = self.host_dir(p);
            let mut names: Vec<String> = fs::read_dir(&host)
                .with_context(|| format!("list {}", host.display()))?
                .collect::<std::io::Result<Vec<_>>>()?
                .into_iter()
                .map(|e| e.file_name().to_string_lossy().into_owned())
                .filter(|n| {
                    n.ends_with(".slice")
                        || n == packed::PARTITION_FILE
                        || (n.starts_with("partition.g") && n.ends_with(".gfsp"))
                })
                .collect();
            names.sort();
            for name in names {
                let rel = format!("host{p}/{name}");
                // Mapped where the platform allows (packed files can be
                // large); `Mapping` degrades to a heap read elsewhere.
                let bytes = match Mapping::map(&host.join(&name)) {
                    Ok(bytes) => bytes,
                    Err(e) => {
                        sum.record_unreadable(&rel, e);
                        continue;
                    }
                };
                if !name.ends_with(".slice") {
                    sum.record(&rel, packed::scrub(&bytes));
                } else {
                    // The filename says what the file must contain; the
                    // scrub validates the kind byte against it.
                    let want = if name.contains(".topo.") {
                        slice::SliceKind::Topology
                    } else {
                        slice::SliceKind::Attribute
                    };
                    sum.record(&rel, slice::scrub(&bytes, want));
                }
            }
        }
        Ok(sum)
    }

    /// Read a named attribute for one sub-graph. On a packed store
    /// this is the seek-skip in miniature: directory, one seek, one
    /// section — `bytes` counts just that column.
    pub fn read_attribute(&self, id: SubgraphId, name: &str) -> Result<(Vec<f32>, LoadStats)> {
        let t0 = Instant::now();
        if self.meta.format == SliceFormat::V3Packed {
            let path = self.packed_path(id.partition);
            let mut f = fs::File::open(&path)
                .with_context(|| format!("read {}", path.display()))?;
            let dir = packed::read_directory(&mut f)
                .with_context(|| format!("decode {}", path.display()))?;
            let e = dir
                .entries
                .iter()
                .find(|e| e.subgraph == id.index && e.name == name)
                .ok_or_else(|| {
                    anyhow!("no attribute `{name}` for {id} in {}", path.display())
                })?;
            let mut buf = vec![0u8; e.len as usize];
            f.seek(SeekFrom::Start(e.offset))?;
            f.read_exact(&mut buf)
                .with_context(|| format!("read section `{}`", e.label()))?;
            ensure!(
                checksum(&buf) == e.checksum,
                "section `{}` of {} corrupt (checksum mismatch)",
                e.label(),
                path.display()
            );
            let values = slice::decode_f32_column(&buf)?;
            return Ok((
                values,
                LoadStats { files: 1, bytes: e.len, seconds: t0.elapsed().as_secs_f64() },
            ));
        }
        let path = self.attr_path(id.partition, id.index, name);
        let bytes = fs::read(&path).with_context(|| format!("read {}", path.display()))?;
        let (got_id, got_name, values) = slice::decode_attribute(&bytes)?;
        ensure!(got_id == id && got_name == name, "attribute slice mismatch");
        Ok((
            values,
            LoadStats { files: 1, bytes: bytes.len() as u64, seconds: t0.elapsed().as_secs_f64() },
        ))
    }

    /// Sub-graph of every global vertex, indexed by vertex id — the
    /// placement table [`Store::append`] routes new edges with and
    /// incremental jobs scope their output with.
    pub fn vertex_locations(&self) -> Result<Vec<SubgraphId>> {
        let mut locs =
            vec![SubgraphId { partition: 0, index: 0 }; self.meta.num_vertices as usize];
        let opts = LoadOptions { sequential: true, cores: 1, ..Default::default() };
        for p in 0..self.meta.num_partitions {
            let (sgs, _, _) = self.load_partition_with(p, &opts)?;
            for sg in &sgs {
                for &gv in &sg.vertices {
                    locs[gv as usize] = sg.id;
                }
            }
        }
        Ok(locs)
    }

    /// Commit `batch` as generation `G+1`. Returns the new generation.
    ///
    /// Only packed (v3) stores mutate — run `goffish store migrate`
    /// first for the per-file formats. Every touched partition gets a
    /// fresh `partition.g<G+1>.gfsp` (earlier generation files are
    /// never rewritten); the atomic rename of `meta.txt` is the commit
    /// point, so a crash anywhere before it leaves the old generation
    /// fully intact and a handle opened before the append keeps
    /// reading its pinned snapshot. Single-appender discipline is the
    /// caller's: two concurrent appends to the same root race on the
    /// generation number.
    pub fn append(&mut self, batch: &AppendBatch) -> Result<u64> {
        ensure!(
            self.meta.format == SliceFormat::V3Packed,
            "append requires a packed (v3) store; run `goffish store migrate` on {} first",
            self.root.display()
        );
        ensure!(!batch.is_empty(), "empty append batch");
        for &(u, v, w) in &batch.edges {
            if self.meta.weighted {
                ensure!(
                    w.is_some(),
                    "edge ({u},{v}): weighted store requires a weight on every appended edge"
                );
            } else {
                ensure!(
                    w.is_none(),
                    "edge ({u},{v}): unweighted store cannot take a weighted edge"
                );
            }
        }
        let old_nv = self.meta.num_vertices;
        let new_nv = old_nv + batch.new_vertices;
        ensure!(new_nv <= u32::MAX as u64, "store would exceed u32 vertex ids");
        for &(u, v, _) in &batch.edges {
            ensure!(
                u < new_nv && v < new_nv,
                "edge ({u},{v}) out of range for {new_nv} vertices"
            );
        }

        // Place new vertices: each becomes a singleton sub-graph on its
        // hash partition (append never restructures existing
        // sub-graphs, so a new vertex cannot join one even when every
        // one of its edges points there).
        let mut locs = self.vertex_locations()?;
        let mut counts = self.meta.subgraph_counts.clone();
        let hasher = HashPartitioner::default();
        let k = self.meta.num_partitions;
        let mut new_sgs: BTreeMap<SubgraphId, u64> = BTreeMap::new();
        for gid in old_nv..new_nv {
            let p = hasher.bucket(gid, k);
            let id = SubgraphId { partition: p, index: counts[p as usize] };
            counts[p as usize] += 1;
            new_sgs.insert(id, gid);
            locs.push(id);
        }

        // Validate attribute targets against the post-append shape so a
        // batch can attach columns to the vertices it just created.
        for (id, name, _) in &batch.attributes {
            ensure!(!name.is_empty(), "attribute name for {id} must be non-empty");
            ensure!(
                id.partition < k,
                "partition {} out of range",
                id.partition
            );
            ensure!(
                id.index < counts[id.partition as usize],
                "sub-graph {id} out of range"
            );
        }

        // Route edges to the sub-graphs they touch.
        let mut deltas: BTreeMap<SubgraphId, SubgraphDelta> = BTreeMap::new();
        for &(u, v, w) in &batch.edges {
            let w = w.unwrap_or(1.0);
            let (lu, lv) = (locs[u as usize], locs[v as usize]);
            if lu == lv {
                deltas.entry(lu).or_default().local.push((u, v, w));
            } else if lu.partition == lv.partition {
                bail!(
                    "edge ({u},{v}) would merge sub-graphs {lu} and {lv}; append \
                     never merges sub-graphs (rebuild the store to re-discover)"
                );
            } else {
                deltas.entry(lu).or_default().remote_out.push((u, v, w));
                deltas.entry(lv).or_default().remote_in.push((u, v, w));
            }
        }

        // The dirty set this generation will record: everything whose
        // topology or attribute bytes change.
        let mut dirty: BTreeSet<SubgraphId> = deltas.keys().copied().collect();
        dirty.extend(new_sgs.keys().copied());
        dirty.extend(batch.attributes.iter().map(|(id, _, _)| *id));

        let next_gen = self.meta.generation + 1;
        let touched: BTreeSet<u32> = dirty.iter().map(|id| id.partition).collect();
        for &p in &touched {
            let path = self.packed_path(p);
            let bytes =
                fs::read(&path).with_context(|| format!("read {}", path.display()))?;
            let dir = packed::parse(&bytes)
                .with_context(|| format!("decode {}", path.display()))?;
            // Every carried-forward body is re-verified first — a
            // rewrite must never launder rotted bytes into a freshly
            // checksummed file (same refusal as `write_attributes`).
            for e in &dir.entries {
                ensure!(
                    checksum(&bytes[e.range()]) == e.checksum,
                    "section `{}` of {} corrupt (checksum mismatch); refusing to \
                     rewrite the packed file over it",
                    e.label(),
                    path.display()
                );
            }

            // Rebuild the sub-graphs whose topology changes: existing
            // ones decoded from the current file and extended, new
            // singletons built from scratch.
            let old_count = self.meta.subgraph_counts[p as usize];
            let mut rebuilt: BTreeMap<u32, Subgraph> = BTreeMap::new();
            for (id, delta) in deltas.iter().filter(|(id, _)| id.partition == p) {
                if id.index >= old_count {
                    continue; // new singleton, handled below
                }
                let mut sg = slice::decode_topology_from(|sec| {
                    dir.entries
                        .iter()
                        .find(|e| {
                            e.subgraph == id.index && e.name.is_empty() && e.section == sec
                        })
                        .map(|e| &bytes[e.range()])
                        .ok_or_else(|| {
                            anyhow!(
                                "missing section `{}` for sub-graph {id}",
                                slice::section_name(sec)
                            )
                        })
                })?;
                sg.num_global_vertices = new_nv;
                rebuilt.insert(id.index, apply_delta(&sg, delta, &locs, new_nv)?);
            }
            for (&id, &gid) in new_sgs.iter().filter(|(id, _)| id.partition == p) {
                let base = Subgraph {
                    id,
                    vertices: vec![gid as u32],
                    local: Graph::from_edges(
                        1,
                        &[],
                        if self.meta.weighted { Some(Vec::new()) } else { None },
                        self.meta.directed,
                    )?,
                    remote_out: Vec::new(),
                    remote_in: Vec::new(),
                    num_global_vertices: new_nv,
                };
                let sg = match deltas.get(&id) {
                    Some(delta) => apply_delta(&base, delta, &locs, new_nv)?,
                    None => base,
                };
                rebuilt.insert(id.index, sg);
            }

            // Attribute columns this batch (re)writes on this
            // partition; within one batch the last write of a name
            // wins, exactly as in `write_attributes`.
            let mut batch_last: Vec<&(SubgraphId, String, Vec<f32>)> = Vec::new();
            for item in batch.attributes.iter().filter(|(id, _, _)| id.partition == p) {
                batch_last
                    .retain(|prev| !(prev.0.index == item.0.index && prev.1 == item.1));
                batch_last.push(item);
            }

            // Assemble the new file: original entry order, with changed
            // topology bodies swapped in place, replaced columns
            // dropped (they re-enter at the end under their new
            // bodies), then the new singletons and new columns.
            let mut fresh: BTreeMap<(u32, u8), Vec<u8>> = BTreeMap::new();
            for (&i, sg) in &rebuilt {
                if i < old_count {
                    for (sec, body) in slice::topology_sections(sg) {
                        fresh.insert((i, sec), body);
                    }
                }
            }
            let mut sections: Vec<(u32, u8, String, Vec<u8>)> = Vec::new();
            for e in &dir.entries {
                if e.name.is_empty() {
                    let body = match fresh.remove(&(e.subgraph, e.section)) {
                        Some(body) => body,
                        None => bytes[e.range()].to_vec(),
                    };
                    sections.push((e.subgraph, e.section, String::new(), body));
                } else if batch_last
                    .iter()
                    .any(|(id, n, _)| id.index == e.subgraph && *n == e.name)
                {
                    continue;
                } else {
                    sections.push((
                        e.subgraph,
                        e.section,
                        e.name.clone(),
                        bytes[e.range()].to_vec(),
                    ));
                }
            }
            for (&i, sg) in rebuilt.iter().filter(|(&i, _)| i >= old_count) {
                for (sec, body) in slice::topology_sections(sg) {
                    sections.push((i, sec, String::new(), body));
                }
            }
            for (id, name, values) in batch_last {
                sections.push((
                    id.index,
                    slice::SEC_VALUES,
                    name.clone(),
                    slice::f32_column(values),
                ));
            }
            let out = self.host_dir(p).join(generation_file(next_gen));
            fsio::persist(
                &out.with_extension("gfsp.tmp"),
                &out,
                &packed::encode(&sections)?,
            )?;
        }

        // Manifest, then meta — the meta rename is the commit point; a
        // crash before it leaves unreferenced g-files that no reader
        // resolves (the pinned generation scan stops at the old head).
        let dirty_list: Vec<String> = dirty
            .iter()
            .map(|id| format!("{}:{}", id.partition, id.index))
            .collect();
        let manifest = format!(
            "generation={next_gen}\ndirty={}\nnew_vertices={}\nnew_edges={}\n",
            dirty_list.join(","),
            batch.new_vertices,
            batch.edges.len()
        );
        let manifest_path = self.root.join(format!("gen_{next_gen}.txt"));
        fsio::persist(
            &manifest_path.with_extension("txt.tmp"),
            &manifest_path,
            manifest.as_bytes(),
        )?;
        let meta = StoreMeta {
            num_vertices: new_nv,
            num_edges: self.meta.num_edges + batch.edges.len() as u64,
            subgraph_counts: counts,
            generation: next_gen,
            ..self.meta.clone()
        };
        let meta_path = self.root.join("meta.txt");
        fsio::persist(
            &self.root.join("meta.txt.tmp"),
            &meta_path,
            meta_text(&meta).as_bytes(),
        )?;
        self.meta = meta;
        Ok(next_gen)
    }

    /// Union of every sub-graph touched by generations `gen+1..=head`
    /// (sorted, deduplicated) — empty when this handle *is* at `gen`.
    /// This is what an incremental re-run scopes its recompute to.
    pub fn dirty_since(&self, since: u64) -> Result<Vec<SubgraphId>> {
        ensure!(
            since <= self.meta.generation,
            "generation {since} is ahead of the store head {}",
            self.meta.generation
        );
        let mut set = BTreeSet::new();
        for g in since + 1..=self.meta.generation {
            let path = self.root.join(format!("gen_{g}.txt"));
            let text = fs::read_to_string(&path)
                .with_context(|| format!("read generation manifest {}", path.display()))?;
            for line in text.lines() {
                let Some(list) = line.strip_prefix("dirty=") else { continue };
                for item in list.split(',').filter(|s| !s.is_empty()) {
                    let (p, i) = item.split_once(':').ok_or_else(|| {
                        anyhow!("malformed dirty entry {item:?} in {}", path.display())
                    })?;
                    set.insert(SubgraphId { partition: p.parse()?, index: i.parse()? });
                }
            }
        }
        Ok(set.into_iter().collect())
    }

    /// Rewrite a v1/v2 store as packed (v3) in place, re-verifying
    /// every checksum along the way (decode *is* verification), and
    /// return a fresh handle. A v3 store is a no-op. Each partition's
    /// packed file — carrying topology *and* every attribute column —
    /// is committed tmp+rename, and the store stays a valid v1/v2
    /// store until the final `meta.txt` rename flips the format (the
    /// commit point); only then are the superseded `.slice` files
    /// removed, so a crash at any step leaves a readable store.
    pub fn migrate_to_packed(root: &Path) -> Result<Store> {
        let store = Store::open(root)?;
        if store.meta.format == SliceFormat::V3Packed {
            return Ok(store);
        }
        let opts = LoadOptions {
            attributes: AttrProjection::All,
            sequential: true,
            cores: 1,
            ..Default::default()
        };
        for p in 0..store.meta.num_partitions {
            let (sgs, attrs, _) = store
                .load_partition_with(p, &opts)
                .with_context(|| format!("migrate: load partition {p}"))?;
            let mut sections: Vec<(u32, u8, String, Vec<u8>)> = Vec::new();
            for (i, sg) in sgs.iter().enumerate() {
                for (sec, body) in slice::topology_sections(sg) {
                    sections.push((sg.id.index, sec, String::new(), body));
                }
                for (name, col) in &attrs[i] {
                    sections.push((
                        sg.id.index,
                        slice::SEC_VALUES,
                        name.clone(),
                        slice::f32_column(col),
                    ));
                }
            }
            let path = store.host_dir(p).join(packed::PARTITION_FILE);
            fsio::persist(
                &path.with_extension("gfsp.tmp"),
                &path,
                &packed::encode(&sections)?,
            )?;
        }
        let meta = StoreMeta { format: SliceFormat::V3Packed, ..store.meta.clone() };
        fsio::persist(
            &root.join("meta.txt.tmp"),
            &root.join("meta.txt"),
            meta_text(&meta).as_bytes(),
        )?;
        // Past the commit point: the .slice files are now invisible to
        // every load path; removing them is pure cleanup and a crash
        // here leaves harmless (still-valid) extras.
        for p in 0..meta.num_partitions {
            let host = store.host_dir(p);
            for entry in fs::read_dir(&host)
                .with_context(|| format!("list {}", host.display()))?
            {
                let entry = entry?;
                if entry.file_name().to_string_lossy().ends_with(".slice") {
                    fs::remove_file(entry.path())?;
                }
            }
        }
        Store::open(root)
    }
}

/// Extend `base` with one sub-graph's routed edge mutations: appended
/// local edges re-enter the (stable) CSR build after the existing
/// ones, remote refs extend the existing vectors in batch order, and
/// the global vertex count moves to the new generation's total.
fn apply_delta(
    base: &Subgraph,
    delta: &SubgraphDelta,
    locs: &[SubgraphId],
    new_nv: u64,
) -> Result<Subgraph> {
    let weighted = base.local.has_weights();
    let local_of = |g: u64| -> Result<u32> {
        base.local_id(g as u32)
            .ok_or_else(|| anyhow!("vertex {g} not in sub-graph {}", base.id))
    };
    let mut edges: Vec<(u32, u32)> = Vec::new();
    let mut weights: Vec<f32> = Vec::new();
    for (u, v, ei) in base.local.edges() {
        edges.push((u, v));
        if weighted {
            weights.push(base.local.weight(ei));
        }
    }
    for &(u, v, w) in &delta.local {
        edges.push((local_of(u)?, local_of(v)?));
        if weighted {
            weights.push(w);
        }
    }
    let local = Graph::from_edges(
        base.vertices.len(),
        &edges,
        if weighted { Some(weights) } else { None },
        base.local.directed(),
    )?;
    let mut remote_out = base.remote_out.clone();
    for &(u, v, w) in &delta.remote_out {
        let t = locs[v as usize];
        remote_out.push(RemoteRef {
            local: local_of(u)?,
            target_global: v as u32,
            partition: t.partition,
            subgraph: t.index,
            weight: w,
        });
    }
    let mut remote_in = base.remote_in.clone();
    for &(u, v, w) in &delta.remote_in {
        let s = locs[u as usize];
        remote_in.push(RemoteRef {
            local: local_of(v)?,
            target_global: u as u32,
            partition: s.partition,
            subgraph: s.index,
            weight: w,
        });
    }
    Ok(Subgraph {
        id: base.id,
        vertices: base.vertices.clone(),
        local,
        remote_out,
        remote_in,
        num_global_vertices: new_nv,
    })
}

/// Read + decode + verify one planned slice.
fn load_one(plan: &SlicePlan, p: u32) -> Result<(Loaded, u64)> {
    match plan {
        SlicePlan::Topo { index, path } => {
            let bytes =
                fs::read(path).with_context(|| format!("read {}", path.display()))?;
            let sg = slice::decode_topology(&bytes)
                .with_context(|| format!("decode {}", path.display()))?;
            ensure!(
                sg.id == SubgraphId { partition: p, index: *index },
                "slice {} holds wrong sub-graph {}",
                path.display(),
                sg.id
            );
            Ok((Loaded::Topo(*index, sg), bytes.len() as u64))
        }
        SlicePlan::Attr { index, name, path } => {
            let bytes = fs::read(path)
                .with_context(|| format!("read attribute slice {}", path.display()))?;
            let (id, got_name, values) = slice::decode_attribute(&bytes)
                .with_context(|| format!("decode {}", path.display()))?;
            ensure!(
                id == SubgraphId { partition: p, index: *index } && got_name == *name,
                "attribute slice mismatch at {}",
                path.display()
            );
            Ok((Loaded::Attr(*index, name.clone(), values), bytes.len() as u64))
        }
    }
}

/// Read one sub-graph's planned sections out of a packed partition
/// file: entries are sorted by offset, byte-adjacent ones coalesce
/// into a single `seek` + `read_exact` run, every unwanted byte range
/// in between is seeked past, and the decoded columns borrow straight
/// from the run buffers (zero copies before materialization). Returns
/// the sub-graph, its projected attribute columns, and the section
/// bytes actually read.
fn load_packed_subgraph(
    path: &Path,
    p: u32,
    index: u32,
    plan: &[packed::Entry],
    num_global: u64,
) -> Result<(Subgraph, BTreeMap<String, Vec<f32>>, u64)> {
    ensure!(
        plan.iter().any(|e| e.name.is_empty()),
        "sub-graph {index} has no topology sections in the packed directory"
    );
    let mut entries: Vec<&packed::Entry> = plan.iter().collect();
    entries.sort_by_key(|e| e.offset);
    // Coalesce adjacent entries: (run start offset, run length, members).
    let mut runs: Vec<(u64, u64, Vec<&packed::Entry>)> = Vec::new();
    for e in entries {
        let extends_last =
            matches!(runs.last(), Some((start, len, _)) if start + len == e.offset);
        if extends_last {
            let (_, len, run) = runs.last_mut().unwrap();
            *len += e.len;
            run.push(e);
        } else {
            runs.push((e.offset, e.len, vec![e]));
        }
    }
    let mut file =
        fs::File::open(path).with_context(|| format!("read {}", path.display()))?;
    let mut bufs: Vec<Vec<u8>> = Vec::with_capacity(runs.len());
    let mut bytes = 0u64;
    for (start, len, _) in &runs {
        let mut buf = vec![0u8; *len as usize];
        file.seek(SeekFrom::Start(*start))
            .with_context(|| format!("seek to {start} in {}", path.display()))?;
        file.read_exact(&mut buf).with_context(|| {
            format!("read {len} bytes at {start} of {}", path.display())
        })?;
        bytes += len;
        bufs.push(buf);
    }
    // Slice each section body out of its run buffer and verify its
    // checksum — only sections actually read are ever checksummed.
    let mut sections: Vec<(&packed::Entry, &[u8])> = Vec::new();
    for ((_, _, run), buf) in runs.iter().zip(&bufs) {
        let mut pos = 0usize;
        for &e in run {
            let body = &buf[pos..pos + e.len as usize];
            pos += e.len as usize;
            ensure!(
                checksum(body) == e.checksum,
                "section `{}` of {} corrupt (checksum mismatch)",
                e.label(),
                path.display()
            );
            sections.push((e, body));
        }
    }
    let (sg, cols) = decode_packed_sections(path, p, index, &sections, num_global)?;
    Ok((sg, cols, bytes))
}

/// Mmap-path sub-graph load: section bodies are sliced straight out of
/// the partition mapping — no seeks, no intermediate buffers; the
/// decoded columns borrow from the mapping until materialization.
/// Checksums and byte accounting are identical to the seek+read path:
/// `bytes` is the sum of directory-listed lengths of the sections
/// decoded, not resident pages (see [`LoadStats::bytes`]).
fn load_packed_subgraph_mapped(
    path: &Path,
    map: &[u8],
    p: u32,
    index: u32,
    plan: &[packed::Entry],
    num_global: u64,
) -> Result<(Subgraph, BTreeMap<String, Vec<f32>>, u64)> {
    ensure!(
        plan.iter().any(|e| e.name.is_empty()),
        "sub-graph {index} has no topology sections in the packed directory"
    );
    let mut sections: Vec<(&packed::Entry, &[u8])> = Vec::with_capacity(plan.len());
    let mut bytes = 0u64;
    for e in plan {
        // `packed::parse` already proved exact byte accounting over the
        // mapping; the `get` guard keeps a corrupt directory panic-free.
        let body = map.get(e.range()).ok_or_else(|| {
            anyhow!(
                "section `{}` of {} extends past end of file",
                e.label(),
                path.display()
            )
        })?;
        ensure!(
            checksum(body) == e.checksum,
            "section `{}` of {} corrupt (checksum mismatch)",
            e.label(),
            path.display()
        );
        bytes += e.len;
        sections.push((e, body));
    }
    let (sg, cols) = decode_packed_sections(path, p, index, &sections, num_global)?;
    Ok((sg, cols, bytes))
}

/// Decode one sub-graph from its checksummed section bodies — the
/// single decoder behind both the seek+read and mmap load paths, so
/// byte-identical outputs across the two reduce to byte-identical
/// section bodies (which the checksums pin).
fn decode_packed_sections(
    path: &Path,
    p: u32,
    index: u32,
    sections: &[(&packed::Entry, &[u8])],
    num_global: u64,
) -> Result<(Subgraph, BTreeMap<String, Vec<f32>>)> {
    let mut sg = slice::decode_topology_from(|id| {
        sections
            .iter()
            .find(|(e, _)| e.name.is_empty() && e.section == id)
            .map(|(_, b)| *b)
            .ok_or_else(|| {
                anyhow!("missing section `{}`", slice::section_name(id))
            })
    })?;
    ensure!(
        sg.id == SubgraphId { partition: p, index },
        "packed sections at {} hold wrong sub-graph {}",
        path.display(),
        sg.id
    );
    // A sub-graph untouched since an earlier generation still carries
    // that generation's global vertex count in its META section; the
    // handle's pinned meta is authoritative for the snapshot being
    // loaded (identical for a never-appended store).
    sg.num_global_vertices = num_global;
    let mut cols = BTreeMap::new();
    for (e, body) in sections {
        if !e.name.is_empty() {
            let values = slice::decode_f32_column(body)
                .with_context(|| format!("decode section `{}`", e.label()))?;
            cols.insert(e.name.clone(), values);
        }
    }
    Ok((sg, cols))
}

/// Parse `sg_<idx>.attr.<name>.slice` file names.
fn parse_attr_filename(fname: &str) -> Option<(u32, String)> {
    let rest = fname.strip_prefix("sg_")?.strip_suffix(".slice")?;
    let (idx, name) = rest.split_once(".attr.")?;
    Some((idx.parse().ok()?, name.to_string()))
}

/// Write one host directory's partition files for `sgs` — the single
/// definition of the on-disk partition layout, shared by
/// [`Store::create_with_format`] and the streaming ingest path (which
/// must produce byte-identical files to the batch builder).
pub(crate) fn write_partition_files(
    host_dir: &Path,
    sgs: &[Subgraph],
    format: SliceFormat,
) -> Result<()> {
    fs::create_dir_all(host_dir)?;
    if format == SliceFormat::V3Packed {
        // One packed file per partition: every sub-graph's topology
        // sections back to back behind one directory (attribute
        // columns join the same file later via `write_attributes`'
        // directory rewrite).
        let mut sections: Vec<(u32, u8, String, Vec<u8>)> = Vec::new();
        for sg in sgs {
            for (sec, body) in slice::topology_sections(sg) {
                sections.push((sg.id.index, sec, String::new(), body));
            }
        }
        fs::write(
            host_dir.join(packed::PARTITION_FILE),
            packed::encode(&sections)?,
        )?;
    } else {
        for sg in sgs {
            let bytes = slice::encode_topology(sg, format);
            fs::write(
                host_dir.join(format!("sg_{}.topo.slice", sg.id.index)),
                bytes,
            )?;
        }
    }
    Ok(())
}

/// `partition.g<G>.gfsp` — the packed file a generation-`G` append
/// writes for a touched partition (generation 0 is the bare
/// [`packed::PARTITION_FILE`]).
fn generation_file(g: u64) -> String {
    format!("partition.g{g}.gfsp")
}

/// The `meta.txt` serialization (one `key=value` per line; parsers
/// must ignore unknown keys so older readers survive newer stores).
fn meta_text(meta: &StoreMeta) -> String {
    let counts: Vec<String> =
        meta.subgraph_counts.iter().map(|c| c.to_string()).collect();
    format!(
        "name={}\nvertices={}\nedges={}\ndirected={}\nweighted={}\npartitions={}\nsubgraphs={}\nformat={}\ngeneration={}\n",
        meta.name,
        meta.num_vertices,
        meta.num_edges,
        meta.directed,
        meta.weighted,
        meta.num_partitions,
        counts.join(","),
        meta.format,
        meta.generation
    )
}

pub(crate) fn write_meta(path: &Path, meta: &StoreMeta) -> Result<()> {
    fs::write(path, meta_text(meta)).with_context(|| format!("write {}", path.display()))
}

fn read_meta(path: &Path) -> Result<StoreMeta> {
    let text = fs::read_to_string(path)?;
    let mut name = None;
    let mut vertices = None;
    let mut edges = None;
    let mut directed = None;
    let mut weighted = None;
    let mut partitions = None;
    let mut subgraphs = None;
    // Stores written before the format knob carry no `format=` key and
    // are v1 by construction.
    let mut format = SliceFormat::V1;
    // Stores written before mutability carry no `generation=` key and
    // have never been appended to.
    let mut generation = 0u64;
    for line in text.lines() {
        let Some((k, v)) = line.split_once('=') else { continue };
        match k {
            "name" => name = Some(v.to_string()),
            "vertices" => vertices = Some(v.parse()?),
            "edges" => edges = Some(v.parse()?),
            "directed" => directed = Some(v == "true"),
            "weighted" => weighted = Some(v == "true"),
            "partitions" => partitions = Some(v.parse()?),
            "subgraphs" => {
                subgraphs = Some(
                    v.split(',')
                        .filter(|s| !s.is_empty())
                        .map(|s| s.parse::<u32>())
                        .collect::<Result<Vec<_>, _>>()?,
                )
            }
            "format" => {
                format = SliceFormat::parse(v)
                    .ok_or_else(|| anyhow!("meta.txt has unknown slice format {v:?}"))?
            }
            "generation" => generation = v.parse()?,
            // Unknown keys are ignored, not rejected: a store written
            // by a newer build (which may add keys, as `generation=`
            // itself once was) must stay readable by older tools.
            _ => {}
        }
    }
    let (Some(name), Some(num_vertices), Some(num_edges), Some(directed), Some(weighted), Some(num_partitions), Some(subgraph_counts)) =
        (name, vertices, edges, directed, weighted, partitions, subgraphs)
    else {
        bail!("meta.txt missing required keys");
    };
    ensure!(
        subgraph_counts.len() == num_partitions as usize,
        "meta.txt subgraph counts do not match partition count"
    );
    Ok(StoreMeta {
        name,
        num_vertices,
        num_edges,
        directed,
        weighted,
        num_partitions,
        subgraph_counts,
        format,
        generation,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;
    use crate::partition::{MultilevelPartitioner, Partitioner};

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join("goffish_store_tests")
            .join(format!("{name}_{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn create_open_load_round_trip() {
        for fmt in [SliceFormat::V1, SliceFormat::V2, SliceFormat::V3Packed] {
            let g = gen::road(16, 0.93, 0.02, 8);
            let parts = MultilevelPartitioner::default().partition(&g, 3);
            let root = tmp(&format!("round_trip_{fmt}"));
            let (store, dg) = Store::create_with_format(&root, "rn", &g, &parts, fmt).unwrap();
            assert_eq!(store.meta().num_partitions, 3);
            assert_eq!(store.meta().format, fmt);

            let reopened = Store::open(&root).unwrap();
            assert_eq!(reopened.meta(), store.meta());
            let (dg2, stats) = reopened.load_all().unwrap();
            assert_eq!(dg2.num_subgraphs(), dg.num_subgraphs());
            // Per-file formats open one file per slice; the packed
            // format opens exactly one file per partition.
            let want_files = if fmt == SliceFormat::V3Packed {
                3
            } else {
                dg.num_subgraphs()
            };
            assert!(stats.bytes > 0 && stats.files as usize == want_files, "{fmt}");
            // Vertex sets identical.
            let verts = |d: &DistributedGraph| -> Vec<Vec<u32>> {
                d.subgraphs().map(|s| s.vertices.clone()).collect()
            };
            assert_eq!(verts(&dg), verts(&dg2));
        }
    }

    #[test]
    fn mmap_and_read_loads_report_equal_stats() {
        // The LoadStats contract under mmap: `bytes` still counts the
        // directory-listed lengths of the sections the projection
        // decodes — not resident pages, not the mapped file length —
        // so the mapped and seek+read paths must account identically,
        // full and projected, and return identical graphs.
        let g = gen::road(16, 0.93, 0.02, 21);
        let parts = MultilevelPartitioner::default().partition(&g, 3);
        let root = tmp("mmap_accounting");
        let (store, dg) =
            Store::create_with_format(&root, "rn", &g, &parts, SliceFormat::V3Packed)
                .unwrap();
        for sg in dg.subgraphs() {
            for a in 0..3 {
                let vals: Vec<f32> =
                    sg.vertices.iter().map(|&v| v as f32 + a as f32).collect();
                store.write_attribute(sg.id, &format!("attr{a}"), &vals).unwrap();
            }
        }
        for projection in [
            AttrProjection::None,
            AttrProjection::All,
            AttrProjection::Only(vec!["attr1".into()]),
        ] {
            let mapped = LoadOptions {
                attributes: projection.clone(),
                mmap: true,
                ..Default::default()
            };
            let read = LoadOptions { mmap: false, ..mapped.clone() };
            let (dg_m, attrs_m, st_m) = store.load_all_with(&mapped).unwrap();
            let (dg_r, attrs_r, st_r) = store.load_all_with(&read).unwrap();
            assert_eq!(st_m.bytes, st_r.bytes, "{projection:?}: equal accounting");
            assert_eq!(st_m.files, st_r.files, "{projection:?}");
            assert!(st_m.bytes > 0);
            assert_eq!(attrs_m, attrs_r, "{projection:?}: identical columns");
            let verts = |d: &DistributedGraph| -> Vec<Vec<u32>> {
                d.subgraphs().map(|s| s.vertices.clone()).collect()
            };
            assert_eq!(verts(&dg_m), verts(&dg_r), "{projection:?}");
        }
    }

    #[test]
    fn default_format_is_v2() {
        let g = gen::chain(8);
        let parts = MultilevelPartitioner::default().partition(&g, 2);
        let root = tmp("default_v2");
        let (store, _) = Store::create(&root, "c", &g, &parts).unwrap();
        assert_eq!(store.meta().format, SliceFormat::V2);
        // The version byte on disk says so too.
        let bytes = fs::read(root.join("host0").join("sg_0.topo.slice")).unwrap();
        assert_eq!(bytes[4], 2);
    }

    #[test]
    fn meta_without_format_key_reads_as_v1() {
        let g = gen::chain(8);
        let parts = MultilevelPartitioner::default().partition(&g, 2);
        let root = tmp("legacy_meta");
        Store::create_with_format(&root, "c", &g, &parts, SliceFormat::V1).unwrap();
        // Strip the format line, as a pre-knob store would look.
        let meta_path = root.join("meta.txt");
        let text: String = fs::read_to_string(&meta_path)
            .unwrap()
            .lines()
            .filter(|l| !l.starts_with("format="))
            .map(|l| format!("{l}\n"))
            .collect();
        fs::write(&meta_path, text).unwrap();
        let store = Store::open(&root).unwrap();
        assert_eq!(store.meta().format, SliceFormat::V1);
        assert!(store.load_all().is_ok());
    }

    #[test]
    fn load_partition_is_data_local() {
        let g = gen::grid(10, 10);
        let parts = MultilevelPartitioner::default().partition(&g, 2);
        let root = tmp("data_local");
        let (store, _) = Store::create(&root, "grid", &g, &parts).unwrap();
        // Remove the other host's directory: partition 0 must still load.
        fs::remove_dir_all(root.join("host1")).unwrap();
        assert!(store.load_partition(0).is_ok());
        assert!(store.load_partition(1).is_err());
    }

    #[test]
    fn write_once_enforced() {
        let g = gen::chain(10);
        let parts = MultilevelPartitioner::default().partition(&g, 2);
        let root = tmp("write_once");
        Store::create(&root, "c", &g, &parts).unwrap();
        assert!(Store::create(&root, "c2", &g, &parts).is_err());
    }

    #[test]
    fn attributes_round_trip() {
        for fmt in [SliceFormat::V1, SliceFormat::V2, SliceFormat::V3Packed] {
            let g = gen::chain(12);
            let parts = MultilevelPartitioner::default().partition(&g, 2);
            let root = tmp(&format!("attrs_{fmt}"));
            let (store, dg) = Store::create_with_format(&root, "c", &g, &parts, fmt).unwrap();
            let sg = dg.subgraphs().next().unwrap();
            let vals: Vec<f32> = (0..sg.num_vertices()).map(|i| i as f32 * 0.5).collect();
            store.write_attribute(sg.id, "rank", &vals).unwrap();
            let (back, st) = store.read_attribute(sg.id, "rank").unwrap();
            assert_eq!(back, vals);
            assert_eq!(st.files, 1);
            assert!(store.read_attribute(sg.id, "missing").is_err());
            // Out-of-range targets fail loudly on EVERY format — not
            // just packed stores (a stray per-file slice would be
            // invisible to every load).
            assert!(store
                .write_attribute(SubgraphId { partition: 0, index: 999 }, "x", &[1.0])
                .is_err(), "{fmt}");
        }
    }

    #[test]
    fn projection_loads_declared_attributes_only() {
        let g = gen::road(14, 0.9, 0.02, 9);
        let parts = MultilevelPartitioner::default().partition(&g, 2);
        let root = tmp("projection");
        let (store, dg) = Store::create(&root, "g", &g, &parts).unwrap();
        for sg in dg.subgraphs() {
            for a in 0..4 {
                let vals: Vec<f32> =
                    sg.vertices.iter().map(|&v| v as f32 + a as f32).collect();
                store.write_attribute(sg.id, &format!("attr{a}"), &vals).unwrap();
            }
        }

        let full = LoadOptions { attributes: AttrProjection::All, ..Default::default() };
        let only = LoadOptions {
            attributes: AttrProjection::Only(vec!["attr1".into()]),
            ..Default::default()
        };
        let none = LoadOptions::default();
        let (_, attrs_full, st_full) = store.load_all_with(&full).map(flatten3).unwrap();
        let (_, attrs_only, st_only) = store.load_all_with(&only).map(flatten3).unwrap();
        let (_, attrs_none, st_none) = store.load_all_with(&none).map(flatten3).unwrap();

        // The projection is visible in bytes touched, strictly ordered.
        assert!(st_none.bytes < st_only.bytes, "{} vs {}", st_none.bytes, st_only.bytes);
        assert!(st_only.bytes < st_full.bytes, "{} vs {}", st_only.bytes, st_full.bytes);
        // And in which columns came back.
        for (i, sg) in dg.subgraphs().enumerate() {
            assert_eq!(attrs_full[i].len(), 4);
            assert_eq!(attrs_only[i].len(), 1);
            assert!(attrs_none[i].is_empty());
            let col = &attrs_only[i]["attr1"];
            let want: Vec<f32> = sg.vertices.iter().map(|&v| v as f32 + 1.0).collect();
            assert_eq!(col, &want);
        }
        // Declaring a missing attribute is an error, not a silent skip.
        let bad = LoadOptions {
            attributes: AttrProjection::Only(vec!["nope".into()]),
            ..Default::default()
        };
        assert!(store.load_partition_with(0, &bad).is_err());
    }

    /// Flatten per-partition attribute maps into sub-graph order for
    /// easy comparison with `dg.subgraphs()`.
    fn flatten3(
        x: (DistributedGraph, Vec<PartitionAttributes>, LoadStats),
    ) -> (DistributedGraph, PartitionAttributes, LoadStats) {
        let (dg, attrs, st) = x;
        (dg, attrs.into_iter().flatten().collect(), st)
    }

    #[test]
    fn parallel_and_sequential_loads_agree() {
        let g = gen::road(18, 0.92, 0.02, 21);
        let parts = MultilevelPartitioner::default().partition(&g, 4);
        let root = tmp("par_eq_seq");
        let (store, _) = Store::create(&root, "g", &g, &parts).unwrap();
        let seq = LoadOptions { sequential: true, ..Default::default() };
        let (dg_s, _, st_s) = store.load_all_with(&seq).unwrap();
        let (dg_p, _, st_p) = store.load_all_with(&LoadOptions::default()).unwrap();
        assert_eq!(st_s.files, st_p.files);
        assert_eq!(st_s.bytes, st_p.bytes);
        let shape = |d: &DistributedGraph| -> Vec<(Vec<u32>, usize, usize, usize)> {
            d.subgraphs()
                .map(|s| {
                    (s.vertices.clone(), s.local.num_edges(), s.remote_out.len(), s.remote_in.len())
                })
                .collect()
        };
        assert_eq!(shape(&dg_s), shape(&dg_p));
    }

    #[test]
    fn open_missing_store_fails() {
        assert!(Store::open(Path::new("/nonexistent/store")).is_err());
    }

    #[test]
    fn corrupted_slice_detected_at_load() {
        for fmt in [SliceFormat::V1, SliceFormat::V2] {
            let g = gen::chain(20);
            let parts = MultilevelPartitioner::default().partition(&g, 2);
            let root = tmp(&format!("corrupt_{fmt}"));
            let (store, _) = Store::create_with_format(&root, "c", &g, &parts, fmt).unwrap();
            // Flip a byte in one slice.
            let slice_path = root.join("host0").join("sg_0.topo.slice");
            let mut bytes = fs::read(&slice_path).unwrap();
            let mid = bytes.len() - 3;
            bytes[mid] ^= 0x55;
            fs::write(&slice_path, bytes).unwrap();
            assert!(store.load_partition(0).is_err(), "{fmt}");
        }
    }

    #[test]
    fn scrub_reports_clean_then_corrupt_by_file_and_section() {
        let g = gen::chain(16);
        let parts = MultilevelPartitioner::default().partition(&g, 2);
        let root = tmp("scrub");
        let (store, dg) = Store::create(&root, "c", &g, &parts).unwrap();
        let sg = dg.subgraphs().next().unwrap();
        store
            .write_attribute(sg.id, "rank", &vec![1.0; sg.num_vertices()])
            .unwrap();

        let sum = store.scrub().unwrap();
        assert!(sum.is_clean(), "{:?}", sum.corrupt);
        assert!(sum.files >= 3, "topology slices + attribute slice");
        assert!(sum.sections > sum.files, "v2 slices are multi-section");

        // Flip one byte in a topology slice: the report names the file.
        let victim = root.join("host0").join("sg_0.topo.slice");
        let mut bytes = fs::read(&victim).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0x55;
        fs::write(&victim, bytes).unwrap();
        let sum = store.scrub().unwrap();
        assert_eq!(sum.corrupt.len(), 1, "{:?}", sum.corrupt);
        assert!(sum.corrupt[0].contains("host0/sg_0.topo.slice"));
        assert!(sum.corrupt[0].contains("section `"));
    }

    #[test]
    fn partition_out_of_range() {
        let g = gen::chain(5);
        let parts = MultilevelPartitioner::default().partition(&g, 2);
        let root = tmp("oob");
        let (store, _) = Store::create(&root, "c", &g, &parts).unwrap();
        assert!(store.load_partition(5).is_err());
    }

    #[test]
    fn packed_store_is_one_file_per_partition() {
        let g = gen::road(14, 0.9, 0.02, 9);
        let parts = MultilevelPartitioner::default().partition(&g, 3);
        let root = tmp("packed_layout");
        let (store, dg) =
            Store::create_with_format(&root, "g", &g, &parts, SliceFormat::V3Packed).unwrap();
        let mut items = Vec::new();
        for sg in dg.subgraphs() {
            let vals: Vec<f32> = sg.vertices.iter().map(|&v| v as f32).collect();
            items.push((sg.id, "rank".to_string(), vals));
        }
        store.write_attributes(&items).unwrap();
        // Each host dir holds exactly the packed file — no .slice files.
        for p in 0..3 {
            let names: Vec<String> = fs::read_dir(root.join(format!("host{p}")))
                .unwrap()
                .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
                .collect();
            assert_eq!(names, vec![crate::gofs::packed::PARTITION_FILE.to_string()]);
        }
        // And it loads identically to a v2 store of the same graph.
        let root2 = tmp("packed_layout_v2");
        let (store2, _) =
            Store::create_with_format(&root2, "g", &g, &parts, SliceFormat::V2).unwrap();
        store2.write_attributes(&items).unwrap();
        let all = LoadOptions { attributes: AttrProjection::All, ..Default::default() };
        let (dg3, attrs3, _) = store.load_all_with(&all).unwrap();
        let (dg2, attrs2, _) = store2.load_all_with(&all).unwrap();
        let verts = |d: &DistributedGraph| -> Vec<Vec<u32>> {
            d.subgraphs().map(|s| s.vertices.clone()).collect()
        };
        assert_eq!(verts(&dg3), verts(&dg2));
        assert_eq!(attrs3, attrs2);
    }

    #[test]
    fn packed_write_attribute_replaces_by_name() {
        let g = gen::chain(12);
        let parts = MultilevelPartitioner::default().partition(&g, 2);
        let root = tmp("packed_replace");
        let (store, dg) =
            Store::create_with_format(&root, "g", &g, &parts, SliceFormat::V3Packed).unwrap();
        let sg = dg.subgraphs().next().unwrap();
        let v1: Vec<f32> = vec![1.0; sg.num_vertices()];
        let v2: Vec<f32> = vec![2.0; sg.num_vertices()];
        store.write_attribute(sg.id, "rank", &v1).unwrap();
        store.write_attribute(sg.id, "rank", &v2).unwrap();
        let (back, st) = store.read_attribute(sg.id, "rank").unwrap();
        assert_eq!(back, v2);
        assert_eq!(st.files, 1);
        // Rewriting under the same name replaced the column in place —
        // the directory lists it once.
        let bytes =
            fs::read(root.join("host0").join(crate::gofs::packed::PARTITION_FILE)).unwrap();
        let dir = crate::gofs::packed::parse(&bytes).unwrap();
        let ranks: Vec<_> =
            dir.entries.iter().filter(|e| e.name == "rank").collect();
        assert_eq!(ranks.len(), 1);
        // Out-of-range targets are refused.
        assert!(store
            .write_attribute(SubgraphId { partition: 9, index: 0 }, "x", &[1.0])
            .is_err());
        // So is an empty attribute name — it would collide with the
        // packed directory's empty-name-means-topology sentinel.
        assert!(store.write_attribute(sg.id, "", &v1).is_err());
    }

    #[test]
    fn packed_projection_seeks_past_undeclared_attributes() {
        let g = gen::road(14, 0.9, 0.02, 9);
        let parts = MultilevelPartitioner::default().partition(&g, 2);
        let root = tmp("packed_projection");
        let (store, dg) =
            Store::create_with_format(&root, "g", &g, &parts, SliceFormat::V3Packed).unwrap();
        let mut items = Vec::new();
        for sg in dg.subgraphs() {
            for a in 0..4 {
                let vals: Vec<f32> =
                    sg.vertices.iter().map(|&v| v as f32 + a as f32).collect();
                items.push((sg.id, format!("attr{a}"), vals));
            }
        }
        store.write_attributes(&items).unwrap();
        let full = LoadOptions { attributes: AttrProjection::All, ..Default::default() };
        let only = LoadOptions {
            attributes: AttrProjection::Only(vec!["attr1".into()]),
            ..Default::default()
        };
        let none = LoadOptions::default();
        let (_, attrs_full, st_full) = store.load_all_with(&full).map(flatten3).unwrap();
        let (_, attrs_only, st_only) = store.load_all_with(&only).map(flatten3).unwrap();
        let (_, attrs_none, st_none) = store.load_all_with(&none).map(flatten3).unwrap();
        assert!(st_none.bytes < st_only.bytes);
        assert!(st_only.bytes < st_full.bytes);
        // Exactly one file per partition, regardless of projection.
        assert_eq!(st_full.files, 2);
        assert_eq!(st_only.files, 2);
        for (i, sg) in dg.subgraphs().enumerate() {
            assert_eq!(attrs_full[i].len(), 4);
            assert_eq!(attrs_only[i].len(), 1);
            assert!(attrs_none[i].is_empty());
            let want: Vec<f32> = sg.vertices.iter().map(|&v| v as f32 + 1.0).collect();
            assert_eq!(&attrs_only[i]["attr1"], &want);
        }
        // Declaring a missing attribute is an error, not a silent skip.
        let bad = LoadOptions {
            attributes: AttrProjection::Only(vec!["nope".into()]),
            ..Default::default()
        };
        assert!(store.load_partition_with(0, &bad).is_err());
    }

    #[test]
    fn packed_rewrite_refuses_to_launder_corruption() {
        // A rewrite re-checksums every body it copies forward; blindly
        // recomputing FNVs over rotted bytes would turn detectable
        // corruption into a file that scrubs clean forever after.
        let g = gen::chain(16);
        let parts = MultilevelPartitioner::default().partition(&g, 2);
        let root = tmp("packed_launder");
        let (store, dg) =
            Store::create_with_format(&root, "g", &g, &parts, SliceFormat::V3Packed).unwrap();
        let sg = dg.partitions[0][0].clone();
        let victim = root.join("host0").join(crate::gofs::packed::PARTITION_FILE);
        let mut bytes = fs::read(&victim).unwrap();
        let last = bytes.len() - 2;
        bytes[last] ^= 0x55;
        fs::write(&victim, &bytes).unwrap();
        // The write fails, names the section…
        let err = store
            .write_attribute(sg.id, "rank", &vec![1.0; sg.num_vertices()])
            .unwrap_err();
        assert!(format!("{err:#}").contains("corrupt"), "{err:#}");
        // …and the original (still-detectable) file is untouched.
        assert_eq!(fs::read(&victim).unwrap(), bytes);
        assert!(!store.scrub().unwrap().is_clean());
        // The other partition still accepts writes.
        let sg1 = dg.partitions[1][0].clone();
        store
            .write_attribute(sg1.id, "rank", &vec![1.0; sg1.num_vertices()])
            .unwrap();
    }

    #[test]
    fn packed_batch_duplicates_resolve_to_last_write() {
        // Same (sub-graph, name) twice in one batch: the later column
        // wins everywhere — matching the per-file formats, where the
        // second fs::write overwrites the first — and the directory
        // lists the name exactly once.
        let g = gen::chain(10);
        let parts = MultilevelPartitioner::default().partition(&g, 2);
        let root = tmp("packed_dup_batch");
        let (store, dg) =
            Store::create_with_format(&root, "g", &g, &parts, SliceFormat::V3Packed).unwrap();
        let sg = dg.subgraphs().next().unwrap();
        let a = vec![1.0f32; sg.num_vertices()];
        let b = vec![2.0f32; sg.num_vertices()];
        store
            .write_attributes(&[
                (sg.id, "rank".to_string(), a),
                (sg.id, "rank".to_string(), b.clone()),
            ])
            .unwrap();
        let (direct, _) = store.read_attribute(sg.id, "rank").unwrap();
        assert_eq!(direct, b);
        let opts = LoadOptions {
            attributes: AttrProjection::Only(vec!["rank".into()]),
            ..Default::default()
        };
        let (_, attrs, _) = store.load_partition_with(sg.id.partition, &opts).unwrap();
        assert_eq!(attrs[sg.id.index as usize]["rank"], b);
        let file = fs::read(
            root.join(format!("host{}", sg.id.partition))
                .join(crate::gofs::packed::PARTITION_FILE),
        )
        .unwrap();
        let dir = crate::gofs::packed::parse(&file).unwrap();
        assert_eq!(dir.entries.iter().filter(|e| e.name == "rank").count(), 1);
    }

    #[test]
    fn packed_corruption_detected_at_load_and_scrub() {
        let g = gen::chain(20);
        let parts = MultilevelPartitioner::default().partition(&g, 2);
        let root = tmp("packed_corrupt");
        let (store, _) =
            Store::create_with_format(&root, "g", &g, &parts, SliceFormat::V3Packed).unwrap();
        assert!(store.scrub().unwrap().is_clean());
        let victim = root.join("host0").join(crate::gofs::packed::PARTITION_FILE);
        let mut bytes = fs::read(&victim).unwrap();
        let last = bytes.len() - 3;
        bytes[last] ^= 0x55;
        fs::write(&victim, bytes).unwrap();
        assert!(store.load_partition(0).is_err());
        // The untouched partition still loads.
        assert!(store.load_partition(1).is_ok());
        let sum = store.scrub().unwrap();
        assert_eq!(sum.corrupt.len(), 1, "{:?}", sum.corrupt);
        assert!(sum.corrupt[0].contains("host0/partition.gfsp"));
    }

    #[test]
    fn attr_filename_parsing() {
        assert_eq!(parse_attr_filename("sg_3.attr.rank.slice"), Some((3, "rank".into())));
        assert_eq!(
            parse_attr_filename("sg_0.attr.with.dots.slice"),
            Some((0, "with.dots".into()))
        );
        assert_eq!(parse_attr_filename("sg_0.topo.slice"), None);
        assert_eq!(parse_attr_filename("meta.txt"), None);
        assert_eq!(parse_attr_filename("sg_x.attr.rank.slice"), None);
    }

    /// Everything a load can observe about a store, in deterministic
    /// order — the equality the generation-isolation tests assert on.
    type Observed = Vec<(
        SubgraphId,
        Vec<u32>,
        Vec<(u32, u32, f32)>,
        Vec<RemoteRef>,
        Vec<RemoteRef>,
        u64,
        Vec<(String, Vec<f32>)>,
    )>;

    fn observe(store: &Store) -> Observed {
        let opts = LoadOptions { attributes: AttrProjection::All, ..Default::default() };
        let (dg, attrs, _) = store.load_all_with(&opts).unwrap();
        let flat: PartitionAttributes = attrs.into_iter().flatten().collect();
        dg.subgraphs()
            .zip(flat)
            .map(|(sg, cols)| {
                (
                    sg.id,
                    sg.vertices.clone(),
                    sg.local
                        .edges()
                        .map(|(u, v, ei)| (u, v, sg.local.weight(ei)))
                        .collect(),
                    sg.remote_out.clone(),
                    sg.remote_in.clone(),
                    sg.num_global_vertices,
                    cols.into_iter().collect(),
                )
            })
            .collect()
    }

    #[test]
    fn meta_tolerates_unknown_keys_and_tracks_generation() {
        let g = gen::chain(8);
        let parts = MultilevelPartitioner::default().partition(&g, 2);
        let root = tmp("meta_unknown");
        let (store, _) = Store::create(&root, "c", &g, &parts).unwrap();
        assert_eq!(store.meta().generation, 0);
        // A future build may add keys; today's parser must skip them —
        // exactly how `generation=` itself stays readable by the tools
        // that predate it (the fig4b bench and the CLI smoke both grep
        // meta.txt line-wise and must keep working on migrated stores).
        let meta_path = root.join("meta.txt");
        let mut text = fs::read_to_string(&meta_path).unwrap();
        assert!(text.contains("generation=0\n"));
        text.push_str("future_key=whatever\n");
        fs::write(&meta_path, text).unwrap();
        let reopened = Store::open(&root).unwrap();
        assert_eq!(reopened.meta(), store.meta());
        assert!(reopened.load_all().is_ok());
    }

    #[test]
    fn append_pins_old_handles_and_tracks_dirty() {
        let g = gen::road(16, 0.93, 0.02, 8);
        let parts = MultilevelPartitioner::default().partition(&g, 3);
        let root = tmp("append_pin");
        let (mut head, dg) =
            Store::create_with_format(&root, "g", &g, &parts, SliceFormat::V3Packed).unwrap();
        let pinned = Store::open(&root).unwrap();
        let before = observe(&pinned);
        let gen0_files: Vec<Vec<u8>> = (0..3)
            .map(|p| {
                fs::read(root.join(format!("host{p}")).join(packed::PARTITION_FILE)).unwrap()
            })
            .collect();

        // An edge between two existing vertices on different partitions,
        // plus one brand-new vertex and one attribute column.
        let mut locs = vec![SubgraphId { partition: 0, index: 0 }; 16];
        for sg in dg.subgraphs() {
            for &v in &sg.vertices {
                locs[v as usize] = sg.id;
            }
        }
        let a = 0u64;
        let b = (1..16u64)
            .find(|&x| locs[x as usize].partition != locs[0].partition)
            .unwrap();
        let (src_id, dst_id) = (locs[a as usize], locs[b as usize]);
        let src_n = dg.subgraph(src_id).num_vertices();
        let batch = AppendBatch {
            new_vertices: 1,
            edges: vec![(a, b, None)],
            attributes: vec![(src_id, "score".into(), vec![0.5; src_n])],
        };
        assert_eq!(head.append(&batch).unwrap(), 1);
        assert_eq!(head.meta().generation, 1);
        assert_eq!(head.meta().num_vertices, 17);

        // The pinned handle keeps reading an unchanged snapshot — down
        // to the bytes of its generation-0 files.
        assert_eq!(pinned.meta().generation, 0);
        assert_eq!(observe(&pinned), before);
        assert!(pinned.read_attribute(src_id, "score").is_err());
        for (p, want) in gen0_files.iter().enumerate() {
            let got =
                fs::read(root.join(format!("host{p}")).join(packed::PARTITION_FILE)).unwrap();
            assert_eq!(&got, want, "generation-0 file for host{p} was rewritten");
        }

        // A fresh open sees the head: the new edge, vertex, and column.
        let fresh = Store::open(&root).unwrap();
        assert_eq!(fresh.meta().generation, 1);
        let (dg_after, _) = fresh.load_all().unwrap();
        assert!(dg_after.subgraphs().all(|s| s.num_global_vertices == 17));
        let src_after = dg_after.subgraph(src_id);
        assert_eq!(src_after.remote_out.len(), dg.subgraph(src_id).remote_out.len() + 1);
        let added = *src_after.remote_out.last().unwrap();
        assert_eq!(added.target_global, b as u32);
        assert_eq!(added.weight, 1.0);
        assert_eq!(
            dg_after.subgraph(dst_id).remote_in.len(),
            dg.subgraph(dst_id).remote_in.len() + 1
        );
        let new_loc = fresh.vertex_locations().unwrap()[16];
        assert_eq!(new_loc.partition, HashPartitioner::default().bucket(16, 3));
        assert_eq!(dg_after.subgraph(new_loc).vertices, vec![16]);
        let (col, _) = fresh.read_attribute(src_id, "score").unwrap();
        assert_eq!(col, vec![0.5; src_n]);

        // dirty_since names exactly the touched sub-graphs.
        let mut want = vec![src_id, dst_id, new_loc];
        want.sort();
        want.dedup();
        assert_eq!(fresh.dirty_since(0).unwrap(), want);
        assert!(fresh.dirty_since(1).unwrap().is_empty());
        assert!(fresh.dirty_since(2).is_err());

        // A second generation: dirty sets stay per-generation and
        // union across them; the gen-1 pin stays isolated too.
        let after_gen1 = observe(&fresh);
        let mut head2 = Store::open(&root).unwrap();
        let dst_n = dg.subgraph(dst_id).num_vertices();
        head2
            .append(&AppendBatch {
                attributes: vec![(dst_id, "score2".into(), vec![1.0; dst_n])],
                ..Default::default()
            })
            .unwrap();
        let fresh2 = Store::open(&root).unwrap();
        assert_eq!(fresh2.meta().generation, 2);
        assert_eq!(fresh2.dirty_since(1).unwrap(), vec![dst_id]);
        assert_eq!(fresh2.dirty_since(0).unwrap(), want);
        assert_eq!(observe(&fresh), after_gen1);
        assert!(fresh2.scrub().unwrap().is_clean());
    }

    #[test]
    fn append_requires_packed_and_rejects_merges() {
        let g = gen::chain(8);
        let parts = MultilevelPartitioner::default().partition(&g, 2);
        let root = tmp("append_guard_v2");
        let (mut store, _) = Store::create(&root, "c", &g, &parts).unwrap();
        let err = store
            .append(&AppendBatch { new_vertices: 1, ..Default::default() })
            .unwrap_err();
        assert!(format!("{err:#}").contains("store migrate"), "{err:#}");

        // Two locally disconnected chains on one partition → two
        // sub-graphs; an edge bridging them is refused, not merged.
        let root3 = tmp("append_guard_merge");
        let g2 = Graph::from_edges(4, &[(0, 1), (2, 3)], None, false).unwrap();
        let parts2 = Partitioning::new(1, vec![0, 0, 0, 0]);
        let (mut s3, dg) =
            Store::create_with_format(&root3, "m", &g2, &parts2, SliceFormat::V3Packed)
                .unwrap();
        assert_eq!(dg.partitions[0].len(), 2);
        let err = s3
            .append(&AppendBatch { edges: vec![(1, 2, None)], ..Default::default() })
            .unwrap_err();
        assert!(format!("{err:#}").contains("merge"), "{err:#}");
        // Empty batches and weight-shape mismatches are refused.
        assert!(s3.append(&AppendBatch::default()).is_err());
        assert!(s3
            .append(&AppendBatch { edges: vec![(0, 1, Some(2.0))], ..Default::default() })
            .is_err());
        // A multi-edge within one sub-graph is fine and visible.
        s3.append(&AppendBatch { edges: vec![(0, 1, None)], ..Default::default() })
            .unwrap();
        let fresh = Store::open(&root3).unwrap();
        assert_eq!(fresh.meta().num_edges, 3);
        let (dg2, _) = fresh.load_all().unwrap();
        assert_eq!(dg2.subgraphs().map(|s| s.local.num_edges()).sum::<usize>(), 3);
    }

    #[test]
    fn migrate_rewrites_v1_and_v2_stores_as_packed() {
        for fmt in [SliceFormat::V1, SliceFormat::V2] {
            let g = gen::road(14, 0.9, 0.02, 9);
            let parts = MultilevelPartitioner::default().partition(&g, 2);
            let root = tmp(&format!("migrate_{fmt}"));
            let (store, dg) = Store::create_with_format(&root, "g", &g, &parts, fmt).unwrap();
            for sg in dg.subgraphs() {
                store
                    .write_attribute(sg.id, "rank", &vec![1.5; sg.num_vertices()])
                    .unwrap();
            }
            let before = observe(&store);
            let migrated = Store::migrate_to_packed(&root).unwrap();
            assert_eq!(migrated.meta().format, SliceFormat::V3Packed);
            assert_eq!(migrated.meta().generation, 0);
            assert_eq!(observe(&migrated), before, "{fmt}");
            assert!(migrated.scrub().unwrap().is_clean());
            // Only packed files remain in each host directory.
            for p in 0..2 {
                let names: Vec<String> = fs::read_dir(root.join(format!("host{p}")))
                    .unwrap()
                    .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
                    .collect();
                assert_eq!(names, vec![packed::PARTITION_FILE.to_string()], "{fmt}");
            }
            // Idempotent, and the migrated store can now mutate.
            let mut again = Store::migrate_to_packed(&root).unwrap();
            assert_eq!(observe(&again), before);
            again
                .append(&AppendBatch { new_vertices: 1, ..Default::default() })
                .unwrap();
            assert_eq!(Store::open(&root).unwrap().meta().num_vertices, 15);
        }
    }
}
