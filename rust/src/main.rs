//! GoFFish CLI entrypoint — see `cli` for the command surface.

fn main() {
    let code = goffish::cli::run(std::env::args().skip(1).collect());
    std::process::exit(code);
}
