//! Vertex-centric BSP baseline — the Apache Giraph stand-in.
//!
//! Same manager/worker BSP skeleton as Gopher (shared fabric, EOS
//! protocol, halting rule) but the unit of computation is a single
//! vertex: `compute(value, vertex-context, messages)`, messages address
//! vertices, and vertices are scattered by hash (the Giraph default the
//! paper compares against). Supports optional Giraph-style combiners.
//!
//! This engine exists so every benchmark can run the *same algorithm* in
//! both models on the same simulated cluster and reproduce the paper's
//! Gopher-vs-Giraph comparisons (Figs 4a/4c).

pub mod api;
pub mod engine;

pub use api::{VertexContext, VertexProgram};
pub use engine::{run as run_vertex, PregelConfig, VertexRunResult};
