//! Vertex-centric BSP engine (the Giraph stand-in).
//!
//! Mirrors `gopher::engine` — same fabric, EOS drain, sync/resume/halt
//! protocol — with vertices as the unit of compute and hash placement as
//! the default. Differences that matter for the paper's comparison:
//!
//! * fine-grained parallelism: active vertices are processed in
//!   core-count chunks (Giraph's vertex-level multithreading);
//! * messages address *vertices*; routing consults the global placement
//!   assignment (every worker holds it — Giraph does the same via its
//!   partition owner map);
//! * optional combiners fold same-destination-vertex messages before
//!   they hit the wire;
//! * the coordinator layer rides the same barrier as in Gopher:
//!   programs register global aggregators, workers report partial
//!   vectors with their sync, and the manager folds and re-broadcasts
//!   the globals with *resume* (read back one superstep later via
//!   [`VertexContext::aggregated`]); the per-superstep traces land in
//!   `JobMetrics::aggregators` exactly as on the sub-graph engine.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::ckpt::{self, InboxEntry, WorkerResume};
use crate::coordinator::{Aggregators, Coordinator};
use crate::graph::csr::{Graph, VertexId};
use crate::metrics::{CheckpointMetrics, JobMetrics, SuperstepMetrics};
use crate::partition::Partitioning;
use crate::util::codec::{Decoder, Encoder};
use crate::util::index::VertexIndex;
use crate::util::pool;

use super::api::{VertexContext, VertexProgram};
use crate::gopher::api::MsgCodec;
use crate::gopher::transport::{self, Fabric, FabricKind};

/// Engine configuration.
#[derive(Clone, Debug)]
pub struct PregelConfig {
    pub cores_per_worker: usize,
    pub fabric: FabricKind,
    pub max_supersteps: usize,
    /// Simulated load time charged to metrics (the HDFS side of Fig 4b is
    /// modelled by `sim::disk`; the engine itself loads from memory).
    pub load_seconds: f64,
    /// Checkpointing (see [`crate::ckpt`] and the matching knob on
    /// `gopher::GopherConfig`): the config's `mode` picks whether the
    /// epoch write happens inside the barrier (sync) or on a background
    /// flusher thread (async double-buffering).
    pub checkpoint: Option<ckpt::CheckpointConfig>,
    /// Restart after a committed epoch instead of superstep 1. The run
    /// must use the same graph/partitioning as the checkpointed one.
    /// With `confined: true`, only the failed worker rebuilds from its
    /// snapshot + the senders' message logs (see [`crate::ckpt`]).
    pub resume: Option<ckpt::ResumePoint>,
    /// Failure-injection testing hook: the named worker aborts at the
    /// start of the named superstep.
    pub fail_at: Option<ckpt::FailPoint>,
    /// Live run-control handle: the manager publishes each completed
    /// superstep through it and honors a cancellation request at the
    /// next barrier (see the matching knob on `gopher::GopherConfig`).
    pub control: Option<crate::coordinator::RunControl>,
    /// Resolve message targets through a dense
    /// [`crate::util::index::VertexIndex`] instead of binary search
    /// (default true); `false` forces the sorted fallback. Results are
    /// identical either way — pinned by the engine parity tests.
    pub dense_index: bool,
    /// Span tracing ([`crate::obs::trace`]): same taxonomy as the
    /// matching knob on `gopher::GopherConfig` — per-worker load (here:
    /// index build + state init) and compute/route/drain/barrier phase
    /// spans, manager-side checkpoint commits. Disabled by default and
    /// never result-affecting.
    pub trace: crate::obs::trace::Tracer,
}

impl Default for PregelConfig {
    fn default() -> Self {
        Self {
            cores_per_worker: 4,
            fabric: FabricKind::InProc,
            max_supersteps: 10_000,
            load_seconds: 0.0,
            checkpoint: None,
            resume: None,
            fail_at: None,
            control: None,
            dense_index: true,
            trace: crate::obs::trace::Tracer::default(),
        }
    }
}

/// Result of a vertex-centric job.
pub struct VertexRunResult<V> {
    /// Final value per vertex (global id order).
    pub values: Vec<V>,
    pub metrics: JobMetrics,
}

const TAG_BATCH: u8 = 0;
const TAG_EOS: u8 = 1;

/// Batch frames carry the sending worker's id (see `gopher::engine`'s
/// wire-format notes): receivers stably sort per-vertex inboxes by
/// sender before compute, making delivery — and floating-point fold —
/// order deterministic across runs (the recovery-parity requirement).
fn encode_batch<M: MsgCodec>(sender: u32, msgs: &[(VertexId, M)]) -> Vec<u8> {
    let mut e = Encoder::with_capacity(8 + msgs.len() * 6);
    e.put_u8(TAG_BATCH);
    e.put_varint(sender as u64);
    e.put_varint(msgs.len() as u64);
    for (v, m) in msgs {
        e.put_varint(*v as u64);
        m.encode(&mut e);
    }
    e.into_bytes()
}

fn decode_batch<M: MsgCodec>(bytes: &[u8]) -> Result<(u32, Vec<(VertexId, M)>)> {
    let mut d = Decoder::new(bytes);
    let tag = d.get_u8()?;
    if tag != TAG_BATCH {
        bail!("expected batch frame, got tag {tag}");
    }
    let sender = d.get_varint()? as u32;
    let n = d.get_varint()? as usize;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let v = d.get_varint()? as u32;
        out.push((v, M::decode(&mut d)?));
    }
    Ok((sender, out))
}

struct WorkerSync {
    worker: u32,
    sent: u64,
    /// Encoded bytes put on the fabric this superstep.
    bytes: u64,
    /// Wall clock of this worker's compute phase (manager publishes a
    /// live straggler ratio through `RunControl`).
    compute_seconds: f64,
    quiescent: bool,
    /// Worker failed: manager must abort the job after this superstep.
    failed: bool,
    /// Worker-local partial aggregator values for this superstep.
    agg: Vec<f64>,
}

enum ManagerCmd {
    /// Continue with the globally folded aggregator values.
    Resume(Vec<f64>),
    Terminate,
}

struct WorkerSuperstep {
    /// Wall clock of this worker's whole superstep (compute + route +
    /// drain + checkpoint), measured worker-side so superstep 1 never
    /// includes load.
    wall_seconds: f64,
    compute_seconds: f64,
    unit_times: Vec<f64>,
    messages: u64,
    bytes: u64,
    active_units: u64,
    /// Messages eliminated by the combiner before encoding.
    combined: u64,
    /// Wall/bytes of this worker's checkpoint write (0 on supersteps
    /// that did not checkpoint).
    ckpt_seconds: f64,
    ckpt_bytes: u64,
}

struct WorkerOutput<V> {
    /// (global id, value) pairs for this worker's vertices.
    values: Vec<(VertexId, V)>,
    per_superstep: Vec<WorkerSuperstep>,
}

/// Worker entry point; see `gopher::engine::worker_body` for the failure
/// protocol (EOS to peers + failed sync, so errors abort, not deadlock).
#[allow(clippy::too_many_arguments)]
fn worker_body<P, F>(
    program: &P,
    fabric: F,
    cfg: &PregelConfig,
    aggs: &Aggregators,
    graph: &Graph,
    parts: &Partitioning,
    my_vertices: Vec<VertexId>,
    writer: Option<&ckpt::CheckpointWriter>,
    flusher: Option<&ckpt::CheckpointFlusher>,
    resume: Option<WorkerResume>,
    sync_tx: Sender<WorkerSync>,
    cmd_rx: Receiver<ManagerCmd>,
) -> Result<WorkerOutput<P::Value>>
where
    P: VertexProgram,
    F: Fabric,
{
    let me = fabric.id();
    let k = fabric.num_workers();
    match worker_loop(
        program, &fabric, cfg, aggs, graph, parts, my_vertices, writer, flusher,
        resume, &sync_tx, &cmd_rx,
    ) {
        Ok(out) => Ok(out),
        Err(e) => {
            for p in 0..k as u32 {
                if p != me {
                    let _ = fabric.send(p, vec![TAG_EOS]);
                }
            }
            let _ = sync_tx.send(WorkerSync {
                worker: me,
                sent: 0,
                bytes: 0,
                compute_seconds: 0.0,
                quiescent: true,
                failed: true,
                agg: Vec::new(),
            });
            let _ = cmd_rx.recv();
            Err(e)
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn worker_loop<P, F>(
    program: &P,
    fabric: &F,
    cfg: &PregelConfig,
    aggs: &Aggregators,
    graph: &Graph,
    parts: &Partitioning,
    my_vertices: Vec<VertexId>,
    writer: Option<&ckpt::CheckpointWriter>,
    flusher: Option<&ckpt::CheckpointFlusher>,
    resume: Option<WorkerResume>,
    sync_tx: &Sender<WorkerSync>,
    cmd_rx: &Receiver<ManagerCmd>,
) -> Result<WorkerOutput<P::Value>>
where
    P: VertexProgram,
    F: Fabric,
{
    let me = fabric.id();
    let k = fabric.num_workers();
    let n_local = my_vertices.len();

    // Span recorder for this worker's lane (tid = worker id + 1; tid 0
    // is the manager). `None` when tracing is disabled — every would-be
    // span below then costs one `Option` branch and nothing else.
    let rec = cfg.trace.recorder(me + 1);
    // The vertex engine has no storage load; its per-worker setup cost
    // (index build + state init / snapshot decode) is the analogous
    // span so traces from both engines share one taxonomy.
    let load_span = rec.as_ref().map(|r| r.span("load", "load"));

    // Global id -> local index: the vertex-centric engine pays this
    // lookup once per delivered message, so it gets the same compact
    // index as the sub-graph engine (dense O(1) remap where the id
    // span allows, sorted binary search otherwise or when the
    // `dense_index` knob forces the fallback).
    let vindex = if cfg.dense_index {
        VertexIndex::build(&my_vertices)
    } else {
        VertexIndex::sorted(&my_vertices)
    };
    let local_of = |v: VertexId| -> Option<usize> { vindex.get(v).map(|i| i as usize) };

    // Fresh start, or rebuild values/halted/queues from this worker's
    // snapshot of the epoch being resumed.
    type Rebuilt<V, M> = (Vec<V>, Vec<bool>, Vec<Vec<InboxEntry<M>>>, usize, Option<Vec<f64>>);
    let (init_values, init_halted, init_inbox, start_superstep, init_globals): Rebuilt<
        P::Value,
        P::Msg,
    > = match resume {
        Some(r) => {
            // The snapshot bytes were read + checksum-validated exactly
            // once by `ckpt::open_resume`; decode straight from the
            // shared buffer instead of re-reading the file per worker.
            let snap = ckpt::decode_partition::<P::Value, P::Msg, _>(
                &r.bytes,
                r.epoch,
                me,
                n_local,
                |i, d| program.restore_state(my_vertices[i], graph, d),
            )
            .with_context(|| format!("decode checkpoint {}", r.path.display()))?;
            let queues = match &r.replay {
                // Confined recovery, dead worker: rebuild the inbox from
                // the senders' logged frames (sender-ordered, per-sender
                // FIFO intact); the stable sender-sort before compute
                // normalizes them exactly like the snapshot queues, so
                // replay is byte-identical (see gopher::engine).
                Some(frames) => {
                    let mut queues: Vec<Vec<InboxEntry<P::Msg>>> =
                        (0..n_local).map(|_| Vec::new()).collect();
                    for frame in frames {
                        let (sender, msgs) = decode_batch::<P::Msg>(frame)?;
                        for (v, payload) in msgs {
                            let i = local_of(v).with_context(|| {
                                format!(
                                    "replayed message for non-local vertex {v} \
                                     on worker {me}"
                                )
                            })?;
                            queues[i].push(InboxEntry {
                                sender,
                                vertex: None,
                                payload,
                            });
                        }
                    }
                    queues
                }
                None => snap.inbox,
            };
            (
                snap.states,
                snap.halted,
                queues,
                r.epoch as usize + 1,
                Some(r.globals),
            )
        }
        None => (
            my_vertices.iter().map(|&v| program.init(v, graph)).collect(),
            vec![false; n_local],
            (0..n_local).map(|_| Vec::new()).collect(),
            1,
            None,
        ),
    };

    let values: Vec<Mutex<P::Value>> = init_values.into_iter().map(Mutex::new).collect();
    let halted: Vec<AtomicBool> = init_halted.into_iter().map(AtomicBool::new).collect();
    let mut inbox: Vec<Vec<InboxEntry<P::Msg>>> = init_inbox;
    drop(load_span);

    let mut per_superstep = Vec::new();
    let mut superstep = start_superstep;
    // Folded global aggregator values from the previous superstep's
    // barrier (None before the first barrier; restored on resume).
    let mut agg_global: Option<Vec<f64>> = init_globals;
    // Adaptive parallelism (see gopher::engine): skip thread fan-out when
    // the previous superstep's compute was negligible.
    const PARALLEL_THRESHOLD_SECONDS: f64 = 200e-6;
    let mut last_compute = f64::INFINITY;

    loop {
        // Failure injection (testing hook): die exactly like a killed
        // host — peers and the manager are unblocked by `worker_body`'s
        // cleanup path, and the job aborts with this error.
        if let Some(fp) = &cfg.fail_at {
            if superstep == fp.superstep && me == fp.worker {
                bail!("injected worker failure: worker {me} killed at superstep {superstep}");
            }
        }
        let t_step = Instant::now();
        // Superstep span stays open through the barrier so the phase
        // spans below nest inside it (see gopher::engine).
        let span_step = rec
            .as_ref()
            .map(|r| r.span_n("superstep", "superstep", "superstep", superstep as f64));
        // Deliveries of the previous superstep, stably sorted by sending
        // worker (see `encode_batch`): deterministic replay.
        let queued: Vec<Vec<InboxEntry<P::Msg>>> =
            std::mem::replace(&mut inbox, (0..n_local).map(|_| Vec::new()).collect());
        let cur_inbox: Vec<Vec<P::Msg>> = queued
            .into_iter()
            .map(|mut unit| {
                unit.sort_by_key(|m| m.sender);
                unit.into_iter().map(|m| m.payload).collect()
            })
            .collect();
        let active: Vec<usize> = (0..n_local)
            .filter(|&i| !halted[i].load(Ordering::Relaxed) || !cur_inbox[i].is_empty())
            .collect();

        // ---- compute phase: chunked vertex-level parallelism
        let cores_now = if last_compute < PARALLEL_THRESHOLD_SECONDS {
            1
        } else {
            cfg.cores_per_worker
        };
        // Chunk layout follows the *configured* core count, never the
        // timing-adaptive `cores_now`: per-chunk aggregator pre-folds
        // associate along chunk boundaries, so a timing-dependent
        // layout would make f64 aggregator sums nondeterministic — a
        // hole in recovery parity. Only the pool's thread count adapts.
        let n_chunks = cfg.cores_per_worker.max(1).min(active.len().max(1));
        let chunk_size = active.len().div_ceil(n_chunks.max(1)).max(1);
        // Each chunk yields (outgoing messages, folded aggregator
        // contributions); both are harvested after the pool joins.
        type ChunkOut<M> = (Vec<(VertexId, M)>, Vec<f64>);
        let chunk_out: Vec<Mutex<ChunkOut<P::Msg>>> = (0..n_chunks)
            .map(|_| Mutex::new((Vec::new(), Vec::new())))
            .collect();
        let span_compute = rec.as_ref().map(|r| r.span("compute", "phase"));
        let t0 = Instant::now();
        let unit_times = pool::run_indexed(cores_now, n_chunks, |c| {
            let lo = (c * chunk_size).min(active.len());
            let hi = ((c + 1) * chunk_size).min(active.len());
            let mut local_out = Vec::new();
            let mut local_agg = aggs.identity_values();
            for &i in &active[lo..hi] {
                let v = my_vertices[i];
                let mut ctx =
                    VertexContext::new(superstep, v, graph, aggs, agg_global.as_deref());
                let mut value = values[i].lock().unwrap();
                program.compute(&mut value, &mut ctx, &cur_inbox[i]);
                halted[i].store(ctx.halted, Ordering::Relaxed);
                local_out.append(&mut ctx.out);
                aggs.fold_into(&mut local_agg, &ctx.agg_local);
            }
            *chunk_out[c].lock().unwrap() = (local_out, local_agg);
        })?;
        let compute_seconds = t0.elapsed().as_secs_f64();
        last_compute = compute_seconds;
        drop(span_compute);

        // ---- route phase (folding aggregator partials as we harvest)
        let span_route = rec.as_ref().map(|r| r.span("route", "phase"));
        let mut sent_msgs = 0u64;
        let mut sent_bytes = 0u64;
        let mut agg_partial = aggs.identity_values();
        let mut pending: Vec<Vec<(VertexId, P::Msg)>> = (0..k).map(|_| Vec::new()).collect();
        for cell in &chunk_out {
            let mut guard = cell.lock().unwrap();
            aggs.fold_into(&mut agg_partial, &guard.1);
            for (target, m) in guard.0.drain(..) {
                sent_msgs += 1;
                pending[parts.of(target) as usize].push((target, m));
            }
        }
        // Combiner: fold same-target messages per destination worker.
        let mut combined = 0u64;
        for buf in pending.iter_mut() {
            if buf.len() < 2 {
                continue;
            }
            let before = buf.len();
            buf.sort_by_key(|(v, _)| *v);
            let mut folded: Vec<(VertexId, P::Msg)> = Vec::with_capacity(buf.len());
            for (v, m) in buf.drain(..) {
                match folded.last_mut() {
                    Some((lv, lm)) if *lv == v => match program.combine(lm, &m) {
                        Some(c) => *lm = c,
                        None => folded.push((v, m)),
                    },
                    _ => folded.push((v, m)),
                }
            }
            combined += (before - folded.len()) as u64;
            *buf = folded;
        }
        // On checkpoint supersteps, log every outgoing frame with its
        // destination: the epoch's send log is what lets a later
        // confined recovery replay the dead worker's in-flight
        // messages from the senders' side (see gopher::engine).
        let log_sends = cfg
            .checkpoint
            .as_ref()
            .is_some_and(|ck| superstep % ck.every == 0);
        let mut sendlog: Option<Vec<(u32, Vec<u8>)>> = log_sends.then(Vec::new);
        for (p, buf) in pending.iter_mut().enumerate() {
            if buf.is_empty() {
                continue;
            }
            if p as u32 == me {
                // Self-delivery bypasses the fabric, but the send log
                // gets the encoded frame anyway: confined replay must
                // cover self-sent messages too.
                if let Some(log) = &mut sendlog {
                    log.push((me, encode_batch(me, buf)));
                }
                for (v, m) in buf.drain(..) {
                    let i = local_of(v)
                        .with_context(|| format!("message for non-local vertex {v}"))?;
                    inbox[i].push(InboxEntry { sender: me, vertex: None, payload: m });
                }
            } else {
                let frame = encode_batch(me, buf);
                sent_bytes += frame.len() as u64;
                if let Some(log) = &mut sendlog {
                    log.push((p as u32, frame.clone()));
                }
                fabric.send(p as u32, frame)?;
                buf.clear();
            }
        }
        for p in 0..k as u32 {
            if p != me {
                fabric.send(p, vec![TAG_EOS])?;
            }
        }
        drop(span_route);

        // ---- drain phase
        let span_drain = rec.as_ref().map(|r| r.span("drain", "phase"));
        let mut eos_seen = 0usize;
        while eos_seen < k - 1 {
            let frame = fabric.recv()?;
            match frame.first() {
                Some(&TAG_EOS) => eos_seen += 1,
                Some(&TAG_BATCH) => {
                    let (sender, msgs) = decode_batch::<P::Msg>(&frame)?;
                    for (v, m) in msgs {
                        let i = local_of(v)
                            .with_context(|| format!("misrouted message for vertex {v}"))?;
                        inbox[i].push(InboxEntry { sender, vertex: None, payload: m });
                    }
                }
                other => bail!("bad frame tag {other:?}"),
            }
        }
        drop(span_drain);

        // ---- checkpoint phase (mirrors gopher::engine: snapshot before
        // sync; the manager commits once every worker synced cleanly).
        let mut ckpt_seconds = 0.0;
        let mut ckpt_bytes = 0u64;
        if let (Some(w), Some(ck)) = (writer, cfg.checkpoint.as_ref()) {
            if superstep % ck.every == 0 {
                let t_ck = Instant::now();
                // Sender-sort the queues before encoding so identical
                // runs write identical snapshot bytes (see
                // gopher::engine; the consumer sorts anyway).
                for unit in &mut inbox {
                    unit.sort_by_key(|m| m.sender);
                }
                let encode = |compress: bool| {
                    ckpt::encode_partition(
                        superstep as u64,
                        me,
                        n_local,
                        |i, e| program.save_state(&values[i].lock().unwrap(), e),
                        |i| halted[i].load(Ordering::Relaxed),
                        &inbox,
                        compress,
                    )
                };
                let log = sendlog.take().unwrap_or_default();
                let log_bytes =
                    ckpt::encode_sendlog(superstep as u64, me, &log, ck.compress);
                match flusher {
                    // Async: the barrier pays only for the encode (the
                    // `ckpt_buffer` span is the whole remaining stall);
                    // the flusher persists on its own thread while the
                    // next superstep computes.
                    Some(f) => {
                        let _span_ckpt =
                            rec.as_ref().map(|r| r.span("ckpt_buffer", "ckpt"));
                        let snapshot = encode(ck.compress);
                        ckpt_bytes = snapshot.len() as u64;
                        f.enqueue_partition(superstep as u64, me, snapshot);
                        f.enqueue_sendlog(superstep as u64, me, log_bytes);
                    }
                    // Sync: persist (and fsync) inside the barrier.
                    None => {
                        let _span_ckpt =
                            rec.as_ref().map(|r| r.span("ckpt_write", "ckpt"));
                        let snapshot = encode(ck.compress);
                        ckpt_bytes = w.write_partition(superstep as u64, me, &snapshot)?;
                        w.write_sendlog(superstep as u64, me, &log_bytes)?;
                    }
                }
                ckpt_seconds = t_ck.elapsed().as_secs_f64();
            }
        }

        per_superstep.push(WorkerSuperstep {
            wall_seconds: t_step.elapsed().as_secs_f64(),
            compute_seconds,
            unit_times,
            messages: sent_msgs,
            bytes: sent_bytes,
            active_units: active.len() as u64,
            combined,
            ckpt_seconds,
            ckpt_bytes,
        });

        let quiescent = (0..n_local)
            .all(|i| halted[i].load(Ordering::Relaxed) && inbox[i].is_empty());
        let span_barrier = rec.as_ref().map(|r| r.span("barrier", "phase"));
        sync_tx
            .send(WorkerSync {
                worker: me,
                sent: sent_msgs,
                bytes: sent_bytes,
                compute_seconds,
                quiescent,
                failed: false,
                agg: agg_partial,
            })
            .map_err(|_| anyhow::anyhow!("manager hung up"))?;
        let cmd = cmd_rx.recv().context("manager command channel closed")?;
        drop(span_barrier);
        drop(span_step);
        match cmd {
            ManagerCmd::Resume(globals) => {
                agg_global = Some(globals);
                superstep += 1;
            }
            ManagerCmd::Terminate => break,
        }
        if superstep > cfg.max_supersteps {
            bail!("exceeded max_supersteps={}", cfg.max_supersteps);
        }
    }

    let values = my_vertices
        .iter()
        .zip(values)
        .map(|(&v, cell)| (v, cell.into_inner().unwrap()))
        .collect();
    Ok(WorkerOutput { values, per_superstep })
}

/// Run a vertex-centric program over `graph` scattered by `parts`.
pub fn run<P: VertexProgram>(
    graph: &Graph,
    parts: &Partitioning,
    program: &P,
    cfg: &PregelConfig,
) -> Result<VertexRunResult<P::Value>> {
    let k = parts.k();
    anyhow::ensure!(k >= 1, "no partitions");
    anyhow::ensure!(
        parts.num_vertices() == graph.num_vertices(),
        "partitioning does not match graph"
    );

    // Coordinator layer: one registry shared by workers, one folding
    // coordinator owned by the manager (mirrors gopher::engine).
    let aggs = Aggregators::new(program.aggregators());

    // Checkpoint plumbing (shared helpers, identical to gopher::engine).
    let writer = match &cfg.checkpoint {
        Some(ck) => {
            Some(Arc::new(ckpt::create_writer(ck, cfg.resume.as_ref(), k as u32)?))
        }
        None => None,
    };
    // Async mode: one background flusher (trace lane k+1, the first
    // after the workers') persists what workers/manager enqueue.
    let flusher = match (&writer, &cfg.checkpoint) {
        (Some(w), Some(ck)) if ck.mode == ckpt::CheckpointMode::Async => {
            Some(ckpt::CheckpointFlusher::spawn(w.clone(), &cfg.trace, k as u32 + 1)?)
        }
        _ => None,
    };
    let resume_state: Option<ckpt::ResumeState> = match &cfg.resume {
        Some(rp) => Some(ckpt::open_resume(rp, k, aggs.len())?),
        None => None,
    };
    let base_superstep = cfg.resume.as_ref().map(|r| r.epoch as usize).unwrap_or(0);

    let (sync_tx, sync_rx) = channel::<WorkerSync>();
    let mut cmd_txs = Vec::with_capacity(k);
    let mut cmd_rxs = Vec::with_capacity(k);
    for _ in 0..k {
        let (tx, rx) = channel();
        cmd_txs.push(tx);
        cmd_rxs.push(rx);
    }

    enum Fabrics {
        InProc(Vec<transport::InProcFabric>),
        Tcp(Vec<transport::TcpFabric>),
    }
    let fabrics = match cfg.fabric {
        FabricKind::InProc => Fabrics::InProc(transport::in_proc(k)),
        FabricKind::Tcp => Fabrics::Tcp(transport::tcp(k)?),
    };

    let outputs: Result<(
        Vec<WorkerOutput<P::Value>>,
        Vec<crate::coordinator::AggregatorTrace>,
    )> = std::thread::scope(|scope| {
            let mut handles = Vec::with_capacity(k);
            enum FabricAny {
                InProc(transport::InProcFabric),
                Tcp(transport::TcpFabric),
            }
            let aggs_ref = &aggs;
            let writer_ref = writer.as_deref();
            let flusher_ref = flusher.as_ref();
            let resume_ref = resume_state.as_ref();
            let mut spawn_worker = |p: usize, fab: FabricAny| {
                let sync_tx = sync_tx.clone();
                let cmd_rx = cmd_rxs.remove(0);
                let my_vertices = parts.vertices_of(p as u32);
                let worker_resume = resume_ref.map(|rs| ckpt::worker_resume(rs, p as u32));
                handles.push(scope.spawn(move || match fab {
                    FabricAny::InProc(f) => worker_body(
                        program, f, cfg, aggs_ref, graph, parts, my_vertices,
                        writer_ref, flusher_ref, worker_resume, sync_tx, cmd_rx,
                    ),
                    FabricAny::Tcp(f) => worker_body(
                        program, f, cfg, aggs_ref, graph, parts, my_vertices,
                        writer_ref, flusher_ref, worker_resume, sync_tx, cmd_rx,
                    ),
                }));
            };
            match fabrics {
                Fabrics::InProc(fs) => {
                    for (p, f) in fs.into_iter().enumerate() {
                        spawn_worker(p, FabricAny::InProc(f));
                    }
                }
                Fabrics::Tcp(fs) => {
                    for (p, f) in fs.into_iter().enumerate() {
                        spawn_worker(p, FabricAny::Tcp(f));
                    }
                }
            }
            drop(sync_tx);

            // ---- manager loop (sync barrier + coordinator fold)
            let mut coordinator = match resume_ref {
                Some(rs) => {
                    Coordinator::with_history(aggs.clone(), rs.coord.history.clone())
                }
                None => Coordinator::new(aggs.clone()),
            };
            let mut superstep = base_superstep;
            let mut commit_err: Option<anyhow::Error> = None;
            let mut cancelled = false;
            // First worker that reported failure this run (recorded in
            // the checkpoint dir's FAILED_WORKER marker at abort so a
            // later --confined-recovery resume knows whom to rebuild).
            let mut failed_worker: Option<u32> = None;
            // Manager lane spans (tid 0) + cumulative counters for the
            // live-progress publication below.
            let mgr_rec = cfg.trace.recorder(0);
            let mut cum_msgs = 0u64;
            let mut cum_bytes = 0u64;
            loop {
                let mut sent_total = 0u64;
                let mut bytes_total = 0u64;
                let mut computes = vec![0.0f64; k];
                let mut all_quiescent = true;
                let mut any_failed = false;
                // Worker-indexed partials: fold order independent of
                // sync arrival order (deterministic replay).
                let mut partials: Vec<Vec<f64>> = vec![Vec::new(); k];
                let mut seen = 0usize;
                while seen < k {
                    match sync_rx.recv() {
                        Ok(s) => {
                            sent_total += s.sent;
                            bytes_total += s.bytes;
                            computes[s.worker as usize] = s.compute_seconds;
                            all_quiescent &= s.quiescent;
                            if s.failed {
                                any_failed = true;
                                failed_worker.get_or_insert(s.worker);
                            }
                            partials[s.worker as usize] = s.agg;
                            seen += 1;
                        }
                        Err(_) => {
                            for h in handles {
                                match h.join() {
                                    Ok(Ok(_)) => {}
                                    Ok(Err(e)) => return Err(e),
                                    Err(p) => std::panic::resume_unwind(p),
                                }
                            }
                            bail!("worker exited mid-superstep without error");
                        }
                    }
                }
                superstep += 1;
                let globals = coordinator.fold_superstep(&partials);
                // Epoch commit at a clean barrier (see gopher::engine).
                if let (Some(w), Some(ck)) = (&writer, &cfg.checkpoint) {
                    if superstep % ck.every == 0 && !any_failed {
                        let coord_bytes = ckpt::encode_coordinator(
                            superstep as u64,
                            aggs.len(),
                            coordinator.history(),
                            ck.compress,
                        );
                        match &flusher {
                            // Async: every worker enqueued its snapshot
                            // before syncing, so the FIFO commit lands
                            // after them; an earlier flush error
                            // surfaces here, at the next barrier.
                            Some(f) => {
                                f.enqueue_commit(superstep as u64, coord_bytes);
                                if let Some(e) = f.take_error() {
                                    commit_err = Some(e);
                                }
                            }
                            None => {
                                let _span_commit = mgr_rec
                                    .as_ref()
                                    .map(|r| r.span("ckpt_commit", "ckpt"));
                                if let Err(e) = w.commit(superstep as u64, &coord_bytes)
                                {
                                    commit_err = Some(e);
                                }
                            }
                        }
                    }
                }
                // Run-control hook: publish progress for external
                // observers and honor a cancellation request — workers
                // are terminated at this barrier, so a cancelled job
                // stops within one superstep of the request.
                cum_msgs += sent_total;
                cum_bytes += bytes_total;
                if let Some(ctl) = &cfg.control {
                    ctl.publish_superstep(superstep);
                    let straggler = SuperstepMetrics {
                        partition_compute_seconds: computes,
                        ..Default::default()
                    }
                    .straggler_ratio();
                    ctl.publish_progress(cum_msgs, cum_bytes, straggler);
                    ctl.publish_ckpt_inflight(
                        flusher.as_ref().map_or(0, |f| f.inflight()),
                    );
                    cancelled = ctl.is_cancelled();
                }
                let done = (all_quiescent && sent_total == 0)
                    || any_failed
                    || commit_err.is_some()
                    || cancelled;
                if done && any_failed {
                    if let (Some(w), Some(fw)) = (&writer, failed_worker) {
                        // Best-effort: a missing marker only downgrades a
                        // later resume from confined to global; a stale
                        // one is harmless (replay equals the snapshot
                        // queues), so neither failure mode is worth
                        // aborting the abort for.
                        let _ = w.write_failed_marker(fw);
                    }
                }
                for tx in &cmd_txs {
                    // A worker that already errored may have dropped its rx.
                    let _ = tx.send(if done {
                        ManagerCmd::Terminate
                    } else {
                        ManagerCmd::Resume(globals.clone())
                    });
                }
                if done {
                    break;
                }
            }

            let mut outs = Vec::with_capacity(k);
            for h in handles {
                match h.join() {
                    Ok(Ok(o)) => outs.push(o),
                    Ok(Err(e)) => return Err(e),
                    Err(p) => std::panic::resume_unwind(p),
                }
            }
            if let Some(e) = commit_err {
                // The writer's own context already names the epoch/file.
                return Err(e);
            }
            if cancelled {
                bail!("job cancelled at superstep {superstep}");
            }
            Ok((outs, coordinator.into_traces()))
        });
    // Always drain + join the flusher, then let a worker/manager error
    // outrank a flush error (the flush error for a failed run is
    // usually downstream noise of the same fault).
    let flush_result = match flusher {
        Some(f) => f.finish(),
        None => Ok(()),
    };
    let (outputs, traces) = outputs?;
    flush_result.context("background checkpoint flush")?;
    if let Some(w) = &writer {
        // Clean completion: drop any failure marker left by an earlier
        // run of this directory.
        w.clear_failed_marker();
    }

    // Merge values back into global id order.
    let mut values: Vec<Option<P::Value>> = vec![None; graph.num_vertices()];
    for out in &outputs {
        for (v, val) in &out.values {
            values[*v as usize] = Some(val.clone());
        }
    }
    let values: Vec<P::Value> = values
        .into_iter()
        .map(|v| v.expect("every vertex owned by exactly one worker"))
        .collect();

    let mut metrics = JobMetrics {
        load_seconds: cfg.load_seconds,
        aggregators: traces,
        ..Default::default()
    };
    let n_steps = outputs.first().map(|o| o.per_superstep.len()).unwrap_or(0);
    for s in 0..n_steps {
        let mut sm = SuperstepMetrics::default();
        let mut ck_seconds = 0.0f64;
        let mut ck_bytes = 0u64;
        for out in &outputs {
            let ws = &out.per_superstep[s];
            sm.partition_compute_seconds.push(ws.compute_seconds);
            sm.unit_times.push(ws.unit_times.clone());
            sm.messages += ws.messages;
            sm.bytes += ws.bytes;
            sm.active_units += ws.active_units;
            sm.combined_messages += ws.combined;
            // Slowest worker's own superstep clock (see metrics docs).
            sm.wall_seconds = sm.wall_seconds.max(ws.wall_seconds);
            ck_seconds = ck_seconds.max(ws.ckpt_seconds);
            ck_bytes += ws.ckpt_bytes;
        }
        if ck_bytes > 0 {
            metrics.checkpoints.push(CheckpointMetrics {
                superstep: base_superstep + s + 1,
                seconds: ck_seconds,
                bytes: ck_bytes,
            });
        }
        metrics.compute_seconds += sm.wall_seconds;
        metrics.supersteps.push(sm);
    }
    metrics.ckpt_prune_failures =
        writer.as_ref().map_or(0, |w| w.pending_prune_count() as u64);

    Ok(VertexRunResult { values, metrics })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;
    use crate::partition::{HashPartitioner, Partitioner};

    /// Max-value, vertex-centric (paper Algorithm 1).
    struct MaxValue;
    impl VertexProgram for MaxValue {
        type Msg = f32;
        type Value = f32;
        fn init(&self, vertex: VertexId, _g: &Graph) -> f32 {
            vertex as f32
        }
        fn compute(
            &self,
            value: &mut f32,
            ctx: &mut VertexContext<'_, f32>,
            msgs: &[f32],
        ) {
            let mut changed = ctx.superstep() == 1;
            for &m in msgs {
                if m > *value {
                    *value = m;
                    changed = true;
                }
            }
            if changed {
                ctx.send_to_all_undirected(*value);
            } else {
                ctx.vote_to_halt();
            }
        }
        fn combine(&self, a: &f32, b: &f32) -> Option<f32> {
            Some(a.max(*b))
        }
    }

    #[test]
    fn max_value_chain_takes_diameter_supersteps() {
        let g = gen::chain(10);
        let parts = HashPartitioner::default().partition(&g, 3);
        let res = run(&g, &parts, &MaxValue, &PregelConfig::default()).unwrap();
        assert!(res.values.iter().all(|&v| v == 9.0));
        // Value must flow 9 hops: >= diameter supersteps (plus settle).
        assert!(res.metrics.num_supersteps() >= 9, "steps={}", res.metrics.num_supersteps());
    }

    #[test]
    fn vertex_and_values_order() {
        let g = gen::star(7);
        let parts = HashPartitioner::default().partition(&g, 2);
        let res = run(&g, &parts, &MaxValue, &PregelConfig::default()).unwrap();
        assert_eq!(res.values.len(), 7);
        assert!(res.values.iter().all(|&v| v == 6.0));
    }

    #[test]
    fn combiner_reduces_message_count() {
        struct NoCombine;
        impl VertexProgram for NoCombine {
            type Msg = f32;
            type Value = f32;
            fn init(&self, v: VertexId, _g: &Graph) -> f32 {
                v as f32
            }
            fn compute(&self, value: &mut f32, ctx: &mut VertexContext<'_, f32>, msgs: &[f32]) {
                MaxValue.compute(value, ctx, msgs)
            }
        }
        let g = gen::social(300, 4, 0.0, 5);
        let parts = HashPartitioner::default().partition(&g, 2);
        let with = run(&g, &parts, &MaxValue, &PregelConfig::default()).unwrap();
        let without = run(&g, &parts, &NoCombine, &PregelConfig::default()).unwrap();
        // Same answer…
        assert_eq!(with.values, without.values);
        // …fewer (or equal) bytes on the wire with the combiner.
        assert!(with.metrics.total_bytes() <= without.metrics.total_bytes());
    }

    #[test]
    fn tcp_fabric_matches_in_proc() {
        let g = gen::grid(6, 6);
        let parts = HashPartitioner::default().partition(&g, 3);
        let a = run(&g, &parts, &MaxValue, &PregelConfig::default()).unwrap();
        let cfg_tcp = PregelConfig { fabric: FabricKind::Tcp, ..Default::default() };
        let b = run(&g, &parts, &MaxValue, &cfg_tcp).unwrap();
        assert_eq!(a.values, b.values);
    }

    #[test]
    fn single_worker() {
        let g = gen::chain(6);
        let parts = crate::partition::Partitioning::new(1, vec![0; 6]);
        let res = run(&g, &parts, &MaxValue, &PregelConfig::default()).unwrap();
        assert!(res.values.iter().all(|&v| v == 5.0));
    }

    #[test]
    fn metrics_superstep_structure() {
        let g = gen::chain(8);
        let parts = HashPartitioner::default().partition(&g, 2);
        let res = run(&g, &parts, &MaxValue, &PregelConfig::default()).unwrap();
        for sm in &res.metrics.supersteps {
            assert_eq!(sm.partition_compute_seconds.len(), 2);
        }
        assert!(res.metrics.total_messages() > 0);
    }
}
