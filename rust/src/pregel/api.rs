//! The vertex-centric programming abstraction (Pregel §3.1 of the paper).
//!
//! Since the unified job layer landed, this surface mirrors the Gopher
//! one where the models overlap: programs may register global
//! aggregators ([`VertexProgram::aggregators`], folded by the engine's
//! manager at every barrier — the same [`crate::coordinator`] machinery
//! Gopher uses), define message combiners, and emit per-vertex result
//! values ([`VertexProgram::emit`]) for `JobOutput::values`.

use anyhow::Result;

use crate::ckpt::StateCodec;
use crate::coordinator::{AggregatorSpec, Aggregators};
use crate::gopher::api::MsgCodec;
use crate::graph::csr::{Graph, VertexId};
use crate::util::codec::{Decoder, Encoder};

/// Per-(vertex, superstep) execution context.
pub struct VertexContext<'a, M> {
    pub(crate) superstep: usize,
    pub(crate) vertex: VertexId,
    pub(crate) graph: &'a Graph,
    pub(crate) out: Vec<(VertexId, M)>,
    pub(crate) halted: bool,
    /// Aggregator registry for this job (empty when none registered).
    pub(crate) aggs: &'a Aggregators,
    /// Previous superstep's folded global values (None at superstep 1).
    pub(crate) agg_global: Option<&'a [f64]>,
    /// This vertex's contributions, folded locally as they arrive.
    pub(crate) agg_local: Vec<f64>,
}

impl<'a, M: Clone> VertexContext<'a, M> {
    pub(crate) fn new(
        superstep: usize,
        vertex: VertexId,
        graph: &'a Graph,
        aggs: &'a Aggregators,
        agg_global: Option<&'a [f64]>,
    ) -> Self {
        Self {
            superstep,
            vertex,
            graph,
            out: Vec::new(),
            halted: false,
            aggs,
            agg_global,
            agg_local: aggs.identity_values(),
        }
    }

    /// Current superstep (1-based).
    pub fn superstep(&self) -> usize {
        self.superstep
    }

    /// This vertex's global id.
    pub fn vertex(&self) -> VertexId {
        self.vertex
    }

    /// Total vertices in the graph.
    pub fn num_vertices(&self) -> u64 {
        self.graph.num_vertices() as u64
    }

    /// Out-neighbours of this vertex.
    pub fn out_neighbors(&self) -> &[VertexId] {
        self.graph.out_neighbors(self.vertex)
    }

    /// Out-edges with weights.
    pub fn out_edges_weighted(&self) -> Vec<(VertexId, f32)> {
        self.graph
            .out_edges(self.vertex)
            .map(|(t, ei)| (t, self.graph.weight(ei)))
            .collect()
    }

    /// Neighbours under the undirected view (for CC-style algorithms).
    pub fn undirected_neighbors(&self) -> Vec<VertexId> {
        self.graph.undirected_neighbors(self.vertex).collect()
    }

    /// Out-degree of this vertex.
    pub fn out_degree(&self) -> usize {
        self.graph.out_degree(self.vertex)
    }

    /// The underlying (shared, read-only) graph.
    pub fn graph(&self) -> &'a Graph {
        self.graph
    }

    /// Send a message to a vertex (delivered next superstep).
    pub fn send_to(&mut self, target: VertexId, payload: M) {
        self.out.push((target, payload));
    }

    /// `SendToAllNeighbors` of the paper's Algorithm 1 (out-edges).
    pub fn send_to_all_neighbors(&mut self, payload: M) {
        let targets: Vec<VertexId> = self.graph.out_neighbors(self.vertex).to_vec();
        for t in targets {
            self.out.push((t, payload.clone()));
        }
    }

    /// Send across the undirected view (out ∪ in neighbours).
    pub fn send_to_all_undirected(&mut self, payload: M) {
        let targets: Vec<VertexId> =
            self.graph.undirected_neighbors(self.vertex).collect();
        for t in targets {
            self.out.push((t, payload.clone()));
        }
    }

    /// Vote to halt (reactivated by incoming messages).
    pub fn vote_to_halt(&mut self) {
        self.halted = true;
    }

    /// Slot index of a named aggregator registered by the program.
    pub fn aggregator(&self, name: &str) -> Option<usize> {
        self.aggs.index_of(name)
    }

    /// Contribute to aggregator slot `idx`; contributions fold with the
    /// slot's monoid, worker-locally first and globally at the barrier.
    pub fn aggregate(&mut self, idx: usize, value: f64) {
        let op = self.aggs.specs()[idx].op;
        self.agg_local[idx] = op.fold(self.agg_local[idx], value);
    }

    /// The global value of aggregator slot `idx` folded at the end of
    /// the *previous* superstep. `None` during superstep 1.
    pub fn aggregated(&self, idx: usize) -> Option<f64> {
        self.agg_global.map(|g| g[idx])
    }
}

/// A vertex-centric program.
///
/// `Value: StateCodec` is the fault-tolerance contract shared with the
/// Gopher surface: the default [`VertexProgram::save_state`] /
/// [`VertexProgram::restore_state`] hooks checkpoint any value-only
/// vertex state with zero per-program code (see [`crate::ckpt`]).
pub trait VertexProgram: Sync {
    type Msg: MsgCodec + Clone + Send + Sync + 'static;
    type Value: StateCodec + Clone + Send + 'static;

    /// Initial vertex value (before superstep 1).
    fn init(&self, vertex: VertexId, graph: &Graph) -> Self::Value;

    /// One superstep for one vertex.
    fn compute(
        &self,
        value: &mut Self::Value,
        ctx: &mut VertexContext<'_, Self::Msg>,
        msgs: &[Self::Msg],
    );

    /// Optional Giraph-style combiner: fold two messages headed to the
    /// same vertex into one. Return `None` (default) to disable.
    fn combine(&self, _a: &Self::Msg, _b: &Self::Msg) -> Option<Self::Msg> {
        None
    }

    /// Global aggregators this program uses. Folded by the engine's
    /// manager at every superstep barrier (the coordinator layer shared
    /// with Gopher); read back via [`VertexContext::aggregated`] the
    /// following superstep.
    fn aggregators(&self) -> Vec<AggregatorSpec> {
        Vec::new()
    }

    /// Per-vertex result extraction for the unified job layer
    /// ([`crate::job`]): map this vertex's final value to
    /// `(global vertex id, value)` pairs (usually exactly one). The
    /// default (empty) opts the program out of per-vertex output.
    fn emit(&self, _vertex: VertexId, _value: &Self::Value) -> Vec<(VertexId, f64)> {
        Vec::new()
    }

    /// Serialize one vertex's value into a checkpoint
    /// ([`crate::ckpt`]). Default: the value's [`StateCodec`] encoding.
    fn save_state(&self, value: &Self::Value, e: &mut Encoder) {
        value.encode_state(e)
    }

    /// Rebuild one vertex's value from a checkpoint; must consume
    /// exactly what [`VertexProgram::save_state`] wrote and reproduce
    /// the value bit-exactly (the recovery-parity contract).
    fn restore_state(
        &self,
        _vertex: VertexId,
        _graph: &Graph,
        d: &mut Decoder,
    ) -> Result<Self::Value> {
        Self::Value::decode_state(d)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;

    #[test]
    fn context_surfaces_topology() {
        let g = gen::chain(5); // undirected chain stored as i -> i+1
        let aggs = Aggregators::default();
        let mut ctx = VertexContext::<u32>::new(1, 2, &g, &aggs, None);
        assert_eq!(ctx.out_neighbors(), &[3]);
        assert_eq!(ctx.undirected_neighbors(), vec![3, 1]);
        assert_eq!(ctx.num_vertices(), 5);
        ctx.send_to_all_undirected(9);
        assert_eq!(ctx.out.len(), 2);
        ctx.send_to(0, 1);
        assert_eq!(ctx.out.last().unwrap(), &(0, 1));
        assert!(!ctx.halted);
        ctx.vote_to_halt();
        assert!(ctx.halted);
    }

    #[test]
    fn weighted_edges_surface() {
        let g = crate::graph::csr::Graph::from_edges(
            3,
            &[(0, 1), (0, 2)],
            Some(vec![1.5, 2.5]),
            true,
        )
        .unwrap();
        let aggs = Aggregators::default();
        let ctx = VertexContext::<u32>::new(1, 0, &g, &aggs, None);
        assert_eq!(ctx.out_edges_weighted(), vec![(1, 1.5), (2, 2.5)]);
    }

    #[test]
    fn context_aggregator_surface() {
        use crate::coordinator::AggOp;
        let g = gen::chain(3);
        let aggs = Aggregators::new(vec![
            AggregatorSpec::new("delta", AggOp::Sum),
            AggregatorSpec::new("low", AggOp::Min),
        ]);

        // Superstep 1: nothing folded yet; contributions fold locally.
        let mut ctx = VertexContext::<u32>::new(1, 0, &g, &aggs, None);
        assert_eq!(ctx.aggregator("delta"), Some(0));
        assert_eq!(ctx.aggregator("nope"), None);
        assert_eq!(ctx.aggregated(0), None);
        ctx.aggregate(0, 2.0);
        ctx.aggregate(0, 3.0);
        ctx.aggregate(1, 7.0);
        ctx.aggregate(1, 4.0);
        assert_eq!(ctx.agg_local, vec![5.0, 4.0]);

        // Superstep 2: folded globals are visible.
        let global = vec![5.0, 4.0];
        let ctx2 = VertexContext::<u32>::new(2, 0, &g, &aggs, Some(&global));
        assert_eq!(ctx2.aggregated(0), Some(5.0));
        assert_eq!(ctx2.aggregated(1), Some(4.0));
    }
}
