//! The vertex-centric programming abstraction (Pregel §3.1 of the paper).

use crate::gopher::api::MsgCodec;
use crate::graph::csr::{Graph, VertexId};

/// Per-(vertex, superstep) execution context.
pub struct VertexContext<'a, M> {
    pub(crate) superstep: usize,
    pub(crate) vertex: VertexId,
    pub(crate) graph: &'a Graph,
    pub(crate) out: Vec<(VertexId, M)>,
    pub(crate) halted: bool,
}

impl<'a, M: Clone> VertexContext<'a, M> {
    pub(crate) fn new(superstep: usize, vertex: VertexId, graph: &'a Graph) -> Self {
        Self { superstep, vertex, graph, out: Vec::new(), halted: false }
    }

    /// Current superstep (1-based).
    pub fn superstep(&self) -> usize {
        self.superstep
    }

    /// This vertex's global id.
    pub fn vertex(&self) -> VertexId {
        self.vertex
    }

    /// Total vertices in the graph.
    pub fn num_vertices(&self) -> u64 {
        self.graph.num_vertices() as u64
    }

    /// Out-neighbours of this vertex.
    pub fn out_neighbors(&self) -> &[VertexId] {
        self.graph.out_neighbors(self.vertex)
    }

    /// Out-edges with weights.
    pub fn out_edges_weighted(&self) -> Vec<(VertexId, f32)> {
        self.graph
            .out_edges(self.vertex)
            .map(|(t, ei)| (t, self.graph.weight(ei)))
            .collect()
    }

    /// Neighbours under the undirected view (for CC-style algorithms).
    pub fn undirected_neighbors(&self) -> Vec<VertexId> {
        self.graph.undirected_neighbors(self.vertex).collect()
    }

    /// Out-degree of this vertex.
    pub fn out_degree(&self) -> usize {
        self.graph.out_degree(self.vertex)
    }

    /// The underlying (shared, read-only) graph.
    pub fn graph(&self) -> &'a Graph {
        self.graph
    }

    /// Send a message to a vertex (delivered next superstep).
    pub fn send_to(&mut self, target: VertexId, payload: M) {
        self.out.push((target, payload));
    }

    /// `SendToAllNeighbors` of the paper's Algorithm 1 (out-edges).
    pub fn send_to_all_neighbors(&mut self, payload: M) {
        let targets: Vec<VertexId> = self.graph.out_neighbors(self.vertex).to_vec();
        for t in targets {
            self.out.push((t, payload.clone()));
        }
    }

    /// Send across the undirected view (out ∪ in neighbours).
    pub fn send_to_all_undirected(&mut self, payload: M) {
        let targets: Vec<VertexId> =
            self.graph.undirected_neighbors(self.vertex).collect();
        for t in targets {
            self.out.push((t, payload.clone()));
        }
    }

    /// Vote to halt (reactivated by incoming messages).
    pub fn vote_to_halt(&mut self) {
        self.halted = true;
    }
}

/// A vertex-centric program.
pub trait VertexProgram: Sync {
    type Msg: MsgCodec + Clone + Send + Sync + 'static;
    type Value: Clone + Send + 'static;

    /// Initial vertex value (before superstep 1).
    fn init(&self, vertex: VertexId, graph: &Graph) -> Self::Value;

    /// One superstep for one vertex.
    fn compute(
        &self,
        value: &mut Self::Value,
        ctx: &mut VertexContext<'_, Self::Msg>,
        msgs: &[Self::Msg],
    );

    /// Optional Giraph-style combiner: fold two messages headed to the
    /// same vertex into one. Return `None` (default) to disable.
    fn combine(&self, _a: &Self::Msg, _b: &Self::Msg) -> Option<Self::Msg> {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;

    #[test]
    fn context_surfaces_topology() {
        let g = gen::chain(5); // undirected chain stored as i -> i+1
        let mut ctx = VertexContext::<u32>::new(1, 2, &g);
        assert_eq!(ctx.out_neighbors(), &[3]);
        assert_eq!(ctx.undirected_neighbors(), vec![3, 1]);
        assert_eq!(ctx.num_vertices(), 5);
        ctx.send_to_all_undirected(9);
        assert_eq!(ctx.out.len(), 2);
        ctx.send_to(0, 1);
        assert_eq!(ctx.out.last().unwrap(), &(0, 1));
        assert!(!ctx.halted);
        ctx.vote_to_halt();
        assert!(ctx.halted);
    }

    #[test]
    fn weighted_edges_surface() {
        let g = crate::graph::csr::Graph::from_edges(
            3,
            &[(0, 1), (0, 2)],
            Some(vec![1.5, 2.5]),
            true,
        )
        .unwrap();
        let ctx = VertexContext::<u32>::new(1, 0, &g);
        assert_eq!(ctx.out_edges_weighted(), vec![(1, 1.5), (2, 2.5)]);
    }
}
