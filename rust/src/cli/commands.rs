//! CLI command implementations.
//!
//! ```text
//! goffish gen       --kind road|trace|social|er|grid|chain --out g.txt [--scale N] [--seed S]
//! goffish info      --graph g.txt [--directed]
//! goffish partition --graph g.txt --k 4 [--strategy multilevel|hash|range]
//! goffish store     --graph g.txt --k 4 --out storedir [--strategy …] [--name NAME]
//!                   [--format v1|v2|v3] [--attrs N]
//! goffish store verify [--store storedir] [--ckpt ckptdir]
//! goffish store migrate --store storedir
//! goffish ingest    --edges edges.tsv --store storedir [--hosts H]
//!                   [--format v1|v2|v3] [--name NAME] [--directed]
//!                   [--spill-buffer BYTES] [--seed S] [--trace t.json]
//! goffish serve     --store storedir [--port P] [--workers N] [--queue N]
//!                   [--cores N] [--keep-results N] [--access-log]
//! goffish run       --store storedir
//!                   --algo <any algos::registry entry>
//!                   [--engine gopher|vertex] [--source V] [--supersteps N]
//!                   [--epsilon E] [--no-combine] [--max-supersteps N]
//!                   [--no-mmap] [--no-dense-index]
//!                   [--xla] [--fabric inproc|tcp] [--cores N]
//!                   [--load-attributes a,b] [--output values.tsv]
//!                   [--checkpoint-every N --checkpoint-dir D]
//!                   [--checkpoint-mode sync|async] [--checkpoint-compress]
//!                   [--resume D [--confined-recovery]]
//!                   [--kill-at S [--kill-worker W]] [--trace t.json]
//! ```
//!
//! Observability (`docs/OBSERVABILITY.md`): `run --trace t.json` and
//! `ingest --trace t.json` write a Chrome trace-event timeline of the
//! run (load/superstep phases per worker, checkpoints, ingest passes —
//! open it in Perfetto); `serve --access-log` prints one line per HTTP
//! request, and `GET /v1/metrics?format=prometheus` on a running server
//! exposes live counters/gauges/histograms for scrapers.
//!
//! `store --format` picks the on-disk layout (v2 columnar default; v1
//! for compat tooling; v3 packs each partition into a single
//! seek-skippable `partition.gfsp`) and `--attrs N` writes N synthetic
//! per-vertex attribute columns (`attr0..attrN-1`, value = global
//! vertex id) so the paper's "10 attributes, load one" scenario is
//! reproducible from the CLI: `run --load-attributes attr0` then reads
//! exactly that column — on a v3 store the loader physically seeks
//! past the other nine.
//!
//! `store verify` is the checksum scrubber: it validates every section
//! of every slice in a GoFS store (`--store`) and/or every snapshot of
//! a checkpoint directory (`--ckpt`), reporting corrupt sections by
//! name and exiting non-zero if anything rotted.
//!
//! `store migrate` rewrites a v1/v2 store as packed v3 in place
//! ([`Store::migrate_to_packed`]) — decode *is* checksum verification,
//! and the result is scrubbed again before the command reports clean.
//! A v3 store is a no-op. Packed stores are the appendable ones, so
//! migrate is the upgrade path onto `Store::append` / `goffish ingest`
//! generations.
//!
//! `ingest` streams a TSV/CSV edge list into a GoFS store under a
//! bounded memory budget (`--spill-buffer`, default 64 MiB): edges are
//! hash-partitioned online, spilled to per-host run files as the
//! buffer fills, and merged per host into sub-graph slices. The result
//! is byte-identical to `gen`→`store --strategy hash` of the same
//! list (see [`crate::ingest`] for why).
//!
//! `run` is a thin shell over the unified job layer: flags are handed
//! to [`Job::builder`], validation (unknown algorithms, engine/knob
//! mismatches like `--epsilon` on the vertex engine, inconsistent
//! checkpoint knobs, unrecoverable `--resume` targets) happens in
//! `build()` with typed errors, and the algorithm dispatch itself lives
//! in [`crate::algos::registry`] — adding an algorithm requires no CLI
//! edits beyond its registry entry. `--output` dumps the uniform
//! `JobOutput::values` as `vertex<TAB>value` lines.
//!
//! `serve` loads the store once and keeps it resident behind a small
//! HTTP/1.1 job API on 127.0.0.1 (submit, poll, page results, cancel);
//! see `docs/API.md` for the endpoint reference and
//! [`crate::serve`] for the architecture. Results fetched with
//! `?format=tsv` are byte-identical to `run --output` for the same job.
//!
//! Fault tolerance: `--checkpoint-every N --checkpoint-dir D` snapshots
//! every N supersteps; after a crash, `run --resume D` restarts from
//! the latest valid committed epoch (and keeps checkpointing into `D`
//! when `--checkpoint-every` is also given). `--checkpoint-mode async`
//! double-buffers the snapshot at the barrier and persists it on a
//! background flusher thread (sync, the default, pays the write inside
//! the barrier); `--checkpoint-compress` run-length packs the section
//! bodies. `--resume D --confined-recovery` restarts only the worker
//! named by the directory's `FAILED_WORKER` marker, replaying its
//! in-flight messages from the surviving senders' logs — output stays
//! byte-identical to a global rollback. `--kill-at S` is the
//! failure-injection hook (kills worker `--kill-worker`, default 0, at
//! superstep S) driving the kill-and-resume smoke tests.

use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::algos::pagerank::RankKernel;
use crate::algos::registry;
use crate::ckpt;
use crate::gofs::{SliceFormat, Store};
use crate::gopher::FabricKind;
use crate::ingest::{ingest_edge_list, IngestOptions};
use crate::graph::{gen, io, props, Graph};
use crate::job::{EngineKind, Job, JobSource};
use crate::partition::{
    HashPartitioner, MultilevelPartitioner, Partitioner, RangePartitioner,
};
use crate::runtime::XlaEngine;

use super::args::Args;

pub fn dispatch(argv: Vec<String>) -> Result<()> {
    let args = Args::parse(&argv);
    match args.command().unwrap_or("help") {
        "gen" => cmd_gen(&args),
        "info" => cmd_info(&args),
        "partition" => cmd_partition(&args),
        "store" if args.positional.get(1).map(String::as_str) == Some("verify") => {
            cmd_store_verify(&args)
        }
        "store" if args.positional.get(1).map(String::as_str) == Some("migrate") => {
            cmd_store_migrate(&args)
        }
        "store" => cmd_store(&args),
        "ingest" => cmd_ingest(&args),
        "run" => cmd_run(&args),
        "serve" => cmd_serve(&args),
        "algos" => cmd_algos(),
        "help" | "--help" | "-h" => {
            print!("{}", HELP);
            Ok(())
        }
        other => bail!("unknown command {other:?}; try `goffish help`"),
    }
}

const HELP: &str = r#"goffish — sub-graph centric graph analytics (GoFFish reproduction)

commands:
  gen          generate a synthetic dataset analog to an edge list
  info         structural properties of a graph (the Table-1 row)
  partition    partition a graph and report cut metrics
  store        build a GoFS store directory (partition + sub-graph slices)
  store verify checksum-scrub a store (--store) and/or checkpoint dir (--ckpt)
  store migrate  rewrite a v1/v2 store as packed v3 in place (re-verified)
  ingest       stream an edge list into a GoFS store with bounded memory
               (--spill-buffer; byte-identical to the batch store path)
  run          execute an algorithm with Gopher or the vertex baseline
               (checkpoint with --checkpoint-every/--checkpoint-dir, plus
               --checkpoint-mode sync|async and --checkpoint-compress;
               recover with --resume [--confined-recovery]; --trace t.json
               writes a Chrome-trace timeline)
  serve        resident job server: load a store once, accept jobs over
               an HTTP API (see docs/API.md; --access-log prints request
               lines, /v1/metrics?format=prometheus exposes live metrics)
  algos        per-engine algorithm support matrix
  help         this message

see rust/src/cli/commands.rs for per-command flags.
"#;

fn load_graph(args: &Args) -> Result<Graph> {
    let path = args.require("graph")?;
    io::read_edge_list(Path::new(path), args.flag("directed"))
}

fn make_partitioner(args: &Args) -> Result<Box<dyn Partitioner>> {
    Ok(match args.get_or("strategy", "multilevel") {
        "multilevel" => Box::new(MultilevelPartitioner::new(args.get_u64("seed", 1)?)),
        "hash" => Box::new(HashPartitioner::new(args.get_u64("seed", 1)?)),
        "range" => Box::new(RangePartitioner),
        s => bail!("unknown strategy {s:?}"),
    })
}

fn cmd_gen(args: &Args) -> Result<()> {
    let kind = args.get_or("kind", "road");
    let scale = args.get_usize("scale", 100)?;
    let seed = args.get_u64("seed", 42)?;
    let g = match kind {
        "road" => gen::road(scale, 0.97, 0.005, seed),
        "trace" => gen::trace(scale * scale, scale.max(8), 0.15, seed),
        "social" => gen::social(scale * scale, 8, 0.02, seed),
        "er" => gen::erdos_renyi(scale * scale, args.get_f64("p", 0.001)?, true, seed),
        "grid" => gen::grid(scale, scale),
        "chain" => gen::chain(scale * scale),
        k => bail!("unknown kind {k:?}"),
    };
    let g = if args.flag("weighted") {
        gen::with_random_weights(&g, 1.0, 10.0, seed ^ 0x57EED)
    } else {
        g
    };
    let out = args.require("out")?;
    io::write_edge_list(&g, Path::new(out))?;
    println!(
        "wrote {} ({} vertices, {} edges) to {out}",
        kind,
        g.num_vertices(),
        g.num_edges()
    );
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let g = load_graph(args)?;
    let stats = props::degree_stats(&g);
    println!("vertices  {}", g.num_vertices());
    println!("edges     {}", g.num_edges());
    println!("directed  {}", g.directed());
    println!("weighted  {}", g.has_weights());
    println!("wcc       {}", props::wcc_count(&g));
    println!(
        "diameter  {} (double-sweep estimate)",
        props::diameter_estimate(&g, 4, 7)
    );
    println!(
        "degree    min={} max={} mean={:.2}",
        stats.min, stats.max, stats.mean
    );
    Ok(())
}

fn cmd_partition(args: &Args) -> Result<()> {
    let g = load_graph(args)?;
    let k = args.get_usize("k", 4)?;
    let partitioner = make_partitioner(args)?;
    let p = partitioner.partition(&g, k);
    let m = p.metrics(&g);
    println!("strategy     {}", partitioner.name());
    println!("k            {k}");
    println!("edge cut     {} ({:.1}%)", m.edge_cut, m.cut_fraction * 100.0);
    println!("imbalance    {:.3}", m.imbalance);
    println!("sizes        {:?}", m.sizes);
    Ok(())
}

fn cmd_store(args: &Args) -> Result<()> {
    let g = load_graph(args)?;
    let k = args.get_usize("k", 4)?;
    let out = args.require("out")?;
    let name = args.get_or("name", "graph");
    let fmt_arg = args.get_or("format", "v2");
    let format = SliceFormat::parse(fmt_arg)
        .with_context(|| format!("--format expects v1, v2 or v3, got {fmt_arg:?}"))?;
    let num_attrs = args.get_usize("attrs", 0)?;
    let partitioner = make_partitioner(args)?;
    let p = partitioner.partition(&g, k);
    let (store, dg) = Store::create_with_format(Path::new(out), name, &g, &p, format)?;
    // Synthetic attribute columns for projection experiments: attrN
    // holds each vertex's global id (deterministic, so outputs compare
    // across formats). One batch write: a packed store rewrites each
    // partition file once, not once per column.
    let mut attr_items = Vec::new();
    for sg in dg.subgraphs() {
        let vals: Vec<f32> = sg.vertices.iter().map(|&v| v as f32).collect();
        for a in 0..num_attrs {
            attr_items.push((sg.id, format!("attr{a}"), vals.clone()));
        }
    }
    store.write_attributes(&attr_items)?;
    println!(
        "stored {} ({}) as {} partitions / {} sub-graphs / {} attribute columns at {}",
        name,
        format,
        k,
        dg.num_subgraphs(),
        dg.num_subgraphs() * num_attrs,
        store.root().display()
    );
    for (i, sgs) in dg.partitions.iter().enumerate() {
        let sizes: Vec<usize> = sgs.iter().map(|s| s.num_vertices()).collect();
        println!("  host{i}: {} sub-graphs, sizes {:?}", sgs.len(), sizes);
    }
    Ok(())
}

/// Registry-driven per-engine support matrix: one column per engine,
/// so a gopher-only algorithm (e.g. `blockrank`) is visible at a
/// glance instead of hiding in a combined "engines" string.
fn cmd_algos() -> Result<()> {
    let mark = |present: bool| if present { "yes" } else { "-" };
    println!("{:<11} {:<7} {:<7} description", "algorithm", "gopher", "vertex");
    for e in registry::entries() {
        println!(
            "{:<11} {:<7} {:<7} {}",
            e.name,
            mark(e.gopher.is_some()),
            mark(e.vertex.is_some()),
            e.description
        );
    }
    Ok(())
}

/// `store verify`: full checksum scrub of a GoFS store
/// ([`Store::scrub`]) and/or a checkpoint directory
/// (`ckpt::scrub_dir`), reporting corrupt sections by name (the
/// ROADMAP "background checksum scrubbing" item in its on-demand form).
fn cmd_store_verify(args: &Args) -> Result<()> {
    let store_dir = args.get("store");
    let ckpt_dir = args.get("ckpt");
    if store_dir.is_none() && ckpt_dir.is_none() {
        bail!("store verify needs --store <dir> and/or --ckpt <dir>");
    }
    let mut sum = crate::gofs::section::ScrubSummary::default();

    if let Some(root) = store_dir {
        let store = Store::open(Path::new(root))?;
        sum.absorb(store.scrub()?, "store ");
        println!(
            "store {root} ({}, {} partitions) scrubbed",
            store.meta().format,
            store.meta().num_partitions
        );
    }

    if let Some(dir) = ckpt_dir {
        sum.absorb(ckpt::scrub_dir(Path::new(dir))?, "ckpt ");
        println!("checkpoint dir {dir} scrubbed");
    }

    println!("checked {} files / {} sections", sum.files, sum.sections);
    if sum.is_clean() {
        println!("all sections clean");
        Ok(())
    } else {
        for c in &sum.corrupt {
            println!("CORRUPT {c}");
        }
        bail!("{} corrupt section(s)", sum.corrupt.len())
    }
}

/// `store migrate`: in-place v1/v2 → packed v3 rewrite. Decoding every
/// slice during the rewrite re-verifies every checksum; the resulting
/// packed store is scrubbed once more before reporting clean.
fn cmd_store_migrate(args: &Args) -> Result<()> {
    let root = args.require("store")?;
    let before = Store::open(Path::new(root))?.meta().format;
    let store = Store::migrate_to_packed(Path::new(root))?;
    if before == SliceFormat::V3Packed {
        println!("store {root} is already packed (v3); nothing to migrate");
        return Ok(());
    }
    let sum = store.scrub()?;
    if !sum.is_clean() {
        for c in &sum.corrupt {
            println!("CORRUPT {c}");
        }
        bail!("{} corrupt section(s) after migration", sum.corrupt.len());
    }
    println!(
        "migrated {root} from {before} to {} ({} partitions, {} files / {} sections re-verified clean)",
        store.meta().format,
        store.meta().num_partitions,
        sum.files,
        sum.sections
    );
    Ok(())
}

/// `ingest`: stream an edge list into a GoFS store under a bounded
/// memory budget. The heavy lifting (online partitioning, spill/merge,
/// incremental partition writes) lives in [`crate::ingest`].
fn cmd_ingest(args: &Args) -> Result<()> {
    let edges = args.require("edges")?;
    let store_root = args.require("store")?;
    let hosts_raw = args.get_usize("hosts", 2)?;
    let hosts = u32::try_from(hosts_raw)
        .with_context(|| format!("--hosts expects a small integer, got {hosts_raw}"))?;
    let fmt_arg = args.get_or("format", "v3");
    let format = SliceFormat::parse(fmt_arg)
        .with_context(|| format!("--format expects v1, v2 or v3, got {fmt_arg:?}"))?;
    let trace_path = args.get("trace");
    let opts = IngestOptions {
        name: args.get_or("name", "graph").to_string(),
        hosts,
        format,
        directed: args.flag("directed"),
        spill_buffer: args.get_usize("spill-buffer", 64 << 20)?,
        seed: args.get_u64("seed", 1)?,
        trace: if trace_path.is_some() {
            crate::obs::trace::Tracer::enabled()
        } else {
            crate::obs::trace::Tracer::default()
        },
    };
    let (store, report) =
        ingest_edge_list(Path::new(edges), Path::new(store_root), &opts)?;
    if let Some(path) = trace_path {
        opts.trace.write_file(Path::new(path))?;
        println!("wrote ingest trace to {path} (load it in Perfetto)");
    }
    println!(
        "ingested {edges} into {} ({}, {} hosts): {} vertices / {} edges / {} sub-graphs in {:.3}s",
        store.root().display(),
        format,
        hosts,
        report.vertices,
        report.edges,
        report.subgraphs,
        report.seconds,
    );
    println!(
        "  spills {} ({} bytes over {} run files, {} byte buffer)",
        report.spills, report.spilled_bytes, report.runs, opts.spill_buffer
    );
    Ok(())
}

/// The single algorithm dispatch path: flags → `Job::builder()` →
/// registry-driven run. No per-algorithm logic lives here.
fn cmd_run(args: &Args) -> Result<()> {
    let store = Store::open(Path::new(args.require("store")?))?;
    let algo = args.get_or("algo", "cc");
    let engine = match args.get_or("engine", "gopher") {
        "gopher" => EngineKind::Gopher,
        "vertex" => EngineKind::Vertex,
        e => bail!("unknown engine {e:?}"),
    };
    let fabric = match args.get_or("fabric", "inproc") {
        "inproc" => FabricKind::InProc,
        "tcp" => FabricKind::Tcp,
        f => bail!("unknown fabric {f:?}"),
    };
    let kernel = if args.flag("xla") {
        RankKernel::Xla(Arc::new(XlaEngine::load_default()?))
    } else {
        RankKernel::Scalar
    };
    let epsilon = match args.get("epsilon") {
        Some(s) => Some(
            s.parse::<f32>()
                .with_context(|| format!("--epsilon expects a number, got {s:?}"))?,
        ),
        None => None,
    };

    let mut builder = Job::builder()
        .algo(algo)
        .engine(engine)
        .fabric(fabric)
        .cores(args.get_usize("cores", 4)?)
        .source_vertex(args.get_usize("source", 0)? as u32)
        .supersteps(args.get_usize("supersteps", 30)?)
        .max_supersteps(args.get_usize("max-supersteps", 10_000)?)
        .kernel(kernel)
        .load_attributes(args.get_list("load-attributes"));
    if let Some(eps) = epsilon {
        builder = builder.epsilon(eps);
    }
    if args.flag("no-combine") {
        builder = builder.combiners(false);
    }
    // Raw-speed knobs, on by default: `--no-mmap` forces the packed
    // store's seek+read load path, `--no-dense-index` the sorted
    // vertex-lookup fallback. Neither affects results (the CI smoke
    // `cmp`s the TSVs); both exist for A/B runs and debugging.
    if args.flag("no-mmap") {
        builder = builder.mmap(false);
    }
    if args.flag("no-dense-index") {
        builder = builder.dense_index(false);
    }
    // Fault-tolerance knobs: checkpoint cadence/target, resume target,
    // and the failure-injection hook (validated in build(), like
    // everything else).
    if let Some(s) = args.get("checkpoint-every") {
        let n = s
            .parse::<usize>()
            .with_context(|| format!("--checkpoint-every expects an integer, got {s:?}"))?;
        builder = builder.checkpoint_every(n);
    }
    if let Some(dir) = args.get("checkpoint-dir") {
        builder = builder.checkpoint_dir(dir);
    }
    if let Some(s) = args.get("checkpoint-mode") {
        builder = builder.checkpoint_mode(s.parse()?);
    }
    if args.flag("checkpoint-compress") {
        builder = builder.checkpoint_compress(true);
    }
    if let Some(dir) = args.get("resume") {
        builder = builder.resume_from(dir);
    }
    if args.flag("confined-recovery") {
        builder = builder.confined_recovery(true);
    }
    if let Some(s) = args.get("kill-at") {
        let superstep = s
            .parse::<usize>()
            .with_context(|| format!("--kill-at expects a superstep number, got {s:?}"))?;
        let worker = args.get_usize("kill-worker", 0)? as u32;
        builder = builder.kill_at(superstep, worker);
    }
    // Observability knob: record per-worker load/superstep-phase/
    // checkpoint spans and write them as a Chrome trace-event timeline.
    // Never affects results (spans only observe the run).
    if let Some(path) = args.get("trace") {
        builder = builder.trace(path);
    }
    // Knob/engine validation happens here, with typed errors (e.g.
    // `--epsilon` or `--no-combine` on the vertex engine).
    let job = builder.build()?;

    let out = job.run(JobSource::Store(&store))?;
    println!("{}", out.metrics.report(&format!("{engine}/{algo}")));
    if let Some(path) = args.get("trace") {
        println!("wrote trace to {path} (load it in Perfetto)");
    }
    for trace in &out.aggregators {
        println!(
            "  aggregator {}: last={:?} over {} supersteps",
            trace.name,
            trace.last(),
            trace.values.len()
        );
    }
    for c in &out.metrics.checkpoints {
        println!(
            "  checkpoint epoch {}: {:.4}s, {} bytes",
            c.superstep, c.seconds, c.bytes
        );
    }
    if let Some(path) = args.get("output") {
        write_values_tsv(Path::new(path), &out.values)?;
        println!("wrote {} vertex values to {path}", out.values.len());
    }
    Ok(())
}

/// `serve`: load the store once, then run jobs submitted over HTTP
/// against the resident graph until the process is killed. The two
/// `println!`s below are the startup handshake the CI smoke waits on.
fn cmd_serve(args: &Args) -> Result<()> {
    let root = args.require("store")?;
    let resident = crate::serve::ResidentGraph::open(Path::new(root))?;
    let port_raw = args.get_usize("port", 8080)?;
    let port = u16::try_from(port_raw)
        .with_context(|| format!("--port expects 0..=65535, got {port_raw}"))?;
    let keep_results = match args.get("keep-results") {
        None => None,
        Some(raw) => Some(raw.parse::<usize>().with_context(|| {
            format!("--keep-results expects a non-negative integer, got {raw:?}")
        })?),
    };
    let opts = crate::serve::ServeOptions {
        port,
        workers: args.get_usize("workers", 2)?,
        queue: args.get_usize("queue", 16)?,
        cores: args.get_usize("cores", 4)?,
        keep_results,
        access_log: args.flag("access-log"),
    };
    let snap = resident.snapshot();
    println!(
        "loaded {} ({}, {} partitions / {} sub-graphs / {} vertices / {} edges, generation {}) in {:.3}s",
        snap.store().meta().name,
        snap.store().meta().format,
        snap.store().meta().num_partitions,
        snap.graph().num_subgraphs(),
        snap.store().meta().num_vertices,
        snap.store().meta().num_edges,
        snap.store().meta().generation,
        snap.load().seconds,
    );
    drop(snap);
    let server = crate::serve::Server::start(resident, &opts)?;
    println!("serving on http://{}", server.addr());
    server.serve_forever();
    Ok(())
}

/// Dump per-vertex job values as `vertex<TAB>value` lines.
fn write_values_tsv(path: &Path, values: &[(u32, f64)]) -> Result<()> {
    use std::io::Write;
    let file = std::fs::File::create(path)
        .with_context(|| format!("create {}", path.display()))?;
    let mut w = std::io::BufWriter::new(file);
    for (v, x) in values {
        writeln!(w, "{v}\t{x}")?;
    }
    w.flush()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run_cmd(argv: &[&str]) -> Result<()> {
        dispatch(argv.iter().map(|s| s.to_string()).collect())
    }

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir()
            .join("goffish_cli")
            .join(format!("{name}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn gen_info_partition_store_run_pipeline() {
        let dir = tmp("pipeline");
        let graph = dir.join("g.txt");
        let store = dir.join("store");
        run_cmd(&["gen", "--kind", "road", "--scale", "14", "--out", graph.to_str().unwrap()])
            .unwrap();
        run_cmd(&["info", "--graph", graph.to_str().unwrap()]).unwrap();
        run_cmd(&["partition", "--graph", graph.to_str().unwrap(), "--k", "3"]).unwrap();
        run_cmd(&[
            "store",
            "--graph",
            graph.to_str().unwrap(),
            "--k",
            "3",
            "--out",
            store.to_str().unwrap(),
        ])
        .unwrap();
        run_cmd(&["run", "--store", store.to_str().unwrap(), "--algo", "cc"]).unwrap();
        run_cmd(&[
            "run",
            "--store",
            store.to_str().unwrap(),
            "--algo",
            "sssp",
            "--engine",
            "vertex",
        ])
        .unwrap();
        // Coordinator knobs: combiner off, aggregator-driven PageRank,
        // and label propagation — on both engines now.
        run_cmd(&[
            "run",
            "--store",
            store.to_str().unwrap(),
            "--algo",
            "sssp",
            "--no-combine",
        ])
        .unwrap();
        run_cmd(&[
            "run",
            "--store",
            store.to_str().unwrap(),
            "--algo",
            "pagerank",
            "--epsilon",
            "0.01",
            "--supersteps",
            "60",
        ])
        .unwrap();
        run_cmd(&["run", "--store", store.to_str().unwrap(), "--algo", "labelprop"])
            .unwrap();
        run_cmd(&[
            "run",
            "--store",
            store.to_str().unwrap(),
            "--algo",
            "labelprop",
            "--engine",
            "vertex",
        ])
        .unwrap();
        run_cmd(&["algos"]).unwrap();
    }

    #[test]
    fn run_trace_flag_writes_chrome_trace() {
        let dir = tmp("trace_flag");
        let graph = dir.join("g.txt");
        let store = dir.join("store");
        let trace = dir.join("t.json");
        run_cmd(&["gen", "--kind", "chain", "--scale", "4", "--out", graph.to_str().unwrap()])
            .unwrap();
        run_cmd(&[
            "store",
            "--graph",
            graph.to_str().unwrap(),
            "--k",
            "2",
            "--out",
            store.to_str().unwrap(),
        ])
        .unwrap();
        run_cmd(&[
            "run",
            "--store",
            store.to_str().unwrap(),
            "--algo",
            "cc",
            "--trace",
            trace.to_str().unwrap(),
        ])
        .unwrap();
        let text = std::fs::read_to_string(&trace).unwrap();
        let v = crate::serve::json::JsonValue::parse(&text).unwrap();
        let rows = v.get("traceEvents").unwrap().as_array().unwrap();
        assert!(!rows.is_empty(), "trace file holds no events");
        // The ingest flavour writes one too.
        let streamed = dir.join("streamed");
        let itrace = dir.join("ingest.json");
        run_cmd(&[
            "ingest",
            "--edges",
            graph.to_str().unwrap(),
            "--store",
            streamed.to_str().unwrap(),
            "--trace",
            itrace.to_str().unwrap(),
        ])
        .unwrap();
        let text = std::fs::read_to_string(&itrace).unwrap();
        let v = crate::serve::json::JsonValue::parse(&text).unwrap();
        assert!(!v.get("traceEvents").unwrap().as_array().unwrap().is_empty());
    }

    #[test]
    fn bad_epsilon_rejected() {
        let dir = tmp("badeps");
        let graph = dir.join("g.txt");
        let store = dir.join("store");
        run_cmd(&["gen", "--kind", "chain", "--scale", "4", "--out", graph.to_str().unwrap()])
            .unwrap();
        run_cmd(&[
            "store",
            "--graph",
            graph.to_str().unwrap(),
            "--k",
            "2",
            "--out",
            store.to_str().unwrap(),
        ])
        .unwrap();
        let err = run_cmd(&[
            "run",
            "--store",
            store.to_str().unwrap(),
            "--algo",
            "pagerank",
            "--epsilon",
            "not-a-number",
        ]);
        assert!(err.is_err());
    }

    #[test]
    fn vertex_engine_rejects_gopher_knobs() {
        let dir = tmp("vxknobs");
        let graph = dir.join("g.txt");
        let store = dir.join("store");
        run_cmd(&["gen", "--kind", "chain", "--scale", "3", "--out", graph.to_str().unwrap()])
            .unwrap();
        run_cmd(&[
            "store",
            "--graph",
            graph.to_str().unwrap(),
            "--k",
            "2",
            "--out",
            store.to_str().unwrap(),
        ])
        .unwrap();
        // Typed build-time rejections from the job layer.
        assert!(run_cmd(&[
            "run",
            "--store",
            store.to_str().unwrap(),
            "--algo",
            "pagerank",
            "--engine",
            "vertex",
            "--epsilon",
            "0.1",
        ])
        .is_err());
        assert!(run_cmd(&[
            "run",
            "--store",
            store.to_str().unwrap(),
            "--algo",
            "cc",
            "--engine",
            "vertex",
            "--no-combine",
        ])
        .is_err());
        // blockrank has no vertex implementation.
        assert!(run_cmd(&[
            "run",
            "--store",
            store.to_str().unwrap(),
            "--algo",
            "blockrank",
            "--engine",
            "vertex",
        ])
        .is_err());
        // Unknown algorithm names fail through the registry.
        assert!(run_cmd(&[
            "run",
            "--store",
            store.to_str().unwrap(),
            "--algo",
            "frobnicate",
        ])
        .is_err());
    }

    #[test]
    fn output_tsv_matches_golden() {
        // Fixed-seed chain(16): one component, HCC labels every vertex
        // with the max id 15 — the golden file is fully determined.
        let dir = tmp("tsv");
        let graph = dir.join("g.txt");
        let store = dir.join("store");
        let out = dir.join("cc.tsv");
        run_cmd(&[
            "gen", "--kind", "chain", "--scale", "4", "--seed", "7", "--out",
            graph.to_str().unwrap(),
        ])
        .unwrap();
        run_cmd(&[
            "store",
            "--graph",
            graph.to_str().unwrap(),
            "--k",
            "2",
            "--out",
            store.to_str().unwrap(),
        ])
        .unwrap();
        run_cmd(&[
            "run",
            "--store",
            store.to_str().unwrap(),
            "--algo",
            "cc",
            "--engine",
            "gopher",
            "--output",
            out.to_str().unwrap(),
        ])
        .unwrap();
        let got = std::fs::read_to_string(&out).unwrap();
        let golden: String = (0..16).map(|v| format!("{v}\t15\n")).collect();
        assert_eq!(got, golden);

        // The vertex engine writes the identical file.
        let out_vx = dir.join("cc_vx.tsv");
        run_cmd(&[
            "run",
            "--store",
            store.to_str().unwrap(),
            "--algo",
            "cc",
            "--engine",
            "vertex",
            "--output",
            out_vx.to_str().unwrap(),
        ])
        .unwrap();
        assert_eq!(std::fs::read_to_string(&out_vx).unwrap(), golden);
    }

    #[test]
    fn all_formats_and_projected_runs_agree() {
        let dir = tmp("fmt_parity");
        let graph = dir.join("g.txt");
        run_cmd(&[
            "gen", "--kind", "chain", "--scale", "4", "--seed", "7", "--out",
            graph.to_str().unwrap(),
        ])
        .unwrap();
        let golden: String = (0..16).map(|v| format!("{v}\t15\n")).collect();
        for fmt in ["v1", "v2", "v3"] {
            let store = dir.join(format!("store-{fmt}"));
            run_cmd(&[
                "store",
                "--graph",
                graph.to_str().unwrap(),
                "--k",
                "2",
                "--format",
                fmt,
                "--attrs",
                "3",
                "--out",
                store.to_str().unwrap(),
            ])
            .unwrap();
            let out = dir.join(format!("{fmt}.tsv"));
            run_cmd(&[
                "run", "--store", store.to_str().unwrap(),
                "--algo", "cc", "--output", out.to_str().unwrap(),
            ])
            .unwrap();
            assert_eq!(std::fs::read_to_string(&out).unwrap(), golden, "{fmt}");
            // The vertex engine (which reassembles the whole store)
            // produces the identical JobOutput from every format.
            let out_vx = dir.join(format!("{fmt}-vx.tsv"));
            run_cmd(&[
                "run", "--store", store.to_str().unwrap(),
                "--algo", "cc", "--engine", "vertex",
                "--output", out_vx.to_str().unwrap(),
            ])
            .unwrap();
            assert_eq!(std::fs::read_to_string(&out_vx).unwrap(), golden, "{fmt}");
            // The sectioned formats also run projected (v3 seeks past
            // attr1/attr2; v2 skips their files) to identical output.
            if fmt != "v1" {
                let proj = dir.join(format!("{fmt}-proj.tsv"));
                run_cmd(&[
                    "run", "--store", store.to_str().unwrap(),
                    "--algo", "cc", "--load-attributes", "attr0",
                    "--output", proj.to_str().unwrap(),
                ])
                .unwrap();
                assert_eq!(std::fs::read_to_string(&proj).unwrap(), golden, "{fmt}");
            }
            // Every format scrubs clean through `store verify`.
            run_cmd(&["store", "verify", "--store", store.to_str().unwrap()]).unwrap();
        }

        // Unknown formats and undeclared attributes fail loudly.
        assert!(run_cmd(&[
            "store", "--graph", graph.to_str().unwrap(), "--k", "2",
            "--format", "v9", "--out", dir.join("store-v9").to_str().unwrap(),
        ])
        .is_err());
        for fmt in ["v2", "v3"] {
            assert!(run_cmd(&[
                "run", "--store", dir.join(format!("store-{fmt}")).to_str().unwrap(),
                "--algo", "cc", "--load-attributes", "nope",
            ])
            .is_err());
        }
    }

    #[test]
    fn kill_resume_recovers_identical_tsv() {
        let dir = tmp("kill_resume");
        let graph = dir.join("g.txt");
        let store = dir.join("store");
        let ckpt = dir.join("ckpt");
        run_cmd(&["gen", "--kind", "road", "--scale", "12", "--seed", "3", "--out",
                  graph.to_str().unwrap()])
            .unwrap();
        run_cmd(&["store", "--graph", graph.to_str().unwrap(), "--k", "3", "--out",
                  store.to_str().unwrap()])
            .unwrap();
        // Baseline: uninterrupted run.
        let full = dir.join("full.tsv");
        run_cmd(&["run", "--store", store.to_str().unwrap(), "--algo", "cc",
                  "--output", full.to_str().unwrap()])
            .unwrap();
        // Checkpointed run killed at superstep 2 fails loudly…
        let err = run_cmd(&["run", "--store", store.to_str().unwrap(), "--algo", "cc",
                            "--checkpoint-every", "1",
                            "--checkpoint-dir", ckpt.to_str().unwrap(),
                            "--kill-at", "2"]);
        assert!(err.is_err(), "killed run must fail");
        assert!(format!("{:#}", err.unwrap_err()).contains("injected worker failure"));
        // …and the resumed run produces a byte-identical TSV.
        let resumed = dir.join("resumed.tsv");
        run_cmd(&["run", "--store", store.to_str().unwrap(), "--algo", "cc",
                  "--resume", ckpt.to_str().unwrap(),
                  "--output", resumed.to_str().unwrap()])
            .unwrap();
        assert_eq!(
            std::fs::read_to_string(&full).unwrap(),
            std::fs::read_to_string(&resumed).unwrap()
        );
        // The scrubber passes over both the store and the checkpoints.
        run_cmd(&["store", "verify", "--store", store.to_str().unwrap(), "--ckpt",
                  ckpt.to_str().unwrap()])
            .unwrap();
        // Resuming with the wrong algorithm is a typed refusal.
        assert!(run_cmd(&["run", "--store", store.to_str().unwrap(), "--algo", "sssp",
                          "--resume", ckpt.to_str().unwrap()])
            .is_err());
    }

    #[test]
    fn async_kill_confined_resume_recovers_identical_tsv() {
        let dir = tmp("kill_resume_async");
        let graph = dir.join("g.txt");
        let store = dir.join("store");
        let ckpt = dir.join("ckpt");
        run_cmd(&["gen", "--kind", "road", "--scale", "12", "--seed", "3", "--out",
                  graph.to_str().unwrap()])
            .unwrap();
        run_cmd(&["store", "--graph", graph.to_str().unwrap(), "--k", "3", "--out",
                  store.to_str().unwrap()])
            .unwrap();
        // Baseline: uninterrupted run.
        let full = dir.join("full.tsv");
        run_cmd(&["run", "--store", store.to_str().unwrap(), "--algo", "cc",
                  "--output", full.to_str().unwrap()])
            .unwrap();
        // Async + compressed checkpointed run killed mid-job: the
        // flusher persists epochs off the barrier; worker 1's failure
        // leaves a FAILED_WORKER marker for confined recovery.
        let err = run_cmd(&["run", "--store", store.to_str().unwrap(), "--algo", "cc",
                            "--checkpoint-every", "1",
                            "--checkpoint-mode", "async",
                            "--checkpoint-compress",
                            "--checkpoint-dir", ckpt.to_str().unwrap(),
                            "--kill-at", "2", "--kill-worker", "1"]);
        assert!(err.is_err(), "killed run must fail");
        assert!(format!("{:#}", err.unwrap_err()).contains("injected worker failure"));
        // Confined resume (only worker 1 rebuilds, replaying its
        // in-flight messages from the senders' logs) produces a
        // byte-identical TSV.
        let resumed = dir.join("resumed.tsv");
        run_cmd(&["run", "--store", store.to_str().unwrap(), "--algo", "cc",
                  "--resume", ckpt.to_str().unwrap(), "--confined-recovery",
                  "--output", resumed.to_str().unwrap()])
            .unwrap();
        assert_eq!(
            std::fs::read_to_string(&full).unwrap(),
            std::fs::read_to_string(&resumed).unwrap()
        );
        // Compressed async-written epochs (and their send logs) scrub
        // clean through `store verify`.
        run_cmd(&["store", "verify", "--ckpt", ckpt.to_str().unwrap()]).unwrap();
        // An unknown mode is a loud parse error.
        assert!(run_cmd(&["run", "--store", store.to_str().unwrap(), "--algo", "cc",
                          "--checkpoint-every", "1",
                          "--checkpoint-dir", ckpt.to_str().unwrap(),
                          "--checkpoint-mode", "turbo"])
            .is_err());
    }

    #[test]
    fn store_verify_flags_corruption() {
        let dir = tmp("verify");
        let graph = dir.join("g.txt");
        let store = dir.join("store");
        run_cmd(&["gen", "--kind", "chain", "--scale", "4", "--out",
                  graph.to_str().unwrap()])
            .unwrap();
        run_cmd(&["store", "--graph", graph.to_str().unwrap(), "--k", "2", "--attrs",
                  "1", "--out", store.to_str().unwrap()])
            .unwrap();
        // Clean store verifies.
        run_cmd(&["store", "verify", "--store", store.to_str().unwrap()]).unwrap();
        // No target is an error.
        assert!(run_cmd(&["store", "verify"]).is_err());
        // Flip one byte in a slice body: verify fails.
        let victim = store.join("host0").join("sg_0.topo.slice");
        let mut bytes = std::fs::read(&victim).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0x55;
        std::fs::write(&victim, bytes).unwrap();
        assert!(run_cmd(&["store", "verify", "--store", store.to_str().unwrap()]).is_err());
    }

    #[test]
    fn ingest_matches_batch_hash_store() {
        // The streamed path with a spill buffer far smaller than the
        // input must agree with `store --strategy hash` of the same
        // list: identical cc output and a clean scrub.
        let dir = tmp("ingest");
        let graph = dir.join("g.txt");
        run_cmd(&["gen", "--kind", "road", "--scale", "8", "--seed", "5", "--out",
                  graph.to_str().unwrap()])
            .unwrap();
        let batch = dir.join("batch");
        run_cmd(&["store", "--graph", graph.to_str().unwrap(), "--k", "2",
                  "--strategy", "hash", "--seed", "1", "--format", "v3",
                  "--out", batch.to_str().unwrap()])
            .unwrap();
        let streamed = dir.join("streamed");
        run_cmd(&["ingest", "--edges", graph.to_str().unwrap(),
                  "--store", streamed.to_str().unwrap(),
                  "--hosts", "2", "--spill-buffer", "64"])
            .unwrap();
        run_cmd(&["store", "verify", "--store", streamed.to_str().unwrap()]).unwrap();
        let a = dir.join("batch.tsv");
        let b = dir.join("streamed.tsv");
        run_cmd(&["run", "--store", batch.to_str().unwrap(), "--algo", "cc",
                  "--output", a.to_str().unwrap()])
            .unwrap();
        run_cmd(&["run", "--store", streamed.to_str().unwrap(), "--algo", "cc",
                  "--output", b.to_str().unwrap()])
            .unwrap();
        assert_eq!(
            std::fs::read_to_string(&a).unwrap(),
            std::fs::read_to_string(&b).unwrap()
        );
        // Refusals: missing inputs, bad formats, occupied target.
        assert!(run_cmd(&["ingest", "--store", dir.join("x").to_str().unwrap()]).is_err());
        assert!(run_cmd(&["ingest", "--edges", graph.to_str().unwrap(),
                          "--store", dir.join("x").to_str().unwrap(),
                          "--format", "v9"])
            .is_err());
        assert!(run_cmd(&["ingest", "--edges", graph.to_str().unwrap(),
                          "--store", streamed.to_str().unwrap()])
            .is_err());
    }

    #[test]
    fn store_migrate_upgrades_in_place() {
        let dir = tmp("migrate");
        let graph = dir.join("g.txt");
        let store = dir.join("store");
        run_cmd(&["gen", "--kind", "chain", "--scale", "4", "--seed", "7", "--out",
                  graph.to_str().unwrap()])
            .unwrap();
        run_cmd(&["store", "--graph", graph.to_str().unwrap(), "--k", "2",
                  "--attrs", "2", "--format", "v2", "--out", store.to_str().unwrap()])
            .unwrap();
        let golden = dir.join("before.tsv");
        run_cmd(&["run", "--store", store.to_str().unwrap(), "--algo", "cc",
                  "--output", golden.to_str().unwrap()])
            .unwrap();
        run_cmd(&["store", "migrate", "--store", store.to_str().unwrap()]).unwrap();
        // Format flipped on disk; superseded slice files are gone.
        assert_eq!(
            Store::open(&store).unwrap().meta().format,
            SliceFormat::V3Packed
        );
        assert!(store.join("host0").join("partition.gfsp").exists());
        assert!(!store.join("host0").join("sg_0.topo.slice").exists());
        // Results (full and projected) are unchanged, and the packed
        // store scrubs clean. Migrating again is a no-op.
        let after = dir.join("after.tsv");
        run_cmd(&["run", "--store", store.to_str().unwrap(), "--algo", "cc",
                  "--load-attributes", "attr0", "--output", after.to_str().unwrap()])
            .unwrap();
        assert_eq!(
            std::fs::read_to_string(&golden).unwrap(),
            std::fs::read_to_string(&after).unwrap()
        );
        run_cmd(&["store", "verify", "--store", store.to_str().unwrap()]).unwrap();
        run_cmd(&["store", "migrate", "--store", store.to_str().unwrap()]).unwrap();
        // Missing --store is a refusal.
        assert!(run_cmd(&["store", "migrate"]).is_err());
    }

    #[test]
    fn unknown_command_errors() {
        assert!(run_cmd(&["frobnicate"]).is_err());
    }

    #[test]
    fn help_is_ok() {
        run_cmd(&["help"]).unwrap();
    }
}
