//! CLI command implementations.
//!
//! ```text
//! goffish gen       --kind road|trace|social|er|grid|chain --out g.txt [--scale N] [--seed S]
//! goffish info      --graph g.txt [--directed]
//! goffish partition --graph g.txt --k 4 [--strategy multilevel|hash|range]
//! goffish store     --graph g.txt --k 4 --out storedir [--strategy …] [--name NAME]
//! goffish run       --store storedir
//!                   --algo cc|sssp|bfs|pagerank|blockrank|maxvalue|labelprop
//!                   [--engine gopher|vertex] [--source V] [--supersteps N]
//!                   [--epsilon E] [--no-combine] [--max-supersteps N]
//!                   [--xla] [--fabric inproc|tcp] [--cores N]
//! ```
//!
//! Coordinator knobs: `--epsilon` switches PageRank to aggregator-driven
//! convergence (global L1 delta < E terminates the job), `--no-combine`
//! disables the Gopher message combiners, and aggregator traces are
//! printed after any run that registered them.

use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::algos;
use crate::algos::pagerank::RankKernel;
use crate::gofs::Store;
use crate::gopher::{self, FabricKind, GopherConfig};
use crate::graph::{gen, io, props, Graph};
use crate::partition::{
    HashPartitioner, MultilevelPartitioner, Partitioner, RangePartitioner,
};
use crate::pregel::{self, PregelConfig};
use crate::runtime::XlaEngine;

use super::args::Args;

pub fn dispatch(argv: Vec<String>) -> Result<()> {
    let args = Args::parse(&argv);
    match args.command().unwrap_or("help") {
        "gen" => cmd_gen(&args),
        "info" => cmd_info(&args),
        "partition" => cmd_partition(&args),
        "store" => cmd_store(&args),
        "run" => cmd_run(&args),
        "help" | "--help" | "-h" => {
            print!("{}", HELP);
            Ok(())
        }
        other => bail!("unknown command {other:?}; try `goffish help`"),
    }
}

const HELP: &str = r#"goffish — sub-graph centric graph analytics (GoFFish reproduction)

commands:
  gen       generate a synthetic dataset analog to an edge list
  info      structural properties of a graph (the Table-1 row)
  partition partition a graph and report cut metrics
  store     build a GoFS store directory (partition + sub-graph slices)
  run       execute an algorithm with Gopher or the vertex baseline
  help      this message

see rust/src/cli/commands.rs for per-command flags.
"#;

fn load_graph(args: &Args) -> Result<Graph> {
    let path = args.require("graph")?;
    io::read_edge_list(Path::new(path), args.flag("directed"))
}

fn make_partitioner(args: &Args) -> Result<Box<dyn Partitioner>> {
    Ok(match args.get_or("strategy", "multilevel") {
        "multilevel" => Box::new(MultilevelPartitioner::new(args.get_u64("seed", 1)?)),
        "hash" => Box::new(HashPartitioner::new(args.get_u64("seed", 1)?)),
        "range" => Box::new(RangePartitioner),
        s => bail!("unknown strategy {s:?}"),
    })
}

fn cmd_gen(args: &Args) -> Result<()> {
    let kind = args.get_or("kind", "road");
    let scale = args.get_usize("scale", 100)?;
    let seed = args.get_u64("seed", 42)?;
    let g = match kind {
        "road" => gen::road(scale, 0.97, 0.005, seed),
        "trace" => gen::trace(scale * scale, scale.max(8), 0.15, seed),
        "social" => gen::social(scale * scale, 8, 0.02, seed),
        "er" => gen::erdos_renyi(scale * scale, args.get_f64("p", 0.001)?, true, seed),
        "grid" => gen::grid(scale, scale),
        "chain" => gen::chain(scale * scale),
        k => bail!("unknown kind {k:?}"),
    };
    let g = if args.flag("weighted") {
        gen::with_random_weights(&g, 1.0, 10.0, seed ^ 0x57EED)
    } else {
        g
    };
    let out = args.require("out")?;
    io::write_edge_list(&g, Path::new(out))?;
    println!(
        "wrote {} ({} vertices, {} edges) to {out}",
        kind,
        g.num_vertices(),
        g.num_edges()
    );
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let g = load_graph(args)?;
    let stats = props::degree_stats(&g);
    println!("vertices  {}", g.num_vertices());
    println!("edges     {}", g.num_edges());
    println!("directed  {}", g.directed());
    println!("weighted  {}", g.has_weights());
    println!("wcc       {}", props::wcc_count(&g));
    println!(
        "diameter  {} (double-sweep estimate)",
        props::diameter_estimate(&g, 4, 7)
    );
    println!(
        "degree    min={} max={} mean={:.2}",
        stats.min, stats.max, stats.mean
    );
    Ok(())
}

fn cmd_partition(args: &Args) -> Result<()> {
    let g = load_graph(args)?;
    let k = args.get_usize("k", 4)?;
    let partitioner = make_partitioner(args)?;
    let p = partitioner.partition(&g, k);
    let m = p.metrics(&g);
    println!("strategy     {}", partitioner.name());
    println!("k            {k}");
    println!("edge cut     {} ({:.1}%)", m.edge_cut, m.cut_fraction * 100.0);
    println!("imbalance    {:.3}", m.imbalance);
    println!("sizes        {:?}", m.sizes);
    Ok(())
}

fn cmd_store(args: &Args) -> Result<()> {
    let g = load_graph(args)?;
    let k = args.get_usize("k", 4)?;
    let out = args.require("out")?;
    let name = args.get_or("name", "graph");
    let partitioner = make_partitioner(args)?;
    let p = partitioner.partition(&g, k);
    let (store, dg) = Store::create(Path::new(out), name, &g, &p)?;
    println!(
        "stored {} as {} partitions / {} sub-graphs at {}",
        name,
        k,
        dg.num_subgraphs(),
        store.root().display()
    );
    for (i, sgs) in dg.partitions.iter().enumerate() {
        let sizes: Vec<usize> = sgs.iter().map(|s| s.num_vertices()).collect();
        println!("  host{i}: {} sub-graphs, sizes {:?}", sgs.len(), sizes);
    }
    Ok(())
}

fn cmd_run(args: &Args) -> Result<()> {
    let store = Store::open(Path::new(args.require("store")?))?;
    let algo = args.get_or("algo", "cc");
    let engine = args.get_or("engine", "gopher");
    let source = args.get_usize("source", 0)? as u32;
    let supersteps = args.get_usize("supersteps", 30)?;
    let max_supersteps = args.get_usize("max-supersteps", 10_000)?;
    let epsilon = match args.get("epsilon") {
        Some(s) => Some(
            s.parse::<f32>()
                .with_context(|| format!("--epsilon expects a number, got {s:?}"))?,
        ),
        None => None,
    };
    let combiners = !args.flag("no-combine");
    let fabric = match args.get_or("fabric", "inproc") {
        "inproc" => FabricKind::InProc,
        "tcp" => FabricKind::Tcp,
        f => bail!("unknown fabric {f:?}"),
    };
    let cores = args.get_usize("cores", 4)?;
    let kernel = if args.flag("xla") {
        RankKernel::Xla(Arc::new(XlaEngine::load_default()?))
    } else {
        RankKernel::Scalar
    };

    if engine == "gopher" {
        let cfg = GopherConfig {
            cores_per_worker: cores,
            fabric,
            combiners,
            max_supersteps,
            ..Default::default()
        };
        let metrics = match algo {
            "cc" => gopher::run_on_store(&store, &algos::cc::CcSg, &cfg)?.metrics,
            "maxvalue" => {
                gopher::run_on_store(&store, &algos::maxvalue::MaxValueSg, &cfg)?.metrics
            }
            "bfs" => {
                gopher::run_on_store(&store, &algos::bfs::BfsSg { source }, &cfg)?.metrics
            }
            "sssp" => {
                gopher::run_on_store(&store, &algos::sssp::SsspSg { source }, &cfg)?.metrics
            }
            "pagerank" => {
                let prog = algos::pagerank::PageRankSg { supersteps, kernel, epsilon };
                gopher::run_on_store(&store, &prog, &cfg)?.metrics
            }
            "labelprop" => {
                let prog = algos::labelprop::LabelPropSg { max_rounds: supersteps };
                gopher::run_on_store(&store, &prog, &cfg)?.metrics
            }
            "blockrank" => {
                let mut prog =
                    algos::blockrank::BlockRankSg::new(&store.meta().subgraph_counts);
                prog.kernel = kernel;
                let cfg2 = GopherConfig { max_supersteps: 500, ..cfg };
                gopher::run_on_store(&store, &prog, &cfg2)?.metrics
            }
            a => bail!("unknown algo {a:?}"),
        };
        println!("{}", metrics.report(&format!("gopher/{algo}")));
        for trace in &metrics.aggregators {
            println!(
                "  aggregator {}: last={:?} over {} supersteps",
                trace.name,
                trace.last(),
                trace.values.len()
            );
        }
    } else if engine == "vertex" {
        // Coordinator knobs are Gopher-only: fail loudly instead of
        // silently running the baseline in the wrong mode.
        if epsilon.is_some() {
            bail!("--epsilon is only supported by the gopher engine");
        }
        if !combiners {
            bail!("--no-combine is only supported by the gopher engine");
        }
        // Vertex baseline reconstructs the full graph from the store.
        let (dg, _) = store.load_all()?;
        let g = reassemble(&dg)?;
        let parts = HashPartitioner::default()
            .partition(&g, store.meta().num_partitions as usize);
        let cfg = PregelConfig {
            cores_per_worker: cores,
            fabric,
            max_supersteps,
            ..Default::default()
        };
        let metrics = match algo {
            "cc" => pregel::run_vertex(&g, &parts, &algos::cc::CcVx, &cfg)?.metrics,
            "maxvalue" => {
                pregel::run_vertex(&g, &parts, &algos::maxvalue::MaxValueVx, &cfg)?.metrics
            }
            "bfs" => {
                pregel::run_vertex(&g, &parts, &algos::bfs::BfsVx { source }, &cfg)?.metrics
            }
            "sssp" => {
                pregel::run_vertex(&g, &parts, &algos::sssp::SsspVx { source }, &cfg)?
                    .metrics
            }
            "pagerank" => {
                let prog = algos::pagerank::PageRankVx { supersteps };
                pregel::run_vertex(&g, &parts, &prog, &cfg)?.metrics
            }
            a => bail!("algo {a:?} has no vertex-centric implementation"),
        };
        println!("{}", metrics.report(&format!("vertex/{algo}")));
    } else {
        bail!("unknown engine {engine:?}");
    }
    Ok(())
}

/// Rebuild a global [`Graph`] from a distributed one (for the vertex
/// baseline, which Giraph-style owns the whole edge list).
pub fn reassemble(dg: &crate::gofs::DistributedGraph) -> Result<Graph> {
    let mut edges = Vec::new();
    let mut weights = Vec::new();
    let mut weighted = false;
    for sg in dg.subgraphs() {
        for (u, v, ei) in sg.local.edges() {
            edges.push((sg.vertices[u as usize], sg.vertices[v as usize]));
            weights.push(sg.local.weight(ei));
            weighted |= sg.local.has_weights();
        }
        for r in &sg.remote_out {
            edges.push((sg.vertices[r.local as usize], r.target_global));
            weights.push(r.weight);
        }
    }
    Graph::from_edges(
        dg.num_global_vertices as usize,
        &edges,
        if weighted { Some(weights) } else { None },
        dg.directed,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run_cmd(argv: &[&str]) -> Result<()> {
        dispatch(argv.iter().map(|s| s.to_string()).collect())
    }

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir()
            .join("goffish_cli")
            .join(format!("{name}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn gen_info_partition_store_run_pipeline() {
        let dir = tmp("pipeline");
        let graph = dir.join("g.txt");
        let store = dir.join("store");
        run_cmd(&["gen", "--kind", "road", "--scale", "14", "--out", graph.to_str().unwrap()])
            .unwrap();
        run_cmd(&["info", "--graph", graph.to_str().unwrap()]).unwrap();
        run_cmd(&["partition", "--graph", graph.to_str().unwrap(), "--k", "3"]).unwrap();
        run_cmd(&[
            "store",
            "--graph",
            graph.to_str().unwrap(),
            "--k",
            "3",
            "--out",
            store.to_str().unwrap(),
        ])
        .unwrap();
        run_cmd(&["run", "--store", store.to_str().unwrap(), "--algo", "cc"]).unwrap();
        run_cmd(&[
            "run",
            "--store",
            store.to_str().unwrap(),
            "--algo",
            "sssp",
            "--engine",
            "vertex",
        ])
        .unwrap();
        // Coordinator knobs: combiner off, aggregator-driven PageRank,
        // and the label-propagation showcase.
        run_cmd(&[
            "run",
            "--store",
            store.to_str().unwrap(),
            "--algo",
            "sssp",
            "--no-combine",
        ])
        .unwrap();
        run_cmd(&[
            "run",
            "--store",
            store.to_str().unwrap(),
            "--algo",
            "pagerank",
            "--epsilon",
            "0.01",
            "--supersteps",
            "60",
        ])
        .unwrap();
        run_cmd(&["run", "--store", store.to_str().unwrap(), "--algo", "labelprop"])
            .unwrap();
    }

    #[test]
    fn bad_epsilon_rejected() {
        let dir = tmp("badeps");
        let graph = dir.join("g.txt");
        let store = dir.join("store");
        run_cmd(&["gen", "--kind", "chain", "--scale", "4", "--out", graph.to_str().unwrap()])
            .unwrap();
        run_cmd(&[
            "store",
            "--graph",
            graph.to_str().unwrap(),
            "--k",
            "2",
            "--out",
            store.to_str().unwrap(),
        ])
        .unwrap();
        let err = run_cmd(&[
            "run",
            "--store",
            store.to_str().unwrap(),
            "--algo",
            "pagerank",
            "--epsilon",
            "not-a-number",
        ]);
        assert!(err.is_err());
    }

    #[test]
    fn unknown_command_errors() {
        assert!(run_cmd(&["frobnicate"]).is_err());
    }

    #[test]
    fn help_is_ok() {
        run_cmd(&["help"]).unwrap();
    }

    #[test]
    fn reassemble_preserves_counts() {
        let g = crate::graph::gen::road(10, 0.9, 0.02, 3);
        let p = MultilevelPartitioner::default().partition(&g, 3);
        let dg = crate::gofs::subgraph::discover(&g, &p).unwrap();
        let g2 = reassemble(&dg).unwrap();
        assert_eq!(g2.num_vertices(), g.num_vertices());
        assert_eq!(g2.num_edges(), g.num_edges());
    }
}
