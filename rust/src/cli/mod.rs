//! Command-line surface (in-crate parser; no clap in the vendor set).
//!
//! See [`commands`] for the command list and flags.

pub mod args;
pub mod commands;

pub use args::Args;

/// Entry point used by `main.rs`; returns the process exit code.
pub fn run(argv: Vec<String>) -> i32 {
    match commands::dispatch(argv) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            2
        }
    }
}
