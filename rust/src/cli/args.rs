//! Tiny argument parser: `--key value`, `--flag`, and positionals.

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

/// Parsed command-line arguments.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    options: HashMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from raw argv (no program name). `--key value` pairs become
    /// options; a `--key` followed by another `--...` or end-of-args is a
    /// boolean flag.
    pub fn parse(argv: &[String]) -> Args {
        let mut args = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let tok = &argv[i];
            if let Some(key) = tok.strip_prefix("--") {
                let next_is_value = argv
                    .get(i + 1)
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false);
                if next_is_value {
                    args.options.insert(key.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    args.flags.push(key.to_string());
                    i += 1;
                }
            } else {
                args.positional.push(tok.clone());
                i += 1;
            }
        }
        args
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn require(&self, name: &str) -> Result<&str> {
        self.get(name)
            .with_context(|| format!("missing required option --{name}"))
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .with_context(|| format!("--{name} expects an integer, got {s:?}")),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .with_context(|| format!("--{name} expects an integer, got {s:?}")),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .with_context(|| format!("--{name} expects a number, got {s:?}")),
        }
    }

    /// Comma-separated list option: `--load-attributes a,b` → `["a",
    /// "b"]`; a missing option is the empty list.
    pub fn get_list(&self, name: &str) -> Vec<String> {
        self.get(name)
            .map(|s| {
                s.split(',')
                    .map(str::trim)
                    .filter(|t| !t.is_empty())
                    .map(str::to_string)
                    .collect()
            })
            .unwrap_or_default()
    }

    /// First positional = subcommand.
    pub fn command(&self) -> Result<&str> {
        match self.positional.first() {
            Some(c) => Ok(c.as_str()),
            None => bail!("no command given"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(&s.iter().map(|x| x.to_string()).collect::<Vec<_>>())
    }

    #[test]
    fn positionals_and_options() {
        let a = parse(&["run", "--graph", "g.txt", "--k", "4", "extra"]);
        assert_eq!(a.command().unwrap(), "run");
        assert_eq!(a.positional, vec!["run", "extra"]);
        assert_eq!(a.get("graph"), Some("g.txt"));
        assert_eq!(a.get_usize("k", 0).unwrap(), 4);
    }

    #[test]
    fn flags_detected() {
        let a = parse(&["gen", "--verbose", "--out", "x", "--quiet"]);
        assert!(a.flag("verbose"));
        assert!(a.flag("quiet"));
        assert!(!a.flag("loud"));
        assert_eq!(a.get("out"), Some("x"));
    }

    #[test]
    fn missing_required_errors() {
        let a = parse(&["run"]);
        assert!(a.require("graph").is_err());
    }

    #[test]
    fn bad_number_errors() {
        let a = parse(&["x", "--k", "four"]);
        assert!(a.get_usize("k", 1).is_err());
    }

    #[test]
    fn defaults() {
        let a = parse(&["x"]);
        assert_eq!(a.get_or("mode", "gopher"), "gopher");
        assert_eq!(a.get_f64("p", 0.5).unwrap(), 0.5);
    }

    #[test]
    fn no_command() {
        let a = parse(&[]);
        assert!(a.command().is_err());
    }

    #[test]
    fn list_options() {
        let a = parse(&["run", "--load-attributes", "a, b,,c"]);
        assert_eq!(a.get_list("load-attributes"), vec!["a", "b", "c"]);
        assert!(a.get_list("missing").is_empty());
    }
}
