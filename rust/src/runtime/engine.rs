//! The XLA kernel engine: manifest-driven executable ladder + service
//! thread (see module docs in `runtime/mod.rs` for the thread model).
//!
//! Artifact contract (shared with `python/compile/aot.py` and
//! `python/compile/model.py`):
//!
//! * `pagerank_step(adj[n,n], ranks[n], out_deg[n], scalars[2]) -> ranks[n]`
//! * `pagerank_local(adj[n,n], out_deg[n], scalars[2]) -> ranks[n]`
//!   (`iters` compiled in; manifest column 4)
//! * `sssp_relax(weights[n,n], dist[n]) -> dist[n]` (`sweeps` compiled in)
//! * `cc_flood(adj[n,n], labels[n]) -> labels[n]` (`sweeps` compiled in)
//!
//! All matrices are row-major in-link oriented (`A[i][j] = edge j->i`);
//! padding rows are marked by `out_deg = -1` / `+inf` weights / zero
//! adjacency respectively (see model.py docstring).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::mpsc::{channel, Sender};

use anyhow::{anyhow, bail, Context, Result};

pub const KERNEL_PAGERANK_STEP: &str = "pagerank_step";
pub const KERNEL_PAGERANK_LOCAL: &str = "pagerank_local";
pub const KERNEL_SSSP_RELAX: &str = "sssp_relax";
pub const KERNEL_CC_FLOOD: &str = "cc_flood";

/// One manifest entry.
#[derive(Clone, Debug)]
struct ManifestEntry {
    kernel: String,
    file: String,
    rung: usize,
    loops: usize,
}

fn parse_manifest(dir: &Path) -> Result<Vec<ManifestEntry>> {
    let path = dir.join("manifest.txt");
    let text = std::fs::read_to_string(&path)
        .with_context(|| format!("read {} (run `make artifacts`)", path.display()))?;
    let mut entries = Vec::new();
    for (i, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let parts: Vec<&str> = line.split_whitespace().collect();
        if parts.len() != 4 {
            bail!("manifest line {}: expected 4 fields, got {}", i + 1, parts.len());
        }
        entries.push(ManifestEntry {
            kernel: parts[0].to_string(),
            file: parts[1].to_string(),
            rung: parts[2].parse().context("manifest rung")?,
            loops: parts[3].parse().context("manifest loops")?,
        });
    }
    if entries.is_empty() {
        bail!("manifest at {} is empty", path.display());
    }
    Ok(entries)
}

// ------------------------------------------------------------- service

struct Arg {
    data: Vec<f32>,
    dims: Vec<i64>,
}

/// A call argument: fresh host data, or a previously registered constant
/// block (the per-sub-graph adjacency, which never changes between
/// supersteps — caching it server-side removes an O(n_pad^2) copy +
/// literal build from every kernel call; see EXPERIMENTS.md §Perf).
enum CallArg {
    Fresh(Arg),
    Cached(u64),
}

enum Request {
    Call {
        kernel: String,
        rung: usize,
        args: Vec<CallArg>,
        reply: Sender<Result<Vec<f32>>>,
    },
    Register {
        arg: Arg,
        reply: Sender<Result<u64>>,
    },
}

fn service_loop(
    dir: PathBuf,
    entries: Vec<ManifestEntry>,
    init_tx: Sender<Result<()>>,
    req_rx: std::sync::mpsc::Receiver<Request>,
) {
    // Own the (!Send) PJRT client on this thread.
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => c,
        Err(e) => {
            let _ = init_tx.send(Err(anyhow!("PJRT CPU client: {e}")));
            return;
        }
    };
    let _ = init_tx.send(Ok(()));

    let index: BTreeMap<(String, usize), ManifestEntry> = entries
        .into_iter()
        .map(|e| ((e.kernel.clone(), e.rung), e))
        .collect();
    // Lazy executable cache: compile each (kernel, rung) on first use.
    let mut exes: BTreeMap<(String, usize), xla::PjRtLoadedExecutable> = BTreeMap::new();
    // Registered constant blocks (adjacency matrices etc.).
    let mut blocks: BTreeMap<u64, xla::Literal> = BTreeMap::new();
    let mut next_block: u64 = 1;

    fn build_literal(a: &Arg) -> Result<xla::Literal> {
        let lit = xla::Literal::vec1(&a.data);
        if a.dims.len() == 1 {
            Ok(lit)
        } else {
            lit.reshape(&a.dims).map_err(|e| anyhow!("reshape: {e}"))
        }
    }

    while let Ok(req) = req_rx.recv() {
        match req {
            Request::Register { arg, reply } => {
                let result = build_literal(&arg).map(|lit| {
                    let id = next_block;
                    next_block += 1;
                    blocks.insert(id, lit);
                    id
                });
                let _ = reply.send(result);
            }
            Request::Call { kernel, rung, args, reply } => {
                let key = (kernel.clone(), rung);
                let result = (|| -> Result<Vec<f32>> {
                    if !exes.contains_key(&key) {
                        let entry = index.get(&key).ok_or_else(|| {
                            anyhow!("no artifact for {kernel} rung {rung}")
                        })?;
                        let path = dir.join(&entry.file);
                        let proto = xla::HloModuleProto::from_text_file(
                            path.to_str().context("artifact path not UTF-8")?,
                        )
                        .map_err(|e| anyhow!("parse {}: {e}", path.display()))?;
                        let comp = xla::XlaComputation::from_proto(&proto);
                        let exe = client
                            .compile(&comp)
                            .map_err(|e| anyhow!("compile {}: {e}", path.display()))?;
                        exes.insert(key.clone(), exe);
                    }
                    let exe = &exes[&key];
                    // Resolve args: fresh literals are built here; cached
                    // blocks are borrowed from the registry.
                    let mut fresh: Vec<xla::Literal> = Vec::new();
                    for a in &args {
                        if let CallArg::Fresh(arg) = a {
                            fresh.push(build_literal(arg)?);
                        }
                    }
                    let mut fresh_it = fresh.iter();
                    let literals: Vec<&xla::Literal> = args
                        .iter()
                        .map(|a| -> Result<&xla::Literal> {
                            match a {
                                CallArg::Fresh(_) => Ok(fresh_it.next().unwrap()),
                                CallArg::Cached(id) => blocks
                                    .get(id)
                                    .ok_or_else(|| anyhow!("unknown block {id}")),
                            }
                        })
                        .collect::<Result<_>>()?;
                    let out = exe
                        .execute::<&xla::Literal>(&literals)
                        .map_err(|e| anyhow!("execute {kernel}: {e}"))?[0][0]
                        .to_literal_sync()
                        .map_err(|e| anyhow!("to_literal: {e}"))?;
                    // aot.py lowers with return_tuple=True: unwrap.
                    let inner =
                        out.to_tuple1().map_err(|e| anyhow!("to_tuple1: {e}"))?;
                    inner.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e}"))
                })();
                // Receiver gone = caller aborted; nothing to do.
                let _ = reply.send(result);
            }
        }
    }
}

// -------------------------------------------------------------- engine

/// Shared handle to the XLA kernel service. `Send + Sync`; clone the
/// `Arc<XlaEngine>` into every Gopher worker.
pub struct XlaEngine {
    tx: Sender<Request>,
    rungs: Vec<usize>,
    loops: BTreeMap<String, usize>,
}

impl XlaEngine {
    /// Load the artifact manifest and start the service thread. Fails
    /// fast if the manifest is missing or the PJRT client cannot start.
    pub fn load(artifacts_dir: &Path) -> Result<XlaEngine> {
        let entries = parse_manifest(artifacts_dir)?;
        let mut rungs: Vec<usize> = entries.iter().map(|e| e.rung).collect();
        rungs.sort_unstable();
        rungs.dedup();
        let loops = entries
            .iter()
            .map(|e| (e.kernel.clone(), e.loops))
            .collect();

        let (init_tx, init_rx) = channel();
        let (req_tx, req_rx) = channel::<Request>();
        let dir = artifacts_dir.to_path_buf();
        std::thread::Builder::new()
            .name("xla-service".to_string())
            .spawn(move || service_loop(dir, entries, init_tx, req_rx))
            .context("spawn xla service thread")?;
        init_rx
            .recv()
            .context("xla service thread died during init")??;
        Ok(XlaEngine { tx: req_tx, rungs, loops })
    }

    /// Load from the default artifacts directory.
    pub fn load_default() -> Result<XlaEngine> {
        Self::load(&super::default_artifacts_dir())
    }

    /// Smallest compiled block size >= `n`.
    pub fn rung_for(&self, n: usize) -> Option<usize> {
        self.rungs.iter().copied().find(|&r| r >= n)
    }

    /// Largest compiled block size.
    pub fn max_rung(&self) -> usize {
        *self.rungs.last().unwrap_or(&0)
    }

    /// Compile-time inner-loop count for a kernel (e.g. sweeps per
    /// `sssp_relax` call).
    pub fn loops(&self, kernel: &str) -> usize {
        self.loops.get(kernel).copied().unwrap_or(1)
    }

    fn call(&self, kernel: &str, rung: usize, args: Vec<CallArg>) -> Result<Vec<f32>> {
        let (reply, rx) = channel();
        self.tx
            .send(Request::Call { kernel: kernel.to_string(), rung, args, reply })
            .map_err(|_| anyhow!("xla service thread gone"))?;
        rx.recv().map_err(|_| anyhow!("xla service dropped request"))?
    }

    /// Register a constant block (e.g. a sub-graph's padded dense
    /// adjacency) with the service; the returned id can replace the
    /// matrix argument in `*_cached` calls, eliminating the per-call
    /// O(n_pad^2) copy + literal construction.
    pub fn register_block(&self, n_pad: usize, matrix: &[f32]) -> Result<u64> {
        if matrix.len() != n_pad * n_pad {
            bail!("matrix has {} elements, want {}", matrix.len(), n_pad * n_pad);
        }
        let (reply, rx) = channel();
        self.tx
            .send(Request::Register {
                arg: Arg {
                    data: matrix.to_vec(),
                    dims: vec![n_pad as i64, n_pad as i64],
                },
                reply,
            })
            .map_err(|_| anyhow!("xla service thread gone"))?;
        rx.recv().map_err(|_| anyhow!("xla service dropped request"))?
    }

    /// `pagerank_step` with a pre-registered adjacency block.
    pub fn pagerank_step_cached(
        &self,
        n_pad: usize,
        block: u64,
        ranks: &[f32],
        out_deg: &[f32],
        base: f32,
        alpha: f32,
    ) -> Result<Vec<f32>> {
        if ranks.len() != n_pad || out_deg.len() != n_pad {
            bail!("vector length mismatch for rung {n_pad}");
        }
        self.call(
            KERNEL_PAGERANK_STEP,
            n_pad,
            vec![
                CallArg::Cached(block),
                CallArg::Fresh(Arg { data: ranks.to_vec(), dims: vec![n_pad as i64] }),
                CallArg::Fresh(Arg { data: out_deg.to_vec(), dims: vec![n_pad as i64] }),
                CallArg::Fresh(Arg { data: vec![base, alpha], dims: vec![2] }),
            ],
        )
    }

    /// One damped PageRank iteration over a padded dense block.
    /// `out_deg` must mark padding rows with `-1.0`.
    pub fn pagerank_step(
        &self,
        n_pad: usize,
        adj: &[f32],
        ranks: &[f32],
        out_deg: &[f32],
        base: f32,
        alpha: f32,
    ) -> Result<Vec<f32>> {
        self.check_block(n_pad, adj, &[ranks, out_deg])?;
        self.call(
            KERNEL_PAGERANK_STEP,
            n_pad,
            vec![
                CallArg::Fresh(Arg { data: adj.to_vec(), dims: vec![n_pad as i64, n_pad as i64] }),
                CallArg::Fresh(Arg { data: ranks.to_vec(), dims: vec![n_pad as i64] }),
                CallArg::Fresh(Arg { data: out_deg.to_vec(), dims: vec![n_pad as i64] }),
                CallArg::Fresh(Arg { data: vec![base, alpha], dims: vec![2] }),
            ],
        )
    }

    /// BlockRank local phase: `loops("pagerank_local")` iterations from a
    /// uniform start. `base` must be `(1-alpha)/n_total`.
    pub fn pagerank_local(
        &self,
        n_pad: usize,
        adj: &[f32],
        out_deg: &[f32],
        base: f32,
        alpha: f32,
    ) -> Result<Vec<f32>> {
        self.check_block(n_pad, adj, &[out_deg])?;
        self.call(
            KERNEL_PAGERANK_LOCAL,
            n_pad,
            vec![
                CallArg::Fresh(Arg { data: adj.to_vec(), dims: vec![n_pad as i64, n_pad as i64] }),
                CallArg::Fresh(Arg { data: out_deg.to_vec(), dims: vec![n_pad as i64] }),
                CallArg::Fresh(Arg { data: vec![base, alpha], dims: vec![2] }),
            ],
        )
    }

    /// `loops("sssp_relax")` min-plus sweeps over a padded weight block.
    pub fn sssp_relax(&self, n_pad: usize, weights: &[f32], dist: &[f32]) -> Result<Vec<f32>> {
        self.check_block(n_pad, weights, &[dist])?;
        self.call(
            KERNEL_SSSP_RELAX,
            n_pad,
            vec![
                CallArg::Fresh(Arg { data: weights.to_vec(), dims: vec![n_pad as i64, n_pad as i64] }),
                CallArg::Fresh(Arg { data: dist.to_vec(), dims: vec![n_pad as i64] }),
            ],
        )
    }

    /// `loops("cc_flood")` max-label flood steps over a padded block.
    pub fn cc_flood(&self, n_pad: usize, adj: &[f32], labels: &[f32]) -> Result<Vec<f32>> {
        self.check_block(n_pad, adj, &[labels])?;
        self.call(
            KERNEL_CC_FLOOD,
            n_pad,
            vec![
                CallArg::Fresh(Arg { data: adj.to_vec(), dims: vec![n_pad as i64, n_pad as i64] }),
                CallArg::Fresh(Arg { data: labels.to_vec(), dims: vec![n_pad as i64] }),
            ],
        )
    }

    fn check_block(&self, n_pad: usize, matrix: &[f32], vecs: &[&[f32]]) -> Result<()> {
        if !self.rungs.contains(&n_pad) {
            bail!("block size {n_pad} is not a compiled rung {:?}", self.rungs);
        }
        if matrix.len() != n_pad * n_pad {
            bail!("matrix has {} elements, want {}", matrix.len(), n_pad * n_pad);
        }
        for v in vecs {
            if v.len() != n_pad {
                bail!("vector has {} elements, want {n_pad}", v.len());
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parse_rejects_garbage() {
        let dir = std::env::temp_dir().join(format!("gf_manifest_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.txt"), "bad line\n").unwrap();
        assert!(parse_manifest(&dir).is_err());
        std::fs::write(dir.join("manifest.txt"), "").unwrap();
        assert!(parse_manifest(&dir).is_err());
        std::fs::write(dir.join("manifest.txt"), "pagerank_step f.hlo.txt 64 1\n").unwrap();
        let e = parse_manifest(&dir).unwrap();
        assert_eq!(e.len(), 1);
        assert_eq!(e[0].rung, 64);
    }

    #[test]
    fn missing_dir_fails_fast() {
        assert!(XlaEngine::load(Path::new("/nonexistent/artifacts")).is_err());
    }

    // Engine-vs-scalar numeric tests live in rust/tests/xla_runtime.rs
    // (they need `make artifacts` to have run).
}
