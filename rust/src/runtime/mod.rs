//! XLA/PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them from Gopher's hot path.
//!
//! Python never runs here — the artifacts are compiled once at build
//! time (`make artifacts`) and the Rust binary is self-contained.
//!
//! Thread-model note: the `xla` crate's `PjRtClient` is `Rc`-based
//! (!Send), while Gopher workers are OS threads. [`XlaEngine`] therefore
//! runs a dedicated *service thread* that owns the client and the
//! compiled-executable cache; workers talk to it through a channel. XLA's
//! CPU backend parallelises inside a single execute call, so one service
//! thread does not serialise the math — and it mirrors the deployment
//! the paper's §7 envisions (one accelerator context per host).

pub mod engine;

pub use engine::{XlaEngine, KERNEL_CC_FLOOD, KERNEL_PAGERANK_LOCAL, KERNEL_PAGERANK_STEP, KERNEL_SSSP_RELAX};

use std::path::PathBuf;

/// Default artifacts directory: `$GOFFISH_ARTIFACTS` or `./artifacts`.
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var_os("GOFFISH_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}
