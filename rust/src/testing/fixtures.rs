//! Shared graph/partitioning fixtures for integration tests and
//! benches.
//!
//! Before this module, every test file (and `benches/common`) carried
//! its own ad-hoc `random_graph`/`arbitrary_partitioning` copy with
//! slightly different mixes; failures were hard to replay across
//! files. These builders are the union of those mixes, driven entirely
//! by the caller's [`Rng`], so a failing case is reproducible from the
//! seed alone (the `testing::prop` harness prints it).

use crate::graph::{gen, Graph};
use crate::partition::{
    HashPartitioner, MultilevelPartitioner, Partitioner, Partitioning, RangePartitioner,
};
use crate::util::rng::Rng;

/// Mixed-shape random graph: road analog, preferential-attachment
/// social, synthetic trace (hub-heavy), or Erdős–Rényi — the graph
/// families the paper's Table 1 datasets span. Sized for integration
/// tests (tens to a few hundred vertices).
pub fn random_graph(rng: &mut Rng) -> Graph {
    match rng.index(4) {
        0 => gen::road(6 + rng.index(12), 0.8 + rng.f64() * 0.19, 0.03, rng.next_u64()),
        1 => gen::social(80 + rng.index(220), 2 + rng.index(3), rng.f64() * 0.15, rng.next_u64()),
        2 => gen::trace(100 + rng.index(400), 10 + rng.index(20), rng.f64() * 0.4, rng.next_u64()),
        _ => gen::erdos_renyi(40 + rng.index(110), 0.03, rng.chance(0.5), rng.next_u64()),
    }
}

/// Small sparse Erdős–Rényi graph (2–121 vertices): cheap enough for
/// hundreds of property cases.
pub fn small_graph(rng: &mut Rng) -> Graph {
    let n = 2 + rng.index(120);
    gen::erdos_renyi(n, rng.f64() * 0.1, rng.chance(0.5), rng.next_u64())
}

/// Half the time, put random weights in [0.1, 9.9] on `g`.
pub fn maybe_weighted(rng: &mut Rng, g: Graph) -> Graph {
    if rng.chance(0.5) {
        gen::with_random_weights(&g, 0.1, 9.9, rng.next_u64())
    } else {
        g
    }
}

/// Random partitioning of `g`: hash, range, or multilevel, with
/// 1 ≤ k ≤ 5.
pub fn random_partitioning(rng: &mut Rng, g: &Graph) -> Partitioning {
    let k = 1 + rng.index(5);
    match rng.index(3) {
        0 => HashPartitioner::new(rng.next_u64()).partition(g, k),
        1 => RangePartitioner.partition(g, k),
        _ => MultilevelPartitioner::new(rng.next_u64()).partition(g, k),
    }
}

/// The three Table-1 dataset analogs at `scale`, with the fixed seeds
/// (RN=11, TR=22, LJ=33) every figure bench uses — so numbers are
/// comparable across benches and across CI runs.
pub fn datasets(scale: f64) -> Vec<(&'static str, Graph)> {
    vec![
        ("RN", gen::rn_analog(scale, 11)),
        ("TR", gen::tr_analog(scale, 22)),
        ("LJ", gen::lj_analog(scale, 33)),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builders_are_deterministic_in_the_seed() {
        let shape = |g: &Graph| (g.num_vertices(), g.num_edges(), g.directed());
        let a = random_graph(&mut Rng::new(7));
        let b = random_graph(&mut Rng::new(7));
        assert_eq!(shape(&a), shape(&b));
        let pa = random_partitioning(&mut Rng::new(9), &a);
        let pb = random_partitioning(&mut Rng::new(9), &b);
        assert_eq!(pa.assignment(), pb.assignment());
        let sa = small_graph(&mut Rng::new(3));
        let sb = small_graph(&mut Rng::new(3));
        assert_eq!(shape(&sa), shape(&sb));
    }

    #[test]
    fn datasets_carry_fixed_names_and_seeds() {
        let d1 = datasets(0.05);
        let d2 = datasets(0.05);
        assert_eq!(d1.len(), 3);
        for ((n1, g1), (n2, g2)) in d1.iter().zip(&d2) {
            assert_eq!(n1, n2);
            assert_eq!(g1.num_vertices(), g2.num_vertices());
            assert_eq!(g1.num_edges(), g2.num_edges());
        }
        assert_eq!(
            d1.iter().map(|(n, _)| *n).collect::<Vec<_>>(),
            vec!["RN", "TR", "LJ"]
        );
    }

    #[test]
    fn partitionings_cover_all_vertices() {
        let mut rng = Rng::new(41);
        for _ in 0..10 {
            let base = random_graph(&mut rng);
            let g = maybe_weighted(&mut rng, base);
            let p = random_partitioning(&mut rng, &g);
            assert_eq!(p.num_vertices(), g.num_vertices());
        }
    }
}
