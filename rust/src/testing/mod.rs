//! Mini property-testing harness (proptest is not in the offline vendor
//! set; DESIGN.md §3).
//!
//! [`prop`] runs a generator+checker pair over many seeded cases and, on
//! failure, reports the failing seed so the case can be replayed:
//!
//! ```no_run
//! // (no_run: doctest binaries miss the xla rpath in this image)
//! use goffish::testing::prop;
//! prop("sorted after sort", 100, |rng| {
//!     let mut v: Vec<u64> = (0..rng.index(20)).map(|_| rng.next_u64()).collect();
//!     v.sort_unstable();
//!     v
//! }, |v| {
//!     if v.windows(2).all(|w| w[0] <= w[1]) { Ok(()) } else { Err("unsorted".into()) }
//! });
//! ```

pub mod fixtures;

use crate::util::rng::Rng;

/// Base seed; override with `GOFFISH_PROP_SEED` to replay a failure.
fn base_seed() -> u64 {
    std::env::var("GOFFISH_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x60FF_15D0)
}

/// Run `cases` property checks. `generate` builds a case from a seeded
/// RNG; `check` returns `Err(reason)` to fail. Panics with the seed and
/// case index on the first failure.
pub fn prop<T, G, C>(name: &str, cases: usize, mut generate: G, check: C)
where
    G: FnMut(&mut Rng) -> T,
    C: Fn(&T) -> Result<(), String>,
    T: std::fmt::Debug,
{
    let base = base_seed();
    for i in 0..cases {
        let seed = base.wrapping_add(i as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        let case = generate(&mut rng);
        if let Err(reason) = check(&case) {
            panic!(
                "property '{name}' failed on case {i} (replay with \
                 GOFFISH_PROP_SEED={base}): {reason}\ncase: {case:?}"
            );
        }
    }
}

/// Like [`prop`] but the checker gets the RNG too (for randomised
/// oracles or follow-up operations).
pub fn prop_with_rng<T, G, C>(name: &str, cases: usize, mut generate: G, check: C)
where
    G: FnMut(&mut Rng) -> T,
    C: Fn(&T, &mut Rng) -> Result<(), String>,
    T: std::fmt::Debug,
{
    let base = base_seed();
    for i in 0..cases {
        let seed = base.wrapping_add(i as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        let case = generate(&mut rng);
        let mut rng2 = Rng::new(seed ^ 0xABCD);
        if let Err(reason) = check(&case, &mut rng2) {
            panic!(
                "property '{name}' failed on case {i} (replay with \
                 GOFFISH_PROP_SEED={base}): {reason}\ncase: {case:?}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        prop("trivial", 50, |rng| rng.index(10), |_x| Ok(()));
        count += 1;
        assert_eq!(count, 1);
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_panics_with_seed() {
        prop("always fails", 10, |rng| rng.index(5), |_x| Err("nope".into()));
    }

    #[test]
    fn generator_sees_different_seeds() {
        let mut seen = std::collections::HashSet::new();
        prop(
            "distinct",
            30,
            |rng| rng.next_u64(),
            |x| {
                let _ = x;
                Ok(())
            },
        );
        // Re-generate manually to check dispersion.
        for i in 0..30u64 {
            let seed = base_seed().wrapping_add(i).wrapping_mul(0x9E3779B97F4A7C15);
            seen.insert(Rng::new(seed).next_u64());
        }
        assert!(seen.len() >= 29);
    }
}
